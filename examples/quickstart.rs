//! Quickstart: simulate a small TetriInfer cluster on a mixed workload and
//! compare it against the coupled vLLM baseline.
//!
//!   cargo run --release --example quickstart

use tetri_infer::baseline::{run_baseline, BaselineConfig};
use tetri_infer::coordinator::{run_cluster, ClusterConfig};
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn main() {
    // 64 mixed requests arriving at 8/s (chat + summarization + creation).
    let trace = WorkloadGen::new(7).trace(WorkloadKind::Mixed, 64, 8.0, 0);

    // TetriInfer: one prefill + one decode instance, paper defaults
    // (SJF prefill scheduling, chunked prefill at 512 tokens, parallel
    // length predictor at 74.9% accuracy, power-of-two dispatch,
    // reserve-dynamic decode admission, RoCE-200Gbps KV links).
    let tetri = run_cluster(ClusterConfig::ts_roce(1, 1), trace.clone());

    // Vanilla vLLM: one coupled instance, continuous batching, fixed
    // prefill batch 16, greedy memory policy.
    let vllm = run_baseline(BaselineConfig { n_instances: 1, ..Default::default() }, trace);

    println!("== quickstart: 64 mixed requests, 8 req/s ==");
    for (name, m) in [("TetriInfer", &tetri), ("vLLM", &vllm)] {
        let t = m.ttft_summary();
        let j = m.jct_summary();
        println!(
            "{name:<10}  TTFT mean {:>7.1} ms (p99 {:>7.1})   JCT mean {:>8.1} ms (p99 {:>8.1})   resource {:>5.1}s",
            t.mean, t.p99, j.mean, j.p99, m.resource_seconds()
        );
    }
    println!("{}", tetri.vs_row("TetriInfer vs vLLM", &vllm));
}
