//! Quickstart: simulate a small TetriInfer cluster on a mixed workload and
//! compare it against the coupled vLLM baseline — all through the
//! declarative `api::Scenario` front door.
//!
//!   cargo run --release --example quickstart

use tetri_infer::api::Scenario;
use tetri_infer::workload::WorkloadKind;

fn main() {
    // 64 mixed requests arriving at 8/s (chat + summarization + creation).
    // TetriInfer: one prefill + one decode instance, paper defaults
    // (SJF prefill scheduling, chunked prefill at 512 tokens, parallel
    // length predictor at 74.9% accuracy, power-of-two dispatch,
    // reserve-dynamic decode admission, RoCE-200Gbps KV links).
    let sc = Scenario::builder()
        .name("quickstart")
        .workload(WorkloadKind::Mixed)
        .requests(64)
        .rate(8.0)
        .seed(7)
        .build();

    let tetri = sc.run().expect("builtin driver");
    // Vanilla vLLM: one coupled instance, continuous batching, fixed
    // prefill batch 16, greedy memory policy — the same trace and seeds.
    let vllm = sc.baseline_counterpart().run().expect("builtin driver");

    println!("== quickstart: 64 mixed requests, 8 req/s ==");
    println!("{}", sc.summary_line());
    for r in [&tetri, &vllm] {
        println!("{}", r.summary_line());
    }
    println!("{}", tetri.vs_row("TetriInfer vs vLLM", &vllm));
    println!("(the same run, from the CLI: tetri sim --workload Mixed --requests 64 --rate 8 --seed 7)");
}
