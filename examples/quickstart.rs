//! Quickstart: simulate a small TetriInfer cluster on a mixed workload and
//! compare it against the coupled vLLM baseline — all through the
//! declarative `api::Scenario` front door.
//!
//!   cargo run --release --example quickstart

use tetri_infer::api::{ClassSpec, Scenario};
use tetri_infer::prefill::PrefillPolicy;
use tetri_infer::workload::WorkloadKind;

fn main() {
    // 64 mixed requests arriving at 8/s (chat + summarization + creation).
    // TetriInfer: one prefill + one decode instance, paper defaults
    // (SJF prefill scheduling, chunked prefill at 512 tokens, parallel
    // length predictor at 74.9% accuracy, power-of-two dispatch,
    // reserve-dynamic decode admission, RoCE-200Gbps KV links).
    let sc = Scenario::builder()
        .name("quickstart")
        .workload(WorkloadKind::Mixed)
        .requests(64)
        .rate(8.0)
        .seed(7)
        .build();

    let tetri = sc.run().expect("builtin driver");
    // Vanilla vLLM: one coupled instance, continuous batching, fixed
    // prefill batch 16, greedy memory policy — the same trace and seeds.
    let vllm = sc.baseline_counterpart().run().expect("builtin driver");

    println!("== quickstart: 64 mixed requests, 8 req/s ==");
    println!("{}", sc.summary_line());
    for r in [&tetri, &vllm] {
        println!("{}", r.summary_line());
    }
    println!("{}", tetri.vs_row("TetriInfer vs vLLM", &vllm));
    println!("(the same run, from the CLI: tetri sim --workload Mixed --requests 64 --rate 8 --seed 7)");

    // The same cluster as a multi-tenant deployment: three workload
    // classes with TTFT/TPOT deadlines and priority tiers, deadline-aware
    // (SLO-EDF) prefill scheduling, and the admission gate armed. The
    // report now answers the production question — who meets their
    // deadlines, and at what cost (goodput/$ instead of raw perf/$).
    let slo = Scenario::builder()
        .name("quickstart-slo")
        .workload(WorkloadKind::Mixed)
        .requests(64)
        .rate(8.0)
        .seed(7)
        .prefill_policy(PrefillPolicy::Slo)
        .admission(true)
        .class(ClassSpec {
            name: "chat".into(),
            weight: 0.5,
            tier: 0,
            ttft_ms: Some(400.0),
            tpot_ms: Some(120.0),
            ..Default::default()
        })
        .class(ClassSpec {
            name: "summarize".into(),
            weight: 0.25,
            tier: 1,
            ttft_ms: Some(4_000.0),
            tpot_ms: Some(250.0),
            ..Default::default()
        })
        .class(ClassSpec {
            name: "batch".into(),
            weight: 0.25,
            tier: 2,
            rate_limit: Some(4.0),
            burst: Some(8.0),
            ..Default::default()
        })
        .build();
    let tetri_slo = slo.run().expect("builtin driver");
    let vllm_slo = slo.baseline_counterpart().run().expect("builtin driver");
    println!("\n== quickstart-slo: same trace, 3 SLO classes, admission on ==");
    println!("{}", tetri_slo.summary_line());
    for row in tetri_slo.metrics.class_rows() {
        println!("{row}");
    }
    println!("{}", tetri_slo.vs_row("TetriInfer vs vLLM (SLO lens)", &vllm_slo));
    println!(
        "(CLI: tetri sim --spec scenarios/slo_mixed.json — or compose --class/--admission flags)"
    );
}
