//! Perf probe: break down one real decode iteration (upload / execute /
//! download) to target the §Perf optimization.
use std::time::Instant;
use tetri_infer::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let e = Engine::load("artifacts")?;
    let m = &e.manifest;
    let d = &m.decode;
    let pool = e.decode_pool_numel();
    let mut kp = vec![0f32; pool];
    let mut vp = vec![0f32; pool];
    let tokens = vec![1i32; d.batch];
    let positions = vec![4i32; d.batch];
    let bt: Vec<i32> = (0..d.batch * d.max_pages_per_req).map(|i| (1 + i % (d.n_pages - 1)) as i32).collect();
    let lens = vec![5i32; d.batch];
    // warm up
    e.decode_step(&tokens, &positions, &mut kp, &mut vp, &bt, &lens)?;
    let t = Instant::now();
    let n = 20;
    for _ in 0..n {
        e.decode_step(&tokens, &positions, &mut kp, &mut vp, &bt, &lens)?;
    }
    println!("decode_step: {:.1} ms/iter (pool {:.1} MB x2 in+out)", t.elapsed().as_secs_f64()*1e3/n as f64, pool as f64*4.0/1e6);

    // prefill
    let kvn = e.prefill_kv_numel();
    let mut k = vec![0f32; kvn];
    let mut v = vec![0f32; kvn];
    let toks = vec![1i32; m.model.chunk];
    e.prefill_segment(&toks, 0, m.model.chunk as i32, &mut k, &mut v)?;
    let t = Instant::now();
    for _ in 0..n {
        e.prefill_segment(&toks, 0, m.model.chunk as i32, &mut k, &mut v)?;
    }
    println!("prefill_segment: {:.1} ms/chunk (cache {:.1} MB x2)", t.elapsed().as_secs_f64()*1e3/n as f64, kvn as f64*4.0/1e6);

    // predictor
    let ptoks = vec![1i32; m.predictor.max_prompt];
    let t = Instant::now();
    for _ in 0..n { e.predict_len(&ptoks, 10)?; }
    println!("predict_len: {:.2} ms", t.elapsed().as_secs_f64()*1e3/n as f64);
    Ok(())
}
