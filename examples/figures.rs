//! Regenerate every table/figure in the paper's evaluation (see DESIGN.md's
//! experiment index). Each `figN` prints the same rows/series the paper
//! reports and writes them to results/figN.txt; the end-to-end figures
//! additionally write machine-readable results/figN.json through the
//! unified `api::Report` serializer.
//!
//!   cargo run --release --example figures -- all
//!   cargo run --release --example figures -- fig12 fig16 flip
//!
//! Every simulated run is constructed through `api::Scenario` — the same
//! declarative specs `tetri sim --spec` loads (scenarios/ ships the
//! headline setups), so a figure row is reproducible from the CLI with
//! the matching spec file. Figures are independent deterministic runs, so
//! they fan out across the sweep harness's worker pool; the heavyweight
//! multi-seed figures additionally sweep their own cells. Output files
//! are identical to a serial run — only the stdout interleaving varies.
//!
//! Absolute numbers come from the calibrated V100/OPT-13B cost model; the
//! comparisons (who wins, by what factor, where crossovers fall) are the
//! reproduction target (EXPERIMENTS.md records paper-vs-measured).

use std::fmt::Write as _;
use std::fs;

use tetri_infer::api::{LinkSpec, Report, Scenario};
use tetri_infer::coordinator::PredictorMode;
use tetri_infer::costmodel::CostModel;
use tetri_infer::decode::DecodePolicy;
use tetri_infer::fabric::Granularity;
use tetri_infer::prefill::{DispatchPolicy, PrefillPolicy};
use tetri_infer::sweep::{default_workers, parallel_map, results_csv, results_json, run_cells, SweepCell};
use tetri_infer::types::TaskType;
use tetri_infer::util::{summarize, Json};
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

const SEED: u64 = 42;
/// §5.1 runs 128 requests; a moderate Poisson rate keeps both systems in
/// their steady-state serving regime (the paper's stress setting).
const N_REQ: usize = 128;
const RATE: f64 = 8.0;

fn out(name: &str, body: &str) {
    fs::create_dir_all("results").ok();
    fs::write(format!("results/{name}.txt"), body).unwrap();
    println!("{body}");
}

fn out_json(name: &str, doc: &Json) {
    fs::create_dir_all("results").ok();
    fs::write(format!("results/{name}.json"), doc.dump()).unwrap();
}

/// The §5.1 end-to-end base scenario (mirrors scenarios/figNN.json).
fn e2e_scenario(kind: WorkloadKind, name: &str) -> Scenario {
    Scenario::builder()
        .name(name)
        .workload(kind)
        .requests(N_REQ)
        .rate(RATE)
        .seed(SEED)
        .build()
}

fn run(sc: &Scenario) -> Report {
    sc.run().expect("figure scenario must resolve")
}

// ---------------------------------------------------------------- fig 1

fn fig1() {
    let mut s = String::new();
    writeln!(s, "== Figure 1: token length distributions per downstream task ==").unwrap();
    writeln!(s, "{:<16} {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8}", "task", "p-p50", "p-p90", "p-p99", "d-p50", "d-p90", "d-p99").unwrap();
    let mut gen = WorkloadGen::new(SEED);
    for task in TaskType::ALL {
        let mut ps = vec![];
        let mut ds = vec![];
        for _ in 0..20_000 {
            let (p, d) = gen.sample_lengths(task);
            ps.push(p as f64);
            ds.push(d as f64);
        }
        let (sp, sd) = (summarize(&ps), summarize(&ds));
        writeln!(
            s,
            "{:<16} {:>8.0} {:>8.0} {:>8.0} | {:>8.0} {:>8.0} {:>8.0}",
            task.name(), sp.p50, sp.p90, sp.p99, sd.p50, sd.p90, sd.p99
        )
        .unwrap();
    }
    writeln!(s, "paper: chat prompts ~18 median / answers ~128; summarization = long-prompt/short-decode; creation = opposite; spans >2 orders of magnitude").unwrap();
    out("fig1", &s);
}

// ---------------------------------------------------------------- fig 2

fn fig2() {
    let m = CostModel::default();
    let mut s = String::new();
    writeln!(s, "== Figure 2: prefill saturates at ~512 tokens; decode plateaus with batch ==").unwrap();
    writeln!(s, "prefill: tokens  latency_ms  thpt_tok_s").unwrap();
    for t in [16u32, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
        writeln!(s, "  {:>6} {:>10.1} {:>11.0}", t, m.prefill_iter_us(t) as f64 / 1e3, m.prefill_throughput(t)).unwrap();
    }
    writeln!(s, "decode (ctx 512/seq): batch  latency_ms  thpt_tok_s  util_vs_peak").unwrap();
    let peak = m.decode_throughput(256, 256 * 512);
    for b in [1u32, 4, 16, 32, 64, 128, 256] {
        let thpt = m.decode_throughput(b, b as u64 * 512);
        writeln!(s, "  {:>5} {:>10.1} {:>11.0} {:>8.2}", b, m.decode_iter_us(b, b as u64 * 512) as f64 / 1e3, thpt, thpt / peak).unwrap();
    }
    out("fig2", &s);
}

// ---------------------------------------------------------------- fig 3

fn fig3() {
    let m = CostModel::default();
    let lp = 18u32;
    let hp = 512u32;
    let mut s = String::new();
    writeln!(s, "== Figure 3: prefill+prefill interference (batched iteration latency) ==").unwrap();
    let solo = m.prefill_iter_us(lp) as f64;
    writeln!(s, "(a) light prefill + N light prefill   (paper: 2x @8, 8x @64)").unwrap();
    for n in [1u32, 2, 4, 8, 16, 32, 64] {
        let lat = m.prefill_iter_us(n * lp) as f64;
        writeln!(s, "  n={:<3} latency {:>8.1} ms  slowdown {:>5.1}x", n, lat / 1e3, lat / solo).unwrap();
    }
    writeln!(s, "(b) light prefill + N heavy prefill   (paper: >10x)").unwrap();
    for n in [1u32, 2, 4, 8] {
        let lat = m.prefill_iter_us(lp + n * hp) as f64;
        writeln!(s, "  n={:<3} latency {:>8.1} ms  slowdown {:>5.1}x", n, lat / 1e3, lat / solo).unwrap();
    }
    let hsolo = m.prefill_iter_us(hp) as f64;
    writeln!(s, "(c) heavy prefill + N light prefill   (paper: ~3x @63)").unwrap();
    for n in [7u32, 15, 31, 63] {
        let lat = m.prefill_iter_us(hp + n * lp) as f64;
        writeln!(s, "  n={:<3} latency {:>8.1} ms  slowdown {:>5.1}x", n, lat / 1e3, lat / hsolo).unwrap();
    }
    out("fig3", &s);
}

// ---------------------------------------------------------------- fig 4

fn fig4() {
    let m = CostModel::default();
    let mut s = String::new();
    writeln!(s, "== Figure 4: prefill+decode interference in one continuous batch ==").unwrap();
    let dec_solo = m.mixed_iter_us(0, 8, 8 * 100) as f64;
    writeln!(s, "(a) light decode (bs=8, ctx 100) + N light prefill (18 tok)").unwrap();
    for n in [0u32, 1, 2, 4, 8, 16] {
        let lat = m.mixed_iter_us(n * 18, 8, 8 * 100) as f64;
        writeln!(s, "  n={:<3} iter {:>8.1} ms  decode slowdown {:>5.1}x", n, lat / 1e3, lat / dec_solo).unwrap();
    }
    writeln!(s, "(b) light decode + N heavy prefill (512 tok)   (paper: 5x @1)").unwrap();
    for n in [0u32, 1, 2, 4] {
        let lat = m.mixed_iter_us(n * 512, 8, 8 * 100) as f64;
        writeln!(s, "  n={:<3} iter {:>8.1} ms  decode slowdown {:>5.1}x", n, lat / 1e3, lat / dec_solo).unwrap();
    }
    let lp_solo = m.mixed_iter_us(18, 0, 0) as f64;
    writeln!(s, "(c) light prefill + N light decode   (paper: ~2.5x, kicks in past ~7)").unwrap();
    for n in [0u32, 4, 8, 16, 32, 64] {
        let lat = m.mixed_iter_us(18, n, n as u64 * 100) as f64;
        writeln!(s, "  n={:<3} iter {:>8.1} ms  prefill slowdown {:>5.2}x", n, lat / 1e3, lat / lp_solo).unwrap();
    }
    let hp_solo = m.mixed_iter_us(512, 0, 0) as f64;
    writeln!(s, "(d) heavy prefill + N light decode").unwrap();
    for n in [0u32, 8, 16, 32, 64] {
        let lat = m.mixed_iter_us(512, n, n as u64 * 100) as f64;
        writeln!(s, "  n={:<3} iter {:>8.1} ms  prefill slowdown {:>5.2}x", n, lat / 1e3, lat / hp_solo).unwrap();
    }
    out("fig4", &s);
}

// ---------------------------------------------------------------- fig 5

fn fig5() {
    let m = CostModel::default();
    let mut s = String::new();
    writeln!(s, "== Figure 5: decode+decode interference (bs=128, light ctx 60, heavy ctx 512) ==").unwrap();
    writeln!(s, "(paper @50% heavy: throughput -16%, latency +23%)").unwrap();
    let base_lat = m.decode_iter_us(128, 128 * 60) as f64;
    let base_thpt = m.decode_throughput(128, 128 * 60);
    for heavy_pct in [0u32, 25, 50, 75, 100] {
        let nh = 128 * heavy_pct / 100;
        let kv = nh as u64 * 512 + (128 - nh) as u64 * 60;
        let lat = m.decode_iter_us(128, kv) as f64;
        let thpt = m.decode_throughput(128, kv);
        writeln!(
            s,
            "  heavy {:>3}%  latency {:>7.1} ms ({:+5.0}%)  thpt {:>6.0} tok/s ({:+5.0}%)",
            heavy_pct, lat / 1e3, (lat / base_lat - 1.0) * 100.0, thpt, (thpt / base_thpt - 1.0) * 100.0
        )
        .unwrap();
    }
    out("fig5", &s);
}

// ------------------------------------------------------- figs 11-15 (e2e)

fn e2e_row(s: &mut String, label: &str, r: &Report, base: &Report) {
    let t = r.metrics.ttft_summary();
    let j = r.metrics.jct_summary();
    writeln!(
        s,
        "  {:<12} TTFT {:>8.1} ms  JCT {:>9.1} ms  resource {:>7.1} s  perf/$ {:>5.2}x",
        label,
        t.mean,
        j.mean,
        r.metrics.resource_seconds(),
        r.perf_per_dollar_vs(base)
    )
    .unwrap();
}

fn e2e(kind: WorkloadKind, fig: &str, paper_note: &str) {
    let mut s = String::new();
    writeln!(s, "== {fig}: end-to-end {} (n={N_REQ}, poisson {RATE}/s) ==", kind.name()).unwrap();
    let sc = e2e_scenario(kind, fig);
    let base = run(&sc.baseline_counterpart());
    let roce = run(&sc);
    let nv = run(&Scenario { link: LinkSpec::Nvlink, ..sc.clone() });
    e2e_row(&mut s, "vLLM", &base, &base);
    e2e_row(&mut s, "TS-RoCE", &roce, &base);
    e2e_row(&mut s, "TS-NVLink", &nv, &base);
    writeln!(s, "  {}", roce.vs_row("TS-RoCE vs vLLM", &base)).unwrap();
    writeln!(s, "  paper: {paper_note}").unwrap();
    out(fig, &s);
    out_json(
        fig,
        &Json::obj([
            ("roce_vs_vllm", roce.comparison_json(&base)),
            ("nvlink", nv.to_json()),
        ]),
    );
}

// ---------------------------------------------------------------- fig 16

fn fig16() {
    let mut s = String::new();
    writeln!(s, "== Figure 16: prefill scheduler policies & chunked prefill ==").unwrap();
    // Steady mixed serving (decodes present, so the baseline exhibits its
    // fixed-batch waiting + interference): prefill latency = TTFT.
    let steady = Scenario::builder()
        .name("fig16")
        .workload(WorkloadKind::Mixed)
        .requests(256)
        .rate(16.0)
        .seed(SEED)
        .build();
    let base = run(&steady.baseline_counterpart());
    writeln!(s, "  vLLM fixed-batch(16): avg prefill latency {:>8.1} ms", base.metrics.ttft_summary().mean).unwrap();
    let mut chunked = vec![];
    for pol in [PrefillPolicy::Fcfs, PrefillPolicy::Sjf, PrefillPolicy::Ljf] {
        let m = run(&Scenario { prefill_policy: pol, ..steady.clone() });
        writeln!(s, "  chunked {:<5}       : avg prefill latency {:>8.1} ms", pol.name(), m.metrics.ttft_summary().mean).unwrap();
        chunked.push((pol, m.metrics.ttft_summary().mean));
    }
    let fcfs = chunked[0].1;
    writeln!(s, "  chunked FCFS vs vLLM: {:+.1}%   (paper: -86.4%)", (fcfs / base.metrics.ttft_summary().mean - 1.0) * 100.0).unwrap();
    writeln!(s, "  SJF vs FCFS: {:+.1}%   (paper: -7.8% wait)", (chunked[1].1 / fcfs - 1.0) * 100.0).unwrap();
    writeln!(s, "  -- right: SJF TTFT vs PrefillSchedBatch (batch arrival backlog; paper: 16->128 = -46.5%) --").unwrap();
    // A standing backlog (batch arrival) is where the sort window matters:
    // the paper's own example is "twenty requests awaiting scheduling".
    let backlog = Scenario { rate: 0.0, ..steady.clone() };
    let mut first = None;
    for batch in [16usize, 32, 64, 128] {
        let m = run(&Scenario { sched_batch: batch, ..backlog.clone() });
        let v = m.metrics.ttft_summary().mean;
        if first.is_none() {
            first = Some(v);
        }
        writeln!(s, "  PrefillSchedBatch {:>4}: avg TTFT {:>8.1} ms ({:+.1}%)", batch, v, (v / first.unwrap() - 1.0) * 100.0).unwrap();
    }
    out("fig16", &s);
}

// ---------------------------------------------------------------- fig 17

fn fig17() {
    let mut s = String::new();
    writeln!(s, "== Figure 17: running the length predictor alongside the main LLM ==").unwrap();
    let sc = Scenario::builder()
        .name("fig17")
        .workload(WorkloadKind::Mixed)
        .requests(256)
        .rate(32.0)
        .seed(SEED)
        .build();
    let alone = run(&Scenario { predictor: PredictorMode::Disabled, ..sc.clone() });
    let par = run(&Scenario { predictor: PredictorMode::Parallel, ..sc.clone() });
    let seq = run(&Scenario { predictor: PredictorMode::Sequential, ..sc });
    let (alone, par, seq) = (
        alone.metrics.ttft_summary().mean,
        par.metrics.ttft_summary().mean,
        seq.metrics.ttft_summary().mean,
    );
    writeln!(s, "  L-Alone     : avg prefill latency {alone:>8.1} ms").unwrap();
    writeln!(
        s,
        "  L+P parallel: avg prefill latency {par:>8.1} ms ({:+.1}%)  (paper: +10%, thpt -12%)",
        (par / alone - 1.0) * 100.0
    )
    .unwrap();
    writeln!(
        s,
        "  L+P sequential: avg prefill latency {seq:>8.1} ms ({:+.1}%)  (prediction on the critical path)",
        (seq / alone - 1.0) * 100.0
    )
    .unwrap();
    writeln!(s, "  predictor model itself is ~10x faster than the target (costmodel::predictor_iter_us)").unwrap();
    out("fig17", &s);
}

// ---------------------------------------------------------------- fig 18

fn fig18() {
    let mut s = String::new();
    writeln!(s, "== Figure 18: intra-decode scheduling (160 heavy-decode reqs @10/s, 1 decode inst) ==").unwrap();
    writeln!(s, "(paper: RD==greedy at acc-200 74.9%; RD -12% / RS -10% JCT at acc 100%)").unwrap();
    let sc = Scenario::builder()
        .name("fig18")
        .workload(WorkloadKind::Lphd)
        .requests(160)
        .rate(10.0)
        .seed(SEED)
        .build();
    for (acc, label) in [(0.749, "acc-200 (74.9%)"), (1.0, "acc-ideal (100%)")] {
        writeln!(s, "  -- {label} --").unwrap();
        let mut greedy_jct = None;
        for pol in [DecodePolicy::Greedy, DecodePolicy::ReserveStatic, DecodePolicy::ReserveDynamic] {
            let m = run(&Scenario { decode_policy: pol, predictor_accuracy: acc, ..sc.clone() });
            let jct = m.metrics.jct_summary().mean;
            let g = *greedy_jct.get_or_insert(jct);
            writeln!(
                s,
                "  {:<16} avg JCT {:>9.1} ms ({:+5.1}% vs greedy)  swapped {:>8} tokens",
                pol.name(), jct, (jct / g - 1.0) * 100.0, m.metrics.swapped_tokens
            )
            .unwrap();
        }
    }
    out("fig18", &s);
}

// ---------------------------------------------------------------- fig 19

fn fig19() {
    let mut s = String::new();
    writeln!(s, "== Figure 19: inter-decode load balancing (32 reqs per decode instance) ==").unwrap();
    writeln!(s, "(paper: power-of-two lowest total decode time; heavy decodes spread evenly)").unwrap();
    const SEEDS: [u64; 5] = [42, 43, 44, 45, 46];
    const POLICIES: [DispatchPolicy; 3] =
        [DispatchPolicy::PowerOfTwo, DispatchPolicy::Random, DispatchPolicy::Imbalance];
    // 3 cluster sizes × 3 policies × 5 seeds = 45 independent scenarios:
    // sweep them all at once, then aggregate in cell order.
    let mut cells = Vec::new();
    for n_dec in [2usize, 4, 8] {
        for pol in POLICIES {
            for seed in SEEDS {
                cells.push(SweepCell::new(
                    format!("{n_dec}d/{}/s{seed}", pol.name()),
                    Scenario::builder()
                        .workload(WorkloadKind::Mixed)
                        .requests(32 * n_dec)
                        .rate(32.0)
                        .seed(seed)
                        .topology(1, n_dec)
                        .dispatch(pol)
                        .build(),
                ));
            }
        }
    }
    let results = run_cells(cells, default_workers());
    let mut it = results.iter();
    for n_dec in [2usize, 4, 8] {
        writeln!(s, "  -- {n_dec} decode instances (mean over {} seeds) --", SEEDS.len()).unwrap();
        for pol in POLICIES {
            let mut tot_time = 0.0;
            let mut tot_h = 0.0;
            let mut tot_l = 0.0;
            for _ in SEEDS {
                let m = &it.next().expect("cell/aggregation order mismatch").report.metrics;
                tot_time += m.makespan_us as f64 / 1e6;
                // slowest decode instance = the busiest one
                let slowest = (0..m.busy_us.len())
                    .filter(|&i| m.decode_assign[i].0 + m.decode_assign[i].1 > 0)
                    .max_by_key(|&i| m.busy_us[i])
                    .unwrap_or(0);
                tot_h += m.decode_assign[slowest].0 as f64;
                tot_l += m.decode_assign[slowest].1 as f64;
            }
            let n = SEEDS.len() as f64;
            writeln!(
                s,
                "  {:<13} total decode time {:>7.1} s  slowest instance: {:>5.1} heavy / {:>5.1} light",
                pol.name(),
                tot_time / n,
                tot_h / n,
                tot_l / n
            )
            .unwrap();
        }
    }
    out("fig19", &s);
}

// ------------------------------------------------------------ flip (§3.5)

fn flip() {
    let mut s = String::new();
    writeln!(s, "== §3.5: instance flip under load shift ==").unwrap();
    // Phase 1 floods prefill-heavy work, phase 2 is decode-heavy: with a
    // short idle threshold the spare prefill instance flips to decode
    // (scenarios/flip.json is this exact spec).
    let sc = Scenario::builder()
        .name("flip")
        .seed(SEED)
        .topology(2, 1)
        .flip_idle_ms(Some(2_000.0))
        .phase(WorkloadKind::Hpld, 64, 16.0, 0.0)
        .phase(WorkloadKind::Lphd, 96, 16.0, 8_000.0)
        .build();
    let m = run(&sc);
    let no_flip = run(&Scenario { flip_idle_ms: None, ..sc });
    writeln!(
        s,
        "  with flips   : {} flips, JCT {:>9.1} ms, makespan {:>6.1} s",
        m.metrics.flips,
        m.metrics.jct_summary().mean,
        m.metrics.makespan_us as f64 / 1e6
    )
    .unwrap();
    writeln!(
        s,
        "  without flips: 0 flips, JCT {:>9.1} ms, makespan {:>6.1} s",
        no_flip.metrics.jct_summary().mean,
        no_flip.metrics.makespan_us as f64 / 1e6
    )
    .unwrap();
    writeln!(s, "  (mechanism cost is 5-7 ms per flip, excluding drain — §3.5)").unwrap();
    out("flip", &s);
}

// --------------------------------------------- SLO multi-tenancy (goodput)

/// The DistServe/Arrow lens over the shipped SLO specs: per-class
/// TTFT/TPOT attainment, shed counts, and goodput/$ for every driver,
/// under steady mixed load and under overload (where admission sheds the
/// low tiers to protect tier 0). Writes results/slo.{txt,csv,json}.
fn slo() {
    let mut s = String::new();
    writeln!(s, "== SLO multi-tenancy: per-class attainment, sheds, goodput/$ ==").unwrap();
    let mut cells = Vec::new();
    for spec in ["slo_mixed", "slo_overload"] {
        let path = tetri_infer::util::repo_root().join(format!("scenarios/{spec}.json"));
        let sc = Scenario::load(path.to_str().unwrap()).expect("shipped SLO spec parses");
        for driver in ["tetri", "vllm", "hybrid"] {
            cells.push(SweepCell::new(
                format!("{spec}/{driver}"),
                Scenario { driver: driver.to_string(), ..sc.clone() },
            ));
        }
    }
    let results = run_cells(cells, default_workers());
    for chunk in results.chunks(3) {
        // per spec: the vllm cell (index 1) is the goodput/$ reference
        let base = &chunk[1].report;
        for cell in chunk {
            let m = &cell.report.metrics;
            writeln!(
                s,
                "  {:<24} finished {:>4}  shed {:>4}  goodput {:>6.2} req/s  goodput/$ {:>5.2}x",
                cell.label,
                m.n_finished(),
                m.shed,
                m.goodput_rps(),
                m.goodput_per_dollar_vs(&base.metrics),
            )
            .unwrap();
            for row in m.class_rows() {
                writeln!(s, "  {row}").unwrap();
            }
        }
    }
    writeln!(
        s,
        "  (overload spec: tier-2 sheds absorb the spike so tier-0 attainment holds — \
         the report's per-class rows above show the split)"
    )
    .unwrap();
    out("slo", &s);
    fs::create_dir_all("results").ok();
    fs::write("results/slo.csv", results_csv(&results)).unwrap();
    out_json("slo", &results_json(&results));
}

// ------------------------------------------------ chaos (fault tolerance)

/// The recovery study (DESIGN.md §Fault tolerance): the shipped
/// crash/restart spec against its fault-free twin (same trace, no plan),
/// then the compound chaos-storm schedule under every driver — the
/// conservation ledger (finished + shed + failed == arrivals) and the
/// loss-to-finish recovery latency are the headline columns. Writes
/// results/chaos.{txt,json}.
fn chaos() {
    let mut s = String::new();
    writeln!(s, "== chaos: crash -> requeue-with-backoff -> restart -> re-expansion ==").unwrap();
    let path = tetri_infer::util::repo_root().join("scenarios/chaos_crash.json");
    let faulted = Scenario::load(path.to_str().unwrap()).expect("shipped chaos spec parses");
    let twin = Scenario { faults: None, ..faulted.clone() };
    let mut cells = vec![
        SweepCell::new("chaos_crash/faulted".to_string(), faulted.clone()),
        SweepCell::new("chaos_crash/fault-free".to_string(), twin),
    ];
    let storm_path = tetri_infer::util::repo_root().join("scenarios/chaos_storm.json");
    let storm = Scenario::load(storm_path.to_str().unwrap()).expect("shipped storm spec parses");
    for driver in ["tetri", "vllm", "hybrid"] {
        cells.push(SweepCell::new(
            format!("chaos_storm/{driver}"),
            Scenario { driver: driver.to_string(), ..storm.clone() },
        ));
    }
    let results = run_cells(cells, default_workers());
    for cell in &results {
        let m = &cell.report.metrics;
        writeln!(
            s,
            "  {:<22} finished {:>4}  shed {:>3}  failed {:>3}  recovered {:>3}  \
             faults {:>2}  resends {:>2}  degraded {:>6.1} ms  JCT {:>9.1} ms",
            cell.label,
            m.finished,
            m.shed,
            m.failed,
            m.recovered,
            m.faults_injected,
            m.transfer_resends,
            m.degraded_us as f64 / 1e3,
            m.jct_summary().mean,
        )
        .unwrap();
        if m.recovered > 0 {
            let r = m.recovery_hist.summary_scaled(1e-3);
            writeln!(
                s,
                "  {:<22}   recovery (loss -> finish): mean {:>8.1} ms  p50 {:>8.1}  p99 {:>8.1}",
                "", r.mean, r.p50, r.p99
            )
            .unwrap();
        }
    }
    writeln!(
        s,
        "  (every row conserves arrivals across the three ledgers; the faulted crash run \
         pays its recovery tail while the fault-free twin is bit-identical to the \
         pre-fault-subsystem trajectory — tests/golden.rs pins both)"
    )
    .unwrap();
    out("chaos", &s);
    out_json("chaos", &results_json(&results));
}

// ------------------------------------------------ prefix cache (KV reuse)

/// The prefix-cache study (DESIGN.md §Prefix cache): the shipped reuse
/// spec against its cache-off twin, then a hit-rate sweep over the
/// prefix-population size (fewer distinct prefixes → more reuse) —
/// TTFT and prefill-tokens-saved as a function of the achieved hit rate,
/// plus the layer-wise transfer overlap the warm runs bank. Writes
/// results/cache.{txt,csv,json}.
fn cache() {
    use tetri_infer::api::PrefixSpec;
    let mut s = String::new();
    writeln!(s, "== prefix cache: radix KV reuse — TTFT & tokens saved vs hit rate ==").unwrap();
    let path = tetri_infer::util::repo_root().join("scenarios/prefix_reuse.json");
    let warm = Scenario::load(path.to_str().unwrap()).expect("shipped prefix spec parses");
    let spec = warm.prefix.expect("prefix_reuse.json carries a prefix block");
    // cold twin: no stamps, no cache — the golden/property tests pin that
    // this is bit-identical to a stamped run with the cache off
    let mut cells = vec![SweepCell::new(
        "cache/cold".to_string(),
        Scenario { prefix: None, ..warm.clone() },
    )];
    for n in [256u32, 64, 16, 8, 2] {
        cells.push(SweepCell::new(
            format!("cache/warm-{n}p"),
            Scenario {
                prefix: Some(PrefixSpec { n_prefixes: n, ..spec }),
                ..warm.clone()
            },
        ));
    }
    let results = run_cells(cells, default_workers());
    let cold_ttft = results[0].report.metrics.ttft_summary().mean;
    for cell in &results {
        let m = &cell.report.metrics;
        writeln!(
            s,
            "  {:<16} hit rate {:>5.1}%  saved {:>8} tok  TTFT {:>8.1} ms ({:+5.1}%)  \
             JCT {:>9.1} ms  overlap {:>7.1} ms",
            cell.label,
            m.cache_hit_rate() * 100.0,
            m.prefill_tokens_saved,
            m.ttft_summary().mean,
            (m.ttft_summary().mean / cold_ttft - 1.0) * 100.0,
            m.jct_summary().mean,
            m.overlap_us as f64 / 1e3,
        )
        .unwrap();
    }
    writeln!(
        s,
        "  (monotone lever: shrinking the prefix population raises the hit rate, \
         which cuts prefill work and TTFT; overlap is the transfer time the \
         layer-wise granularity hid behind prefill compute)"
    )
    .unwrap();
    out("cache", &s);
    fs::create_dir_all("results").ok();
    fs::write("results/cache.csv", results_csv(&results)).unwrap();
    out_json("cache", &results_json(&results));
}

// ------------------------------------------- pareto (topology search)

/// The optimizer tentpole figure (DESIGN.md §Optimizer): the shipped
/// goodput-per-dollar topology search over 2–12-instance clusters —
/// the Pareto frontier of goodput vs $/hr with the recommended cell
/// marked, plus the work-saved accounting (tests/golden.rs pins the
/// frontier itself). Writes results/pareto.{txt,csv,json}.
fn pareto() {
    use tetri_infer::optimizer;
    let mut s = String::new();
    writeln!(s, "== pareto: goodput-per-dollar topology search (scenarios/optimize_mixed.json) ==").unwrap();
    let path = tetri_infer::util::repo_root().join("scenarios/optimize_mixed.json");
    let sc = Scenario::load(path.to_str().unwrap()).expect("shipped optimize spec parses");
    let res = optimizer::optimize(&sc, default_workers()).expect("search runs");
    let rec_label = res.recommended_cell().map(|c| c.label.clone());
    writeln!(s, "  {:<22} {:>10} {:>9} {:>12}", "cell", "goodput", "$/hr", "goodput/$hr").unwrap();
    for cell in &res.frontier {
        let m = &cell.report.metrics;
        let star = if Some(&cell.label) == rec_label.as_ref() { "  <- recommended" } else { "" };
        writeln!(
            s,
            "  {:<22} {:>10.3} {:>9.2} {:>12.6}{star}",
            cell.label,
            m.goodput_rps(),
            optimizer::cost_per_hr(m),
            optimizer::value_of(m),
        )
        .unwrap();
    }
    let st = &res.stats;
    writeln!(
        s,
        "  (searched {} cells in {} rungs: {} halved, {} SLO-pruned, {} dominance-pruned, \
         {} full runs — {:.3} of the exhaustive grid's events)",
        st.grid_cells,
        st.rungs,
        st.halving_discarded,
        st.pruned_slo,
        st.pruned_dominance,
        st.full_runs,
        st.fraction_of_exhaustive(),
    )
    .unwrap();
    out("pareto", &s);
    fs::create_dir_all("results").ok();
    fs::write("results/pareto.csv", res.frontier_csv()).unwrap();
    out_json("pareto", &res.to_json());
}

// ------------------------------------- telemetry (latency attribution)

/// The observability figure (DESIGN.md §Telemetry): "where did my latency
/// go?" — the shipped telemetry demo plus the overload spec under every
/// driver, each with the span machine armed. Prints each run's per-phase
/// breakdown rows (queue vs prefill vs transfer vs decode, % of request
/// time), writes the demo's sampler series CSV and its Perfetto trace
/// (open in ui.perfetto.dev), and results/telemetry.{txt,json}.
fn telemetry() {
    use tetri_infer::api::TelemetrySpec;
    let mut s = String::new();
    writeln!(s, "== telemetry: per-phase latency attribution (spans + sampler) ==").unwrap();
    let demo_path = tetri_infer::util::repo_root().join("scenarios/telemetry_demo.json");
    let demo = Scenario::load(demo_path.to_str().unwrap()).expect("shipped telemetry spec parses");
    let over_path = tetri_infer::util::repo_root().join("scenarios/slo_overload.json");
    let over = Scenario::load(over_path.to_str().unwrap()).expect("shipped SLO spec parses");
    let mut cells = vec![SweepCell::new("telemetry_demo/tetri".to_string(), demo)];
    for driver in ["tetri", "vllm", "hybrid"] {
        cells.push(SweepCell::new(
            format!("slo_overload/{driver}"),
            Scenario {
                driver: driver.to_string(),
                telemetry: Some(TelemetrySpec { sample_ms: 20.0, max_samples: 1024, trace: false }),
                ..over.clone()
            },
        ));
    }
    let results = run_cells(cells, default_workers());
    for cell in &results {
        let t = cell.report.telemetry.as_ref().expect("armed cells distill a summary");
        writeln!(
            s,
            "  {:<24} {} spans, {} samples, {:.1} ms of request time accounted",
            cell.label,
            t.spans,
            t.series.len(),
            t.accounted_ms(),
        )
        .unwrap();
        for line in t.breakdown_lines() {
            writeln!(s, "    {line}").unwrap();
        }
    }
    // the demo spec arms trace=true: keep its Perfetto export and series
    // around next to the figure text (the same files `tetri sim --trace
    // --series` would write)
    let demo_t = results[0].report.telemetry.as_ref().expect("demo cell is armed");
    fs::create_dir_all("results").ok();
    fs::write("results/telemetry.series.csv", demo_t.series_csv()).unwrap();
    let trace = demo_t.trace.as_ref().expect("telemetry_demo.json arms trace");
    fs::write("results/telemetry.trace.json", trace.dump()).unwrap();
    writeln!(s, "  (trace: results/telemetry.trace.json — open in ui.perfetto.dev;").unwrap();
    writeln!(s, "   series: results/telemetry.series.csv — queue/KV/shed over virtual time)").unwrap();
    out("telemetry", &s);
    out_json("telemetry", &results_json(&results));
}

// ------------------------------------------------- ablation (§3.3.4 disc.)

fn ablation() {
    let mut s = String::new();
    writeln!(s, "== ablation: KV transfer granularity (§3.3.4 discussion) ==").unwrap();
    writeln!(s, "(heavy prompts over the slow Indirect/socket link, where wire time is exposed)").unwrap();
    let slow = Scenario::builder()
        .name("ablation_transfer")
        .workload(WorkloadKind::Hphd)
        .requests(64)
        .rate(8.0)
        .seed(SEED)
        .link(LinkSpec::Socket)
        .build();
    for (label, gran) in [
        ("request-level", Granularity::RequestLevel),
        ("chunk-level", Granularity::ChunkLevel),
        ("layer-level", Granularity::LayerLevel),
    ] {
        let m = run(&Scenario { transfer: gran, ..slow.clone() });
        writeln!(
            s,
            "  {:<14} JCT mean {:>9.1} ms  p99 {:>9.1} ms",
            label,
            m.metrics.jct_summary().mean,
            m.metrics.jct_summary().p99
        )
        .unwrap();
    }
    writeln!(s, "  (the paper implements request-level and leaves chunk-level to future work)").unwrap();
    out("ablation_transfer", &s);

    // ---- SRTF preemptive chunk assembly (§3.3.1's noted future work)
    let mut s = String::new();
    writeln!(s, "== ablation: SRTF preemptive chunked prefill (§3.3.1 future work) ==").unwrap();
    writeln!(s, "(prefill-latency view: short prompts preempt long ones at chunk boundaries)").unwrap();
    let backlog = Scenario::builder()
        .name("ablation_srtf")
        .workload(WorkloadKind::Mixed)
        .requests(256)
        .rate(0.0)
        .seed(SEED)
        .build();
    for (label, srtf) in [("SJF + FIFO chunks", false), ("SJF + SRTF chunks", true)] {
        let m = run(&Scenario { srtf_chunking: srtf, ..backlog.clone() });
        writeln!(
            s,
            "  {:<18} avg TTFT {:>8.1} ms  p99 {:>8.1} ms",
            label,
            m.metrics.ttft_summary().mean,
            m.metrics.ttft_summary().p99
        )
        .unwrap();
    }
    out("ablation_srtf", &s);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let all = args.is_empty() || args.iter().any(|a| a == "all");
    let want = |n: &str| all || args.iter().any(|a| a == n);

    // Every figure is an independent deterministic run writing its own
    // results/ file, so fan the requested set across the sweep pool.
    let mut tasks: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    if want("fig1") {
        tasks.push(Box::new(fig1));
    }
    if want("fig2") {
        tasks.push(Box::new(fig2));
    }
    if want("fig3") {
        tasks.push(Box::new(fig3));
    }
    if want("fig4") {
        tasks.push(Box::new(fig4));
    }
    if want("fig5") {
        tasks.push(Box::new(fig5));
    }
    if want("fig11") {
        tasks.push(Box::new(|| e2e(WorkloadKind::Lpld, "fig11", "TTFT -44%, JCT -40%, perf/$ 1.4x")));
    }
    if want("fig12") {
        tasks.push(Box::new(|| {
            e2e(WorkloadKind::Lphd, "fig12", "TTFT -97%, JCT -47%, resource -38%, perf/$ 2.4x")
        }));
    }
    if want("fig13") {
        tasks.push(Box::new(|| {
            e2e(WorkloadKind::Hpld, "fig13", "TTFT -9%, JCT -23%, resource +43%, perf/$ 0.86x (vLLM wins)")
        }));
    }
    if want("fig14") {
        tasks.push(Box::new(|| e2e(WorkloadKind::Hphd, "fig14", "JCT -19%, resource +7%, perf/$ 1.1x")));
    }
    if want("fig15") {
        tasks.push(Box::new(|| {
            e2e(WorkloadKind::Mixed, "fig15", "TTFT -85%, JCT -50%, resource -21%, perf/$ 1.9x")
        }));
    }
    if want("fig16") {
        tasks.push(Box::new(fig16));
    }
    if want("fig17") {
        tasks.push(Box::new(fig17));
    }
    if want("fig18") {
        tasks.push(Box::new(fig18));
    }
    if want("fig19") {
        tasks.push(Box::new(fig19));
    }
    if want("flip") {
        tasks.push(Box::new(flip));
    }
    if want("slo") {
        tasks.push(Box::new(slo));
    }
    if want("chaos") {
        tasks.push(Box::new(chaos));
    }
    if want("cache") {
        tasks.push(Box::new(cache));
    }
    if want("pareto") {
        tasks.push(Box::new(pareto));
    }
    if want("telemetry") {
        tasks.push(Box::new(telemetry));
    }
    if want("ablation") {
        tasks.push(Box::new(ablation));
    }
    parallel_map(tasks, default_workers(), |task| task());
}
