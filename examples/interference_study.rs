//! The §2.2 interference study end-to-end: instead of reading iteration
//! latencies off the cost model (examples/figures.rs does that for the
//! microbenchmark series), this drives whole *serving runs* through the
//! coupled baseline and shows how victim requests suffer when co-located
//! with aggressors — then shows TetriInfer's disaggregation removing the
//! interference.
//!
//! The victim+aggressor traces are hand-stitched (two generators with
//! offset ids), which a declarative `Scenario` can't express — so this
//! example drives the `api::Driver` layer directly: registry-resolved
//! drivers fed explicit traces. That is exactly what the Driver trait is
//! for; everything scenario-shaped should go through `api::Scenario`.
//!
//!   cargo run --release --example interference_study

use tetri_infer::api::{Driver, NullObserver, Registry, Scenario};
use tetri_infer::metrics::RunMetrics;
use tetri_infer::types::Request;
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

/// Mean JCT (ms) of the subset of records matching `pred`.
fn mean_jct(m: &RunMetrics, pred: impl Fn(&tetri_infer::types::RequestRecord) -> bool) -> f64 {
    let xs: Vec<f64> = m.records.iter().filter(|r| pred(r)).map(|r| r.jct() as f64 / 1e3).collect();
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

fn mean_ttft(m: &RunMetrics, pred: impl Fn(&tetri_infer::types::RequestRecord) -> bool) -> f64 {
    let xs: Vec<f64> = m.records.iter().filter(|r| pred(r)).map(|r| r.ttft() as f64 / 1e3).collect();
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

fn victims(seed: u64) -> Vec<Request> {
    // 32 light chat requests — the victims we measure.
    WorkloadGen::new(seed).trace(WorkloadKind::Lpld, 32, 16.0, 0)
}

/// Distinct generators both number requests from 0; shift the aggressors'
/// ids so a combined trace has unique ids.
fn offset_ids(mut v: Vec<Request>, base: u64) -> Vec<Request> {
    for r in &mut v {
        r.id += base;
    }
    v
}

fn main() {
    println!("== interference study (victim: 32 light chat requests @16/s) ==\n");
    // Registry-resolved drivers with default 1-prefill/1-decode scenarios;
    // the traces below are supplied explicitly.
    let registry = Registry::builtin();
    let sc = Scenario::default();
    let vllm = registry.resolve(&sc.baseline_counterpart()).expect("builtin driver");
    let tetri_drv = registry.resolve(&sc).expect("builtin driver");
    let run_baseline = |trace: Vec<Request>| vllm.run(&trace, &mut NullObserver).metrics;
    let run_cluster = |trace: Vec<Request>| tetri_drv.run(&trace, &mut NullObserver).metrics;

    // -- victims alone on one coupled instance
    let alone = run_baseline(victims(1));
    let solo_ttft = mean_ttft(&alone, |_| true);
    let solo_jct = mean_jct(&alone, |_| true);
    println!("victims alone          : TTFT {solo_ttft:>7.1} ms   JCT {solo_jct:>8.1} ms");

    // -- §2.2.1/§2.2.2: add heavy-prefill aggressors (summarization)
    let mut tr = victims(1);
    let mut gen = WorkloadGen::new(99);
    tr.extend(offset_ids(gen.trace(WorkloadKind::Hpld, 24, 16.0, 0), 1000));
    let hp = run_baseline(tr.clone());
    let is_victim = |r: &tetri_infer::types::RequestRecord| r.prompt_len <= 512 && r.decode_len <= 128;
    println!(
        "+ 24 heavy prefills    : TTFT {:>7.1} ms ({:>4.1}x)   JCT {:>8.1} ms ({:>4.1}x)   [vLLM coupled]",
        mean_ttft(&hp, is_victim),
        mean_ttft(&hp, is_victim) / solo_ttft,
        mean_jct(&hp, is_victim),
        mean_jct(&hp, is_victim) / solo_jct
    );

    // -- same mix on TetriInfer: disaggregation shields the victims
    let tetri = run_cluster(tr);
    println!(
        "  same on TetriInfer   : TTFT {:>7.1} ms ({:>4.1}x)   JCT {:>8.1} ms ({:>4.1}x)   [disaggregated]",
        mean_ttft(&tetri, is_victim),
        mean_ttft(&tetri, is_victim) / solo_ttft,
        mean_jct(&tetri, is_victim),
        mean_jct(&tetri, is_victim) / solo_jct
    );

    // -- §2.2.3: heavy-decode aggressors (creation)
    let mut tr = victims(1);
    tr.extend(offset_ids(gen.trace(WorkloadKind::Lphd, 24, 16.0, 0), 2000));
    let hd = run_baseline(tr.clone());
    println!(
        "+ 24 heavy decodes     : TTFT {:>7.1} ms ({:>4.1}x)   JCT {:>8.1} ms ({:>4.1}x)   [vLLM coupled]",
        mean_ttft(&hd, is_victim),
        mean_ttft(&hd, is_victim) / solo_ttft,
        mean_jct(&hd, is_victim),
        mean_jct(&hd, is_victim) / solo_jct
    );
    let tetri_hd = run_cluster(tr);
    println!(
        "  same on TetriInfer   : TTFT {:>7.1} ms ({:>4.1}x)   JCT {:>8.1} ms ({:>4.1}x)   [disaggregated]",
        mean_ttft(&tetri_hd, is_victim),
        mean_ttft(&tetri_hd, is_victim) / solo_ttft,
        mean_jct(&tetri_hd, is_victim),
        mean_jct(&tetri_hd, is_victim) / solo_jct
    );

    println!("\npaper's corresponding microbenchmarks: Figures 3-5 (see examples/figures.rs)");
}
