//! End-to-end real-mode driver: load the AOT'd model artifacts and serve a
//! batched mixed workload through the full disaggregated pipeline —
//! PJRT CPU execution, chunked prefill, real KV-cache transfer into the
//! paged decode pool, length-predictor-informed scheduling — and report
//! latency/throughput. This proves all three layers compose with Python
//! nowhere on the request path.
//!
//!   make artifacts && cargo run --release --example serve_e2e [n_requests]

use tetri_infer::fabric::Link;
use tetri_infer::runtime::Engine;
use tetri_infer::serve::{ServeConfig, Server};
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let engine = Engine::load("artifacts")?;
    let m = &engine.manifest;
    println!(
        "loaded model: d={} layers={} heads={} ctx={} chunk={} | decode batch={} pages={}x{}",
        m.model.d_model, m.model.n_layers, m.model.n_heads, m.model.max_seq, m.model.chunk,
        m.decode.batch, m.decode.n_pages, m.decode.page_size
    );
    println!(
        "length predictor: {} buckets @ granularity {} (fine-tuned acc@200 = {:?})",
        m.predictor.n_buckets, m.predictor.granularity, m.predictor_acc200
    );

    let mut gen = WorkloadGen::new(11);
    let trace = gen.trace(WorkloadKind::Mixed, n, 0.0, 0);
    println!("\nserving {n} mixed requests (chat/summarization/creation) ...");

    // Emulate the paper's TS-RoCE setup on KV transfers.
    let cfg = ServeConfig { emulate_link: Some(Link::roce200()), ..Default::default() };
    let report = Server::new(&engine, cfg).serve(trace, &mut gen)?;

    let t = report.metrics.ttft_summary();
    let j = report.metrics.jct_summary();
    println!("\n== results ==");
    println!(
        "requests {}   generated tokens {}   wall {:.2}s   throughput {:.1} tok/s",
        report.metrics.records.len(),
        report.generated_tokens,
        report.wall_secs,
        report.generated_tokens as f64 / report.wall_secs
    );
    println!(
        "TTFT mean {:.1} ms  p50 {:.1}  p99 {:.1}   |   JCT mean {:.1} ms  p50 {:.1}  p99 {:.1}",
        t.mean, t.p50, t.p99, j.mean, j.p50, j.p99
    );
    println!(
        "prefill chunks {}   decode iterations {}   KV transferred {:.2} MB",
        report.prefill_chunks, report.decode_iters, report.transfer_bytes as f64 / 1e6
    );
    println!("sample output tokens (req 0): {:?}", &report.sample_output[..report.sample_output.len().min(16)]);

    // Smoke checks: all requests served, deterministic token budget.
    assert_eq!(report.metrics.records.len(), n, "every request must complete");
    assert!(report.generated_tokens > 0);
    println!("\nOK: three-layer stack (rust coordinator -> AOT HLO -> pallas kernels) verified end-to-end");
    Ok(())
}
