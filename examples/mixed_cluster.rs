//! Cluster-scale scenario: a bigger disaggregated deployment (2 prefill +
//! 4 decode instances) serving sustained mixed traffic with instance
//! flipping enabled — the "cloud-scale" deployment of §3.2/§3.5.
//!
//!   cargo run --release --example mixed_cluster

use tetri_infer::coordinator::{run_cluster, ClusterConfig, FlipConfig};
use tetri_infer::prefill::DispatchPolicy;
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn main() {
    println!("== mixed_cluster: 2 prefill + 4 decode, 512 mixed requests @ 24/s ==\n");
    let trace = WorkloadGen::new(3).trace(WorkloadKind::Mixed, 512, 24.0, 0);

    for (label, dispatch) in [
        ("power-of-two", DispatchPolicy::PowerOfTwo),
        ("random", DispatchPolicy::Random),
        ("least-load", DispatchPolicy::LeastLoad),
    ] {
        let cfg = ClusterConfig {
            n_prefill: 2,
            n_decode: 4,
            dispatch,
            flip: Some(FlipConfig { idle_us: 10_000_000, ..Default::default() }),
            seed: 3,
            ..Default::default()
        };
        let m = run_cluster(cfg, trace.clone());
        let t = m.ttft_summary();
        let j = m.jct_summary();
        let assigns: Vec<String> = m
            .decode_assign
            .iter()
            .filter(|(h, l)| h + l > 0)
            .map(|(h, l)| format!("{h}H/{l}L"))
            .collect();
        println!(
            "{label:<13} TTFT {:>6.1} ms  JCT {:>8.1} ms (p99 {:>8.1})  makespan {:>5.1}s  util {:>4.1}%  flips {}",
            t.mean, j.mean, j.p99, m.makespan_us as f64 / 1e6, m.utilization() * 100.0, m.flips
        );
        println!("              decode assignment (heavy/light): {}", assigns.join("  "));
    }

    println!("\nscaling decode instances (power-of-two, same trace):");
    for n_dec in [2usize, 4, 8] {
        let cfg = ClusterConfig { n_prefill: 2, n_decode: n_dec, seed: 3, ..Default::default() };
        let m = run_cluster(cfg, trace.clone());
        println!(
            "  {} decode: JCT mean {:>8.1} ms  makespan {:>5.1}s  resource {:>6.1}s",
            n_dec,
            m.jct_summary().mean,
            m.makespan_us as f64 / 1e6,
            m.resource_seconds()
        );
    }
}
