//! Cluster-scale scenario: a bigger disaggregated deployment (2 prefill +
//! 4 decode instances) serving sustained mixed traffic with instance
//! flipping enabled — the "cloud-scale" deployment of §3.2/§3.5. Runs are
//! built through `api::Scenario`; a `TimelineObserver` streams per-event
//! hooks out of the DES to report per-instance busy time and chunk/iter
//! counts without touching the drivers.
//!
//!   cargo run --release --example mixed_cluster

use tetri_infer::api::{Scenario, TimelineObserver};
use tetri_infer::prefill::DispatchPolicy;
use tetri_infer::workload::WorkloadKind;

fn main() {
    println!("== mixed_cluster: 2 prefill + 4 decode, 512 mixed requests @ 24/s ==\n");
    let base = Scenario::builder()
        .name("mixed_cluster")
        .workload(WorkloadKind::Mixed)
        .requests(512)
        .rate(24.0)
        .seed(3)
        .topology(2, 4)
        .flip_idle_ms(Some(10_000.0))
        .build();

    for (label, dispatch) in [
        ("power-of-two", DispatchPolicy::PowerOfTwo),
        ("random", DispatchPolicy::Random),
        ("least-load", DispatchPolicy::LeastLoad),
    ] {
        let sc = Scenario { dispatch, ..base.clone() };
        let mut timeline = TimelineObserver::new();
        let r = sc.run_with(&mut timeline).expect("builtin driver");
        let m = &r.metrics;
        let t = m.ttft_summary();
        let j = m.jct_summary();
        let assigns: Vec<String> = m
            .decode_assign
            .iter()
            .filter(|(h, l)| h + l > 0)
            .map(|(h, l)| format!("{h}H/{l}L"))
            .collect();
        println!(
            "{label:<13} TTFT {:>6.1} ms  JCT {:>8.1} ms (p99 {:>8.1})  makespan {:>5.1}s  util {:>4.1}%  flips {}",
            t.mean, j.mean, j.p99, m.makespan_us as f64 / 1e6, m.utilization() * 100.0, m.flips
        );
        println!("              decode assignment (heavy/light): {}", assigns.join("  "));
        // Observer-side view: per-instance busy seconds straight from the
        // event stream ({} chunks / {} decode iters overall).
        let busy: Vec<String> = (0..6)
            .map(|i| format!("{:.1}s", timeline.busy_us(i) as f64 / 1e6))
            .collect();
        println!(
            "              observed busy/instance: {}   ({} chunks, {} decode iters, {} transfers)",
            busy.join(" "),
            timeline.chunks,
            timeline.decode_iters,
            timeline.transfers
        );
    }

    println!("\nscaling decode instances (power-of-two, same trace):");
    for n_dec in [2usize, 4, 8] {
        let sc = Scenario {
            n_decode: n_dec,
            dispatch: DispatchPolicy::PowerOfTwo,
            flip_idle_ms: Some(60_000.0),
            ..base.clone()
        };
        let r = sc.run().expect("builtin driver");
        println!(
            "  {} decode: JCT mean {:>8.1} ms  makespan {:>5.1}s  resource {:>6.1}s",
            n_dec,
            r.metrics.jct_summary().mean,
            r.metrics.makespan_us as f64 / 1e6,
            r.metrics.resource_seconds()
        );
    }
}
