"""Offline fine-tuning of the length-prediction model (paper §3.3.2, Fig 8).

Workflow mirrors the paper's: (1) assemble a prompt-only dataset, (2) label
each prompt with the bucketized length of the target model's generation —
here the synthetic ground-truth decode length from data.py — and (3) train
the small classifier to predict the bucket.

The paper fine-tunes OPT-125M with HuggingFace Trainer on 75K ShareGPT
prompts and reports 58.9% / 74.9% / 85% accuracy at granularity 100/200/400.
We train a 2-layer OPT-style classifier with a hand-rolled Adam loop (no
optax in this environment) and evaluate at the same three granularities;
the hint-noise in data.py is calibrated so accuracies land in the same
regime. Run standalone:  python -m compile.train_predictor
"""

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .config import Config, DEFAULT
from .model import init_predictor_params, predict_len

GRANULARITIES = (100, 200, 400)


def _batched_logits(params, toks, valid, cfg):
    return jax.vmap(lambda t, v: predict_len(params, t, v, cfg))(toks, valid)


def _loss(params, toks, valid, labels, cfg):
    logits = _batched_logits(params, toks, valid, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def _adam_update(params, grads, mom, vel, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    mom = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, mom, grads)
    vel = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, vel, grads)
    mh = jax.tree.map(lambda m: m / (1 - b1**step), mom)
    vh = jax.tree.map(lambda v: v / (1 - b2**step), vel)
    params = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mh, vh)
    return params, mom, vel


def train(cfg: Config = DEFAULT, n_train: int = 6000, n_eval: int = 1500,
          steps: int = 350, batch: int = 64, lr: float = 2e-3, seed: int = 0,
          verbose: bool = True):
    """Train the gran-200 classifier; returns (params, metrics dict)."""
    p = cfg.predictor
    toks, valid, dlens, _ = data.make_dataset(n_train, seed, p.max_prompt, p.vocab)
    etoks, evalid, edlens, _ = data.make_dataset(n_eval, seed + 1, p.max_prompt, p.vocab)
    labels = np.minimum(dlens // p.granularity, p.n_buckets - 1).astype(np.int32)

    params = init_predictor_params(jax.random.PRNGKey(seed), cfg)
    mom = jax.tree.map(jnp.zeros_like, params)
    vel = jax.tree.map(jnp.zeros_like, params)

    loss_and_grad = jax.jit(
        jax.value_and_grad(functools.partial(_loss, cfg=cfg)),
        static_argnames=(),
    )
    update = jax.jit(functools.partial(_adam_update, lr=lr))

    rng = np.random.default_rng(seed + 2)
    t0 = time.time()
    for step in range(1, steps + 1):
        idx = rng.integers(0, n_train, size=batch)
        lv, grads = loss_and_grad(
            params, jnp.asarray(toks[idx]), jnp.asarray(valid[idx]), jnp.asarray(labels[idx])
        )
        params, mom, vel = update(params, grads, mom, vel, step)
        if verbose and step % 100 == 0:
            print(f"  step {step:4d} loss {float(lv):.3f} ({time.time()-t0:.0f}s)")

    # Evaluate at the paper's three granularities. The model natively
    # predicts gran-200 buckets; coarser granularities merge buckets,
    # finer ones refine via the hint structure — evaluate gran-200 exactly
    # and derive gran-100/400 from the same predicted length range.
    logits = np.asarray(
        jax.jit(functools.partial(_batched_logits, cfg=cfg))(
            params, jnp.asarray(etoks), jnp.asarray(evalid)
        )
    )
    pred200 = logits.argmax(-1)
    metrics = {}
    true200 = np.minimum(edlens // 200, p.n_buckets - 1)
    metrics["acc_200"] = float((pred200 == true200).mean())
    # gran-400: merge adjacent gran-200 buckets.
    metrics["acc_400"] = float(((pred200 // 2) == np.minimum(edlens // 400, p.n_buckets // 2 - 1)).mean())
    # gran-100: the classifier only resolves 200-token ranges; predict the
    # lower 100-bucket of the range (upper-bounds paper behaviour of a
    # finer head being harder — reported as-is).
    metrics["acc_100"] = float(((pred200 * 2) == np.minimum(edlens // 100, 2 * p.n_buckets - 1)).mean())
    metrics["train_seconds"] = round(time.time() - t0, 1)
    metrics["n_train"] = n_train
    metrics["steps"] = steps
    return params, metrics


if __name__ == "__main__":
    params, metrics = train()
    print(json.dumps(metrics, indent=2))
    print("paper: acc_100=58.9% acc_200=74.9% acc_400=85.0%")
