"""Synthetic mixed-downstream workload generator (python side).

Stands in for the ShareGPT / pubmed-summarization / writing-doc datasets
the paper samples (Figure 1): three downstream task families whose prompt
and decode token lengths follow lognormal distributions calibrated to the
medians the paper reports (chat prompt median ~18, chat answer median ~128;
summarization = long prompt / short decode; creation = the opposite).

The rust workload module (rust/src/workload/) uses the *same* constants —
keep the two in sync (see DESIGN.md §Hardware-Adaptation).

Vocabulary layout (shared with the target model):
  0        PAD
  1..3     task marker token (chat/summarization/creation)
  16..47   length-hint tokens: quantized true decode length, the learnable
           signal standing in for "prompt content predicts answer length"
  64..511  filler body tokens
"""

import math

import numpy as np

TASK_CHAT, TASK_SUMM, TASK_CREATE = 0, 1, 2
TASK_NAMES = ["chat", "summarization", "creation"]

# (prompt_median, prompt_sigma, decode_median, decode_sigma) in tokens.
TASK_PARAMS = {
    TASK_CHAT: (18.0, 0.8, 128.0, 0.9),
    TASK_SUMM: (600.0, 0.5, 40.0, 0.7),
    TASK_CREATE: (25.0, 0.7, 600.0, 0.6),
}

HINT_BASE, HINT_LEVELS, HINT_GRAN = 16, 32, 50  # hint = dec_len bucketed at 50
FILLER_BASE = 64
MAX_DECODE = 1599
# Multiplicative log-noise on the hint: controls achievable prediction
# accuracy (calibrated so gran-200 accuracy lands near the paper's 74.9%).
HINT_SIGMA = 0.22


def sample_request(rng: np.random.Generator, task: int | None = None, vocab: int = 512):
    """Sample (task, prompt_tokens, decode_len). Prompt carries a noisy
    length hint; decode_len is the ground-truth generation length."""
    if task is None:
        task = int(rng.choice([TASK_CHAT, TASK_SUMM, TASK_CREATE], p=[0.5, 0.25, 0.25]))
    pm, ps, dm, ds = TASK_PARAMS[task]
    plen = int(np.clip(rng.lognormal(math.log(pm), ps), 2, 1024))
    dlen = int(np.clip(rng.lognormal(math.log(dm), ds), 1, MAX_DECODE))
    noisy = dlen * math.exp(HINT_SIGMA * rng.standard_normal())
    hint = HINT_BASE + min(int(noisy) // HINT_GRAN, HINT_LEVELS - 1)
    body = rng.integers(FILLER_BASE, vocab, size=max(plen - 2, 0))
    prompt = np.concatenate(([1 + task, hint], body)).astype(np.int32)
    return task, prompt, dlen


def bucketize(dlen: int, granularity: int, n_buckets: int) -> int:
    return min(dlen // granularity, n_buckets - 1)


def make_dataset(n: int, seed: int, max_prompt: int, vocab: int = 512):
    """Returns (tokens [n, max_prompt] i32, valid [n] i32, dec_lens [n] i32,
    tasks [n] i32). Prompts truncated/padded to max_prompt."""
    rng = np.random.default_rng(seed)
    toks = np.zeros((n, max_prompt), np.int32)
    valid = np.zeros((n,), np.int32)
    dlens = np.zeros((n,), np.int32)
    tasks = np.zeros((n,), np.int32)
    for i in range(n):
        task, prompt, dlen = sample_request(rng, vocab=vocab)
        t = prompt[:max_prompt]
        toks[i, : len(t)] = t
        valid[i] = len(t)
        dlens[i] = dlen
        tasks[i] = task
    return toks, valid, dlens, tasks
