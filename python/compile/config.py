"""Model/runtime configuration shared by the L1 kernels, L2 model, and AOT.

These are the *real-mode* shapes: a small OPT-style transformer that stands
in for OPT-13B (see DESIGN.md §Hardware-Adaptation). The rust coordinator
reads the same values from artifacts/manifest.json, so python and rust can
never disagree about shapes.
"""

from dataclasses import dataclass, asdict, field


@dataclass(frozen=True)
class ModelConfig:
    """Target model (stands in for OPT-13B)."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_head: int = 32
    d_ffn: int = 1024
    max_seq: int = 512          # per-request context window
    chunk: int = 64             # ChunkSize (real mode); sim mode uses 512

    @property
    def n_params(self) -> int:
        per_layer = (
            4 * self.d_model * self.d_model  # wq wk wv wo
            + 2 * self.d_model * self.d_ffn  # w1 w2
            + self.d_ffn + self.d_model      # b1 b2
            + 4 * self.d_model               # ln1/ln2 gains+biases
        )
        return (
            self.vocab * self.d_model        # tok emb (tied head)
            + self.max_seq * self.d_model    # pos emb
            + self.n_layers * per_layer
            + 2 * self.d_model               # final ln
        )


@dataclass(frozen=True)
class DecodeConfig:
    """Paged decode-instance shapes (vLLM-style paged KV)."""

    batch: int = 8              # static decode batch (continuous batching pads)
    page_size: int = 16         # tokens per KV page
    n_pages: int = 288          # shared pool; page 0 is the trash page
    # max pages one request may hold: ceil(max_seq / page_size)
    max_pages_per_req: int = 32


@dataclass(frozen=True)
class PredictorConfig:
    """Length-prediction classifier (stands in for OPT-125M)."""

    vocab: int = 512
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_head: int = 32
    d_ffn: int = 512
    max_prompt: int = 64        # prompts truncated/padded to this for classification
    n_buckets: int = 8          # predicted decode-length buckets
    granularity: int = 200      # tokens per bucket (paper: 100/200/400)

    @property
    def n_params(self) -> int:
        per_layer = (
            4 * self.d_model * self.d_model
            + 2 * self.d_model * self.d_ffn
            + self.d_ffn + self.d_model
            + 4 * self.d_model
        )
        return (
            self.vocab * self.d_model
            + self.max_prompt * self.d_model
            + self.n_layers * per_layer
            + 2 * self.d_model
            + self.d_model * self.n_buckets + self.n_buckets  # cls head
        )


@dataclass(frozen=True)
class Config:
    model: ModelConfig = field(default_factory=ModelConfig)
    decode: DecodeConfig = field(default_factory=DecodeConfig)
    predictor: PredictorConfig = field(default_factory=PredictorConfig)

    def to_dict(self) -> dict:
        return {
            "model": asdict(self.model),
            "decode": asdict(self.decode),
            "predictor": asdict(self.predictor),
        }


DEFAULT = Config()
