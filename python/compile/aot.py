"""AOT pipeline: lower the L2 entrypoints to HLO *text* + weight blobs.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under artifacts/):
  prefill.hlo.txt     prefill_segment(params, tokens, start, valid, k, v)
  decode.hlo.txt      decode_step(params, tokens, positions, kpool, vpool, bt, lens)
  predictor.hlo.txt   predict_len(pred_params, tokens, valid)
  params.bin          target-model weights, flat f32 LE, pytree-flatten order
  predictor_params.bin
  manifest.json       config + per-artifact argument specs + predictor metrics

Weights are runtime *arguments* (not baked constants) so the HLO stays
small; the rust runtime uploads each .bin once and keeps the device
buffers alive across calls (execute_b).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from .config import DEFAULT, Config
from .model import (
    decode_step,
    init_target_params,
    predict_len,
    prefill_segment,
)
from .train_predictor import train as train_predictor


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten_params(params):
    """Deterministic (path, leaf) list in jax pytree-flatten order."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in leaves_with_paths:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, np.asarray(leaf)))
    return out


def save_params_bin(params, path):
    """Concatenate all leaves (f32 LE) in flatten order; return spec list."""
    spec = []
    with open(path, "wb") as f:
        for name, arr in flatten_params(params):
            assert arr.dtype == np.float32, (name, arr.dtype)
            f.write(arr.astype("<f4").tobytes())
            spec.append({"name": name, "shape": list(arr.shape)})
    return spec


def _argspec(args):
    """Shape/dtype spec for the non-param arguments of an entrypoint."""
    return [
        {"name": name, "shape": list(a.shape), "dtype": str(a.dtype)}
        for name, a in args
    ]


def load_params_bin(path, template):
    """Inverse of save_params_bin: read a flat f32 blob back into the
    template pytree's structure (used by --reuse-predictor)."""
    leaves_with_paths, treedef = jax.tree_util.tree_flatten(template)
    raw = np.fromfile(path, dtype="<f4")
    out, off = [], 0
    for leaf in leaves_with_paths:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        out.append(jnp.asarray(raw[off : off + n].reshape(leaf.shape)))
        off += n
    assert off == raw.size, f"{path}: size mismatch"
    return jax.tree_util.tree_unflatten(treedef, out)


def build(cfg: Config, out_dir: str, seed: int = 0, quick: bool = False,
          skip_train: bool = False, reuse_predictor: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    m, d, p = cfg.model, cfg.decode, cfg.predictor

    # ------------------------------------------------------------- weights
    params = init_target_params(jax.random.PRNGKey(seed), cfg)
    param_spec = save_params_bin(params, os.path.join(out_dir, "params.bin"))

    pp_path = os.path.join(out_dir, "predictor_params.bin")
    if reuse_predictor and os.path.exists(pp_path):
        from .model import init_predictor_params

        template = init_predictor_params(jax.random.PRNGKey(seed + 7), cfg)
        pred_params = load_params_bin(pp_path, template)
        old = json.load(open(os.path.join(out_dir, "manifest.json")))
        metrics = old.get("predictor_metrics", {"note": "reused, metrics unknown"})
        print("reusing fine-tuned predictor weights")
    elif skip_train:
        from .model import init_predictor_params

        pred_params = init_predictor_params(jax.random.PRNGKey(seed + 7), cfg)
        metrics = {"acc_200": 1.0 / p.n_buckets, "note": "untrained (--skip-train)"}
    else:
        kwargs = dict(n_train=1500, n_eval=400, steps=120) if quick else {}
        print("training length predictor ...")
        pred_params, metrics = train_predictor(cfg, seed=seed, **kwargs)
        print(f"  acc@100/200/400 = {metrics['acc_100']:.3f} / "
              f"{metrics['acc_200']:.3f} / {metrics['acc_400']:.3f}")
    pred_spec = save_params_bin(pred_params, os.path.join(out_dir, "predictor_params.bin"))

    manifest = {
        "config": cfg.to_dict(),
        "predictor_metrics": metrics,
        "params": {"file": "params.bin", "leaves": param_spec},
        "predictor_params": {"file": "predictor_params.bin", "leaves": pred_spec},
        "workload": {
            "task_params": {
                data.TASK_NAMES[t]: dict(
                    zip(("prompt_median", "prompt_sigma", "decode_median", "decode_sigma"),
                        data.TASK_PARAMS[t])
                )
                for t in sorted(data.TASK_PARAMS)
            },
            "hint": {"base": data.HINT_BASE, "levels": data.HINT_LEVELS,
                     "gran": data.HINT_GRAN, "sigma": data.HINT_SIGMA},
            "max_decode": data.MAX_DECODE,
        },
        "artifacts": {},
    }

    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct

    # ------------------------------------------------------------- prefill
    kv_shape = (m.n_layers, m.max_seq, m.n_heads, m.d_head)
    pre_args = [
        ("tokens", sds((m.chunk,), i32)),
        ("start", sds((), i32)),
        ("valid", sds((), i32)),
        ("k_cache", sds(kv_shape, f32)),
        ("v_cache", sds(kv_shape, f32)),
    ]
    # donate the KV caches: input_output_alias survives HLO text, letting
    # XLA:CPU update them in place instead of copying (§Perf)
    lowered = jax.jit(
        functools.partial(prefill_segment, cfg=cfg), donate_argnums=(4, 5)
    ).lower(params, *[a for _, a in pre_args])
    with open(os.path.join(out_dir, "prefill.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["prefill"] = {
        "file": "prefill.hlo.txt",
        "params": "params",
        "args": _argspec(pre_args),
        "outputs": [
            {"name": "last_logits", "shape": [m.vocab]},
            {"name": "k_cache", "shape": list(kv_shape)},
            {"name": "v_cache", "shape": list(kv_shape)},
        ],
    }

    # -------------------------------------------------------------- decode
    pool_shape = (m.n_layers, d.n_pages * d.page_size, m.n_heads, m.d_head)
    dec_args = [
        ("tokens", sds((d.batch,), i32)),
        ("positions", sds((d.batch,), i32)),
        ("k_pool", sds(pool_shape, f32)),
        ("v_pool", sds(pool_shape, f32)),
        ("block_tables", sds((d.batch, d.max_pages_per_req), i32)),
        ("seq_lens", sds((d.batch,), i32)),
    ]
    lowered = jax.jit(
        functools.partial(decode_step, cfg=cfg), donate_argnums=(3, 4)
    ).lower(params, *[a for _, a in dec_args])
    with open(os.path.join(out_dir, "decode.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["decode"] = {
        "file": "decode.hlo.txt",
        "params": "params",
        "args": _argspec(dec_args),
        "outputs": [
            {"name": "logits", "shape": [d.batch, m.vocab]},
            {"name": "k_pool", "shape": list(pool_shape)},
            {"name": "v_pool", "shape": list(pool_shape)},
        ],
    }

    # ----------------------------------------------------------- predictor
    prd_args = [
        ("tokens", sds((p.max_prompt,), i32)),
        ("valid", sds((), i32)),
    ]
    lowered = jax.jit(functools.partial(predict_len, cfg=cfg)).lower(
        pred_params, *[a for _, a in prd_args]
    )
    with open(os.path.join(out_dir, "predictor.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["predictor"] = {
        "file": "predictor.hlo.txt",
        "params": "predictor_params",
        "args": _argspec(prd_args),
        "outputs": [{"name": "bucket_logits", "shape": [p.n_buckets]}],
    }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    for name, info in manifest["artifacts"].items():
        size = os.path.getsize(os.path.join(out_dir, info["file"]))
        print(f"  {name}: {info['file']} ({size/1e6:.1f} MB)")
    print(f"wrote {out_dir}/manifest.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="shorter predictor fine-tune (CI-speed)")
    ap.add_argument("--skip-train", action="store_true",
                    help="random predictor weights (artifacts only)")
    ap.add_argument("--reuse-predictor", action="store_true",
                    help="keep existing fine-tuned predictor_params.bin")
    args = ap.parse_args()
    build(DEFAULT, args.out_dir, seed=args.seed, quick=args.quick,
          skip_train=args.skip_train, reuse_predictor=args.reuse_predictor)


if __name__ == "__main__":
    main()
