"""L2: OPT-style transformer in JAX, calling the L1 Pallas kernels.

Three AOT entrypoints (all static-shape, lowered by aot.py to HLO text):

  * ``prefill_segment`` — run one ChunkSize-token chunk of one request's
    prompt, writing KV into that request's contiguous cache (the cache a
    prefill instance later *transfers* to a decode instance, §3.3.4).
  * ``decode_step``     — one continuous-batching iteration over the paged
    KV pool (vLLM-style block tables, §3.4).
  * ``predict_len``     — the OPT-125M-style classifier head used by the
    length predictor (§3.3.2).

Weights are *runtime arguments* (flattened pytree order, see aot.py), so
the HLO text stays small and the rust side feeds params.bin once, keeping
device buffers alive across calls (execute_b).

Model flavour follows OPT: learned positional embeddings, pre-LN blocks,
ReLU MLPs, tied input/output embedding.
"""

import jax
import jax.numpy as jnp

from .config import Config, DEFAULT
from .kernels.chunked_prefill import chunked_prefill_attention, causal_chunk_mask
from .kernels.paged_decode import paged_decode_attention
from .kernels.ref import NEG_INF


# ---------------------------------------------------------------------------
# Parameters


def init_layer(key, d, dff, scale=0.02):
    ks = jax.random.split(key, 6)
    g = lambda k, shape: (scale * jax.random.normal(k, shape)).astype(jnp.float32)
    return {
        "ln1_g": jnp.ones((d,), jnp.float32),
        "ln1_b": jnp.zeros((d,), jnp.float32),
        "wq": g(ks[0], (d, d)),
        "wk": g(ks[1], (d, d)),
        "wv": g(ks[2], (d, d)),
        "wo": g(ks[3], (d, d)),
        "ln2_g": jnp.ones((d,), jnp.float32),
        "ln2_b": jnp.zeros((d,), jnp.float32),
        "w1": g(ks[4], (d, dff)),
        "b1": jnp.zeros((dff,), jnp.float32),
        "w2": g(ks[5], (dff, d)),
        "b2": jnp.zeros((d,), jnp.float32),
    }


def init_target_params(key, cfg: Config = DEFAULT):
    m = cfg.model
    ks = jax.random.split(key, m.n_layers + 2)
    return {
        "tok_emb": (0.02 * jax.random.normal(ks[0], (m.vocab, m.d_model))).astype(jnp.float32),
        "pos_emb": (0.02 * jax.random.normal(ks[1], (m.max_seq, m.d_model))).astype(jnp.float32),
        "layers": [init_layer(ks[2 + i], m.d_model, m.d_ffn) for i in range(m.n_layers)],
        "lnf_g": jnp.ones((m.d_model,), jnp.float32),
        "lnf_b": jnp.zeros((m.d_model,), jnp.float32),
    }


def init_predictor_params(key, cfg: Config = DEFAULT):
    p = cfg.predictor
    ks = jax.random.split(key, p.n_layers + 3)
    return {
        "tok_emb": (0.02 * jax.random.normal(ks[0], (p.vocab, p.d_model))).astype(jnp.float32),
        "pos_emb": (0.02 * jax.random.normal(ks[1], (p.max_prompt, p.d_model))).astype(jnp.float32),
        "layers": [init_layer(ks[2 + i], p.d_model, p.d_ffn) for i in range(p.n_layers)],
        "lnf_g": jnp.ones((p.d_model,), jnp.float32),
        "lnf_b": jnp.zeros((p.d_model,), jnp.float32),
        "cls_w": (0.02 * jax.random.normal(ks[-1], (p.d_model, p.n_buckets))).astype(jnp.float32),
        "cls_b": jnp.zeros((p.n_buckets,), jnp.float32),
    }


def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _split_heads(x, h, dh):
    return x.reshape(x.shape[0], h, dh)


# ---------------------------------------------------------------------------
# Prefill (per-request contiguous KV, chunked — §3.3.3)


def prefill_segment(params, tokens, start, valid, k_cache, v_cache, cfg: Config = DEFAULT):
    """Prefill one chunk of one request.

    tokens:  [C] i32     chunk token ids (pad tail is arbitrary)
    start:   scalar i32  global position of tokens[0]
    valid:   scalar i32  number of real tokens in this chunk (1..C)
    k_cache: [L, S, H, Dh] request's contiguous KV cache (k)
    v_cache: [L, S, H, Dh]
    Returns (last_logits [V], k_cache', v_cache') where last_logits is the
    next-token distribution after the last *valid* token.
    """
    m = cfg.model
    c = m.chunk
    pos = jnp.clip(start + jnp.arange(c), 0, m.max_seq - 1)
    x = params["tok_emb"][tokens] + params["pos_emb"][pos]  # [C, d]
    mask = causal_chunk_mask(start, valid, c, m.max_seq)

    for li, lp in enumerate(params["layers"]):
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        q = _split_heads(h @ lp["wq"], m.n_heads, m.d_head)
        k = _split_heads(h @ lp["wk"], m.n_heads, m.d_head)
        v = _split_heads(h @ lp["wv"], m.n_heads, m.d_head)
        # Write this chunk's KV rows into the contiguous [L, ...] cache
        # (donated input → in-place update chain, no stack).
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None], (li, start, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None], (li, start, 0, 0))
        att = chunked_prefill_attention(q, k_cache[li], v_cache[li], mask)  # [C, H, Dh]
        x = x + att.reshape(c, m.d_model) @ lp["wo"]
        h2 = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + jax.nn.relu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]

    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    last = jax.lax.dynamic_index_in_dim(x, valid - 1, axis=0, keepdims=False)
    logits = last @ params["tok_emb"].T  # tied head, [V]
    return logits, k_cache, v_cache


# ---------------------------------------------------------------------------
# Decode (shared paged KV pool — §3.4)


def decode_step(params, tokens, positions, k_pool, v_pool, block_tables, seq_lens,
                cfg: Config = DEFAULT):
    """One decode iteration for a (padded) continuous batch.

    tokens:       [B] i32     current token per slot
    positions:    [B] i32     global position of that token (0-based)
    k_pool/v_pool:[L, P*psz, H, Dh] shared paged KV pool
    block_tables: [B, MaxP] i32
    seq_lens:     [B] i32     visible tokens per slot incl. current
                              (= positions + 1 for active slots)
    Inactive (padding) slots must point their block table at page 0, the
    trash page; the rust KV manager never hands out page 0 (proptest'd).
    Returns (logits [B, V], k_pool', v_pool').
    """
    m, d = cfg.model, cfg.decode
    b = d.batch
    psz = d.page_size
    pos = jnp.clip(positions, 0, m.max_seq - 1)
    x = params["tok_emb"][tokens] + params["pos_emb"][pos]  # [B, d]

    # Row in the flattened pool where each slot's current token lives.
    page_idx = positions // psz
    page = jnp.take_along_axis(block_tables, page_idx[:, None], axis=1)[:, 0]
    rows = page * psz + positions % psz  # [B]

    for li, lp in enumerate(params["layers"]):
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        q = _split_heads(h @ lp["wq"], m.n_heads, m.d_head)  # [B, H, Dh]
        k = _split_heads(h @ lp["wk"], m.n_heads, m.d_head)
        v = _split_heads(h @ lp["wv"], m.n_heads, m.d_head)
        # scatter new KV rows directly into the [L, ...] pool: with donated
        # inputs this chains into in-place updates (no per-layer stack —
        # EXPERIMENTS.md §Perf)
        k_pool = k_pool.at[li, rows].set(k)
        v_pool = v_pool.at[li, rows].set(v)
        att = paged_decode_attention(q, k_pool[li], v_pool[li], block_tables, seq_lens, psz)
        x = x + att.reshape(b, m.d_model) @ lp["wo"]
        h2 = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + jax.nn.relu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]

    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["tok_emb"].T  # [B, V]
    return logits, k_pool, v_pool


# ---------------------------------------------------------------------------
# Length predictor (§3.3.2) — small classifier, fixed-size batch, no chunking
# (the paper notes small models show no clear compute-saturate threshold).


def predict_len(params, tokens, valid, cfg: Config = DEFAULT):
    """Classify a prompt into a decode-length bucket.

    tokens: [PL] i32 (padded); valid: scalar i32. Returns bucket logits [NB].
    """
    p = cfg.predictor
    pl_len = p.max_prompt
    x = params["tok_emb"][tokens] + params["pos_emb"][jnp.arange(pl_len)]
    kj = jnp.arange(pl_len)
    # Bidirectional over real tokens only.
    mask = jnp.where(kj[None, :] < valid, 0.0, NEG_INF).astype(jnp.float32)

    for lp in params["layers"]:
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        q = _split_heads(h @ lp["wq"], p.n_heads, p.d_head)
        k = _split_heads(h @ lp["wk"], p.n_heads, p.d_head)
        v = _split_heads(h @ lp["wv"], p.n_heads, p.d_head)
        scale = 1.0 / jnp.sqrt(jnp.asarray(p.d_head, jnp.float32))
        s = jnp.einsum("chd,shd->hcs", q, k) * scale + mask[None]
        w = jax.nn.softmax(s, axis=-1)
        att = jnp.einsum("hcs,shd->chd", w, v).reshape(pl_len, p.d_model)
        x = x + att @ lp["wo"]
        h2 = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + jax.nn.relu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]

    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    keep = (kj < valid).astype(jnp.float32)[:, None]
    pooled = (x * keep).sum(0) / jnp.maximum(keep.sum(), 1.0)
    return pooled @ params["cls_w"] + params["cls_b"]


# ---------------------------------------------------------------------------
# Reference full-context forward (oracle for prefill/decode consistency)


def full_forward_ref(params, tokens, cfg: Config = DEFAULT):
    """Naive full-sequence causal forward; returns logits [T, V].

    Used only by tests: prefill chunks + decode steps must reproduce the
    same next-token logits this produces in one shot.
    """
    m = cfg.model
    t = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][jnp.arange(t)]
    causal = jnp.where(jnp.tril(jnp.ones((t, t), bool)), 0.0, NEG_INF)
    for lp in params["layers"]:
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        q = _split_heads(h @ lp["wq"], m.n_heads, m.d_head)
        k = _split_heads(h @ lp["wk"], m.n_heads, m.d_head)
        v = _split_heads(h @ lp["wv"], m.n_heads, m.d_head)
        scale = 1.0 / jnp.sqrt(jnp.asarray(m.d_head, jnp.float32))
        s = jnp.einsum("chd,shd->hcs", q, k) * scale + causal[None]
        w = jax.nn.softmax(s, axis=-1)
        att = jnp.einsum("hcs,shd->chd", w, v).reshape(t, m.d_model)
        x = x + att @ lp["wo"]
        h2 = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + jax.nn.relu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["tok_emb"].T
