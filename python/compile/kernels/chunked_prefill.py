"""L1 Pallas kernel: flash-style attention for one fixed-size prefill chunk.

This is TetriInfer's prefill hot spot (§3.3.3): the accelerator always runs
one ChunkSize-token chunk per iteration, so the kernel's shapes are fully
static — [C] queries against the request's [S]-row KV cache.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over
heads; each program holds the whole chunk's queries in VMEM (C×Dh ≤ 64×32
f32 = 8 KiB) and streams the KV cache HBM→VMEM in BK-row blocks via
``pl.ds`` loads, maintaining a running-max online softmax — the same
schedule FlashAttention expresses with threadblocks/shared memory, here
expressed with a BlockSpec + fori_loop. MXU alignment: BK = 128 keeps the
score matmul at [C,Dh]×[Dh,BK] with a 128-wide stationary dimension.

Kernels must run with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BK = 128  # KV rows streamed per inner step (MXU lane width)


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, n_kblocks: int):
    """One grid program = one attention head.

    q_ref:    [1, C, Dh]   this head's chunk queries (VMEM-resident)
    k_ref:    [1, S, Dh]   this head's KV cache keys
    v_ref:    [1, S, Dh]   this head's KV cache values
    mask_ref: [C, S]       additive visibility mask (shared across heads)
    o_ref:    [1, C, Dh]
    """
    q = q_ref[0]  # [C, Dh]
    c, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))

    def body(i, carry):
        m_prev, l_prev, acc = carry
        kb = k_ref[0, pl.ds(i * BK, BK)]          # [BK, Dh]
        vb = v_ref[0, pl.ds(i * BK, BK)]          # [BK, Dh]
        s = jnp.dot(q, kb.T) * scale              # [C, BK]
        s = s + mask_ref[:, pl.ds(i * BK, BK)]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)           # rescale old accumulator
        p = jnp.exp(s - m_cur[:, None])           # [C, BK]
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, vb)
        return m_cur, l_cur, acc

    init = (
        jnp.full((c,), NEG_INF, q.dtype),
        jnp.zeros((c,), q.dtype),
        jnp.zeros((c, dh), q.dtype),
    )
    _, l, acc = jax.lax.fori_loop(0, n_kblocks, body, init)
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


def chunked_prefill_attention(q, k, v, mask):
    """Flash-style chunk attention. Same contract as the ref oracle.

    q:    [C, H, Dh];  k, v: [S, H, Dh];  mask: [C, S] additive.
    Returns [C, H, Dh].
    """
    c, h, dh = q.shape
    s = k.shape[0]
    assert s % BK == 0, f"KV rows {s} must be a multiple of BK={BK}"
    # Head-major layout so each grid step owns one contiguous head.
    qh = jnp.swapaxes(q, 0, 1)  # [H, C, Dh]
    kh = jnp.swapaxes(k, 0, 1)  # [H, S, Dh]
    vh = jnp.swapaxes(v, 0, 1)
    out = pl.pallas_call(
        functools.partial(_kernel, n_kblocks=s // BK),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, c, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((c, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, c, dh), q.dtype),
        interpret=True,
    )(qh, kh, vh, mask)
    return jnp.swapaxes(out, 0, 1)  # [C, H, Dh]


def causal_chunk_mask(start, valid, chunk, max_seq, dtype=jnp.float32):
    """Additive mask for a chunk whose queries sit at global positions
    ``start .. start+chunk-1``; only the first ``valid`` are real tokens.

    Query i may see key j iff j <= start+i (causal) — pad queries
    (i >= valid) get a degenerate self-only row so their softmax stays
    finite; their outputs are never read.
    """
    qi = jnp.arange(chunk)[:, None]
    kj = jnp.arange(max_seq)[None, :]
    visible = kj <= (start + qi)
    return jnp.where(visible, 0.0, NEG_INF).astype(dtype)
