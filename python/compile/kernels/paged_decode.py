"""L1 Pallas kernel: paged decode attention (one new token per sequence).

This is TetriInfer's decode hot spot (§3.4): decode instances run
continuous batching over a vLLM-style paged KV pool, so each query token
gathers its context through a block table instead of a contiguous cache.

TPU mapping: the grid iterates over sequences; each program walks its
sequence's block table, gathering whole psz-row page blocks HBM→VMEM (the
gather below is page-aligned, so on real TPU it lowers to one DMA per
page — the schedule a CUDA paged-attention kernel expresses with per-warp
page loops), then runs the masked softmax over the gathered [T, H, Dh]
context for all heads at once (T = MaxP·psz rows, 512·8·32 f32 = 512 KiB
of VMEM at the shipped shapes — comfortably resident).

A note on structure (EXPERIMENTS.md §Perf): this formulation was chosen by
measurement on the AOT'd CPU artifact. A (batch, head) grid with a
flash-style running softmax over pages costs 177.8 ms per decode iteration
(each of the B·H grid steps materializes the full-pool block in interpret
mode); a single-program whole-batch gather costs 98–117 ms (2-D batched
gathers hit XLA:CPU's slow path); the per-sequence grid below costs
87.5 ms. All three are numerically identical (pytest vs ref.py).

interpret=True is mandatory on CPU (Mosaic custom-calls do not run here).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *, page_size: int):
    """One grid program = one sequence (all heads).

    bt_ref:  [1, MaxP] i32   this sequence's block table
    len_ref: [1] i32         visible tokens (incl. the current one)
    q_ref:   [1, H, Dh]      this sequence's query
    k_ref:   [P*psz, H, Dh]  full key pool
    v_ref:   [P*psz, H, Dh]  full value pool
    o_ref:   [1, H, Dh]
    """
    bt = bt_ref[0]  # [MaxP]
    # page-aligned row gather: one DMA per page on real hardware
    rows = (bt[:, None] * page_size + jnp.arange(page_size)[None, :]).reshape(-1)
    k = k_ref[rows]  # [T, H, Dh]
    v = v_ref[rows]
    q = q_ref[0]  # [H, Dh]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.einsum("hd,thd->ht", q, k) * scale  # [H, T]
    t_idx = jnp.arange(k.shape[0])
    s = jnp.where(t_idx[None, :] < len_ref[0], s, NEG_INF)
    w = jnp.exp(s - s.max(axis=-1, keepdims=True))
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    o_ref[0] = jnp.einsum("ht,thd->hd", w, v)


def paged_decode_attention(q, k_pool, v_pool, block_tables, seq_lens, page_size):
    """Paged decode attention. Same contract as the ref oracle.

    q:             [B, H, Dh]
    k_pool/v_pool: [P*psz, H, Dh]
    block_tables:  [B, MaxP] i32
    seq_lens:      [B] i32
    Returns [B, H, Dh].
    """
    b, h, dh = q.shape
    rows = k_pool.shape[0]
    max_pages = block_tables.shape[1]
    out = pl.pallas_call(
        functools.partial(_kernel, page_size=page_size),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, max_pages), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((rows, h, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((rows, h, dh), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=True,
    )(block_tables, seq_lens, q, k_pool, v_pool)
    return out
