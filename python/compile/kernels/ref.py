"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis sweeps in python/tests/test_kernels.py). They intentionally use
the most naive formulation: materialize full score matrices, full softmax,
no blocking, no running-max tricks.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_chunked_prefill_attention(q, k, v, mask):
    """Naive attention for one prefill chunk.

    q:    [C, H, Dh]  chunk queries
    k, v: [S, H, Dh]  full per-request KV cache (rows past the written
                      region are excluded by ``mask``)
    mask: [C, S]      additive mask (0 = visible, NEG_INF = hidden)
    returns [C, H, Dh]
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    # [H, C, S]
    scores = jnp.einsum("chd,shd->hcs", q, k) * scale + mask[None, :, :]
    w = jnp.exp(scores - scores.max(-1, keepdims=True))
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("hcs,shd->chd", w, v)


def ref_paged_decode_attention(q, k_pool, v_pool, block_tables, seq_lens, page_size):
    """Naive paged decode attention.

    q:            [B, H, Dh]   one new query token per sequence
    k_pool/v_pool:[P*psz, H, Dh] shared paged KV pool (flattened rows)
    block_tables: [B, MaxP] i32  page ids per sequence
    seq_lens:     [B] i32        tokens visible per sequence (incl. current)
    returns [B, H, Dh]
    """
    B, H, Dh = q.shape
    max_pages = block_tables.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, q.dtype))
    # Gather each sequence's KV rows: [B, MaxP*psz, H, Dh]
    rows = (
        block_tables[:, :, None] * page_size
        + jnp.arange(page_size, dtype=block_tables.dtype)[None, None, :]
    ).reshape(B, max_pages * page_size)
    k = k_pool[rows]  # [B, T, H, Dh]
    v = v_pool[rows]
    scores = jnp.einsum("bhd,bthd->bht", q, k) * scale
    t_idx = jnp.arange(max_pages * page_size)
    visible = t_idx[None, :] < seq_lens[:, None]  # [B, T]
    scores = jnp.where(visible[:, None, :], scores, NEG_INF)
    w = jnp.exp(scores - scores.max(-1, keepdims=True))
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bht,bthd->bhd", w, v)
