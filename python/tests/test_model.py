"""L2 correctness: the chunked-prefill + paged-decode pipeline must
reproduce the one-shot full-context forward (full_forward_ref) exactly —
this is the end-to-end numerical contract the rust runtime relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.config import Config, DecodeConfig, ModelConfig, PredictorConfig

SMALL = Config(
    model=ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_head=16,
                      d_ffn=64, max_seq=128, chunk=16),
    decode=DecodeConfig(batch=2, page_size=8, n_pages=40, max_pages_per_req=16),
    predictor=PredictorConfig(vocab=64, d_model=32, n_layers=1, n_heads=2,
                              d_head=16, d_ffn=64, max_prompt=16),
)


@pytest.fixture(scope="module")
def params():
    return M.init_target_params(jax.random.PRNGKey(0), SMALL)


def run_chunked_prefill(params, toks, cfg):
    m = cfg.model
    k = jnp.zeros((m.n_layers, m.max_seq, m.n_heads, m.d_head), jnp.float32)
    v = jnp.zeros_like(k)
    start, last = 0, None
    while start < len(toks):
        valid = min(m.chunk, len(toks) - start)
        buf = np.zeros(m.chunk, np.int32)
        buf[:valid] = toks[start : start + valid]
        last, k, v = M.prefill_segment(params, jnp.asarray(buf), start, valid, k, v, cfg)
        start += valid
    return last, k, v


@settings(max_examples=8, deadline=None)
@given(t=st.integers(1, 100), seed=st.integers(0, 2**31 - 1))
def test_chunked_prefill_matches_full_forward(t, seed):
    params = M.init_target_params(jax.random.PRNGKey(0), SMALL)
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, SMALL.model.vocab, size=t).astype(np.int32)
    last, _, _ = run_chunked_prefill(params, toks, SMALL)
    want = M.full_forward_ref(params, jnp.asarray(toks), SMALL)[-1]
    np.testing.assert_allclose(np.asarray(last), np.asarray(want), atol=5e-5, rtol=5e-4)


def test_prefill_pad_tokens_do_not_change_output(params):
    """Garbage in the pad tail of the final chunk must not matter."""
    toks = np.arange(1, 20, dtype=np.int32) % SMALL.model.vocab  # 19 tokens → pad 13
    m = SMALL.model
    k = jnp.zeros((m.n_layers, m.max_seq, m.n_heads, m.d_head), jnp.float32)
    v = jnp.zeros_like(k)
    outs = []
    for pad_val in (0, 7):
        buf = np.full(m.chunk, pad_val, np.int32)
        buf[:16] = toks[:16]
        _, k1, v1 = M.prefill_segment(params, jnp.asarray(buf), 0, 16, k, v, SMALL)
        buf2 = np.full(m.chunk, pad_val, np.int32)
        buf2[:3] = toks[16:]
        last, _, _ = M.prefill_segment(params, jnp.asarray(buf2), 16, 3, k1, v1, SMALL)
        outs.append(np.asarray(last))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)


def contiguous_to_paged(k_cache, v_cache, t, pages, pool_shape, psz):
    """Mimic the rust-side KV transfer: contiguous rows → pool pages."""
    k_pool = jnp.zeros(pool_shape, jnp.float32)
    v_pool = jnp.zeros(pool_shape, jnp.float32)
    for i, pg in enumerate(pages):
        lo, hi = i * psz, min((i + 1) * psz, t)
        if lo >= t:
            break
        k_pool = k_pool.at[:, pg * psz : pg * psz + hi - lo].set(k_cache[:, lo:hi])
        v_pool = v_pool.at[:, pg * psz : pg * psz + hi - lo].set(v_cache[:, lo:hi])
    return k_pool, v_pool


def test_decode_after_transfer_matches_full_forward(params):
    """prefill → transfer → N decode steps == one-shot forward, greedy."""
    cfg = SMALL
    m, d = cfg.model, cfg.decode
    rng = np.random.default_rng(5)
    t = 21
    toks = rng.integers(0, m.vocab, size=t).astype(np.int32)
    last, kc, vc = run_chunked_prefill(params, toks, cfg)

    psz = d.page_size
    pool_shape = (m.n_layers, d.n_pages * psz, m.n_heads, m.d_head)
    pages = list(range(1, 9))
    kp, vp = contiguous_to_paged(kc, vc, t, pages, pool_shape, psz)
    bt = np.zeros((d.batch, d.max_pages_per_req), np.int32)
    bt[0, : len(pages)] = pages

    cur = int(jnp.argmax(last))
    full = list(toks)
    for step in range(4):
        full.append(cur)
        pos = t + step
        logits, kp, vp = M.decode_step(
            params,
            jnp.asarray([cur, 0], jnp.int32),
            jnp.asarray([pos, 0], jnp.int32),
            kp, vp, jnp.asarray(bt),
            jnp.asarray([pos + 1, 1], jnp.int32),
            cfg,
        )
        want = M.full_forward_ref(params, jnp.asarray(full, jnp.int32), cfg)[-1]
        np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(want),
                                   atol=5e-5, rtol=5e-4)
        cur = int(jnp.argmax(logits[0]))


def test_decode_batch_isolation(params):
    """Two active slots with disjoint pages must not influence each other."""
    cfg = SMALL
    m, d = cfg.model, cfg.decode
    psz = d.page_size
    pool_shape = (m.n_layers, d.n_pages * psz, m.n_heads, m.d_head)
    rng = np.random.default_rng(11)
    toks = rng.integers(0, m.vocab, size=9).astype(np.int32)
    _, kc, vc = run_chunked_prefill(params, toks, cfg)
    kp, vp = contiguous_to_paged(kc, vc, 9, [1, 2], pool_shape, psz)

    bt_solo = np.zeros((d.batch, d.max_pages_per_req), np.int32)
    bt_solo[0, :2] = [1, 2]
    solo, _, _ = M.decode_step(
        params,
        jnp.asarray([5, 0], jnp.int32), jnp.asarray([9, 0], jnp.int32),
        kp, vp, jnp.asarray(bt_solo), jnp.asarray([10, 1], jnp.int32), cfg,
    )

    # Same pool, but slot 1 now holds a *different* request on pages 5,6.
    toks2 = rng.integers(0, m.vocab, size=12).astype(np.int32)
    _, kc2, vc2 = run_chunked_prefill(params, toks2, cfg)
    kp2, vp2 = contiguous_to_paged(kc2, vc2, 12, [5, 6], pool_shape, psz)
    kp_both = kp + kp2  # disjoint pages → pure union
    vp_both = vp + vp2
    bt_both = bt_solo.copy()
    bt_both[1, :2] = [5, 6]
    both, _, _ = M.decode_step(
        params,
        jnp.asarray([5, 3], jnp.int32), jnp.asarray([9, 12], jnp.int32),
        kp_both, vp_both, jnp.asarray(bt_both), jnp.asarray([10, 13], jnp.int32), cfg,
    )
    np.testing.assert_allclose(np.asarray(both[0]), np.asarray(solo[0]), atol=1e-5)


def test_predictor_shapes_and_determinism():
    cfg = SMALL
    p = cfg.predictor
    params = M.init_predictor_params(jax.random.PRNGKey(1), cfg)
    toks = jnp.asarray(np.arange(p.max_prompt) % p.vocab, jnp.int32)
    out1 = M.predict_len(params, toks, 10, cfg)
    out2 = M.predict_len(params, toks, 10, cfg)
    assert out1.shape == (p.n_buckets,)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_predictor_ignores_padding():
    cfg = SMALL
    p = cfg.predictor
    params = M.init_predictor_params(jax.random.PRNGKey(1), cfg)
    base = np.zeros(p.max_prompt, np.int32)
    base[:6] = [1, 17, 40, 41, 42, 43]
    alt = base.copy()
    alt[6:] = 9  # different pad garbage
    o1 = M.predict_len(params, jnp.asarray(base), 6, cfg)
    o2 = M.predict_len(params, jnp.asarray(alt), 6, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
