"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and dtypes; fixed-seed cases pin the exact
configurations the AOT artifacts use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.chunked_prefill import (
    BK,
    causal_chunk_mask,
    chunked_prefill_attention,
)
from compile.kernels.paged_decode import paged_decode_attention

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------- prefill


@settings(max_examples=20, deadline=None)
@given(
    c=st.sampled_from([4, 16, 64]),
    h=st.sampled_from([1, 2, 8]),
    dh=st.sampled_from([8, 32]),
    s_blocks=st.integers(1, 4),
    start_frac=st.floats(0.0, 0.9),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_prefill_matches_ref(c, h, dh, s_blocks, start_frac, dtype, seed):
    s = s_blocks * BK
    rng = np.random.default_rng(seed)
    q = _rand(rng, (c, h, dh), dtype)
    k = _rand(rng, (s, h, dh), dtype)
    v = _rand(rng, (s, h, dh), dtype)
    start = min(int(start_frac * s), s - c)
    valid = rng.integers(1, c + 1)
    mask = causal_chunk_mask(start, valid, c, s, dtype=dtype)
    got = chunked_prefill_attention(q, k, v, mask)
    want = ref.ref_chunked_prefill_attention(q, k, v, mask)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_chunked_prefill_aot_shape():
    """The exact shape the prefill artifact uses (C=64, H=8, Dh=32, S=512)."""
    rng = np.random.default_rng(0)
    q = _rand(rng, (64, 8, 32), jnp.float32)
    k = _rand(rng, (512, 8, 32), jnp.float32)
    v = _rand(rng, (512, 8, 32), jnp.float32)
    mask = causal_chunk_mask(128, 64, 64, 512)
    got = chunked_prefill_attention(q, k, v, mask)
    want = ref.ref_chunked_prefill_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_chunked_prefill_rejects_unaligned_kv():
    rng = np.random.default_rng(0)
    q = _rand(rng, (8, 1, 8), jnp.float32)
    k = _rand(rng, (100, 1, 8), jnp.float32)  # not a multiple of BK
    with pytest.raises(AssertionError):
        chunked_prefill_attention(q, k, k, causal_chunk_mask(0, 8, 8, 100))


def test_causal_chunk_mask_semantics():
    m = np.asarray(causal_chunk_mask(start=4, valid=2, chunk=3, max_seq=8))
    # query i (global 4+i) sees keys j <= 4+i
    for i in range(3):
        for j in range(8):
            assert (m[i, j] == 0.0) == (j <= 4 + i), (i, j)


def test_pad_queries_do_not_affect_valid_rows():
    """Pad tail contents must not change valid-query outputs."""
    rng = np.random.default_rng(3)
    c, h, dh, s = 16, 2, 8, 128
    k = _rand(rng, (s, h, dh), jnp.float32)
    v = _rand(rng, (s, h, dh), jnp.float32)
    q1 = np.asarray(_rand(rng, (c, h, dh), jnp.float32))
    q2 = q1.copy()
    valid = 5
    q2[valid:] = rng.normal(size=(c - valid, h, dh))  # different pad garbage
    mask = causal_chunk_mask(0, valid, c, s)
    o1 = np.asarray(chunked_prefill_attention(jnp.asarray(q1), k, v, mask))
    o2 = np.asarray(chunked_prefill_attention(jnp.asarray(q2), k, v, mask))
    np.testing.assert_allclose(o1[:valid], o2[:valid], atol=1e-6)


# ----------------------------------------------------------------- decode


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8]),
    h=st.sampled_from([1, 4]),
    dh=st.sampled_from([8, 32]),
    psz=st.sampled_from([8, 16]),
    n_pages=st.sampled_from([8, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_decode_matches_ref(b, h, dh, psz, n_pages, dtype, seed):
    rng = np.random.default_rng(seed)
    max_pages = n_pages // 2
    q = _rand(rng, (b, h, dh), dtype)
    kp = _rand(rng, (n_pages * psz, h, dh), dtype)
    vp = _rand(rng, (n_pages * psz, h, dh), dtype)
    bt = jnp.asarray(rng.integers(0, n_pages, size=(b, max_pages)), jnp.int32)
    sl = jnp.asarray(rng.integers(1, max_pages * psz + 1, size=(b,)), jnp.int32)
    got = paged_decode_attention(q, kp, vp, bt, sl, psz)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, sl, psz)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_paged_decode_aot_shape():
    """The exact shape the decode artifact uses (B=8, psz=16, P=288)."""
    rng = np.random.default_rng(0)
    b, h, dh, psz, p, maxp = 8, 8, 32, 16, 288, 32
    q = _rand(rng, (b, h, dh), jnp.float32)
    kp = _rand(rng, (p * psz, h, dh), jnp.float32)
    vp = _rand(rng, (p * psz, h, dh), jnp.float32)
    bt = jnp.asarray(rng.integers(0, p, size=(b, maxp)), jnp.int32)
    sl = jnp.asarray(rng.integers(1, maxp * psz, size=(b,)), jnp.int32)
    got = paged_decode_attention(q, kp, vp, bt, sl, psz)
    want = ref.ref_paged_decode_attention(q, kp, vp, bt, sl, psz)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_paged_decode_only_visible_tokens_matter():
    """Rows beyond seq_len (and pages beyond the table) must not leak."""
    rng = np.random.default_rng(7)
    b, h, dh, psz, n_pages, maxp = 1, 2, 8, 8, 8, 4
    q = _rand(rng, (b, h, dh), jnp.float32)
    kp1 = np.asarray(_rand(rng, (n_pages * psz, h, dh), jnp.float32))
    vp1 = np.asarray(_rand(rng, (n_pages * psz, h, dh), jnp.float32))
    bt = np.zeros((b, maxp), np.int32)
    bt[0] = [2, 3, 0, 0]
    sl = jnp.asarray([11], jnp.int32)  # 8 rows of page 2 + 3 rows of page 3
    o1 = np.asarray(paged_decode_attention(q, jnp.asarray(kp1), jnp.asarray(vp1), jnp.asarray(bt), sl, psz))
    kp2, vp2 = kp1.copy(), vp1.copy()
    kp2[3 * psz + 3 :] = 99.0  # beyond visible rows of page 3
    vp2[3 * psz + 3 :] = -99.0
    kp2[: 2 * psz] = 7.0  # pages not referenced
    o2 = np.asarray(paged_decode_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), jnp.asarray(bt), sl, psz))
    np.testing.assert_allclose(o1, o2, atol=1e-6)


def test_paged_decode_single_token():
    """seq_len == 1: output must equal the single visible value row."""
    rng = np.random.default_rng(9)
    b, h, dh, psz = 1, 1, 4, 8
    q = _rand(rng, (b, h, dh), jnp.float32)
    kp = _rand(rng, (4 * psz, h, dh), jnp.float32)
    vp = _rand(rng, (4 * psz, h, dh), jnp.float32)
    bt = jnp.asarray([[2, 0]], jnp.int32)
    sl = jnp.asarray([1], jnp.int32)
    out = np.asarray(paged_decode_attention(q, kp, vp, bt, sl, psz))
    np.testing.assert_allclose(out[0, 0], np.asarray(vp)[2 * psz, 0], atol=1e-6)
