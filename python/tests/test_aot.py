"""AOT pipeline: artifacts must exist, parse, and agree with the manifest."""

import json
import os
import struct

import pytest

from compile.aot import build, flatten_params, save_params_bin
from compile.config import Config, DecodeConfig, ModelConfig, PredictorConfig

TINY = Config(
    model=ModelConfig(vocab=64, d_model=32, n_layers=1, n_heads=2, d_head=16,
                      d_ffn=64, max_seq=128, chunk=16),
    decode=DecodeConfig(batch=2, page_size=8, n_pages=24, max_pages_per_req=16),
    predictor=PredictorConfig(vocab=64, d_model=32, n_layers=1, n_heads=2,
                              d_head=16, d_ffn=64, max_prompt=16),
)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    build(TINY, str(out), skip_train=True)
    return str(out)


def test_all_artifacts_written(built):
    for f in ("prefill.hlo.txt", "decode.hlo.txt", "predictor.hlo.txt",
              "params.bin", "predictor_params.bin", "manifest.json"):
        assert os.path.exists(os.path.join(built, f)), f


def test_hlo_text_is_parseable_hlo(built):
    for f in ("prefill.hlo.txt", "decode.hlo.txt", "predictor.hlo.txt"):
        text = open(os.path.join(built, f)).read()
        assert "HloModule" in text, f
        assert "ENTRY" in text, f
        # AOT must never serialize protos (xla_extension 0.5.1 rejects them)
        assert not text.startswith("\x08"), f


def test_params_bin_matches_manifest(built):
    man = json.load(open(os.path.join(built, "manifest.json")))
    for key in ("params", "predictor_params"):
        spec = man[key]["leaves"]
        n_floats = sum(
            int.__mul__(1, 1) if not leaf["shape"] else
            __import__("math").prod(leaf["shape"]) for leaf in spec
        )
        size = os.path.getsize(os.path.join(built, man[key]["file"]))
        assert size == 4 * n_floats, key


def test_manifest_argspec_consistent_with_config(built):
    man = json.load(open(os.path.join(built, "manifest.json")))
    cfg = man["config"]
    pre = {a["name"]: a for a in man["artifacts"]["prefill"]["args"]}
    assert pre["tokens"]["shape"] == [cfg["model"]["chunk"]]
    assert pre["k_cache"]["shape"] == [
        cfg["model"]["n_layers"], cfg["model"]["max_seq"],
        cfg["model"]["n_heads"], cfg["model"]["d_head"],
    ]
    dec = {a["name"]: a for a in man["artifacts"]["decode"]["args"]}
    assert dec["tokens"]["shape"] == [cfg["decode"]["batch"]]
    assert dec["k_pool"]["shape"][1] == cfg["decode"]["n_pages"] * cfg["decode"]["page_size"]
    prd = {a["name"]: a for a in man["artifacts"]["predictor"]["args"]}
    assert prd["tokens"]["shape"] == [cfg["predictor"]["max_prompt"]]


def test_param_count_matches_config_formula(built):
    man = json.load(open(os.path.join(built, "manifest.json")))
    import math
    n = sum(math.prod(l["shape"]) if l["shape"] else 1
            for l in man["params"]["leaves"])
    assert n == TINY.model.n_params
    n = sum(math.prod(l["shape"]) if l["shape"] else 1
            for l in man["predictor_params"]["leaves"])
    assert n == TINY.predictor.n_params


def test_flatten_order_is_deterministic():
    import jax
    from compile.model import init_target_params
    p1 = init_target_params(jax.random.PRNGKey(0), TINY)
    p2 = init_target_params(jax.random.PRNGKey(0), TINY)
    names1 = [n for n, _ in flatten_params(p1)]
    names2 = [n for n, _ in flatten_params(p2)]
    assert names1 == names2
    assert len(names1) == len(set(names1))  # unique paths
