//! Microbenchmarks of the L3 hot paths (hand-rolled harness: the vendored
//! environment has no criterion). Run with `cargo bench --bench scheduler`
//! or scripts/bench.sh.
//!
//! These are the §Perf profiling base for EXPERIMENTS.md: the coordinator
//! is the paper's contribution, so scheduling-decision throughput and DES
//! event throughput are the headline numbers. Emits machine-readable
//! `BENCH_sched.json` at the repo root (the perf trajectory future PRs
//! regress against).

use std::time::Instant;

use tetri_infer::api::Scenario;
use tetri_infer::decode::{DecodePolicy, DecodeScheduler};
use tetri_infer::kvcache::PagedKvCache;
use tetri_infer::prefill::{choose, Chunker, DecodeLoad, DispatchPolicy, PrefillPolicy, PrefillScheduler};
use tetri_infer::sim::{Event, EventQueue};
use tetri_infer::types::Request;
use tetri_infer::util::{bench_meta, merge_bench_sections, repo_root, Json, Pcg};
use tetri_infer::workload::WorkloadKind;

/// Time `f` (which performs `iters` inner operations), repeated `reps`
/// times; prints the best rep (ns/op and Mops/s) and records it in `rows`
/// for the BENCH_sched.json trajectory.
fn bench(rows: &mut Vec<(String, f64)>, name: &str, iters: u64, reps: usize, mut f: impl FnMut()) {
    let mut best = f64::MAX;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt);
    }
    let ns = best * 1e9 / iters as f64;
    println!("{name:<40} {ns:>10.1} ns/op {:>10.2} Mops/s", 1e3 / ns);
    rows.push((name.to_string(), ns));
}

fn req(id: u64, plen: u32, dlen: u32) -> Request {
    Request {
        id,
        task: tetri_infer::types::TaskType::Chat,
        class: 0,
        arrival: 0,
        prompt_len: plen,
        decode_len: dlen,
        predicted: None,
        prefix: None,
    }
}

fn main() {
    println!("== L3 microbenches (best of 5) ==");
    let mut rows: Vec<(String, f64)> = Vec::new();

    // ---- prefill scheduler: push+pop under SJF sorting
    let n = 100_000u64;
    bench(&mut rows, "prefill_scheduler sjf push+pop", n, 5, || {
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 16);
        for i in 0..n {
            s.push(req(i, (i % 997) as u32 + 1, 10).meta());
        }
        while s.pop().is_some() {}
    });

    // ---- chunker: slice/merge a 100k-request stream
    bench(&mut rows, "chunker slice+merge", n, 5, || {
        let mut c = Chunker::new(512);
        let mut emitted = 0u64;
        for i in 0..n {
            c.admit(req(i, (i % 997) as u32 + 1, 10).meta());
            while let Some(ch) = c.next_chunk() {
                emitted += ch.tokens as u64;
            }
        }
        std::hint::black_box(emitted);
    });

    // ---- dispatcher: power-of-two decisions over 64 instances
    let loads: Vec<DecodeLoad> = (0..64)
        .map(|i| DecodeLoad {
            instance: i,
            free_kv_tokens: 10_000 + (i as u64 * 13 % 7_000),
            n_heavy: (i % 5) as u32,
            n_light: (i % 9) as u32,
            queue_len: 0,
        })
        .collect();
    let mut rng = Pcg::new(1);
    bench(&mut rows, "dispatcher power-of-two choose", n, 5, || {
        for i in 0..n {
            std::hint::black_box(choose(
                &loads,
                (i % 512) as u32,
                None,
                200,
                DispatchPolicy::PowerOfTwo,
                &mut rng,
            ));
        }
    });

    // ---- paged KV: alloc/append/release cycle
    bench(&mut rows, "kvcache alloc+append+release", n, 5, || {
        let mut kv = PagedKvCache::new(4096, 16);
        for i in 0..n {
            let id = i % 128;
            if kv.contains(id) {
                kv.release(id);
            }
            kv.alloc(id, (i % 500) as u32 + 1).unwrap();
            kv.append_token(id).unwrap();
        }
    });

    // ---- decode scheduler: admission + step over a 128-deep batch
    bench(&mut rows, "decode_scheduler admit+step (bs128)", 10_000, 5, || {
        let mut s = DecodeScheduler::new(DecodePolicy::ReserveDynamic, 200, 128);
        let mut kv = PagedKvCache::new(8192, 16);
        let mut done = Vec::new();
        for i in 0..256u64 {
            s.push(req(i, 64, 40));
        }
        for _ in 0..10_000 / 128 {
            s.admit(&mut kv);
            done.clear();
            s.step(&mut kv, &mut done);
        }
    });

    // ---- decode scheduler under constant preemption: a greedy batch that
    // outgrows a small pool, so every iteration evicts victims — the path
    // that used to be O(batch²) via Vec::remove.
    bench(&mut rows, "decode_scheduler step under preemption", 2_000, 5, || {
        let mut s = DecodeScheduler::new(DecodePolicy::Greedy, 200, 128);
        let mut kv = PagedKvCache::new(512, 16); // 511 pages = 8176 tokens
        let mut done = Vec::new();
        for i in 0..128u64 {
            s.push(req(i, 60, 200));
        }
        for _ in 0..2_000 {
            s.admit(&mut kv);
            done.clear();
            s.step(&mut kv, &mut done);
        }
    });

    // ---- DES event queue. Horizons spread across iteration-scale (many
    // calendar buckets), monitor-scale, and far-future (overflow) times —
    // all < 1000 µs would collapse into one 4 ms bucket and measure a
    // plain BinaryHeap instead of the production queue's scan/migration
    // paths (benches/engine.rs has the dedicated heap-vs-calendar A/B).
    bench(&mut rows, "event_queue schedule+pop", n, 5, || {
        let mut q = EventQueue::new();
        for i in 0..n {
            let at = match i % 47 {
                0 => i * 7919 % 6_000_000_000, // far future: overflow path
                1..=4 => i * 7919 % 120_000_000, // monitor/flip horizon
                _ => i * 7919 % 40_000,        // iteration horizon
            };
            q.schedule_at(at, Event::Arrival(i));
        }
        while q.pop().is_some() {}
    });

    // ---- end-to-end cluster sim throughput (requests/s of sim) — one
    // api::Scenario per seed, same 512-request mixed trace (trace_seed 5).
    let mut out = 0u64;
    let mut events = 0u64;
    let t = Instant::now();
    let reps = 5;
    for s in 0..reps {
        let sc = Scenario::builder()
            .workload(WorkloadKind::Mixed)
            .requests(512)
            .rate(32.0)
            .seed(s as u64)
            .trace_seed(5)
            .topology(2, 4)
            .build();
        let m = sc.run().expect("builtin driver").metrics;
        out += m.records.len() as u64;
        events += m.events;
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "{:<40} {:>10.1} ms/run {:>10.0} req/s-sim {:>12.0} events/s",
        "cluster sim 512 reqs 2P+4D",
        dt * 1e3 / reps as f64,
        out as f64 / dt,
        events as f64 / dt
    );
    rows.push(("cluster sim 512 reqs 2P+4D (ns/event)".to_string(), dt * 1e9 / events as f64));

    // ---- machine-readable trajectory
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|(name, ns)| {
            Json::obj([
                ("name", Json::from(name.clone())),
                ("ns_per_op", Json::from(*ns)),
            ])
        })
        .collect();
    let path = repo_root().join("BENCH_sched.json");
    merge_bench_sections(
        &path,
        &[("bench", Json::from("sched")), ("schema", Json::from(1u64))],
        vec![("meta", bench_meta()), ("rows", Json::from(json_rows))],
    );
    println!("wrote {}", path.display());
}
