//! Optimizer search-throughput bench (hand-rolled harness, like the
//! other benches: no criterion vendored).
//!
//! Runs the shipped topology search (scenarios/optimize_mixed.json —
//! 36 cells spanning 2–12 instances × 2 chunk sizes × 2 prefill
//! policies) end to end and reports:
//!
//!  - **cells/sec** — the search-throughput headline that the bench gate
//!    regresses against;
//!  - **fraction of exhaustive** — events actually simulated vs the
//!    estimated cost of running every grid cell full-length. This is the
//!    whole point of the tentpole: successive halving + SLO aborts +
//!    dominance pruning must do strictly less than half the exhaustive
//!    work on the shipped spec (hard-asserted here, per ISSUE.md).
//!
//! Results merge into `BENCH_cluster.json` under the `"optimizer"` key
//! (read-modify-write — the "engine"/cluster sections survive). Run via
//! `cargo bench --bench optimizer` or scripts/bench.sh; set
//! OPTIMIZER_BENCH_REQUESTS to shrink the horizon while iterating.

use std::time::Instant;

use tetri_infer::api::Scenario;
use tetri_infer::optimizer;
use tetri_infer::sweep::default_workers;
use tetri_infer::util::{bench_meta, merge_bench_sections, repo_root, Json};

const REPS: usize = 3;

fn main() {
    println!("== optimizer search benches (best of {REPS}) ==");

    let spec = repo_root().join("scenarios/optimize_mixed.json");
    let mut sc = Scenario::load(spec.to_str().unwrap()).expect("optimize_mixed spec parses");
    if let Some(n) = std::env::var("OPTIMIZER_BENCH_REQUESTS").ok().and_then(|v| v.parse().ok()) {
        sc.clamp_requests(n);
    }
    let workers = default_workers();
    println!(
        "search: {} requests/cell horizon, {} workers ...",
        sc.requests, workers
    );

    let mut best_wall = f64::MAX;
    let mut result = None;
    for _ in 0..REPS {
        let t = Instant::now();
        let res = optimizer::optimize(&sc, workers).expect("search runs");
        best_wall = best_wall.min(t.elapsed().as_secs_f64());
        result = Some(res);
    }
    let res = result.unwrap();
    let st = &res.stats;
    let cells_per_sec = st.grid_cells as f64 / best_wall.max(1e-12);
    let fraction = st.fraction_of_exhaustive();

    println!(
        "search: {} cells in {:>7.2} s wall = {:>7.2} cells/s ({} rungs, {} full runs)",
        st.grid_cells, best_wall, cells_per_sec, st.rungs, st.full_runs
    );
    println!(
        "search: pruned {} by halving, {} by SLO budget, {} by dominance",
        st.halving_discarded, st.pruned_slo, st.pruned_dominance
    );
    println!(
        "search: {} events simulated vs ~{:.0} exhaustive = {:.3} of exhaustive",
        st.events_simulated, st.events_exhaustive_est, fraction
    );
    match res.recommended_cell() {
        Some(rec) => println!(
            "search: recommended {} | goodput/$ {:.6}",
            rec.label,
            optimizer::value_of(&rec.report.metrics)
        ),
        None => println!("search: recommended none (no cell met the SLO floor)"),
    }

    // The acceptance bar from ISSUE.md: the search must cost < 0.5 of the
    // exhaustive grid on the shipped spec. Hard failure, not a warning —
    // this is a semantic property of the algorithm, not a host-speed one.
    assert!(
        fraction < 0.5,
        "search simulated {fraction:.3} of the exhaustive grid (bar: < 0.5)"
    );

    // ---- regression gate (warn-only, same protocol as benches/engine.rs)
    let out = repo_root().join("BENCH_cluster.json");
    let tolerance: f64 = std::env::var("BENCH_TOLERANCE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    let baseline = std::fs::read_to_string(&out)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.at(&["optimizer", "cells_per_sec"])?.as_f64());
    match baseline {
        Some(base) if base > 0.0 => {
            let ratio = cells_per_sec / base;
            if ratio < 1.0 - tolerance {
                println!(
                    "WARNING: search throughput regressed {:.1}% vs committed baseline \
                     ({:.1} -> {:.1} cells/s, tolerance {:.0}%)",
                    (1.0 - ratio) * 100.0,
                    base,
                    cells_per_sec,
                    tolerance * 100.0
                );
                if std::env::var("BENCH_GATE_STRICT").as_deref() == Ok("1") {
                    std::process::exit(1);
                }
            } else {
                println!(
                    "bench gate: {:.1} cells/s vs baseline {:.1} ({:+.1}%, tolerance {:.0}%) — ok",
                    cells_per_sec,
                    base,
                    (ratio - 1.0) * 100.0,
                    tolerance * 100.0
                );
            }
        }
        _ => println!(
            "bench gate: no committed optimizer baseline in {} — recording fresh numbers",
            out.display()
        ),
    }

    // ---- merge into BENCH_cluster.json -------------------------------
    let section = Json::obj([
        ("meta", bench_meta()),
        ("spec", Json::from("scenarios/optimize_mixed.json")),
        ("requests_per_cell", Json::from(sc.requests)),
        ("workers", Json::from(workers)),
        ("reps", Json::from(REPS)),
        ("grid_cells", Json::from(st.grid_cells)),
        ("rungs", Json::from(st.rungs)),
        ("full_runs", Json::from(st.full_runs)),
        ("halving_discarded", Json::from(st.halving_discarded)),
        ("pruned_slo", Json::from(st.pruned_slo)),
        ("pruned_dominance", Json::from(st.pruned_dominance)),
        ("events_simulated", Json::from(st.events_simulated)),
        ("events_exhaustive_est", Json::from(st.events_exhaustive_est)),
        ("fraction_of_exhaustive", Json::from(fraction)),
        ("wall_s", Json::from(best_wall)),
        ("cells_per_sec", Json::from(cells_per_sec)),
        (
            "recommended",
            match res.recommended_cell() {
                Some(rec) => Json::from(rec.label.clone()),
                None => Json::Null,
            },
        ),
    ]);
    merge_bench_sections(
        &out,
        &[("bench", Json::from("cluster")), ("schema", Json::from(1u64))],
        vec![("optimizer", section)],
    );
    println!("merged optimizer rows into {}", out.display());
}
