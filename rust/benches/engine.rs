//! Engine-level perf benches (hand-rolled harness: no criterion vendored):
//!
//!  1. **Queue A/B** — the calendar (timing-wheel) queue vs the reference
//!     `BinaryHeap` queue on an identical synthetic DES schedule, with a
//!     pop-order checksum proving they executed the same run. This is the
//!     old-vs-new events/sec number for the million-request engine.
//!  2. **Scale run** — scenarios/scale.json (1M mixed requests, streaming
//!     arrivals, records off, elastic pools): end-to-end events/sec,
//!     macro-step collapse ratio, and peak arena size (the O(active)
//!     memory witness — compare it against the request count).
//!  3. **Prefix A/B** — scenarios/prefix_reuse.json warm (radix cache on)
//!     vs its cold twin (`prefix` stripped) on a scaled-up request count:
//!     events/sec both ways, the warm run's hit rate, and the TTFT cut
//!     the cache buys. The cache must never cost engine throughput.
//!
//! Results merge into `BENCH_cluster.json` at the repo root under the
//! `"engine"` key (read-modify-write, so benches/cluster.rs keeps its
//! rows). Run via `cargo bench --bench engine` or scripts/bench.sh; set
//! ENGINE_BENCH_REQUESTS to shrink the scale run while iterating.

use std::time::Instant;

use tetri_infer::api::Scenario;
use tetri_infer::sim::{CalendarQueue, Event, HeapQueue};
use tetri_infer::util::{bench_meta, merge_bench_sections, repo_root, Json, Pcg};

const QUEUE_OPS: usize = 2_000_000;
/// Standing event population during the queue bench (each pop schedules a
/// replacement) — roughly a large cluster's in-flight event set.
const QUEUE_HANDLES: usize = 4_096;
/// Best-of reps per queue, so first-pass warmup (CPU ramp, cold caches
/// over the delay stream) doesn't bias whichever queue runs first.
const QUEUE_REPS: usize = 3;

/// Deterministic delay stream shared by both queue runs: mostly short
/// iteration-scale gaps, a tail of monitor/flip/idle-scale gaps that
/// exercise the overflow path.
fn delays(n: usize) -> Vec<u64> {
    let mut rng = Pcg::new(7);
    (0..n)
        .map(|_| match rng.index(32) {
            0 => rng.range(100_000, 8_000_000),  // monitor/flip horizon
            1 => rng.range(8_000_000, 120_000_000), // idle-gap horizon (overflow)
            _ => rng.range(500, 50_000),         // iteration horizon
        })
        .collect()
}

macro_rules! drive_queue {
    ($queue:expr, $delays:expr) => {{
        let mut q = $queue;
        let delays = $delays;
        for i in 0..QUEUE_HANDLES {
            q.schedule_at(delays[i], Event::Arrival(i as u64));
        }
        let mut checksum = 0u64;
        let t = Instant::now();
        for d in delays[QUEUE_HANDLES..].iter() {
            let (at, ev) = q.pop().expect("standing population never drains");
            let Event::Arrival(id) = ev else { unreachable!() };
            checksum = checksum
                .wrapping_mul(0x100000001b3)
                .wrapping_add(at)
                .wrapping_add(id);
            q.schedule_at(at + d, Event::Arrival(id));
        }
        (t.elapsed().as_secs_f64(), checksum)
    }};
}

fn main() {
    println!("== engine benches ==");

    // ---- 1. queue A/B (best of QUEUE_REPS per queue) -----------------
    let ds = delays(QUEUE_OPS + QUEUE_HANDLES);
    let (mut heap_s, mut cal_s) = (f64::MAX, f64::MAX);
    let (mut heap_sum, mut cal_sum) = (0u64, 0u64);
    for _ in 0..QUEUE_REPS {
        let (s, c) = drive_queue!(HeapQueue::new(), &ds);
        heap_s = heap_s.min(s);
        heap_sum = c;
        let (s, c) = drive_queue!(CalendarQueue::new(), &ds);
        cal_s = cal_s.min(s);
        cal_sum = c;
    }
    assert_eq!(cal_sum, heap_sum, "queues diverged: the A/B numbers are meaningless");
    let heap_eps = QUEUE_OPS as f64 / heap_s.max(1e-12);
    let cal_eps = QUEUE_OPS as f64 / cal_s.max(1e-12);
    println!(
        "queue A/B ({QUEUE_OPS} pops, {QUEUE_HANDLES} standing, best of {QUEUE_REPS}): heap {:>12.0} ev/s  calendar {:>12.0} ev/s  ({:.2}x)",
        heap_eps,
        cal_eps,
        cal_eps / heap_eps
    );

    // ---- 2. million-request scale run --------------------------------
    let spec = repo_root().join("scenarios/scale.json");
    let mut sc = Scenario::load(spec.to_str().unwrap()).expect("scale spec parses");
    if let Some(n) = std::env::var("ENGINE_BENCH_REQUESTS").ok().and_then(|v| v.parse().ok()) {
        sc.requests = n;
    }
    println!("scale run: {} requests (streaming arrivals, records off) ...", sc.requests);
    let t = Instant::now();
    let report = sc.run().expect("scale spec resolves");
    let wall = t.elapsed().as_secs_f64();
    let m = &report.metrics;
    let events_per_sec = m.events as f64 / wall.max(1e-12);
    println!(
        "scale run: {} reqs {:>10} events (+{} macro-stepped) {:>8.1} s wall {:>12.0} events/s",
        m.n_finished(),
        m.events,
        m.macro_steps,
        wall,
        events_per_sec
    );
    println!(
        "scale run: peak arena {} slots ({:.4}% of trace) | makespan {:.0} s sim | JCT mean {:.1} ms | scale +{}/-{}",
        m.peak_arena,
        100.0 * m.peak_arena as f64 / m.n_finished().max(1) as f64,
        m.makespan_us as f64 / 1e6,
        m.jct_summary().mean,
        m.scale_ups,
        m.scale_downs
    );
    assert_eq!(m.n_finished(), sc.requests, "scale run must complete every request");
    assert!(m.records.is_empty(), "scale run must not retain records");

    // ---- 3. prefix warm-vs-cold A/B ----------------------------------
    let spec = repo_root().join("scenarios/prefix_reuse.json");
    let mut warm_sc = Scenario::load(spec.to_str().unwrap()).expect("prefix spec parses");
    warm_sc.requests = 20_000;
    warm_sc.records = false;
    if let Some(n) = std::env::var("ENGINE_BENCH_REQUESTS").ok().and_then(|v| v.parse().ok()) {
        warm_sc.requests = n;
    }
    let cold_sc = Scenario { prefix: None, ..warm_sc.clone() };
    println!("prefix A/B: {} requests warm (radix cache) vs cold ...", warm_sc.requests);
    let t = Instant::now();
    let warm = warm_sc.run().expect("warm prefix run resolves").metrics;
    let warm_wall = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let cold = cold_sc.run().expect("cold prefix run resolves").metrics;
    let cold_wall = t.elapsed().as_secs_f64();
    let warm_eps = warm.events as f64 / warm_wall.max(1e-12);
    let cold_eps = cold.events as f64 / cold_wall.max(1e-12);
    assert!(warm.cache_hits > 0, "warm prefix run must hit the cache");
    assert!(warm.prefill_tokens_saved > 0, "warm prefix run must save prefill tokens");
    assert_eq!(cold.cache_hits + cold.cache_misses, 0, "cold twin must never touch the cache");
    println!(
        "prefix A/B: cold {:>12.0} ev/s  warm {:>12.0} ev/s  hit rate {:>5.1}%  saved {} tok",
        cold_eps,
        warm_eps,
        warm.cache_hit_rate() * 100.0,
        warm.prefill_tokens_saved
    );
    println!(
        "prefix A/B: TTFT cold {:>8.1} ms -> warm {:>8.1} ms ({:+.1}%)",
        cold.ttft_summary().mean,
        warm.ttft_summary().mean,
        (warm.ttft_summary().mean / cold.ttft_summary().mean - 1.0) * 100.0
    );

    // ---- regression gate (warn-only) ---------------------------------
    // Compare the fresh scale-run throughput against the committed
    // baseline *before* overwriting it. Warn-only by default — committed
    // numbers from a different host/toolchain are not comparable until a
    // baseline is blessed on the CI host; BENCH_GATE_STRICT=1 turns the
    // warning into a failure (scripts/bench_gate.sh).
    let out = repo_root().join("BENCH_cluster.json");
    let tolerance: f64 = std::env::var("BENCH_TOLERANCE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    let baseline_eps = std::fs::read_to_string(&out)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.at(&["engine", "scale_run", "events_per_sec"])?.as_f64());
    match baseline_eps {
        Some(base) if base > 0.0 => {
            let ratio = events_per_sec / base;
            if ratio < 1.0 - tolerance {
                println!(
                    "WARNING: scale-run throughput regressed {:.1}% vs committed baseline \
                     ({:.0} -> {:.0} events/s, tolerance {:.0}%)",
                    (1.0 - ratio) * 100.0,
                    base,
                    events_per_sec,
                    tolerance * 100.0
                );
                if std::env::var("BENCH_GATE_STRICT").as_deref() == Ok("1") {
                    std::process::exit(1);
                }
            } else {
                println!(
                    "bench gate: {:.0} events/s vs baseline {:.0} ({:+.1}%, tolerance {:.0}%) — ok",
                    events_per_sec,
                    base,
                    (ratio - 1.0) * 100.0,
                    tolerance * 100.0
                );
            }
        }
        _ => println!(
            "bench gate: no committed engine baseline in {} — recording fresh numbers",
            out.display()
        ),
    }

    // ---- merge into BENCH_cluster.json -------------------------------
    let engine = Json::obj([
        ("meta", bench_meta()),
        (
            "queue",
            Json::obj([
                ("ops", Json::from(QUEUE_OPS)),
                ("standing_events", Json::from(QUEUE_HANDLES)),
                ("reps", Json::from(QUEUE_REPS)),
                ("heap_events_per_sec", Json::from(heap_eps)),
                ("calendar_events_per_sec", Json::from(cal_eps)),
                ("speedup", Json::from(cal_eps / heap_eps)),
            ]),
        ),
        (
            "scale_run",
            Json::obj([
                ("spec", Json::from("scenarios/scale.json")),
                ("requests", Json::from(m.n_finished())),
                ("events", Json::from(m.events)),
                ("macro_steps", Json::from(m.macro_steps)),
                ("events_per_sec", Json::from(events_per_sec)),
                ("wall_s", Json::from(wall)),
                ("peak_arena", Json::from(m.peak_arena)),
                ("makespan_s", Json::from(m.makespan_us as f64 / 1e6)),
            ]),
        ),
        (
            "prefix_ab",
            Json::obj([
                ("spec", Json::from("scenarios/prefix_reuse.json")),
                ("requests", Json::from(warm_sc.requests)),
                ("cold_events_per_sec", Json::from(cold_eps)),
                ("warm_events_per_sec", Json::from(warm_eps)),
                ("hit_rate", Json::from(warm.cache_hit_rate())),
                ("prefill_tokens_saved", Json::from(warm.prefill_tokens_saved)),
                ("ttft_cold_ms", Json::from(cold.ttft_summary().mean)),
                ("ttft_warm_ms", Json::from(warm.ttft_summary().mean)),
            ]),
        ),
    ]);
    // Section-keyed read-modify-write (util::merge_bench_sections): only
    // the "engine" key is replaced, so whatever benches/cluster.rs
    // recorded survives verbatim — idempotent however many times and in
    // whatever order the two benches re-run. Panics loudly on a
    // present-but-corrupt baseline instead of silently overwriting it.
    merge_bench_sections(
        &out,
        &[("bench", Json::from("cluster")), ("schema", Json::from(1u64))],
        vec![("engine", engine)],
    );
    println!("merged engine rows into {}", out.display());
}
