//! End-to-end benches, one per paper experiment: measures the wall time of
//! regenerating each figure's workload run and prints the figure's key
//! metric next to it, so `cargo bench` covers every table/figure the
//! paper reports (DESIGN.md experiment index). All simulated runs are
//! constructed through `api::Scenario`, the same specs the figure
//! harness and `tetri sim --spec` resolve.

use std::time::Instant;

use tetri_infer::api::{Report, Scenario};
use tetri_infer::coordinator::PredictorMode;
use tetri_infer::costmodel::CostModel;
use tetri_infer::decode::DecodePolicy;
use tetri_infer::prefill::{DispatchPolicy, PrefillPolicy};
use tetri_infer::workload::WorkloadKind;

const SEED: u64 = 42;

fn timed<T>(name: &str, metric: impl FnOnce() -> (T, String)) {
    let t = Instant::now();
    let (_, desc) = metric();
    println!("{name:<28} {:>8.1} ms   {desc}", t.elapsed().as_secs_f64() * 1e3);
}

fn run(sc: &Scenario) -> Report {
    sc.run().expect("builtin driver")
}

fn e2e(kind: WorkloadKind) -> (f64, String) {
    let sc = Scenario::builder().workload(kind).requests(128).rate(8.0).seed(SEED).build();
    let base = run(&sc.baseline_counterpart());
    let tetri = run(&sc);
    let p = tetri.perf_per_dollar_vs(&base);
    (
        p,
        format!(
            "TTFT {:+.0}%  JCT {:+.0}%  perf/$ {p:.2}x",
            (tetri.metrics.ttft_summary().mean / base.metrics.ttft_summary().mean - 1.0) * 100.0,
            (tetri.metrics.jct_summary().mean / base.metrics.jct_summary().mean - 1.0) * 100.0
        ),
    )
}

fn main() {
    println!("== figure-regeneration benches ==");
    let m = CostModel::default();

    timed("fig2 prefill saturation", || {
        let t = m.prefill_throughput(512);
        (t, format!("thpt@512 = {t:.0} tok/s"))
    });
    timed("fig3 prefill interference", || {
        let x = m.prefill_iter_us(18 + 7 * 512) as f64 / m.prefill_iter_us(18) as f64;
        (x, format!("LP+7HP slowdown = {x:.1}x"))
    });
    timed("fig4 mixed interference", || {
        let x = m.mixed_iter_us(512, 8, 800) as f64 / m.mixed_iter_us(0, 8, 800) as f64;
        (x, format!("decode slowdown w/ 1 HP = {x:.1}x"))
    });
    timed("fig5 decode interference", || {
        let x = m.decode_iter_us(128, 64 * 60 + 64 * 512) as f64 / m.decode_iter_us(128, 128 * 60) as f64;
        (x, format!("half-heavy latency = {x:+.0}%", x = (x - 1.0) * 100.0))
    });

    timed("fig11 LPLD e2e", || e2e(WorkloadKind::Lpld));
    timed("fig12 LPHD e2e", || e2e(WorkloadKind::Lphd));
    timed("fig13 HPLD e2e", || e2e(WorkloadKind::Hpld));
    timed("fig14 HPHD e2e", || e2e(WorkloadKind::Hphd));
    timed("fig15 Mixed e2e", || e2e(WorkloadKind::Mixed));

    timed("fig16 scheduler policies", || {
        let sc = Scenario::builder()
            .workload(WorkloadKind::Mixed)
            .requests(256)
            .rate(16.0)
            .seed(SEED)
            .build();
        let base = run(&sc.baseline_counterpart());
        let fcfs = run(&Scenario { prefill_policy: PrefillPolicy::Fcfs, ..sc });
        let x = fcfs.metrics.ttft_summary().mean / base.metrics.ttft_summary().mean - 1.0;
        (x, format!("chunked FCFS vs vLLM = {:+.0}%", x * 100.0))
    });

    timed("fig17 predictor co-run", || {
        let sc = Scenario::builder()
            .workload(WorkloadKind::Mixed)
            .requests(256)
            .rate(32.0)
            .seed(SEED)
            .build();
        let alone = run(&Scenario { predictor: PredictorMode::Disabled, ..sc.clone() });
        let par = run(&Scenario { predictor: PredictorMode::Parallel, ..sc });
        let x = par.metrics.ttft_summary().mean / alone.metrics.ttft_summary().mean - 1.0;
        (x, format!("parallel-mode overhead = {:+.0}%", x * 100.0))
    });

    timed("fig18 intra-decode policies", || {
        let sc = Scenario::builder()
            .workload(WorkloadKind::Lphd)
            .requests(160)
            .rate(10.0)
            .seed(SEED)
            .predictor_accuracy(1.0)
            .build();
        let greedy = run(&Scenario { decode_policy: DecodePolicy::Greedy, ..sc.clone() });
        let rd = run(&Scenario { decode_policy: DecodePolicy::ReserveDynamic, ..sc });
        let x = rd.metrics.jct_summary().mean / greedy.metrics.jct_summary().mean - 1.0;
        (x, format!("RD vs greedy (ideal acc) = {:+.0}%", x * 100.0))
    });

    timed("fig19 inter-decode balance", || {
        let sc = Scenario::builder()
            .workload(WorkloadKind::Mixed)
            .requests(128)
            .rate(32.0)
            .seed(SEED)
            .topology(1, 4)
            .build();
        let po2 = run(&Scenario { dispatch: DispatchPolicy::PowerOfTwo, ..sc.clone() });
        let imb = run(&Scenario { dispatch: DispatchPolicy::Imbalance, ..sc });
        let x = po2.metrics.makespan_us as f64 / imb.metrics.makespan_us as f64 - 1.0;
        (x, format!("po2 vs imbalance decode time = {:+.0}%", x * 100.0))
    });
}
