//! End-to-end benches, one per paper experiment: measures the wall time of
//! regenerating each figure's workload run and prints the figure's key
//! metric next to it, so `cargo bench` covers every table/figure the
//! paper reports (DESIGN.md experiment index).

use std::time::Instant;

use tetri_infer::baseline::{run_baseline, BaselineConfig};
use tetri_infer::coordinator::{run_cluster, ClusterConfig, PredictorMode};
use tetri_infer::costmodel::CostModel;
use tetri_infer::decode::DecodePolicy;
use tetri_infer::prefill::{DispatchPolicy, PrefillPolicy};
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

const SEED: u64 = 42;

fn timed<T>(name: &str, metric: impl FnOnce() -> (T, String)) {
    let t = Instant::now();
    let (_, desc) = metric();
    println!("{name:<28} {:>8.1} ms   {desc}", t.elapsed().as_secs_f64() * 1e3);
}

fn e2e(kind: WorkloadKind) -> (f64, String) {
    let trace = WorkloadGen::new(SEED).trace(kind, 128, 8.0, 0);
    let base = run_baseline(BaselineConfig { seed: SEED, ..Default::default() }, trace.clone());
    let tetri = run_cluster(ClusterConfig { seed: SEED, ..ClusterConfig::ts_roce(1, 1) }, trace);
    let p = tetri.perf_per_dollar_vs(&base);
    (
        p,
        format!(
            "TTFT {:+.0}%  JCT {:+.0}%  perf/$ {p:.2}x",
            (tetri.ttft_summary().mean / base.ttft_summary().mean - 1.0) * 100.0,
            (tetri.jct_summary().mean / base.jct_summary().mean - 1.0) * 100.0
        ),
    )
}

fn main() {
    println!("== figure-regeneration benches ==");
    let m = CostModel::default();

    timed("fig2 prefill saturation", || {
        let t = m.prefill_throughput(512);
        (t, format!("thpt@512 = {t:.0} tok/s"))
    });
    timed("fig3 prefill interference", || {
        let x = m.prefill_iter_us(18 + 7 * 512) as f64 / m.prefill_iter_us(18) as f64;
        (x, format!("LP+7HP slowdown = {x:.1}x"))
    });
    timed("fig4 mixed interference", || {
        let x = m.mixed_iter_us(512, 8, 800) as f64 / m.mixed_iter_us(0, 8, 800) as f64;
        (x, format!("decode slowdown w/ 1 HP = {x:.1}x"))
    });
    timed("fig5 decode interference", || {
        let x = m.decode_iter_us(128, 64 * 60 + 64 * 512) as f64 / m.decode_iter_us(128, 128 * 60) as f64;
        (x, format!("half-heavy latency = {x:+.0}%", x = (x - 1.0) * 100.0))
    });

    timed("fig11 LPLD e2e", || e2e(WorkloadKind::Lpld));
    timed("fig12 LPHD e2e", || e2e(WorkloadKind::Lphd));
    timed("fig13 HPLD e2e", || e2e(WorkloadKind::Hpld));
    timed("fig14 HPHD e2e", || e2e(WorkloadKind::Hphd));
    timed("fig15 Mixed e2e", || e2e(WorkloadKind::Mixed));

    timed("fig16 scheduler policies", || {
        let mk = || WorkloadGen::new(SEED).trace(WorkloadKind::Mixed, 256, 16.0, 0);
        let base = run_baseline(BaselineConfig { seed: SEED, ..Default::default() }, mk());
        let fcfs = run_cluster(
            ClusterConfig { prefill_policy: PrefillPolicy::Fcfs, seed: SEED, ..ClusterConfig::ts_roce(1, 1) },
            mk(),
        );
        let x = fcfs.ttft_summary().mean / base.ttft_summary().mean - 1.0;
        (x, format!("chunked FCFS vs vLLM = {:+.0}%", x * 100.0))
    });

    timed("fig17 predictor co-run", || {
        let mk = || WorkloadGen::new(SEED).trace(WorkloadKind::Mixed, 256, 32.0, 0);
        let alone = run_cluster(
            ClusterConfig { predictor_mode: PredictorMode::Disabled, seed: SEED, ..ClusterConfig::ts_roce(1, 1) },
            mk(),
        );
        let par = run_cluster(
            ClusterConfig { predictor_mode: PredictorMode::Parallel, seed: SEED, ..ClusterConfig::ts_roce(1, 1) },
            mk(),
        );
        let x = par.ttft_summary().mean / alone.ttft_summary().mean - 1.0;
        (x, format!("parallel-mode overhead = {:+.0}%", x * 100.0))
    });

    timed("fig18 intra-decode policies", || {
        let mk = || WorkloadGen::new(SEED).trace(WorkloadKind::Lphd, 160, 10.0, 0);
        let greedy = run_cluster(
            ClusterConfig { decode_policy: DecodePolicy::Greedy, predictor_accuracy: 1.0, seed: SEED, ..ClusterConfig::ts_roce(1, 1) },
            mk(),
        );
        let rd = run_cluster(
            ClusterConfig { decode_policy: DecodePolicy::ReserveDynamic, predictor_accuracy: 1.0, seed: SEED, ..ClusterConfig::ts_roce(1, 1) },
            mk(),
        );
        let x = rd.jct_summary().mean / greedy.jct_summary().mean - 1.0;
        (x, format!("RD vs greedy (ideal acc) = {:+.0}%", x * 100.0))
    });

    timed("fig19 inter-decode balance", || {
        let mk = || WorkloadGen::new(SEED).trace(WorkloadKind::Mixed, 128, 32.0, 0);
        let po2 = run_cluster(
            ClusterConfig { dispatch: DispatchPolicy::PowerOfTwo, seed: SEED, ..ClusterConfig::ts_roce(1, 4) },
            mk(),
        );
        let imb = run_cluster(
            ClusterConfig { dispatch: DispatchPolicy::Imbalance, seed: SEED, ..ClusterConfig::ts_roce(1, 4) },
            mk(),
        );
        let x = po2.makespan_us as f64 / imb.makespan_us as f64 - 1.0;
        (x, format!("po2 vs imbalance decode time = {:+.0}%", x * 100.0))
    });
}
