//! End-to-end DES throughput bench: the perf-trajectory baseline future
//! PRs regress against (hand-rolled harness: no criterion vendored).
//!
//! Measures events/sec and wall time of whole cluster runs on the
//! scenarios that stress the hot paths this repo optimizes —
//! preemption-heavy decode (greedy policy under memory pressure, where
//! eviction/completion used to be O(batch²)), a multi-instance mixed
//! cluster (dispatch + monitor + arena paths), and the coupled baseline —
//! plus the parallel sweep harness's serial-vs-parallel speedup.
//!
//! Emits machine-readable `BENCH_cluster.json` at the repo root (see
//! EXPERIMENTS.md §Perf for the schema and the recorded trajectory).
//! Run via `cargo bench --bench cluster` or scripts/bench.sh.

use std::time::Instant;

use tetri_infer::baseline::BaselineConfig;
use tetri_infer::coordinator::ClusterConfig;
use tetri_infer::costmodel::CostModel;
use tetri_infer::decode::DecodePolicy;
use tetri_infer::metrics::RunMetrics;
use tetri_infer::sweep::{default_workers, run_cells, SweepCell, SweepSystem};
use tetri_infer::util::{repo_root, Json};
use tetri_infer::workload::WorkloadKind;

const REPS: usize = 3;

struct Row {
    name: String,
    events: u64,
    requests: u64,
    wall_ms: f64,
    events_per_sec: f64,
    makespan_s: f64,
}

/// Best-of-REPS wall time for one deterministic scenario.
fn run_scenario(name: &str, cell: SweepCell) -> Row {
    let mut best = f64::MAX;
    let mut metrics: Option<RunMetrics> = None;
    for _ in 0..REPS {
        let r = cell.clone().run();
        best = best.min(r.wall_secs);
        metrics = Some(r.metrics);
    }
    let m = metrics.unwrap();
    let row = Row {
        name: name.to_string(),
        events: m.events,
        requests: m.records.len() as u64,
        wall_ms: best * 1e3,
        events_per_sec: m.events as f64 / best.max(1e-12),
        makespan_s: m.makespan_us as f64 / 1e6,
    };
    println!(
        "{:<28} {:>9} events {:>7} reqs {:>9.1} ms {:>12.0} events/s  (makespan {:.1}s sim)",
        row.name, row.events, row.requests, row.wall_ms, row.events_per_sec, row.makespan_s
    );
    row
}

fn cluster_cell(label: &str, cfg: ClusterConfig, kind: WorkloadKind, n: usize, rate: f64, seed: u64) -> SweepCell {
    SweepCell {
        label: label.to_string(),
        system: SweepSystem::Cluster(cfg),
        kind,
        n_requests: n,
        rate_per_sec: rate,
        trace_seed: seed,
    }
}

fn main() {
    println!("== end-to-end cluster DES benches (best of {REPS}) ==");

    let mut rows = Vec::new();

    // The §Perf headline scenario: greedy decode admission under a
    // shrunken HBM — constant preemption/swap churn, the regime where the
    // old Vec::remove victim loops went quadratic in the batch.
    rows.push(run_scenario(
        "preempt_greedy_pressure",
        cluster_cell(
            "preempt",
            ClusterConfig {
                decode_policy: DecodePolicy::Greedy,
                cost: CostModel { hbm_kv_bytes: 2e9, ..Default::default() },
                flip: None,
                ..ClusterConfig::ts_roce(1, 1)
            },
            WorkloadKind::Lphd,
            192,
            0.0,
            13,
        ),
    ));

    // Mixed multi-instance cluster: dispatch, monitor broadcast, arena
    // and transfer paths all hot.
    rows.push(run_scenario(
        "mixed_cluster_2p4d",
        cluster_cell(
            "mixed",
            ClusterConfig { seed: 5, ..ClusterConfig::ts_roce(2, 4) },
            WorkloadKind::Mixed,
            512,
            32.0,
            5,
        ),
    ));

    // The coupled vLLM baseline driver (its own arena + fixed-batch path).
    rows.push(run_scenario(
        "baseline_coupled_2x",
        SweepCell {
            label: "baseline".to_string(),
            system: SweepSystem::Baseline(BaselineConfig {
                n_instances: 2,
                seed: 7,
                ..Default::default()
            }),
            kind: WorkloadKind::Mixed,
            n_requests: 256,
            rate_per_sec: 8.0,
            trace_seed: 7,
        },
    ));

    // Sweep harness: the same 8-seed mixed sweep serial vs parallel.
    let mk_sweep = || -> Vec<SweepCell> {
        (0..8u64)
            .map(|seed| {
                cluster_cell(
                    &format!("sweep-seed{seed}"),
                    ClusterConfig { seed, ..ClusterConfig::ts_roce(2, 4) },
                    WorkloadKind::Mixed,
                    256,
                    32.0,
                    seed,
                )
            })
            .collect()
    };
    let t = Instant::now();
    let serial = run_cells(mk_sweep(), 1);
    let serial_s = t.elapsed().as_secs_f64();
    let workers = default_workers();
    let t = Instant::now();
    let parallel = run_cells(mk_sweep(), workers);
    let parallel_s = t.elapsed().as_secs_f64();
    let sweep_events: u64 = parallel.iter().map(|c| c.metrics.events).sum();
    for (a, b) in serial.iter().zip(parallel.iter()) {
        assert_eq!(
            a.metrics.makespan_us, b.metrics.makespan_us,
            "sweep must be deterministic across worker counts"
        );
    }
    let speedup = serial_s / parallel_s.max(1e-12);
    println!(
        "{:<28} {:>9} events {:>7} cells {:>9.1} ms serial {:>9.1} ms x{} workers  ({speedup:.2}x)",
        "sweep_8seed_mixed",
        sweep_events,
        parallel.len(),
        serial_s * 1e3,
        parallel_s * 1e3,
        workers
    );

    // ---- machine-readable trajectory
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj([
                ("name", Json::from(r.name.clone())),
                ("events", Json::from(r.events)),
                ("requests", Json::from(r.requests)),
                ("wall_ms", Json::from(r.wall_ms)),
                ("events_per_sec", Json::from(r.events_per_sec)),
                ("makespan_s", Json::from(r.makespan_s)),
            ])
        })
        .collect();
    let doc = Json::obj([
        ("bench", Json::from("cluster")),
        ("schema", Json::from(1u64)),
        ("reps", Json::from(REPS)),
        ("rows", Json::from(json_rows)),
        (
            "sweep",
            Json::obj([
                ("cells", Json::from(parallel.len())),
                ("events", Json::from(sweep_events)),
                ("serial_ms", Json::from(serial_s * 1e3)),
                ("parallel_ms", Json::from(parallel_s * 1e3)),
                ("workers", Json::from(workers)),
                ("speedup", Json::from(speedup)),
            ]),
        ),
    ]);
    let path = repo_root().join("BENCH_cluster.json");
    std::fs::write(&path, doc.dump()).expect("writing BENCH_cluster.json");
    println!("wrote {}", path.display());
}
