//! End-to-end DES throughput bench: the perf-trajectory baseline future
//! PRs regress against (hand-rolled harness: no criterion vendored).
//!
//! Measures events/sec and wall time of whole cluster runs on the
//! scenarios that stress the hot paths this repo optimizes —
//! preemption-heavy decode (greedy policy under memory pressure, where
//! eviction/completion used to be O(batch²)), a multi-instance mixed
//! cluster (dispatch + monitor + arena paths), and the coupled baseline —
//! plus the parallel sweep harness's serial-vs-parallel speedup. Every
//! run is described by an `api::Scenario` (the preemption cell is
//! scenarios/preempt_pressure.json).
//!
//! Emits machine-readable `BENCH_cluster.json` at the repo root (see
//! EXPERIMENTS.md §Perf for the schema and the recorded trajectory).
//! Run via `cargo bench --bench cluster` or scripts/bench.sh.

use std::time::Instant;

use tetri_infer::api::Scenario;
use tetri_infer::decode::DecodePolicy;
use tetri_infer::metrics::RunMetrics;
use tetri_infer::sweep::{default_workers, run_cells, SweepCell};
use tetri_infer::util::{bench_meta, merge_bench_sections, repo_root, Json};
use tetri_infer::workload::WorkloadKind;

const REPS: usize = 3;

struct Row {
    name: String,
    events: u64,
    requests: u64,
    wall_ms: f64,
    events_per_sec: f64,
    makespan_s: f64,
}

/// Best-of-REPS wall time for one deterministic scenario.
fn run_scenario(name: &str, sc: Scenario) -> Row {
    let mut best = f64::MAX;
    let mut metrics: Option<RunMetrics> = None;
    for _ in 0..REPS {
        let r = SweepCell::new(name, sc.clone()).run();
        best = best.min(r.report.wall_secs);
        metrics = Some(r.report.metrics);
    }
    let m = metrics.unwrap();
    let row = Row {
        name: name.to_string(),
        events: m.events,
        requests: m.n_finished() as u64,
        wall_ms: best * 1e3,
        events_per_sec: m.events as f64 / best.max(1e-12),
        makespan_s: m.makespan_us as f64 / 1e6,
    };
    println!(
        "{:<28} {:>9} events {:>7} reqs {:>9.1} ms {:>12.0} events/s  (makespan {:.1}s sim)",
        row.name, row.events, row.requests, row.wall_ms, row.events_per_sec, row.makespan_s
    );
    row
}

fn main() {
    println!("== end-to-end cluster DES benches (best of {REPS}) ==");

    let mut rows = Vec::new();

    // The §Perf headline scenario: greedy decode admission under a
    // shrunken HBM — constant preemption/swap churn, the regime where the
    // old Vec::remove victim loops went quadratic in the batch.
    rows.push(run_scenario(
        "preempt_greedy_pressure",
        Scenario::builder()
            .name("preempt")
            .workload(WorkloadKind::Lphd)
            .requests(192)
            .seed(13)
            .decode_policy(DecodePolicy::Greedy)
            .hbm_kv_bytes(Some(2e9))
            .flip_idle_ms(None)
            .build(),
    ));

    // Mixed multi-instance cluster: dispatch, monitor broadcast, arena
    // and transfer paths all hot.
    rows.push(run_scenario(
        "mixed_cluster_2p4d",
        Scenario::builder()
            .name("mixed")
            .workload(WorkloadKind::Mixed)
            .requests(512)
            .rate(32.0)
            .seed(5)
            .topology(2, 4)
            .build(),
    ));

    // The coupled vLLM baseline driver (its own arena + fixed-batch path).
    rows.push(run_scenario(
        "baseline_coupled_2x",
        Scenario::builder()
            .name("baseline")
            .driver("vllm")
            .workload(WorkloadKind::Mixed)
            .requests(256)
            .rate(8.0)
            .seed(7)
            .topology(2, 2) // → 2 coupled instances (min convention)
            .build(),
    ));

    // Sweep harness: the same 8-seed mixed sweep serial vs parallel.
    let mk_sweep = || -> Vec<SweepCell> {
        (0..8u64)
            .map(|seed| {
                SweepCell::new(
                    format!("sweep-seed{seed}"),
                    Scenario::builder()
                        .workload(WorkloadKind::Mixed)
                        .requests(256)
                        .rate(32.0)
                        .seed(seed)
                        .topology(2, 4)
                        .build(),
                )
            })
            .collect()
    };
    let t = Instant::now();
    let serial = run_cells(mk_sweep(), 1);
    let serial_s = t.elapsed().as_secs_f64();
    let workers = default_workers();
    let t = Instant::now();
    let parallel = run_cells(mk_sweep(), workers);
    let parallel_s = t.elapsed().as_secs_f64();
    let sweep_events: u64 = parallel.iter().map(|c| c.report.metrics.events).sum();
    for (a, b) in serial.iter().zip(parallel.iter()) {
        assert_eq!(
            a.report.metrics.makespan_us, b.report.metrics.makespan_us,
            "sweep must be deterministic across worker counts"
        );
    }
    let speedup = serial_s / parallel_s.max(1e-12);
    println!(
        "{:<28} {:>9} events {:>7} cells {:>9.1} ms serial {:>9.1} ms x{} workers  ({speedup:.2}x)",
        "sweep_8seed_mixed",
        sweep_events,
        parallel.len(),
        serial_s * 1e3,
        parallel_s * 1e3,
        workers
    );

    // ---- machine-readable trajectory
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj([
                ("name", Json::from(r.name.clone())),
                ("events", Json::from(r.events)),
                ("requests", Json::from(r.requests)),
                ("wall_ms", Json::from(r.wall_ms)),
                ("events_per_sec", Json::from(r.events_per_sec)),
                ("makespan_s", Json::from(r.makespan_s)),
            ])
        })
        .collect();
    // Section-keyed read-modify-write: only this bench's keys are
    // replaced, so the "engine" section benches/engine.rs owns survives
    // verbatim (the old full-file write orphaned it on every re-run).
    let path = repo_root().join("BENCH_cluster.json");
    merge_bench_sections(
        &path,
        &[("bench", Json::from("cluster")), ("schema", Json::from(1u64))],
        vec![
            ("meta", bench_meta()),
            ("reps", Json::from(REPS)),
            ("rows", Json::from(json_rows)),
            (
                "sweep",
                Json::obj([
                    ("cells", Json::from(parallel.len())),
                    ("events", Json::from(sweep_events)),
                    ("serial_ms", Json::from(serial_s * 1e3)),
                    ("parallel_ms", Json::from(parallel_s * 1e3)),
                    ("workers", Json::from(workers)),
                    ("speedup", Json::from(speedup)),
                ]),
            ),
        ],
    );
    println!("merged cluster rows into {}", path.display());
}
