//! Prefill instance's local scheduler (§3.3.1): FCFS / SJF / LJF over a
//! raw queue, with a `PrefillSchedBatch` anti-starvation window — only
//! `sched_batch` requests are sorted and committed at a time, so a stream
//! of short jobs cannot starve a long one forever (and vice versa).
//!
//! The queued-token total is maintained incrementally (push/pop), so the
//! global scheduler's least-loaded routing reads it in O(1) instead of
//! rescanning both queues per arrival (see DESIGN.md §Hot paths).

use std::collections::VecDeque;

use crate::types::{ReqMeta, Us};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillPolicy {
    Fcfs,
    /// Shortest-job-first: prefill time is accurately predictable from
    /// prompt length, so SJF is exact (not estimated).
    Sjf,
    Ljf,
    /// SLO policy: priority tier first (tier 0 = most latency-critical,
    /// never scheduled behind a higher tier number within a committed
    /// batch), earliest TTFT deadline first within a tier; classes
    /// without a TTFT target order by arrival behind deadlined peers of
    /// the same tier. Requires a class table
    /// ([`PrefillScheduler::set_class_table`]); chunk-budget preemption
    /// downstream is unchanged.
    Slo,
}

impl PrefillPolicy {
    pub fn name(self) -> &'static str {
        match self {
            PrefillPolicy::Fcfs => "FCFS",
            PrefillPolicy::Sjf => "SJF",
            PrefillPolicy::Ljf => "LJF",
            PrefillPolicy::Slo => "SLO-EDF",
        }
    }
}

#[derive(Debug)]
pub struct PrefillScheduler {
    pub policy: PrefillPolicy,
    /// PrefillSchedBatch: how many requests are sorted per scheduling round.
    pub sched_batch: usize,
    raw: VecDeque<ReqMeta>,
    scheduled: VecDeque<ReqMeta>,
    /// Prompt tokens across both queues, maintained incrementally.
    tokens: u64,
    /// `(tier, ttft_deadline_us)` per workload class, indexed by class id
    /// (`Us::MAX` deadline = no TTFT target) — the [`PrefillPolicy::Slo`]
    /// sort key source. Empty for classless runs: every class resolves to
    /// `(0, MAX)` and SLO degenerates to FCFS.
    class_table: Vec<(u8, Us)>,
}

impl PrefillScheduler {
    pub fn new(policy: PrefillPolicy, sched_batch: usize) -> Self {
        assert!(sched_batch > 0);
        PrefillScheduler {
            policy,
            sched_batch,
            raw: VecDeque::new(),
            scheduled: VecDeque::new(),
            tokens: 0,
            class_table: Vec::new(),
        }
    }

    /// Install the per-class `(tier, ttft_deadline_us)` table the SLO
    /// policy sorts by (see `slo::SloConfig::prefill_table`).
    pub fn set_class_table(&mut self, table: Vec<(u8, Us)>) {
        self.class_table = table;
    }

    /// `(tier, absolute deadline)` of one request under the class table.
    fn slo_key(&self, r: &ReqMeta) -> (u8, Us) {
        let (tier, dl) = self.class_table.get(r.class as usize).copied().unwrap_or((0, Us::MAX));
        (tier, r.arrival.saturating_add(dl))
    }

    pub fn push(&mut self, req: ReqMeta) {
        self.tokens += req.prompt_len as u64;
        self.raw.push_back(req);
    }

    pub fn queued(&self) -> usize {
        self.raw.len() + self.scheduled.len()
    }

    /// Prompt tokens awaiting prefill — O(1) (cached).
    pub fn queued_tokens(&self) -> u64 {
        self.tokens
    }

    pub fn is_empty(&self) -> bool {
        self.queued() == 0
    }

    /// Move one scheduling batch from raw → scheduled, sorted per policy.
    fn refill(&mut self) {
        if !self.scheduled.is_empty() || self.raw.is_empty() {
            return;
        }
        let n = self.sched_batch.min(self.raw.len());
        let mut batch: Vec<ReqMeta> = self.raw.drain(..n).collect();
        match self.policy {
            PrefillPolicy::Fcfs => {}
            // stable sort keeps arrival order among equal lengths
            PrefillPolicy::Sjf => batch.sort_by_key(|r| r.prompt_len),
            PrefillPolicy::Ljf => batch.sort_by_key(|r| std::cmp::Reverse(r.prompt_len)),
            // tier, then earliest absolute TTFT deadline; stable sort
            // keeps arrival order among undeadlined (MAX-key) peers
            PrefillPolicy::Slo => batch.sort_by_key(|r| self.slo_key(r)),
        }
        self.scheduled.extend(batch);
    }

    /// Next request to prefill (consumed by the chunker).
    pub fn pop(&mut self) -> Option<ReqMeta> {
        self.refill();
        let req = self.scheduled.pop_front()?;
        self.tokens -= req.prompt_len as u64;
        Some(req)
    }

    /// Peek without consuming (used by backpressure checks).
    pub fn peek(&mut self) -> Option<&ReqMeta> {
        self.refill();
        self.scheduled.front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn req(id: u64, plen: u32) -> ReqMeta {
        ReqMeta {
            id,
            task: TaskType::Chat,
            class: 0,
            arrival: id,
            prompt_len: plen,
            predicted: None,
            prefix: None,
        }
    }

    fn classed(id: u64, class: u8, arrival: Us) -> ReqMeta {
        ReqMeta {
            id,
            task: TaskType::Chat,
            class,
            arrival,
            prompt_len: 10,
            predicted: None,
            prefix: None,
        }
    }

    fn drain(s: &mut PrefillScheduler) -> Vec<u64> {
        std::iter::from_fn(|| s.pop()).map(|r| r.id).collect()
    }

    #[test]
    fn fcfs_keeps_arrival_order() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Fcfs, 16);
        for (i, p) in [50, 10, 30].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(drain(&mut s), vec![0, 1, 2]);
    }

    #[test]
    fn sjf_sorts_ascending_within_batch() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 16);
        for (i, p) in [50, 10, 30].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(drain(&mut s), vec![1, 2, 0]);
    }

    #[test]
    fn ljf_sorts_descending_within_batch() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Ljf, 16);
        for (i, p) in [50, 10, 30].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(drain(&mut s), vec![0, 2, 1]);
    }

    #[test]
    fn slo_orders_tier_then_deadline_then_arrival() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Slo, 16);
        // class 0: tier 0, 100 ms TTFT; class 1: tier 1, 50 ms TTFT;
        // class 2: tier 1, no deadline
        s.set_class_table(vec![(0, 100_000), (1, 50_000), (1, Us::MAX)]);
        s.push(classed(0, 2, 0)); // tier 1, no deadline
        s.push(classed(1, 1, 10)); // tier 1, dl 50_010
        s.push(classed(2, 0, 90)); // tier 0, dl 100_090
        s.push(classed(3, 1, 5)); // tier 1, dl 50_005
        s.push(classed(4, 0, 20)); // tier 0, dl 100_020
        s.push(classed(5, 2, 1)); // tier 1, no deadline, arrived after 0
        // tier 0 first (by deadline), then tier-1 deadlines, then the
        // undeadlined tier-1 pair in arrival (push) order
        assert_eq!(drain(&mut s), vec![4, 2, 3, 1, 0, 5]);
    }

    #[test]
    fn slo_without_table_degenerates_to_fcfs() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Slo, 16);
        for (i, p) in [50, 10, 30].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(drain(&mut s), vec![0, 1, 2], "classless: every key is (0, MAX)");
    }

    #[test]
    fn sched_batch_prevents_starvation() {
        // One long job among shorts: with batch=2, the long job must be
        // scheduled within its window even under SJF.
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 2);
        s.push(req(0, 1000)); // long, arrives first
        s.push(req(1, 1));
        s.push(req(2, 2));
        s.push(req(3, 3));
        let order = drain(&mut s);
        let pos = order.iter().position(|&id| id == 0).unwrap();
        assert!(pos < 2, "long job starved: order {order:?}");
    }

    #[test]
    fn late_arrivals_do_not_jump_committed_batch() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 4);
        s.push(req(0, 100));
        s.push(req(1, 200));
        assert_eq!(s.pop().unwrap().id, 0); // batch {0,1} committed
        s.push(req(2, 1)); // shorter, but next batch
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.pop().unwrap().id, 2);
    }

    #[test]
    fn queued_tokens_counts_both_queues() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Fcfs, 1);
        s.push(req(0, 10));
        s.push(req(1, 20));
        s.peek(); // forces one refill
        assert_eq!(s.queued_tokens(), 30);
        assert_eq!(s.queued(), 2);
    }

    #[test]
    fn queued_tokens_tracks_pops_incrementally() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 4);
        for (i, p) in [100u32, 40, 7].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(s.queued_tokens(), 147);
        let first = s.pop().unwrap();
        assert_eq!(s.queued_tokens(), 147 - first.prompt_len as u64);
        while s.pop().is_some() {}
        assert_eq!(s.queued_tokens(), 0);
    }
}
