//! Prefill instance's local scheduler (§3.3.1): FCFS / SJF / LJF over a
//! raw queue, with a `PrefillSchedBatch` anti-starvation window — only
//! `sched_batch` requests are sorted and committed at a time, so a stream
//! of short jobs cannot starve a long one forever (and vice versa).
//!
//! The queued-token total is maintained incrementally (push/pop), so the
//! global scheduler's least-loaded routing reads it in O(1) instead of
//! rescanning both queues per arrival (see DESIGN.md §Hot paths).

use std::collections::VecDeque;

use crate::types::ReqMeta;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillPolicy {
    Fcfs,
    /// Shortest-job-first: prefill time is accurately predictable from
    /// prompt length, so SJF is exact (not estimated).
    Sjf,
    Ljf,
}

impl PrefillPolicy {
    pub fn name(self) -> &'static str {
        match self {
            PrefillPolicy::Fcfs => "FCFS",
            PrefillPolicy::Sjf => "SJF",
            PrefillPolicy::Ljf => "LJF",
        }
    }
}

#[derive(Debug)]
pub struct PrefillScheduler {
    pub policy: PrefillPolicy,
    /// PrefillSchedBatch: how many requests are sorted per scheduling round.
    pub sched_batch: usize,
    raw: VecDeque<ReqMeta>,
    scheduled: VecDeque<ReqMeta>,
    /// Prompt tokens across both queues, maintained incrementally.
    tokens: u64,
}

impl PrefillScheduler {
    pub fn new(policy: PrefillPolicy, sched_batch: usize) -> Self {
        assert!(sched_batch > 0);
        PrefillScheduler {
            policy,
            sched_batch,
            raw: VecDeque::new(),
            scheduled: VecDeque::new(),
            tokens: 0,
        }
    }

    pub fn push(&mut self, req: ReqMeta) {
        self.tokens += req.prompt_len as u64;
        self.raw.push_back(req);
    }

    pub fn queued(&self) -> usize {
        self.raw.len() + self.scheduled.len()
    }

    /// Prompt tokens awaiting prefill — O(1) (cached).
    pub fn queued_tokens(&self) -> u64 {
        self.tokens
    }

    pub fn is_empty(&self) -> bool {
        self.queued() == 0
    }

    /// Move one scheduling batch from raw → scheduled, sorted per policy.
    fn refill(&mut self) {
        if !self.scheduled.is_empty() || self.raw.is_empty() {
            return;
        }
        let n = self.sched_batch.min(self.raw.len());
        let mut batch: Vec<ReqMeta> = self.raw.drain(..n).collect();
        match self.policy {
            PrefillPolicy::Fcfs => {}
            // stable sort keeps arrival order among equal lengths
            PrefillPolicy::Sjf => batch.sort_by_key(|r| r.prompt_len),
            PrefillPolicy::Ljf => batch.sort_by_key(|r| std::cmp::Reverse(r.prompt_len)),
        }
        self.scheduled.extend(batch);
    }

    /// Next request to prefill (consumed by the chunker).
    pub fn pop(&mut self) -> Option<ReqMeta> {
        self.refill();
        let req = self.scheduled.pop_front()?;
        self.tokens -= req.prompt_len as u64;
        Some(req)
    }

    /// Peek without consuming (used by backpressure checks).
    pub fn peek(&mut self) -> Option<&ReqMeta> {
        self.refill();
        self.scheduled.front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn req(id: u64, plen: u32) -> ReqMeta {
        ReqMeta { id, task: TaskType::Chat, arrival: id, prompt_len: plen, predicted: None }
    }

    fn drain(s: &mut PrefillScheduler) -> Vec<u64> {
        std::iter::from_fn(|| s.pop()).map(|r| r.id).collect()
    }

    #[test]
    fn fcfs_keeps_arrival_order() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Fcfs, 16);
        for (i, p) in [50, 10, 30].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(drain(&mut s), vec![0, 1, 2]);
    }

    #[test]
    fn sjf_sorts_ascending_within_batch() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 16);
        for (i, p) in [50, 10, 30].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(drain(&mut s), vec![1, 2, 0]);
    }

    #[test]
    fn ljf_sorts_descending_within_batch() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Ljf, 16);
        for (i, p) in [50, 10, 30].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(drain(&mut s), vec![0, 2, 1]);
    }

    #[test]
    fn sched_batch_prevents_starvation() {
        // One long job among shorts: with batch=2, the long job must be
        // scheduled within its window even under SJF.
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 2);
        s.push(req(0, 1000)); // long, arrives first
        s.push(req(1, 1));
        s.push(req(2, 2));
        s.push(req(3, 3));
        let order = drain(&mut s);
        let pos = order.iter().position(|&id| id == 0).unwrap();
        assert!(pos < 2, "long job starved: order {order:?}");
    }

    #[test]
    fn late_arrivals_do_not_jump_committed_batch() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 4);
        s.push(req(0, 100));
        s.push(req(1, 200));
        assert_eq!(s.pop().unwrap().id, 0); // batch {0,1} committed
        s.push(req(2, 1)); // shorter, but next batch
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.pop().unwrap().id, 2);
    }

    #[test]
    fn queued_tokens_counts_both_queues() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Fcfs, 1);
        s.push(req(0, 10));
        s.push(req(1, 20));
        s.peek(); // forces one refill
        assert_eq!(s.queued_tokens(), 30);
        assert_eq!(s.queued(), 2);
    }

    #[test]
    fn queued_tokens_tracks_pops_incrementally() {
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 4);
        for (i, p) in [100u32, 40, 7].iter().enumerate() {
            s.push(req(i as u64, *p));
        }
        assert_eq!(s.queued_tokens(), 147);
        let first = s.pop().unwrap();
        assert_eq!(s.queued_tokens(), 147 - first.prompt_len as u64);
        while s.pop().is_some() {}
        assert_eq!(s.queued_tokens(), 0);
    }
}
