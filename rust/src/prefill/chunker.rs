//! Chunked prefill (§3.3.3): slice and merge scheduled prompts into
//! fixed-size `ChunkSize` chunks (Figure 7) without altering their order.
//! The final chunk of a batch may be partial and is padded to ChunkSize —
//! the accelerator always runs one saturated iteration per chunk.
//!
//! Progress tracking is the paper's "simple variable per request that
//! records the last prefilled token position".

use std::collections::VecDeque;

use crate::types::{ReqId, ReqMeta};

/// A contiguous span of one request's prompt inside a chunk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Segment {
    pub req: ReqId,
    /// First prompt position covered by this segment.
    pub start: u32,
    pub len: u32,
    /// True iff this segment completes the request's prompt — its KV can
    /// be dispatched and its first token emitted.
    pub last: bool,
}

/// One fixed-size prefill iteration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub segments: Vec<Segment>,
    /// Real prompt tokens in the chunk (≤ chunk_size; rest is padding).
    pub tokens: u32,
    pub chunk_size: u32,
}

impl Chunk {
    pub fn pad(&self) -> u32 {
        self.chunk_size - self.tokens
    }
}

/// In-progress request state inside the chunker.
#[derive(Clone, Copy, Debug)]
struct Open {
    req: ReqMeta,
    /// Last prefilled token position (exclusive).
    done: u32,
}

#[derive(Debug)]
pub struct Chunker {
    pub chunk_size: u32,
    /// Shortest-remaining-time-first chunk assembly (§3.3.1's noted
    /// future work): chunked prefill makes prefill preemptible, so at
    /// every chunk boundary the open request with the least remaining
    /// prompt goes first. Off by default (paper semantics: FIFO order of
    /// the scheduled queue, no reordering).
    pub srtf: bool,
    open: VecDeque<Open>,
    /// Unprefilled tokens across all open requests, maintained
    /// incrementally (admit adds, slicing subtracts) so backpressure and
    /// load queries are O(1).
    pending: u64,
}

impl Chunker {
    pub fn new(chunk_size: u32) -> Self {
        assert!(chunk_size > 0);
        Chunker { chunk_size, srtf: false, open: VecDeque::new(), pending: 0 }
    }

    pub fn new_srtf(chunk_size: u32) -> Self {
        Chunker { srtf: true, ..Chunker::new(chunk_size) }
    }

    /// Admit a scheduled request for slicing.
    pub fn admit(&mut self, req: ReqMeta) {
        self.pending += req.prompt_len as u64;
        self.open.push_back(Open { req, done: 0 });
    }

    /// Unprefilled tokens still open — O(1) (cached).
    pub fn pending_tokens(&self) -> u64 {
        self.pending
    }

    pub fn has_work(&self) -> bool {
        !self.open.is_empty()
    }

    pub fn n_open(&self) -> usize {
        self.open.len()
    }

    /// Build the next fixed-size chunk by slicing the open requests in
    /// order. Returns None when no prompt tokens are pending.
    pub fn next_chunk(&mut self) -> Option<Chunk> {
        if self.open.is_empty() {
            return None;
        }
        if self.srtf {
            // preempt at the chunk boundary: least remaining prompt first
            // (stable, so equal-remaining requests keep arrival order)
            self.open
                .make_contiguous()
                .sort_by_key(|o| o.req.prompt_len - o.done);
        }
        let mut segments = Vec::new();
        let mut used = 0u32;
        while used < self.chunk_size {
            let Some(o) = self.open.front_mut() else { break };
            let remaining = o.req.prompt_len - o.done;
            let take = remaining.min(self.chunk_size - used);
            let last = take == remaining;
            segments.push(Segment { req: o.req.id, start: o.done, len: take, last });
            o.done += take;
            used += take;
            if last {
                self.open.pop_front();
            }
        }
        debug_assert!(!segments.is_empty());
        self.pending -= used as u64;
        Some(Chunk { segments, tokens: used, chunk_size: self.chunk_size })
    }

    /// Crash harvest: take every open request (partially prefilled
    /// progress is lost — recovery re-prefills from token 0) and zero the
    /// pending-token tally so no load remains attributed to the dead
    /// incarnation. Requests come back in queue order.
    pub fn drain_open(&mut self) -> Vec<ReqMeta> {
        self.pending = 0;
        self.open.drain(..).map(|o| o.req).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn req(id: u64, plen: u32) -> ReqMeta {
        ReqMeta {
            id,
            task: TaskType::Chat,
            class: 0,
            arrival: 0,
            prompt_len: plen,
            predicted: None,
            prefix: None,
        }
    }

    fn chunker_with(reqs: &[(u64, u32)], size: u32) -> Chunker {
        let mut c = Chunker::new(size);
        for (id, p) in reqs {
            c.admit(req(*id, *p));
        }
        c
    }

    #[test]
    fn figure7_slicing_and_merging() {
        // R1=700, R2=300, R3=512, R4=100 with ChunkSize=512 (FCFS order):
        // C1 = R1[0..512); C2 = R1[512..700) + R2[0..300) + R3[0..24) ...
        let mut c = chunker_with(&[(1, 700), (2, 300), (3, 512), (4, 100)], 512);
        let c1 = c.next_chunk().unwrap();
        assert_eq!(c1.segments, vec![Segment { req: 1, start: 0, len: 512, last: false }]);
        assert_eq!(c1.pad(), 0);

        let c2 = c.next_chunk().unwrap();
        assert_eq!(
            c2.segments,
            vec![
                Segment { req: 1, start: 512, len: 188, last: true },
                Segment { req: 2, start: 0, len: 300, last: true },
                Segment { req: 3, start: 0, len: 24, last: false },
            ]
        );

        let c3 = c.next_chunk().unwrap();
        assert_eq!(c3.segments[0], Segment { req: 3, start: 24, len: 488, last: true });
        assert_eq!(c3.segments[1], Segment { req: 4, start: 0, len: 24, last: false });

        let c4 = c.next_chunk().unwrap();
        assert_eq!(c4.segments, vec![Segment { req: 4, start: 24, len: 76, last: true }]);
        assert_eq!(c4.tokens, 76);
        assert_eq!(c4.pad(), 436); // final partial chunk is padded

        assert!(c.next_chunk().is_none());
    }

    #[test]
    fn every_prompt_token_covered_exactly_once() {
        let mut c = chunker_with(&[(1, 137), (2, 1), (3, 512), (4, 999), (5, 64)], 128);
        let mut covered: std::collections::HashMap<u64, u32> = Default::default();
        while let Some(ch) = c.next_chunk() {
            assert!(ch.tokens <= 128);
            let sum: u32 = ch.segments.iter().map(|s| s.len).sum();
            assert_eq!(sum, ch.tokens);
            for s in &ch.segments {
                let e = covered.entry(s.req).or_default();
                assert_eq!(*e, s.start, "segments must be contiguous per request");
                *e += s.len;
            }
        }
        for (id, plen) in [(1, 137), (2, 1), (3, 512), (4, 999), (5, 64)] {
            assert_eq!(covered[&id], plen, "req {id}");
        }
    }

    #[test]
    fn last_flag_set_exactly_once_per_request() {
        let mut c = chunker_with(&[(1, 1000), (2, 3), (3, 600)], 256);
        let mut lasts: Vec<u64> = vec![];
        while let Some(ch) = c.next_chunk() {
            for s in ch.segments.iter().filter(|s| s.last) {
                lasts.push(s.req);
            }
        }
        lasts.sort();
        assert_eq!(lasts, vec![1, 2, 3]);
    }

    #[test]
    fn order_is_preserved_no_reordering() {
        let mut c = chunker_with(&[(9, 100), (4, 100), (7, 100)], 512);
        let ch = c.next_chunk().unwrap();
        let ids: Vec<u64> = ch.segments.iter().map(|s| s.req).collect();
        assert_eq!(ids, vec![9, 4, 7], "chunker must not reorder scheduled requests");
    }

    #[test]
    fn srtf_preempts_long_request_at_chunk_boundary() {
        // R1 = 1000 tokens in flight; a 50-token R2 arrives. SRTF runs R2
        // ahead of R1's remaining chunks; FIFO would finish R1 first.
        let mut c = Chunker::new_srtf(512);
        c.admit(req(1, 1000));
        let c1 = c.next_chunk().unwrap();
        assert_eq!(c1.segments[0].req, 1);
        c.admit(req(2, 50));
        let c2 = c.next_chunk().unwrap();
        assert_eq!(c2.segments[0].req, 2, "short request must preempt");
        assert!(c2.segments[0].last);
        assert_eq!(c2.segments[1].req, 1); // long request resumes in-chunk
    }

    #[test]
    fn srtf_still_covers_everything() {
        let mut c = Chunker::new_srtf(128);
        for (id, p) in [(1u64, 999u32), (2, 3), (3, 600), (4, 128)] {
            c.admit(req(id, p));
        }
        let mut covered: std::collections::HashMap<u64, u32> = Default::default();
        while let Some(ch) = c.next_chunk() {
            for s in &ch.segments {
                *covered.entry(s.req).or_default() += s.len;
            }
        }
        assert_eq!(covered[&1], 999);
        assert_eq!(covered[&2], 3);
        assert_eq!(covered[&3], 600);
        assert_eq!(covered[&4], 128);
    }

    #[test]
    fn pending_tokens_tracks_slicing_incrementally() {
        let mut c = chunker_with(&[(1, 700), (2, 300)], 512);
        assert_eq!(c.pending_tokens(), 1000);
        let c1 = c.next_chunk().unwrap();
        assert_eq!(c.pending_tokens(), 1000 - c1.tokens as u64);
        while c.next_chunk().is_some() {}
        assert_eq!(c.pending_tokens(), 0);
        assert!(!c.has_work());
    }

    #[test]
    fn drain_open_returns_requests_and_zeroes_pending() {
        let mut c = chunker_with(&[(1, 700), (2, 300)], 512);
        let _ = c.next_chunk().unwrap(); // req 1 partially prefilled
        let lost = c.drain_open();
        assert_eq!(lost.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(c.pending_tokens(), 0, "no load left on the dead incarnation");
        assert!(!c.has_work());
        assert!(c.next_chunk().is_none());
    }

    #[test]
    fn late_admission_joins_next_chunk() {
        let mut c = chunker_with(&[(1, 600)], 512);
        let _c1 = c.next_chunk().unwrap();
        c.admit(req(2, 10));
        let c2 = c.next_chunk().unwrap();
        assert_eq!(c2.segments.len(), 2);
        assert_eq!(c2.segments[1].req, 2);
        assert_eq!(c2.tokens, 88 + 10);
    }
}
