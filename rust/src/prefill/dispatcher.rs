//! Inter-decode-instance dispatch (§3.3.4): decentralized load balancing
//! run by each prefill instance once a request's prompt is prefilled.
//!
//! The paper's algorithm: (1) split decode instances into α (enough
//! resources for this request's *predicted* decode footprint) and β (not);
//! (2) power-of-two [25]: pick two random α members; (3) of the two, pick
//! the one that minimizes interference — the lowest resulting
//! heavy:light ratio, spreading heavy decodes evenly (Figure 5's lesson).
//!
//! `Random` and `Imbalance` are Figure 19's comparison policies.

use crate::types::{BucketPrediction, InstanceId, Us, HEAVY_DECODE_TOKENS};
use crate::util::Pcg;

/// A decode instance's load as last broadcast by the cluster monitor
/// (§3.2) — deliberately stale information.
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeLoad {
    pub instance: InstanceId,
    /// KV tokens still free in the paged pool.
    pub free_kv_tokens: u64,
    /// Running + waiting requests predicted heavy-decode.
    pub n_heavy: u32,
    /// Running + waiting requests predicted light-decode.
    pub n_light: u32,
    /// Requests waiting for a batch slot.
    pub queue_len: u32,
}

impl DecodeLoad {
    /// Interference score after hypothetically adding a request of the
    /// given class. The paper minimizes the average heavy:light ratio,
    /// i.e. spreads heavy decodes evenly; comparing (heavy, light) counts
    /// lexicographically achieves exactly that without the ratio's
    /// pathology of turning light-rich instances into heavy magnets.
    fn interference_after(&self, heavy: bool) -> (u32, u32) {
        (self.n_heavy + heavy as u32, self.n_light + !heavy as u32)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// The paper's decentralized power-of-two + least-interference.
    PowerOfTwo,
    /// Uniform random decode instance (Figure 19 baseline).
    Random,
    /// Worst case: heavy decodes always pile onto the same instance
    /// (Figure 19's "imbalance").
    Imbalance,
    /// Classic join-least-loaded (extra ablation, not in the paper).
    LeastLoad,
}

impl DispatchPolicy {
    pub fn name(self) -> &'static str {
        match self {
            DispatchPolicy::PowerOfTwo => "power-of-two",
            DispatchPolicy::Random => "random",
            DispatchPolicy::Imbalance => "imbalance",
            DispatchPolicy::LeastLoad => "least-load",
        }
    }
}

/// Predicted KV footprint (tokens) of a request's decode phase: prompt KV
/// plus the predicted generation, using the range's upper end for admission
/// safety (the paper uses the lower end for *memory provisioning* inside
/// the decode scheduler; the dispatcher just needs "enough resources").
pub fn predicted_footprint(prompt_len: u32, pred: Option<BucketPrediction>, granularity: u32) -> u64 {
    let gen = match pred {
        Some(p) if p.hi != u32::MAX => p.hi,
        Some(p) => p.lo + granularity, // top bucket: lo + one granule
        None => granularity,           // unpredicted: assume one granule
    };
    prompt_len as u64 + gen as u64
}

/// Choose a decode instance for a prefilled request.
pub fn choose(
    loads: &[DecodeLoad],
    prompt_len: u32,
    pred: Option<BucketPrediction>,
    granularity: u32,
    policy: DispatchPolicy,
    rng: &mut Pcg,
) -> Option<InstanceId> {
    choose_ranked(loads, prompt_len, pred, granularity, policy, rng, None)
}

/// [`choose`] with an optional SLO ranking stage: when the request's
/// workload class carries a TPOT deadline, the driver supplies
/// `tpot_est` — a predictor of the next decode-iteration latency on a
/// candidate instance (cost model over the broadcast load plus this
/// request). The power-of-two winner is then the candidate with the
/// *larger TPOT headroom* (smaller predicted iteration time — both
/// candidates share the request's deadline, so minimizing predicted TPOT
/// maximizes headroom), falling back to the interference tuple on ties:
/// hotspot avoidance becomes violation avoidance. `None` (classless
/// runs, or classes without a TPOT target) is bit-identical to the
/// paper's least-interference pick — same RNG draws, same winners.
pub fn choose_ranked(
    loads: &[DecodeLoad],
    prompt_len: u32,
    pred: Option<BucketPrediction>,
    granularity: u32,
    policy: DispatchPolicy,
    rng: &mut Pcg,
    tpot_est: Option<&dyn Fn(&DecodeLoad) -> Us>,
) -> Option<InstanceId> {
    if loads.is_empty() {
        return None;
    }
    let heavy = pred.map(|p| p.predicts_heavy(HEAVY_DECODE_TOKENS)).unwrap_or(false);
    match policy {
        DispatchPolicy::Random => Some(loads[rng.index(loads.len())].instance),
        DispatchPolicy::Imbalance => {
            // Adversarial: all heavy decodes to the first instance, the
            // rest spread randomly over the others.
            if heavy || loads.len() == 1 {
                Some(loads[0].instance)
            } else {
                Some(loads[1 + rng.index(loads.len() - 1)].instance)
            }
        }
        DispatchPolicy::LeastLoad => loads
            .iter()
            .max_by_key(|l| l.free_kv_tokens)
            .map(|l| l.instance),
        DispatchPolicy::PowerOfTwo => {
            let need = predicted_footprint(prompt_len, pred, granularity);
            let alpha: Vec<&DecodeLoad> =
                loads.iter().filter(|l| l.free_kv_tokens >= need).collect();
            let pick_two = |set: &[&DecodeLoad], rng: &mut Pcg| -> (usize, usize) {
                let a = rng.index(set.len());
                if set.len() == 1 {
                    return (a, a);
                }
                let mut b = rng.index(set.len() - 1);
                if b >= a {
                    b += 1;
                }
                (a, b)
            };
            if alpha.is_empty() {
                // β fallback: the least-loaded instance, which will queue
                // the request until pages free up.
                return loads.iter().max_by_key(|l| l.free_kv_tokens).map(|l| l.instance);
            }
            let (a, b) = pick_two(&alpha, rng);
            let (la, lb) = (alpha[a], alpha[b]);
            let (ia, ib) = (la.interference_after(heavy), lb.interference_after(heavy));
            // SLO classes with a TPOT deadline rank by predicted headroom
            // first: the candidate whose next iteration is predicted
            // faster keeps the class inside its per-token budget.
            let (ta, tb) = match tpot_est {
                Some(est) => (est(la), est(lb)),
                None => (0, 0),
            };
            // least predicted TPOT, then least interference; tie-break on
            // free memory then queue
            let winner = if (ta, ia, std::cmp::Reverse(la.free_kv_tokens), la.queue_len)
                <= (tb, ib, std::cmp::Reverse(lb.free_kv_tokens), lb.queue_len)
            {
                la
            } else {
                lb
            };
            Some(winner.instance)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::BucketPrediction;

    fn load(instance: usize, free: u64, h: u32, l: u32) -> DecodeLoad {
        DecodeLoad { instance, free_kv_tokens: free, n_heavy: h, n_light: l, queue_len: 0 }
    }

    fn heavy_pred() -> Option<BucketPrediction> {
        Some(BucketPrediction::from_bucket(3, 200, 8)) // [600, 800)
    }

    fn light_pred() -> Option<BucketPrediction> {
        Some(BucketPrediction::from_bucket(0, 200, 8)) // [0, 200)
    }

    #[test]
    fn footprint_uses_range_upper_bound() {
        assert_eq!(predicted_footprint(100, heavy_pred(), 200), 100 + 800);
        let top = Some(BucketPrediction::from_bucket(7, 200, 8));
        assert_eq!(predicted_footprint(0, top, 200), 1400 + 200);
        assert_eq!(predicted_footprint(50, None, 200), 250);
    }

    #[test]
    fn power_of_two_filters_alpha_by_capacity() {
        let mut rng = Pcg::new(1);
        // only instance 2 can fit the 900-token footprint
        let loads = vec![load(0, 100, 0, 0), load(1, 200, 0, 0), load(2, 5000, 0, 0)];
        for _ in 0..32 {
            let got = choose(&loads, 100, heavy_pred(), 200, DispatchPolicy::PowerOfTwo, &mut rng);
            assert_eq!(got, Some(2));
        }
    }

    #[test]
    fn power_of_two_spreads_heavy_evenly() {
        let mut rng = Pcg::new(2);
        let mut loads = vec![load(0, 1 << 20, 0, 4), load(1, 1 << 20, 0, 4), load(2, 1 << 20, 0, 4)];
        let mut counts = [0u32; 3];
        for _ in 0..300 {
            let i = choose(&loads, 10, heavy_pred(), 200, DispatchPolicy::PowerOfTwo, &mut rng).unwrap();
            counts[i] += 1;
            loads[i].n_heavy += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min < 1.5, "heavy spread uneven: {counts:?}");
    }

    #[test]
    fn tpot_ranking_overrides_interference_and_none_is_identity() {
        // Instance 0 looks better on interference (fewer heavies) but is
        // predicted slower; the SLO ranking must pick instance 1, while
        // the unranked call keeps the paper's least-interference pick.
        let loads = vec![load(0, 1 << 20, 0, 40), load(1, 1 << 20, 2, 0)];
        let est = |l: &DecodeLoad| -> Us {
            // proxy: total resident jobs drive the next iteration time
            ((l.n_heavy + l.n_light) as u64 + 1) * 1_000
        };
        for seed in 0..16 {
            let mut rng = Pcg::new(seed);
            let ranked = choose_ranked(
                &loads, 10, light_pred(), 200, DispatchPolicy::PowerOfTwo, &mut rng, Some(&est),
            );
            assert_eq!(ranked, Some(1), "seed {seed}: headroom must win");
        }
        // None-ranked choose_ranked == choose, draw for draw
        for seed in 0..16 {
            let mut a = Pcg::new(seed);
            let mut b = Pcg::new(seed);
            let plain = choose(&loads, 10, heavy_pred(), 200, DispatchPolicy::PowerOfTwo, &mut a);
            let unranked = choose_ranked(
                &loads, 10, heavy_pred(), 200, DispatchPolicy::PowerOfTwo, &mut b, None,
            );
            assert_eq!(plain, unranked, "seed {seed}");
            assert_eq!(a.next_u64(), b.next_u64(), "seed {seed}: RNG streams must stay aligned");
        }
    }

    #[test]
    fn beta_fallback_picks_most_free() {
        let mut rng = Pcg::new(3);
        let loads = vec![load(0, 10, 0, 0), load(1, 50, 0, 0)];
        let got = choose(&loads, 1000, heavy_pred(), 200, DispatchPolicy::PowerOfTwo, &mut rng);
        assert_eq!(got, Some(1));
    }

    #[test]
    fn imbalance_targets_first_instance_for_heavy() {
        let mut rng = Pcg::new(4);
        let loads = vec![load(0, 100, 0, 0), load(1, 100, 0, 0), load(2, 100, 0, 0)];
        for _ in 0..16 {
            assert_eq!(
                choose(&loads, 10, heavy_pred(), 200, DispatchPolicy::Imbalance, &mut rng),
                Some(0)
            );
            let l = choose(&loads, 10, light_pred(), 200, DispatchPolicy::Imbalance, &mut rng);
            assert_ne!(l, Some(0));
        }
    }

    #[test]
    fn random_covers_all_instances() {
        let mut rng = Pcg::new(5);
        let loads = vec![load(0, 100, 0, 0), load(1, 100, 0, 0), load(2, 100, 0, 0)];
        let mut seen = [false; 3];
        for _ in 0..64 {
            let i = choose(&loads, 10, light_pred(), 200, DispatchPolicy::Random, &mut rng).unwrap();
            seen[i] = true;
        }
        assert_eq!(seen, [true, true, true]);
    }

    #[test]
    fn empty_cluster_yields_none() {
        let mut rng = Pcg::new(6);
        assert_eq!(choose(&[], 1, None, 200, DispatchPolicy::PowerOfTwo, &mut rng), None);
    }
}
