//! Prefill instance (§3.3): local scheduler → length predictor → chunked
//! prefill → dispatcher. The sim/real drivers wire these pieces to an
//! engine; all policy logic lives here.

pub mod chunker;
pub mod dispatcher;
pub mod scheduler;

pub use chunker::{Chunk, Chunker, Segment};
pub use dispatcher::{choose, choose_ranked, predicted_footprint, DecodeLoad, DispatchPolicy};
pub use scheduler::{PrefillPolicy, PrefillScheduler};
