//! artifacts/manifest.json loader: shapes, argument order, and model
//! configuration shared between aot.py and the rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

#[derive(Clone, Debug)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl LeafSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    /// Which param bundle feeds this artifact ("params"/"predictor_params").
    pub params: String,
    pub args: Vec<ArgSpec>,
}

#[derive(Clone, Debug)]
pub struct ModelShapes {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub chunk: usize,
}

#[derive(Clone, Debug)]
pub struct DecodeShapes {
    pub batch: usize,
    pub page_size: usize,
    pub n_pages: usize,
    pub max_pages_per_req: usize,
}

#[derive(Clone, Debug)]
pub struct PredictorShapes {
    pub max_prompt: usize,
    pub n_buckets: usize,
    pub granularity: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelShapes,
    pub decode: DecodeShapes,
    pub predictor: PredictorShapes,
    pub params_file: PathBuf,
    pub params_leaves: Vec<LeafSpec>,
    pub predictor_params_file: PathBuf,
    pub predictor_params_leaves: Vec<LeafSpec>,
    pub prefill: ArtifactSpec,
    pub decode_art: ArtifactSpec,
    pub predictor_art: ArtifactSpec,
    /// Reported fine-tune accuracy at granularity 200 (None if untrained).
    pub predictor_acc200: Option<f64>,
}

fn usize_at(j: &Json, path: &[&str]) -> Result<usize> {
    j.at(path)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest missing {}", path.join(".")))
}

fn leaves(j: &Json, key: &str) -> Result<(PathBuf, Vec<LeafSpec>)> {
    let node = j.get(key).ok_or_else(|| anyhow!("manifest missing {key}"))?;
    let file = node
        .get("file")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("{key}.file missing"))?;
    let leaves = node
        .get("leaves")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{key}.leaves missing"))?
        .iter()
        .map(|l| {
            Ok(LeafSpec {
                name: l.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                shape: l
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("leaf shape missing"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((PathBuf::from(file), leaves))
}

fn artifact(j: &Json, key: &str) -> Result<ArtifactSpec> {
    let node = j
        .at(&["artifacts", key])
        .ok_or_else(|| anyhow!("manifest missing artifacts.{key}"))?;
    Ok(ArtifactSpec {
        file: PathBuf::from(
            node.get("file").and_then(Json::as_str).ok_or_else(|| anyhow!("file missing"))?,
        ),
        params: node.get("params").and_then(Json::as_str).unwrap_or("params").to_string(),
        args: node
            .get("args")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("args missing"))?
            .iter()
            .map(|a| ArgSpec {
                name: a.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                shape: a
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|s| s.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                dtype: a.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
            })
            .collect(),
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let model = ModelShapes {
            vocab: usize_at(&j, &["config", "model", "vocab"])?,
            d_model: usize_at(&j, &["config", "model", "d_model"])?,
            n_layers: usize_at(&j, &["config", "model", "n_layers"])?,
            n_heads: usize_at(&j, &["config", "model", "n_heads"])?,
            d_head: usize_at(&j, &["config", "model", "d_head"])?,
            max_seq: usize_at(&j, &["config", "model", "max_seq"])?,
            chunk: usize_at(&j, &["config", "model", "chunk"])?,
        };
        let decode = DecodeShapes {
            batch: usize_at(&j, &["config", "decode", "batch"])?,
            page_size: usize_at(&j, &["config", "decode", "page_size"])?,
            n_pages: usize_at(&j, &["config", "decode", "n_pages"])?,
            max_pages_per_req: usize_at(&j, &["config", "decode", "max_pages_per_req"])?,
        };
        let predictor = PredictorShapes {
            max_prompt: usize_at(&j, &["config", "predictor", "max_prompt"])?,
            n_buckets: usize_at(&j, &["config", "predictor", "n_buckets"])?,
            granularity: usize_at(&j, &["config", "predictor", "granularity"])?,
        };
        let (params_file, params_leaves) = leaves(&j, "params")?;
        let (pp_file, pp_leaves) = leaves(&j, "predictor_params")?;
        Ok(Manifest {
            model,
            decode,
            predictor,
            params_file,
            params_leaves,
            predictor_params_file: pp_file,
            predictor_params_leaves: pp_leaves,
            prefill: artifact(&j, "prefill")?,
            decode_art: artifact(&j, "decode")?,
            predictor_art: artifact(&j, "predictor")?,
            predictor_acc200: j.at(&["predictor_metrics", "acc_200"]).and_then(Json::as_f64),
            dir,
        })
    }

    /// Total floats expected in a params bundle (size check for the .bin).
    pub fn param_numel(leaves: &[LeafSpec]) -> usize {
        leaves.iter().map(LeafSpec::numel).sum()
    }
}
