//! PJRT runtime: load the AOT'd HLO-text artifacts, keep the weights
//! device-resident, and expose typed prefill/decode/predict calls.
//!
//! Interchange is HLO *text* (see aot.py / /opt/xla-example/README.md):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute_b`. Weights upload once at load time;
//! each call uploads only the (small) data arguments plus the KV state,
//! and the returned tuple is synced back to host.

pub mod manifest;

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::Manifest;

use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Host-side tensor state for one engine call (f32 payloads).
pub struct HostTensors;

pub struct Engine {
    pub client: PjRtClient,
    pub manifest: Manifest,
    prefill_exe: PjRtLoadedExecutable,
    decode_exe: PjRtLoadedExecutable,
    predictor_exe: PjRtLoadedExecutable,
    /// Target-model weights, uploaded once.
    params: Vec<PjRtBuffer>,
    pred_params: Vec<PjRtBuffer>,
}

/// Read a flat f32 (little-endian) params file and split it per leaf spec.
fn read_params_bin(path: &Path, leaves: &[manifest::LeafSpec]) -> Result<Vec<(Vec<f32>, Vec<usize>)>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let want = manifest::Manifest::param_numel(leaves) * 4;
    if bytes.len() != want {
        bail!("{} is {} bytes, manifest expects {}", path.display(), bytes.len(), want);
    }
    let mut out = Vec::with_capacity(leaves.len());
    let mut off = 0usize;
    for leaf in leaves {
        let n = leaf.numel();
        let mut v = Vec::with_capacity(n);
        for i in 0..n {
            let b = &bytes[off + 4 * i..off + 4 * i + 4];
            v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        off += 4 * n;
        out.push((v, leaf.shape.clone()));
    }
    Ok(out)
}

fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
}

impl Engine {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

        let prefill_exe = compile(&client, &manifest.dir.join(&manifest.prefill.file))?;
        let decode_exe = compile(&client, &manifest.dir.join(&manifest.decode_art.file))?;
        let predictor_exe = compile(&client, &manifest.dir.join(&manifest.predictor_art.file))?;

        let upload = |file: &Path, leaves: &[manifest::LeafSpec]| -> Result<Vec<PjRtBuffer>> {
            read_params_bin(file, leaves)?
                .into_iter()
                .map(|(data, shape)| {
                    let dims = if shape.is_empty() { vec![] } else { shape };
                    client
                        .buffer_from_host_buffer::<f32>(&data, &dims, None)
                        .map_err(|e| anyhow!("uploading params: {e:?}"))
                })
                .collect()
        };
        let params = upload(&manifest.dir.join(&manifest.params_file), &manifest.params_leaves)?;
        let pred_params = upload(
            &manifest.dir.join(&manifest.predictor_params_file),
            &manifest.predictor_params_leaves,
        )?;
        Ok(Engine { client, manifest, prefill_exe, decode_exe, predictor_exe, params, pred_params })
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    fn run(&self, exe: &PjRtLoadedExecutable, params: &[PjRtBuffer], data: Vec<PjRtBuffer>) -> Result<Vec<Literal>> {
        let mut args: Vec<&PjRtBuffer> = params.iter().collect();
        let extra: Vec<PjRtBuffer> = data;
        for b in &extra {
            args.push(b);
        }
        let out = exe.execute_b(&args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("sync: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// KV cache element count for one request's contiguous prefill cache.
    pub fn prefill_kv_numel(&self) -> usize {
        let m = &self.manifest.model;
        m.n_layers * m.max_seq * m.n_heads * m.d_head
    }

    /// KV pool element count for the shared decode pool.
    pub fn decode_pool_numel(&self) -> usize {
        let m = &self.manifest.model;
        let d = &self.manifest.decode;
        m.n_layers * d.n_pages * d.page_size * m.n_heads * m.d_head
    }

    /// Run one chunk of one request's prompt. `k_cache`/`v_cache` are the
    /// request's contiguous caches (mutated in place). Returns the
    /// next-token logits after the last valid token.
    pub fn prefill_segment(
        &self,
        tokens: &[i32],
        start: i32,
        valid: i32,
        k_cache: &mut Vec<f32>,
        v_cache: &mut Vec<f32>,
    ) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        if tokens.len() != m.chunk {
            bail!("prefill chunk must be exactly {} tokens (padded)", m.chunk);
        }
        let kv_dims = [m.n_layers, m.max_seq, m.n_heads, m.d_head];
        let data = vec![
            self.buf_i32(tokens, &[m.chunk])?,
            self.buf_i32(&[start], &[])?,
            self.buf_i32(&[valid], &[])?,
            self.buf_f32(k_cache, &kv_dims)?,
            self.buf_f32(v_cache, &kv_dims)?,
        ];
        let mut outs = self.run(&self.prefill_exe, &self.params, data)?;
        if outs.len() != 3 {
            bail!("prefill artifact returned {} outputs, want 3", outs.len());
        }
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        *k_cache = k_new.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        *v_cache = v_new.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Run one decode iteration over the shared paged pool. Returns
    /// per-slot logits ([batch, vocab] flattened).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_step(
        &self,
        tokens: &[i32],
        positions: &[i32],
        k_pool: &mut Vec<f32>,
        v_pool: &mut Vec<f32>,
        block_tables: &[i32],
        seq_lens: &[i32],
    ) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let d = &self.manifest.decode;
        if tokens.len() != d.batch || positions.len() != d.batch || seq_lens.len() != d.batch {
            bail!("decode batch must be exactly {}", d.batch);
        }
        if block_tables.len() != d.batch * d.max_pages_per_req {
            bail!("block_tables must be {}x{}", d.batch, d.max_pages_per_req);
        }
        let pool_dims = [m.n_layers, d.n_pages * d.page_size, m.n_heads, m.d_head];
        let data = vec![
            self.buf_i32(tokens, &[d.batch])?,
            self.buf_i32(positions, &[d.batch])?,
            self.buf_f32(k_pool, &pool_dims)?,
            self.buf_f32(v_pool, &pool_dims)?,
            self.buf_i32(block_tables, &[d.batch, d.max_pages_per_req])?,
            self.buf_i32(seq_lens, &[d.batch])?,
        ];
        let mut outs = self.run(&self.decode_exe, &self.params, data)?;
        if outs.len() != 3 {
            bail!("decode artifact returned {} outputs, want 3", outs.len());
        }
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        *k_pool = k_new.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        *v_pool = v_new.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Classify a prompt into a decode-length bucket. Returns bucket logits.
    pub fn predict_len(&self, tokens: &[i32], valid: i32) -> Result<Vec<f32>> {
        let p = &self.manifest.predictor;
        if tokens.len() != p.max_prompt {
            bail!("predictor prompt must be padded to {}", p.max_prompt);
        }
        let data = vec![self.buf_i32(tokens, &[p.max_prompt])?, self.buf_i32(&[valid], &[])?];
        let mut outs = self.run(&self.predictor_exe, &self.pred_params, data)?;
        let logits = outs
            .pop()
            .ok_or_else(|| anyhow!("predictor artifact returned no outputs"))?;
        logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Argmax helper for sampling (greedy decoding in the examples).
    pub fn argmax(xs: &[f32]) -> usize {
        xs.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}
