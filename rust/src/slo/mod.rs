//! SLO-aware multi-tenancy: workload classes, deterministic admission
//! control, and the deadline vocabulary the two-level schedulers and the
//! goodput accounting consume.
//!
//! DistServe frames serving quality as *goodput* — requests completed
//! within their SLO per unit resource — and Arrow adapts scheduling on
//! disaggregated fleets to per-class targets. This module is the single
//! source of truth for that vocabulary here:
//!
//!   * [`ClassSpec`] — one workload class as declared in an
//!     `api::Scenario` (JSON / builder / `--class` CLI flag): name,
//!     arrival-share weight, priority tier, TTFT/TPOT deadlines in ms,
//!     and optional admission limits (token-bucket rate, queue depth).
//!   * [`ClassDef`] / [`SloConfig`] — the resolved runtime form (µs
//!     deadlines) carried by `ClusterConfig`/`BaselineConfig` and echoed
//!     into `RunMetrics` so per-class attainment can be computed at
//!     finish time with O(classes) memory.
//!   * [`TokenBucket`] / [`AdmissionGate`] — the deterministic entry
//!     gate. Integer micro-token arithmetic with a sub-µtoken carry: the
//!     bucket level is a `u64` that is only ever decremented when a full
//!     token is present, so it is *structurally* non-negative
//!     (property-tested in tests/proptest_slo.rs), and refills are a
//!     pure function of the virtual clock — no wall time, no RNG, every
//!     replay takes identical decisions (see [`AdmissionGate`] for what
//!     is and isn't comparable *across* drivers).
//!
//! Classless runs (`Scenario` with no `classes`) resolve to the default
//! [`SloConfig`]: an implicit single class 0 with no deadlines and
//! admission off — the gate is never constructed, no extra RNG stream is
//! consumed, and the event trajectory is bit-identical to pre-SLO builds
//! (golden-tested).

use crate::types::Us;

/// Hard cap on declared classes: class ids travel as `u8` on every
/// request, so a spec may declare at most this many.
pub const MAX_CLASSES: usize = 256;

/// One workload class as declared in a scenario spec (ms units — the
/// spec-level mirror of the runtime [`ClassDef`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ClassSpec {
    /// Display name echoed into reports ("chat", "batch", ...).
    pub name: String,
    /// Unnormalized arrival share (the workload generator samples the
    /// class of each request from these weights, on an RNG stream
    /// *separate* from the length draws — a classed trace keeps the same
    /// arrivals and lengths as its classless twin).
    pub weight: f64,
    /// Priority tier: 0 is the most latency-critical. The SLO prefill
    /// policy never schedules a higher tier number ahead of a lower one
    /// within a committed batch.
    pub tier: u8,
    /// TTFT deadline in ms; `None` = no TTFT target.
    pub ttft_ms: Option<f64>,
    /// TPOT (time per output token) deadline in ms; `None` = no target.
    pub tpot_ms: Option<f64>,
    /// Token-bucket admission rate in requests/s; `None` = unlimited.
    /// Over-rate arrivals are *shed* (counted per class, never silently
    /// dropped).
    pub rate_limit: Option<f64>,
    /// Token-bucket burst capacity in requests; defaults to
    /// `max(1, rate_limit)` (one second of burst).
    pub burst: Option<f64>,
    /// Queue-depth gate: shed an arrival of this class while the
    /// cluster-wide in-flight count (excluding the arrival itself) is at
    /// or above this. `None` = no depth limit.
    pub max_queue: Option<u64>,
}

impl Default for ClassSpec {
    fn default() -> Self {
        ClassSpec {
            name: "default".to_string(),
            weight: 1.0,
            tier: 0,
            ttft_ms: None,
            tpot_ms: None,
            rate_limit: None,
            burst: None,
            max_queue: None,
        }
    }
}

impl ClassSpec {
    /// Resolve to the runtime form (ms → µs, burst default applied).
    pub fn to_def(&self) -> ClassDef {
        ClassDef {
            name: self.name.clone(),
            weight: self.weight,
            tier: self.tier,
            ttft_deadline_us: self.ttft_ms.map(|ms| (ms * 1e3) as Us),
            tpot_deadline_us: self.tpot_ms.map(|ms| (ms * 1e3) as Us),
            rate_limit: self.rate_limit,
            burst: self.burst.unwrap_or_else(|| self.rate_limit.unwrap_or(1.0).max(1.0)),
            max_queue: self.max_queue,
        }
    }
}

/// Runtime form of a workload class (µs deadlines). Carried by driver
/// configs and echoed into `RunMetrics::classes` for finish-time
/// attainment accounting.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassDef {
    pub name: String,
    pub weight: f64,
    pub tier: u8,
    pub ttft_deadline_us: Option<Us>,
    pub tpot_deadline_us: Option<Us>,
    pub rate_limit: Option<f64>,
    pub burst: f64,
    pub max_queue: Option<u64>,
}

/// The resolved SLO configuration a driver runs under. The default —
/// empty class table, admission off — is the classless legacy behavior:
/// every request is implicit class 0 with no deadlines.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SloConfig {
    /// Class table indexed by class id; empty = implicit single class.
    pub classes: Vec<ClassDef>,
    /// Whether the entry admission gate is active.
    pub admission: bool,
}

impl SloConfig {
    /// `(tier, ttft deadline)` per class for the SLO prefill policy
    /// (deadline `Us::MAX` when the class has no TTFT target, so
    /// undeadlined classes order by arrival within their tier).
    pub fn prefill_table(&self) -> Vec<(u8, Us)> {
        self.classes
            .iter()
            .map(|c| (c.tier, c.ttft_deadline_us.unwrap_or(Us::MAX)))
            .collect()
    }

    /// TPOT deadline of `class`, if it has one (activates the
    /// headroom-ranked decode dispatch).
    pub fn tpot_deadline_us(&self, class: u8) -> Option<Us> {
        self.classes.get(class as usize).and_then(|c| c.tpot_deadline_us)
    }

    /// Whether any class declares any deadline or admission limit — i.e.
    /// whether SLO machinery can affect this run at all.
    pub fn is_active(&self) -> bool {
        self.admission
            || self.classes.iter().any(|c| {
                c.ttft_deadline_us.is_some()
                    || c.tpot_deadline_us.is_some()
                    || c.rate_limit.is_some()
                    || c.max_queue.is_some()
            })
    }
}

// ------------------------------------------------------------ admission

/// One micro-token = 1e-6 request tokens; the bucket does all arithmetic
/// in integer micro-tokens so the level is exact and structurally
/// non-negative at any virtual-time scale.
const MICRO: u64 = 1_000_000;

/// Deterministic token bucket over virtual time. Starts full (a burst at
/// t=0 is admitted up to `burst`), refills `rate` tokens per virtual
/// second, caps at `burst`.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate_per_sec: f64,
    cap_micro: u64,
    level_micro: u64,
    /// Sub-µtoken refill remainder carried between refills (in [0, 1)
    /// µtokens). Without it, closely spaced arrivals would truncate each
    /// tiny refill to zero while still advancing `last_refill`, starving
    /// low-rate buckets entirely under µs-spaced probe storms.
    frac_micro: f64,
    last_refill: Us,
}

impl TokenBucket {
    pub fn new(rate_per_sec: f64, burst_tokens: f64) -> Self {
        let cap_micro = ((burst_tokens.max(0.0) * MICRO as f64) as u64).max(MICRO);
        TokenBucket {
            rate_per_sec: rate_per_sec.max(0.0),
            cap_micro,
            level_micro: cap_micro,
            frac_micro: 0.0,
            last_refill: 0,
        }
    }

    /// Current level in whole tokens (diagnostics/tests).
    pub fn level_tokens(&self) -> f64 {
        self.level_micro as f64 / MICRO as f64
    }

    fn refill(&mut self, now: Us) {
        let dt = now.saturating_sub(self.last_refill);
        self.last_refill = self.last_refill.max(now);
        if dt == 0 {
            return;
        }
        // rate tokens/s == rate µtokens/µs. The whole µtokens land in the
        // level; the sub-µtoken remainder carries to the next refill, so
        // the long-run refill rate is exact however finely the virtual
        // clock slices it (a pure function of elapsed virtual time —
        // deterministic across drivers and replays).
        let exact = self.rate_per_sec * dt as f64 + self.frac_micro;
        let add = exact as u64; // saturating float→int cast
        self.level_micro = self.level_micro.saturating_add(add).min(self.cap_micro);
        // a full bucket discards overflow, fraction included
        self.frac_micro = if self.level_micro >= self.cap_micro { 0.0 } else { exact.fract() };
    }

    /// Take one token if available. Never drives the level negative: the
    /// subtraction only happens when a full token is present.
    pub fn try_take(&mut self, now: Us) -> bool {
        self.refill(now);
        if self.level_micro >= MICRO {
            self.level_micro -= MICRO;
            true
        } else {
            false
        }
    }
}

/// Per-class gate state (limits resolved from the class table).
#[derive(Clone, Debug)]
struct GateClass {
    bucket: Option<TokenBucket>,
    max_queue: Option<u64>,
}

/// The deterministic entry admission gate every driver consults at the
/// *first* delivery of each arrival (mid-flip re-deliveries skip it —
/// one decision per request). Inputs are the virtual clock and the
/// cluster-wide in-flight count; every run of the same driver + config +
/// trace replays the identical decisions.
///
/// Policy: a class sheds when in-flight ≥ its `max_queue` (if declared)
/// or when its token bucket is empty (if it declares a `rate_limit`).
/// Classes without limits — the usual configuration for tier 0 — are
/// always admitted. Shed requests are counted per class and surfaced via
/// `Observer::on_shed`; they are never silently dropped.
///
/// Cross-driver comparison note: the *rate-limit* component is a pure
/// function of arrival times, so on a shared trace it sheds identically
/// under tetri/vllm/hybrid (until decisions start compounding). The
/// *queue-depth* component deliberately reads the serving system's own
/// congestion — a slower system sheds more — so `max_queue` sheds (and,
/// downstream of them, bucket levels) legitimately differ across
/// drivers; goodput/$ comparisons measure exactly that difference.
#[derive(Clone, Debug)]
pub struct AdmissionGate {
    per_class: Vec<GateClass>,
}

impl AdmissionGate {
    /// Build the gate, or `None` when admission is off (the gate is then
    /// never consulted — zero cost on the classless hot path).
    pub fn from_config(slo: &SloConfig) -> Option<AdmissionGate> {
        if !slo.admission {
            return None;
        }
        Some(AdmissionGate {
            per_class: slo
                .classes
                .iter()
                .map(|c| GateClass {
                    bucket: c.rate_limit.map(|r| TokenBucket::new(r, c.burst)),
                    max_queue: c.max_queue,
                })
                .collect(),
        })
    }

    /// One admission decision: `true` = admit, `false` = shed. `in_flight`
    /// is the number of admitted-but-unfinished requests *excluding* the
    /// arrival under decision.
    pub fn admits(&mut self, class: u8, now: Us, in_flight: u64) -> bool {
        let Some(gc) = self.per_class.get_mut(class as usize) else {
            return true; // class beyond the table (or classless): admit
        };
        if let Some(mq) = gc.max_queue {
            if in_flight >= mq {
                return false;
            }
        }
        if let Some(bucket) = gc.bucket.as_mut() {
            if !bucket.try_take(now) {
                return false;
            }
        }
        true
    }
}

// ------------------------------------------------------------- CLI flag

/// Parse one `--class` CLI flag value into a [`ClassSpec`]. Format is
/// comma-separated `key=value` pairs using the same key spellings as the
/// JSON spec:
///
/// ```text
/// name=chat,weight=0.5,tier=0,ttft_ms=300,tpot_ms=100,rate_limit=4,burst=8,max_queue=64
/// ```
///
/// `name` is required; everything else takes the [`ClassSpec`] defaults.
/// Unknown keys and malformed numbers are errors, never silent defaults.
pub fn parse_class_flag(s: &str) -> Result<ClassSpec, String> {
    let mut spec = ClassSpec { name: String::new(), ..Default::default() };
    for pair in s.split(',') {
        let pair = pair.trim();
        if pair.is_empty() {
            continue;
        }
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| format!("--class: expected key=value, got '{pair}'"))?;
        let num = |key: &str| -> Result<f64, String> {
            v.parse::<f64>().map_err(|_| format!("--class: {key} needs a number, got '{v}'"))
        };
        match k {
            "name" => spec.name = v.to_string(),
            "weight" => spec.weight = num("weight")?,
            "tier" => {
                spec.tier = v
                    .parse::<u8>()
                    .map_err(|_| format!("--class: tier needs an integer in [0,255], got '{v}'"))?
            }
            "ttft_ms" => spec.ttft_ms = Some(num("ttft_ms")?),
            "tpot_ms" => spec.tpot_ms = Some(num("tpot_ms")?),
            "rate_limit" => spec.rate_limit = Some(num("rate_limit")?),
            "burst" => spec.burst = Some(num("burst")?),
            "max_queue" => spec.max_queue = Some(num("max_queue")? as u64),
            _ => {
                return Err(format!(
                    "--class: unknown key '{k}' (known: name, weight, tier, ttft_ms, tpot_ms, \
                     rate_limit, burst, max_queue)"
                ))
            }
        }
    }
    if spec.name.is_empty() {
        return Err("--class: 'name=' is required".to_string());
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_inactive_and_gateless() {
        let slo = SloConfig::default();
        assert!(!slo.is_active());
        assert!(AdmissionGate::from_config(&slo).is_none());
        assert!(slo.prefill_table().is_empty());
        assert_eq!(slo.tpot_deadline_us(0), None);
    }

    #[test]
    fn class_spec_resolves_ms_to_us_and_defaults_burst() {
        let spec = ClassSpec {
            name: "chat".into(),
            ttft_ms: Some(300.0),
            tpot_ms: Some(100.0),
            rate_limit: Some(4.0),
            ..Default::default()
        };
        let def = spec.to_def();
        assert_eq!(def.ttft_deadline_us, Some(300_000));
        assert_eq!(def.tpot_deadline_us, Some(100_000));
        assert_eq!(def.burst, 4.0, "burst defaults to the rate (one second)");
        let unlimited = ClassSpec::default().to_def();
        assert_eq!(unlimited.burst, 1.0, "unlimited classes default to burst 1");
    }

    #[test]
    fn token_bucket_admits_burst_then_refills_at_rate() {
        // 2 req/s, burst 3: three admits at t=0, the fourth sheds, half a
        // second later one token is back.
        let mut b = TokenBucket::new(2.0, 3.0);
        assert!(b.try_take(0) && b.try_take(0) && b.try_take(0));
        assert!(!b.try_take(0), "burst exhausted");
        assert!(!b.try_take(400_000), "0.4 s × 2/s = 0.8 tokens: not yet");
        assert!(b.try_take(500_000), "1.0 token refilled by 0.5 s");
        assert!(!b.try_take(500_000));
    }

    #[test]
    fn token_bucket_caps_at_burst_and_never_goes_negative() {
        let mut b = TokenBucket::new(10.0, 2.0);
        // a huge idle period must not bank more than the burst
        assert!(b.try_take(3_600_000_000));
        assert!(b.try_take(3_600_000_000));
        assert!(!b.try_take(3_600_000_000));
        // zero-rate bucket: burst only, then dry forever
        let mut z = TokenBucket::new(0.0, 1.0);
        assert!(z.try_take(0));
        assert!(!z.try_take(u64::MAX / 2));
        assert!(z.level_tokens() >= 0.0);
    }

    #[test]
    fn token_bucket_sub_microtoken_refills_accumulate() {
        // 0.5 req/s probed every virtual µs: each refill is 0.5 µtokens —
        // without the fractional carry every one would truncate to zero
        // (while still advancing the clock) and the bucket would starve
        // forever. With the carry, exactly one token accrues over 2 s.
        let mut b = TokenBucket::new(0.5, 1.0);
        assert!(b.try_take(0), "initial burst");
        let mut admitted = 0u64;
        for now in 1..=2_000_000u64 {
            if b.try_take(now) {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 1, "0.5 req/s over 2 s refills exactly one token");
    }

    #[test]
    fn gate_sheds_on_rate_and_queue_depth_independently() {
        let slo = SloConfig {
            classes: vec![
                ClassSpec { name: "a".into(), ..Default::default() }.to_def(),
                ClassSpec {
                    name: "b".into(),
                    tier: 2,
                    rate_limit: Some(1.0),
                    burst: Some(1.0),
                    max_queue: Some(4),
                    ..Default::default()
                }
                .to_def(),
            ],
            admission: true,
        };
        let mut gate = AdmissionGate::from_config(&slo).expect("admission on");
        // class 0: no limits, always admitted
        for i in 0..32 {
            assert!(gate.admits(0, i, 1_000_000));
        }
        // class 1: queue-depth gate fires first
        assert!(!gate.admits(1, 0, 4), "at the depth cap: shed");
        assert!(gate.admits(1, 0, 3), "below the cap + one burst token");
        assert!(!gate.admits(1, 0, 3), "bucket dry");
        assert!(gate.admits(1, 1_000_000, 0), "refilled after 1 s");
        // classes beyond the table admit (defensive default)
        assert!(gate.admits(9, 0, u64::MAX));
    }

    #[test]
    fn prefill_table_and_tpot_lookup() {
        let slo = SloConfig {
            classes: vec![
                ClassSpec { name: "chat".into(), ttft_ms: Some(250.0), tpot_ms: Some(80.0), ..Default::default() }
                    .to_def(),
                ClassSpec { name: "batch".into(), tier: 2, ..Default::default() }.to_def(),
            ],
            admission: false,
        };
        assert_eq!(slo.prefill_table(), vec![(0, 250_000), (2, Us::MAX)]);
        assert_eq!(slo.tpot_deadline_us(0), Some(80_000));
        assert_eq!(slo.tpot_deadline_us(1), None);
        assert_eq!(slo.tpot_deadline_us(7), None);
        assert!(slo.is_active(), "deadlines alone activate the machinery");
    }

    #[test]
    fn class_flag_parses_and_rejects() {
        let c = parse_class_flag("name=chat,weight=0.5,tier=0,ttft_ms=300,tpot_ms=100").unwrap();
        assert_eq!(c.name, "chat");
        assert_eq!(c.weight, 0.5);
        assert_eq!(c.ttft_ms, Some(300.0));
        let c = parse_class_flag("name=batch,tier=2,rate_limit=4,burst=8,max_queue=64").unwrap();
        assert_eq!((c.tier, c.rate_limit, c.burst, c.max_queue), (2, Some(4.0), Some(8.0), Some(64)));
        assert!(parse_class_flag("weight=1").is_err(), "name required");
        assert!(parse_class_flag("name=x,tirr=2").is_err(), "unknown key");
        assert!(parse_class_flag("name=x,tier=abc").is_err(), "bad number");
        assert!(parse_class_flag("name=x,ttft_ms").is_err(), "missing '='");
    }
}
