//! Vanilla-vLLM baseline (§5: "vanilla vLLM tightly couples prefill and
//! decode phases"): coupled instances run continuous batching where each
//! iteration mixes (a) up to `prefill_batch` whole waiting prompts —
//! fixed-batch prefill, no chunking — with (b) every running decode.
//! Memory is paged (the paper adopted vLLM's paging for both systems) with
//! greedy admission.
//!
//! This is the system whose interference §2.2 measures: one heavy prompt
//! in an iteration stalls every co-running decode (Figure 4), and decode
//! batches are packed without working-set awareness (Figure 5).
//!
//! Since the instance-engine refactor this driver is pure policy glue:
//! the arena request store, event loop and finish bookkeeping live in
//! `sim::EngineCore` (shared with the TetriInfer cluster driver), and the
//! mixed-iteration mechanics live in `instance::CoupledInst` (shared with
//! the hybrid cluster). What remains here is the least-loaded arrival
//! routing and the last-arrival partial-batch kick.

use crate::api::{NullObserver, Observer};
use crate::costmodel::CostModel;
use crate::instance::CoupledInst;
use crate::metrics::RunMetrics;
use crate::slo::{AdmissionGate, SloConfig};
use crate::sim::{
    macro_chain, run_des, run_des_source, ArrivalSource, EngineCore, EngineHost, Event,
};
use crate::types::{ReqId, Request, Us};

#[derive(Clone, Debug)]
pub struct BaselineConfig {
    pub n_instances: usize,
    /// Fixed prefill batch size (paper §5.2.1: vLLM's batch size = 16).
    /// Fixed-batch mode *waits* until this many prompts are queued before
    /// running a prefill iteration (they all complete together at the
    /// iteration's end) — the behaviour Figure 16 compares chunking
    /// against. Partial batches run only when the instance has nothing
    /// else to do and no more arrivals can fill them.
    pub prefill_batch: usize,
    /// Decode batch cap. The paper's vanilla-vLLM setup uses a *fixed*
    /// batch size of 16 for both phases (§5.2.1, and Figure 12 credits
    /// TetriInfer's "variable decode batch size over vLLM's fixed batch
    /// size"); TetriInfer's decode instances batch up to 128.
    pub max_batch: u32,
    /// Keep per-request records in the run metrics (see
    /// `ClusterConfig::retain_records` — same knob, same default).
    pub retain_records: bool,
    /// Macro-step coupled iteration chains (see
    /// `ClusterConfig::macro_step` — pure perf knob, parity-tested).
    pub macro_step: bool,
    /// SLO multi-tenancy (see `ClusterConfig::slo` — the identical gate
    /// logic runs here; rate-limit sheds match the cluster's on a shared
    /// trace, queue-depth sheds track this system's own congestion —
    /// see `slo::AdmissionGate`).
    pub slo: SloConfig,
    pub cost: CostModel,
    pub seed: u64,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig {
            n_instances: 1,
            prefill_batch: 16,
            max_batch: 16,
            retain_records: true,
            macro_step: true,
            slo: SloConfig::default(),
            cost: CostModel::default(),
            seed: 0,
        }
    }
}

pub struct BaselineCluster {
    cfg: BaselineConfig,
    /// Shared DES engine: queue + arena + metrics + termination.
    core: EngineCore,
    insts: Vec<CoupledInst>,
    /// Arrivals not yet delivered (partial prefill batches wait on these).
    arrivals_pending: usize,
    /// SLO admission gate (`None` = admission off) — the same
    /// deterministic logic the cluster entry router runs.
    gate: Option<AdmissionGate>,
}

impl BaselineCluster {
    pub fn new(cfg: BaselineConfig) -> Self {
        let pages = (cfg.cost.kv_capacity_tokens() / 16) as u32;
        let insts = (0..cfg.n_instances).map(|_| CoupledInst::new(pages)).collect();
        let n = cfg.n_instances;
        let mut core = EngineCore::new(n);
        core.metrics.retain_records = cfg.retain_records;
        core.metrics.set_classes(cfg.slo.classes.clone());
        let gate = AdmissionGate::from_config(&cfg.slo);
        BaselineCluster {
            cfg,
            core,
            insts,
            arrivals_pending: 0,
            gate,
        }
    }

    pub fn run(self, trace: Vec<Request>) -> RunMetrics {
        self.run_observed(trace, &mut NullObserver)
    }

    /// Run a trace to completion, streaming per-event hooks to `obs`
    /// (the coupled baseline fires arrival/chunk/decode-iter/finish; it
    /// has no fabric, monitor, or flips). Metrics are bit-identical to
    /// `run` whatever the observer does.
    pub fn run_observed(mut self, trace: Vec<Request>, obs: &mut dyn Observer) -> RunMetrics {
        run_des(&mut self, trace, obs)
    }

    /// Run a pull-based arrival stream to completion (O(active) memory;
    /// identical trajectory to `run_observed` on the materialized trace).
    pub fn run_streamed(mut self, source: &mut dyn ArrivalSource, obs: &mut dyn Observer) -> RunMetrics {
        run_des_source(&mut self, source, obs)
    }

    fn on_arrival(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        self.core.note_arrival(slot, obs);
        // One admission decision per request (the baseline never
        // re-delivers arrivals, but the contract matches the cluster's).
        if let Some(gate) = self.gate.as_mut() {
            let req = self.core.requests[slot as usize].req;
            let in_flight = (self.core.in_flight() - 1) as u64;
            if !gate.admits(req.class, self.core.now(), in_flight) {
                self.core.shed(slot, obs);
                self.note_delivered(obs);
                return;
            }
        }
        // Least-loaded coupled instance (waiting prompts + resident jobs)
        // — O(n_instances) over maintained counters.
        let i = (0..self.insts.len())
            .min_by_key(|&i| self.insts[i].route_load())
            .unwrap();
        let plen = self.core.requests[slot as usize].req.prompt_len;
        self.insts[i].enqueue(slot, plen);
        if !self.note_delivered(obs) {
            self.try_start(i, obs);
        }
    }

    /// One arrival left the global queue (routed or shed). When it was
    /// the last one, partial prefill batches may run everywhere; returns
    /// whether that kick happened.
    fn note_delivered(&mut self, obs: &mut dyn Observer) -> bool {
        self.arrivals_pending -= 1;
        if self.arrivals_pending == 0 {
            for j in 0..self.insts.len() {
                self.try_start(j, obs);
            }
            true
        } else {
            false
        }
    }

    /// Begin one mixed iteration on `i` at virtual time `now` — the
    /// single copy of iteration start shared by the arrival path
    /// ([`BaselineCluster::try_start`]) and the macro-step chain. A
    /// partial prefill batch runs only when no future arrival could still
    /// fill it and the decode side gives the instance nothing to do. One
    /// mixed iteration = a prefill side and a decode side sharing `dur`;
    /// each observer hook fires only when its side is non-empty. Returns
    /// the iteration's end time, or `None` when there is nothing to do.
    fn start_iteration(&mut self, i: usize, now: Us, obs: &mut dyn Observer) -> Option<Us> {
        let cost = self.cfg.cost;
        let more_arrivals = self.arrivals_pending > 0;
        let st = self.insts[i].begin_iteration(
            &self.core.requests,
            &cost,
            self.cfg.prefill_batch,
            self.cfg.max_batch,
            more_arrivals,
            now,
        )?;
        self.core.metrics.busy_us[i] += st.dur;
        if st.prefill_tokens > 0 {
            obs.on_chunk(now, i, st.prefill_tokens, 0, st.dur);
        }
        if st.batch > 0 {
            obs.on_decode_iter(now, i, st.batch, st.kv_tokens, st.dur);
        }
        Some(now + st.dur)
    }

    fn try_start(&mut self, i: usize, obs: &mut dyn Observer) {
        let now = self.core.now();
        if let Some(end) = self.start_iteration(i, now, obs) {
            self.core.queue.schedule_at(end, Event::CoupledIterDone { instance: i });
        }
    }

    /// Close the mixed iteration that just ended on instance `i` at
    /// virtual time `now`: stamp first tokens, finish single-token
    /// prompts and completed decodes, hand the buffers back for reuse.
    fn close_iteration(&mut self, i: usize, now: Us, obs: &mut dyn Observer) {
        let (mut prefilled, mut done) = self.insts[i].end_iteration(now);
        for slot in prefilled.drain(..) {
            self.core.requests[slot as usize].first_token = now;
            // single-token requests finish at prefill
            if self.core.requests[slot as usize].req.decode_len <= 1 {
                self.insts[i].drop_running(slot);
                self.core.finish(slot, now, obs);
            }
        }
        for slot in done.drain(..) {
            self.core.finish(slot, now, obs);
        }
        self.insts[i].return_bufs(prefilled, done);
    }

    /// Iteration-complete handler: the coupled-baseline instantiation of
    /// the shared [`macro_chain`] scaffold — iterations chain inline
    /// while nothing external can land in the window, event-for-event
    /// identical to per-iteration stepping (parity-tested in
    /// tests/golden.rs).
    fn on_iter_done(&mut self, i: usize, obs: &mut dyn Observer) {
        let macro_on = self.cfg.macro_step;
        macro_chain(
            self,
            macro_on,
            obs,
            |s, now, obs| s.close_iteration(i, now, obs),
            |s, now, obs| s.start_iteration(i, now, obs),
            |s, end| s.core.queue.schedule_at(end, Event::CoupledIterDone { instance: i }),
        );
    }
}

impl EngineHost for BaselineCluster {
    fn core_mut(&mut self) -> &mut EngineCore {
        &mut self.core
    }

    fn driver_name(&self) -> &'static str {
        "baseline"
    }

    fn begin(&mut self, _obs: &mut dyn Observer) {
        // arrivals stream in lazily: start from the source's total
        self.arrivals_pending = self.core.total_expected;
    }

    fn handle(&mut self, ev: Event, obs: &mut dyn Observer) {
        match ev {
            Event::Arrival(slot) => self.on_arrival(slot, obs),
            Event::CoupledIterDone { instance } => self.on_iter_done(instance, obs),
            _ => unreachable!("unexpected event in baseline"),
        }
    }

    fn end(&mut self, _obs: &mut dyn Observer) {
        self.core.stamp_alive_full_run();
        for inst in &self.insts {
            self.core.metrics.swapped_tokens += inst.kv.swapped_out_tokens;
        }
    }
}

/// Convenience: run a trace through the coupled-baseline driver (the same
/// `api::Driver` the scenario registry resolves for `"vllm"`), with no
/// observer attached.
pub fn run_baseline(cfg: BaselineConfig, trace: Vec<Request>) -> RunMetrics {
    use crate::api::Driver as _;
    crate::api::BaselineDriver::from_config(cfg)
        .run(&trace, &mut NullObserver)
        .metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{WorkloadGen, WorkloadKind};

    #[test]
    fn completes_every_request() {
        let mut gen = WorkloadGen::new(1);
        let trace = gen.trace(WorkloadKind::Mixed, 64, 20.0, 0);
        let m = run_baseline(BaselineConfig::default(), trace);
        assert_eq!(m.records.len(), 64);
        assert!(m.events >= 64);
    }

    #[test]
    fn deterministic() {
        let mk = || {
            let mut gen = WorkloadGen::new(2);
            run_baseline(BaselineConfig::default(), gen.trace(WorkloadKind::Lpld, 32, 0.0, 0))
        };
        assert_eq!(mk().makespan_us, mk().makespan_us);
    }

    #[test]
    fn heavy_prompts_inflate_corunning_decode_latency() {
        // The §2.2.2 effect end-to-end: a stream of light decodes completes
        // slower when heavy prompts keep arriving on the same instance.
        let mut gen = WorkloadGen::new(3);
        let mut light = gen.trace(WorkloadKind::Lpld, 32, 0.0, 0);
        let light_only = run_baseline(BaselineConfig { n_instances: 1, ..Default::default() }, light.clone());
        // add heavy-prefill requests arriving alongside
        let heavy = gen.trace(WorkloadKind::Hpld, 16, 0.0, 0);
        light.extend(heavy);
        let mixed = run_baseline(BaselineConfig { n_instances: 1, ..Default::default() }, light);
        let jct_light_only = light_only.jct_summary().mean;
        let jct_mixed_lights: f64 = {
            let xs: Vec<f64> = mixed
                .records
                .iter()
                .filter(|r| r.prompt_len <= 512)
                .map(|r| r.jct() as f64 / 1e3)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            jct_mixed_lights > jct_light_only * 1.3,
            "light requests should suffer from heavy co-runners: {jct_light_only} vs {jct_mixed_lights}"
        );
    }
}
