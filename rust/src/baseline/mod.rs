//! Vanilla-vLLM baseline (§5: "vanilla vLLM tightly couples prefill and
//! decode phases"): coupled instances run continuous batching where each
//! iteration mixes (a) up to `prefill_batch` whole waiting prompts —
//! fixed-batch prefill, no chunking — with (b) every running decode.
//! Memory is paged (the paper adopted vLLM's paging for both systems) with
//! greedy admission.
//!
//! This is the system whose interference §2.2 measures: one heavy prompt
//! in an iteration stalls every co-running decode (Figure 4), and decode
//! batches are packed without working-set awareness (Figure 5).
//!
//! Since the instance-engine refactor this driver is pure policy glue:
//! the arena request store, event loop and finish bookkeeping live in
//! `sim::EngineCore` (shared with the TetriInfer cluster driver), and the
//! mixed-iteration mechanics live in `instance::CoupledInst` (shared with
//! the hybrid cluster). What remains here is the least-loaded arrival
//! routing and the last-arrival partial-batch kick.

use crate::api::{NullObserver, Observer};
use crate::costmodel::CostModel;
use crate::fault::{scale_dur, FaultConfig, FaultPlan, Injection};
use crate::instance::CoupledInst;
use crate::metrics::RunMetrics;
use crate::slo::{AdmissionGate, SloConfig};
use crate::sim::{
    macro_chain, run_des, run_des_source, ArrivalSource, EngineCore, EngineHost, Event,
};
use crate::types::{ReqId, Request, Us};

#[derive(Clone, Debug)]
pub struct BaselineConfig {
    pub n_instances: usize,
    /// Fixed prefill batch size (paper §5.2.1: vLLM's batch size = 16).
    /// Fixed-batch mode *waits* until this many prompts are queued before
    /// running a prefill iteration (they all complete together at the
    /// iteration's end) — the behaviour Figure 16 compares chunking
    /// against. Partial batches run only when the instance has nothing
    /// else to do and no more arrivals can fill them.
    pub prefill_batch: usize,
    /// Decode batch cap. The paper's vanilla-vLLM setup uses a *fixed*
    /// batch size of 16 for both phases (§5.2.1, and Figure 12 credits
    /// TetriInfer's "variable decode batch size over vLLM's fixed batch
    /// size"); TetriInfer's decode instances batch up to 128.
    pub max_batch: u32,
    /// Keep per-request records in the run metrics (see
    /// `ClusterConfig::retain_records` — same knob, same default).
    pub retain_records: bool,
    /// Macro-step coupled iteration chains (see
    /// `ClusterConfig::macro_step` — pure perf knob, parity-tested).
    pub macro_step: bool,
    /// SLO multi-tenancy (see `ClusterConfig::slo` — the identical gate
    /// logic runs here; rate-limit sheds match the cluster's on a shared
    /// trace, queue-depth sheds track this system's own congestion —
    /// see `slo::AdmissionGate`).
    pub slo: SloConfig,
    /// Deterministic fault injection (see `ClusterConfig::fault` — the
    /// same chaos schedule runs against coupled instances; link events
    /// are no-ops here because the baseline ships no KV). `None` runs
    /// fault-free, bit-identical to pre-fault builds.
    pub fault: Option<FaultConfig>,
    /// Collect a per-event-kind wall-time profile (see
    /// `ClusterConfig::profile_events` — same knob, observability only).
    pub profile_events: bool,
    /// Early-stop knobs (see `ClusterConfig::stop` — same knob, off by
    /// default).
    pub stop: crate::sim::StopPolicy,
    pub cost: CostModel,
    pub seed: u64,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig {
            n_instances: 1,
            prefill_batch: 16,
            max_batch: 16,
            retain_records: true,
            macro_step: true,
            slo: SloConfig::default(),
            fault: None,
            profile_events: false,
            stop: crate::sim::StopPolicy::off(),
            cost: CostModel::default(),
            seed: 0,
        }
    }
}

pub struct BaselineCluster {
    cfg: BaselineConfig,
    /// Shared DES engine: queue + arena + metrics + termination.
    core: EngineCore,
    insts: Vec<CoupledInst>,
    /// Arrivals not yet delivered (partial prefill batches wait on these).
    arrivals_pending: usize,
    /// SLO admission gate (`None` = admission off) — the same
    /// deterministic logic the cluster entry router runs.
    gate: Option<AdmissionGate>,
    /// Deterministic chaos schedule (`None` = fault-free; every fault
    /// path below is gated on it).
    plan: Option<FaultPlan>,
    /// Per-instance incarnation counters: a crash bumps the epoch so
    /// in-flight `CoupledIterDone` events go inert (the pool-less mirror
    /// of `instance::InstancePool`'s epochs).
    epochs: Vec<u32>,
    /// Whether each slot currently serves (false = crashed).
    alive: Vec<bool>,
    /// Crashed slots with a scheduled restart — capacity that will
    /// return, which recovery waits for instead of burning retry budget.
    restarts_pending: usize,
    /// Swap tallies of crashed incarnations (their state objects are
    /// replaced wholesale at crash).
    swapped_graveyard: u64,
    /// When the fleet dropped below the plan's capacity watermark.
    degraded_since: Option<Us>,
}

impl BaselineCluster {
    pub fn new(cfg: BaselineConfig) -> Self {
        let pages = (cfg.cost.kv_capacity_tokens() / 16) as u32;
        let insts = (0..cfg.n_instances).map(|_| CoupledInst::new(pages)).collect();
        let n = cfg.n_instances;
        let mut core = EngineCore::new(n);
        core.metrics.retain_records = cfg.retain_records;
        core.stop = cfg.stop;
        if cfg.profile_events {
            core.profile = Some(Box::default());
        }
        core.metrics.set_classes(cfg.slo.classes.clone());
        let gate = AdmissionGate::from_config(&cfg.slo);
        let plan = cfg.fault.clone().map(|fc| FaultPlan::new(fc, cfg.seed));
        BaselineCluster {
            cfg,
            core,
            insts,
            arrivals_pending: 0,
            gate,
            plan,
            epochs: vec![0; n],
            alive: vec![true; n],
            restarts_pending: 0,
            swapped_graveyard: 0,
            degraded_since: None,
        }
    }

    pub fn run(self, trace: Vec<Request>) -> RunMetrics {
        self.run_observed(trace, &mut NullObserver)
    }

    /// Run a trace to completion, streaming per-event hooks to `obs`
    /// (the coupled baseline fires arrival/chunk/decode-iter/finish; it
    /// has no fabric, monitor, or flips). Metrics are bit-identical to
    /// `run` whatever the observer does.
    pub fn run_observed(mut self, trace: Vec<Request>, obs: &mut dyn Observer) -> RunMetrics {
        run_des(&mut self, trace, obs)
    }

    /// Run a pull-based arrival stream to completion (O(active) memory;
    /// identical trajectory to `run_observed` on the materialized trace).
    pub fn run_streamed(mut self, source: &mut dyn ArrivalSource, obs: &mut dyn Observer) -> RunMetrics {
        run_des_source(&mut self, source, obs)
    }

    fn on_arrival(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        // One admission decision per request, at its first delivery —
        // fault retries re-enter here and must not re-charge the gate.
        let first_delivery = !self.core.seen(slot);
        self.core.note_arrival(slot, obs);
        if first_delivery {
            if let Some(gate) = self.gate.as_mut() {
                let req = self.core.requests[slot as usize];
                let in_flight = (self.core.in_flight() - 1) as u64;
                if !gate.admits(req.class, self.core.now(), in_flight) {
                    self.core.shed(slot, obs);
                    self.note_delivered(obs);
                    return;
                }
            }
            // Graceful degradation: below the fault plan's watermark,
            // best-effort tiers shed at the door (see the cluster's twin).
            if self.degraded_since.is_some() {
                let class = self.core.requests[slot as usize].class;
                let tier =
                    self.cfg.slo.classes.get(class as usize).map(|c| c.tier).unwrap_or(0);
                if tier != 0 {
                    self.core.shed(slot, obs);
                    self.note_delivered(obs);
                    return;
                }
            }
        }
        // Least-loaded coupled instance (waiting prompts + resident jobs)
        // — O(n_instances) over maintained counters. Crashed slots are
        // skipped; fault-free every slot is alive and the scan is the
        // legacy one.
        let target = (0..self.insts.len())
            .filter(|&i| self.alive[i])
            .min_by_key(|&i| self.insts[i].route_load());
        let Some(i) = target else {
            // Every instance is down. With a restart coming, park the
            // arrival until capacity returns; permanently dead fleets
            // burn retry budget so the request fails bounded instead of
            // looping forever.
            if self.restarts_pending > 0 {
                let delay = self.plan.as_ref().map(|p| p.backoff_us(1)).unwrap_or(100_000);
                self.core.queue.schedule_in(delay, Event::Arrival(slot));
            } else {
                self.requeue_lost(slot, obs);
            }
            return;
        };
        let plen = self.core.requests[slot as usize].prompt_len;
        self.insts[i].enqueue(slot, plen);
        if !self.note_delivered(obs) {
            self.try_start(i, obs);
        }
    }

    /// Re-queue a request lost to a fault (or stranded by a dead fleet):
    /// charge a retry against the plan's budget, re-enter the arrival
    /// router after exponential backoff, or fail once the budget is
    /// spent. All callers reach here with the slot still counted in
    /// `arrivals_pending` (crash harvest re-adds it first).
    fn requeue_lost(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        // fault-recovery bookkeeping — cold path (plan-gated)
        let _cold = crate::util::cold_section();
        let now = self.core.now();
        let n = self.core.note_lost(slot, now);
        let (retry_max, backoff) = match self.plan.as_ref() {
            Some(p) => (p.retry_max(), p.backoff_us(n)),
            None => return, // unreachable: fault paths require a plan
        };
        if n > retry_max {
            // leaves the global queue without ever enqueuing — unblock
            // partial batches like a shed
            self.note_delivered(obs);
            self.core.fail(slot, obs);
            return;
        }
        obs.on_backoff(now, self.core.requests[slot as usize].id, now + backoff);
        self.core.queue.schedule_in(backoff, Event::Retry(slot));
        obs.on_recovery(now, "requeue", None);
    }

    /// One arrival left the global queue (routed or shed). When it was
    /// the last one, partial prefill batches may run everywhere; returns
    /// whether that kick happened.
    fn note_delivered(&mut self, obs: &mut dyn Observer) -> bool {
        self.arrivals_pending -= 1;
        if self.arrivals_pending == 0 {
            for j in 0..self.insts.len() {
                self.try_start(j, obs);
            }
            true
        } else {
            false
        }
    }

    /// Begin one mixed iteration on `i` at virtual time `now` — the
    /// single copy of iteration start shared by the arrival path
    /// ([`BaselineCluster::try_start`]) and the macro-step chain. A
    /// partial prefill batch runs only when no future arrival could still
    /// fill it and the decode side gives the instance nothing to do. One
    /// mixed iteration = a prefill side and a decode side sharing `dur`;
    /// each observer hook fires only when its side is non-empty. Returns
    /// the iteration's end time, or `None` when there is nothing to do.
    fn start_iteration(&mut self, i: usize, now: Us, obs: &mut dyn Observer) -> Option<Us> {
        if !self.alive[i] {
            return None;
        }
        let cost = self.cfg.cost;
        let more_arrivals = self.arrivals_pending > 0;
        // straggler windows are pure functions of `now`: macro-stepped and
        // per-iteration runs price them identically
        let slow = self.plan.as_ref().map(|p| p.slowdown(i, now)).unwrap_or(1.0);
        let st = self.insts[i].begin_iteration(
            &self.core.requests,
            &cost,
            self.cfg.prefill_batch,
            self.cfg.max_batch,
            more_arrivals,
            now,
        )?;
        let dur = scale_dur(st.dur, slow);
        self.core.metrics.busy_us[i] += dur;
        if st.prefill_tokens > 0 {
            obs.on_chunk(now, i, st.prefill_tokens, 0, dur);
        }
        if st.batch > 0 {
            obs.on_decode_iter(now, i, st.batch, st.kv_tokens, dur);
        }
        // prompts admitted into this iteration begin prefill now (coupled
        // instances prefill whole prompts in one iteration — no chunking)
        for k in 0..self.insts[i].pending_prefilled.len() {
            let slot = self.insts[i].pending_prefilled[k];
            obs.on_prefill_start(now, i, self.core.requests[slot as usize].id);
        }
        Some(now + dur)
    }

    fn try_start(&mut self, i: usize, obs: &mut dyn Observer) {
        let now = self.core.now();
        if let Some(end) = self.start_iteration(i, now, obs) {
            let epoch = self.epochs[i];
            self.core.queue.schedule_at(end, Event::CoupledIterDone { instance: i, epoch });
        }
    }

    /// Close the mixed iteration that just ended on instance `i` at
    /// virtual time `now`: stamp first tokens, finish single-token
    /// prompts and completed decodes, hand the buffers back for reuse.
    fn close_iteration(&mut self, i: usize, now: Us, obs: &mut dyn Observer) {
        let (mut prefilled, mut done) = self.insts[i].end_iteration(now);
        for slot in prefilled.drain(..) {
            self.core.hot[slot as usize].first_token = now;
            obs.on_prefill_finish(now, i, self.core.requests[slot as usize].id);
            // single-token requests finish at prefill
            if self.core.requests[slot as usize].decode_len <= 1 {
                self.insts[i].drop_running(slot);
                self.core.finish(slot, now, obs);
            } else {
                obs.on_decode_enter(now, i, self.core.requests[slot as usize].id);
            }
        }
        for slot in done.drain(..) {
            self.core.finish(slot, now, obs);
        }
        self.insts[i].return_bufs(prefilled, done);
    }

    /// Iteration-complete handler: the coupled-baseline instantiation of
    /// the shared [`macro_chain`] scaffold — iterations chain inline
    /// while nothing external can land in the window, event-for-event
    /// identical to per-iteration stepping (parity-tested in
    /// tests/golden.rs).
    fn on_iter_done(&mut self, i: usize, epoch: u32, obs: &mut dyn Observer) {
        if self.epochs[i] != epoch {
            // crashed mid-iteration: the batch was harvested at crash
            // time; nothing may land on the restarted incarnation
            return;
        }
        let macro_on = self.cfg.macro_step;
        macro_chain(
            self,
            macro_on,
            obs,
            |s, now, obs| s.close_iteration(i, now, obs),
            |s, now, obs| s.start_iteration(i, now, obs),
            |s, end| {
                let epoch = s.epochs[i];
                s.core.queue.schedule_at(end, Event::CoupledIterDone { instance: i, epoch })
            },
        );
    }

    /// Deliver fault-plan event `k`. Link events open their windows in
    /// the plan but are otherwise no-ops — the coupled baseline ships no
    /// KV over any fabric (its observer hook still fires so chaos
    /// timelines line up across drivers).
    fn on_fault_event(&mut self, k: usize, obs: &mut dyn Observer) {
        // fault delivery allocates freely (harvests, target resolution)
        let _cold = crate::util::cold_section();
        let now = self.core.now();
        let live: Vec<usize> = (0..self.insts.len()).filter(|&i| self.alive[i]).collect();
        let inj = match self.plan.as_mut() {
            Some(p) => p.fire(k, now, &live),
            None => return,
        };
        match inj {
            Injection::Skipped => {}
            Injection::Crash { instance, restart_at } => {
                self.core.metrics.faults_injected += 1;
                self.crash_instance(instance, obs);
                if let Some(at) = restart_at {
                    self.restarts_pending += 1;
                    self.core.queue.schedule_at(at, Event::Restart { instance });
                }
            }
            Injection::Link { outage, .. } => {
                self.core.metrics.faults_injected += 1;
                obs.on_fault(now, if outage { "link_out" } else { "link_degrade" }, None);
            }
            Injection::Straggle { instance, .. } => {
                self.core.metrics.faults_injected += 1;
                obs.on_fault(now, "straggler", Some(instance));
            }
        }
    }

    /// Abrupt instance failure: harvest every request whose state dies
    /// with the incarnation, replace the state object wholesale (no KV or
    /// load tally survives on the dead slot), bump the epoch, and
    /// re-queue or fail the harvested requests.
    fn crash_instance(&mut self, i: usize, obs: &mut dyn Observer) {
        // crash harvest + state replacement allocate — cold path
        let _cold = crate::util::cold_section();
        let now = self.core.now();
        let lost = self.insts[i].harvest_crashed();
        // the dead incarnation's swap tally would die with the object
        self.swapped_graveyard += self.insts[i].kv.swapped_out_tokens;
        let pages = (self.cfg.cost.kv_capacity_tokens() / 16) as u32;
        self.insts[i] = CoupledInst::new(pages);
        self.alive[i] = false;
        self.epochs[i] += 1;
        obs.on_fault(now, "crash", Some(i));
        for slot in lost {
            // harvested requests had left the global queue; they re-enter
            // it, so they count as pending again until re-delivered
            self.arrivals_pending += 1;
            self.requeue_lost(slot, obs);
        }
        self.check_degraded(obs);
    }

    /// A crashed slot's downtime elapsed: it serves again (the fresh
    /// state object was installed at crash time, on the new epoch).
    fn on_restart(&mut self, i: usize, obs: &mut dyn Observer) {
        // fault recovery — cold path
        let _cold = crate::util::cold_section();
        if self.alive[i] {
            return; // duplicate restart event
        }
        self.alive[i] = true;
        self.restarts_pending = self.restarts_pending.saturating_sub(1);
        obs.on_recovery(self.core.now(), "restart", Some(i));
        self.check_degraded(obs);
        self.try_start(i, obs);
    }

    /// Re-evaluate degraded mode against the plan's capacity watermark
    /// (called only at crash/restart — capacity moves nowhere else).
    fn check_degraded(&mut self, obs: &mut dyn Observer) {
        let Some(watermark) = self.plan.as_ref().map(|p| p.watermark()) else { return };
        let now = self.core.now();
        let live = self.alive.iter().filter(|a| **a).count();
        let degraded = (live as f64) < watermark * self.insts.len() as f64;
        match (degraded, self.degraded_since) {
            (true, None) => {
                self.degraded_since = Some(now);
                obs.on_fault(now, "degraded", None);
            }
            (false, Some(since)) => {
                self.core.metrics.degraded_us += now.saturating_sub(since);
                self.degraded_since = None;
                obs.on_recovery(now, "capacity_restored", None);
            }
            _ => {}
        }
    }
}

impl EngineHost for BaselineCluster {
    fn core_mut(&mut self) -> &mut EngineCore {
        &mut self.core
    }

    fn driver_name(&self) -> &'static str {
        "baseline"
    }

    fn begin(&mut self, _obs: &mut dyn Observer) {
        // arrivals stream in lazily: start from the source's total
        self.arrivals_pending = self.core.total_expected;
        if let Some(plan) = &self.plan {
            // chaos schedule seeded in one batched admission
            self.core
                .queue
                .push_batch(plan.events().iter().enumerate().map(|(k, ev)| (ev.at, Event::Fault(k))));
        }
    }

    fn handle(&mut self, ev: Event, obs: &mut dyn Observer) {
        match ev {
            Event::Arrival(slot) => self.on_arrival(slot, obs),
            Event::CoupledIterDone { instance, epoch } => self.on_iter_done(instance, epoch, obs),
            Event::Fault(k) => self.on_fault_event(k, obs),
            Event::Restart { instance } => self.on_restart(instance, obs),
            Event::Retry(slot) => self.on_arrival(slot, obs),
            _ => unreachable!("unexpected event in baseline"),
        }
    }

    fn end(&mut self, _obs: &mut dyn Observer) {
        self.core.stamp_alive_full_run();
        if let Some(since) = self.degraded_since.take() {
            let now = self.core.now();
            self.core.metrics.degraded_us += now.saturating_sub(since);
        }
        self.core.metrics.swapped_tokens += self.swapped_graveyard;
        for inst in &self.insts {
            self.core.metrics.swapped_tokens += inst.kv.swapped_out_tokens;
        }
    }
}

/// Convenience: run a trace through the coupled-baseline driver (the same
/// `api::Driver` the scenario registry resolves for `"vllm"`), with no
/// observer attached.
pub fn run_baseline(cfg: BaselineConfig, trace: Vec<Request>) -> RunMetrics {
    use crate::api::Driver as _;
    crate::api::BaselineDriver::from_config(cfg)
        .run(&trace, &mut NullObserver)
        .metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{WorkloadGen, WorkloadKind};

    #[test]
    fn completes_every_request() {
        let mut gen = WorkloadGen::new(1);
        let trace = gen.trace(WorkloadKind::Mixed, 64, 20.0, 0);
        let m = run_baseline(BaselineConfig::default(), trace);
        assert_eq!(m.records.len(), 64);
        assert!(m.events >= 64);
    }

    #[test]
    fn deterministic() {
        let mk = || {
            let mut gen = WorkloadGen::new(2);
            run_baseline(BaselineConfig::default(), gen.trace(WorkloadKind::Lpld, 32, 0.0, 0))
        };
        assert_eq!(mk().makespan_us, mk().makespan_us);
    }

    #[test]
    fn heavy_prompts_inflate_corunning_decode_latency() {
        // The §2.2.2 effect end-to-end: a stream of light decodes completes
        // slower when heavy prompts keep arriving on the same instance.
        let mut gen = WorkloadGen::new(3);
        let mut light = gen.trace(WorkloadKind::Lpld, 32, 0.0, 0);
        let light_only = run_baseline(BaselineConfig { n_instances: 1, ..Default::default() }, light.clone());
        // add heavy-prefill requests arriving alongside
        let heavy = gen.trace(WorkloadKind::Hpld, 16, 0.0, 0);
        light.extend(heavy);
        let mixed = run_baseline(BaselineConfig { n_instances: 1, ..Default::default() }, light);
        let jct_light_only = light_only.jct_summary().mean;
        let jct_mixed_lights: f64 = {
            let xs: Vec<f64> = mixed
                .records
                .iter()
                .filter(|r| r.prompt_len <= 512)
                .map(|r| r.jct() as f64 / 1e3)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            jct_mixed_lights > jct_light_only * 1.3,
            "light requests should suffer from heavy co-runners: {jct_light_only} vs {jct_mixed_lights}"
        );
    }

    fn fault_cfg(events: Vec<crate::fault::FaultEvent>) -> FaultConfig {
        FaultConfig { events, retry_max: 4, backoff_us: 25_000, watermark: 0.5 }
    }

    #[test]
    fn empty_fault_plan_is_bit_identical_to_no_plan() {
        let mut gen = WorkloadGen::new(11);
        let trace = gen.trace(WorkloadKind::Mixed, 48, 30.0, 0);
        let base = run_baseline(BaselineConfig::default(), trace.clone());
        let faulted = run_baseline(
            BaselineConfig { fault: Some(fault_cfg(Vec::new())), ..Default::default() },
            trace,
        );
        assert_eq!(base.makespan_us, faulted.makespan_us);
        assert_eq!(base.events, faulted.events);
        assert_eq!(base.records.len(), faulted.records.len());
        for (a, b) in base.records.iter().zip(faulted.records.iter()) {
            assert_eq!(a.finished, b.finished, "req {} diverged", a.id);
            assert_eq!(a.first_token, b.first_token);
            assert_eq!(a.retries, 0);
            assert!(!a.recovered);
        }
    }

    #[test]
    fn coupled_crash_with_restart_recovers_and_conserves() {
        use crate::fault::{FaultEvent, FaultKind};
        let mut gen = WorkloadGen::new(13);
        let trace = gen.trace(WorkloadKind::Mixed, 64, 0.0, 0);
        let ev = FaultEvent {
            at: 100_000,
            kind: FaultKind::Restart,
            instance: Some(1),
            down: 400_000,
            factor: 1.0,
        };
        let m = run_baseline(
            BaselineConfig {
                n_instances: 2,
                fault: Some(fault_cfg(vec![ev])),
                ..Default::default()
            },
            trace,
        );
        assert_eq!(m.faults_injected, 1);
        assert_eq!(m.finished + m.shed + m.failed, 64, "conservation");
        assert_eq!(m.failed, 0, "a surviving instance plus a restart loses nothing");
        assert!(m.records.iter().any(|r| r.recovered), "someone must have re-entered");
        assert!(m.records.iter().all(|r| r.retries <= 4));
    }

    #[test]
    fn permanent_crash_of_whole_fleet_fails_bounded() {
        use crate::fault::{FaultEvent, FaultKind};
        let mut gen = WorkloadGen::new(17);
        let trace = gen.trace(WorkloadKind::Lpld, 24, 50.0, 0);
        let ev = FaultEvent {
            at: 50_000,
            kind: FaultKind::Crash,
            instance: Some(0),
            down: 0,
            factor: 1.0,
        };
        let m = run_baseline(
            BaselineConfig { n_instances: 1, fault: Some(fault_cfg(vec![ev])), ..Default::default() },
            trace,
        );
        // the run terminates (we got metrics back) and every request is
        // accounted for: finished before the crash, or failed after
        // burning its retry budget
        assert_eq!(m.finished + m.shed + m.failed, 24, "conservation");
        assert!(m.failed >= 1, "a dead fleet must fail the stragglers");
        assert!(m.degraded_us > 0, "0 of 1 live is below any watermark");
        assert!(m.records.iter().all(|r| r.retries <= 4 + 1));
    }
}
