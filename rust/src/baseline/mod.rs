//! Vanilla-vLLM baseline (§5: "vanilla vLLM tightly couples prefill and
//! decode phases"): coupled instances run continuous batching where each
//! iteration mixes (a) up to `prefill_batch` whole waiting prompts —
//! fixed-batch prefill, no chunking — with (b) every running decode.
//! Memory is paged (the paper adopted vLLM's paging for both systems) with
//! greedy admission.
//!
//! This is the system whose interference §2.2 measures: one heavy prompt
//! in an iteration stalls every co-running decode (Figure 4), and decode
//! batches are packed without working-set awareness (Figure 5).
//!
//! Like the TetriInfer cluster, the request book is a dense arena indexed
//! by slot (events, KV tables and queues all carry slots), per-instance
//! waiting-token load is a maintained counter, and iteration buffers are
//! reused — no per-event hashing or cloning (DESIGN.md §Hot paths).

use std::collections::VecDeque;

use crate::api::{NullObserver, Observer};
use crate::costmodel::CostModel;
use crate::decode::{DecodeJob, DecodePolicy, DecodeScheduler};
use crate::kvcache::PagedKvCache;
use crate::metrics::RunMetrics;
use crate::sim::{Event, EventQueue};
use crate::types::{ReqId, ReqMeta, Request, RequestRecord, Us};

#[derive(Clone, Debug)]
pub struct BaselineConfig {
    pub n_instances: usize,
    /// Fixed prefill batch size (paper §5.2.1: vLLM's batch size = 16).
    /// Fixed-batch mode *waits* until this many prompts are queued before
    /// running a prefill iteration (they all complete together at the
    /// iteration's end) — the behaviour Figure 16 compares chunking
    /// against. Partial batches run only when the instance has nothing
    /// else to do and no more arrivals can fill them.
    pub prefill_batch: usize,
    /// Decode batch cap. The paper's vanilla-vLLM setup uses a *fixed*
    /// batch size of 16 for both phases (§5.2.1, and Figure 12 credits
    /// TetriInfer's "variable decode batch size over vLLM's fixed batch
    /// size"); TetriInfer's decode instances batch up to 128.
    pub max_batch: u32,
    pub cost: CostModel,
    pub seed: u64,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig {
            n_instances: 1,
            prefill_batch: 16,
            max_batch: 16,
            cost: CostModel::default(),
            seed: 0,
        }
    }
}

/// Sentinel for "first token not yet produced".
const NO_TIME: Us = Us::MAX;

struct ReqState {
    req: Request,
    first_token: Us,
}

struct CoupledInst {
    /// Arrived, not yet prefilled (arena slots).
    waiting: VecDeque<ReqId>,
    /// Prompt tokens across `waiting`, maintained incrementally (the
    /// arrival router's O(1) load input).
    waiting_tokens: u64,
    /// Decode-side state (greedy admission = vLLM's policy). We reuse the
    /// decode scheduler with jobs that were prefilled locally.
    dec: DecodeScheduler,
    kv: PagedKvCache,
    busy: bool,
    /// (prefilled this iteration, completed this iteration) — slot
    /// buffers reused across iterations.
    pending: (Vec<ReqId>, Vec<ReqId>),
}

pub struct BaselineCluster {
    cfg: BaselineConfig,
    queue: EventQueue,
    insts: Vec<CoupledInst>,
    /// Request arena indexed by slot (events carry slots).
    requests: Vec<ReqState>,
    metrics: RunMetrics,
    outstanding: usize,
    /// Arrivals not yet delivered (partial prefill batches wait on these).
    arrivals_pending: usize,
}

impl BaselineCluster {
    pub fn new(cfg: BaselineConfig) -> Self {
        let pages = (cfg.cost.kv_capacity_tokens() / 16) as u32;
        let insts = (0..cfg.n_instances)
            .map(|_| CoupledInst {
                waiting: VecDeque::new(),
                waiting_tokens: 0,
                // residency is memory-bound, not batch-bound: the fixed
                // batch caps the per-iteration *step window* (see
                // try_start), not how many requests hold pages.
                dec: DecodeScheduler::new(DecodePolicy::Greedy, 200, u32::MAX),
                kv: PagedKvCache::new(pages.max(2), 16),
                busy: false,
                pending: (Vec::new(), Vec::new()),
            })
            .collect();
        let n = cfg.n_instances;
        BaselineCluster {
            cfg,
            queue: EventQueue::new(),
            insts,
            requests: Vec::new(),
            metrics: RunMetrics {
                busy_us: vec![0; n],
                alive_us: vec![0; n],
                decode_assign: vec![(0, 0); n],
                ..Default::default()
            },
            outstanding: 0,
            arrivals_pending: 0,
        }
    }

    pub fn run(self, trace: Vec<Request>) -> RunMetrics {
        self.run_observed(trace, &mut NullObserver)
    }

    /// Run a trace to completion, streaming per-event hooks to `obs`
    /// (the coupled baseline fires arrival/chunk/decode-iter/finish; it
    /// has no fabric, monitor, or flips). Metrics are bit-identical to
    /// `run` whatever the observer does.
    pub fn run_observed(mut self, trace: Vec<Request>, obs: &mut dyn Observer) -> RunMetrics {
        self.outstanding = trace.len();
        self.arrivals_pending = trace.len();
        self.requests = trace
            .into_iter()
            .map(|req| ReqState { req, first_token: NO_TIME })
            .collect();
        for slot in 0..self.requests.len() {
            self.queue
                .schedule_at(self.requests[slot].req.arrival, Event::Arrival(slot as ReqId));
        }
        while self.outstanding > 0 {
            let Some((_, ev)) = self.queue.pop() else {
                panic!("baseline deadlock: {} outstanding", self.outstanding);
            };
            self.metrics.events += 1;
            match ev {
                Event::Arrival(slot) => self.on_arrival(slot, obs),
                Event::CoupledIterDone { instance } => self.on_iter_done(instance, obs),
                _ => unreachable!("unexpected event in baseline"),
            }
        }
        self.metrics.makespan_us = self.queue.now();
        for a in self.metrics.alive_us.iter_mut() {
            *a = self.queue.now();
        }
        for inst in &self.insts {
            self.metrics.swapped_tokens += inst.kv.swapped_out_tokens;
        }
        self.metrics
    }

    fn on_arrival(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        {
            let req = self.requests[slot as usize].req;
            obs.on_arrival(self.queue.now(), &req);
        }
        // Least-loaded coupled instance (waiting prompts + resident jobs)
        // — O(n_instances) over maintained counters.
        let i = (0..self.insts.len())
            .min_by_key(|&i| {
                let inst = &self.insts[i];
                inst.waiting_tokens + inst.dec.total_jobs() as u64 * 64
            })
            .unwrap();
        let plen = self.requests[slot as usize].req.prompt_len;
        let inst = &mut self.insts[i];
        inst.waiting.push_back(slot);
        inst.waiting_tokens += plen as u64;
        self.arrivals_pending -= 1;
        if self.arrivals_pending == 0 {
            // last arrival: partial batches may now run everywhere
            for j in 0..self.insts.len() {
                self.try_start(j, obs);
            }
        } else {
            self.try_start(i, obs);
        }
    }

    fn try_start(&mut self, i: usize, obs: &mut dyn Observer) {
        let cost = self.cfg.cost;
        let prefill_batch = self.cfg.prefill_batch;
        // May a partial prefill batch run? Only when no future arrival
        // could still fill it and the decode side gives us nothing to do.
        let more_arrivals = self.arrivals_pending > 0;
        let inst = &mut self.insts[i];
        if inst.busy {
            return;
        }
        inst.pending.0.clear();
        inst.pending.1.clear();
        // (a) fixed-batch prefill: wait for `prefill_batch` prompts, then
        // prefill them all in one iteration (greedy memory admission).
        let mut prefill_tokens = 0u32;
        let batch_ready = inst.waiting.len() >= prefill_batch
            || (!inst.waiting.is_empty() && (!more_arrivals || inst.dec.total_jobs() == 0));
        if batch_ready {
            while inst.pending.0.len() < prefill_batch {
                let Some(&slot) = inst.waiting.front() else { break };
                let plen = self.requests[slot as usize].req.prompt_len;
                if !inst.kv.can_fit(slot, plen + 1) {
                    break; // head-of-line block: vLLM stalls prefill on memory
                }
                inst.waiting.pop_front();
                inst.waiting_tokens -= plen as u64;
                inst.kv.alloc(slot, plen + 1).expect("can_fit checked");
                prefill_tokens += plen;
                inst.pending.0.push(slot);
            }
        }
        // (b) decodes ride the same iteration, capped at the *fixed* batch
        // size (FCFS window over resident jobs — vanilla vLLM semantics).
        let paged_in = inst.dec.admit(&mut inst.kv);
        let window = (self.cfg.max_batch as usize).min(inst.dec.n_resident());
        let batch = window as u32;
        let kv_tokens: u64 = inst.dec.running()[..window]
            .iter()
            .map(|j| j.kv_tokens() as u64)
            .sum();
        if inst.pending.0.is_empty() && batch == 0 {
            return;
        }
        let swapped_out = inst.dec.step_n(&mut inst.kv, window, &mut inst.pending.1);
        debug_assert!(inst.kv.check_invariants().is_ok());
        let dur = cost.mixed_iter_us(prefill_tokens, batch, kv_tokens)
            + cost.swap_us(swapped_out + paged_in_swapped(paged_in, &inst.dec));

        // Prefilled requests become decode jobs at iteration end. Their
        // pages were allocated above, so they enter the running batch
        // directly (the scheduler keeps its aggregates in sync).
        for k in 0..inst.pending.0.len() {
            let slot = inst.pending.0[k];
            let st = &self.requests[slot as usize];
            // scheduler-facing meta keyed by the arena slot, not the
            // original request id
            let meta = ReqMeta { id: slot, ..st.req.meta() };
            let mut job = DecodeJob::new(meta, st.req.decode_len);
            job.generated = 1;
            inst.dec.inject_running(job);
        }
        inst.busy = true;
        self.metrics.busy_us[i] += dur;
        self.queue.schedule_in(dur, Event::CoupledIterDone { instance: i });
        // One mixed iteration = a prefill side and a decode side sharing
        // `dur`: report whichever sides are non-empty.
        let now = self.queue.now();
        if prefill_tokens > 0 {
            obs.on_chunk(now, i, prefill_tokens, 0, dur);
        }
        if batch > 0 {
            obs.on_decode_iter(now, i, batch, kv_tokens, dur);
        }
    }

    fn on_iter_done(&mut self, i: usize, obs: &mut dyn Observer) {
        let now = self.queue.now();
        let (mut prefilled, mut done) = {
            let inst = &mut self.insts[i];
            inst.busy = false;
            (
                std::mem::take(&mut inst.pending.0),
                std::mem::take(&mut inst.pending.1),
            )
        };
        for slot in prefilled.drain(..) {
            self.requests[slot as usize].first_token = now;
            // single-token requests finish at prefill
            if self.requests[slot as usize].req.decode_len <= 1 {
                let inst = &mut self.insts[i];
                if inst.dec.remove_running(slot).is_some() {
                    inst.kv.release(slot);
                }
                self.finish(slot, now, obs);
            }
        }
        for slot in done.drain(..) {
            self.finish(slot, now, obs);
        }
        // hand the buffers back so the next iteration reuses their capacity
        self.insts[i].pending = (prefilled, done);
        self.try_start(i, obs);
    }

    fn finish(&mut self, slot: ReqId, now: Us, obs: &mut dyn Observer) {
        let st = &self.requests[slot as usize];
        let first = if st.first_token == NO_TIME { now } else { st.first_token };
        let rec = RequestRecord {
            id: st.req.id,
            task: st.req.task,
            prompt_len: st.req.prompt_len,
            decode_len: st.req.decode_len,
            arrival: st.req.arrival,
            first_token: first,
            finished: now,
            predicted: None,
        };
        obs.on_finish(now, &rec);
        self.metrics.records.push(rec);
        self.outstanding -= 1;
    }
}

fn paged_in_swapped(paged_in: u64, dec: &DecodeScheduler) -> u64 {
    if dec.running_has_swap_history() {
        paged_in
    } else {
        0
    }
}

/// Convenience: run a trace through the coupled-baseline driver (the same
/// `api::Driver` the scenario registry resolves for `"vllm"`), with no
/// observer attached.
pub fn run_baseline(cfg: BaselineConfig, trace: Vec<Request>) -> RunMetrics {
    use crate::api::Driver as _;
    crate::api::BaselineDriver::from_config(cfg)
        .run(&trace, &mut NullObserver)
        .metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{WorkloadGen, WorkloadKind};

    #[test]
    fn completes_every_request() {
        let mut gen = WorkloadGen::new(1);
        let trace = gen.trace(WorkloadKind::Mixed, 64, 20.0, 0);
        let m = run_baseline(BaselineConfig::default(), trace);
        assert_eq!(m.records.len(), 64);
        assert!(m.events >= 64);
    }

    #[test]
    fn deterministic() {
        let mk = || {
            let mut gen = WorkloadGen::new(2);
            run_baseline(BaselineConfig::default(), gen.trace(WorkloadKind::Lpld, 32, 0.0, 0))
        };
        assert_eq!(mk().makespan_us, mk().makespan_us);
    }

    #[test]
    fn heavy_prompts_inflate_corunning_decode_latency() {
        // The §2.2.2 effect end-to-end: a stream of light decodes completes
        // slower when heavy prompts keep arriving on the same instance.
        let mut gen = WorkloadGen::new(3);
        let mut light = gen.trace(WorkloadKind::Lpld, 32, 0.0, 0);
        let light_only = run_baseline(BaselineConfig { n_instances: 1, ..Default::default() }, light.clone());
        // add heavy-prefill requests arriving alongside
        let heavy = gen.trace(WorkloadKind::Hpld, 16, 0.0, 0);
        light.extend(heavy);
        let mixed = run_baseline(BaselineConfig { n_instances: 1, ..Default::default() }, light);
        let jct_light_only = light_only.jct_summary().mean;
        let jct_mixed_lights: f64 = {
            let xs: Vec<f64> = mixed
                .records
                .iter()
                .filter(|r| r.prompt_len <= 512)
                .map(|r| r.jct() as f64 / 1e3)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            jct_mixed_lights > jct_light_only * 1.3,
            "light requests should suffer from heavy co-runners: {jct_light_only} vs {jct_mixed_lights}"
        );
    }
}
