//! `tetri` — TetriInfer launcher.
//!
//! Subcommands:
//!   sim    — run a declarative experiment `Scenario` (flags and/or a JSON
//!            spec file; both resolve through `tetri_infer::api` and are
//!            bit-identical) and print TTFT/JCT/resource/perf-$ rows.
//!   sim optimize — goodput-per-dollar auto-search over a spec's
//!            `optimize` grid (shared-trace memoization + successive
//!            halving + early-abort pruning; see `tetri_infer::optimizer`).
//!   sim sweep    — the same grid run exhaustively (every cell, full
//!            length; the reference the optimizer's savings are
//!            measured against).
//!   serve  — real mode: load artifacts/ and serve a workload through the
//!            AOT'd model on the PJRT CPU client.
//!   info   — print the artifact manifest summary.
//!
//! (Hand-rolled arg parsing: no clap in the vendored environment. Unknown
//! flags and unknown policy spellings are hard errors, never silent
//! defaults; malformed numbers get a friendly message instead of a
//! panic.)

use tetri_infer::api::{
    class_keys, elastic_keys, fault_event_keys, fault_keys, optimize_keys, parse_class_flag,
    parse_decode_policy, parse_dispatch, parse_fault_flag, parse_link, parse_predictor,
    parse_prefill_policy, parse_prefix_flag, parse_telemetry_flag, parse_workload, phase_keys,
    prefix_keys, spec_keys, telemetry_keys, value_vocab,
    Driver as _, ElasticSpec, FaultPlanSpec, NullObserver, Observer, ProgressObserver, Registry,
    Scenario, TelemetrySpec,
};
use tetri_infer::metrics::vs_row_from;
use tetri_infer::optimizer;
use tetri_infer::sweep::{default_workers, results_csv, results_json, run_cells, SweepCell};
use tetri_infer::util::Json;
#[cfg(feature = "pjrt")]
use tetri_infer::runtime::Engine;
#[cfg(feature = "pjrt")]
use tetri_infer::serve::{ServeConfig, Server};
#[cfg(feature = "pjrt")]
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn usage() -> ! {
    eprintln!(
        "usage: tetri <sim|serve|info> [options]
  sim options (defaults in parentheses; flags override --spec values):
    --spec FILE.json      load a scenario spec (see scenarios/)
    --driver tetri|vllm|hybrid   system under test (tetri)
    --workload LPLD|LPHD|HPLD|HPHD|Mixed   (Mixed)
    --requests N          (128; with a phased spec, caps each phase)
    --rate R              arrivals/s, 0 = batch (0)
    --prefill N --decode N   instances (1/1; the vLLM comparison uses
                          min(prefill,decode) coupled instances — §5.1)
    --coupled N           coupled vLLM instances inside the cluster (0;
                          the hybrid-fleet study)
    --elastic-max N       elastic pool cap: autoscale instances up to N
                          (0 = static pool; thresholds take defaults)
    --link nvlink|roce|socket (roce)
    --prefill-policy fcfs|sjf|ljf|slo   (sjf; slo = tier + earliest
                          TTFT deadline first, needs --class / spec classes)
    --decode-policy greedy|rs|rd    (rd)
    --dispatch po2|random|imbalance|least  (po2)
    --predictor parallel|sequential|disabled  (parallel)
    --predictor-accuracy F  (0.749)
    --chunk-size N        (512)
    --sched-batch N       (16)
    --max-batch N         (128)
    --flip MS|off         flip idle threshold in ms (60000)
    --seed S              policy + trace seed (0)
    --trace-seed S        split the trace seed from --seed
    --name NAME           label echoed into reports
    --json PATH|-         write the run report (one JSON doc) to PATH
    --progress            print completion progress to stderr
    --no-records          drop per-request records: constant-memory mode
                          for scale runs (summaries stream through
                          log-bucketed histograms, quantiles ±~3%)
    --records             keep per-request records (overrides a spec that
                          ships records:false, e.g. scenarios/scale.json)
    --no-baseline         skip the vLLM comparison run (scale runs)
    --profile-events      print a per-event-kind wall-time table after the
                          run (observability only; the simulated trajectory
                          is identical either way)
    --class SPEC          add one workload class (repeatable; replaces the
                          spec's class table when given). SPEC is
                          key=value pairs, e.g.
                          name=chat,weight=0.5,tier=0,ttft_ms=300,tpot_ms=100
                          (also: rate_limit=R, burst=B, max_queue=N)
    --admission on|off    toggle the per-class entry admission gate
                          (token-bucket + queue-depth sheds)
    --fault SPEC          inject one fault event (repeatable; replaces the
                          spec's fault schedule when given). SPEC is
                          key=value pairs, e.g.
                          kind=restart,at_ms=150,instance=2,down_ms=300
                          (kinds: crash, restart, link_out, link_degrade,
                          straggler; also factor=F for the slow kinds)
    --prefix SPEC|off     stamp the trace with a shared-prefix population
                          and arm the per-prefill radix KV cache (replaces
                          the spec's prefix knob when given). SPEC is
                          key=value pairs, e.g.
                          n_prefixes=32,prefix_len=512,zipf=1.0
                          (also: cache_pages=N, block_tokens=N)
    --telemetry SPEC|off  arm the telemetry subsystem: per-phase latency
                          attribution + virtual-time series sampling
                          (replaces the spec's telemetry knob). SPEC is
                          key=value pairs, e.g.
                          sample_ms=50,max_samples=4096,trace=on
                          ('' = all defaults; off disarms a spec)
    --trace PATH          write a Perfetto/Chrome trace-event JSON of the
                          run to PATH — load it in ui.perfetto.dev
                          (implies --telemetry, arms span export)
    --series PATH         write the sampled virtual-time series CSV
                          (queue depths, phase populations, KV occupancy,
                          shed rate, ...) to PATH (implies --telemetry)
    --workers N           worker threads for sim optimize / sim sweep
                          (default: all cores; echoed in the startup line
                          and the JSON meta)
    --list                print registered drivers, scenario spec files,
                          and recognized spec keys/values, then exit
  sim optimize [sim options]:
    goodput-per-dollar auto-search over the spec's 'optimize' grid
    (n_prefill × n_decode × chunk × policy × link × elastic × driver).
    Needs --spec FILE.json with an 'optimize' block (see
    scenarios/optimize_mixed.json). Prints the Pareto frontier CSV, the
    recommended topology, and the search accounting; --json writes the
    machine-readable result.
  sim sweep [sim options]:
    run the spec's 'optimize' grid exhaustively (every cell at full
    length — no halving, no pruning) and print the results CSV; --json
    writes the labeled reports. A spec without an 'optimize' block runs
    as a single cell.
  serve options:
    --artifacts DIR       (default artifacts)
    --requests N          (default 8)
    --link nvlink|roce|socket  emulate transfer bandwidth (default: raw)
  info options:
    --artifacts DIR"
    );
    std::process::exit(2)
}

fn die(msg: &str) -> ! {
    eprintln!("error: {msg}\n");
    usage()
}

fn arg_val(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

/// Parse a numeric flag value with a friendly error instead of a panic.
fn num<T: std::str::FromStr>(key: &str, v: &str, expected: &str) -> T {
    v.parse().unwrap_or_else(|_| die(&format!("invalid value '{v}' for {key} (expected {expected})")))
}

/// Every `sim` flag and whether it consumes a value. Anything else
/// starting with `--` is rejected — a typo must never silently fall back
/// to a default.
const SIM_FLAGS: &[(&str, bool)] = &[
    ("--spec", true),
    ("--driver", true),
    ("--workload", true),
    ("--requests", true),
    ("--rate", true),
    ("--prefill", true),
    ("--decode", true),
    ("--coupled", true),
    ("--elastic-max", true),
    ("--link", true),
    ("--prefill-policy", true),
    ("--decode-policy", true),
    ("--dispatch", true),
    ("--predictor", true),
    ("--predictor-accuracy", true),
    ("--chunk-size", true),
    ("--sched-batch", true),
    ("--max-batch", true),
    ("--flip", true),
    ("--seed", true),
    ("--trace-seed", true),
    ("--name", true),
    ("--json", true),
    ("--progress", false),
    ("--no-records", false),
    ("--records", false),
    ("--no-baseline", false),
    ("--profile-events", false),
    ("--class", true),
    ("--admission", true),
    ("--fault", true),
    ("--prefix", true),
    ("--telemetry", true),
    ("--trace", true),
    ("--series", true),
    ("--workers", true),
    ("--list", false),
];

/// Collect every value of a repeatable flag, in order.
fn arg_vals(args: &[String], key: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == key {
            if let Some(v) = args.get(i + 1) {
                out.push(v.clone());
            }
            i += 2;
        } else {
            i += 1;
        }
    }
    out
}

fn validate_sim_flags(args: &[String]) {
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if !a.starts_with("--") {
            die(&format!("unexpected argument '{a}'"));
        }
        match SIM_FLAGS.iter().find(|(k, _)| k == a) {
            Some((_, true)) => {
                if i + 1 >= args.len() {
                    die(&format!("flag {a} needs a value"));
                }
                i += 2;
            }
            Some((_, false)) => i += 1,
            None => die(&format!("unknown flag '{a}'")),
        }
    }
}

/// Assemble the scenario: spec file (if any) as the base, then any
/// explicit flag overrides on top — so `--spec x.json` and the equivalent
/// flag spelling produce the identical `Scenario` (golden-tested).
fn scenario_from_args(args: &[String]) -> Scenario {
    let mut sc = match arg_val(args, "--spec") {
        Some(p) => Scenario::load(&p).unwrap_or_else(|e| die(&e)),
        None => Scenario::default(),
    };
    if let Some(v) = arg_val(args, "--name") {
        sc.name = v;
    }
    if let Some(v) = arg_val(args, "--driver") {
        sc.driver = v;
    }
    if let Some(v) = arg_val(args, "--workload") {
        if !sc.phases.is_empty() {
            die("--workload has no effect on a phased spec (edit the spec's phases instead)");
        }
        sc.workload = parse_workload(&v).unwrap_or_else(|e| die(&e));
    }
    if let Some(v) = arg_val(args, "--requests") {
        let n: usize = num("--requests", &v, "a request count");
        if sc.phases.is_empty() {
            sc.requests = n;
        } else {
            sc.clamp_requests(n); // smoke mode for phased specs
        }
    }
    if let Some(v) = arg_val(args, "--rate") {
        if !sc.phases.is_empty() {
            die("--rate has no effect on a phased spec (edit the spec's phases instead)");
        }
        sc.rate = num("--rate", &v, "arrivals/s");
    }
    if let Some(v) = arg_val(args, "--prefill") {
        sc.n_prefill = num("--prefill", &v, "an instance count");
    }
    if let Some(v) = arg_val(args, "--decode") {
        sc.n_decode = num("--decode", &v, "an instance count");
    }
    if let Some(v) = arg_val(args, "--coupled") {
        sc.n_coupled = num("--coupled", &v, "an instance count");
    }
    if let Some(v) = arg_val(args, "--elastic-max") {
        let n: usize = num("--elastic-max", &v, "a pool cap (0 = static)");
        // Override only the cap: a spec's tuned thresholds survive.
        sc.elastic = if n == 0 {
            None
        } else {
            let mut el = sc.elastic.unwrap_or_default();
            el.max_instances = n;
            Some(el)
        };
    }
    if let Some(v) = arg_val(args, "--link") {
        sc.link = parse_link(&v).unwrap_or_else(|e| die(&e));
    }
    if let Some(v) = arg_val(args, "--prefill-policy") {
        sc.prefill_policy = parse_prefill_policy(&v).unwrap_or_else(|e| die(&e));
    }
    if let Some(v) = arg_val(args, "--decode-policy") {
        sc.decode_policy = parse_decode_policy(&v).unwrap_or_else(|e| die(&e));
    }
    if let Some(v) = arg_val(args, "--dispatch") {
        sc.dispatch = parse_dispatch(&v).unwrap_or_else(|e| die(&e));
    }
    if let Some(v) = arg_val(args, "--predictor") {
        sc.predictor = parse_predictor(&v).unwrap_or_else(|e| die(&e));
    }
    if let Some(v) = arg_val(args, "--predictor-accuracy") {
        sc.predictor_accuracy = num("--predictor-accuracy", &v, "a fraction in [0,1]");
    }
    if let Some(v) = arg_val(args, "--chunk-size") {
        sc.chunk_size = num("--chunk-size", &v, "a token count");
    }
    if let Some(v) = arg_val(args, "--sched-batch") {
        sc.sched_batch = num("--sched-batch", &v, "a batch size");
    }
    if let Some(v) = arg_val(args, "--max-batch") {
        sc.max_batch = num("--max-batch", &v, "a batch size");
    }
    if let Some(v) = arg_val(args, "--flip") {
        sc.flip_idle_ms = if v == "off" {
            None
        } else {
            Some(num("--flip", &v, "an idle threshold in ms, or 'off'"))
        };
    }
    if let Some(v) = arg_val(args, "--seed") {
        let s: u64 = num("--seed", &v, "an integer seed");
        sc.seed = s;
        sc.trace_seed = s;
    }
    if let Some(v) = arg_val(args, "--trace-seed") {
        sc.trace_seed = num("--trace-seed", &v, "an integer seed");
    }
    match (args.iter().any(|a| a == "--records"), args.iter().any(|a| a == "--no-records")) {
        (true, true) => die("--records and --no-records are contradictory"),
        (true, false) => sc.records = true,
        (false, true) => sc.records = false,
        (false, false) => {}
    }
    if args.iter().any(|a| a == "--profile-events") {
        sc.profile_events = true;
    }
    // --class is repeatable: given at all, the flags replace the spec's
    // class table wholesale (mixing the two would be ambiguous).
    let class_flags = arg_vals(args, "--class");
    if !class_flags.is_empty() {
        if class_flags.len() > tetri_infer::slo::MAX_CLASSES {
            die(&format!(
                "{} --class flags given; class ids are u8, max {}",
                class_flags.len(),
                tetri_infer::slo::MAX_CLASSES
            ));
        }
        sc.classes =
            class_flags.iter().map(|s| parse_class_flag(s).unwrap_or_else(|e| die(&e))).collect();
    }
    if let Some(v) = arg_val(args, "--admission") {
        sc.admission = match v.as_str() {
            "on" => true,
            "off" => false,
            _ => die(&format!("--admission expects on|off, got '{v}'")),
        };
    }
    // --fault is repeatable: given at all, the flags replace the spec's
    // fault event list wholesale (recovery knobs keep the spec's values,
    // so `--spec chaos.json --fault ...` retunes the schedule without
    // silently resetting retry/backoff/watermark).
    let fault_flags = arg_vals(args, "--fault");
    if !fault_flags.is_empty() {
        let events = fault_flags
            .iter()
            .map(|s| parse_fault_flag(s).unwrap_or_else(|e| die(&e)))
            .collect();
        sc.faults.get_or_insert_with(FaultPlanSpec::default).events = events;
    }
    if let Some(v) = arg_val(args, "--prefix") {
        sc.prefix = parse_prefix_flag(&v).unwrap_or_else(|e| die(&e));
    }
    if let Some(v) = arg_val(args, "--telemetry") {
        sc.telemetry = parse_telemetry_flag(&v).unwrap_or_else(|e| die(&e));
    }
    // --trace / --series are output paths, but asking for either arms the
    // subsystem that produces them (a spec's sample_ms/max_samples survive).
    if args.iter().any(|a| a == "--trace") {
        sc.telemetry.get_or_insert_with(TelemetrySpec::default).trace = true;
    }
    if args.iter().any(|a| a == "--series") {
        sc.telemetry.get_or_insert_with(TelemetrySpec::default);
    }
    sc
}

/// `--list`: the registered drivers, every scenario spec file found, and
/// the recognized spec keys/value spellings. Keys come straight from the
/// spec's key consts and the value spellings from `api::value_vocab()`
/// (generated through the same `*_key` maps the parsers invert and
/// round-trip-tested against them), so the listing cannot drift in
/// spelling from what the parsers accept.
fn cmd_list() {
    println!("drivers: {}", Registry::builtin().driver_names().join(", "));
    let dir = tetri_infer::util::repo_root().join("scenarios");
    let mut specs: Vec<String> = std::fs::read_dir(&dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| p.extension().is_some_and(|x| x == "json"))
                .filter_map(|p| p.file_name().and_then(|n| n.to_str()).map(String::from))
                .collect()
        })
        .unwrap_or_default();
    specs.sort();
    println!("scenario specs in {} ({}):", dir.display(), specs.len());
    for s in &specs {
        println!("  {s}");
    }
    println!("spec keys: {}", spec_keys().join(", "));
    println!("  phases[] keys: {}", phase_keys().join(", "));
    println!("  elastic keys: {}", elastic_keys().join(", "));
    println!("  classes[] keys: {}", class_keys().join(", "));
    println!("  faults keys: {}", fault_keys().join(", "));
    println!("  faults.events[] keys: {}", fault_event_keys().join(", "));
    println!("  prefix keys: {}", prefix_keys().join(", "));
    println!("  telemetry keys: {}", telemetry_keys().join(", "));
    println!("  optimize keys: {}", optimize_keys().join(", "));
    for (key, vals) in value_vocab() {
        println!("{key} values: {}", vals.join(", "));
    }
}

fn cmd_sim(args: &[String]) {
    validate_sim_flags(args);
    if args.iter().any(|a| a == "--list") {
        cmd_list();
        return;
    }
    let mut sc = scenario_from_args(args);
    // The hybrid driver guarantees ≥ 1 coupled instance; normalize before
    // printing so the startup line describes the run that actually
    // happens (the driver applies the same default).
    if sc.driver == "hybrid" && sc.n_coupled == 0 {
        sc.n_coupled = 1;
    }
    // Self-describing runs: one line with every resolved knob, so any run
    // is reproducible from its log alone.
    println!("{}", sc.summary_line());

    let registry = Registry::builtin();

    let total = sc.total_requests();
    let mut progress;
    let mut null = NullObserver;
    let obs: &mut dyn Observer = if args.iter().any(|a| a == "--progress") {
        progress = ProgressObserver::new(total, (total / 10).max(1));
        &mut progress
    } else {
        &mut null
    };
    // Arrivals stream straight from the scenario's source: a run never
    // materializes its trace, so memory follows in-flight requests (the
    // baseline comparison below regenerates the identical stream from the
    // same trace seed). `run_with` tees in the telemetry observer when the
    // scenario arms it — otherwise this is exactly the raw driver path.
    let report = sc.run_with(obs).unwrap_or_else(|e| die(&e));
    // Each side's summaries are computed once (a full collect + sort over
    // the records when retained) and threaded through every row and the
    // JSON document below.
    let own = report.metrics.summaries();
    println!("{}", report.summary_line_with(&own));
    // Per-class SLO attainment + shed rows (only for classed runs).
    if !report.metrics.classes.is_empty() {
        for row in report.metrics.class_rows() {
            println!("{row}");
        }
    }
    // --profile-events: per-event-kind wall-time table, busiest first.
    if let Some(profile) = &report.metrics.event_profile {
        println!("event profile (host wall-clock, busiest kind first):");
        for row in profile.render() {
            println!("{row}");
        }
    }
    // Telemetry: "where did my latency go?" — the per-phase attribution,
    // plus the trace/series artifacts when their flags asked for them.
    if let Some(t) = &report.telemetry {
        println!(
            "latency attribution ({} spans, {} samples, {:.1} ms request time accounted):",
            t.spans,
            t.series.len(),
            t.accounted_ms()
        );
        for line in t.breakdown_lines() {
            println!("  {line}");
        }
        for c in &t.classes {
            let parts: Vec<String> = c
                .phases
                .iter()
                .map(|p| format!("{} p99 {:.1} ms", p.phase, p.p99_ms))
                .collect();
            println!("  class {} ({}): {}", c.class, c.name, parts.join(" | "));
        }
        if let Some(path) = arg_val(args, "--series") {
            std::fs::write(&path, t.series_csv())
                .unwrap_or_else(|e| die(&format!("cannot write {path}: {e}")));
            eprintln!("wrote {path}");
        }
        if let Some(path) = arg_val(args, "--trace") {
            let trace = t.trace.as_ref().expect("--trace arms span export");
            std::fs::write(&path, trace.dump())
                .unwrap_or_else(|e| die(&format!("cannot write {path}: {e}")));
            eprintln!("wrote {path} (open in ui.perfetto.dev)");
        }
    }
    // alloc-count builds report the steady-state allocation count; with
    // ALLOC_COUNT_STRICT=1 (the CI canary) a nonzero count is fatal.
    if cfg!(feature = "alloc-count") {
        let n = report.metrics.steady_allocs;
        println!("steady-state heap allocations (alloc-count): {n}");
        if n > 0 && std::env::var("ALLOC_COUNT_STRICT").as_deref() == Ok("1") {
            eprintln!(
                "error: {n} steady-state allocation(s) escaped the hot loop \
                 (zero-alloc invariant, see DESIGN.md §Performance)"
            );
            std::process::exit(1);
        }
    }

    // Paper's comparison setup (§5.1): TetriInfer's prefill+decode pair
    // uses twice the cards of one coupled vLLM instance; fairness is
    // restored through resource-usage time and perf/$. Hybrid runs get
    // the same coupled-only reference row. `--no-baseline` skips it
    // (scale runs pay for one system, not two).
    let want_base = (sc.driver == "tetri" || sc.driver == "hybrid")
        && !args.iter().any(|a| a == "--no-baseline");
    let base = if want_base {
        let base_sc = sc.baseline_counterpart();
        let base = registry
            .resolve(&base_sc)
            .unwrap_or_else(|e| die(&e))
            .run_source(base_sc.source().as_mut(), &mut NullObserver);
        let base_s = base.metrics.summaries();
        println!("{}", base.summary_line_with(&base_s));
        println!("{}", vs_row_from("TetriInfer vs vLLM", &own, &base_s));
        Some((base, base_s))
    } else {
        None
    };

    if let Some(path) = arg_val(args, "--json") {
        let doc = match &base {
            Some((b, base_s)) => report.comparison_json_with(&own, b, base_s),
            None => report.to_json_with(&own),
        };
        let text = doc.dump();
        if path == "-" {
            println!("{text}");
        } else {
            std::fs::write(&path, &text)
                .unwrap_or_else(|e| die(&format!("cannot write {path}: {e}")));
            eprintln!("wrote {path}");
        }
    }
}

/// Resolve `--workers` (default: every core), clamped to ≥ 1.
fn workers_from_args(args: &[String]) -> usize {
    arg_val(args, "--workers")
        .map(|v| num::<usize>("--workers", &v, "a worker count"))
        .unwrap_or_else(default_workers)
        .max(1)
}

/// Write a JSON doc to `--json PATH|-` when the flag is given.
fn emit_json(args: &[String], doc: &Json) {
    if let Some(path) = arg_val(args, "--json") {
        let text = doc.dump();
        if path == "-" {
            println!("{text}");
        } else {
            std::fs::write(&path, &text)
                .unwrap_or_else(|e| die(&format!("cannot write {path}: {e}")));
            eprintln!("wrote {path}");
        }
    }
}

/// `sim optimize`: the goodput-per-dollar auto-search (see
/// `tetri_infer::optimizer`). Deterministic for a given spec + seed at
/// any `--workers` count.
fn cmd_optimize(args: &[String]) {
    validate_sim_flags(args);
    let sc = scenario_from_args(args);
    let Some(grid) = sc.optimize.as_ref() else {
        die(
            "sim optimize needs a spec with an 'optimize' block \
             (see scenarios/optimize_mixed.json)",
        );
    };
    let workers = workers_from_args(args);
    println!("{}", sc.summary_line());
    println!(
        "optimize: grid={} cells | start_fraction={} keep_fraction={} min_attainment={} \
         prune={} | workers={workers}",
        optimizer::expand(&sc, grid).len(),
        grid.start_fraction,
        grid.keep_fraction,
        grid.min_attainment,
        grid.prune,
    );
    let res = optimizer::optimize(&sc, workers).unwrap_or_else(|e| die(&e));
    print!("{}", res.frontier_csv());
    match res.recommended_cell() {
        Some(r) => println!(
            "recommended: {} | goodput/$ {:.6} | goodput {:.3} rps | ${:.1}/hr",
            r.label,
            optimizer::value_of(&r.report.metrics),
            r.report.metrics.goodput_rps(),
            optimizer::cost_per_hr(&r.report.metrics),
        ),
        None => println!("recommended: none (no cell met the SLO floor)"),
    }
    let st = &res.stats;
    println!(
        "searched {} cells: rungs={} halving_discarded={} pruned_slo={} pruned_dominance={} \
         full_runs={} | {} events = {:.3} of exhaustive | {:.2}s wall ({:.1} cells/s)",
        st.grid_cells,
        st.rungs,
        st.halving_discarded,
        st.pruned_slo,
        st.pruned_dominance,
        st.full_runs,
        st.events_simulated,
        st.fraction_of_exhaustive(),
        st.wall_secs,
        st.cells_per_sec(),
    );
    emit_json(
        args,
        &Json::obj([
            ("scenario", sc.to_json()),
            ("workers", Json::from(workers)),
            ("result", res.to_json()),
        ]),
    );
}

/// `sim sweep`: the exhaustive reference — every grid cell at full
/// length through the sweep harness.
fn cmd_sweep(args: &[String]) {
    validate_sim_flags(args);
    let sc = scenario_from_args(args);
    let workers = workers_from_args(args);
    println!("{}", sc.summary_line());
    let cells = match sc.optimize.as_ref() {
        Some(grid) => optimizer::expand(&sc, grid),
        None => {
            let label = if sc.name.is_empty() { "cell".to_string() } else { sc.name.clone() };
            vec![SweepCell::new(label, sc.clone())]
        }
    };
    println!("sweep: grid={} cells (exhaustive, full length) | workers={workers}", cells.len());
    let results = run_cells(cells, workers);
    print!("{}", results_csv(&results));
    emit_json(
        args,
        &Json::obj([
            ("scenario", sc.to_json()),
            ("workers", Json::from(workers)),
            ("cells", results_json(&results)),
        ]),
    );
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &[String]) {
    eprintln!(
        "this build has no real-mode runtime: rebuild with `--features pjrt` \
         (requires the vendored xla bindings; sim mode is always available)"
    );
    std::process::exit(1);
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &[String]) {
    let dir = arg_val(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let n: usize = arg_val(args, "--requests")
        .map(|v| num("--requests", &v, "a request count"))
        .unwrap_or(8);
    let link = arg_val(args, "--link")
        .map(|l| parse_link(&l).unwrap_or_else(|e| die(&e)).to_link());
    let engine = Engine::load(&dir).unwrap_or_else(|e| {
        eprintln!("failed to load artifacts from {dir}: {e:#}\nrun `make artifacts` first");
        std::process::exit(1);
    });
    println!(
        "loaded artifacts: platform={} model d={} L={} heads={} ctx={}",
        engine.client.platform_name(),
        engine.manifest.model.d_model,
        engine.manifest.model.n_layers,
        engine.manifest.model.n_heads,
        engine.manifest.model.max_seq
    );
    let mut gen = WorkloadGen::new(0);
    let trace = gen.trace(WorkloadKind::Mixed, n, 0.0, 0);
    let cfg = ServeConfig { emulate_link: link, ..Default::default() };
    let report = Server::new(&engine, cfg).serve(trace, &mut gen).unwrap();
    let t = report.metrics.ttft_summary();
    let j = report.metrics.jct_summary();
    println!(
        "served {} requests | {} tokens | {:.2}s wall | {:.1} tok/s",
        report.metrics.records.len(),
        report.generated_tokens,
        report.wall_secs,
        report.generated_tokens as f64 / report.wall_secs
    );
    println!(
        "TTFT mean {:.1} ms p99 {:.1} | JCT mean {:.1} ms p99 {:.1} | chunks {} | decode iters {} | transferred {:.1} MB",
        t.mean, t.p99, j.mean, j.p99,
        report.prefill_chunks, report.decode_iters,
        report.transfer_bytes as f64 / 1e6
    );
}

#[cfg(not(feature = "pjrt"))]
fn cmd_info(_args: &[String]) {
    eprintln!("artifact inspection needs the `pjrt` feature (manifest loader lives in runtime/)");
    std::process::exit(1);
}

#[cfg(feature = "pjrt")]
fn cmd_info(args: &[String]) {
    let dir = arg_val(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    match tetri_infer::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts at {dir}:");
            println!(
                "  model: vocab={} d={} layers={} heads={} ctx={} chunk={}",
                m.model.vocab, m.model.d_model, m.model.n_layers, m.model.n_heads,
                m.model.max_seq, m.model.chunk
            );
            println!(
                "  decode: batch={} page={} pages={} max_pages/req={}",
                m.decode.batch, m.decode.page_size, m.decode.n_pages, m.decode.max_pages_per_req
            );
            println!(
                "  predictor: prompt={} buckets={} gran={} acc200={:?}",
                m.predictor.max_prompt, m.predictor.n_buckets, m.predictor.granularity,
                m.predictor_acc200
            );
        }
        Err(e) => {
            eprintln!("cannot load manifest: {e:#}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        // positional subcommands must peel off before cmd_sim's flag
        // validation (it rejects any non-`--` argument)
        Some("sim") => match args.get(1).map(String::as_str) {
            Some("optimize") => cmd_optimize(&args[2..]),
            Some("sweep") => cmd_sweep(&args[2..]),
            _ => cmd_sim(&args[1..]),
        },
        Some("serve") => cmd_serve(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        // `tetri --list` works top-level too (sugar for `sim --list`)
        Some("--list") => cmd_list(),
        _ => usage(),
    }
}
