//! `tetri` — TetriInfer launcher.
//!
//! Subcommands:
//!   sim    — run the TetriInfer cluster (and the vLLM baseline) on a
//!            workload with the calibrated cost model; print TTFT/JCT/
//!            resource/perf-$ comparisons.
//!   serve  — real mode: load artifacts/ and serve a workload through the
//!            AOT'd model on the PJRT CPU client.
//!   info   — print the artifact manifest summary.
//!
//! (Hand-rolled arg parsing: no clap in the vendored environment.)

use tetri_infer::baseline::{run_baseline, BaselineConfig};
use tetri_infer::coordinator::{run_cluster, ClusterConfig};
use tetri_infer::decode::DecodePolicy;
use tetri_infer::fabric::Link;
use tetri_infer::prefill::{DispatchPolicy, PrefillPolicy};
#[cfg(feature = "pjrt")]
use tetri_infer::runtime::Engine;
#[cfg(feature = "pjrt")]
use tetri_infer::serve::{ServeConfig, Server};
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn usage() -> ! {
    eprintln!(
        "usage: tetri <sim|serve|info> [options]
  sim options:
    --workload LPLD|LPHD|HPLD|HPHD|Mixed   (default Mixed)
    --requests N          (default 128)
    --rate R              arrivals/s, 0 = batch (default 0)
    --prefill N --decode N (default 1/1; baseline uses (N+N)/2... see docs)
    --link nvlink|roce|socket (default roce)
    --prefill-policy fcfs|sjf|ljf   --decode-policy greedy|rs|rd
    --dispatch po2|random|imbalance|least
    --seed S
  serve options:
    --artifacts DIR       (default artifacts)
    --requests N          (default 8)
    --link nvlink|roce    emulate transfer bandwidth (default: raw)
  info options:
    --artifacts DIR"
    );
    std::process::exit(2)
}

fn arg_val(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn parse_kind(s: &str) -> WorkloadKind {
    match s.to_ascii_uppercase().as_str() {
        "LPLD" => WorkloadKind::Lpld,
        "LPHD" => WorkloadKind::Lphd,
        "HPLD" => WorkloadKind::Hpld,
        "HPHD" => WorkloadKind::Hphd,
        "MIXED" => WorkloadKind::Mixed,
        _ => usage(),
    }
}

fn parse_link(s: &str) -> Link {
    match s {
        "nvlink" => Link::nvlink(),
        "roce" => Link::roce200(),
        "socket" => Link::indirect_socket(),
        _ => usage(),
    }
}

fn cmd_sim(args: &[String]) {
    let kind = parse_kind(&arg_val(args, "--workload").unwrap_or_else(|| "Mixed".into()));
    let n: usize = arg_val(args, "--requests").map(|v| v.parse().unwrap()).unwrap_or(128);
    let rate: f64 = arg_val(args, "--rate").map(|v| v.parse().unwrap()).unwrap_or(0.0);
    let n_prefill: usize = arg_val(args, "--prefill").map(|v| v.parse().unwrap()).unwrap_or(1);
    let n_decode: usize = arg_val(args, "--decode").map(|v| v.parse().unwrap()).unwrap_or(1);
    let seed: u64 = arg_val(args, "--seed").map(|v| v.parse().unwrap()).unwrap_or(0);
    let link = parse_link(&arg_val(args, "--link").unwrap_or_else(|| "roce".into()));
    let prefill_policy = match arg_val(args, "--prefill-policy").as_deref() {
        Some("fcfs") => PrefillPolicy::Fcfs,
        Some("ljf") => PrefillPolicy::Ljf,
        _ => PrefillPolicy::Sjf,
    };
    let decode_policy = match arg_val(args, "--decode-policy").as_deref() {
        Some("greedy") => DecodePolicy::Greedy,
        Some("rs") => DecodePolicy::ReserveStatic,
        _ => DecodePolicy::ReserveDynamic,
    };
    let dispatch = match arg_val(args, "--dispatch").as_deref() {
        Some("random") => DispatchPolicy::Random,
        Some("imbalance") => DispatchPolicy::Imbalance,
        Some("least") => DispatchPolicy::LeastLoad,
        _ => DispatchPolicy::PowerOfTwo,
    };

    let mut gen = WorkloadGen::new(seed);
    let trace = gen.trace(kind, n, rate, 0);

    let cfg = ClusterConfig {
        n_prefill,
        n_decode,
        prefill_policy,
        decode_policy,
        dispatch,
        link,
        seed,
        ..Default::default()
    };
    let tetri = run_cluster(cfg, trace.clone());
    // Paper's comparison setup (§5.1): TetriInfer's prefill+decode pair
    // uses twice the cards of one coupled vLLM instance; fairness is
    // restored through resource-usage time and perf/$.
    let base_n = n_prefill.min(n_decode).max(1);
    let base_cfg = BaselineConfig { n_instances: base_n, seed, ..Default::default() };
    let base = run_baseline(base_cfg, trace);

    println!("workload={} n={} rate={}/s", kind.name(), n, rate);
    let t = tetri.ttft_summary();
    let j = tetri.jct_summary();
    println!(
        "TetriInfer: TTFT mean {:.1} ms p99 {:.1} | JCT mean {:.1} ms p99 {:.1} | resource {:.1}s | flips {}",
        t.mean, t.p99, j.mean, j.p99, tetri.resource_seconds(), tetri.flips
    );
    let t = base.ttft_summary();
    let j = base.jct_summary();
    println!(
        "vLLM:       TTFT mean {:.1} ms p99 {:.1} | JCT mean {:.1} ms p99 {:.1} | resource {:.1}s",
        t.mean, t.p99, j.mean, j.p99, base.resource_seconds()
    );
    println!("{}", tetri.vs_row("TetriInfer vs vLLM", &base));
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &[String]) {
    eprintln!(
        "this build has no real-mode runtime: rebuild with `--features pjrt` \
         (requires the vendored xla bindings; sim mode is always available)"
    );
    std::process::exit(1);
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &[String]) {
    let dir = arg_val(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let n: usize = arg_val(args, "--requests").map(|v| v.parse().unwrap()).unwrap_or(8);
    let link = arg_val(args, "--link").map(|l| parse_link(&l));
    let engine = Engine::load(&dir).unwrap_or_else(|e| {
        eprintln!("failed to load artifacts from {dir}: {e:#}\nrun `make artifacts` first");
        std::process::exit(1);
    });
    println!(
        "loaded artifacts: platform={} model d={} L={} heads={} ctx={}",
        engine.client.platform_name(),
        engine.manifest.model.d_model,
        engine.manifest.model.n_layers,
        engine.manifest.model.n_heads,
        engine.manifest.model.max_seq
    );
    let mut gen = WorkloadGen::new(0);
    let trace = gen.trace(WorkloadKind::Mixed, n, 0.0, 0);
    let cfg = ServeConfig { emulate_link: link, ..Default::default() };
    let report = Server::new(&engine, cfg).serve(trace, &mut gen).unwrap();
    let t = report.metrics.ttft_summary();
    let j = report.metrics.jct_summary();
    println!(
        "served {} requests | {} tokens | {:.2}s wall | {:.1} tok/s",
        report.metrics.records.len(),
        report.generated_tokens,
        report.wall_secs,
        report.generated_tokens as f64 / report.wall_secs
    );
    println!(
        "TTFT mean {:.1} ms p99 {:.1} | JCT mean {:.1} ms p99 {:.1} | chunks {} | decode iters {} | transferred {:.1} MB",
        t.mean, t.p99, j.mean, j.p99,
        report.prefill_chunks, report.decode_iters,
        report.transfer_bytes as f64 / 1e6
    );
}

#[cfg(not(feature = "pjrt"))]
fn cmd_info(_args: &[String]) {
    eprintln!("artifact inspection needs the `pjrt` feature (manifest loader lives in runtime/)");
    std::process::exit(1);
}

#[cfg(feature = "pjrt")]
fn cmd_info(args: &[String]) {
    let dir = arg_val(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    match tetri_infer::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts at {dir}:");
            println!(
                "  model: vocab={} d={} layers={} heads={} ctx={} chunk={}",
                m.model.vocab, m.model.d_model, m.model.n_layers, m.model.n_heads,
                m.model.max_seq, m.model.chunk
            );
            println!(
                "  decode: batch={} page={} pages={} max_pages/req={}",
                m.decode.batch, m.decode.page_size, m.decode.n_pages, m.decode.max_pages_per_req
            );
            println!(
                "  predictor: prompt={} buckets={} gran={} acc200={:?}",
                m.predictor.max_prompt, m.predictor.n_buckets, m.predictor.granularity,
                m.predictor_acc200
            );
        }
        Err(e) => {
            eprintln!("cannot load manifest: {e:#}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("sim") => cmd_sim(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        _ => usage(),
    }
}
