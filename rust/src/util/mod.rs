//! Substrate utilities built from scratch (no external crates vendored for
//! these): deterministic RNG, summary statistics, and a JSON
//! parser/writer.

pub mod alloc;
pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;

pub use alloc::{cold_section, hot_allocs, ColdSection};
pub use bench::{bench_meta, merge_bench_sections};
pub use json::Json;
pub use rng::Pcg;
pub use stats::{percentile, summarize, Histogram, LogHist, Summary};

/// Locate the repository root by walking up from the current directory
/// until a `ROADMAP.md` is found (falling back to `.`). Lets the bench
/// binaries emit `BENCH_*.json` at the repo root whether cargo was invoked
/// from `rust/` (scripts/bench.sh) or from the root via
/// `--manifest-path`.
pub fn repo_root() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    loop {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return std::path::PathBuf::from(".");
        }
    }
}
