//! Substrate utilities built from scratch (no external crates vendored for
//! these): deterministic RNG, summary statistics, and a JSON parser.

pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Pcg;
pub use stats::{percentile, summarize, Histogram, Summary};
