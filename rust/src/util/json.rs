//! Minimal recursive-descent JSON parser **and writer** — enough for
//! artifacts/manifest.json and the BENCH_*.json perf baselines.
//!
//! Hand-rolled because the environment vendors no serde_json. Supports the
//! full JSON grammar except `\u` surrogate pairs (manifest content is
//! ASCII). `dump()` emits deterministic output (object keys are sorted by
//! the BTreeMap), so perf baselines diff cleanly across runs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["config", "model", "chunk"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Build an object from (key, value) pairs.
    pub fn obj<K: Into<String>>(pairs: impl IntoIterator<Item = (K, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// Serialize to a compact JSON string (deterministic key order).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Rust's {} prints the shortest roundtrip form
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    out.push(match e {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        b'n' => '\n',
                        b'r' => '\r',
                        b't' => '\t',
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        }
                        _ => return Err(self.err("bad escape")),
                    });
                }
                _ => {
                    // copy a run of plain bytes (utf-8 passes through)
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}], "c": {"d": 2}}"#).unwrap();
        assert_eq!(j.at(&["c", "d"]).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let j = Json::obj([
            ("name", Json::from("cluster bench")),
            ("events", Json::from(123456u64)),
            ("speedup", Json::from(2.25)),
            ("ok", Json::from(true)),
            ("rows", Json::from(vec![Json::from(1.0), Json::Null])),
            ("note", Json::from("line\nbreak \"quoted\"")),
        ]);
        let s = j.dump();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn dump_is_deterministic_and_sorted() {
        let a = Json::obj([("b", Json::from(1u64)), ("a", Json::from(2u64))]);
        assert_eq!(a.dump(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn dump_replaces_non_finite_with_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }
}
