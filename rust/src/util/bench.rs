//! Shared plumbing for the hand-rolled bench binaries: provenance
//! metadata (git SHA + wall timestamp, so `BENCH_*.json` baselines say
//! *which* commit on *which* day produced them — the bench-regression
//! gate keys on this) and a section-keyed read-modify-write merge so the
//! benches sharing one JSON file (`benches/engine.rs` and
//! `benches/cluster.rs` both own sections of `BENCH_cluster.json`) never
//! clobber or orphan each other's sections, however many times and in
//! whatever order they re-run.

use super::Json;

/// Provenance stamp for a bench document: `{"git_sha": ..., "unix_time":
/// ...}`. The SHA comes from `git rev-parse --short HEAD` (override or
/// supply via `BENCH_GIT_SHA` when git is unavailable — e.g. a CI tarball
/// checkout); `"unknown"` when neither source works.
pub fn bench_meta() -> Json {
    let sha = std::env::var("BENCH_GIT_SHA").ok().filter(|s| !s.is_empty()).unwrap_or_else(|| {
        std::process::Command::new("git")
            .args(["rev-parse", "--short", "HEAD"])
            .output()
            .ok()
            .filter(|o| o.status.success())
            .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "unknown".to_string())
    });
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    Json::obj([("git_sha", Json::from(sha)), ("unix_time", Json::from(unix_time))])
}

/// Merge `sections` into the JSON object at `path`, replacing only those
/// keys: existing sections written by other benches survive verbatim, and
/// re-running the same bench overwrites its own sections in place —
/// idempotent, no duplicates, no orphans. When the file doesn't exist the
/// document starts from `header` (e.g. `bench`/`schema` identity keys).
///
/// Panics on a present-but-unparseable or non-object file instead of
/// silently discarding a committed baseline.
pub fn merge_bench_sections(
    path: &std::path::Path,
    header: &[(&str, Json)],
    sections: Vec<(&'static str, Json)>,
) {
    let mut pairs: Vec<(String, Json)> = match std::fs::read_to_string(path) {
        Ok(s) => {
            let doc = Json::parse(&s).unwrap_or_else(|e| {
                panic!(
                    "{} exists but does not parse ({e}); refusing to overwrite the \
                     perf baseline — fix or delete the file first",
                    path.display()
                )
            });
            let map = doc.as_obj().unwrap_or_else(|| {
                panic!(
                    "{} is not a JSON object; refusing to overwrite the perf baseline",
                    path.display()
                )
            });
            map.iter()
                .filter(|(k, _)| !sections.iter().any(|(sk, _)| sk == k))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect()
        }
        Err(_) => header.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
    };
    pairs.extend(sections.into_iter().map(|(k, v)| (k.to_string(), v)));
    std::fs::write(path, Json::obj(pairs).dump())
        .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_meta_has_sha_and_time() {
        std::env::set_var("BENCH_GIT_SHA", "abc1234");
        let m = bench_meta();
        std::env::remove_var("BENCH_GIT_SHA");
        let obj = m.as_obj().expect("meta is an object");
        assert_eq!(obj.get("git_sha").and_then(|j| j.as_str()), Some("abc1234"));
        assert!(obj.get("unix_time").is_some());
    }

    #[test]
    fn merge_replaces_own_sections_and_keeps_others() {
        let dir = std::env::temp_dir().join(format!("bench_merge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let header = [("bench", Json::from("test")), ("schema", Json::from(1u64))];

        // fresh file: header + section
        merge_bench_sections(&path, &header, vec![("alpha", Json::from(1u64))]);
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.as_obj().unwrap().len(), 3);

        // another bench adds its own section; alpha survives
        merge_bench_sections(&path, &header, vec![("beta", Json::from(2u64))]);
        // re-running the first bench overwrites alpha in place — no
        // duplicates, beta untouched
        merge_bench_sections(&path, &header, vec![("alpha", Json::from(9u64))]);
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let obj = doc.as_obj().unwrap();
        assert_eq!(obj.len(), 4, "bench+schema+alpha+beta: {obj:?}");
        assert_eq!(obj.get("alpha").and_then(|j| j.as_usize()), Some(9));
        assert_eq!(obj.get("beta").and_then(|j| j.as_usize()), Some(2));
        std::fs::remove_file(&path).ok();
    }
}
