//! Steady-state allocation ledger (the `alloc-count` feature).
//!
//! The DES hot loop is supposed to be allocation-free once every pool has
//! reached its working size (DESIGN.md §Performance rule 5: "No
//! steady-state allocation per event"). This module makes that invariant
//! *checkable* instead of aspirational: with `--features alloc-count` a
//! counting [`GlobalAlloc`] wraps the system allocator and counts every
//! `alloc`/`realloc` made outside a [`cold_section`] scope. The engine
//! loop reads the counter at half-completion and again at loop exit;
//! the difference lands in `RunMetrics::steady_allocs` and the 100k
//! canary asserts it is zero (`ALLOC_COUNT_STRICT=1`).
//!
//! Cold sections mark work that is legitimately allocating — run setup,
//! fault handling, elastic scale-ups, arena growth, end-of-run folding —
//! via an RAII guard on a thread-local depth. Without the feature every
//! item here compiles to a no-op: zero-sized guard, constant-0 reads, no
//! global allocator override.

#[cfg(feature = "alloc-count")]
mod counting {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Allocations made at cold depth 0 ("hot" allocations), all threads.
    static HOT_ALLOCS: AtomicU64 = AtomicU64::new(0);

    thread_local! {
        /// Nesting depth of [`ColdSection`] guards on this thread.
        static COLD_DEPTH: Cell<u32> = const { Cell::new(0) };
    }

    /// Counting wrapper over the system allocator: every `alloc` and
    /// `realloc` outside a cold section bumps the global ledger. `dealloc`
    /// is free — releasing memory is never the invariant being policed.
    pub struct CountingAlloc;

    impl CountingAlloc {
        #[inline]
        fn note(&self) {
            // During thread teardown the TLS slot may already be gone;
            // treat that window as cold (teardown allocates legitimately).
            let cold = COLD_DEPTH.try_with(|d| d.get()).unwrap_or(1);
            if cold == 0 {
                HOT_ALLOCS.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    // SAFETY: pure pass-through to `System`; the ledger touches only an
    // atomic and a TLS cell, neither of which allocates.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            self.note();
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            self.note();
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static GLOBAL: CountingAlloc = CountingAlloc;

    /// RAII guard marking the enclosing scope as legitimately allocating.
    /// Nests; the thread is "hot" again once every guard has dropped.
    pub struct ColdSection(());

    impl ColdSection {
        pub(super) fn enter() -> Self {
            COLD_DEPTH.with(|d| d.set(d.get() + 1));
            ColdSection(())
        }
    }

    impl Drop for ColdSection {
        fn drop(&mut self) {
            COLD_DEPTH.with(|d| d.set(d.get() - 1));
        }
    }

    /// Total hot allocations so far, across all threads.
    pub fn hot_allocs() -> u64 {
        HOT_ALLOCS.load(Ordering::Relaxed)
    }
}

#[cfg(feature = "alloc-count")]
pub use counting::{hot_allocs, ColdSection};

/// Feature-off stand-ins: zero-sized guard, constant-0 counter, so call
/// sites need no `cfg` of their own.
#[cfg(not(feature = "alloc-count"))]
pub struct ColdSection(());

/// Hot-allocation ledger (always 0 without the `alloc-count` feature).
#[cfg(not(feature = "alloc-count"))]
pub fn hot_allocs() -> u64 {
    0
}

/// Enter a cold (legitimately-allocating) scope; hold the guard for its
/// duration. No-op without the `alloc-count` feature.
pub fn cold_section() -> ColdSection {
    #[cfg(feature = "alloc-count")]
    {
        ColdSection::enter()
    }
    #[cfg(not(feature = "alloc-count"))]
    {
        ColdSection(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_and_counter_are_always_callable() {
        let before = hot_allocs();
        {
            let _cold = cold_section();
            // allocations here never count, feature on or off
            let v: Vec<u64> = (0..64).collect();
            assert_eq!(v.len(), 64);
        }
        let after = hot_allocs();
        #[cfg(not(feature = "alloc-count"))]
        assert_eq!((before, after), (0, 0), "feature off: counter is pinned to 0");
        #[cfg(feature = "alloc-count")]
        assert_eq!(before, after, "cold-section allocations must not count");
    }

    #[cfg(feature = "alloc-count")]
    #[test]
    fn hot_allocations_are_counted() {
        let before = hot_allocs();
        let v: Vec<u64> = Vec::with_capacity(1024);
        assert!(v.capacity() >= 1024);
        assert!(hot_allocs() > before, "a hot allocation must bump the ledger");
    }
}
