//! Summary statistics for latency/throughput series (used by metrics and
//! every figure regenerator).

#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
    pub sum: f64,
}

/// Percentile by linear interpolation on a sorted copy.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let sum: f64 = s.iter().sum();
    Summary {
        n: s.len(),
        mean: sum / s.len() as f64,
        p50: percentile(&s, 0.5),
        p90: percentile(&s, 0.9),
        p99: percentile(&s, 0.99),
        min: s[0],
        max: *s.last().unwrap(),
        sum,
    }
}

/// Sub-buckets per octave in [`LogHist`]: 2^5 = 32, bounding relative
/// quantile error at 1/32 ≈ 3.2%.
const LOG_SUB_BITS: u32 = 5;
const LOG_SUB: usize = 1 << LOG_SUB_BITS;

/// Constant-memory streaming summary over non-negative integer samples
/// (the DES feeds it µs latencies): exact count / sum / min / max, plus a
/// log-bucketed histogram for quantiles with ≤ ~3.2% relative error.
/// Values below 32 land in exact unit buckets; above, each octave splits
/// into 32 sub-buckets. The bin vector grows on demand and tops out at a
/// couple of KB however many samples stream through — this is what lets a
/// million-request run drop per-request records entirely.
#[derive(Clone, Debug, Default)]
pub struct LogHist {
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
    bins: Vec<u64>,
}

impl LogHist {
    fn idx(v: u64) -> usize {
        if v < LOG_SUB as u64 {
            v as usize
        } else {
            let e = 63 - v.leading_zeros(); // v ∈ [2^e, 2^(e+1)), e ≥ 5
            let sub = ((v >> (e - LOG_SUB_BITS)) as usize) & (LOG_SUB - 1);
            LOG_SUB + ((e - LOG_SUB_BITS) as usize) * LOG_SUB + sub
        }
    }

    /// Lower/upper bound of bucket `idx` (upper exclusive; saturating at
    /// the very top of the u64 range, far beyond any latency).
    fn bounds(idx: usize) -> (u64, u64) {
        if idx < LOG_SUB {
            (idx as u64, idx as u64 + 1)
        } else {
            let e = LOG_SUB_BITS + ((idx - LOG_SUB) / LOG_SUB) as u32;
            let sub = ((idx - LOG_SUB) % LOG_SUB) as u64;
            let width = 1u64 << (e - LOG_SUB_BITS);
            let lo = (1u64 << e) + sub * width;
            (lo, lo.saturating_add(width))
        }
    }

    pub fn record(&mut self, v: u64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v as u128;
        let idx = Self::idx(v);
        if self.bins.len() <= idx {
            self.bins.resize(idx + 1, 0);
        }
        self.bins[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all recorded samples.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Exact mean (sum and count are exact; only quantiles approximate).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Bucket-midpoint value of the 0-based rank-`r` sample, clamped into
    /// the exact [min, max] envelope.
    fn value_at_rank(&self, r: u64) -> f64 {
        let mut seen = 0u64;
        for (i, &n) in self.bins.iter().enumerate() {
            seen += n;
            if n > 0 && seen > r {
                let (lo, hi) = Self::bounds(i);
                // overflow-safe midpoint of [lo, hi)
                let mid = (lo + (hi - 1 - lo) / 2) as f64;
                return mid.clamp(self.min as f64, self.max as f64);
            }
        }
        self.max as f64
    }

    /// Approximate quantile with the same linear-interpolation convention
    /// as [`percentile`] on sorted samples — interpolating between the
    /// two straddled ranks' bucket midpoints — so records-off summaries
    /// track the exact records path even at tiny sample counts (the
    /// residual error is the ≤ ~3.2% bucket width, not a rank-rounding
    /// jump between far-apart order statistics).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let pos = q.clamp(0.0, 1.0) * (self.count - 1) as f64;
        let lo_rank = pos.floor() as u64;
        let lo = self.value_at_rank(lo_rank);
        let frac = pos - lo_rank as f64;
        if frac == 0.0 {
            return lo;
        }
        lo + (self.value_at_rank(lo_rank + 1) - lo) * frac
    }

    /// A [`Summary`] with every field multiplied by `scale` (the metrics
    /// layer records µs and reports ms → scale 1e-3). Mean/min/max/sum are
    /// exact; p50/p90/p99 carry the ≤ ~3.2% bucket error.
    pub fn summary_scaled(&self, scale: f64) -> Summary {
        if self.count == 0 {
            return Summary::default();
        }
        Summary {
            n: self.count as usize,
            mean: self.mean() * scale,
            p50: self.quantile(0.5) * scale,
            p90: self.quantile(0.9) * scale,
            p99: self.quantile(0.99) * scale,
            min: self.min as f64 * scale,
            max: self.max as f64 * scale,
            sum: self.sum as f64 * scale,
        }
    }
}

/// Fixed-width histogram over [lo, hi) with n bins (overflow in last bin).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        Self { lo, hi, bins: vec![0; n] }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = if x < self.lo {
            0
        } else {
            (((x - self.lo) / (self.hi - self.lo) * n as f64) as usize).min(n - 1)
        };
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile(&s, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan_or_default() {
        assert_eq!(summarize(&[]).n, 0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn log_hist_exact_below_32_and_bounded_error_above() {
        // exact unit buckets below 32
        let mut h = LogHist::default();
        for v in 0..32u64 {
            h.record(v);
        }
        for v in 0..32u64 {
            let i = LogHist::idx(v);
            assert_eq!(LogHist::bounds(i), (v, v + 1));
        }
        // every value maps into a bucket containing it, width ≤ v/32
        for v in [32u64, 33, 63, 64, 1_000, 65_535, 1_000_000, u64::MAX / 2] {
            let (lo, hi) = LogHist::bounds(LogHist::idx(v));
            assert!(lo <= v && v < hi, "{v} outside [{lo},{hi})");
            assert!(hi - lo <= (v / 32).max(1), "{v}: bucket too wide ({lo},{hi})");
        }
    }

    #[test]
    fn log_hist_summary_tracks_exact_summary() {
        let mut h = LogHist::default();
        let mut xs = Vec::new();
        // deterministic skewed series, like a latency distribution
        let mut v: u64 = 17;
        for i in 0..10_000u64 {
            v = v.wrapping_mul(6364136223846793005).wrapping_add(i) % 5_000_000;
            h.record(v);
            xs.push(v as f64);
        }
        let exact = summarize(&xs);
        let approx = h.summary_scaled(1.0);
        assert_eq!(approx.n, exact.n);
        assert!((approx.mean - exact.mean).abs() < 1e-6, "mean is exact");
        assert_eq!(approx.min, exact.min);
        assert_eq!(approx.max, exact.max);
        for (a, e) in [(approx.p50, exact.p50), (approx.p90, exact.p90), (approx.p99, exact.p99)] {
            assert!((a / e - 1.0).abs() < 0.04, "quantile {a} vs {e}");
        }
    }

    #[test]
    fn log_hist_quantiles_interpolate_like_percentile() {
        // two far-apart samples: nearest-rank would report ~1e6 for p50;
        // interpolation must land near the exact percentile() value
        let mut h = LogHist::default();
        h.record(1_000);
        h.record(1_000_000);
        let exact = percentile(&[1_000.0, 1_000_000.0], 0.5);
        let got = h.quantile(0.5);
        assert!((got / exact - 1.0).abs() < 0.04, "{got} vs {exact}");
        assert_eq!(h.quantile(0.0), 1_000.0);
        assert_eq!(h.quantile(1.0), 1_000_000.0);
    }

    #[test]
    fn log_hist_empty_and_single() {
        let h = LogHist::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.summary_scaled(1.0).n, 0);
        let mut h = LogHist::default();
        h.record(12_345);
        let s = h.summary_scaled(1e-3);
        assert_eq!(s.n, 1);
        assert!((s.mean - 12.345).abs() < 1e-9);
        assert_eq!(s.min, s.max);
        assert!((s.p50 - 12.345).abs() / 12.345 < 0.04);
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.99);
        h.add(100.0); // overflow clamps to last bin
        h.add(-5.0); // underflow clamps to first
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total(), 4);
    }
}
