//! Summary statistics for latency/throughput series (used by metrics and
//! every figure regenerator).

#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
    pub sum: f64,
}

/// Percentile by linear interpolation on a sorted copy.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let sum: f64 = s.iter().sum();
    Summary {
        n: s.len(),
        mean: sum / s.len() as f64,
        p50: percentile(&s, 0.5),
        p90: percentile(&s, 0.9),
        p99: percentile(&s, 0.99),
        min: s[0],
        max: *s.last().unwrap(),
        sum,
    }
}

/// Fixed-width histogram over [lo, hi) with n bins (overflow in last bin).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        Self { lo, hi, bins: vec![0; n] }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = if x < self.lo {
            0
        } else {
            (((x - self.lo) / (self.hi - self.lo) * n as f64) as usize).min(n - 1)
        };
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile(&s, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan_or_default() {
        assert_eq!(summarize(&[]).n, 0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.99);
        h.add(100.0); // overflow clamps to last bin
        h.add(-5.0); // underflow clamps to first
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total(), 4);
    }
}
