//! Deterministic PCG-XSH-RR 64/32 RNG + distribution helpers.
//!
//! Hand-rolled because the environment vendors no `rand` crate; the DES and
//! workload generators need *reproducible* streams anyway (every figure in
//! EXPERIMENTS.md is regenerated from a fixed seed).

#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-instance RNGs).
    pub fn fork(&mut self, stream: u64) -> Pcg {
        Pcg::with_stream(self.next_u64(), stream.wrapping_mul(2654435761).wrapping_add(1))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — hi must be > lo.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + (self.f64() * (hi - lo) as f64) as u64
    }

    /// Pick a uniformly random element index for a slice of length n.
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with the given *median* and log-space sigma.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.normal()).exp()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Exponential inter-arrival with the given rate (events per unit).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Pcg::new(13);
        let n = 50_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(128.0, 0.9)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[n / 2];
        assert!((med / 128.0 - 1.0).abs() < 0.1, "{med}");
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Pcg::new(17);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg::new(19);
        for _ in 0..10_000 {
            let x = r.range(5, 10);
            assert!((5..10).contains(&x));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg::new(3);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
