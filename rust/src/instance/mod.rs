//! The instance layer: first-class serving instances with an explicit
//! role state machine, shared by every DES driver.
//!
//! Before this module existed, `PrefillInst` and `DecodeInst` were
//! private structs inside the 900-line `coordinator/cluster.rs` monolith
//! and `CoupledInst` was a private struct inside `baseline/mod.rs`, each
//! with its iteration mechanics inlined into the driver's event handlers.
//! Now each role owns its scheduler/chunker/KV state here, behind the
//! [`InstanceRole`] trait for load reporting and drain checks, and the
//! drivers are policy glue (routing, two-level scheduling, flip/scale
//! decisions) over [`InstancePool`] + `sim::EngineCore`.
//!
//! Role state machine (one [`Instance`] slot moves through it):
//!
//! ```text
//!            ┌────────────────────────────────────────────────┐
//!            │                 drain_to = None                │
//!            │   Prefill ⇄ (Flipping) ⇄ Decode       Coupled  │
//!            └────────┬───────────────────┬──────────────┬────┘
//!    begin_drain      │                   │              │
//!            ┌────────▼───────────────────▼──────────────▼────┐
//!            │ Draining{to}: same role state, no new work      │
//!            └────────┬───────────────────────────────────────┘
//!   drained           │ DrainTarget::Flip(role) → Flipping{to}
//!                     │ DrainTarget::Retire     → Retired
//!            ┌────────▼────────┐      ┌─────────┐
//!            │ Flipping { to } │ ───▶ │ fresh    │ (epoch bumped on
//!            └─────────────────┘      │ role     │  every role exit)
//!                                     └─────────┘
//! ```
//!
//! "Draining" is represented as the live role state plus a `drain_to`
//! target rather than a wrapper variant, so the instance keeps serving
//! its in-flight work with zero indirection while the pool stops routing
//! new work to it. Epochs guard in-flight references (KV releases, stale
//! transfers) against instances that left their role and came back.

pub mod coupled;
pub mod decode;
pub mod pool;
pub mod prefill;

pub use coupled::{CoupledInst, CoupledIterStats};
pub use decode::{swapin_charge, DecodeInst, DecodeIterStats};
pub use pool::{DrainTarget, Instance, InstancePool, InstanceState};
pub use prefill::PrefillInst;

use crate::kvcache::PagedKvCache;
use crate::types::{Role, Us};

/// What every role exposes to the pool and the drivers' policy glue:
/// identity, load reporting, and drain status. Role-specific mechanics
/// (chunk slicing, continuous batching, mixed iterations) stay on the
/// concrete types.
pub trait InstanceRole {
    /// Which role this state serves.
    fn role(&self) -> Role;

    /// Scheduling load in role-specific units (prompt tokens for prefill,
    /// jobs for decode, the blended token score for coupled). Routing
    /// policies compare loads *within* a role; cross-role comparisons are
    /// the hybrid router's explicit business.
    fn load(&self) -> u64;

    /// An iteration is currently in flight.
    fn busy(&self) -> bool;

    /// No queued and no in-flight work: safe to flip or retire.
    fn drained(&self) -> bool;

    /// Virtual time of the last iteration start/end (idleness input for
    /// flip and scale-down policies).
    fn last_active(&self) -> Us;

    /// The KV pool this role owns, if any (decode and coupled do; prefill
    /// tracks residency as a counter, not pages).
    fn kv(&self) -> Option<&PagedKvCache>;
}
