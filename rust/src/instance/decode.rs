//! The decode role (§3.4): continuous batching over the paged KV pool.
//! Moved out of `coordinator/cluster.rs`; the iteration mechanics (admit,
//! step, swap pricing) live here, the driver only schedules and observes.

use crate::costmodel::CostModel;
use crate::decode::{DecodePolicy, DecodeScheduler};
use crate::kvcache::PagedKvCache;
use crate::types::{ReqId, Role, Us};

use super::InstanceRole;

pub struct DecodeInst {
    pub sched: DecodeScheduler,
    pub kv: PagedKvCache,
    pub busy: bool,
    /// Completions computed at iteration start, recorded at iteration end
    /// (buffer reused across iterations).
    pub pending_done: Vec<ReqId>,
    pub last_active: Us,
}

/// One priced decode iteration, ready to schedule and observe.
pub struct DecodeIterStats {
    pub batch: u32,
    pub kv_tokens: u64,
    pub dur: Us,
}

impl DecodeInst {
    pub fn new(policy: DecodePolicy, granularity: u32, max_batch: u32, kv_pages: u32) -> Self {
        DecodeInst {
            sched: DecodeScheduler::new(policy, granularity, max_batch),
            kv: PagedKvCache::new(kv_pages.max(2), 16),
            busy: false,
            pending_done: Vec::new(),
            last_active: 0,
        }
    }

    /// Run one continuous-batching iteration's effects now (admission,
    /// token generation, preemption) and price it; the driver exposes the
    /// effects at IterDone. Returns `None` when busy or nothing is
    /// resident.
    pub fn begin_iteration(&mut self, cost: &CostModel, now: Us) -> Option<DecodeIterStats> {
        if self.busy {
            return None;
        }
        let paged_in = self.sched.admit(&mut self.kv);
        if self.sched.n_resident() == 0 {
            return None;
        }
        let batch = self.sched.n_resident() as u32;
        let kv_tokens = self.sched.running_kv_tokens();
        self.pending_done.clear();
        let swapped_out = self.sched.step(&mut self.kv, &mut self.pending_done);
        // preemption transitions happened inside step(): fail loudly on
        // any page-accounting corruption before the iteration is priced
        debug_assert!(self.kv.check_invariants().is_ok());
        // Iteration cost: compute + any PCIe swap traffic this iteration
        // (victim page-out now, victim page-in when it re-admits).
        let dur = cost.decode_iter_us(batch, kv_tokens)
            + cost.swap_us(swapped_out)
            + cost.swap_us(swapin_charge(paged_in, &self.sched));
        self.busy = true;
        self.last_active = now;
        Some(DecodeIterStats { batch, kv_tokens, dur })
    }

    /// Iteration completed: hand the completion buffer to the driver.
    /// Return it via [`DecodeInst::return_done_buf`] so the next
    /// iteration reuses its capacity.
    pub fn end_iteration(&mut self, now: Us) -> Vec<ReqId> {
        self.busy = false;
        self.last_active = now;
        std::mem::take(&mut self.pending_done)
    }

    pub fn return_done_buf(&mut self, buf: Vec<ReqId>) {
        self.pending_done = buf;
    }

    /// Crash harvest: every request whose decode state dies with this
    /// instance — all scheduler jobs plus completions buffered inside an
    /// in-flight iteration whose DecodeIterDone will now be epoch-dropped
    /// (their final tokens were never surfaced). The paged KV dies with
    /// the instance; recovery re-prefills from scratch.
    pub fn harvest_crashed(&mut self) -> Vec<ReqId> {
        let mut ids = self.sched.drain_all();
        ids.extend(self.pending_done.drain(..));
        self.busy = false;
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

/// Swap-in charge: re-admitted (previously swapped) jobs pay the PCIe
/// fetch; fresh admissions' KV arrived over the fabric (or was produced
/// locally by a coupled prefill) and is charged there. We approximate by
/// charging swap cost only when the scheduler has swap history.
///
/// This is the single copy of what used to be two identical helpers —
/// `paged_in_swapins` in the cluster driver and `paged_in_swapped` in the
/// baseline. (Kept as a free function for the ablation bench to
/// override.)
pub fn swapin_charge(paged_in: u64, sched: &DecodeScheduler) -> u64 {
    if sched.running_has_swap_history() {
        paged_in
    } else {
        0
    }
}

impl InstanceRole for DecodeInst {
    fn role(&self) -> Role {
        Role::Decode
    }

    fn load(&self) -> u64 {
        self.sched.total_jobs() as u64
    }

    fn busy(&self) -> bool {
        self.busy
    }

    fn drained(&self) -> bool {
        !self.busy && self.sched.total_jobs() == 0
    }

    fn last_active(&self) -> Us {
        self.last_active
    }

    fn kv(&self) -> Option<&PagedKvCache> {
        Some(&self.kv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::DecodeJob;
    use crate::types::{ReqMeta, TaskType};

    fn job(id: u64, plen: u32, dlen: u32) -> DecodeJob {
        let meta = ReqMeta {
            id,
            task: TaskType::Chat,
            class: 0,
            arrival: 0,
            prompt_len: plen,
            predicted: None,
            prefix: None,
        };
        DecodeJob::new(meta, dlen)
    }

    fn inst() -> DecodeInst {
        DecodeInst::new(DecodePolicy::Greedy, 200, 128, 64)
    }

    #[test]
    fn iteration_lifecycle_generates_and_completes() {
        let cost = CostModel::default();
        let mut d = inst();
        assert!(d.begin_iteration(&cost, 0).is_none(), "no work yet");
        d.sched.enqueue(job(0, 10, 1));
        let st = d.begin_iteration(&cost, 5).expect("one job resident");
        assert_eq!(st.batch, 1);
        assert!(st.dur > 0 && d.busy);
        assert!(d.begin_iteration(&cost, 6).is_none(), "busy instances refuse");
        let done = d.end_iteration(9);
        assert_eq!(done, vec![0], "single-token decode finishes in one iteration");
        assert_eq!(d.last_active, 9);
        assert!(InstanceRole::drained(&d));
        d.return_done_buf(done);
    }

    #[test]
    fn swapin_charge_requires_swap_history() {
        let mut d = inst();
        d.sched.enqueue(job(0, 10, 5));
        d.sched.admit(&mut d.kv);
        assert_eq!(swapin_charge(64, &d.sched), 0, "fresh admissions ride the fabric");
    }

    #[test]
    fn harvest_crashed_includes_iteration_buffered_completions() {
        let mut d = inst();
        d.sched.enqueue(job(0, 10, 1));
        d.sched.enqueue(job(1, 10, 5));
        // job 0 completes *inside* the iteration: it leaves the scheduler
        // and sits in pending_done until DecodeIterDone — which a crash
        // epoch-drops, so harvest must still surface it
        let _ = d.begin_iteration(&CostModel::default(), 0).unwrap();
        assert_eq!(d.pending_done, vec![0]);
        let lost = d.harvest_crashed();
        assert_eq!(lost, vec![0, 1]);
        assert_eq!(d.sched.total_jobs(), 0);
        assert!(InstanceRole::drained(&d));
    }

    #[test]
    fn drained_reflects_queued_jobs() {
        let mut d = inst();
        assert!(InstanceRole::drained(&d));
        d.sched.enqueue(job(0, 10, 5));
        assert!(!InstanceRole::drained(&d), "waiting jobs block draining");
        assert_eq!(InstanceRole::load(&d), 1);
    }
}
