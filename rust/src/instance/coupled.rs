//! The coupled role: vanilla-vLLM continuous batching where each
//! iteration mixes fixed-batch whole-prompt prefills with every running
//! decode (§5.2.1 semantics — the system whose interference §2.2
//! measures). Moved out of `baseline/mod.rs`; the same type now also
//! serves inside the hybrid cluster, where coupled and disaggregated
//! instances share one engine and one arena.

use std::collections::VecDeque;

use crate::costmodel::CostModel;
use crate::decode::{DecodeJob, DecodePolicy, DecodeScheduler};
use crate::kvcache::PagedKvCache;
use crate::types::{ReqId, ReqMeta, Request, Role, Us};

use super::{swapin_charge, InstanceRole};

pub struct CoupledInst {
    /// Arrived, not yet prefilled (arena slots).
    pub waiting: VecDeque<ReqId>,
    /// Prompt tokens across `waiting`, maintained incrementally (the
    /// arrival router's O(1) load input).
    pub waiting_tokens: u64,
    /// Decode-side state (greedy admission = vLLM's policy). We reuse the
    /// decode scheduler with jobs that were prefilled locally.
    pub dec: DecodeScheduler,
    pub kv: PagedKvCache,
    pub busy: bool,
    /// Prefilled this iteration — slot buffer reused across iterations.
    pub pending_prefilled: Vec<ReqId>,
    /// Completed this iteration — slot buffer reused across iterations.
    pub pending_done: Vec<ReqId>,
    pub last_active: Us,
}

/// One priced mixed iteration, ready to schedule and observe. The driver
/// fires `on_chunk` for the prefill side and `on_decode_iter` for the
/// decode side, each only when non-empty.
pub struct CoupledIterStats {
    pub prefill_tokens: u32,
    pub batch: u32,
    pub kv_tokens: u64,
    pub dur: Us,
}

impl CoupledInst {
    pub fn new(kv_pages: u32) -> Self {
        CoupledInst {
            waiting: VecDeque::new(),
            waiting_tokens: 0,
            // residency is memory-bound, not batch-bound: the fixed batch
            // caps the per-iteration *step window* (see begin_iteration),
            // not how many requests hold pages.
            dec: DecodeScheduler::new(DecodePolicy::Greedy, 200, u32::MAX),
            kv: PagedKvCache::new(kv_pages.max(2), 16),
            busy: false,
            pending_prefilled: Vec::new(),
            pending_done: Vec::new(),
            last_active: 0,
        }
    }

    /// The arrival router's load score: waiting prompt tokens plus a
    /// fixed per-resident-job charge.
    pub fn route_load(&self) -> u64 {
        self.waiting_tokens + self.dec.total_jobs() as u64 * 64
    }

    /// Accept a routed request into the waiting line.
    pub fn enqueue(&mut self, slot: ReqId, prompt_len: u32) {
        self.waiting.push_back(slot);
        self.waiting_tokens += prompt_len as u64;
    }

    /// Run one mixed iteration's effects now and price it: (a)
    /// fixed-batch prefill — wait for `prefill_batch` prompts, then
    /// prefill them all in one iteration (greedy memory admission;
    /// partial batches run only when `more_arrivals` is false or the
    /// decode side is empty), and (b) decodes riding the same iteration,
    /// capped at the *fixed* batch `fixed_batch` (FCFS window over
    /// resident jobs — vanilla vLLM semantics). Returns `None` when busy
    /// or there is nothing to do.
    pub fn begin_iteration(
        &mut self,
        requests: &[Request],
        cost: &CostModel,
        prefill_batch: usize,
        fixed_batch: u32,
        more_arrivals: bool,
        now: Us,
    ) -> Option<CoupledIterStats> {
        if self.busy {
            return None;
        }
        self.pending_prefilled.clear();
        self.pending_done.clear();
        let mut prefill_tokens = 0u32;
        let batch_ready = self.waiting.len() >= prefill_batch
            || (!self.waiting.is_empty() && (!more_arrivals || self.dec.total_jobs() == 0));
        if batch_ready {
            while self.pending_prefilled.len() < prefill_batch {
                let Some(&slot) = self.waiting.front() else { break };
                let plen = requests[slot as usize].prompt_len;
                if !self.kv.can_fit(slot, plen + 1) {
                    break; // head-of-line block: vLLM stalls prefill on memory
                }
                self.waiting.pop_front();
                self.waiting_tokens -= plen as u64;
                self.kv.alloc(slot, plen + 1).expect("can_fit checked");
                prefill_tokens += plen;
                self.pending_prefilled.push(slot);
            }
        }
        let paged_in = self.dec.admit(&mut self.kv);
        let window = (fixed_batch as usize).min(self.dec.n_resident());
        let batch = window as u32;
        let kv_tokens: u64 = self.dec.running()[..window]
            .iter()
            .map(|j| j.kv_tokens() as u64)
            .sum();
        if self.pending_prefilled.is_empty() && batch == 0 {
            return None;
        }
        let swapped_out = self.dec.step_n(&mut self.kv, window, &mut self.pending_done);
        // preemption transitions happened inside step_n(): fail loudly on
        // any page-accounting corruption before the iteration is priced
        debug_assert!(self.kv.check_invariants().is_ok());
        let dur = cost.mixed_iter_us(prefill_tokens, batch, kv_tokens)
            + cost.swap_us(swapped_out + swapin_charge(paged_in, &self.dec));

        // Prefilled requests become decode jobs at iteration end. Their
        // pages were allocated above, so they enter the running batch
        // directly (the scheduler keeps its aggregates in sync).
        for &slot in &self.pending_prefilled {
            let req = &requests[slot as usize];
            // scheduler-facing meta keyed by the arena slot, not the
            // original request id
            let meta = ReqMeta { id: slot, ..req.meta() };
            let mut job = DecodeJob::new(meta, req.decode_len);
            job.generated = 1;
            self.dec.inject_running(job);
        }
        self.busy = true;
        self.last_active = now;
        Some(CoupledIterStats { prefill_tokens, batch, kv_tokens, dur })
    }

    /// Iteration completed: hand both slot buffers (prefilled, done) to
    /// the driver. Return them via [`CoupledInst::return_bufs`] so the
    /// next iteration reuses their capacity.
    pub fn end_iteration(&mut self, now: Us) -> (Vec<ReqId>, Vec<ReqId>) {
        self.busy = false;
        self.last_active = now;
        (
            std::mem::take(&mut self.pending_prefilled),
            std::mem::take(&mut self.pending_done),
        )
    }

    pub fn return_bufs(&mut self, prefilled: Vec<ReqId>, done: Vec<ReqId>) {
        self.pending_prefilled = prefilled;
        self.pending_done = done;
    }

    /// Remove a request from the running batch and release its pages
    /// (single-token requests that finish at prefill).
    pub fn drop_running(&mut self, slot: ReqId) {
        if self.dec.remove_running(slot).is_some() {
            self.kv.release(slot);
        }
    }

    /// Crash harvest: every request whose state dies with this instance —
    /// the waiting line, all decode-scheduler jobs, and completions
    /// buffered inside an in-flight iteration whose CoupledIterDone will
    /// now be epoch-dropped (their final tokens were never surfaced).
    /// In-flight prefilled slots were already injected into the decode
    /// scheduler, so they arrive via `drain_all`; ids are deduped. Load
    /// tallies reset to zero — nothing stays attributed to the dead
    /// incarnation.
    pub fn harvest_crashed(&mut self) -> Vec<ReqId> {
        let mut ids: Vec<ReqId> = self.waiting.drain(..).collect();
        self.waiting_tokens = 0;
        ids.extend(self.dec.drain_all());
        ids.extend(self.pending_done.drain(..));
        self.pending_prefilled.clear();
        self.busy = false;
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

impl InstanceRole for CoupledInst {
    fn role(&self) -> Role {
        Role::Coupled
    }

    fn load(&self) -> u64 {
        self.route_load()
    }

    fn busy(&self) -> bool {
        self.busy
    }

    fn drained(&self) -> bool {
        !self.busy && self.waiting.is_empty() && self.dec.total_jobs() == 0
    }

    fn last_active(&self) -> Us {
        self.last_active
    }

    fn kv(&self) -> Option<&PagedKvCache> {
        Some(&self.kv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn arena(specs: &[(u32, u32)]) -> Vec<Request> {
        specs
            .iter()
            .enumerate()
            .map(|(i, &(plen, dlen))| Request {
                id: i as u64,
                task: TaskType::Chat,
                class: 0,
                arrival: 0,
                prompt_len: plen,
                decode_len: dlen,
                predicted: None,
                prefix: None,
            })
            .collect()
    }

    #[test]
    fn partial_batches_wait_for_more_arrivals() {
        let cost = CostModel::default();
        let reqs = arena(&[(100, 5), (100, 5)]);
        let mut c = CoupledInst::new(64);
        c.enqueue(0, 100);
        c.enqueue(1, 100);
        // batch of 4 not filled, more arrivals coming, decodes running →
        // the fixed batch waits
        c.kv.alloc(9, 10).unwrap();
        let mut j = DecodeJob::new(ReqMeta { id: 9, ..reqs[0].meta() }, 5);
        j.generated = 1;
        c.dec.inject_running(j);
        let st = c.begin_iteration(&reqs, &cost, 4, 16, true, 0).expect("decode side runs");
        assert_eq!(st.prefill_tokens, 0, "partial prefill batch must wait");
        assert_eq!(st.batch, 1);
        c.end_iteration(1);
        // last arrival seen: the partial batch may now run
        let st = c.begin_iteration(&reqs, &cost, 4, 16, false, 2).expect("batch runs");
        assert_eq!(st.prefill_tokens, 200);
        assert_eq!(c.waiting_tokens, 0);
    }

    #[test]
    fn iteration_injects_prefilled_jobs_into_the_batch() {
        let cost = CostModel::default();
        let reqs = arena(&[(50, 3), (60, 1)]);
        let mut c = CoupledInst::new(64);
        c.enqueue(0, 50);
        c.enqueue(1, 60);
        let st = c.begin_iteration(&reqs, &cost, 2, 16, false, 0).unwrap();
        assert_eq!(st.prefill_tokens, 110);
        let (prefilled, done) = c.end_iteration(5);
        assert_eq!(prefilled, vec![0, 1]);
        assert!(done.is_empty());
        assert_eq!(c.dec.n_resident(), 2, "prefilled prompts join the running batch");
        // slot 1 is a single-token request: the driver drops it at
        // iteration end
        c.drop_running(1);
        assert_eq!(c.dec.n_resident(), 1);
        c.return_bufs(prefilled, done);
        assert!(!InstanceRole::drained(&c), "slot 0 still decoding");
    }

    #[test]
    fn harvest_crashed_collects_waiting_and_running() {
        let cost = CostModel::default();
        let reqs = arena(&[(50, 3), (60, 2), (70, 4)]);
        let mut c = CoupledInst::new(64);
        c.enqueue(0, 50);
        c.enqueue(1, 60);
        let _ = c.begin_iteration(&reqs, &cost, 2, 16, false, 0).unwrap();
        c.enqueue(2, 70); // arrives while the iteration is in flight
        let lost = c.harvest_crashed();
        assert_eq!(lost, vec![0, 1, 2], "waiting + running, deduped");
        assert_eq!(c.route_load(), 0, "no load left on the dead incarnation");
        assert!(InstanceRole::drained(&c));
    }

    #[test]
    fn route_load_blends_waiting_tokens_and_jobs() {
        let mut c = CoupledInst::new(8);
        assert_eq!(c.route_load(), 0);
        c.enqueue(0, 100);
        assert_eq!(c.route_load(), 100);
        assert_eq!(InstanceRole::load(&c), 100);
        assert_eq!(InstanceRole::role(&c), Role::Coupled);
    }
}
