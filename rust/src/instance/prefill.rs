//! The prefill role (§3.3): local scheduler → chunked prefill, with KV
//! residency backpressure and the parallel-predictor co-run tax. Moved
//! out of `coordinator/cluster.rs`; the driver now only prices, schedules
//! and observes the iterations this type assembles.

use crate::costmodel::CostModel;
use crate::kvcache::PagedKvCache;
use crate::prefill::{Chunk, Chunker, PrefillPolicy, PrefillScheduler};
use crate::types::{Role, Us};

use super::InstanceRole;

/// Predictions a single saturated chunk iteration can absorb in parallel
/// mode (the predict model is ~10x faster than the target, §3.3.2).
pub const PREDICTIONS_PER_CHUNK: u32 = 10;
/// Main-LLM slowdown while co-running the predictor (Figure 17: ~10%).
pub const PARALLEL_PREDICT_OVERHEAD: f64 = 0.10;

pub struct PrefillInst {
    pub sched: PrefillScheduler,
    pub chunker: Chunker,
    pub busy: bool,
    /// Chunk currently executing (applied at PrefillIterDone).
    pub current: Option<Chunk>,
    /// KV tokens resident for prefilled-but-untransferred requests plus
    /// in-flight chunked requests (backpressure input).
    pub resident_kv: u64,
    /// Predictions waiting to ride the accelerator (parallel mode).
    pub pending_pred: u32,
    pub last_active: Us,
}

impl PrefillInst {
    pub fn new(policy: PrefillPolicy, sched_batch: usize, chunk_size: u32, srtf: bool, now: Us) -> Self {
        PrefillInst {
            sched: PrefillScheduler::new(policy, sched_batch),
            chunker: if srtf { Chunker::new_srtf(chunk_size) } else { Chunker::new(chunk_size) },
            busy: false,
            current: None,
            resident_kv: 0,
            pending_pred: 0,
            last_active: now,
        }
    }

    /// Scheduling load (§3.2): queued + in-flight prompt tokens. O(1) —
    /// both counters are maintained incrementally.
    pub fn load(&self) -> u64 {
        self.sched.queued_tokens() + self.chunker.pending_tokens()
    }

    /// Admit scheduled requests into the chunker lazily — just enough to
    /// keep the next iterations fed. The backlog stays in the local
    /// scheduler where PrefillSchedBatch sorting applies (§3.3.1), and KV
    /// backpressure caps residency (prompt KV lives here until
    /// transferred out). Moving a request sched → chunker leaves the
    /// instance's total load unchanged.
    pub fn admit_ready(&mut self, chunk_size: u32, kv_cap: u64) {
        while self.chunker.pending_tokens() < 2 * chunk_size as u64 {
            let Some(nxt) = self.sched.peek() else { break };
            if self.resident_kv + nxt.prompt_len as u64 > kv_cap {
                break;
            }
            let m = self.sched.pop().unwrap();
            self.resident_kv += m.prompt_len as u64;
            self.chunker.admit(m);
        }
    }

    /// Slice and price the next fixed-size chunk iteration. Returns
    /// `(tokens, pad, dur)` for the driver to schedule and observe, or
    /// `None` when busy or out of open prompt tokens.
    ///
    /// Fixed-size iteration, charged by real tokens: the ChunkSize cap is
    /// what prevents over-saturated iterations (§3.3.3); the final
    /// partial chunk's zero-padding is shape filler, not useful compute
    /// (under the paper's stress workloads chunks are full anyway, so
    /// this matches their regime — see DESIGN.md §Calibration).
    pub fn begin_chunk(&mut self, cost: &CostModel, now: Us) -> Option<(u32, u32, Us)> {
        if self.busy {
            return None;
        }
        let chunk = self.chunker.next_chunk()?;
        let mut dur = cost.prefill_iter_us(chunk.tokens);
        if self.pending_pred > 0 {
            dur = (dur as f64 * (1.0 + PARALLEL_PREDICT_OVERHEAD)) as Us;
            self.pending_pred = self.pending_pred.saturating_sub(PREDICTIONS_PER_CHUNK);
        }
        let (tokens, pad) = (chunk.tokens, chunk.pad());
        self.current = Some(chunk);
        self.busy = true;
        self.last_active = now;
        Some((tokens, pad, dur))
    }

    /// Segments of the chunk currently executing (empty when idle) — the
    /// telemetry seam: a segment with `start == 0` is its request's first
    /// inclusion in any chunk, one with `last` its final tokens.
    pub fn in_flight_segments(&self) -> &[crate::prefill::Segment] {
        self.current.as_ref().map(|c| c.segments.as_slice()).unwrap_or(&[])
    }

    /// Iteration completed: hand the finished chunk back to the driver
    /// (which walks the `last` segments to dispatch completed prompts).
    pub fn end_chunk(&mut self, now: Us) -> Chunk {
        self.busy = false;
        self.last_active = now;
        self.current.take().expect("iteration completed without a chunk")
    }

    /// The prompt KV of one request left this instance (transfer done, or
    /// the request finished at prefill): release backpressure.
    pub fn release_resident(&mut self, tokens: u64) {
        self.resident_kv = self.resident_kv.saturating_sub(tokens);
    }

    /// Crash harvest: every request whose prefill state dies with this
    /// instance — the scheduler backlog, the chunker's open requests, and
    /// the chunk executing when the crash hit (its PrefillIterDone will be
    /// epoch-dropped, so partial progress is lost and these re-prefill
    /// from token 0). Ids are deduped — an open request usually also has a
    /// segment in the in-flight chunk. All load and residency tallies
    /// reset to zero so nothing stays attributed to the dead incarnation.
    /// Requests already prefilled here but awaiting transfer are *not*
    /// harvested: their in-flight TransferDone carries the old epoch and
    /// the driver recovers them when it lands stale.
    pub fn harvest_crashed(&mut self) -> Vec<crate::types::ReqId> {
        let mut ids: Vec<crate::types::ReqId> = Vec::new();
        while let Some(m) = self.sched.pop() {
            ids.push(m.id);
        }
        ids.extend(self.chunker.drain_open().into_iter().map(|m| m.id));
        if let Some(chunk) = self.current.take() {
            ids.extend(chunk.segments.iter().map(|s| s.req));
        }
        self.busy = false;
        self.resident_kv = 0;
        self.pending_pred = 0;
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

impl InstanceRole for PrefillInst {
    fn role(&self) -> Role {
        Role::Prefill
    }

    fn load(&self) -> u64 {
        PrefillInst::load(self)
    }

    fn busy(&self) -> bool {
        self.busy
    }

    fn drained(&self) -> bool {
        !self.busy && self.sched.is_empty() && !self.chunker.has_work()
    }

    fn last_active(&self) -> Us {
        self.last_active
    }

    fn kv(&self) -> Option<&PagedKvCache> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{ReqMeta, TaskType};

    fn meta(id: u64, plen: u32) -> ReqMeta {
        ReqMeta {
            id,
            task: TaskType::Chat,
            class: 0,
            arrival: 0,
            prompt_len: plen,
            predicted: None,
            prefix: None,
        }
    }

    fn inst() -> PrefillInst {
        PrefillInst::new(PrefillPolicy::Fcfs, 16, 512, false, 0)
    }

    #[test]
    fn admit_ready_respects_kv_backpressure() {
        let mut p = inst();
        p.sched.push(meta(0, 600));
        p.sched.push(meta(1, 600));
        p.admit_ready(512, 700); // only the first fits the residency cap
        assert_eq!(p.chunker.n_open(), 1);
        assert_eq!(p.resident_kv, 600);
        assert_eq!(p.load(), 1200, "sched→chunker moves keep total load");
        p.release_resident(600);
        p.admit_ready(512, 700);
        assert_eq!(p.chunker.n_open(), 2);
    }

    #[test]
    fn chunk_lifecycle_sets_busy_and_prices_predict_tax() {
        let cost = CostModel::default();
        let mut p = inst();
        p.sched.push(meta(0, 512));
        p.admit_ready(512, u64::MAX);
        let plain = cost.prefill_iter_us(512);
        p.pending_pred = 1;
        let (tokens, pad, dur) = p.begin_chunk(&cost, 5).expect("chunk ready");
        assert_eq!((tokens, pad), (512, 0));
        assert!(dur > plain, "parallel predictions must tax the iteration");
        assert!(p.busy && p.begin_chunk(&cost, 6).is_none());
        assert_eq!(p.pending_pred, 0);
        // the in-flight view exposes the whole prompt as one first+last segment
        let segs = p.in_flight_segments();
        assert_eq!(segs.len(), 1);
        assert!(segs[0].start == 0 && segs[0].last);
        let chunk = p.end_chunk(7);
        assert!(!p.busy);
        assert!(p.in_flight_segments().is_empty(), "idle instances expose no segments");
        assert_eq!(chunk.tokens, 512);
        assert_eq!(p.last_active, 7);
    }

    #[test]
    fn harvest_crashed_collects_backlog_open_and_inflight() {
        let mut p = inst();
        for i in 0..3 {
            p.sched.push(meta(i, 600));
        }
        p.admit_ready(512, u64::MAX); // reqs 0,1 enter the chunker; 2 stays queued
        let _ = p.begin_chunk(&CostModel::default(), 0).unwrap(); // req 0 mid-chunk
        let lost = p.harvest_crashed();
        assert_eq!(lost, vec![0, 1, 2], "backlog + open + in-flight, deduped");
        assert_eq!(p.load(), 0, "no load left on the dead incarnation");
        assert_eq!(p.resident_kv, 0);
        assert!(InstanceRole::drained(&p));
    }

    #[test]
    fn drained_tracks_sched_chunker_and_busy() {
        let mut p = inst();
        assert!(InstanceRole::drained(&p));
        p.sched.push(meta(0, 100));
        assert!(!InstanceRole::drained(&p));
        p.admit_ready(512, u64::MAX);
        let _ = p.begin_chunk(&CostModel::default(), 0).unwrap();
        assert!(!InstanceRole::drained(&p), "busy instances are not drained");
        p.end_chunk(1);
        assert!(InstanceRole::drained(&p));
    }
}
