//! The elastic instance pool: a growable set of [`Instance`] slots, each
//! moving through the role state machine documented in the module header
//! (`instance/mod.rs`). The pool owns the mechanics of every lifecycle
//! transition — drain, flip, retire, add — plus the epoch counters that
//! guard in-flight references, and (under `debug_assertions`) checks
//! `PagedKvCache::check_invariants` on every transition out of a role so
//! state-machine bugs fail loudly in tests. Drivers decide *when* to
//! transition; the pool guarantees *how*.

use crate::types::{Role, Us};

use super::{CoupledInst, DecodeInst, InstanceRole, PrefillInst};

/// What a draining instance becomes once its last work item leaves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainTarget {
    Flip(Role),
    Retire,
}

/// The role half of the state machine. `Draining` is not a variant here:
/// a draining instance keeps its live role state (it must keep serving
/// in-flight work) and carries its target in [`Instance::drain_to`].
pub enum InstanceState {
    Prefill(PrefillInst),
    Decode(DecodeInst),
    Coupled(CoupledInst),
    /// Drained and mid-role-switch (§3.5); live again at FlipDone.
    Flipping { to: Role },
    /// Crashed by fault injection — abrupt, *not* drained: the role state
    /// (and every queued/in-flight request and resident KV token in it)
    /// died with the incarnation. `role` remembers what to restart as;
    /// `until` is the scheduled restart time (`None` = permanent). The
    /// epoch was bumped at the crash, so stale completions and KV
    /// releases keyed to the old incarnation go inert.
    Dead { role: Role, until: Option<Us> },
    /// Permanently removed from the pool (elastic scale-down). The slot
    /// index stays valid so metric vectors and in-flight events keyed by
    /// instance id never dangle.
    Retired,
}

impl InstanceState {
    /// The role this slot serves, if it currently serves one.
    pub fn role(&self) -> Option<Role> {
        self.as_role().map(|r| r.role())
    }

    /// Trait view of the live role state (None for Flipping/Dead/Retired).
    pub fn as_role(&self) -> Option<&dyn InstanceRole> {
        match self {
            InstanceState::Prefill(p) => Some(p),
            InstanceState::Decode(d) => Some(d),
            InstanceState::Coupled(c) => Some(c),
            InstanceState::Flipping { .. } | InstanceState::Dead { .. } | InstanceState::Retired => {
                None
            }
        }
    }

    /// Swap-accounting tally a departing role must not take to the grave:
    /// the cumulative swapped-out tokens of its KV pool.
    fn swapped_out_tokens(&self) -> u64 {
        match self.as_role().and_then(|r| r.kv()) {
            Some(kv) => kv.swapped_out_tokens,
            None => 0,
        }
    }

    #[cfg(debug_assertions)]
    fn debug_check_kv(&self) {
        if let Some(kv) = self.as_role().and_then(|r| r.kv()) {
            if let Err(e) = kv.check_invariants() {
                panic!("KV invariants violated at lifecycle transition: {e}");
            }
        }
    }

    #[cfg(not(debug_assertions))]
    fn debug_check_kv(&self) {}
}

/// One pool slot: role state + lifecycle bookkeeping.
pub struct Instance {
    pub state: InstanceState,
    /// Bumped every time this slot leaves a role (flip or retire): any
    /// in-flight references to the old incarnation become stale.
    pub epoch: u32,
    /// `Some` while draining: the router sends no new work here; once
    /// [`InstanceRole::drained`], the driver completes the transition.
    pub drain_to: Option<DrainTarget>,
    /// Virtual time this slot entered the pool (0 for initial topology;
    /// the driver stamps elastic additions). Alive-time accounting input.
    pub born: Us,
    /// Virtual time this slot was retired; `None` while it lives.
    pub retired_at: Option<Us>,
}

impl Instance {
    /// This slot serves a role and accepts new work (live, not draining).
    pub fn accepts_work(&self) -> bool {
        self.drain_to.is_none() && self.state.as_role().is_some()
    }
}

/// The growable pool. Instances are only ever appended (retired slots
/// stay, keeping instance ids stable for events and metric vectors).
#[derive(Default)]
pub struct InstancePool {
    insts: Vec<Instance>,
}

impl InstancePool {
    pub fn new() -> Self {
        InstancePool { insts: Vec::new() }
    }

    /// Add an instance (initial construction or elastic scale-up);
    /// returns its id. The caller stamps `born` for mid-run additions.
    pub fn push(&mut self, state: InstanceState) -> usize {
        self.insts.push(Instance { state, epoch: 0, drain_to: None, born: 0, retired_at: None });
        self.insts.len() - 1
    }

    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Instance> {
        self.insts.iter()
    }

    pub fn get(&self, i: usize) -> &Instance {
        &self.insts[i]
    }

    pub fn get_mut(&mut self, i: usize) -> &mut Instance {
        &mut self.insts[i]
    }

    pub fn state(&self, i: usize) -> &InstanceState {
        &self.insts[i].state
    }

    pub fn state_mut(&mut self, i: usize) -> &mut InstanceState {
        &mut self.insts[i].state
    }

    pub fn epoch(&self, i: usize) -> u32 {
        self.insts[i].epoch
    }

    /// Instances currently serving `role` and accepting work.
    pub fn n_active(&self, role: Role) -> usize {
        self.insts
            .iter()
            .filter(|s| s.accepts_work() && s.state.role() == Some(role))
            .count()
    }

    /// Instances not yet permanently gone (live roles + draining +
    /// flipping + dead-but-restarting) — what an elastic `max_instances`
    /// cap counts. A permanently crashed slot (`Dead { until: None }`)
    /// counts like Retired: its capacity never returns, so the elastic
    /// pool may replace it.
    pub fn n_live(&self) -> usize {
        self.insts
            .iter()
            .filter(|s| {
                !matches!(
                    s.state,
                    InstanceState::Retired | InstanceState::Dead { until: None, .. }
                )
            })
            .count()
    }

    /// Ids of instances currently serving a role — the candidate set
    /// fault injection crashes/straggles (Flipping/Dead/Retired slots
    /// have no state left to kill).
    pub fn live_roles(&self) -> Vec<usize> {
        self.insts
            .iter()
            .enumerate()
            .filter(|(_, s)| s.state.as_role().is_some())
            .map(|(i, _)| i)
            .collect()
    }

    /// Whether slot `i` is crashed (restarting or permanent).
    pub fn is_dead(&self, i: usize) -> bool {
        matches!(self.insts[i].state, InstanceState::Dead { .. })
    }

    /// Whether any crashed slot is scheduled to restart — capacity that
    /// *will* return, which recovery paths wait for instead of burning a
    /// request's retry budget against a temporary hole.
    pub fn any_restart_pending(&self) -> bool {
        self.insts
            .iter()
            .any(|s| matches!(s.state, InstanceState::Dead { until: Some(_), .. }))
    }

    pub fn accepts_work(&self, i: usize) -> bool {
        self.insts[i].accepts_work()
    }

    /// Concrete accessors (draining instances included — they keep
    /// serving their in-flight work).
    pub fn prefill_mut(&mut self, i: usize) -> Option<&mut PrefillInst> {
        match &mut self.insts[i].state {
            InstanceState::Prefill(p) => Some(p),
            _ => None,
        }
    }

    pub fn decode_mut(&mut self, i: usize) -> Option<&mut DecodeInst> {
        match &mut self.insts[i].state {
            InstanceState::Decode(d) => Some(d),
            _ => None,
        }
    }

    pub fn coupled_mut(&mut self, i: usize) -> Option<&mut CoupledInst> {
        match &mut self.insts[i].state {
            InstanceState::Coupled(c) => Some(c),
            _ => None,
        }
    }

    /// Queued and in-flight work both gone?
    pub fn is_drained(&self, i: usize) -> bool {
        self.insts[i].state.as_role().map(|r| r.drained()).unwrap_or(true)
    }

    /// Stop routing new work to `i`; once drained, the driver completes
    /// the transition (`begin_flip` or `retire`). Checking KV invariants
    /// here catches corruption *entering* the drain window.
    pub fn begin_drain(&mut self, i: usize, to: DrainTarget) {
        debug_assert!(
            self.insts[i].state.as_role().is_some(),
            "drain of instance {i} which serves no role"
        );
        self.insts[i].state.debug_check_kv();
        self.insts[i].drain_to = Some(to);
    }

    /// Leave the current role toward `Flipping { to }`. The instance
    /// must be drained (the §3.5 policy flips idle instances; the drain
    /// path reaches here via `drain_to`). Bumps the epoch and returns the
    /// departing role's cumulative swapped-out tokens for the driver to
    /// fold into its metrics (they die with the role state otherwise).
    pub fn begin_flip(&mut self, i: usize, to: Role) -> u64 {
        debug_assert!(self.is_drained(i), "flip of undrained instance {i}");
        self.insts[i].state.debug_check_kv();
        let swapped = self.insts[i].state.swapped_out_tokens();
        self.insts[i].state = InstanceState::Flipping { to };
        self.insts[i].epoch += 1;
        self.insts[i].drain_to = None;
        swapped
    }

    /// Install the fresh role state at FlipDone. Returns false (and does
    /// nothing) if the slot is not mid-flip.
    pub fn finish_flip(&mut self, i: usize, state: InstanceState) -> bool {
        if !matches!(self.insts[i].state, InstanceState::Flipping { .. }) {
            return false;
        }
        self.insts[i].state = state;
        self.insts[i].drain_to = None;
        true
    }

    /// Permanently remove `i` from service (elastic scale-down). The
    /// instance must be drained. Bumps the epoch; returns the departing
    /// role's cumulative swapped-out tokens.
    pub fn retire(&mut self, i: usize) -> u64 {
        debug_assert!(self.is_drained(i), "retire of undrained instance {i}");
        self.insts[i].state.debug_check_kv();
        let swapped = self.insts[i].state.swapped_out_tokens();
        self.insts[i].state = InstanceState::Retired;
        self.insts[i].epoch += 1;
        self.insts[i].drain_to = None;
        swapped
    }

    /// Abrupt fault-injected failure of `i` — the crash twin of
    /// [`InstancePool::retire`], with the drain requirement deliberately
    /// absent: queued and in-flight work dies with the role state (the
    /// driver harvests it *before* calling this, then re-queues or fails
    /// each request). Bumps the epoch so stale completions and the
    /// `prefilled_by` KV-release guard go inert, clears any drain target
    /// (a crash overtakes an in-progress drain), and returns
    /// `(role, swapped_out_tokens)` for the driver's graveyard accounting
    /// — the same swap-tally rescue the flip path gained in the
    /// flip-graveyard fix, which an abrupt exit needs even more: without
    /// it a crashed slot's cumulative swap traffic would silently vanish
    /// from the run totals. Returns `None` (and does nothing) if the slot
    /// serves no role (already dead, flipping, or retired).
    ///
    /// KV invariants are still checked on the way out: a crash destroys
    /// *contents*, not bookkeeping consistency — corruption present at
    /// the crash instant is a real bug and must fail loudly.
    pub fn crash(&mut self, i: usize, until: Option<Us>) -> Option<(Role, u64)> {
        let role = self.insts[i].state.role()?;
        self.insts[i].state.debug_check_kv();
        let swapped = self.insts[i].state.swapped_out_tokens();
        self.insts[i].state = InstanceState::Dead { role, until };
        self.insts[i].epoch += 1;
        self.insts[i].drain_to = None;
        Some((role, swapped))
    }

    /// Bring a crashed slot back with a fresh (empty) role state. The
    /// epoch stays at its post-crash value — the restarted incarnation is
    /// the *new* epoch, so anything stamped with the pre-crash epoch can
    /// never land on it. Returns the role to restart as, or `None` (and
    /// does nothing) if the slot is not dead (e.g. a duplicate restart
    /// event); the caller installs the state it builds for that role via
    /// [`InstancePool::install_restarted`].
    pub fn dead_role(&self, i: usize) -> Option<Role> {
        match self.insts[i].state {
            InstanceState::Dead { role, .. } => Some(role),
            _ => None,
        }
    }

    /// Install the fresh role state on a dead slot (restart). Returns
    /// false (and does nothing) if the slot is not dead.
    pub fn install_restarted(&mut self, i: usize, state: InstanceState) -> bool {
        if !matches!(self.insts[i].state, InstanceState::Dead { .. }) {
            return false;
        }
        self.insts[i].state = state;
        self.insts[i].drain_to = None;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::DecodePolicy;
    use crate::prefill::PrefillPolicy;

    fn prefill() -> InstanceState {
        InstanceState::Prefill(PrefillInst::new(PrefillPolicy::Sjf, 16, 512, false, 0))
    }

    fn decode() -> InstanceState {
        InstanceState::Decode(DecodeInst::new(DecodePolicy::Greedy, 200, 128, 64))
    }

    #[test]
    fn push_counts_and_roles() {
        let mut pool = InstancePool::new();
        let a = pool.push(prefill());
        let b = pool.push(decode());
        let c = pool.push(InstanceState::Coupled(CoupledInst::new(16)));
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(pool.n_active(Role::Prefill), 1);
        assert_eq!(pool.n_active(Role::Decode), 1);
        assert_eq!(pool.n_active(Role::Coupled), 1);
        assert_eq!(pool.n_live(), 3);
    }

    #[test]
    fn drain_excludes_from_active_but_keeps_role_state() {
        let mut pool = InstancePool::new();
        pool.push(prefill());
        pool.begin_drain(0, DrainTarget::Retire);
        assert_eq!(pool.n_active(Role::Prefill), 0, "draining instances take no new work");
        assert!(pool.prefill_mut(0).is_some(), "draining instances keep serving");
        assert_eq!(pool.n_live(), 1);
        assert!(pool.is_drained(0));
    }

    #[test]
    fn flip_bumps_epoch_and_round_trips() {
        let mut pool = InstancePool::new();
        pool.push(prefill());
        assert_eq!(pool.epoch(0), 0);
        pool.begin_flip(0, Role::Decode);
        assert_eq!(pool.epoch(0), 1);
        assert!(matches!(pool.state(0), InstanceState::Flipping { to: Role::Decode }));
        assert_eq!(pool.n_active(Role::Prefill), 0);
        assert!(pool.finish_flip(0, decode()));
        assert_eq!(pool.n_active(Role::Decode), 1);
        assert!(!pool.finish_flip(0, prefill()), "finish_flip only lands mid-flip");
        // a second flip keeps bumping
        pool.begin_flip(0, Role::Prefill);
        assert_eq!(pool.epoch(0), 2);
    }

    #[test]
    fn crash_needs_no_drain_bumps_epoch_and_harvests_swap_tally() {
        let mut pool = InstancePool::new();
        pool.push(prefill());
        pool.push(decode());
        // a crash lands on an undrained, even mid-drain, instance
        pool.begin_drain(0, DrainTarget::Flip(Role::Decode));
        let (role, swapped) = pool.crash(0, Some(500)).expect("live role crashes");
        assert_eq!(role, Role::Prefill);
        assert_eq!(swapped, 0);
        assert_eq!(pool.epoch(0), 1, "crash bumps the epoch like flip/retire");
        assert!(pool.is_dead(0));
        assert!(pool.get(0).drain_to.is_none(), "crash overtakes the drain");
        assert_eq!(pool.n_active(Role::Prefill), 0);
        assert_eq!(pool.n_live(), 2, "dead-with-restart still occupies a slot");
        assert!(pool.any_restart_pending());
        assert_eq!(pool.live_roles(), vec![1]);
        // crashing a dead slot is a no-op
        assert!(pool.crash(0, None).is_none());
        assert_eq!(pool.epoch(0), 1);
    }

    #[test]
    fn permanent_crash_frees_elastic_capacity() {
        let mut pool = InstancePool::new();
        pool.push(prefill());
        pool.push(decode());
        pool.crash(1, None);
        assert_eq!(pool.n_live(), 1, "permanent dead counts like retired");
        assert!(!pool.any_restart_pending());
        assert!(pool.is_drained(1), "roleless slots count as drained");
    }

    #[test]
    fn restart_installs_fresh_state_under_the_post_crash_epoch() {
        let mut pool = InstancePool::new();
        pool.push(decode());
        pool.crash(0, Some(1_000));
        assert_eq!(pool.dead_role(0), Some(Role::Decode));
        assert!(pool.install_restarted(0, decode()));
        assert_eq!(pool.epoch(0), 1, "restart keeps the post-crash epoch");
        assert_eq!(pool.n_active(Role::Decode), 1);
        assert!(!pool.is_dead(0));
        // duplicate restart events land on a live slot: no-op
        assert!(!pool.install_restarted(0, prefill()));
        assert_eq!(pool.dead_role(0), None);
    }

    #[test]
    fn retire_is_terminal_and_preserves_slot_ids() {
        let mut pool = InstancePool::new();
        pool.push(prefill());
        pool.push(decode());
        pool.begin_drain(0, DrainTarget::Retire);
        pool.retire(0);
        assert!(matches!(pool.state(0), InstanceState::Retired));
        assert_eq!(pool.epoch(0), 1);
        assert_eq!(pool.n_live(), 1);
        assert_eq!(pool.len(), 2, "retired slots keep ids stable");
        assert!(pool.is_drained(0), "retired slots count as drained");
        assert!(!pool.accepts_work(0));
    }
}
