//! Workload generator: mixed downstream inference requests (Figure 1).
//!
//! Stands in for the paper's ShareGPT [35] / pubmed-summarization [17] /
//! writing-doc [18] samples. Each task family draws prompt and decode token
//! lengths from lognormal distributions calibrated to the medians the paper
//! reports; python/compile/data.py uses the *same* constants for predictor
//! fine-tuning (keep in sync — checked by tests against manifest.json).

use crate::types::{PrefixStamp, Request, TaskType, Us, HEAVY_DECODE_TOKENS, HEAVY_PREFILL_TOKENS};
use crate::util::Pcg;

/// (prompt_median, prompt_sigma, decode_median, decode_sigma) per task.
pub fn task_params(task: TaskType) -> (f64, f64, f64, f64) {
    match task {
        TaskType::Chat => (18.0, 0.8, 128.0, 0.9),
        TaskType::Summarization => (600.0, 0.5, 40.0, 0.7),
        TaskType::Creation => (25.0, 0.7, 600.0, 0.6),
    }
}

pub const MAX_PROMPT: u32 = 1024;
pub const MAX_DECODE: u32 = 1599;

/// The five end-to-end workload mixes of §5.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Light prefill, light decode — chat.
    Lpld,
    /// Light prefill, heavy decode — content creation.
    Lphd,
    /// Heavy prefill, light decode — summarization / prompt engineering.
    Hpld,
    /// Heavy prefill, heavy decode.
    Hphd,
    /// Random mix of everything (ShareGPT-like cluster traffic).
    Mixed,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 5] = [
        WorkloadKind::Lpld,
        WorkloadKind::Lphd,
        WorkloadKind::Hpld,
        WorkloadKind::Hphd,
        WorkloadKind::Mixed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Lpld => "LPLD",
            WorkloadKind::Lphd => "LPHD",
            WorkloadKind::Hpld => "HPLD",
            WorkloadKind::Hphd => "HPHD",
            WorkloadKind::Mixed => "Mixed",
        }
    }
}

/// Shared-prefix population knob: requests draw which of `n_prefixes`
/// shared prompt prefixes (system prompts, multi-turn histories) they
/// start with, zipf-weighted by popularity rank, each covering the first
/// `prefix_len` prompt tokens (clamped to the sampled prompt).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefixPopulation {
    pub n_prefixes: u32,
    pub prefix_len: u32,
    /// Zipf popularity exponent: weight of rank k ∝ 1/(k+1)^zipf
    /// (0 = uniform; higher = a few prefixes dominate).
    pub zipf: f64,
}

impl Default for PrefixPopulation {
    fn default() -> Self {
        PrefixPopulation { n_prefixes: 32, prefix_len: 512, zipf: 1.0 }
    }
}

#[derive(Clone, Debug)]
pub struct WorkloadGen {
    rng: Pcg,
    next_id: u64,
    /// Per-class arrival-share weights (empty or single entry = every
    /// request is the implicit class 0 and `class_rng` is never drawn).
    class_weights: Vec<f64>,
    /// The class stamp rides a *separate* RNG stream: a classed trace
    /// keeps exactly the same arrivals and lengths as its classless twin
    /// (and a classless trace consumes nothing here — bit-identical to
    /// pre-SLO builds).
    class_rng: Pcg,
    /// Shared-prefix population (`None` = prefix-free legacy traffic).
    prefix: Option<PrefixPopulation>,
    /// Precomputed zipf weights, one per prefix rank.
    prefix_weights: Vec<f64>,
    /// The prefix stamp rides its own RNG stream, exactly like the class
    /// stamp: a prefix-stamped trace keeps the same arrivals and lengths
    /// as its prefix-free twin, and a prefix-free trace consumes nothing
    /// here — bit-identical to pre-cache builds.
    prefix_rng: Pcg,
}

impl WorkloadGen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg::with_stream(seed, 0x9e3779b97f4a7c15),
            next_id: 0,
            class_weights: Vec::new(),
            class_rng: Pcg::with_stream(seed, 0x51f0_5e5a_71b7_4c3d),
            prefix: None,
            prefix_weights: Vec::new(),
            prefix_rng: Pcg::with_stream(seed, 0x7c15_85eb_ca6b_9fe1),
        }
    }

    /// Install the workload-class arrival shares (one weight per class id,
    /// in class order). Empty or single-class tables leave every request
    /// stamped class 0 without consuming RNG state.
    pub fn set_classes(&mut self, weights: Vec<f64>) {
        self.class_weights = weights;
    }

    /// Install (or clear) the shared-prefix population. `None`, or a
    /// population of zero prefixes, leaves every request unstamped
    /// without consuming RNG state.
    pub fn set_prefix(&mut self, prefix: Option<PrefixPopulation>) {
        self.prefix_weights = match &prefix {
            Some(p) if p.n_prefixes > 0 => {
                (0..p.n_prefixes).map(|k| 1.0 / ((k + 1) as f64).powf(p.zipf)).collect()
            }
            _ => Vec::new(),
        };
        self.prefix = prefix;
    }

    /// Sample a task with the mixed-traffic prior (chat-dominant, like
    /// ShareGPT): 50% chat, 25% summarization, 25% creation.
    pub fn sample_task(&mut self) -> TaskType {
        match self.rng.weighted(&[0.5, 0.25, 0.25]) {
            0 => TaskType::Chat,
            1 => TaskType::Summarization,
            _ => TaskType::Creation,
        }
    }

    /// Sample (prompt_len, decode_len) for one task family.
    pub fn sample_lengths(&mut self, task: TaskType) -> (u32, u32) {
        let (pm, ps, dm, ds) = task_params(task);
        let p = self.rng.lognormal(pm, ps).round().clamp(2.0, MAX_PROMPT as f64) as u32;
        let d = self.rng.lognormal(dm, ds).round().clamp(1.0, MAX_DECODE as f64) as u32;
        (p, d)
    }

    fn request(&mut self, task: TaskType, arrival: Us, p: u32, d: u32) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        let class = if self.class_weights.len() > 1 {
            self.class_rng.weighted(&self.class_weights) as u8
        } else {
            0
        };
        let prefix = match self.prefix {
            Some(cfg) if !self.prefix_weights.is_empty() => {
                let rank = self.prefix_rng.weighted(&self.prefix_weights) as u64;
                Some(PrefixStamp { id: rank, len: cfg.prefix_len.min(p) })
            }
            _ => None,
        };
        Request { id, task, class, arrival, prompt_len: p, decode_len: d, predicted: None, prefix }
    }

    /// Sample one request from the full mixed distribution.
    pub fn sample_mixed(&mut self, arrival: Us) -> Request {
        let task = self.sample_task();
        let (p, d) = self.sample_lengths(task);
        self.request(task, arrival, p, d)
    }

    /// Sample one request constrained to a §5.1 quadrant by rejection.
    pub fn sample_kind(&mut self, kind: WorkloadKind, arrival: Us) -> Request {
        if kind == WorkloadKind::Mixed {
            return self.sample_mixed(arrival);
        }
        let (want_hp, want_hd) = match kind {
            WorkloadKind::Lpld => (false, false),
            WorkloadKind::Lphd => (false, true),
            WorkloadKind::Hpld => (true, false),
            WorkloadKind::Hphd => (true, true),
            WorkloadKind::Mixed => unreachable!(),
        };
        // Each §5.1 quadrant corresponds to a Figure 1 task family: chat
        // is LPLD, creation is LPHD, summarization is HPLD; HPHD (long
        // prompt engineering) draws prompts like summarization and decodes
        // like creation. Rejection-sample the family; force after a cap.
        for _ in 0..256 {
            let (ptask, dtask) = match kind {
                WorkloadKind::Lpld => (TaskType::Chat, TaskType::Chat),
                WorkloadKind::Lphd => (TaskType::Creation, TaskType::Creation),
                WorkloadKind::Hpld => (TaskType::Summarization, TaskType::Summarization),
                WorkloadKind::Hphd => (TaskType::Summarization, TaskType::Creation),
                WorkloadKind::Mixed => unreachable!(),
            };
            let (p, _) = self.sample_lengths(ptask);
            let (_, d) = self.sample_lengths(dtask);
            if (p > HEAVY_PREFILL_TOKENS) == want_hp && (d > HEAVY_DECODE_TOKENS) == want_hd {
                return self.request(ptask, arrival, p, d);
            }
        }
        let p = if want_hp {
            self.rng.range(HEAVY_PREFILL_TOKENS as u64 + 1, MAX_PROMPT as u64) as u32
        } else {
            self.rng.range(2, HEAVY_PREFILL_TOKENS as u64) as u32
        };
        let d = if want_hd {
            self.rng.range(HEAVY_DECODE_TOKENS as u64 + 1, MAX_DECODE as u64) as u32
        } else {
            self.rng.range(1, HEAVY_DECODE_TOKENS as u64) as u32
        };
        let task = self.sample_task();
        self.request(task, arrival, p, d)
    }

    /// Synthesize the actual prompt token ids for a request, mirroring
    /// python/compile/data.py's vocabulary layout: [task marker, noisy
    /// length-hint token, filler...]. Real mode feeds these to the AOT'd
    /// model + length predictor, so they must stay in-distribution with
    /// the predictor's fine-tuning data.
    pub fn prompt_tokens(&mut self, req: &Request, vocab: u32) -> Vec<i32> {
        const HINT_BASE: u32 = 16;
        const HINT_LEVELS: u32 = 32;
        const HINT_GRAN: u32 = 50;
        const HINT_SIGMA: f64 = 0.22;
        const FILLER_BASE: u32 = 64;
        let marker = 1 + match req.task {
            TaskType::Chat => 0,
            TaskType::Summarization => 1,
            TaskType::Creation => 2,
        };
        let noisy = req.decode_len.max(1) as f64 * (HINT_SIGMA * self.rng.normal()).exp();
        let hint = HINT_BASE + ((noisy as u32) / HINT_GRAN).min(HINT_LEVELS - 1);
        let mut toks = Vec::with_capacity(req.prompt_len as usize);
        toks.push(marker as i32);
        if req.prompt_len > 1 {
            toks.push(hint as i32);
        }
        while toks.len() < req.prompt_len as usize {
            toks.push(self.rng.range(FILLER_BASE as u64, vocab as u64) as i32);
        }
        toks
    }

    /// Advance a Poisson arrival clock by one inter-arrival gap, in
    /// *integer nanoseconds* (`t_ns` is the offset from the trace start;
    /// arrivals stamp `start + t_ns / 1_000` µs). The running sum used to
    /// live in f64 µs: past millions of requests its absolute value
    /// outgrows the sub-µs fractions being added, silently reordering and
    /// colliding arrivals. Integer ns accumulation keeps the arithmetic
    /// exact at any trace length while preserving sub-µs carry across
    /// gaps, so the per-gap truncation bias is sub-ns — unmeasurable at
    /// any rate the sweeps use. The sampled exponential draws are
    /// unchanged; the stamped instants shift by (at most) the old
    /// representation's accumulated f64 error — an intentional, one-time
    /// trace-timing change; goldens re-bless (none were committed).
    /// Rate <= 0 leaves the clock where it is (batch arrivals).
    pub fn advance_arrival_ns(&mut self, t_ns: u64, rate_per_sec: f64) -> u64 {
        if rate_per_sec > 0.0 {
            t_ns + (self.rng.exponential(rate_per_sec) * 1e9) as u64
        } else {
            t_ns
        }
    }

    /// A batch of n requests with Poisson arrivals at `rate_per_sec`
    /// starting at `start` (rate <= 0 → all arrive at `start`).
    /// [`GenSource`] streams the identical request sequence one at a time.
    pub fn trace(
        &mut self,
        kind: WorkloadKind,
        n: usize,
        rate_per_sec: f64,
        start: Us,
    ) -> Vec<Request> {
        let mut t_ns = 0u64;
        (0..n)
            .map(|_| {
                t_ns = self.advance_arrival_ns(t_ns, rate_per_sec);
                self.sample_kind(kind, start + t_ns / 1_000)
            })
            .collect()
    }
}

/// Streaming arrival source sampling straight from a [`WorkloadGen`] —
/// the O(1)-memory twin of [`WorkloadGen::trace`]: same RNG draws in the
/// same order, so the delivered request stream is bit-identical to the
/// materialized trace (parity-tested below). This is what lets a
/// million-request run hold one pending request instead of the trace.
pub struct GenSource {
    gen: WorkloadGen,
    kind: WorkloadKind,
    rate: f64,
    start: Us,
    /// ns offset of the arrival clock from `start` (see
    /// [`WorkloadGen::advance_arrival_ns`]).
    t_ns: u64,
    total: usize,
    yielded: usize,
}

impl GenSource {
    pub fn new(seed: u64, kind: WorkloadKind, n: usize, rate_per_sec: f64, start: Us) -> Self {
        GenSource {
            gen: WorkloadGen::new(seed),
            kind,
            rate: rate_per_sec,
            start,
            t_ns: 0,
            total: n,
            yielded: 0,
        }
    }

    /// Same stream, with workload-class arrival shares installed —
    /// bit-identical to `WorkloadGen::set_classes` + `trace()` (the class
    /// stamp rides its own RNG stream, see [`WorkloadGen::set_classes`]).
    pub fn with_classes(mut self, weights: Vec<f64>) -> Self {
        self.gen.set_classes(weights);
        self
    }

    /// Same stream, with a shared-prefix population installed —
    /// bit-identical to `WorkloadGen::set_prefix` + `trace()` (the prefix
    /// stamp rides its own RNG stream, see [`WorkloadGen::set_prefix`]).
    pub fn with_prefix(mut self, prefix: Option<PrefixPopulation>) -> Self {
        self.gen.set_prefix(prefix);
        self
    }
}

impl crate::sim::ArrivalSource for GenSource {
    fn next_request(&mut self) -> Option<Request> {
        if self.yielded == self.total {
            return None;
        }
        self.yielded += 1;
        self.t_ns = self.gen.advance_arrival_ns(self.t_ns, self.rate);
        Some(self.gen.sample_kind(self.kind, self.start + self.t_ns / 1_000))
    }

    fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::summarize;

    #[test]
    fn deterministic_across_instances() {
        let mut a = WorkloadGen::new(1);
        let mut b = WorkloadGen::new(1);
        for _ in 0..64 {
            let (ra, rb) = (a.sample_mixed(0), b.sample_mixed(0));
            assert_eq!((ra.prompt_len, ra.decode_len), (rb.prompt_len, rb.decode_len));
        }
    }

    #[test]
    fn medians_track_figure1() {
        let mut g = WorkloadGen::new(7);
        for task in TaskType::ALL {
            let (pm, _, dm, _) = task_params(task);
            let mut ps = vec![];
            let mut ds = vec![];
            for _ in 0..4000 {
                let (p, d) = g.sample_lengths(task);
                ps.push(p as f64);
                ds.push(d as f64);
            }
            let sp = summarize(&ps);
            let sd = summarize(&ds);
            // clamping pulls extreme medians slightly; allow 20%
            assert!((sp.p50 / pm - 1.0).abs() < 0.2, "{task:?} prompt {}", sp.p50);
            assert!((sd.p50 / dm - 1.0).abs() < 0.2, "{task:?} decode {}", sd.p50);
        }
    }

    #[test]
    fn quadrants_respected() {
        let mut g = WorkloadGen::new(3);
        for kind in [WorkloadKind::Lpld, WorkloadKind::Lphd, WorkloadKind::Hpld, WorkloadKind::Hphd] {
            for _ in 0..200 {
                let r = g.sample_kind(kind, 0);
                match kind {
                    WorkloadKind::Lpld => assert!(!r.heavy_prefill() && !r.heavy_decode()),
                    WorkloadKind::Lphd => assert!(!r.heavy_prefill() && r.heavy_decode()),
                    WorkloadKind::Hpld => assert!(r.heavy_prefill() && !r.heavy_decode()),
                    WorkloadKind::Hphd => assert!(r.heavy_prefill() && r.heavy_decode()),
                    WorkloadKind::Mixed => {}
                }
            }
        }
    }

    #[test]
    fn trace_arrivals_monotone_and_ids_unique() {
        let mut g = WorkloadGen::new(5);
        let tr = g.trace(WorkloadKind::Mixed, 100, 50.0, 1000);
        let mut last = 0;
        let mut ids = std::collections::HashSet::new();
        for r in &tr {
            assert!(r.arrival >= last);
            last = r.arrival;
            assert!(ids.insert(r.id));
        }
    }

    #[test]
    fn zero_rate_means_batch_arrival() {
        let mut g = WorkloadGen::new(5);
        let tr = g.trace(WorkloadKind::Lpld, 16, 0.0, 42);
        assert!(tr.iter().all(|r| r.arrival == 42));
    }

    #[test]
    fn gen_source_streams_the_identical_trace() {
        use crate::sim::ArrivalSource as _;
        for (kind, rate) in
            [(WorkloadKind::Mixed, 40.0), (WorkloadKind::Hphd, 0.0), (WorkloadKind::Lphd, 3.5)]
        {
            let want = WorkloadGen::new(11).trace(kind, 200, rate, 7);
            let mut src = GenSource::new(11, kind, 200, rate, 7);
            assert_eq!(src.total(), 200);
            for (i, w) in want.iter().enumerate() {
                let g = src.next_request().expect("source ends with the trace");
                assert_eq!(
                    (g.id, g.arrival, g.prompt_len, g.decode_len, g.task),
                    (w.id, w.arrival, w.prompt_len, w.decode_len, w.task),
                    "{kind:?} request {i}"
                );
            }
            assert!(src.next_request().is_none());
        }
    }

    #[test]
    fn class_stamp_rides_its_own_stream() {
        // A classed trace keeps exactly the same arrivals/lengths as its
        // classless twin; only the class stamp differs. Shares track the
        // weights, and GenSource delivers the identical classed stream.
        use crate::sim::ArrivalSource as _;
        let classless = WorkloadGen::new(29).trace(WorkloadKind::Mixed, 600, 20.0, 0);
        let mut gen = WorkloadGen::new(29);
        gen.set_classes(vec![0.5, 0.25, 0.25]);
        let classed = gen.trace(WorkloadKind::Mixed, 600, 20.0, 0);
        let mut counts = [0usize; 3];
        for (a, b) in classless.iter().zip(classed.iter()) {
            assert_eq!(
                (a.id, a.arrival, a.prompt_len, a.decode_len, a.task),
                (b.id, b.arrival, b.prompt_len, b.decode_len, b.task)
            );
            assert_eq!(a.class, 0, "classless requests are the implicit class 0");
            counts[b.class as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        assert!(counts[0] > counts[1] && counts[0] > counts[2], "{counts:?}");
        let mut src =
            GenSource::new(29, WorkloadKind::Mixed, 600, 20.0, 0).with_classes(vec![0.5, 0.25, 0.25]);
        for w in &classed {
            let g = src.next_request().unwrap();
            assert_eq!((g.id, g.class), (w.id, w.class), "GenSource class parity");
        }
        // a single-class table is the same as no table at all
        let mut one = WorkloadGen::new(29);
        one.set_classes(vec![1.0]);
        for (a, b) in classless.iter().zip(one.trace(WorkloadKind::Mixed, 600, 20.0, 0)) {
            assert_eq!((a.id, a.arrival, a.class), (b.id, b.arrival, b.class));
        }
    }

    #[test]
    fn prefix_stamp_rides_its_own_stream() {
        // A prefix-stamped trace keeps exactly the same arrivals, lengths,
        // classes and ids as its prefix-free twin; only the stamp differs.
        // Popularity tracks the zipf weights, stamp lengths clamp to the
        // prompt, and GenSource delivers the identical stamped stream.
        use crate::sim::ArrivalSource as _;
        let plain = WorkloadGen::new(31).trace(WorkloadKind::Mixed, 600, 20.0, 0);
        let mut gen = WorkloadGen::new(31);
        let pop = PrefixPopulation { n_prefixes: 4, prefix_len: 256, zipf: 1.2 };
        gen.set_prefix(Some(pop));
        let stamped = gen.trace(WorkloadKind::Mixed, 600, 20.0, 0);
        let mut counts = [0usize; 4];
        for (a, b) in plain.iter().zip(stamped.iter()) {
            assert_eq!(
                (a.id, a.arrival, a.prompt_len, a.decode_len, a.task, a.class),
                (b.id, b.arrival, b.prompt_len, b.decode_len, b.task, b.class)
            );
            assert_eq!(a.prefix, None, "prefix-free requests stay unstamped");
            let s = b.prefix.expect("every request draws a prefix");
            assert!(s.id < 4);
            assert_eq!(s.len, 256.min(b.prompt_len), "stamp clamps to the prompt");
            counts[s.id as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        assert!(counts[0] > counts[3], "zipf rank 0 must dominate rank 3: {counts:?}");
        let mut src =
            GenSource::new(31, WorkloadKind::Mixed, 600, 20.0, 0).with_prefix(Some(pop));
        for w in &stamped {
            let g = src.next_request().unwrap();
            assert_eq!((g.id, g.prefix), (w.id, w.prefix), "GenSource prefix parity");
        }
        // an empty population is the same as no population at all
        let mut none = WorkloadGen::new(31);
        none.set_prefix(Some(PrefixPopulation { n_prefixes: 0, ..pop }));
        for (a, b) in plain.iter().zip(none.trace(WorkloadKind::Mixed, 600, 20.0, 0)) {
            assert_eq!((a.id, a.arrival, a.prefix), (b.id, b.arrival, b.prefix));
        }
    }

    #[test]
    fn arrival_accumulation_is_integral_and_unbiased() {
        // The arrival clock accumulates whole ns per gap: monotone at any
        // rate (the old f64 running sum drifted at scale), and the mean
        // inter-arrival tracks 1/rate (no per-gap truncation bias).
        let mut g = WorkloadGen::new(13);
        let tr = g.trace(WorkloadKind::Mixed, 4_000, 1000.0, 0);
        let mut last = 0;
        for r in &tr {
            assert!(r.arrival >= last);
            last = r.arrival;
        }
        let mean_gap_us = last as f64 / (tr.len() - 1) as f64;
        assert!((mean_gap_us / 1_000.0 - 1.0).abs() < 0.05, "mean gap {mean_gap_us}µs vs 1000µs");
    }
}
