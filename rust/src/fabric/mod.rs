//! Unified network-transfer abstraction for prefill→decode KV shipping
//! (§3.3.4, Figure 9).
//!
//! The paper classifies physical links into Direct (NVLink/HCCS),
//! Direct-NIC (GPU↔NIC↔GPU), and Indirect (bounce via CPU DRAM) and could
//! itself only *emulate* the fast ones (§4's mock mechanism: metadata-only
//! transfer + computed wait). We implement the same: a `Link` computes the
//! wire time of a KV payload; sim mode sleeps virtual time, real mode
//! meters actual copies. One-sided vs two-sided changes the fixed latency
//! and whether the receiver CPU adds a bounce copy.

use crate::types::Us;

/// Physical link class (Figure 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Accelerator-to-accelerator high-speed link (NVLink 300 GBps class).
    Direct,
    /// Via companion NICs (ConnectX-6 200 Gbps class RoCE/IB).
    DirectNic,
    /// Bounce through CPU DRAM (sockets) — what the paper's testbed had.
    Indirect,
}

/// One-sided (receiver CPU not involved) vs two-sided transfer stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sidedness {
    OneSided,
    TwoSided,
}

#[derive(Clone, Copy, Debug)]
pub struct Link {
    pub kind: LinkKind,
    pub sidedness: Sidedness,
    /// Payload bandwidth in Gbit/s.
    pub gbps: f64,
    /// Fixed per-transfer setup latency in µs.
    pub setup_us: f64,
    /// Extra per-byte factor for the DRAM bounce of Indirect links.
    pub bounce_factor: f64,
}

impl Link {
    /// The two emulated hardware setups of §5.1 plus the paper's own
    /// socket testbed.
    pub fn nvlink() -> Link {
        // "TS-NVLink": 300 GBps = 2400 Gbps, one-sided device copy.
        Link { kind: LinkKind::Direct, sidedness: Sidedness::OneSided, gbps: 2400.0, setup_us: 30.0, bounce_factor: 0.0 }
    }

    pub fn roce200() -> Link {
        // "TS-RoCE": ConnectX-6 200 Gbps, one-sided RDMA write.
        Link { kind: LinkKind::DirectNic, sidedness: Sidedness::OneSided, gbps: 200.0, setup_us: 100.0, bounce_factor: 0.0 }
    }

    pub fn indirect_socket() -> Link {
        // TCP sockets via CPU DRAM: two-sided, extra memcpy each side.
        Link { kind: LinkKind::Indirect, sidedness: Sidedness::TwoSided, gbps: 90.0, setup_us: 250.0, bounce_factor: 0.35 }
    }

    /// Wire time for `bytes` of payload.
    pub fn transfer_us(&self, bytes: f64) -> Us {
        let side = match self.sidedness {
            Sidedness::OneSided => 0.0,
            Sidedness::TwoSided => 50.0, // receiver CPU involvement
        };
        let wire = bytes * 8.0 / (self.gbps * 1e3); // gbps*1e3 bits per µs
        (self.setup_us + side + wire * (1.0 + self.bounce_factor)) as Us
    }
}

/// Transfer-granularity policy (§3.3.4 discussion). The paper implements
/// request-level; chunk-level is modeled so the ablation bench can compare.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One transfer of the whole prompt KV after the last chunk prefills.
    RequestLevel,
    /// One transfer per chunk, overlapped with subsequent chunk compute.
    ChunkLevel,
    /// Layer-wise streaming (TRT-LLM "KV Cache Exchange"): KV for finished
    /// layers departs while later layers of the same chunk still compute,
    /// so even the final chunk hides all but its last layer's worth.
    LayerLevel,
}

/// The unified API of Figure 9's "unified network transfer abstraction".
/// Sim mode uses `transfer_us` for virtual waits; real mode's serve path
/// meters actual byte copies through the same descriptor.
#[derive(Clone, Copy, Debug)]
pub struct Fabric {
    pub link: Link,
    pub granularity: Granularity,
    /// Bytes of KV per token (model-dependent; from CostModel).
    pub kv_bytes_per_tok: f64,
    /// Transformer layer count — the pipelining depth LayerLevel streams
    /// across (OPT-13B has 40 decoder layers).
    pub n_layers: u32,
}

impl Fabric {
    pub fn new(link: Link, kv_bytes_per_tok: f64) -> Self {
        Fabric { link, granularity: Granularity::RequestLevel, kv_bytes_per_tok, n_layers: 40 }
    }

    /// Time to ship a whole prompt's KV (request-level granularity).
    pub fn request_transfer_us(&self, prompt_tokens: u32) -> Us {
        self.link.transfer_us(self.kv_bytes_per_tok * prompt_tokens as f64)
    }

    /// Time to ship one chunk's KV (chunk-level granularity). The chunks
    /// overlap compute, so the *exposed* cost of all but the last chunk is
    /// max(0, transfer - next_chunk_compute).
    pub fn chunk_transfer_us(&self, chunk_tokens: u32) -> Us {
        self.link.transfer_us(self.kv_bytes_per_tok * chunk_tokens as f64)
    }

    /// A copy of this fabric whose link runs `factor`× slower — what a
    /// fault plan's link-degrade window prices transfers through (both
    /// the wire time and the per-transfer setup stretch; congestion slows
    /// the handshake as much as the payload).
    pub fn degraded(&self, factor: f64) -> Fabric {
        let mut f = *self;
        f.link.gbps /= factor;
        f.link.setup_us *= factor;
        f
    }

    /// Total exposed transfer latency for a prompt of `n_chunks` chunks of
    /// `chunk_tokens` each, when each chunk's shipping overlaps the next
    /// chunk's compute (`chunk_compute_us`).
    pub fn exposed_transfer_us(
        &self,
        n_chunks: u32,
        chunk_tokens: u32,
        chunk_compute_us: Us,
    ) -> Us {
        match self.granularity {
            Granularity::RequestLevel => self.request_transfer_us(n_chunks * chunk_tokens),
            Granularity::ChunkLevel => {
                let per = self.chunk_transfer_us(chunk_tokens);
                let hidden = per.saturating_sub(chunk_compute_us);
                // n-1 chunks overlap; the last is fully exposed.
                hidden * n_chunks.saturating_sub(1) as u64 + per
            }
            Granularity::LayerLevel => {
                // Within a chunk, layer i's KV ships while layers i+1..L
                // still compute: the chunk hides up to (L-1)/L of its own
                // compute, and the tail chunk only exposes what outlives
                // that window — never less than one layer's slice of wire
                // time (the last layer has nothing left to hide behind).
                let per = self.chunk_transfer_us(chunk_tokens);
                let layers = self.n_layers.max(1) as u64;
                let window = chunk_compute_us * (layers - 1) / layers;
                let tail = per.saturating_sub(window).max(per / layers);
                let hidden = per.saturating_sub(chunk_compute_us);
                hidden * n_chunks.saturating_sub(1) as u64 + tail
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KV_TOK: f64 = 820_000.0; // OPT-13B fp16 bytes/token

    #[test]
    fn nvlink_is_much_faster_than_roce() {
        let nv = Fabric::new(Link::nvlink(), KV_TOK);
        let roce = Fabric::new(Link::roce200(), KV_TOK);
        let t_nv = nv.request_transfer_us(512);
        let t_roce = roce.request_transfer_us(512);
        assert!(t_nv * 5 < t_roce, "nv={t_nv} roce={t_roce}");
    }

    #[test]
    fn indirect_pays_bounce() {
        let direct = Link { bounce_factor: 0.0, ..Link::indirect_socket() };
        let indirect = Link::indirect_socket();
        let bytes = KV_TOK * 100.0;
        assert!(indirect.transfer_us(bytes) > direct.transfer_us(bytes));
    }

    #[test]
    fn transfer_scales_linearly_in_tokens() {
        let f = Fabric::new(Link::roce200(), KV_TOK);
        let t1 = f.request_transfer_us(100) as f64;
        let t2 = f.request_transfer_us(200) as f64;
        let setup = Link::roce200().setup_us;
        assert!(((t2 - setup) / (t1 - setup) - 2.0).abs() < 0.05);
    }

    #[test]
    fn chunk_level_hides_transfer_behind_compute() {
        let mut f = Fabric::new(Link::roce200(), KV_TOK);
        f.granularity = Granularity::ChunkLevel;
        let per_chunk = f.chunk_transfer_us(512);
        let compute = per_chunk * 2; // compute dominates: fully hidden
        let exposed = f.exposed_transfer_us(4, 512, compute);
        assert_eq!(exposed, per_chunk, "only the last chunk is exposed");
        // request-level ships everything at the end
        f.granularity = Granularity::RequestLevel;
        assert!(f.exposed_transfer_us(4, 512, compute) > exposed);
    }

    #[test]
    fn layer_level_never_exposes_more_than_chunk_level() {
        let mut f = Fabric::new(Link::roce200(), KV_TOK);
        for compute_scale in [0u64, 1, 2, 5] {
            let per = f.chunk_transfer_us(512);
            let compute = per * compute_scale / 2;
            f.granularity = Granularity::ChunkLevel;
            let chunk = f.exposed_transfer_us(4, 512, compute);
            f.granularity = Granularity::LayerLevel;
            let layer = f.exposed_transfer_us(4, 512, compute);
            assert!(layer <= chunk, "scale {compute_scale}: layer={layer} chunk={chunk}");
            // the last layer's slice of wire time can never be hidden
            assert!(layer >= per / f.n_layers as u64);
        }
        // compute-rich case: layer-wise streaming beats chunk-level strictly,
        // because the tail chunk overlaps its own compute too.
        let per = f.chunk_transfer_us(512);
        let compute = per * 2;
        f.granularity = Granularity::ChunkLevel;
        let chunk = f.exposed_transfer_us(4, 512, compute);
        f.granularity = Granularity::LayerLevel;
        assert!(f.exposed_transfer_us(4, 512, compute) < chunk);
        // degenerate single-layer "model" degrades to chunk-level exactly
        f.n_layers = 1;
        assert_eq!(f.exposed_transfer_us(4, 512, compute), chunk);
    }

    #[test]
    fn degraded_fabric_slows_transfers_proportionally() {
        let f = Fabric::new(Link::roce200(), KV_TOK);
        let slow = f.degraded(4.0);
        let t = f.request_transfer_us(512);
        let ts = slow.request_transfer_us(512);
        // one-sided link: setup and wire both scale, so the total does too
        // (up to µs truncation)
        let ratio = ts as f64 / t as f64;
        assert!((ratio - 4.0).abs() < 0.01, "4x degrade must price ~4x: {ratio}");
        let unity = f.degraded(1.0);
        assert_eq!(unity.request_transfer_us(512), t);
    }

    #[test]
    fn one_sided_cheaper_than_two_sided() {
        let mut a = Link::roce200();
        a.sidedness = Sidedness::OneSided;
        let mut b = Link::roce200();
        b.sidedness = Sidedness::TwoSided;
        assert!(a.transfer_us(1e6) < b.transfer_us(1e6));
    }
}
