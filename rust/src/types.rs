//! Core request/phase/instance types shared by every module.
//!
//! Times are virtual microseconds (`Us`) in sim mode and wall-clock
//! microseconds in real mode — policy code never knows the difference.

pub type Us = u64;
pub type ReqId = u64;
pub type InstanceId = usize;

pub const US_PER_MS: u64 = 1_000;
pub const US_PER_SEC: u64 = 1_000_000;

/// Downstream task family (Figure 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskType {
    Chat,
    Summarization,
    Creation,
}

impl TaskType {
    pub const ALL: [TaskType; 3] =
        [TaskType::Chat, TaskType::Summarization, TaskType::Creation];

    pub fn name(self) -> &'static str {
        match self {
            TaskType::Chat => "chat",
            TaskType::Summarization => "summarization",
            TaskType::Creation => "creation",
        }
    }
}

/// Light/heavy classification thresholds (§5.1): prefill heavy above 512
/// prompt tokens, decode heavy above 128 generated tokens (ShareGPT answer
/// median).
pub const HEAVY_PREFILL_TOKENS: u32 = 512;
pub const HEAVY_DECODE_TOKENS: u32 = 128;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefilling,
    Transferring,
    Decoding,
    Finished,
}

/// One inference request as the serving system sees it. Plain old data —
/// `Copy`, so drivers hand values around without heap traffic. Doubles as
/// the payload lane of the engine's SoA request arena: `EngineCore` keeps
/// a dense `Vec<Request>` with the mutable driver-side state split into
/// parallel hot/cold lanes (`sim::HotState` / `sim::ColdState`), so
/// iteration-time scans touch only plain `Request` rows.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: ReqId,
    pub task: TaskType,
    /// Workload class id (index into the scenario's `ClassSpec` table;
    /// 0 = the implicit default class of classless runs). Carries the
    /// SLO vocabulary — tier, TTFT/TPOT deadlines, admission limits —
    /// by reference, so requests stay plain `Copy` data.
    pub class: u8,
    pub arrival: Us,
    pub prompt_len: u32,
    /// Ground-truth generation length. In sim mode the decode instance
    /// "discovers" it one token at a time; schedulers must not read it —
    /// they only see `predicted` (this separation is what Figure 18
    /// ablates).
    pub decode_len: u32,
    /// Predicted decode-length bucket (filled by the length predictor).
    pub predicted: Option<BucketPrediction>,
    /// Shared-prefix stamp (`None` for prefix-free traffic — the legacy
    /// default, consuming no generator RNG and touching no cache).
    pub prefix: Option<PrefixStamp>,
}

impl Request {
    pub fn heavy_prefill(&self) -> bool {
        self.prompt_len > HEAVY_PREFILL_TOKENS
    }

    pub fn heavy_decode(&self) -> bool {
        self.decode_len > HEAVY_DECODE_TOKENS
    }

    /// Scheduler-facing view of this request (keeps the original id).
    pub fn meta(&self) -> ReqMeta {
        ReqMeta {
            id: self.id,
            task: self.task,
            class: self.class,
            arrival: self.arrival,
            prompt_len: self.prompt_len,
            predicted: self.predicted,
            prefix: self.prefix,
        }
    }
}

/// Copyable scheduler-facing view of a request: everything policy code may
/// legally read. The ground-truth `decode_len` is deliberately absent —
/// schedulers only ever see `predicted` (the Figure 18 separation), and
/// the decode instance "discovers" the true length one token at a time.
///
/// Drivers that renumber requests into dense arena slots put the *slot*
/// in `id`; everything keyed off this id (KV tables, events, queues) then
/// indexes the arena directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReqMeta {
    pub id: ReqId,
    pub task: TaskType,
    /// Workload class id (see [`Request::class`]) — schedulers may read
    /// it to apply per-class SLO policy.
    pub class: u8,
    pub arrival: Us,
    pub prompt_len: u32,
    pub predicted: Option<BucketPrediction>,
    /// Shared-prefix stamp (see [`Request::prefix`]) — cache-aware
    /// routing and the prefill instance's suffix admission read it.
    pub prefix: Option<PrefixStamp>,
}

impl ReqMeta {
    pub fn heavy_prefill(&self) -> bool {
        self.prompt_len > HEAVY_PREFILL_TOKENS
    }
}

/// Shared-prefix stamp: the request's prompt starts with the first `len`
/// tokens of shared-prefix population member `id` (a system prompt or a
/// multi-turn history). Stamped by the workload generator's `prefix` knob;
/// the prefix cache derives its content-hash chain from this
/// (`prefixcache::block_hashes`), standing in for hashing real token ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PrefixStamp {
    pub id: u64,
    pub len: u32,
}

/// A predicted decode-length range [lo, hi) in tokens (§3.3.2: ranges, not
/// exact lengths — schedulers use lo/hi as resource bounds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BucketPrediction {
    pub bucket: u8,
    pub lo: u32,
    pub hi: u32,
}

impl BucketPrediction {
    pub fn from_bucket(bucket: u8, granularity: u32, n_buckets: u8) -> Self {
        let lo = bucket as u32 * granularity;
        let hi = if bucket + 1 >= n_buckets {
            u32::MAX
        } else {
            (bucket as u32 + 1) * granularity
        };
        BucketPrediction { bucket, lo, hi }
    }

    /// "Heavy decode" classification by the range midpoint (a bucket that
    /// merely brushes the threshold — e.g. [0,200) vs threshold 128 —
    /// stays light; the paper spreads *expected* heavy decodes).
    pub fn predicts_heavy(&self, threshold: u32) -> bool {
        if self.hi == u32::MAX {
            return self.lo >= threshold;
        }
        (self.lo + self.hi) / 2 > threshold
    }
}

/// What an instance is currently serving (§3.5: roles are virtual and flip).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Prefill,
    Decode,
    /// Coupled prefill+decode — the vanilla-vLLM baseline role.
    Coupled,
}

/// Per-request serving record used for end-of-run metrics.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: ReqId,
    pub task: TaskType,
    /// Workload class id (per-class attainment accounting key).
    pub class: u8,
    pub prompt_len: u32,
    pub decode_len: u32,
    pub arrival: Us,
    /// Time the first token was produced (end of prefill) — TTFT basis.
    pub first_token: Us,
    /// Time the last token was produced — JCT basis.
    pub finished: Us,
    pub predicted: Option<BucketPrediction>,
    /// How many times this request was re-queued after a fault lost its
    /// in-flight state (0 in fault-free runs).
    pub retries: u32,
    /// True if the request finished after surviving at least one fault
    /// (its recovery latency feeds the per-class recovery histogram).
    pub recovered: bool,
}

impl RequestRecord {
    pub fn ttft(&self) -> Us {
        self.first_token.saturating_sub(self.arrival)
    }

    pub fn jct(&self) -> Us {
        self.finished.saturating_sub(self.arrival)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_ranges() {
        let b = BucketPrediction::from_bucket(0, 200, 8);
        assert_eq!((b.lo, b.hi), (0, 200));
        let b = BucketPrediction::from_bucket(7, 200, 8);
        assert_eq!(b.lo, 1400);
        assert_eq!(b.hi, u32::MAX);
    }

    #[test]
    fn heavy_classification() {
        let mut r = Request {
            id: 0,
            task: TaskType::Chat,
            class: 0,
            arrival: 0,
            prompt_len: 512,
            decode_len: 128,
            predicted: None,
            prefix: None,
        };
        assert!(!r.heavy_prefill());
        assert!(!r.heavy_decode());
        r.prompt_len = 513;
        r.decode_len = 129;
        assert!(r.heavy_prefill());
        assert!(r.heavy_decode());
    }

    #[test]
    fn meta_mirrors_request_minus_decode_len() {
        let r = Request {
            id: 9,
            task: TaskType::Creation,
            class: 3,
            arrival: 77,
            prompt_len: 600,
            decode_len: 4,
            predicted: Some(BucketPrediction::from_bucket(2, 200, 8)),
            prefix: Some(PrefixStamp { id: 4, len: 256 }),
        };
        let m = r.meta();
        assert_eq!((m.id, m.task, m.arrival, m.prompt_len), (9, TaskType::Creation, 77, 600));
        assert_eq!(m.class, 3, "meta must carry the workload class");
        assert_eq!(m.predicted, r.predicted);
        assert_eq!(m.prefix, r.prefix, "meta must carry the prefix stamp");
        assert!(m.heavy_prefill());
    }

    #[test]
    fn record_times() {
        let rec = RequestRecord {
            id: 1,
            task: TaskType::Chat,
            class: 0,
            prompt_len: 10,
            decode_len: 5,
            arrival: 100,
            first_token: 150,
            finished: 300,
            predicted: None,
            retries: 0,
            recovered: false,
        };
        assert_eq!(rec.ttft(), 50);
        assert_eq!(rec.jct(), 200);
    }
}
