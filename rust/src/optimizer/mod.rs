//! Goodput-per-dollar auto-search over serving topologies (DistServe-style
//! placement search, arXiv:2401.09670 §4, applied to this paper's
//! disaggregated cluster): expand an [`OptimizeGrid`] into
//! n_prefill × n_decode × chunk × policy × link × elastic × driver cells,
//! then find the Pareto frontier of goodput vs $/hr — engineered so the
//! dominant cost is the handful of finalist cells, not the grid.
//!
//! Three pillars keep the search cheap (see DESIGN.md §Optimizer):
//!
//!   1. **Shared-trace memoization** — every cell replays one `Arc`'d
//!      arrival trace ([`TraceCache`] keyed by [`Scenario::trace_key`]);
//!      grid axes never enter the workload generator, so the trace is
//!      generated once and shared zero-copy across all cells
//!      (bit-identical to per-cell generation — pinned in
//!      tests/optimizer.rs).
//!   2. **Truncated successive halving** — every live cell runs a short
//!      prefix of the trace (`SharedTraceSource::truncated`, a *complete*
//!      run of the first `h` requests — no mid-flight abort), the top
//!      `keep_fraction` by estimated goodput/$ survive, the horizon
//!      doubles, repeat until the full length.
//!   3. **Early-abort pruning** — a `StopPolicy` miss budget kills cells
//!      mid-run the moment SLO attainment is hopeless, and a dominance
//!      bound skips finalists whose rung-derived upper bound cannot reach
//!      the best completed full run (final stage only — rung-vs-rung
//!      pruning is not sound; see DESIGN.md for the bound's derivation).
//!
//! Everything is deterministic: cells run under `sweep::parallel_map`
//! (input-order results), ranking ties break on grid index, and pruning
//! decisions only read state from completed waves — same spec + seed ⇒
//! byte-identical frontier JSON (pinned in tests/optimizer.rs and
//! tests/golden.rs).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::api::{NullObserver, OptimizeGrid, Registry, Scenario};
use crate::api::{prefill_policy_key, ElasticSpec, Report};
use crate::metrics::RunMetrics;
use crate::sim::{SharedTraceSource, StopPolicy};
use crate::sweep::{parallel_map, CellResult, SweepCell};
use crate::types::{Request, Us};
use crate::util::Json;

// ------------------------------------------------------------ trace cache

/// Memoized arrival traces, keyed by [`Scenario::trace_key`]: one
/// generation + one stable sort per distinct fingerprint, shared as an
/// `Arc` across every grid cell that replays it. The sort matches
/// `TraceSource::new`, so a `SharedTraceSource` over the cached trace is
/// bit-identical to a fresh per-cell source.
#[derive(Default)]
pub struct TraceCache {
    map: HashMap<String, Arc<Vec<Request>>>,
}

impl TraceCache {
    pub fn new() -> Self {
        TraceCache::default()
    }

    /// The trace for `sc`, generating (and arrival-sorting) it on first
    /// use and handing back the shared `Arc` afterwards.
    pub fn get(&mut self, sc: &Scenario) -> Arc<Vec<Request>> {
        self.map
            .entry(sc.trace_key())
            .or_insert_with(|| {
                let mut t = sc.trace();
                // phased traces may interleave; TraceSource sorts stably
                // by arrival, so the shared copy must too
                t.sort_by_key(|r| r.arrival);
                Arc::new(t)
            })
            .clone()
    }

    /// Distinct traces generated so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

// -------------------------------------------------------- grid expansion

/// Sentinel for "axis not searched — inherit the base scenario's value"
/// on the elastic axis (where `0` already means "static pool").
const INHERIT: usize = usize::MAX;

/// Expand the grid into concrete sweep cells. Empty axes inherit the base
/// scenario's value; cell labels encode the searched axes only. Cells
/// drop the `optimize` block (no recursion, compact echoes) and force
/// `records: false` — a grid holds O(cells) summaries, never
/// O(cells × requests) record vectors.
pub fn expand(base: &Scenario, g: &OptimizeGrid) -> Vec<SweepCell> {
    let usizes = |axis: &Vec<usize>, b: usize| -> Vec<usize> {
        if axis.is_empty() { vec![b] } else { axis.clone() }
    };
    let prefills = usizes(&g.prefill, base.n_prefill);
    let decodes = usizes(&g.decode, base.n_decode);
    let chunks = if g.chunk.is_empty() { vec![base.chunk_size] } else { g.chunk.clone() };
    let policies = if g.prefill_policy.is_empty() {
        vec![base.prefill_policy]
    } else {
        g.prefill_policy.clone()
    };
    let links = if g.link.is_empty() { vec![base.link] } else { g.link.clone() };
    let elastics = if g.elastic.is_empty() { vec![INHERIT] } else { g.elastic.clone() };
    let drivers = if g.drivers.is_empty() {
        vec![base.driver.clone()]
    } else {
        g.drivers.clone()
    };

    let mut cells = Vec::new();
    for &np in &prefills {
        for &nd in &decodes {
            for &ch in &chunks {
                for &pol in &policies {
                    for &link in &links {
                        for &el in &elastics {
                            for drv in &drivers {
                                let mut sc = base.clone();
                                sc.optimize = None;
                                sc.records = false;
                                sc.n_prefill = np;
                                sc.n_decode = nd;
                                sc.chunk_size = ch;
                                sc.prefill_policy = pol;
                                sc.link = link;
                                sc.driver = drv.clone();
                                if el != INHERIT {
                                    sc.elastic = if el == 0 {
                                        None
                                    } else {
                                        Some(ElasticSpec {
                                            max_instances: el,
                                            ..base.elastic.unwrap_or_default()
                                        })
                                    };
                                }
                                let mut label =
                                    format!("p{np}d{nd}c{ch}-{}", prefill_policy_key(pol));
                                if !g.link.is_empty() {
                                    label.push('-');
                                    label.push_str(link.key());
                                }
                                if !g.elastic.is_empty() {
                                    if el == 0 {
                                        label.push_str("-static");
                                    } else {
                                        label.push_str(&format!("-e{el}"));
                                    }
                                }
                                if !g.drivers.is_empty() {
                                    label.push('-');
                                    label.push_str(drv);
                                }
                                sc.name = label.clone();
                                cells.push(SweepCell::new(label, sc));
                            }
                        }
                    }
                }
            }
        }
    }
    cells
}

// ------------------------------------------------------- value functions

/// $/hr of a run: average live instance count × the cost model's dollar
/// rate. Static pools resolve to `n_instances × 3600 × rate` exactly;
/// elastic pools pay only for the instance-seconds they kept alive.
pub fn cost_per_hr(m: &RunMetrics) -> f64 {
    let mk = m.makespan_us.max(1) as f64;
    let avg_instances = m.alive_us.iter().sum::<Us>() as f64 / mk;
    avg_instances * crate::costmodel::CostModel::default().dollar_per_sec * 3600.0
}

/// The search objective: goodput (SLO-attained requests/sec) per $/hr.
pub fn value_of(m: &RunMetrics) -> f64 {
    let cost = cost_per_hr(m);
    if cost <= 0.0 {
        return 0.0;
    }
    m.goodput_rps() / cost
}

/// Miss budget for a horizon of `h` requests: the run aborts once
/// `misses > floor((1 - min_attainment) × h)`. `min_attainment == 0`
/// disarms the knob entirely (`u64::MAX` — the budget can never be
/// exceeded before the run completes anyway).
fn miss_budget(min_attainment: f64, h: usize) -> u64 {
    if min_attainment <= 0.0 {
        u64::MAX
    } else {
        ((1.0 - min_attainment) * h as f64).floor() as u64
    }
}

// ----------------------------------------------------------- the search

/// Per-cell search state: the cell itself plus whatever its most recent
/// (longest-horizon) run established.
struct CellState {
    cell: SweepCell,
    /// Horizon of `last` (requests delivered).
    last_h: usize,
    /// Most recent rung report (None until the first rung runs).
    last: Option<Report>,
    /// Estimated goodput/$ from `last` — the halving rank key.
    value_est: f64,
    /// Observed DES events per delivered request (exhaustive-cost
    /// estimator; refined at every horizon this cell reaches).
    events_per_req: f64,
}

/// Search accounting: how much work the three pillars saved.
#[derive(Clone, Debug, Default)]
pub struct OptimizerStats {
    /// Cells in the expanded grid.
    pub grid_cells: usize,
    /// Halving rungs executed (0 = the grid went straight to finals).
    pub rungs: usize,
    /// Cells discarded by successive-halving rank cuts.
    pub halving_discarded: usize,
    /// Runs killed mid-flight by the SLO miss budget (rungs + finals).
    pub pruned_slo: usize,
    /// Finalists skipped because their upper bound could not reach the
    /// incumbent full-run value.
    pub pruned_dominance: usize,
    /// Full-length runs actually executed.
    pub full_runs: usize,
    /// DES events actually simulated across every run.
    pub events_simulated: u64,
    /// Estimated events an exhaustive full-length sweep of the whole grid
    /// would have cost (per-cell observed events/request × full length).
    pub events_exhaustive_est: f64,
    /// Host wall time of the whole search (not serialized — see
    /// [`OptimizerResult::to_json`]).
    pub wall_secs: f64,
}

impl OptimizerStats {
    /// Fraction of the exhaustive sweep's event count actually simulated
    /// — the headline savings number (BENCH_cluster.json asserts < 0.5 on
    /// the shipped spec).
    pub fn fraction_of_exhaustive(&self) -> f64 {
        if self.events_exhaustive_est <= 0.0 {
            return 1.0;
        }
        self.events_simulated as f64 / self.events_exhaustive_est
    }

    /// Grid cells per wall second (the optimizer bench headline).
    pub fn cells_per_sec(&self) -> f64 {
        self.grid_cells as f64 / self.wall_secs.max(1e-9)
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("grid_cells", Json::from(self.grid_cells)),
            ("rungs", Json::from(self.rungs)),
            ("halving_discarded", Json::from(self.halving_discarded)),
            ("pruned_slo", Json::from(self.pruned_slo)),
            ("pruned_dominance", Json::from(self.pruned_dominance)),
            ("full_runs", Json::from(self.full_runs)),
            ("events_simulated", Json::from(self.events_simulated)),
            ("events_exhaustive_est", Json::from(self.events_exhaustive_est)),
            ("fraction_of_exhaustive", Json::from(self.fraction_of_exhaustive())),
        ])
    }
}

/// The search output: the Pareto frontier (full-length runs,
/// cost-ascending), the recommended topology, and the work accounting.
pub struct OptimizerResult {
    /// Non-dominated full-length cells, sorted by $/hr ascending.
    pub frontier: Vec<CellResult>,
    /// Index into `frontier` of the best goodput/$ cell (`None` when no
    /// cell survived the SLO floor).
    pub recommended: Option<usize>,
    pub stats: OptimizerStats,
}

impl OptimizerResult {
    /// The recommended cell, if any cell was feasible.
    pub fn recommended_cell(&self) -> Option<&CellResult> {
        self.recommended.and_then(|i| self.frontier.get(i))
    }

    /// Frontier CSV through the sweep serializer (same 17 columns as
    /// every other grid artifact in the repo).
    pub fn frontier_csv(&self) -> String {
        crate::sweep::results_csv(&self.frontier)
    }

    /// Deterministic machine-readable result: compact frontier points,
    /// the recommended topology, and the stats. Wall time is deliberately
    /// *not* serialized — same spec + seed must dump byte-identical JSON
    /// (pinned in tests/optimizer.rs).
    pub fn to_json(&self) -> Json {
        let frontier: Vec<Json> = self
            .frontier
            .iter()
            .map(|r| {
                let m = &r.report.metrics;
                Json::obj([
                    ("label", Json::from(r.label.clone())),
                    ("driver", Json::from(r.report.driver.clone())),
                    ("goodput_rps", Json::from(m.goodput_rps())),
                    ("cost_per_hr", Json::from(cost_per_hr(m))),
                    ("goodput_per_dollar_hr", Json::from(value_of(m))),
                    ("attained", Json::from(m.attained)),
                    ("requests", Json::from(m.n_finished())),
                    ("makespan_s", Json::from(m.makespan_us as f64 / 1e6)),
                ])
            })
            .collect();
        let recommended = match self.recommended_cell() {
            None => Json::Null,
            Some(r) => {
                let sc = r.report.scenario.as_ref();
                let mut pairs = vec![
                    ("label", Json::from(r.label.clone())),
                    ("driver", Json::from(r.report.driver.clone())),
                    ("goodput_per_dollar_hr", Json::from(value_of(&r.report.metrics))),
                ];
                if let Some(sc) = sc {
                    pairs.push(("n_prefill", Json::from(sc.n_prefill)));
                    pairs.push(("n_decode", Json::from(sc.n_decode)));
                    pairs.push(("chunk_size", Json::from(u64::from(sc.chunk_size))));
                    pairs.push((
                        "prefill_policy",
                        Json::from(prefill_policy_key(sc.prefill_policy)),
                    ));
                    pairs.push(("link", Json::from(sc.link.key())));
                }
                Json::obj(pairs)
            }
        };
        Json::obj([
            ("frontier", Json::from(frontier)),
            ("recommended", recommended),
            ("stats", self.stats.to_json()),
        ])
    }
}

/// One cell run: resolve the driver, arm the miss budget, replay the
/// shared trace up to `horizon` requests. A truncated horizon is a
/// *complete* run of the prefix (metrics finalize cleanly); only the miss
/// budget can abort it (`metrics.aborted`).
fn run_cell(sc: &Scenario, trace: &Arc<Vec<Request>>, horizon: usize, budget: u64) -> Report {
    let mut sc = sc.clone();
    sc.stop = StopPolicy { miss_budget: budget, ..StopPolicy::off() };
    let driver = Registry::builtin()
        .resolve(&sc)
        .unwrap_or_else(|e| panic!("optimizer cell '{}': {e}", sc.name));
    let mut src = SharedTraceSource::truncated(trace.clone(), horizon);
    driver.run_source(&mut src, &mut NullObserver)
}

/// Rank cell indices best-first by estimated goodput/$ (stable grid-index
/// tie-break — determinism does not depend on float totality).
fn rank_desc(indices: &mut [usize], states: &[CellState]) {
    indices.sort_by(|&a, &b| {
        states[b]
            .value_est
            .partial_cmp(&states[a].value_est)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
}

/// Run the goodput-per-dollar search over `sc`'s `optimize` grid.
/// Deterministic for a given spec + seed at any worker count. Errors on a
/// missing `optimize` block, an unknown driver on the `drivers` axis, or
/// an empty trace.
pub fn optimize(sc: &Scenario, workers: usize) -> Result<OptimizerResult, String> {
    let t0 = Instant::now();
    let grid = sc.optimize.clone().ok_or("scenario has no 'optimize' block")?;
    let workers = workers.max(1);
    let cells = expand(sc, &grid);

    // fail fast on a bad drivers axis — worker panics are bugs, not input
    // errors, so input errors must never reach the workers
    let registry = Registry::builtin();
    {
        let mut seen: Vec<&str> = Vec::new();
        for c in &cells {
            if !seen.contains(&c.scenario.driver.as_str()) {
                seen.push(&c.scenario.driver);
                registry.resolve(&c.scenario)?;
            }
        }
    }

    // pillar 1: one trace per distinct fingerprint, shared by Arc. The
    // grid axes never enter the generator, so this is one generation for
    // the whole search; the per-cell lookup keeps the code honest if a
    // future axis ever does affect the trace.
    let mut cache = TraceCache::new();
    let traces: Vec<Arc<Vec<Request>>> =
        cells.iter().map(|c| cache.get(&c.scenario)).collect();
    let n = traces.first().map(|t| t.len()).unwrap_or(0);
    if n == 0 {
        return Err("optimize spec generates an empty trace".to_string());
    }

    let mut stats = OptimizerStats { grid_cells: cells.len(), ..Default::default() };
    let mut states: Vec<CellState> = cells
        .into_iter()
        .map(|cell| CellState {
            cell,
            last_h: 0,
            last: None,
            value_est: 0.0,
            events_per_req: 0.0,
        })
        .collect();
    let mut active: Vec<usize> = (0..states.len()).collect();

    // pillar 2: truncated successive halving — short horizons for the
    // whole grid, full length only for the finalists
    // floor of 8 requests per rung, but never past the trace itself
    // (spelled without max().min() — clamp would panic when n < 8)
    let mut h = ((n as f64 * grid.start_fraction).ceil() as usize).max(8);
    if h > n {
        h = n;
    }
    while h < n && active.len() > 1 {
        stats.rungs += 1;
        let budget = miss_budget(grid.min_attainment, h);
        let runs: Vec<(usize, Report)> = {
            let states = &states;
            let traces = &traces;
            parallel_map(active.clone(), workers, move |i| {
                (i, run_cell(&states[i].cell.scenario, &traces[i], h, budget))
            })
        };
        let mut alive = Vec::with_capacity(runs.len());
        for (i, r) in runs {
            stats.events_simulated += r.metrics.events;
            let st = &mut states[i];
            st.events_per_req =
                r.metrics.events as f64 / r.metrics.n_finished().max(1) as f64;
            st.last_h = h;
            st.value_est = value_of(&r.metrics);
            let aborted = r.metrics.aborted;
            st.last = Some(r);
            if aborted {
                // pillar 3a: the miss budget proved this cell's SLO
                // attainment hopeless at this horizon — dead, not ranked
                stats.pruned_slo += 1;
            } else {
                alive.push(i);
            }
        }
        rank_desc(&mut alive, &states);
        let keep = ((alive.len() as f64 * grid.keep_fraction).ceil() as usize).max(1);
        stats.halving_discarded += alive.len().saturating_sub(keep);
        alive.truncate(keep);
        active = alive;
        h = (h * 2).min(n);
    }

    // final stage: full-length runs, best-ranked first so the incumbent
    // is strong early and the dominance bound bites. Waves of `workers`
    // keep the pruning deterministic (decisions only read completed
    // waves) without serializing the runs.
    rank_desc(&mut active, &states);
    let full_budget = miss_budget(grid.min_attainment, n);
    let t_last_arrival_s =
        traces.first().and_then(|t| t.last()).map(|r| r.arrival as f64 / 1e6).unwrap_or(0.0);
    let mut completed: Vec<(usize, Report)> = Vec::new();
    let mut incumbent = f64::NEG_INFINITY;
    for wave in active.chunks(workers) {
        let mut to_run: Vec<usize> = Vec::with_capacity(wave.len());
        for &i in wave {
            // pillar 3b: dominance bound — only ever applied here, against
            // *completed full-length* incumbents (rung-vs-rung pruning is
            // unsound; DESIGN.md §Optimizer derives the bound)
            let mut prune = false;
            if grid.prune && incumbent > f64::NEG_INFINITY {
                if let Some(ref last) = states[i].last {
                    let m = &last.metrics;
                    let cost = cost_per_hr(m);
                    if cost > 0.0 {
                        let attained_ub =
                            m.attained as f64 + (n - states[i].last_h) as f64;
                        let elapsed_lb_s =
                            (m.makespan_us as f64 / 1e6).max(t_last_arrival_s).max(1e-9);
                        let ub = attained_ub / elapsed_lb_s / cost;
                        prune = ub < (1.0 - grid.prune_slack) * incumbent;
                    }
                }
            }
            if prune {
                stats.pruned_dominance += 1;
            } else {
                to_run.push(i);
            }
        }
        let runs: Vec<(usize, Report)> = {
            let states = &states;
            let traces = &traces;
            parallel_map(to_run, workers, move |i| {
                (i, run_cell(&states[i].cell.scenario, &traces[i], n, full_budget))
            })
        };
        for (i, r) in runs {
            stats.events_simulated += r.metrics.events;
            stats.full_runs += 1;
            states[i].events_per_req =
                r.metrics.events as f64 / r.metrics.n_finished().max(1) as f64;
            states[i].last_h = n;
            if r.metrics.aborted {
                stats.pruned_slo += 1;
                continue;
            }
            let v = value_of(&r.metrics);
            if v > incumbent {
                incumbent = v;
            }
            completed.push((i, r));
        }
    }

    // exhaustive-cost estimate: every grid cell at full length, priced at
    // the events/request rate observed at its longest horizon
    stats.events_exhaustive_est =
        states.iter().map(|st| st.events_per_req * n as f64).sum();

    // Pareto frontier over the completed full runs: goodput up, $/hr down
    let points: Vec<(usize, f64, f64)> = completed
        .iter()
        .enumerate()
        .map(|(k, (_, r))| (k, r.metrics.goodput_rps(), cost_per_hr(&r.metrics)))
        .collect();
    let dominated = |&(k, g, c): &(usize, f64, f64)| -> bool {
        points.iter().any(|&(j, gj, cj)| {
            j != k && gj >= g && cj <= c && (gj > g || cj < c)
        })
    };
    let mut frontier_keys: Vec<(usize, f64, f64)> =
        points.iter().filter(|p| !dominated(*p)).copied().collect();
    frontier_keys.sort_by(|a, b| {
        a.2.partial_cmp(&b.2)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
            .then(completed[a.0].0.cmp(&completed[b.0].0))
    });

    // pull the chosen reports out of `completed` without cloning metrics
    let mut picked: Vec<Option<(usize, Report)>> = Vec::new();
    {
        let mut taken: Vec<Option<(usize, Report)>> =
            completed.into_iter().map(Some).collect();
        for &(k, _, _) in &frontier_keys {
            picked.push(taken[k].take());
        }
    }
    let frontier: Vec<CellResult> = picked
        .into_iter()
        .map(|slot| {
            let (i, report) = slot.expect("frontier keys are unique");
            CellResult { label: states[i].cell.label.clone(), report }
        })
        .collect();

    // recommended: max goodput/$ on the frontier (ties: cheaper, then
    // frontier order — which is grid order for identical points)
    let mut recommended: Option<usize> = None;
    let mut best = f64::NEG_INFINITY;
    for (k, r) in frontier.iter().enumerate() {
        let v = value_of(&r.report.metrics);
        if v > best {
            best = v;
            recommended = Some(k);
        }
    }

    stats.wall_secs = t0.elapsed().as_secs_f64();
    Ok(OptimizerResult { frontier, recommended, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::LinkSpec;
    use crate::prefill::PrefillPolicy;
    use crate::workload::WorkloadKind;

    fn base(requests: usize) -> Scenario {
        Scenario::builder()
            .workload(WorkloadKind::Mixed)
            .requests(requests)
            .rate(24.0)
            .seed(11)
            .build()
    }

    #[test]
    fn expansion_covers_the_product_and_inherits_the_base() {
        let mut sc = base(16);
        sc.optimize = Some(OptimizeGrid {
            prefill: vec![1, 2],
            decode: vec![2, 4],
            chunk: vec![256, 512],
            prefill_policy: vec![PrefillPolicy::Sjf, PrefillPolicy::Slo],
            ..Default::default()
        });
        let cells = expand(&sc, sc.optimize.as_ref().unwrap());
        assert_eq!(cells.len(), 16);
        // unsearched axes inherit the base spec
        for c in &cells {
            assert_eq!(c.scenario.link, sc.link);
            assert_eq!(c.scenario.driver, sc.driver);
            assert_eq!(c.scenario.elastic, sc.elastic);
            assert!(c.scenario.optimize.is_none(), "cells must not recurse");
            assert!(!c.scenario.records, "cells must not retain records");
            assert_eq!(c.label, c.scenario.name);
        }
        assert_eq!(cells[0].label, "p1d2c256-sjf");
        // labels are unique
        let mut labels: Vec<&str> = cells.iter().map(|c| c.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 16);
    }

    #[test]
    fn searched_link_elastic_driver_axes_land_in_labels_and_specs() {
        let mut sc = base(16);
        sc.optimize = Some(OptimizeGrid {
            link: vec![LinkSpec::Roce, LinkSpec::Nvlink],
            elastic: vec![0, 6],
            drivers: vec!["tetri".into(), "vllm".into()],
            ..Default::default()
        });
        let cells = expand(&sc, sc.optimize.as_ref().unwrap());
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].label, "p1d1c512-sjf-roce-static-tetri");
        assert!(cells.iter().any(|c| c.label.ends_with("-vllm")));
        let e6 = cells.iter().find(|c| c.label.contains("-e6")).unwrap();
        assert_eq!(e6.scenario.elastic.unwrap().max_instances, 6);
        let st = cells.iter().find(|c| c.label.contains("-static")).unwrap();
        assert!(st.scenario.elastic.is_none());
    }

    #[test]
    fn trace_cache_shares_one_arc_across_grid_cells() {
        let mut sc = base(32);
        sc.optimize = Some(OptimizeGrid {
            prefill: vec![1, 2],
            chunk: vec![256, 512],
            ..Default::default()
        });
        let cells = expand(&sc, sc.optimize.as_ref().unwrap());
        let mut cache = TraceCache::new();
        let first = cache.get(&cells[0].scenario);
        for c in &cells[1..] {
            assert!(
                Arc::ptr_eq(&first, &cache.get(&c.scenario)),
                "grid axes must not fork the trace"
            );
        }
        assert_eq!(cache.len(), 1);
        assert_eq!(first.len(), 32);
        // the cached trace is the scenario's own trace, arrival-sorted
        let mut fresh = cells[0].scenario.trace();
        fresh.sort_by_key(|r| r.arrival);
        assert_eq!(first.len(), fresh.len());
        for (a, b) in first.iter().zip(fresh.iter()) {
            assert_eq!(
                (a.id, a.arrival, a.prompt_len, a.decode_len, a.class),
                (b.id, b.arrival, b.prompt_len, b.decode_len, b.class)
            );
        }
    }

    #[test]
    fn tiny_search_finds_a_frontier_and_accounts_for_its_work() {
        let mut sc = base(48);
        sc.optimize = Some(OptimizeGrid {
            prefill: vec![1, 2],
            decode: vec![1, 2],
            start_fraction: 0.25,
            keep_fraction: 0.5,
            ..Default::default()
        });
        let res = optimize(&sc, 2).unwrap();
        assert_eq!(res.stats.grid_cells, 4);
        assert!(!res.frontier.is_empty(), "classless cells are all feasible");
        let rec = res.recommended_cell().expect("a recommendation");
        // the recommended cell is the max-value frontier point
        for r in &res.frontier {
            assert!(value_of(&rec.report.metrics) >= value_of(&r.report.metrics));
        }
        // frontier is cost-ascending and non-dominated
        for w in res.frontier.windows(2) {
            let (c0, c1) = (cost_per_hr(&w[0].report.metrics), cost_per_hr(&w[1].report.metrics));
            assert!(c0 <= c1, "frontier must be cost-sorted: {c0} vs {c1}");
            assert!(
                w[1].report.metrics.goodput_rps() > w[0].report.metrics.goodput_rps()
                    || (c0 == c1),
                "a higher-cost frontier point must buy goodput"
            );
        }
        // halving ran and saved work
        assert!(res.stats.rungs >= 1);
        assert!(res.stats.full_runs <= res.stats.grid_cells);
        assert!(res.stats.events_simulated > 0);
        assert!(res.stats.events_exhaustive_est > 0.0);
        // CSV rides the sweep serializer
        let csv = res.frontier_csv();
        assert!(csv.starts_with(crate::sweep::RESULTS_CSV_HEADER));
        assert_eq!(csv.lines().count(), 1 + res.frontier.len());
        // JSON is self-consistent
        let j = res.to_json();
        assert_eq!(
            j.at(&["frontier"]).unwrap().as_arr().unwrap().len(),
            res.frontier.len()
        );
        assert!(j.at(&["recommended", "label"]).is_some());
        assert!(j.at(&["stats", "grid_cells"]).is_some());
    }

    #[test]
    fn missing_grid_and_unknown_driver_are_input_errors() {
        let sc = base(8);
        assert!(optimize(&sc, 1).unwrap_err().contains("optimize"));
        let mut bad = base(8);
        bad.optimize =
            Some(OptimizeGrid { drivers: vec!["nope".into()], ..Default::default() });
        assert!(optimize(&bad, 1).unwrap_err().contains("nope"));
    }
}
