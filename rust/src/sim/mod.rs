//! Discrete-event simulation engine: a virtual clock, a monotone event
//! queue, and the shared [`EngineCore`] both DES drivers run on — the
//! arena request store, the pop-dispatch loop ([`run_des`]), per-request
//! finish bookkeeping, and metric finalization. Drivers implement
//! [`EngineHost`] and keep only policy state of their own. Real mode
//! replaces the clock with wall time but reuses all policy code.

pub mod engine;

pub use engine::{run_des, EngineCore, EngineHost, ReqState, NO_TIME};

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::types::Us;

/// Event payloads understood by the cluster driver. Kept as a plain enum
/// (not boxed closures) so runs are deterministic and debuggable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// A request arrives at the global scheduler.
    Arrival(crate::types::ReqId),
    /// A prefill instance finished its current iteration.
    PrefillIterDone { instance: usize },
    /// Sequential-mode length prediction finished for a request.
    PredictDone { instance: usize, req: crate::types::ReqId },
    /// A KV-cache transfer to a decode instance completed.
    TransferDone { instance: usize, req: crate::types::ReqId },
    /// A decode instance finished its current iteration.
    DecodeIterDone { instance: usize },
    /// Cluster monitor tick: refresh load stats, broadcast, maybe flip.
    MonitorTick,
    /// An instance finished draining and flips role (§3.5).
    FlipDone { instance: usize },
    /// Coupled (vLLM baseline) instance finished an iteration.
    CoupledIterDone { instance: usize },
}

#[derive(Clone, Debug, PartialEq, Eq)]
struct Scheduled {
    at: Us,
    seq: u64, // tiebreaker: FIFO among same-time events
    ev: Event,
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Virtual-time event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Scheduled>>,
    now: Us,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> Us {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `ev` at absolute time `at` (clamped to now — the DES never
    /// travels backwards).
    pub fn schedule_at(&mut self, at: Us, ev: Event) {
        let at = at.max(self.now);
        self.heap.push(Reverse(Scheduled { at, seq: self.seq, ev }));
        self.seq += 1;
    }

    /// Schedule `ev` after a delay relative to now.
    pub fn schedule_in(&mut self, delay: Us, ev: Event) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Us, Event)> {
        let Reverse(s) = self.heap.pop()?;
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = s.at;
        Some((s.at, s.ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, Event::MonitorTick);
        q.schedule_at(10, Event::Arrival(1));
        q.schedule_at(20, Event::Arrival(2));
        let order: Vec<Us> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn fifo_among_equal_times() {
        let mut q = EventQueue::new();
        q.schedule_at(5, Event::Arrival(1));
        q.schedule_at(5, Event::Arrival(2));
        q.schedule_at(5, Event::Arrival(3));
        let ids: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                Event::Arrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_in(100, Event::MonitorTick);
        q.pop();
        assert_eq!(q.now(), 100);
        // scheduling in the past clamps to now
        q.schedule_at(50, Event::Arrival(9));
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 100);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_in(10, Event::MonitorTick);
        q.pop();
        q.schedule_in(10, Event::MonitorTick);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 20);
    }
}
