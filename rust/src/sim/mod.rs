//! Discrete-event simulation engine: a virtual clock, a monotone event
//! queue, and the shared [`EngineCore`] both DES drivers run on — the
//! arena request store (slots recycled through a free list so memory is
//! O(in-flight), not O(trace)), the pull-based arrival stream
//! ([`ArrivalSource`]), the pop-dispatch loop ([`run_des_source`]),
//! per-request finish bookkeeping, and metric finalization. Drivers
//! implement [`EngineHost`] and keep only policy state of their own. Real
//! mode replaces the clock with wall time but reuses all policy code.
//!
//! Two event-queue implementations share one API and one pop order:
//! [`CalendarQueue`] — the default, a bucketed timing wheel with O(1)
//! amortized operations — and [`HeapQueue`], the reference `BinaryHeap`
//! kept selectable via the `heap-queue` cargo feature and compared
//! pop-for-pop in tests/proptest_queue.rs and benches/engine.rs.

pub mod engine;

pub use engine::{
    macro_chain, run_des, run_des_source, ArrivalSource, ColdState, EngineCore, EngineHost,
    HotState, SharedTraceSource, StopPolicy, TraceSource, NO_TIME,
};

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::types::Us;

/// Event payloads understood by the cluster driver. Kept as a plain enum
/// (not boxed closures) so runs are deterministic and debuggable.
///
/// Per-instance completion events carry the slot `epoch` they were
/// scheduled under: a crash bumps the slot's epoch without waiting for a
/// drain (unlike flips, which only fire on drained instances), so a
/// completion can outlive the incarnation that scheduled it. Handlers
/// drop stale-epoch deliveries — a restarted incarnation never sees its
/// predecessor's events. Fault-free runs never observe a mismatch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// A request arrives at the global scheduler.
    Arrival(crate::types::ReqId),
    /// A prefill instance finished its current iteration.
    PrefillIterDone { instance: usize, epoch: u32 },
    /// Sequential-mode length prediction finished for a request.
    PredictDone { instance: usize, epoch: u32, req: crate::types::ReqId },
    /// A KV-cache transfer to a decode instance completed.
    TransferDone { instance: usize, epoch: u32, req: crate::types::ReqId },
    /// A decode instance finished its current iteration.
    DecodeIterDone { instance: usize, epoch: u32 },
    /// Cluster monitor tick: refresh load stats, broadcast, maybe flip.
    MonitorTick,
    /// An instance finished draining and flips role (§3.5).
    FlipDone { instance: usize },
    /// Coupled (vLLM baseline) instance finished an iteration.
    CoupledIterDone { instance: usize, epoch: u32 },
    /// Deliver fault-plan event `k` (index into `FaultConfig::events`).
    Fault(usize),
    /// A crashed instance's downtime elapsed: restart it with fresh state.
    Restart { instance: usize },
    /// Backoff timer for a fault-lost request expired: re-queue it.
    Retry(crate::types::ReqId),
}

impl Event {
    /// Dense per-variant index into the `--profile-events` table; order
    /// matches [`crate::metrics::EventProfile::NAMES`].
    pub fn kind_index(&self) -> usize {
        match self {
            Event::Arrival(_) => 0,
            Event::PrefillIterDone { .. } => 1,
            Event::PredictDone { .. } => 2,
            Event::TransferDone { .. } => 3,
            Event::DecodeIterDone { .. } => 4,
            Event::MonitorTick => 5,
            Event::FlipDone { .. } => 6,
            Event::CoupledIterDone { .. } => 7,
            Event::Fault(_) => 8,
            Event::Restart { .. } => 9,
            Event::Retry(_) => 10,
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
struct Scheduled {
    at: Us,
    seq: u64, // tiebreaker: FIFO among same-time events
    ev: Event,
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue both DES drivers run on. The calendar queue is the
/// default; building with `--features heap-queue` pins the reference
/// heap (perf A/B runs and divergence debugging).
#[cfg(not(feature = "heap-queue"))]
pub type EventQueue = CalendarQueue;
#[cfg(feature = "heap-queue")]
pub type EventQueue = HeapQueue;

/// Reference virtual-time event queue: one global `BinaryHeap` ordered by
/// `(at, seq)`. O(log n) per operation where n is every pending event in
/// the run. Kept as the behavioral oracle: [`CalendarQueue`] must match
/// its pop order bit for bit (tests/proptest_queue.rs).
#[derive(Debug, Default)]
pub struct HeapQueue {
    heap: BinaryHeap<Reverse<Scheduled>>,
    now: Us,
    seq: u64,
}

impl HeapQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> Us {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `ev` at absolute time `at` (clamped to now — the DES never
    /// travels backwards).
    pub fn schedule_at(&mut self, at: Us, ev: Event) {
        let at = at.max(self.now);
        self.heap.push(Reverse(Scheduled { at, seq: self.seq, ev }));
        self.seq += 1;
    }

    /// Schedule `ev` after a delay relative to now.
    pub fn schedule_in(&mut self, delay: Us, ev: Event) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Bulk insertion, reference semantics: exactly a loop of
    /// [`HeapQueue::schedule_at`] calls in input order (same clamping,
    /// same seq stamps). The oracle [`CalendarQueue::push_batch`] must
    /// match pop for pop (tests/proptest_queue.rs).
    pub fn push_batch(&mut self, events: impl IntoIterator<Item = (Us, Event)>) {
        for (at, ev) in events {
            self.schedule_at(at, ev);
        }
    }

    /// Empty the queue and rewind the clock/seq counter to a fresh state,
    /// keeping the heap's allocation. A reset queue is indistinguishable
    /// from [`HeapQueue::new`] except for capacity — the property the
    /// persistent sweep-worker contexts rely on.
    pub fn reset(&mut self) {
        self.heap.clear();
        self.now = 0;
        self.seq = 0;
    }

    /// Time of the next event without popping it (`&mut self` for API
    /// parity with the calendar queue, whose peek settles its cursor).
    pub fn peek_at(&mut self) -> Option<Us> {
        self.heap.peek().map(|Reverse(s)| s.at)
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Us, Event)> {
        let Reverse(s) = self.heap.pop()?;
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = s.at;
        Some((s.at, s.ev))
    }

    /// Advance the clock without popping (the engine delivers arrivals
    /// from outside the queue). `t` must not pass any queued event.
    pub fn advance_to(&mut self, t: Us) {
        debug_assert!(
            !self.heap.peek().is_some_and(|Reverse(s)| s.at < t),
            "advance_to past a pending event"
        );
        self.now = self.now.max(t);
    }
}

/// 2^12 µs ≈ 4 ms per bucket: decode/prefill iteration completions — the
/// dominant event class — land within a handful of buckets of `now`.
const BUCKET_SHIFT: u32 = 12;
/// Ring size (power of two): the wheel covers ~4.2 s of virtual time
/// ahead of the cursor before events spill into the overflow heap
/// (monitor retries, flip completions, long quiet gaps).
const N_BUCKETS: usize = 1024;

/// Calendar (timing-wheel) event queue: events are bucketed by time into
/// a power-of-two ring of tiny per-bucket heaps; far-future events park
/// in an overflow heap and migrate into the ring as the window slides.
///
/// Pop order is identical to [`HeapQueue`] — global `(at, seq)` — because
/// a bucket's heap orders its few co-bucketed events exactly, and across
/// buckets time strictly increases. The parity proptest pins this bit for
/// bit, including overflow migration, clamped past-scheduling, and
/// equal-time FIFO bursts.
///
/// Why it wins: push/pop touch one heap of O(events-per-4ms) entries
/// instead of one global heap over every pending event, so event handling
/// is O(1) amortized at any queue depth — the property the million-request
/// runs lean on (see DESIGN.md §Performance).
#[derive(Debug)]
pub struct CalendarQueue {
    ring: Vec<BinaryHeap<Reverse<Scheduled>>>,
    overflow: BinaryHeap<Reverse<Scheduled>>,
    /// Events currently in `ring` (the rest sit in `overflow`).
    ring_len: usize,
    len: usize,
    /// Absolute bucket index the pop scan stands at. Invariant: never
    /// ahead of the bucket of any queued event (pushes into earlier
    /// buckets pull it back).
    cursor: u64,
    now: Us,
    seq: u64,
}

impl CalendarQueue {
    #[inline]
    fn bucket_of(at: Us) -> u64 {
        at >> BUCKET_SHIFT
    }

    pub fn new() -> Self {
        CalendarQueue {
            ring: (0..N_BUCKETS).map(|_| BinaryHeap::new()).collect(),
            overflow: BinaryHeap::new(),
            ring_len: 0,
            len: 0,
            cursor: 0,
            now: 0,
            seq: 0,
        }
    }

    pub fn now(&self) -> Us {
        self.now
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Schedule `ev` at absolute time `at` (clamped to now — the DES never
    /// travels backwards).
    pub fn schedule_at(&mut self, at: Us, ev: Event) {
        let at = at.max(self.now);
        let s = Scheduled { at, seq: self.seq, ev };
        self.seq += 1;
        self.len += 1;
        let b = Self::bucket_of(at);
        if b < self.cursor {
            // a peek had settled the cursor past this bucket: re-open the
            // scan window (b ≥ bucket_of(now), so the invariant holds)
            self.cursor = b;
        }
        if b < self.cursor + N_BUCKETS as u64 {
            self.ring[(b as usize) & (N_BUCKETS - 1)].push(Reverse(s));
            self.ring_len += 1;
        } else {
            self.overflow.push(Reverse(s));
        }
    }

    /// Schedule `ev` after a delay relative to now.
    pub fn schedule_in(&mut self, delay: Us, ev: Event) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Bulk insertion: admits `events` with exactly the clamping and seq
    /// stamps a sequence of [`CalendarQueue::schedule_at`] calls in input
    /// order would assign — pop order is identical by construction — but
    /// rebuilds each touched ring bucket's heap once with an O(k)
    /// heapify instead of k per-event sift-ups, and pulls the cursor
    /// back at most once for the whole batch. Intended for fan-out sites
    /// that enqueue many events at one go (pre-seeded fault plans, chunk
    /// fan-outs); parity vs sequential push is pinned bit for bit in
    /// tests/proptest_queue.rs.
    pub fn push_batch(&mut self, events: impl IntoIterator<Item = (Us, Event)>) {
        let mut staged: Vec<Scheduled> = events
            .into_iter()
            .map(|(at, ev)| {
                let s = Scheduled { at: at.max(self.now), seq: self.seq, ev };
                self.seq += 1;
                s
            })
            .collect();
        if staged.is_empty() {
            return;
        }
        self.len += staged.len();
        // One cursor pull-back for the whole batch keeps the invariant
        // (the cursor never stands ahead of any queued event's bucket);
        // classifying every item against the settled cursor may park a
        // window-edge event in the overflow where sequential pushes would
        // have ringed it, but the overflow only ever holds events at or
        // beyond the window, so migration delivers them in (at, seq)
        // order all the same.
        let min_b = staged.iter().map(|s| Self::bucket_of(s.at)).min().expect("non-empty batch");
        if min_b < self.cursor {
            self.cursor = min_b;
        }
        let end = self.cursor + N_BUCKETS as u64;
        // Group by bucket so each touched heap is drained, extended, and
        // re-heapified exactly once ("sorts once per bucket"). Order
        // within a bucket is irrelevant — the heap orders by (at, seq).
        staged.sort_unstable_by_key(|s| Self::bucket_of(s.at));
        let mut i = 0;
        while i < staged.len() {
            let b = Self::bucket_of(staged[i].at);
            let mut j = i + 1;
            while j < staged.len() && Self::bucket_of(staged[j].at) == b {
                j += 1;
            }
            if b < end {
                let slot = (b as usize) & (N_BUCKETS - 1);
                let mut v = std::mem::take(&mut self.ring[slot]).into_vec();
                v.extend(staged[i..j].iter().map(|s| Reverse(s.clone())));
                self.ring[slot] = BinaryHeap::from(v);
                self.ring_len += j - i;
            } else {
                self.overflow.extend(staged[i..j].iter().map(|s| Reverse(s.clone())));
            }
            i = j;
        }
    }

    /// Empty the queue and rewind the clock, cursor, and seq counter to a
    /// fresh state, keeping the ring and every per-bucket heap's grown
    /// allocation. A reset queue is indistinguishable from
    /// [`CalendarQueue::new`] except for capacity — the property the
    /// persistent sweep-worker contexts rely on (runs can end with
    /// undelivered events still queued, e.g. a scheduled restart after
    /// the last finish, so every heap is cleared explicitly).
    pub fn reset(&mut self) {
        for h in self.ring.iter_mut() {
            h.clear();
        }
        self.overflow.clear();
        self.ring_len = 0;
        self.len = 0;
        self.cursor = 0;
        self.now = 0;
        self.seq = 0;
    }

    /// Move overflow events whose bucket slid inside the ring window.
    fn migrate(&mut self) {
        let end = self.cursor + N_BUCKETS as u64;
        while self.overflow.peek().is_some_and(|Reverse(s)| Self::bucket_of(s.at) < end) {
            let Reverse(s) = self.overflow.pop().expect("peeked above");
            self.ring[(Self::bucket_of(s.at) as usize) & (N_BUCKETS - 1)].push(Reverse(s));
            self.ring_len += 1;
        }
    }

    /// Walk the cursor to the bucket holding the earliest event and
    /// return its ring slot (None when empty). After settling, the
    /// earliest event is always in the ring — the overflow only holds
    /// events beyond the window.
    fn settle(&mut self) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        loop {
            if self.ring_len == 0 {
                // everything is far future: jump the window to it
                let at = self
                    .overflow
                    .peek()
                    .map(|Reverse(s)| s.at)
                    .expect("non-empty queue with empty ring must have overflow");
                self.cursor = Self::bucket_of(at);
                self.migrate();
                continue;
            }
            let slot = (self.cursor as usize) & (N_BUCKETS - 1);
            if let Some(Reverse(head)) = self.ring[slot].peek() {
                // A slot can host events from a later wheel revolution
                // (the cursor was pulled back by a push after advancing);
                // only a head in *this* bucket stops the scan — anything
                // later must wait for buckets in between.
                if Self::bucket_of(head.at) == self.cursor {
                    return Some(slot);
                }
            }
            self.cursor += 1;
            self.migrate();
        }
    }

    /// Time of the next event without popping it (settles the cursor).
    pub fn peek_at(&mut self) -> Option<Us> {
        let slot = self.settle()?;
        self.ring[slot].peek().map(|Reverse(s)| s.at)
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Us, Event)> {
        let slot = self.settle()?;
        let Reverse(s) = self.ring[slot].pop().expect("settle returned a non-empty slot");
        self.len -= 1;
        self.ring_len -= 1;
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = s.at;
        Some((s.at, s.ev))
    }

    /// Advance the clock without popping (the engine delivers arrivals
    /// from outside the queue). `t` must not pass any queued event.
    pub fn advance_to(&mut self, t: Us) {
        if t <= self.now {
            return;
        }
        // Settle unconditionally — NOT inside the debug_assert — so debug
        // and release builds execute identical queue code (the parity
        // proptests run in debug and must cover exactly what release
        // scale runs execute). With any event queued, settling already
        // walked the cursor to that event's bucket, which is ≥
        // bucket_of(t) since nothing may precede t; the jump below then
        // only fires on an empty queue, keeping the window fresh for
        // future pushes.
        let _head = self.peek_at();
        debug_assert!(!_head.is_some_and(|p| p < t), "advance_to past a pending event");
        self.now = t;
        let b = Self::bucket_of(t);
        if b > self.cursor {
            self.cursor = b;
            self.migrate();
        }
    }
}

impl Default for CalendarQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, Event::MonitorTick);
        q.schedule_at(10, Event::Arrival(1));
        q.schedule_at(20, Event::Arrival(2));
        let order: Vec<Us> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn fifo_among_equal_times() {
        let mut q = EventQueue::new();
        q.schedule_at(5, Event::Arrival(1));
        q.schedule_at(5, Event::Arrival(2));
        q.schedule_at(5, Event::Arrival(3));
        let ids: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                Event::Arrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_in(100, Event::MonitorTick);
        q.pop();
        assert_eq!(q.now(), 100);
        // scheduling in the past clamps to now
        q.schedule_at(50, Event::Arrival(9));
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 100);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_in(10, Event::MonitorTick);
        q.pop();
        q.schedule_in(10, Event::MonitorTick);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 20);
    }

    #[test]
    fn overflow_events_pop_in_order() {
        // far beyond the ring window (~4.2 s), plus near events: the
        // migration path must deliver everything in global time order
        let mut q = CalendarQueue::new();
        q.schedule_at(60_000_000_000, Event::Arrival(4)); // ~16.7 h out
        q.schedule_at(10_000_000, Event::Arrival(2)); // past the window
        q.schedule_at(100, Event::Arrival(1));
        q.schedule_at(10_000_001, Event::Arrival(3));
        let ids: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                Event::Arrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3, 4]);
        assert_eq!(q.now(), 60_000_000_000);
    }

    #[test]
    fn peek_does_not_disturb_pop_order() {
        let mut q = CalendarQueue::new();
        q.schedule_at(7_000_000, Event::Arrival(2));
        assert_eq!(q.peek_at(), Some(7_000_000));
        // a push *behind* the settled cursor must still pop first
        q.schedule_at(5, Event::Arrival(1));
        assert_eq!(q.peek_at(), Some(5));
        assert!(matches!(q.pop(), Some((5, Event::Arrival(1)))));
        assert!(matches!(q.pop(), Some((7_000_000, Event::Arrival(2)))));
        assert!(q.pop().is_none() && q.is_empty());
    }

    #[test]
    fn advance_to_jumps_the_window() {
        let mut q = CalendarQueue::new();
        q.schedule_at(90_000_000, Event::Arrival(1));
        q.advance_to(60_000_000); // long quiet gap, no event passed
        assert_eq!(q.now(), 60_000_000);
        // post-jump scheduling lands relative to the new now
        q.schedule_in(10, Event::Arrival(0));
        assert!(matches!(q.pop(), Some((60_000_010, Event::Arrival(0)))));
        assert!(matches!(q.pop(), Some((90_000_000, Event::Arrival(1)))));
    }

    #[test]
    fn push_batch_matches_sequential_pushes() {
        // smoke parity here (same-time storm, cross-bucket span, deep
        // overflow, past-clamp); the exhaustive randomized version lives
        // in tests/proptest_queue.rs
        let ats = [5u64, 5, 5, 4_095, 4_096, 70, 9_000_000, 60_000_000_000, 0, 8_191];
        let mut batched = CalendarQueue::new();
        let mut seq = CalendarQueue::new();
        // advance both past t=60 so the t=0/t=5 entries exercise clamping
        batched.schedule_at(60, Event::MonitorTick);
        seq.schedule_at(60, Event::MonitorTick);
        batched.pop();
        seq.pop();
        batched.push_batch(ats.iter().enumerate().map(|(i, &at)| (at, Event::Arrival(i as u64))));
        for (i, &at) in ats.iter().enumerate() {
            seq.schedule_at(at, Event::Arrival(i as u64));
        }
        loop {
            let (a, b) = (batched.pop(), seq.pop());
            assert_eq!(a, b);
            assert_eq!(batched.now(), seq.now());
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn reset_restores_a_fresh_queue_keeping_capacity() {
        let mut q = CalendarQueue::new();
        q.schedule_at(7, Event::Arrival(1));
        q.schedule_at(9_000_000, Event::MonitorTick);
        q.schedule_at(60_000_000_000, Event::Arrival(2)); // parks in overflow
        q.pop();
        q.reset();
        assert!(q.is_empty() && q.pop().is_none());
        assert_eq!(q.now(), 0);
        // a reset queue behaves exactly like a new one, including seq
        // numbering (FIFO among equal times restarts from scratch)
        q.schedule_at(5, Event::Arrival(10));
        q.schedule_at(5, Event::Arrival(11));
        assert!(matches!(q.pop(), Some((5, Event::Arrival(10)))));
        assert!(matches!(q.pop(), Some((5, Event::Arrival(11)))));
        let mut h = HeapQueue::new();
        h.schedule_at(3, Event::MonitorTick);
        h.pop();
        h.reset();
        assert!(h.is_empty());
        assert_eq!(h.now(), 0);
    }

    #[test]
    fn event_kind_indices_are_dense_and_stable() {
        let evs = [
            Event::Arrival(0),
            Event::PrefillIterDone { instance: 0, epoch: 0 },
            Event::PredictDone { instance: 0, epoch: 0, req: 0 },
            Event::TransferDone { instance: 0, epoch: 0, req: 0 },
            Event::DecodeIterDone { instance: 0, epoch: 0 },
            Event::MonitorTick,
            Event::FlipDone { instance: 0 },
            Event::CoupledIterDone { instance: 0, epoch: 0 },
            Event::Fault(0),
            Event::Restart { instance: 0 },
            Event::Retry(0),
        ];
        for (i, ev) in evs.iter().enumerate() {
            assert_eq!(ev.kind_index(), i);
        }
        assert_eq!(evs.len(), crate::metrics::EventProfile::KINDS);
    }

    #[test]
    fn calendar_matches_heap_on_a_mixed_schedule() {
        // smoke parity here; the exhaustive randomized version lives in
        // tests/proptest_queue.rs
        let mut cal = CalendarQueue::new();
        let mut heap = HeapQueue::new();
        let ats = [5u64, 5, 0, 4_095, 4_096, 8_191, 5_000_000, 5_000_000, 7, 60_000_000_000];
        for (i, &at) in ats.iter().enumerate() {
            cal.schedule_at(at, Event::Arrival(i as u64));
            heap.schedule_at(at, Event::Arrival(i as u64));
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            assert_eq!(a, b);
            assert_eq!(cal.now(), heap.now());
            if a.is_none() {
                break;
            }
        }
    }
}
