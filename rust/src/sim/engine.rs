//! The DES engine core: the piece of a driver that is *not* policy.
//!
//! Before this existed, `coordinator/cluster.rs` and `baseline/mod.rs`
//! each owned a private copy of the same machinery — the arena request
//! store, the pop-dispatch event loop, the per-request finish bookkeeping,
//! and the end-of-run metric finalization. [`EngineCore`] owns all of
//! that once; a driver keeps a core as a field, implements [`EngineHost`]
//! for its event handling and lifecycle hooks, and [`run_des_source`]
//! drives the run. Drivers shrink to policy glue: routing, two-level
//! scheduling, flip/scale decisions.
//!
//! Since the million-request refactor the engine is O(active), not
//! O(trace): arrivals stream in one at a time from an [`ArrivalSource`]
//! (exactly one is pending at any instant, held outside the queue), and
//! finished arena slots recycle through a free list so the arena tracks
//! peak *in-flight* requests. Delivery order is bit-identical to the old
//! pre-scheduled heap: arrivals win ties against queued events (they used
//! to carry the smallest seq numbers), equal-time arrivals keep source
//! order, and re-delivered `Event::Arrival` retries ride the queue like
//! any runtime event.
//!
//! The observer fan-out contract is unchanged: hooks fire at the instant
//! an action is issued, and observers never influence the run.

use crate::api::Observer;
use crate::metrics::RunMetrics;
use crate::types::{ReqId, ReqMeta, Request, RequestRecord, Us};

use super::{Event, EventQueue};

/// Sentinel for "first token not yet produced".
pub const NO_TIME: Us = Us::MAX;

/// Early-stop knobs for a run (all off by default — the normal
/// run-to-completion semantics). The optimizer's truncated
/// successive-halving rungs and its SLO-hopeless abort both ride these:
/// the loop checks the policy *between* events, so a cutoff never lands
/// mid-handler and [`EngineCore::finalize`] still stamps a clean
/// makespan/peak/profile snapshot of everything simulated so far. A run
/// cut short marks [`RunMetrics::aborted`]; the conservation law
/// `finished + shed + failed == arrivals` intentionally does not hold
/// for aborted runs (in-flight requests are simply never counted).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StopPolicy {
    /// Stop once this many requests reached an outcome (finish, shed, or
    /// fail). `0` = off.
    pub max_requests: usize,
    /// Stop before handling any event past this virtual time — the clock
    /// never advances beyond the horizon. [`NO_TIME`] = off.
    pub horizon_us: Us,
    /// Abort once the running count of non-attained outcomes
    /// (SLO-violating finishes + sheds + fails) *exceeds* this budget —
    /// the optimizer's "attainment already hopeless" prune. The count is
    /// monotone in events handled, so the check is an exact lower bound
    /// on the run's final violations. `u64::MAX` = off.
    pub miss_budget: u64,
}

impl Default for StopPolicy {
    fn default() -> Self {
        StopPolicy { max_requests: 0, horizon_us: NO_TIME, miss_budget: u64::MAX }
    }
}

impl StopPolicy {
    /// The run-to-completion default (no knob armed).
    pub fn off() -> Self {
        Self::default()
    }

    pub fn is_off(&self) -> bool {
        self == &Self::default()
    }
}

/// A pull-based stream of requests in non-decreasing arrival order. The
/// engine admits them into the arena lazily, so a million-request run
/// holds one pending `Request`, not a million. Implementations:
/// [`TraceSource`] (replay a materialized trace) and
/// [`crate::workload::GenSource`] (sample straight from the generator).
pub trait ArrivalSource {
    /// The next request, or `None` once the source is exhausted. Arrival
    /// times must be non-decreasing (trace-backed sources sort first).
    fn next_request(&mut self) -> Option<Request>;

    /// Total requests this source yields over its lifetime (the DES
    /// termination condition and the progress denominator).
    fn total(&self) -> usize;
}

/// Replay a materialized trace. Sorts by arrival time on construction —
/// *stably*, so equal-time requests keep trace order: exactly the
/// `(at, seq)` order the old pre-scheduled heap produced, for sorted and
/// unsorted traces alike.
pub struct TraceSource {
    trace: Vec<Request>,
    pos: usize,
}

impl TraceSource {
    pub fn new(mut trace: Vec<Request>) -> Self {
        trace.sort_by_key(|r| r.arrival);
        TraceSource { trace, pos: 0 }
    }

    /// One memcpy of the Copy-POD trace (~50 B/request) so callers can
    /// re-run the same borrowed trace; noise next to the DES run itself.
    pub fn from_slice(trace: &[Request]) -> Self {
        Self::new(trace.to_vec())
    }
}

impl ArrivalSource for TraceSource {
    fn next_request(&mut self) -> Option<Request> {
        let r = self.trace.get(self.pos).copied()?;
        self.pos += 1;
        Some(r)
    }

    fn total(&self) -> usize {
        self.trace.len()
    }
}

/// Replay an `Arc`-shared, pre-sorted trace zero-copy — the optimizer's
/// trace-memoization primitive. Every grid cell sharing a (workload,
/// classes, prefix, seed) fingerprint replays the *same* materialized
/// trace through its own `SharedTraceSource`, so a 1000-cell grid
/// generates arrivals once instead of 1000 times. `truncated` caps the
/// replay at a request-count horizon for successive-halving rungs: the
/// engine sees a complete `limit`-request run (clean totals, clean
/// finalize), not an aborted one.
///
/// The trace must already be in non-decreasing arrival order (the
/// [`ArrivalSource`] contract). Callers sort once at materialization
/// with the same stable `sort_by_key(arrival)` as [`TraceSource::new`] —
/// bit-parity with per-cell generation is pinned in tests/optimizer.rs.
pub struct SharedTraceSource {
    trace: std::sync::Arc<Vec<Request>>,
    pos: usize,
    limit: usize,
}

impl SharedTraceSource {
    /// Replay the whole shared trace.
    pub fn new(trace: std::sync::Arc<Vec<Request>>) -> Self {
        let limit = trace.len();
        SharedTraceSource { trace, pos: 0, limit }
    }

    /// Replay only the first `limit` requests (clamped to the trace
    /// length) — a successive-halving rung's horizon.
    pub fn truncated(trace: std::sync::Arc<Vec<Request>>, limit: usize) -> Self {
        let limit = limit.min(trace.len());
        SharedTraceSource { trace, pos: 0, limit }
    }
}

impl ArrivalSource for SharedTraceSource {
    fn next_request(&mut self) -> Option<Request> {
        if self.pos >= self.limit {
            return None;
        }
        let r = self.trace[self.pos];
        self.pos += 1;
        Some(r)
    }

    fn total(&self) -> usize {
        self.limit
    }
}

/// Hot arena lane, parallel to the `Request` payload lane: the two
/// driver-side fields the mid-flight pipeline writes (first token at
/// prefill completion, the KV-holding prefill instance at dispatch).
/// Split out of the old AoS `ReqState` so iteration-time reads of the
/// `Request` payload stay cache-dense (DESIGN.md §Performance, SoA
/// layout). The coupled baseline simply never touches `prefilled_by`.
#[derive(Clone, Copy, Debug)]
pub struct HotState {
    pub first_token: Us,
    /// The prefill instance (and its epoch) holding this request's prompt
    /// KV until the transfer out completes. Consumed (`take`n) exactly
    /// once; the epoch guards against the instance leaving its role and
    /// coming back while the KV is in flight (a reborn incarnation must
    /// not have a stale release land on its counter).
    pub prefilled_by: Option<(usize, u32)>,
}

/// Cold arena lane: per-slot bookkeeping touched only at arrival, fault,
/// and finish time — never inside an iteration. Lives in its own side
/// table so the hot lanes above stay dense.
#[derive(Clone, Copy, Debug)]
pub struct ColdState {
    /// The arrival event fired at least once (mid-flip retries re-enqueue
    /// `Event::Arrival`; observers must see one arrival per request).
    pub seen: bool,
    /// The request lost in-flight state to a fault at least once; stamped
    /// onto the final record so recovered completions are countable.
    pub recovered: bool,
    /// Times this request was re-queued after a fault destroyed its
    /// in-flight state (crashed instance, dead KV). Bounded by the fault
    /// plan's retry budget; 0 in fault-free runs.
    pub retries: u32,
    /// Virtual time of the *first* fault loss ([`NO_TIME`] = never lost) —
    /// the recovery-latency clock starts here and stops at finish.
    pub lost_at: Us,
}

/// Reusable engine buffers a finished run parks for the next run on the
/// same thread: the arena lanes, the free list, and the event queue
/// (whose calendar ring and per-bucket heaps are the expensive part)
/// keep their grown capacities across cells. Sweep workers run many
/// cells back to back, so this is what makes `parallel_map` worker
/// contexts persistent. Pure allocation reuse: the lanes are emptied and
/// the queue reset before parking, and no capacity is ever observable in
/// a trajectory, so reuse is bit-identical to fresh construction
/// (parity-tested in `sweep::tests` and tests/golden.rs).
struct CoreBuffers {
    queue: super::EventQueue,
    requests: Vec<Request>,
    hot: Vec<HotState>,
    cold: Vec<ColdState>,
    free_slots: Vec<ReqId>,
}

impl Default for CoreBuffers {
    fn default() -> Self {
        CoreBuffers {
            queue: EventQueue::new(),
            requests: Vec::new(),
            hot: Vec::new(),
            cold: Vec::new(),
            free_slots: Vec::new(),
        }
    }
}

thread_local! {
    /// The parked buffers of the last run finished on this thread.
    static SALVAGE: std::cell::RefCell<Option<CoreBuffers>> =
        const { std::cell::RefCell::new(None) };
}

/// Queue + arena + metrics + termination condition: the state every DES
/// driver shares. Drivers own one and layer policy state next to it.
pub struct EngineCore {
    pub queue: EventQueue,
    /// Request payload arena indexed by slot (events carry slots, not
    /// original request ids). Finished slots recycle through the free
    /// list, so the arena's length is the run's *peak in-flight* request
    /// count — the O(active) memory property the scale runs depend on.
    /// `hot` and `cold` are parallel lanes over the same slots.
    pub requests: Vec<Request>,
    /// Hot SoA lane, parallel to `requests` (see [`HotState`]).
    pub hot: Vec<HotState>,
    /// Cold SoA lane, parallel to `requests` (see [`ColdState`]).
    pub cold: Vec<ColdState>,
    /// Recycled arena slots awaiting reuse (LIFO, deterministic).
    free_slots: Vec<ReqId>,
    /// Requests remaining (termination condition).
    pub outstanding: usize,
    /// Total requests the arrival source delivers over the whole run
    /// (what "trace length" used to mean to drivers).
    pub total_expected: usize,
    /// Arrival time of the next source request not yet admitted
    /// ([`NO_TIME`] once exhausted) — one half of the macro-step bound.
    next_arrival_at: Us,
    /// Early-stop knobs (see [`StopPolicy`]); off by default. Drivers
    /// copy their config's policy in right after construction, next to
    /// `retain_records`.
    pub stop: StopPolicy,
    pub metrics: RunMetrics,
    /// When set (`--profile-events`), the event loop times every handled
    /// event into this per-kind table; [`EngineCore::finalize`] moves it
    /// into the metrics. Boxed so the common unprofiled case costs one
    /// pointer in the core.
    pub profile: Option<Box<crate::metrics::EventProfile>>,
}

impl EngineCore {
    /// A core with per-instance metric vectors sized for `n_insts`.
    /// Record retention defaults on; drivers override it from their
    /// config before the run starts. Reuses this thread's parked
    /// [`CoreBuffers`] when a previous run left some (sweep workers run
    /// many cells back to back); trajectory-neutral — see `CoreBuffers`.
    pub fn new(n_insts: usize) -> Self {
        let buffers = SALVAGE.with(|s| s.borrow_mut().take()).unwrap_or_default();
        EngineCore {
            queue: buffers.queue,
            requests: buffers.requests,
            hot: buffers.hot,
            cold: buffers.cold,
            free_slots: buffers.free_slots,
            outstanding: 0,
            total_expected: 0,
            next_arrival_at: NO_TIME,
            stop: StopPolicy::off(),
            metrics: RunMetrics {
                retain_records: true,
                busy_us: vec![0; n_insts],
                alive_us: vec![0; n_insts],
                decode_assign: vec![(0, 0); n_insts],
                ..Default::default()
            },
            profile: None,
        }
    }

    /// Park this core's reusable buffers (emptied) for the next run on
    /// this thread. Called by `run_des_source` after `finalize`.
    fn salvage(&mut self) {
        let mut queue = std::mem::take(&mut self.queue);
        let mut requests = std::mem::take(&mut self.requests);
        let mut hot = std::mem::take(&mut self.hot);
        let mut cold = std::mem::take(&mut self.cold);
        let mut free_slots = std::mem::take(&mut self.free_slots);
        queue.reset();
        requests.clear();
        hot.clear();
        cold.clear();
        free_slots.clear();
        SALVAGE.with(|s| {
            *s.borrow_mut() = Some(CoreBuffers { queue, requests, hot, cold, free_slots });
        });
    }

    pub fn now(&self) -> Us {
        self.queue.now()
    }

    /// Earliest external event that can reach any instance: the queue's
    /// head or the next source arrival. Drivers macro-step decode chains
    /// strictly *before* this instant (DESIGN.md §Performance has the
    /// determinism argument).
    pub fn next_external_at(&mut self) -> Us {
        let q = self.queue.peek_at().unwrap_or(NO_TIME);
        q.min(self.next_arrival_at)
    }

    /// Whether an armed [`StopPolicy`] knob says to cut the run here,
    /// checked between events by `run_des_source`. Three compares when
    /// every knob is off — negligible against an event dispatch.
    fn should_stop(&mut self) -> bool {
        let sp = self.stop;
        if sp.max_requests > 0 && self.total_expected - self.outstanding >= sp.max_requests {
            return true;
        }
        if sp.miss_budget != u64::MAX {
            let m = &self.metrics;
            if (m.finished - m.attained) + m.shed + m.failed > sp.miss_budget {
                return true;
            }
        }
        if sp.horizon_us != NO_TIME && self.next_external_at() > sp.horizon_us {
            return true;
        }
        false
    }

    /// Admit one request into the arena, recycling a finished slot when
    /// one is free. Events carry the returned slot from here on; the
    /// original request id resurfaces only in the final `RequestRecord`.
    pub fn admit(&mut self, req: Request) -> ReqId {
        let hot = HotState { first_token: NO_TIME, prefilled_by: None };
        let cold = ColdState { seen: false, recovered: false, retries: 0, lost_at: NO_TIME };
        match self.free_slots.pop() {
            Some(slot) => {
                self.requests[slot as usize] = req;
                self.hot[slot as usize] = hot;
                self.cold[slot as usize] = cold;
                slot
            }
            None => {
                // Arena growth is a capacity event, not steady state: the
                // lanes only push while peak in-flight is still rising.
                let _cold = crate::util::cold_section();
                self.requests.push(req);
                self.hot.push(hot);
                self.cold.push(cold);
                (self.requests.len() - 1) as ReqId
            }
        }
    }

    /// Whether the arrival hook already fired for this slot (cold lane).
    pub fn seen(&self, slot: ReqId) -> bool {
        self.cold[slot as usize].seen
    }

    /// Scheduler-facing view of an arena slot (slot becomes the id).
    pub fn meta_of(&self, slot: ReqId) -> ReqMeta {
        let r = &self.requests[slot as usize];
        ReqMeta {
            id: slot,
            task: r.task,
            class: r.class,
            arrival: r.arrival,
            prompt_len: r.prompt_len,
            predicted: r.predicted,
            prefix: r.prefix,
        }
    }

    /// Admitted-but-unfinished requests currently in the arena — the
    /// queue-depth input every driver feeds the admission gate. Computed
    /// the same way in every driver, but its *value* tracks the driver's
    /// own serving speed: queue-depth sheds deliberately respond to each
    /// system's congestion (see `slo::AdmissionGate`). Includes the
    /// arrival being handled, if any.
    pub fn in_flight(&self) -> usize {
        self.requests.len() - self.free_slots.len()
    }

    /// Fire the observer's arrival hook exactly once per request,
    /// whatever number of times the arrival event is re-delivered.
    pub fn note_arrival(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        if !self.cold[slot as usize].seen {
            self.cold[slot as usize].seen = true;
            let req = self.requests[slot as usize];
            obs.on_arrival(self.queue.now(), &req);
        }
    }

    /// Record a completion: emit the `RequestRecord` (with the original
    /// trace id), recycle the arena slot, and shrink the termination
    /// counter. The slot must carry no live references past this call —
    /// the next admitted arrival may reuse it.
    pub fn finish(&mut self, slot: ReqId, now: Us, obs: &mut dyn Observer) {
        let req = &self.requests[slot as usize];
        let cold = self.cold[slot as usize];
        let first_token = self.hot[slot as usize].first_token;
        let first = if first_token == NO_TIME { now } else { first_token };
        let rec = RequestRecord {
            id: req.id,
            task: req.task,
            class: req.class,
            prompt_len: req.prompt_len,
            decode_len: req.decode_len,
            arrival: req.arrival,
            first_token: first,
            finished: now,
            predicted: req.predicted,
            retries: cold.retries,
            recovered: cold.recovered,
        };
        if cold.recovered {
            self.metrics.note_recovery(rec.class, now.saturating_sub(cold.lost_at));
        }
        obs.on_finish(now, &rec);
        let (ttft_violated, tpot_violated) = self.metrics.note_finish(&rec);
        if ttft_violated || tpot_violated {
            obs.on_violation(now, &rec, ttft_violated, tpot_violated);
        }
        self.free_slots.push(slot);
        self.outstanding -= 1;
    }

    /// Record an admission-gate shed: surface it to the observer, count
    /// it per class (shed requests are never silently dropped), recycle
    /// the arena slot, and shrink the termination counter — a shed is a
    /// first-class request outcome, it just never produces tokens.
    pub fn shed(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        let req = self.requests[slot as usize];
        let now = self.queue.now();
        obs.on_shed(now, &req);
        self.metrics.note_shed(req.class);
        self.free_slots.push(slot);
        self.outstanding -= 1;
    }

    /// Record a permanent fault failure: the request exhausted its retry
    /// budget (or no capacity can ever return). Mirrors [`EngineCore::shed`]
    /// exactly — observer hook, per-class count, slot recycle, termination
    /// counter — so the conservation law extends to
    /// `finished + shed + failed == arrivals` and the loop still ends.
    pub fn fail(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        let req = self.requests[slot as usize];
        let now = self.queue.now();
        obs.on_fault(now, "request_failed", None);
        obs.on_request_failed(now, &req);
        self.metrics.note_fail(req.class);
        self.free_slots.push(slot);
        self.outstanding -= 1;
    }

    /// Stamp a fault loss on a request about to be re-queued: bump its
    /// retry counter, mark it recovered-in-progress, and start the
    /// recovery clock at the *first* loss. Returns the new retry count
    /// (the caller checks it against the plan's budget).
    pub fn note_lost(&mut self, slot: ReqId, now: Us) -> u32 {
        let st = &mut self.cold[slot as usize];
        st.retries += 1;
        st.recovered = true;
        if st.lost_at == NO_TIME {
            st.lost_at = now;
        }
        st.retries
    }

    /// Grow the per-instance metric vectors to cover `n_insts` slots (the
    /// elastic pool added instances mid-run). Existing entries keep their
    /// accumulated values.
    pub fn grow_instances(&mut self, n_insts: usize) {
        while self.metrics.busy_us.len() < n_insts {
            self.metrics.busy_us.push(0);
            self.metrics.alive_us.push(0);
            self.metrics.decode_assign.push((0, 0));
        }
    }

    /// Stamp every instance as alive for the whole run — the static-pool
    /// default. Drivers with instance lifecycles (elastic pools) write
    /// per-slot alive spans themselves in `EngineHost::end` instead.
    pub fn stamp_alive_full_run(&mut self) {
        let now = self.queue.now();
        for a in self.metrics.alive_us.iter_mut() {
            *a = now;
        }
    }

    /// End-of-run: stamp makespan and the peak arena size, hand the
    /// metrics out. Alive-time accounting is the host's job (see
    /// [`EngineCore::stamp_alive_full_run`]); `run_des_source` calls this
    /// after `EngineHost::end`.
    pub fn finalize(&mut self) -> RunMetrics {
        self.metrics.makespan_us = self.queue.now();
        self.metrics.peak_arena = self.requests.len();
        self.metrics.event_profile = self.profile.take();
        std::mem::take(&mut self.metrics)
    }
}

/// What a driver supplies on top of the shared core: a name (for the
/// deadlock diagnostic), lifecycle hooks, and the per-event policy.
pub trait EngineHost {
    /// The shared core this driver runs on.
    fn core_mut(&mut self) -> &mut EngineCore;

    /// Driver name used in the deadlock panic message.
    fn driver_name(&self) -> &'static str;

    /// Called once before the first event pops, after `total_expected`
    /// is known (schedule periodic events, take the initial broadcast, ...).
    fn begin(&mut self, obs: &mut dyn Observer);

    /// Handle one event. The core has already counted it.
    fn handle(&mut self, ev: Event, obs: &mut dyn Observer);

    /// Called once after the last request finishes, before metric
    /// finalization (fold per-instance tallies into the metrics, ...).
    fn end(&mut self, obs: &mut dyn Observer);
}

/// The one copy of the macro-stepping scaffold every iteration-complete
/// handler runs (cluster decode, cluster coupled, baseline coupled): the
/// invariants live here, the hosts only supply the three role-specific
/// pieces.
///
///   * `close(host, now, obs)` — apply the just-ended iteration's
///     effects (completions, first tokens) at virtual time `now`;
///   * `start(host, now, obs)` — begin the next iteration at `now` and
///     return its end time (busy accounting + observer hooks included),
///     or `None` when the instance has nothing to do / left its role;
///   * `schedule(host, end)` — enqueue the completion event at `end`.
///
/// The scaffold chains iterations inline while the next one ends
/// *strictly before* every queued event and the pending arrival
/// ([`EngineCore::next_external_at`]) — within that window nothing can
/// pop, hence nothing can be scheduled to pop, so the chain is a function
/// of instance-local state and is event-for-event identical to
/// per-iteration stepping (`macro_on = false`, the reference). Strictness
/// carries the tie-break: an equal-time external event holds a smaller
/// seq and must run first, so the iteration is scheduled, not inlined.
/// When the last request finishes mid-chain the clock is advanced to the
/// inline instant so the makespan matches the reference exactly.
pub fn macro_chain<H: EngineHost>(
    host: &mut H,
    macro_on: bool,
    obs: &mut dyn Observer,
    mut close: impl FnMut(&mut H, Us, &mut dyn Observer),
    mut start: impl FnMut(&mut H, Us, &mut dyn Observer) -> Option<Us>,
    mut schedule: impl FnMut(&mut H, Us),
) {
    let mut now = host.core_mut().now();
    loop {
        close(host, now, obs);
        if host.core_mut().outstanding == 0 {
            // the run ends at this inline instant: surface it to the
            // clock so the makespan matches per-iteration stepping
            host.core_mut().queue.advance_to(now);
            return;
        }
        let Some(end) = start(host, now, obs) else { return };
        if !macro_on || end >= host.core_mut().next_external_at() {
            schedule(host, end);
            return;
        }
        host.core_mut().metrics.macro_steps += 1;
        now = end;
    }
}

/// Compatibility wrapper: run a materialized trace (wraps it in a
/// [`TraceSource`], which stable-sorts by arrival — the old pre-scheduled
/// heap order).
pub fn run_des<H: EngineHost>(
    host: &mut H,
    trace: Vec<Request>,
    obs: &mut dyn Observer,
) -> RunMetrics {
    run_des_source(host, &mut TraceSource::new(trace), obs)
}

/// The one event loop every DES driver shares: pull arrivals from the
/// source (admitting each into the arena the instant it is delivered),
/// pop queue events, dispatch to the host until every request finished,
/// then finalize metrics. Deterministic given the host's config and the
/// source; the observer never influences the run.
pub fn run_des_source<H: EngineHost>(
    host: &mut H,
    source: &mut dyn ArrivalSource,
    obs: &mut dyn Observer,
) -> RunMetrics {
    let name = host.driver_name();
    // Setup and `begin` (fault-plan seeding, initial broadcasts, observer
    // warm-up) are one-time work: exempt from the zero-alloc ledger.
    let mut pending;
    {
        let _cold = crate::util::cold_section();
        pending = source.next_request();
        let core = host.core_mut();
        core.total_expected = source.total();
        core.outstanding = core.total_expected;
        core.next_arrival_at = pending.map_or(NO_TIME, |r| r.arrival);
        host.begin(obs);
    }
    let profiling = host.core_mut().profile.is_some();
    // The steady-state allocation ledger (alloc-count feature): arm at
    // half-completion — by then every pool has reached its working size —
    // and read the counter when the loop exits. Outside the feature this
    // compiles to nothing.
    #[cfg(feature = "alloc-count")]
    let mut steady_start: Option<u64> = None;
    loop {
        let ev = {
            let core = host.core_mut();
            if core.outstanding == 0 {
                break;
            }
            if core.should_stop() {
                // Cut cleanly *between* events: everything simulated so
                // far is already folded into the metrics, and `finalize`
                // below stamps makespan at the current clock. In-flight
                // requests stay uncounted — `aborted` flags the partial
                // conservation law for downstream consumers.
                core.metrics.aborted = true;
                break;
            }
            #[cfg(feature = "alloc-count")]
            if steady_start.is_none() && core.outstanding * 2 <= core.total_expected {
                steady_start = Some(crate::util::hot_allocs());
            }
            // Fresh arrivals win ties against queued events (they carried
            // the smallest seq numbers under the pre-scheduled heap);
            // equal-time arrivals keep source order because exactly one is
            // pending at a time.
            let take_arrival = match (&pending, core.queue.peek_at()) {
                (Some(a), Some(t)) => a.arrival <= t,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => {
                    panic!("{name} deadlock: {} requests outstanding, no events", core.outstanding)
                }
            };
            let ev = if take_arrival {
                let req = pending.take().expect("matched Some above");
                core.queue.advance_to(req.arrival);
                let slot = core.admit(req);
                pending = source.next_request();
                core.next_arrival_at = pending.map_or(NO_TIME, |r| r.arrival);
                Event::Arrival(slot)
            } else {
                core.queue.pop().expect("peeked above").1
            };
            core.metrics.events += 1;
            ev
        };
        if profiling {
            let kind = ev.kind_index();
            let t0 = std::time::Instant::now();
            host.handle(ev, obs);
            let dt = t0.elapsed().as_nanos() as u64;
            if let Some(p) = host.core_mut().profile.as_deref_mut() {
                p.rows[kind].0 += 1;
                p.rows[kind].1 += dt;
            }
        } else {
            host.handle(ev, obs);
        }
    }
    #[cfg(feature = "alloc-count")]
    let steady_allocs = steady_start.map(|s| crate::util::hot_allocs() - s);
    {
        // End-of-run folding (per-instance tallies, alive spans) is
        // one-time work like `begin`.
        let _cold = crate::util::cold_section();
        host.end(obs);
    }
    let core = host.core_mut();
    #[cfg(feature = "alloc-count")]
    {
        core.metrics.steady_allocs = steady_allocs.unwrap_or(0);
    }
    let metrics = core.finalize();
    core.salvage();
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::NullObserver;
    use crate::types::TaskType;

    fn req(id: ReqId, arrival: Us) -> Request {
        Request {
            id,
            task: TaskType::Chat,
            class: 0,
            arrival,
            prompt_len: 8,
            decode_len: 2,
            predicted: None,
            prefix: None,
        }
    }

    /// Minimal host: finishes each request the moment it arrives.
    struct Echo {
        core: EngineCore,
        began: bool,
        ended: bool,
    }

    impl EngineHost for Echo {
        fn core_mut(&mut self) -> &mut EngineCore {
            &mut self.core
        }

        fn driver_name(&self) -> &'static str {
            "echo"
        }

        fn begin(&mut self, _obs: &mut dyn Observer) {
            self.began = true;
        }

        fn handle(&mut self, ev: Event, obs: &mut dyn Observer) {
            let Event::Arrival(slot) = ev else { unreachable!() };
            self.core.note_arrival(slot, obs);
            let now = self.core.now();
            self.core.finish(slot, now, obs);
        }

        fn end(&mut self, _obs: &mut dyn Observer) {
            self.core.stamp_alive_full_run();
            self.ended = true;
        }
    }

    #[test]
    fn run_des_completes_and_finalizes() {
        let mut host = Echo { core: EngineCore::new(2), began: false, ended: false };
        let trace = vec![req(100, 5), req(200, 9)];
        let m = run_des(&mut host, trace, &mut NullObserver);
        assert!(host.began && host.ended);
        assert_eq!(m.records.len(), 2);
        assert_eq!(m.events, 2);
        assert_eq!(m.makespan_us, 9);
        // records carry the original ids, not arena slots
        let ids: Vec<ReqId> = m.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![100, 200]);
        assert_eq!(m.alive_us, vec![9, 9]);
    }

    #[test]
    fn unsorted_traces_replay_in_time_order() {
        let mut host = Echo { core: EngineCore::new(1), began: false, ended: false };
        let trace = vec![req(1, 9), req(2, 5), req(3, 9)];
        let m = run_des(&mut host, trace, &mut NullObserver);
        // stable sort by arrival: id 2 first, then 1 and 3 in trace order
        let ids: Vec<ReqId> = m.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 1, 3]);
        assert_eq!(m.makespan_us, 9);
    }

    #[test]
    fn arena_slots_recycle_and_track_peak_in_flight() {
        // Echo finishes each arrival before the next is admitted, so the
        // arena never grows past one slot — however long the trace.
        let mut host = Echo { core: EngineCore::new(1), began: false, ended: false };
        let trace: Vec<Request> = (0..64).map(|i| req(1000 + i, i)).collect();
        let m = run_des(&mut host, trace, &mut NullObserver);
        assert_eq!(m.records.len(), 64);
        assert_eq!(m.peak_arena, 1, "finished slots must be reused");
        let ids: Vec<ReqId> = m.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, (1000..1064).collect::<Vec<_>>());
    }

    #[test]
    fn records_retention_is_opt_in() {
        let mut host = Echo { core: EngineCore::new(1), began: false, ended: false };
        host.core.metrics.retain_records = false;
        let trace: Vec<Request> = (0..16).map(|i| req(i, i)).collect();
        let m = run_des(&mut host, trace, &mut NullObserver);
        assert!(m.records.is_empty(), "records off: nothing retained");
        assert_eq!(m.n_finished(), 16, "the finish counter still counts");
        assert_eq!(m.generated_tokens, 32, "2 decode tokens per request");
        assert_eq!(m.jct_hist.count(), 16);
    }

    #[test]
    fn note_arrival_fires_once_per_request() {
        struct Count(u64);
        impl Observer for Count {
            fn on_arrival(&mut self, _now: Us, _req: &Request) {
                self.0 += 1;
            }
        }
        let mut core = EngineCore::new(1);
        let slot = core.admit(req(1, 0));
        let mut obs = Count(0);
        core.note_arrival(slot, &mut obs);
        core.note_arrival(slot, &mut obs);
        assert_eq!(obs.0, 1, "re-delivered arrivals must not re-fire the hook");
    }

    #[test]
    fn shed_recycles_slot_counts_class_and_fires_hook() {
        struct Sheds(u64);
        impl Observer for Sheds {
            fn on_shed(&mut self, _now: Us, _req: &Request) {
                self.0 += 1;
            }
        }
        let mut core = EngineCore::new(1);
        core.outstanding = 2;
        let slot = core.admit(req(5, 0));
        assert_eq!(core.in_flight(), 1);
        let mut obs = Sheds(0);
        core.shed(slot, &mut obs);
        assert_eq!(obs.0, 1, "on_shed must fire");
        assert_eq!(core.metrics.shed, 1);
        assert_eq!(core.metrics.per_class[0].shed, 1);
        assert_eq!(core.outstanding, 1);
        assert_eq!(core.in_flight(), 0);
        let slot2 = core.admit(req(6, 1));
        assert_eq!(slot, slot2, "shed slots recycle like finished ones");
    }

    #[test]
    fn fail_recycles_slot_counts_class_and_fires_hook() {
        struct Fails(u64);
        impl Observer for Fails {
            fn on_fault(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {
                self.0 += 1;
            }
        }
        let mut core = EngineCore::new(1);
        core.outstanding = 2;
        let slot = core.admit(req(5, 0));
        let mut obs = Fails(0);
        core.fail(slot, &mut obs);
        assert_eq!(obs.0, 1, "on_fault must fire");
        assert_eq!(core.metrics.failed, 1);
        assert_eq!(core.metrics.per_class[0].failed, 1);
        assert_eq!(core.outstanding, 1);
        assert_eq!(core.in_flight(), 0);
        let slot2 = core.admit(req(6, 1));
        assert_eq!(slot, slot2, "failed slots recycle like finished ones");
    }

    #[test]
    fn note_lost_counts_retries_and_starts_recovery_clock() {
        let mut core = EngineCore::new(1);
        core.outstanding = 1;
        let slot = core.admit(req(9, 0));
        core.queue.schedule_in(100, Event::MonitorTick);
        core.queue.pop();
        assert_eq!(core.note_lost(slot, 100), 1);
        assert_eq!(core.note_lost(slot, 250), 2, "retry count accumulates");
        assert_eq!(core.cold[slot as usize].lost_at, 100, "clock starts at first loss");
        core.finish(slot, 100, &mut NullObserver);
        let rec = &core.metrics.records[0];
        assert_eq!(rec.retries, 2);
        assert!(rec.recovered);
        assert_eq!(core.metrics.recovered, 1);
    }

    #[test]
    fn salvaged_buffers_replay_identically() {
        // Back-to-back runs on one thread: the second pulls the first's
        // parked CoreBuffers (arena lanes + queue). Reuse must be
        // trajectory-neutral — same records, same event count, same clock.
        let trace: Vec<Request> = (0..32).map(|i| req(2000 + i, i * 3)).collect();
        let run = |trace: &[Request]| {
            let mut host = Echo { core: EngineCore::new(1), began: false, ended: false };
            run_des(&mut host, trace.to_vec(), &mut NullObserver)
        };
        let a = run(&trace);
        let b = run(&trace);
        let key = |m: &RunMetrics| {
            m.records.iter().map(|r| (r.id, r.first_token, r.finished)).collect::<Vec<_>>()
        };
        assert_eq!(key(&a), key(&b), "buffer salvage must be trajectory-neutral");
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_us, b.makespan_us);
    }

    #[test]
    fn shared_trace_source_replays_and_truncates() {
        let trace: Vec<Request> = (0..16).map(|i| req(100 + i, i * 2)).collect();
        let arc = std::sync::Arc::new(trace.clone());

        // Full replay is bit-identical to the owned TraceSource.
        let run_src = |src: &mut dyn ArrivalSource| {
            let mut host = Echo { core: EngineCore::new(1), began: false, ended: false };
            run_des_source(&mut host, src, &mut NullObserver)
        };
        let a = run_src(&mut TraceSource::new(trace.clone()));
        let b = run_src(&mut SharedTraceSource::new(arc.clone()));
        assert_eq!(
            a.records.iter().map(|r| (r.id, r.finished)).collect::<Vec<_>>(),
            b.records.iter().map(|r| (r.id, r.finished)).collect::<Vec<_>>()
        );
        assert_eq!(a.events, b.events);

        // Truncation is a *complete* short run, not an aborted one: the
        // engine's total comes from the source, so totals and the
        // conservation law hold at the horizon.
        let c = run_src(&mut SharedTraceSource::truncated(arc.clone(), 5));
        assert_eq!(c.n_finished(), 5);
        assert!(!c.aborted);
        let d = run_src(&mut TraceSource::new(trace[..5].to_vec()));
        assert_eq!(
            c.records.iter().map(|r| (r.id, r.finished)).collect::<Vec<_>>(),
            d.records.iter().map(|r| (r.id, r.finished)).collect::<Vec<_>>()
        );

        // Limit clamps to the trace length.
        let e = run_src(&mut SharedTraceSource::truncated(arc, 99));
        assert_eq!(e.n_finished(), 16);
    }

    #[test]
    fn stop_policy_max_requests_cuts_cleanly() {
        let mut host = Echo { core: EngineCore::new(1), began: false, ended: false };
        host.core.stop = StopPolicy { max_requests: 5, ..StopPolicy::off() };
        let trace: Vec<Request> = (0..8).map(|i| req(i, i * 10)).collect();
        let m = run_des(&mut host, trace, &mut NullObserver);
        assert!(m.aborted, "a cutoff run must be flagged");
        assert_eq!(m.n_finished(), 5, "exactly max_requests outcomes");
        assert_eq!(m.makespan_us, 40, "clock stops at the last handled event");
        assert!(host.ended, "EngineHost::end still runs on abort");
    }

    #[test]
    fn stop_policy_horizon_never_advances_past_cutoff() {
        let mut host = Echo { core: EngineCore::new(1), began: false, ended: false };
        host.core.stop = StopPolicy { horizon_us: 25, ..StopPolicy::off() };
        let trace: Vec<Request> = (0..8).map(|i| req(i, i * 10)).collect();
        let m = run_des(&mut host, trace, &mut NullObserver);
        assert!(m.aborted);
        assert_eq!(m.n_finished(), 3, "arrivals at 0/10/20 beat the horizon");
        assert!(m.makespan_us <= 25, "the clock never crosses the horizon");
    }

    #[test]
    fn stop_policy_miss_budget_aborts_hopeless_runs() {
        /// Sheds every arrival — pure non-attained outcomes.
        struct Shedder {
            core: EngineCore,
        }
        impl EngineHost for Shedder {
            fn core_mut(&mut self) -> &mut EngineCore {
                &mut self.core
            }
            fn driver_name(&self) -> &'static str {
                "shedder"
            }
            fn begin(&mut self, _obs: &mut dyn Observer) {}
            fn handle(&mut self, ev: Event, obs: &mut dyn Observer) {
                let Event::Arrival(slot) = ev else { unreachable!() };
                self.core.shed(slot, obs);
            }
            fn end(&mut self, _obs: &mut dyn Observer) {
                self.core.stamp_alive_full_run();
            }
        }
        let mut host = Shedder { core: EngineCore::new(1) };
        host.core.stop = StopPolicy { miss_budget: 3, ..StopPolicy::off() };
        let trace: Vec<Request> = (0..32).map(|i| req(i, i)).collect();
        let m = run_des(&mut host, trace, &mut NullObserver);
        assert!(m.aborted, "budget exceeded must abort");
        assert_eq!(m.shed, 4, "aborts on the first outcome past the budget");
    }

    #[test]
    fn stop_policy_off_is_the_default_and_changes_nothing() {
        assert!(StopPolicy::default().is_off());
        let run = |stop: StopPolicy| {
            let mut host = Echo { core: EngineCore::new(1), began: false, ended: false };
            host.core.stop = stop;
            let trace: Vec<Request> = (0..12).map(|i| req(i, i * 7)).collect();
            run_des(&mut host, trace, &mut NullObserver)
        };
        let a = run(StopPolicy::off());
        // Generous armed knobs that never fire leave the run untouched.
        let b = run(StopPolicy { max_requests: 1000, horizon_us: 1_000_000, miss_budget: 1000 });
        assert!(!a.aborted && !b.aborted);
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.n_finished(), 12);
    }

    #[test]
    fn grow_instances_extends_metric_vectors() {
        let mut core = EngineCore::new(2);
        core.metrics.busy_us[1] = 7;
        core.grow_instances(4);
        assert_eq!(core.metrics.busy_us, vec![0, 7, 0, 0]);
        assert_eq!(core.metrics.alive_us.len(), 4);
        assert_eq!(core.metrics.decode_assign.len(), 4);
    }
}
