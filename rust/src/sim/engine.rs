//! The DES engine core: the piece of a driver that is *not* policy.
//!
//! Before this existed, `coordinator/cluster.rs` and `baseline/mod.rs`
//! each owned a private copy of the same machinery — the arena request
//! store (trace renumbered into dense slots), the pop-dispatch event loop,
//! the per-request finish bookkeeping, and the end-of-run metric
//! finalization. [`EngineCore`] owns all of that once; a driver keeps a
//! core as a field, implements [`EngineHost`] for its event handling and
//! lifecycle hooks, and [`run_des`] drives the run. Drivers shrink to
//! policy glue: routing, two-level scheduling, flip/scale decisions.
//!
//! The observer fan-out contract is unchanged: hooks fire at the instant
//! an action is issued, and observers never influence the run.

use crate::api::Observer;
use crate::metrics::RunMetrics;
use crate::types::{ReqId, ReqMeta, Request, RequestRecord, Us};

use super::{Event, EventQueue};

/// Sentinel for "first token not yet produced".
pub const NO_TIME: Us = Us::MAX;

/// Arena entry: one request plus the driver-side state that used to live
/// in side HashMaps (first-token time) or nowhere at all (the prefilling
/// instance, which the KV-release path needs). Shared by every driver;
/// the coupled baseline simply never touches `prefilled_by`.
pub struct ReqState {
    pub req: Request,
    pub first_token: Us,
    /// The prefill instance (and its epoch) holding this request's prompt
    /// KV until the transfer out completes. Consumed (`take`n) exactly
    /// once; the epoch guards against the instance leaving its role and
    /// coming back while the KV is in flight (a reborn incarnation must
    /// not have a stale release land on its counter).
    pub prefilled_by: Option<(usize, u32)>,
    /// The arrival event fired at least once (mid-flip retries re-enqueue
    /// `Event::Arrival`; observers must see one arrival per request).
    pub seen: bool,
}

/// Queue + arena + metrics + termination condition: the state every DES
/// driver shares. Drivers own one and layer policy state next to it.
pub struct EngineCore {
    pub queue: EventQueue,
    /// Request arena: everything the run has seen, indexed by arena slot
    /// (events carry slots, not original request ids).
    pub requests: Vec<ReqState>,
    /// Requests remaining (termination condition).
    pub outstanding: usize,
    pub metrics: RunMetrics,
}

impl EngineCore {
    /// A core with per-instance metric vectors sized for `n_insts`.
    pub fn new(n_insts: usize) -> Self {
        EngineCore {
            queue: EventQueue::new(),
            requests: Vec::new(),
            outstanding: 0,
            metrics: RunMetrics {
                busy_us: vec![0; n_insts],
                alive_us: vec![0; n_insts],
                decode_assign: vec![(0, 0); n_insts],
                ..Default::default()
            },
        }
    }

    pub fn now(&self) -> Us {
        self.queue.now()
    }

    /// Renumber the trace into dense arena slots and schedule one arrival
    /// event per request. All internal ids (events, KV tables, queues) are
    /// slots from here on; the original request id resurfaces only in the
    /// final `RequestRecord`.
    pub fn load_trace(&mut self, trace: Vec<Request>) {
        self.outstanding = trace.len();
        self.requests = trace
            .into_iter()
            .map(|req| ReqState { req, first_token: NO_TIME, prefilled_by: None, seen: false })
            .collect();
        for slot in 0..self.requests.len() {
            self.queue
                .schedule_at(self.requests[slot].req.arrival, Event::Arrival(slot as ReqId));
        }
    }

    /// Scheduler-facing view of an arena slot (slot becomes the id).
    pub fn meta_of(&self, slot: ReqId) -> ReqMeta {
        let r = &self.requests[slot as usize].req;
        ReqMeta {
            id: slot,
            task: r.task,
            arrival: r.arrival,
            prompt_len: r.prompt_len,
            predicted: r.predicted,
        }
    }

    /// Fire the observer's arrival hook exactly once per request,
    /// whatever number of times the arrival event is re-delivered.
    pub fn note_arrival(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        if !self.requests[slot as usize].seen {
            self.requests[slot as usize].seen = true;
            let req = self.requests[slot as usize].req;
            obs.on_arrival(self.queue.now(), &req);
        }
    }

    /// Record a completion: emit the `RequestRecord` (with the original
    /// trace id) and shrink the termination counter.
    pub fn finish(&mut self, slot: ReqId, now: Us, obs: &mut dyn Observer) {
        let st = &self.requests[slot as usize];
        let first = if st.first_token == NO_TIME { now } else { st.first_token };
        let rec = RequestRecord {
            id: st.req.id,
            task: st.req.task,
            prompt_len: st.req.prompt_len,
            decode_len: st.req.decode_len,
            arrival: st.req.arrival,
            first_token: first,
            finished: now,
            predicted: st.req.predicted,
        };
        obs.on_finish(now, &rec);
        self.metrics.records.push(rec);
        self.outstanding -= 1;
    }

    /// Grow the per-instance metric vectors to cover `n_insts` slots (the
    /// elastic pool added instances mid-run). Existing entries keep their
    /// accumulated values.
    pub fn grow_instances(&mut self, n_insts: usize) {
        while self.metrics.busy_us.len() < n_insts {
            self.metrics.busy_us.push(0);
            self.metrics.alive_us.push(0);
            self.metrics.decode_assign.push((0, 0));
        }
    }

    /// Stamp every instance as alive for the whole run — the static-pool
    /// default. Drivers with instance lifecycles (elastic pools) write
    /// per-slot alive spans themselves in `EngineHost::end` instead.
    pub fn stamp_alive_full_run(&mut self) {
        let now = self.queue.now();
        for a in self.metrics.alive_us.iter_mut() {
            *a = now;
        }
    }

    /// End-of-run: stamp makespan and hand the metrics out. Alive-time
    /// accounting is the host's job (see [`EngineCore::stamp_alive_full_run`]);
    /// `run_des` calls this after `EngineHost::end`.
    pub fn finalize(&mut self) -> RunMetrics {
        self.metrics.makespan_us = self.queue.now();
        std::mem::take(&mut self.metrics)
    }
}

/// What a driver supplies on top of the shared core: a name (for the
/// deadlock diagnostic), lifecycle hooks, and the per-event policy.
pub trait EngineHost {
    /// The shared core this driver runs on.
    fn core_mut(&mut self) -> &mut EngineCore;

    /// Driver name used in the deadlock panic message.
    fn driver_name(&self) -> &'static str;

    /// Called once after the trace is loaded, before the first event pops
    /// (schedule periodic events, take the initial broadcast, ...).
    fn begin(&mut self, obs: &mut dyn Observer);

    /// Handle one event. The core has already counted it.
    fn handle(&mut self, ev: Event, obs: &mut dyn Observer);

    /// Called once after the last request finishes, before metric
    /// finalization (fold per-instance tallies into the metrics, ...).
    fn end(&mut self, obs: &mut dyn Observer);
}

/// The one event loop both drivers share: load the trace, pop events
/// until every request finished, finalize metrics. Deterministic given
/// the host's config and the trace; the observer never influences the
/// run.
pub fn run_des<H: EngineHost>(host: &mut H, trace: Vec<Request>, obs: &mut dyn Observer) -> RunMetrics {
    let name = host.driver_name();
    host.core_mut().load_trace(trace);
    host.begin(obs);
    loop {
        let ev = {
            let core = host.core_mut();
            if core.outstanding == 0 {
                break;
            }
            let Some((_, ev)) = core.queue.pop() else {
                panic!("{name} deadlock: {} requests outstanding, no events", core.outstanding);
            };
            core.metrics.events += 1;
            ev
        };
        host.handle(ev, obs);
    }
    host.end(obs);
    host.core_mut().finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::NullObserver;
    use crate::types::TaskType;

    fn req(id: ReqId, arrival: Us) -> Request {
        Request {
            id,
            task: TaskType::Chat,
            arrival,
            prompt_len: 8,
            decode_len: 2,
            predicted: None,
        }
    }

    /// Minimal host: finishes each request the moment it arrives.
    struct Echo {
        core: EngineCore,
        began: bool,
        ended: bool,
    }

    impl EngineHost for Echo {
        fn core_mut(&mut self) -> &mut EngineCore {
            &mut self.core
        }

        fn driver_name(&self) -> &'static str {
            "echo"
        }

        fn begin(&mut self, _obs: &mut dyn Observer) {
            self.began = true;
        }

        fn handle(&mut self, ev: Event, obs: &mut dyn Observer) {
            let Event::Arrival(slot) = ev else { unreachable!() };
            self.core.note_arrival(slot, obs);
            let now = self.core.now();
            self.core.finish(slot, now, obs);
        }

        fn end(&mut self, _obs: &mut dyn Observer) {
            self.core.stamp_alive_full_run();
            self.ended = true;
        }
    }

    #[test]
    fn run_des_completes_and_finalizes() {
        let mut host = Echo { core: EngineCore::new(2), began: false, ended: false };
        let trace = vec![req(100, 5), req(200, 9)];
        let m = run_des(&mut host, trace, &mut NullObserver);
        assert!(host.began && host.ended);
        assert_eq!(m.records.len(), 2);
        assert_eq!(m.events, 2);
        assert_eq!(m.makespan_us, 9);
        // records carry the original ids, not arena slots
        let ids: Vec<ReqId> = m.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![100, 200]);
        assert_eq!(m.alive_us, vec![9, 9]);
    }

    #[test]
    fn note_arrival_fires_once_per_request() {
        struct Count(u64);
        impl Observer for Count {
            fn on_arrival(&mut self, _now: Us, _req: &Request) {
                self.0 += 1;
            }
        }
        let mut core = EngineCore::new(1);
        core.load_trace(vec![req(1, 0)]);
        let mut obs = Count(0);
        core.note_arrival(0, &mut obs);
        core.note_arrival(0, &mut obs);
        assert_eq!(obs.0, 1, "re-delivered arrivals must not re-fire the hook");
    }

    #[test]
    fn grow_instances_extends_metric_vectors() {
        let mut core = EngineCore::new(2);
        core.metrics.busy_us[1] = 7;
        core.grow_instances(4);
        assert_eq!(core.metrics.busy_us, vec![0, 7, 0, 0]);
        assert_eq!(core.metrics.alive_us.len(), 4);
        assert_eq!(core.metrics.decode_assign.len(), 4);
    }
}
