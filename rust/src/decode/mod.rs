//! Decode instance (§3.4): receiver → local scheduler → continuous
//! batching over the paged KV pool.
//!
//! Three admission policies:
//!  * `Greedy` — vLLM's: admit while pages are free *now*; oblivious to
//!    the working set, so it can thrash (swap) later.
//!  * `ReserveStatic` — admit only if the request's full predicted memory
//!    usage fits the currently-free pool.
//!  * `ReserveDynamic` — admit if the footprint fits once the shortest
//!    (predicted) remaining job in the batch finishes — proactive but not
//!    as conservative as static reservation.
//!
//! Both reserve policies estimate usage from the predicted length range's
//! *lower end*, matching §5.2.3's evaluation setup.

use std::collections::VecDeque;

use crate::kvcache::PagedKvCache;
use crate::types::{BucketPrediction, ReqId, Request};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    Greedy,
    ReserveStatic,
    ReserveDynamic,
}

impl DecodePolicy {
    pub fn name(self) -> &'static str {
        match self {
            DecodePolicy::Greedy => "greedy",
            DecodePolicy::ReserveStatic => "reserve-static",
            DecodePolicy::ReserveDynamic => "reserve-dynamic",
        }
    }
}

/// A request resident on the decode instance.
#[derive(Clone, Debug)]
pub struct DecodeJob {
    pub req: Request,
    /// Tokens generated so far.
    pub generated: u32,
    /// True once the job holds pages and sits in the running batch.
    pub running: bool,
    /// Times this job was swapped out (thrash diagnostics).
    pub swaps: u32,
}

impl DecodeJob {
    pub fn new(req: Request) -> Self {
        DecodeJob { req, generated: 0, running: false, swaps: 0 }
    }

    /// Current KV footprint in tokens.
    pub fn kv_tokens(&self) -> u32 {
        self.req.prompt_len + self.generated
    }

    /// Predicted *remaining* generation, from the range's lower end
    /// (clamped to at least 1 so jobs always make progress estimates).
    pub fn predicted_remaining(&self, granularity: u32) -> u32 {
        let total = predicted_total(self.req.predicted, granularity);
        total.saturating_sub(self.generated).max(1)
    }

    /// Predicted *total* KV footprint at completion (lower end).
    pub fn predicted_peak_kv(&self, granularity: u32) -> u64 {
        self.req.prompt_len as u64 + predicted_total(self.req.predicted, granularity) as u64
    }

    pub fn done(&self) -> bool {
        self.generated >= self.req.decode_len
    }
}

fn predicted_total(pred: Option<BucketPrediction>, granularity: u32) -> u32 {
    match pred {
        Some(p) => p.lo.max(granularity / 2), // lower end; half-granule floor
        None => granularity / 2,
    }
}

/// The decode instance's local scheduler state.
#[derive(Debug)]
pub struct DecodeScheduler {
    pub policy: DecodePolicy,
    pub granularity: u32,
    /// Max sequences per iteration (continuous-batching cap).
    pub max_batch: u32,
    /// Waiting for first admission (KV already transferred but not paged
    /// in — the sim charges the page-in at admission).
    pub waiting: VecDeque<DecodeJob>,
    /// Admitted, holding pages, decoded every iteration.
    pub running: Vec<DecodeJob>,
    /// Victims of memory pressure, waiting to swap back in.
    pub swapped: VecDeque<DecodeJob>,
}

impl DecodeScheduler {
    pub fn new(policy: DecodePolicy, granularity: u32, max_batch: u32) -> Self {
        DecodeScheduler {
            policy,
            granularity,
            max_batch,
            waiting: VecDeque::new(),
            running: Vec::new(),
            swapped: VecDeque::new(),
        }
    }

    pub fn queue_len(&self) -> u32 {
        (self.waiting.len() + self.swapped.len()) as u32
    }

    pub fn n_resident(&self) -> usize {
        self.running.len()
    }

    pub fn total_jobs(&self) -> usize {
        self.waiting.len() + self.running.len() + self.swapped.len()
    }

    /// Counts of (heavy, light) predicted decodes across all local jobs —
    /// the load the cluster monitor broadcasts (§3.2).
    pub fn heavy_light(&self, heavy_threshold: u32) -> (u32, u32) {
        let mut h = 0;
        let mut l = 0;
        for j in self.waiting.iter().chain(self.running.iter()).chain(self.swapped.iter()) {
            let heavy = j
                .req
                .predicted
                .map(|p| p.predicts_heavy(heavy_threshold))
                .unwrap_or(false);
            if heavy {
                h += 1;
            } else {
                l += 1;
            }
        }
        (h, l)
    }

    /// Future KV growth already promised to running jobs (reserve-static's
    /// notion of "unavailable" memory beyond current allocations).
    fn reserved_growth(&self) -> u64 {
        self.running
            .iter()
            .map(|j| j.predicted_peak_kv(self.granularity).saturating_sub(j.kv_tokens() as u64))
            .sum()
    }

    /// Admission test for one candidate under the configured policy.
    fn admits(&self, job: &DecodeJob, kv: &PagedKvCache) -> bool {
        let now_need = job.kv_tokens() as u64 + 1; // prompt KV + first new token
        if kv.free_tokens() < now_need {
            return false; // can't even page the prompt in
        }
        match self.policy {
            DecodePolicy::Greedy => true,
            DecodePolicy::ReserveStatic => {
                // full predicted footprint must fit memory not yet
                // promised to running jobs
                let available = kv.free_tokens().saturating_sub(self.reserved_growth());
                job.predicted_peak_kv(self.granularity) <= available
            }
            DecodePolicy::ReserveDynamic => {
                // Proactive variant: like reserve-static, but project to
                // when the shortest (predicted) remaining job finishes —
                // its entire footprint returns to the pool by the time the
                // candidate approaches its own peak, so that release
                // counts as available. Less conservative than static,
                // still thrash-free under correct predictions.
                let available =
                    kv.free_tokens().saturating_sub(self.reserved_growth());
                let release = self
                    .running
                    .iter()
                    .min_by_key(|j| j.predicted_remaining(self.granularity))
                    .map(|j| j.predicted_peak_kv(self.granularity))
                    .unwrap_or(0);
                job.predicted_peak_kv(self.granularity) <= available + release
            }
        }
    }

    /// Run one admission round: move admissible jobs from `swapped` (first,
    /// they are oldest) then `waiting` into `running`, allocating pages.
    /// Returns tokens paged in (for swap-in cost accounting).
    pub fn admit(&mut self, kv: &mut PagedKvCache) -> u64 {
        let mut paged_in = 0u64;
        loop {
            if self.running.len() as u32 >= self.max_batch {
                break;
            }
            let from_swapped = !self.swapped.is_empty();
            let candidate = if from_swapped {
                self.swapped.front()
            } else {
                self.waiting.front()
            };
            let Some(job) = candidate else { break };
            if !self.admits(job, kv) {
                break; // FIFO head-of-line: preserve order, stop admitting
            }
            let mut job = if from_swapped {
                self.swapped.pop_front().unwrap()
            } else {
                self.waiting.pop_front().unwrap()
            };
            kv.alloc(job.req.id, job.kv_tokens())
                .expect("admits() guaranteed capacity");
            paged_in += job.kv_tokens() as u64;
            job.running = true;
            self.running.push(job);
        }
        paged_in
    }

    /// Generate one token for every running job. Requests that overflow
    /// their pages trigger vLLM-style preemption: the *newest* running job
    /// is swapped out until the append succeeds. Returns
    /// (completed jobs, tokens swapped out this iteration).
    pub fn step(&mut self, kv: &mut PagedKvCache) -> (Vec<DecodeJob>, u64) {
        self.step_n(kv, usize::MAX)
    }

    /// Like `step`, but only the first `n` running jobs decode this
    /// iteration — the *fixed decode batch* of the vanilla-vLLM baseline
    /// (later jobs wait their turn, FCFS).
    pub fn step_n(&mut self, kv: &mut PagedKvCache, n: usize) -> (Vec<DecodeJob>, u64) {
        let mut swapped_tokens = 0u64;
        let mut i = 0;
        while i < self.running.len().min(n) {
            let id = self.running[i].req.id;
            loop {
                match kv.append_token(id) {
                    Ok(()) => break,
                    Err(_) => {
                        // Preempt the newest running job that is not the
                        // one appending (recompute/swap-in later).
                        let victim_idx = (0..self.running.len())
                            .rev()
                            .find(|&j| self.running[j].req.id != id);
                        let Some(v) = victim_idx else {
                            // only this job left and still no pages: it
                            // swaps itself out and retries next iteration
                            let mut job = self.running.remove(i);
                            swapped_tokens += kv.swap_out(id).unwrap_or(0) as u64;
                            job.running = false;
                            job.swaps += 1;
                            self.swapped.push_back(job);
                            break;
                        };
                        let mut job = self.running.remove(v);
                        swapped_tokens += kv.swap_out(job.req.id).unwrap_or(0) as u64;
                        job.running = false;
                        job.swaps += 1;
                        self.swapped.push_back(job);
                        if v < i {
                            i -= 1;
                        }
                    }
                }
            }
            // if the job swapped itself out it is no longer at index i
            if i < self.running.len() && self.running[i].req.id == id {
                self.running[i].generated += 1;
                i += 1;
            }
        }
        let mut done = Vec::new();
        let mut j = 0;
        while j < self.running.len() {
            if self.running[j].done() {
                let job = self.running.remove(j);
                kv.release(job.req.id);
                done.push(job);
            } else {
                j += 1;
            }
        }
        (done, swapped_tokens)
    }

    /// Total KV tokens resident in the running batch (iteration cost input).
    pub fn running_kv_tokens(&self) -> u64 {
        self.running.iter().map(|j| j.kv_tokens() as u64).sum()
    }

    pub fn push(&mut self, req: Request) {
        self.waiting.push_back(DecodeJob::new(req));
    }
}

/// Completed-job record helper for drivers.
pub fn job_ids(jobs: &[DecodeJob]) -> Vec<ReqId> {
    jobs.iter().map(|j| j.req.id).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{BucketPrediction, TaskType};

    fn req(id: u64, plen: u32, dlen: u32, pred_bucket: Option<u8>) -> Request {
        Request {
            id,
            task: TaskType::Chat,
            arrival: 0,
            prompt_len: plen,
            decode_len: dlen,
            predicted: pred_bucket.map(|b| BucketPrediction::from_bucket(b, 200, 8)),
        }
    }

    fn sched(policy: DecodePolicy) -> (DecodeScheduler, PagedKvCache) {
        (DecodeScheduler::new(policy, 200, 64), PagedKvCache::new(65, 16)) // 64 usable pages = 1024 tokens
    }

    #[test]
    fn greedy_admits_until_pages_run_out() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        for i in 0..10 {
            s.push(req(i, 150, 50, Some(0))); // ~10 pages each
        }
        s.admit(&mut kv);
        assert!(s.running.len() >= 6, "greedy should pack the pool: {}", s.running.len());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_static_reserves_predicted_peak() {
        let (mut s, mut kv) = sched(DecodePolicy::ReserveStatic);
        // predicted bucket 3 → lo=600 → peak 700 tokens each; pool 1024
        s.push(req(0, 100, 650, Some(3)));
        s.push(req(1, 100, 650, Some(3)));
        s.admit(&mut kv);
        assert_eq!(s.running.len(), 1, "static must reserve the 2nd job out");
    }

    #[test]
    fn reserve_dynamic_projects_freed_memory() {
        let (mut s, mut kv) = sched(DecodePolicy::ReserveDynamic);
        // Job A: short remaining (bucket 0 → lo=0 → floor 100), holds 400.
        s.push(req(0, 400, 90, Some(0)));
        s.admit(&mut kv);
        assert_eq!(s.running.len(), 1);
        // Candidate B: peak 100+600=700. Free now: 1024-401=623 → static
        // would refuse; dynamic sees A freeing ~500 soon and admits.
        s.push(req(1, 100, 650, Some(3)));
        let before = s.running.len();
        s.admit(&mut kv);
        assert_eq!(s.running.len(), before + 1, "dynamic should admit B");
        let (mut s2, mut kv2) = sched(DecodePolicy::ReserveStatic);
        s2.push(req(0, 400, 90, Some(0)));
        s2.admit(&mut kv2);
        s2.push(req(1, 100, 650, Some(3)));
        s2.admit(&mut kv2);
        assert_eq!(s2.running.len(), 1, "static refuses what dynamic admits");
    }

    #[test]
    fn step_generates_and_completes() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        s.push(req(0, 10, 3, None));
        s.admit(&mut kv);
        let (d1, _) = s.step(&mut kv);
        assert!(d1.is_empty());
        s.step(&mut kv);
        let (d3, _) = s.step(&mut kv);
        assert_eq!(job_ids(&d3), vec![0]);
        assert_eq!(kv.n_live(), 0, "completed job must release pages");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn memory_pressure_triggers_swap_not_corruption() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        // 3 jobs of 320 tokens = 20 pages each (60 of 64), each decoding
        // 100 tokens → they outgrow the pool and must thrash.
        for i in 0..3 {
            s.push(req(i, 320, 100, Some(0)));
        }
        s.admit(&mut kv);
        assert_eq!(s.running.len(), 3);
        let mut swapped = 0;
        for _ in 0..30 {
            s.admit(&mut kv);
            let (_, sw) = s.step(&mut kv);
            swapped += sw;
            kv.check_invariants().unwrap();
        }
        assert!(swapped > 0, "greedy under pressure must swap");
        assert!(s.swapped.iter().chain(s.running.iter()).count() + s.waiting.len() == 3);
    }

    #[test]
    fn reserve_static_avoids_swaps_with_ideal_prediction() {
        // Same pressure as above, but predictions are exact and static
        // reservation refuses the third job up front → no swaps at all.
        let (mut s, mut kv) = sched(DecodePolicy::ReserveStatic);
        for i in 0..3 {
            s.push(req(i, 320, 100, Some(0))); // peak 420 ≤ free? 2*421 < 1024 only for 2
        }
        let mut swapped = 0;
        for _ in 0..260 {
            s.admit(&mut kv);
            let (_, sw) = s.step(&mut kv);
            swapped += sw;
        }
        assert_eq!(swapped, 0, "static reservation must not thrash");
        assert_eq!(s.total_jobs(), 0, "all jobs finish eventually");
    }

    #[test]
    fn heavy_light_uses_predictions() {
        let (mut s, _) = sched(DecodePolicy::Greedy);
        s.push(req(0, 10, 999, Some(3))); // heavy
        s.push(req(1, 10, 5, Some(0))); // light
        s.push(req(2, 10, 5, None)); // unpredicted → light
        let (h, l) = s.heavy_light(128);
        assert_eq!((h, l), (1, 2));
    }

    #[test]
    fn batch_cap_respected() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        s.max_batch = 2;
        for i in 0..5 {
            s.push(req(i, 4, 10, None));
        }
        s.admit(&mut kv);
        assert_eq!(s.running.len(), 2);
    }
}
