//! Decode instance (§3.4): receiver → local scheduler → continuous
//! batching over the paged KV pool.
//!
//! Three admission policies:
//!  * `Greedy` — vLLM's: admit while pages are free *now*; oblivious to
//!    the working set, so it can thrash (swap) later.
//!  * `ReserveStatic` — admit only if the request's full predicted memory
//!    usage fits the currently-free pool.
//!  * `ReserveDynamic` — admit if the footprint fits once the shortest
//!    (predicted) remaining job in the batch finishes — proactive but not
//!    as conservative as static reservation.
//!
//! Both reserve policies estimate usage from the predicted length range's
//! *lower end*, matching §5.2.3's evaluation setup.
//!
//! Hot-path design (see DESIGN.md §Hot paths): the scheduler maintains
//! its aggregates — running KV tokens, reserved future growth, predicted
//! heavy/light counts, swap-scarred count — *incrementally* on every
//! admit/step/swap/finish instead of rescanning the batch, so a decode
//! iteration is O(batch) total and every load query is O(1). Preemption
//! victims leave from the back of the running batch (`pop`/one-slot
//! `swap_remove`), and completions compact the batch in a single stable
//! pass — no O(batch) `Vec::remove` shifting anywhere. The invariant
//! "cached aggregates == from-scratch recount" is property-tested in
//! rust/tests/proptest_decode.rs.

use std::collections::VecDeque;

use crate::kvcache::PagedKvCache;
use crate::types::{BucketPrediction, ReqId, ReqMeta, Request, HEAVY_DECODE_TOKENS};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    Greedy,
    ReserveStatic,
    ReserveDynamic,
}

impl DecodePolicy {
    pub fn name(self) -> &'static str {
        match self {
            DecodePolicy::Greedy => "greedy",
            DecodePolicy::ReserveStatic => "reserve-static",
            DecodePolicy::ReserveDynamic => "reserve-dynamic",
        }
    }
}

/// A request resident on the decode instance.
#[derive(Clone, Copy, Debug)]
pub struct DecodeJob {
    pub meta: ReqMeta,
    /// Ground-truth generation target. The decode instance "discovers" it
    /// one token at a time; policy code must only read `meta.predicted`.
    pub target_len: u32,
    /// Tokens generated so far.
    pub generated: u32,
    /// True once the job holds pages and sits in the running batch.
    pub running: bool,
    /// Times this job was swapped out (thrash diagnostics).
    pub swaps: u32,
    /// Predicted-heavy classification, fixed at creation (monitor input).
    pub pred_heavy: bool,
    /// Cached predicted peak KV (set when the job enters a scheduler).
    peak_kv: u64,
}

impl DecodeJob {
    pub fn new(meta: ReqMeta, target_len: u32) -> Self {
        let pred_heavy = meta
            .predicted
            .map(|p| p.predicts_heavy(HEAVY_DECODE_TOKENS))
            .unwrap_or(false);
        DecodeJob { meta, target_len, generated: 0, running: false, swaps: 0, pred_heavy, peak_kv: 0 }
    }

    /// Current KV footprint in tokens.
    pub fn kv_tokens(&self) -> u32 {
        self.meta.prompt_len + self.generated
    }

    /// Predicted *remaining* generation, from the range's lower end
    /// (clamped to at least 1 so jobs always make progress estimates).
    pub fn predicted_remaining(&self, granularity: u32) -> u32 {
        let total = predicted_total(self.meta.predicted, granularity);
        total.saturating_sub(self.generated).max(1)
    }

    /// Predicted *total* KV footprint at completion (lower end).
    pub fn predicted_peak_kv(&self, granularity: u32) -> u64 {
        self.meta.prompt_len as u64 + predicted_total(self.meta.predicted, granularity) as u64
    }

    pub fn done(&self) -> bool {
        self.generated >= self.target_len
    }

    /// This job's current contribution to the reserved-growth aggregate.
    fn reserved_now(&self) -> u64 {
        self.peak_kv.saturating_sub(self.kv_tokens() as u64)
    }
}

fn predicted_total(pred: Option<BucketPrediction>, granularity: u32) -> u32 {
    match pred {
        Some(p) => p.lo.max(granularity / 2), // lower end; half-granule floor
        None => granularity / 2,
    }
}

/// The incrementally-maintained aggregates (exposed for the property test
/// and debug assertions — see `DecodeScheduler::recount_aggregates`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct SchedAggregates {
    /// Σ kv_tokens over the running batch.
    pub running_kv: u64,
    /// Σ max(0, predicted peak − current kv) over the running batch.
    pub reserved_growth: u64,
    /// Predicted-heavy jobs across waiting + running + swapped.
    pub n_heavy: u32,
    /// Predicted-light jobs across waiting + running + swapped.
    pub n_light: u32,
    /// Running jobs with swap history (swap-in cost attribution).
    pub swap_scarred: u32,
}

/// The decode instance's local scheduler state.
#[derive(Debug)]
pub struct DecodeScheduler {
    pub policy: DecodePolicy,
    pub granularity: u32,
    /// Max sequences per iteration (continuous-batching cap).
    pub max_batch: u32,
    /// Waiting for first admission (KV already transferred but not paged
    /// in — the sim charges the page-in at admission).
    waiting: VecDeque<DecodeJob>,
    /// Admitted, holding pages, decoded every iteration (push order =
    /// admission order, so the *newest* job sits at the back).
    running: Vec<DecodeJob>,
    /// Victims of memory pressure, waiting to swap back in.
    swapped: VecDeque<DecodeJob>,
    agg: SchedAggregates,
    /// Reusable buffer for the completion compaction pass.
    compact_scratch: Vec<DecodeJob>,
}

impl DecodeScheduler {
    pub fn new(policy: DecodePolicy, granularity: u32, max_batch: u32) -> Self {
        DecodeScheduler {
            policy,
            granularity,
            max_batch,
            waiting: VecDeque::new(),
            running: Vec::new(),
            swapped: VecDeque::new(),
            agg: SchedAggregates::default(),
            compact_scratch: Vec::new(),
        }
    }

    pub fn queue_len(&self) -> u32 {
        (self.waiting.len() + self.swapped.len()) as u32
    }

    pub fn n_resident(&self) -> usize {
        self.running.len()
    }

    pub fn total_jobs(&self) -> usize {
        self.waiting.len() + self.running.len() + self.swapped.len()
    }

    /// The running batch, in admission order (read-only).
    pub fn running(&self) -> &[DecodeJob] {
        &self.running
    }

    /// Counts of (heavy, light) predicted decodes across all local jobs —
    /// the load the cluster monitor broadcasts (§3.2). O(1): maintained
    /// on enqueue/inject/finish.
    pub fn heavy_light(&self) -> (u32, u32) {
        (self.agg.n_heavy, self.agg.n_light)
    }

    /// Total KV tokens resident in the running batch (iteration cost
    /// input). O(1): maintained on admit/step/swap/finish.
    pub fn running_kv_tokens(&self) -> u64 {
        self.agg.running_kv
    }

    /// Whether any running job carries swap history (drivers use this to
    /// attribute page-in traffic to PCIe swap-ins). O(1).
    pub fn running_has_swap_history(&self) -> bool {
        self.agg.swap_scarred > 0
    }

    /// Current cached aggregates.
    pub fn aggregates(&self) -> SchedAggregates {
        self.agg
    }

    /// From-scratch recount of every aggregate — the reference the cached
    /// values must always match (property-tested after random op
    /// sequences in rust/tests/proptest_decode.rs).
    pub fn recount_aggregates(&self) -> SchedAggregates {
        let mut agg = SchedAggregates::default();
        for j in self.running.iter() {
            agg.running_kv += j.kv_tokens() as u64;
            agg.reserved_growth +=
                self.predicted_peak(j).saturating_sub(j.kv_tokens() as u64);
            if j.swaps > 0 {
                agg.swap_scarred += 1;
            }
        }
        for j in self.waiting.iter().chain(self.running.iter()).chain(self.swapped.iter()) {
            let heavy = j
                .meta
                .predicted
                .map(|p| p.predicts_heavy(HEAVY_DECODE_TOKENS))
                .unwrap_or(false);
            if heavy {
                agg.n_heavy += 1;
            } else {
                agg.n_light += 1;
            }
        }
        agg
    }

    fn predicted_peak(&self, job: &DecodeJob) -> u64 {
        job.predicted_peak_kv(self.granularity)
    }

    /// Start tracking a job (it entered waiting/running/swapped).
    fn count_tracked(&mut self, job: &DecodeJob) {
        if job.pred_heavy {
            self.agg.n_heavy += 1;
        } else {
            self.agg.n_light += 1;
        }
    }

    /// Stop tracking a job (it left the scheduler for good).
    fn count_untracked(&mut self, job: &DecodeJob) {
        if job.pred_heavy {
            self.agg.n_heavy -= 1;
        } else {
            self.agg.n_light -= 1;
        }
    }

    /// Fold `job`'s current contribution into the running-batch
    /// aggregates (call right before pushing it into `running`).
    fn agg_add_running(&mut self, job: &DecodeJob) {
        self.agg.running_kv += job.kv_tokens() as u64;
        self.agg.reserved_growth += job.reserved_now();
        if job.swaps > 0 {
            self.agg.swap_scarred += 1;
        }
    }

    /// Remove `job`'s current contribution from the running-batch
    /// aggregates (call right after detaching it from `running`).
    fn agg_sub_running(&mut self, job: &DecodeJob) {
        self.agg.running_kv -= job.kv_tokens() as u64;
        self.agg.reserved_growth -= job.reserved_now();
        if job.swaps > 0 {
            self.agg.swap_scarred -= 1;
        }
    }

    /// Admission test for one candidate under the configured policy.
    fn admits(&self, job: &DecodeJob, kv: &PagedKvCache) -> bool {
        let now_need = job.kv_tokens() as u64 + 1; // prompt KV + first new token
        if kv.free_tokens() < now_need {
            return false; // can't even page the prompt in
        }
        match self.policy {
            DecodePolicy::Greedy => true,
            DecodePolicy::ReserveStatic => {
                // full predicted footprint must fit memory not yet
                // promised to running jobs
                let available = kv.free_tokens().saturating_sub(self.agg.reserved_growth);
                self.predicted_peak(job) <= available
            }
            DecodePolicy::ReserveDynamic => {
                // Proactive variant: like reserve-static, but project to
                // when the shortest (predicted) remaining job finishes —
                // its entire footprint returns to the pool by the time the
                // candidate approaches its own peak, so that release
                // counts as available. Less conservative than static,
                // still thrash-free under correct predictions. (The min
                // scan is O(batch) but only runs on admission attempts,
                // not every iteration.)
                let available = kv.free_tokens().saturating_sub(self.agg.reserved_growth);
                let release = self
                    .running
                    .iter()
                    .min_by_key(|j| j.predicted_remaining(self.granularity))
                    .map(|j| self.predicted_peak(j))
                    .unwrap_or(0);
                self.predicted_peak(job) <= available + release
            }
        }
    }

    /// Enqueue a job into the waiting line (KV transferred, not yet paged
    /// in). All entry points go through here so the heavy/light counts
    /// stay exact.
    pub fn enqueue(&mut self, mut job: DecodeJob) {
        job.peak_kv = self.predicted_peak(&job);
        self.count_tracked(&job);
        self.waiting.push_back(job);
    }

    /// Convenience: enqueue a fresh job for `req`.
    pub fn push(&mut self, req: Request) {
        self.enqueue(DecodeJob::new(req.meta(), req.decode_len));
    }

    /// Insert a job straight into the running batch *without* allocating
    /// pages — for drivers whose jobs already own their pages (the coupled
    /// baseline's locally-prefilled requests, real mode's transferred KV).
    pub fn inject_running(&mut self, mut job: DecodeJob) {
        job.running = true;
        job.peak_kv = self.predicted_peak(&job);
        self.count_tracked(&job);
        self.agg_add_running(&job);
        self.running.push(job);
    }

    /// Remove a specific job from the running batch, preserving order
    /// (rare path: e.g. single-token requests that finish at prefill).
    /// The caller owns the job's pages and must release them.
    pub fn remove_running(&mut self, id: ReqId) -> Option<DecodeJob> {
        let pos = self.running.iter().position(|j| j.meta.id == id)?;
        let job = self.running.remove(pos);
        self.agg_sub_running(&job);
        self.count_untracked(&job);
        Some(job)
    }

    /// Run one admission round: move admissible jobs from `swapped` (first,
    /// they are oldest) then `waiting` into `running`, allocating pages.
    /// Returns tokens paged in (for swap-in cost accounting).
    pub fn admit(&mut self, kv: &mut PagedKvCache) -> u64 {
        let mut paged_in = 0u64;
        loop {
            if self.running.len() as u32 >= self.max_batch {
                break;
            }
            let from_swapped = !self.swapped.is_empty();
            let candidate = if from_swapped {
                self.swapped.front()
            } else {
                self.waiting.front()
            };
            let Some(job) = candidate else { break };
            if !self.admits(job, kv) {
                break; // FIFO head-of-line: preserve order, stop admitting
            }
            let mut job = if from_swapped {
                self.swapped.pop_front().unwrap()
            } else {
                self.waiting.pop_front().unwrap()
            };
            kv.alloc(job.meta.id, job.kv_tokens())
                .expect("admits() guaranteed capacity");
            paged_in += job.kv_tokens() as u64;
            job.running = true;
            job.peak_kv = self.predicted_peak(&job);
            self.agg_add_running(&job);
            self.running.push(job);
        }
        paged_in
    }

    /// Move `job` (already detached from `running`) into the swapped
    /// queue, returning the tokens freed.
    fn evict(&mut self, mut job: DecodeJob, kv: &mut PagedKvCache) -> u64 {
        let freed = kv.swap_out(job.meta.id).unwrap_or(0) as u64;
        self.agg_sub_running(&job);
        job.running = false;
        job.swaps += 1;
        self.swapped.push_back(job);
        freed
    }

    /// Crash harvest: remove every job — waiting, running, swapped — and
    /// reset the aggregates to zero so no load stays attributed to the
    /// dead incarnation. Returns the request ids in queue order. Pages
    /// are not individually released: the paged KV cache dies with the
    /// instance, and recovery re-prefills from scratch.
    pub fn drain_all(&mut self) -> Vec<ReqId> {
        let mut ids: Vec<ReqId> = Vec::with_capacity(self.total_jobs());
        ids.extend(self.waiting.drain(..).map(|j| j.meta.id));
        ids.extend(self.running.drain(..).map(|j| j.meta.id));
        ids.extend(self.swapped.drain(..).map(|j| j.meta.id));
        self.agg = SchedAggregates::default();
        ids
    }

    /// Generate one token for every running job. Requests that overflow
    /// their pages trigger vLLM-style preemption: the *newest* running job
    /// is swapped out until the append succeeds. Completed job ids are
    /// appended to `done` (in batch order); returns tokens swapped out
    /// this iteration.
    pub fn step(&mut self, kv: &mut PagedKvCache, done: &mut Vec<ReqId>) -> u64 {
        self.step_n(kv, usize::MAX, done)
    }

    /// Like `step`, but only the first `n` running jobs decode this
    /// iteration — the *fixed decode batch* of the vanilla-vLLM baseline
    /// (later jobs wait their turn, FCFS).
    pub fn step_n(&mut self, kv: &mut PagedKvCache, n: usize, done: &mut Vec<ReqId>) -> u64 {
        let mut swapped_tokens = 0u64;
        let mut newly_done = 0usize;
        let mut i = 0;
        while i < self.running.len().min(n) {
            let id = self.running[i].meta.id;
            loop {
                match kv.append_token(id) {
                    Ok(()) => break,
                    Err(_) => {
                        let len = self.running.len();
                        if len == 1 {
                            // only this job left and still no pages: it
                            // swaps itself out and retries next iteration
                            let job = self.running.pop().unwrap();
                            swapped_tokens += self.evict(job, kv);
                            break;
                        }
                        // Victim: the newest running job that is not the
                        // one appending. Admission order puts it at the
                        // tail — O(1) and order-preserving: `pop` when the
                        // appender isn't the tail, else remove the tail's
                        // neighbor (the appender slides one slot left).
                        if i == len - 1 {
                            let job = self.running.swap_remove(len - 2);
                            swapped_tokens += self.evict(job, kv);
                            i = len - 2;
                        } else {
                            let job = self.running.pop().unwrap();
                            swapped_tokens += self.evict(job, kv);
                        }
                    }
                }
            }
            // if the job swapped itself out it is no longer at index i
            if i < self.running.len() && self.running[i].meta.id == id {
                let job = &mut self.running[i];
                if job.peak_kv > job.kv_tokens() as u64 {
                    self.agg.reserved_growth -= 1;
                }
                job.generated += 1;
                self.agg.running_kv += 1;
                if job.done() {
                    newly_done += 1;
                }
                i += 1;
            }
        }
        if newly_done > 0 {
            // Single stable compaction pass over the batch (no per-removal
            // shifting): completed jobs release pages and report their
            // ids; survivors keep their order. Buffers are reused across
            // iterations, so the steady state allocates nothing.
            let mut olds =
                std::mem::replace(&mut self.running, std::mem::take(&mut self.compact_scratch));
            for job in olds.drain(..) {
                if job.done() {
                    kv.release(job.meta.id);
                    self.agg_sub_running(&job);
                    self.count_untracked(&job);
                    done.push(job.meta.id);
                } else {
                    self.running.push(job);
                }
            }
            self.compact_scratch = olds;
        }
        debug_assert_eq!(self.agg, self.recount_aggregates());
        swapped_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{BucketPrediction, TaskType};

    fn req(id: u64, plen: u32, dlen: u32, pred_bucket: Option<u8>) -> Request {
        Request {
            id,
            task: TaskType::Chat,
            class: 0,
            arrival: 0,
            prompt_len: plen,
            decode_len: dlen,
            predicted: pred_bucket.map(|b| BucketPrediction::from_bucket(b, 200, 8)),
            prefix: None,
        }
    }

    fn sched(policy: DecodePolicy) -> (DecodeScheduler, PagedKvCache) {
        (DecodeScheduler::new(policy, 200, 64), PagedKvCache::new(65, 16)) // 64 usable pages = 1024 tokens
    }

    fn step_ids(s: &mut DecodeScheduler, kv: &mut PagedKvCache) -> (Vec<u64>, u64) {
        let mut done = Vec::new();
        let sw = s.step(kv, &mut done);
        (done, sw)
    }

    #[test]
    fn greedy_admits_until_pages_run_out() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        for i in 0..10 {
            s.push(req(i, 150, 50, Some(0))); // ~10 pages each
        }
        s.admit(&mut kv);
        assert!(s.n_resident() >= 6, "greedy should pack the pool: {}", s.n_resident());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_static_reserves_predicted_peak() {
        let (mut s, mut kv) = sched(DecodePolicy::ReserveStatic);
        // predicted bucket 3 → lo=600 → peak 700 tokens each; pool 1024
        s.push(req(0, 100, 650, Some(3)));
        s.push(req(1, 100, 650, Some(3)));
        s.admit(&mut kv);
        assert_eq!(s.n_resident(), 1, "static must reserve the 2nd job out");
    }

    #[test]
    fn reserve_dynamic_projects_freed_memory() {
        let (mut s, mut kv) = sched(DecodePolicy::ReserveDynamic);
        // Job A: short remaining (bucket 0 → lo=0 → floor 100), holds 400.
        s.push(req(0, 400, 90, Some(0)));
        s.admit(&mut kv);
        assert_eq!(s.n_resident(), 1);
        // Candidate B: peak 100+600=700. Free now: 1024-401=623 → static
        // would refuse; dynamic sees A freeing ~500 soon and admits.
        s.push(req(1, 100, 650, Some(3)));
        let before = s.n_resident();
        s.admit(&mut kv);
        assert_eq!(s.n_resident(), before + 1, "dynamic should admit B");
        let (mut s2, mut kv2) = sched(DecodePolicy::ReserveStatic);
        s2.push(req(0, 400, 90, Some(0)));
        s2.admit(&mut kv2);
        s2.push(req(1, 100, 650, Some(3)));
        s2.admit(&mut kv2);
        assert_eq!(s2.n_resident(), 1, "static refuses what dynamic admits");
    }

    #[test]
    fn step_generates_and_completes() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        s.push(req(0, 10, 3, None));
        s.admit(&mut kv);
        let (d1, _) = step_ids(&mut s, &mut kv);
        assert!(d1.is_empty());
        step_ids(&mut s, &mut kv);
        let (d3, _) = step_ids(&mut s, &mut kv);
        assert_eq!(d3, vec![0]);
        assert_eq!(kv.n_live(), 0, "completed job must release pages");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn memory_pressure_triggers_swap_not_corruption() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        // 3 jobs of 320 tokens = 20 pages each (60 of 64), each decoding
        // 100 tokens → they outgrow the pool and must thrash.
        for i in 0..3 {
            s.push(req(i, 320, 100, Some(0)));
        }
        s.admit(&mut kv);
        assert_eq!(s.n_resident(), 3);
        let mut swapped = 0;
        for _ in 0..30 {
            s.admit(&mut kv);
            let (_, sw) = step_ids(&mut s, &mut kv);
            swapped += sw;
            kv.check_invariants().unwrap();
        }
        assert!(swapped > 0, "greedy under pressure must swap");
        assert_eq!(s.total_jobs(), 3, "no job may be lost to preemption");
    }

    #[test]
    fn reserve_static_avoids_swaps_with_ideal_prediction() {
        // Same pressure as above, but predictions are exact and static
        // reservation refuses the third job up front → no swaps at all.
        let (mut s, mut kv) = sched(DecodePolicy::ReserveStatic);
        for i in 0..3 {
            s.push(req(i, 320, 100, Some(0))); // peak 420 ≤ free? 2*421 < 1024 only for 2
        }
        let mut swapped = 0;
        let mut done = Vec::new();
        for _ in 0..260 {
            s.admit(&mut kv);
            swapped += s.step(&mut kv, &mut done);
        }
        assert_eq!(swapped, 0, "static reservation must not thrash");
        assert_eq!(s.total_jobs(), 0, "all jobs finish eventually");
        assert_eq!(done.len(), 3);
    }

    #[test]
    fn heavy_light_uses_predictions() {
        let (mut s, _) = sched(DecodePolicy::Greedy);
        s.push(req(0, 10, 999, Some(3))); // heavy
        s.push(req(1, 10, 5, Some(0))); // light
        s.push(req(2, 10, 5, None)); // unpredicted → light
        let (h, l) = s.heavy_light();
        assert_eq!((h, l), (1, 2));
    }

    #[test]
    fn batch_cap_respected() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        s.max_batch = 2;
        for i in 0..5 {
            s.push(req(i, 4, 10, None));
        }
        s.admit(&mut kv);
        assert_eq!(s.n_resident(), 2);
    }

    #[test]
    fn aggregates_match_recount_through_lifecycle() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        for i in 0..6 {
            s.push(req(i, 150, 40, Some((i % 4) as u8)));
        }
        assert_eq!(s.aggregates(), s.recount_aggregates());
        let mut done = Vec::new();
        for _ in 0..400 {
            s.admit(&mut kv);
            s.step(&mut kv, &mut done);
            assert_eq!(s.aggregates(), s.recount_aggregates());
            if s.total_jobs() == 0 {
                break;
            }
        }
        assert_eq!(done.len(), 6);
        assert_eq!(s.aggregates(), SchedAggregates::default());
    }

    #[test]
    fn drain_all_empties_every_queue_and_zeroes_aggregates() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        for i in 0..3 {
            s.push(req(i, 320, 100, Some((i % 4) as u8))); // enough to force a swap
        }
        s.admit(&mut kv);
        let mut done = Vec::new();
        for _ in 0..10 {
            s.admit(&mut kv);
            s.step(&mut kv, &mut done);
        }
        let mut ids = s.drain_all();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2], "every live job must be harvested");
        assert_eq!(s.total_jobs(), 0);
        assert_eq!(s.aggregates(), SchedAggregates::default());
        assert_eq!(s.heavy_light(), (0, 0));
    }

    #[test]
    fn remove_running_keeps_order_and_aggregates() {
        let (mut s, mut kv) = sched(DecodePolicy::Greedy);
        for i in 0..4 {
            s.push(req(i, 10, 5, None));
        }
        s.admit(&mut kv);
        let job = s.remove_running(1).expect("job 1 admitted");
        kv.release(job.meta.id);
        let order: Vec<u64> = s.running().iter().map(|j| j.meta.id).collect();
        assert_eq!(order, vec![0, 2, 3], "removal must preserve batch order");
        assert_eq!(s.aggregates(), s.recount_aggregates());
    }
}
