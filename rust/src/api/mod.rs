//! The experiment API — the crate's single front door (see DESIGN.md
//! §Experiment API).
//!
//! Three pieces compose every run:
//!
//!   * [`Scenario`] — a declarative spec (builder / JSON file / CLI flags,
//!     all bit-identical) covering workload, arrival process, topology,
//!     policies, link, predictor mode, and seeds;
//!   * [`Driver`] — a pluggable simulated system resolved from the
//!     string-keyed [`Registry`] (`"tetri"`, `"vllm"`, ...);
//!   * [`Observer`] — streaming per-event hooks (arrivals, chunks,
//!     transfers, decode iterations, flips, finishes, monitor ticks)
//!     threaded through both DES drivers.
//!
//! A run yields a [`Report`] (metrics + scenario echo + comparison
//! helpers) with one JSON serializer shared by the CLI, the figure
//! harness, the sweep, and the benches.
//!
//! ```no_run
//! use tetri_infer::api::Scenario;
//! use tetri_infer::workload::WorkloadKind;
//!
//! let report = Scenario::builder()
//!     .name("quick")
//!     .workload(WorkloadKind::Mixed)
//!     .requests(64)
//!     .rate(8.0)
//!     .seed(7)
//!     .build()
//!     .run()
//!     .unwrap();
//! println!("{}", report.summary_line());
//! ```

pub mod driver;
pub mod observer;
pub mod report;
pub mod scenario;

pub use driver::{BaselineDriver, ClusterDriver, Driver, Registry};
pub use observer::{
    NullObserver, Observer, ProgressObserver, QueueSample, Span, SpanKind, Tee, TimelineObserver,
};
pub use report::{metrics_json, Report};
pub use scenario::{
    class_keys, decode_policy_key, dispatch_key, elastic_keys, fault_event_keys, fault_keys,
    granularity_key, parse_decode_policy, parse_dispatch, parse_granularity, parse_link,
    optimize_keys, parse_predictor, parse_prefill_policy, parse_prefix_flag,
    parse_telemetry_flag, parse_workload, phase_keys, predictor_key, prefill_policy_key,
    prefix_keys, spec_keys, telemetry_keys, value_vocab, ElasticSpec, LinkSpec, OptimizeGrid,
    Phase, PrefixSpec, Scenario, ScenarioBuilder, TelemetrySpec,
};

pub use crate::fault::{
    fault_kind_key, parse_fault_flag, parse_fault_kind, FaultConfig, FaultKind, FaultPlanSpec,
    FaultSpec,
};
pub use crate::slo::{parse_class_flag, ClassSpec};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadKind;

    fn tiny() -> Scenario {
        Scenario::builder().workload(WorkloadKind::Mixed).requests(16).rate(20.0).seed(1).build()
    }

    #[test]
    fn scenario_run_completes_and_echoes() {
        let sc = tiny();
        let report = sc.run().unwrap();
        assert_eq!(report.metrics.records.len(), 16);
        assert_eq!(report.scenario.as_ref().unwrap(), &sc);
        assert_eq!(report.driver, "tetri");
    }

    #[test]
    fn observers_do_not_perturb_the_run() {
        let sc = tiny();
        let plain = sc.run().unwrap();
        let mut timeline = TimelineObserver::new();
        let observed = sc.run_with(&mut timeline).unwrap();
        assert_eq!(plain.metrics.makespan_us, observed.metrics.makespan_us);
        assert_eq!(plain.metrics.events, observed.metrics.events);
        assert_eq!(
            format!("{:.9}", plain.metrics.jct_summary().mean),
            format!("{:.9}", observed.metrics.jct_summary().mean)
        );
    }

    #[test]
    fn timeline_observer_sees_the_whole_pipeline() {
        let sc = tiny();
        let mut t = TimelineObserver::new();
        sc.run_with(&mut t).unwrap();
        assert_eq!(t.arrivals, 16);
        assert!(t.chunks > 0, "prefill chunks must be observed");
        assert!(t.decode_iters > 0, "decode iterations must be observed");
        assert!(t.transfers > 0, "KV transfers must be observed");
        assert_eq!(t.finished.len(), 16);
        assert!(t.busy_us(0) > 0);
    }

    #[test]
    fn baseline_driver_fires_observer_hooks_too() {
        let sc = Scenario { driver: "vllm".into(), ..tiny() };
        let mut t = TimelineObserver::new();
        let report = sc.run_with(&mut t).unwrap();
        assert_eq!(report.driver, "vllm");
        assert_eq!(t.arrivals, 16);
        assert!(t.chunks > 0, "coupled prefill sides must be observed");
        assert!(t.decode_iters > 0);
        assert_eq!(t.transfers, 0, "the coupled baseline has no KV fabric");
        assert_eq!(t.finished.len(), 16);
    }

    #[test]
    fn spec_loaded_run_matches_builder_run() {
        let sc = tiny();
        let reparsed = Scenario::from_str(&sc.to_json().dump()).unwrap();
        let a = sc.run().unwrap();
        let b = reparsed.run().unwrap();
        assert_eq!(a.metrics.makespan_us, b.metrics.makespan_us);
        assert_eq!(a.metrics.events, b.metrics.events);
    }
}
