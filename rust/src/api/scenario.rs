//! Declarative experiment scenarios: one value that fully determines a
//! simulated run — workload, arrival process, topology, every policy knob,
//! link, predictor mode, and seeds.
//!
//! A `Scenario` can be built three equivalent ways that produce
//! bit-identical runs (golden-tested):
//!   * the builder API: `Scenario::builder().workload(..).seed(42).build()`
//!   * a JSON spec file: `Scenario::load("scenarios/fig12.json")`
//!   * CLI flags: `tetri sim --workload LPHD --seed 42` (main.rs assembles
//!     the same struct through the same parsers)
//!
//! String keys (`"sjf"`, `"po2"`, `"roce"`, ...) are owned by this module:
//! the `parse_*`/`*_key` pairs here are the single source of truth for
//! CLI flags, JSON specs, and sweep grids alike — there is exactly one
//! place a policy name can be spelled, and unknown spellings are errors
//! everywhere (never silent defaults).

use super::driver::Driver as _;
use crate::coordinator::{ClusterConfig, FlipConfig, PredictorMode};
use crate::costmodel::CostModel;
use crate::decode::DecodePolicy;
use crate::fabric::Link;
use crate::fault::{fault_kind_key, parse_fault_kind, FaultKind, FaultPlanSpec, FaultSpec};
use crate::prefill::{DispatchPolicy, PrefillPolicy};
use crate::slo::{ClassSpec, SloConfig, MAX_CLASSES};
use crate::types::{Request, Us};
use crate::util::Json;
use crate::workload::{WorkloadGen, WorkloadKind};

use crate::baseline::BaselineConfig;

// ------------------------------------------------------------ key parsers

/// Emulated hardware link (§5.1): the three setups the paper evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkSpec {
    Nvlink,
    Roce,
    Socket,
}

impl LinkSpec {
    pub fn key(self) -> &'static str {
        match self {
            LinkSpec::Nvlink => "nvlink",
            LinkSpec::Roce => "roce",
            LinkSpec::Socket => "socket",
        }
    }

    pub fn to_link(self) -> Link {
        match self {
            LinkSpec::Nvlink => Link::nvlink(),
            LinkSpec::Roce => Link::roce200(),
            LinkSpec::Socket => Link::indirect_socket(),
        }
    }
}

pub fn parse_link(s: &str) -> Result<LinkSpec, String> {
    match s {
        "nvlink" => Ok(LinkSpec::Nvlink),
        "roce" => Ok(LinkSpec::Roce),
        "socket" => Ok(LinkSpec::Socket),
        _ => Err(format!("unknown link '{s}' (expected nvlink|roce|socket)")),
    }
}

pub fn parse_workload(s: &str) -> Result<WorkloadKind, String> {
    match s.to_ascii_uppercase().as_str() {
        "LPLD" => Ok(WorkloadKind::Lpld),
        "LPHD" => Ok(WorkloadKind::Lphd),
        "HPLD" => Ok(WorkloadKind::Hpld),
        "HPHD" => Ok(WorkloadKind::Hphd),
        "MIXED" => Ok(WorkloadKind::Mixed),
        _ => Err(format!("unknown workload '{s}' (expected LPLD|LPHD|HPLD|HPHD|Mixed)")),
    }
}

pub fn prefill_policy_key(p: PrefillPolicy) -> &'static str {
    match p {
        PrefillPolicy::Fcfs => "fcfs",
        PrefillPolicy::Sjf => "sjf",
        PrefillPolicy::Ljf => "ljf",
        PrefillPolicy::Slo => "slo",
    }
}

pub fn parse_prefill_policy(s: &str) -> Result<PrefillPolicy, String> {
    match s {
        "fcfs" => Ok(PrefillPolicy::Fcfs),
        "sjf" => Ok(PrefillPolicy::Sjf),
        "ljf" => Ok(PrefillPolicy::Ljf),
        "slo" => Ok(PrefillPolicy::Slo),
        _ => Err(format!("unknown prefill policy '{s}' (expected fcfs|sjf|ljf|slo)")),
    }
}

pub fn decode_policy_key(p: DecodePolicy) -> &'static str {
    match p {
        DecodePolicy::Greedy => "greedy",
        DecodePolicy::ReserveStatic => "rs",
        DecodePolicy::ReserveDynamic => "rd",
    }
}

pub fn parse_decode_policy(s: &str) -> Result<DecodePolicy, String> {
    match s {
        "greedy" => Ok(DecodePolicy::Greedy),
        "rs" => Ok(DecodePolicy::ReserveStatic),
        "rd" => Ok(DecodePolicy::ReserveDynamic),
        _ => Err(format!("unknown decode policy '{s}' (expected greedy|rs|rd)")),
    }
}

pub fn dispatch_key(p: DispatchPolicy) -> &'static str {
    match p {
        DispatchPolicy::PowerOfTwo => "po2",
        DispatchPolicy::Random => "random",
        DispatchPolicy::Imbalance => "imbalance",
        DispatchPolicy::LeastLoad => "least",
    }
}

pub fn parse_dispatch(s: &str) -> Result<DispatchPolicy, String> {
    match s {
        "po2" => Ok(DispatchPolicy::PowerOfTwo),
        "random" => Ok(DispatchPolicy::Random),
        "imbalance" => Ok(DispatchPolicy::Imbalance),
        "least" => Ok(DispatchPolicy::LeastLoad),
        _ => Err(format!("unknown dispatch '{s}' (expected po2|random|imbalance|least)")),
    }
}

pub fn predictor_key(m: PredictorMode) -> &'static str {
    match m {
        PredictorMode::Parallel => "parallel",
        PredictorMode::Sequential => "sequential",
        PredictorMode::Disabled => "disabled",
    }
}

pub fn parse_predictor(s: &str) -> Result<PredictorMode, String> {
    match s {
        "parallel" => Ok(PredictorMode::Parallel),
        "sequential" => Ok(PredictorMode::Sequential),
        "disabled" => Ok(PredictorMode::Disabled),
        _ => Err(format!("unknown predictor mode '{s}' (expected parallel|sequential|disabled)")),
    }
}

pub fn granularity_key(g: crate::fabric::Granularity) -> &'static str {
    match g {
        crate::fabric::Granularity::RequestLevel => "request",
        crate::fabric::Granularity::ChunkLevel => "chunk",
        crate::fabric::Granularity::LayerLevel => "layer",
    }
}

pub fn parse_granularity(s: &str) -> Result<crate::fabric::Granularity, String> {
    match s {
        "request" => Ok(crate::fabric::Granularity::RequestLevel),
        "chunk" => Ok(crate::fabric::Granularity::ChunkLevel),
        "layer" => Ok(crate::fabric::Granularity::LayerLevel),
        _ => Err(format!("unknown transfer granularity '{s}' (expected request|chunk|layer)")),
    }
}

// --------------------------------------------------------------- elastic

/// Elastic instance-pool knob (the spec-level mirror of
/// `coordinator::ElasticConfig`; milliseconds here, µs there). When set,
/// the cluster monitor grows the pool under backlog and drains + retires
/// idle instances (see DESIGN.md §Instance engine).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElasticSpec {
    /// Hard cap on non-retired instances.
    pub max_instances: usize,
    /// Scale prefill up when queued+in-flight prompt tokens per active
    /// prefill instance exceed this.
    pub prefill_up_tokens: u64,
    /// Scale decode up when decode jobs per active decode instance
    /// exceed this.
    pub decode_up_jobs: u64,
    /// Drain + retire an instance idle at least this long (ms).
    pub down_idle_ms: f64,
    /// Never retire below this many active instances of either role.
    pub min_per_role: usize,
}

impl Default for ElasticSpec {
    fn default() -> Self {
        ElasticSpec {
            max_instances: 8,
            prefill_up_tokens: 4096,
            decode_up_jobs: 32,
            down_idle_ms: 2_000.0,
            min_per_role: 1,
        }
    }
}

impl ElasticSpec {
    pub fn to_config(self) -> crate::coordinator::ElasticConfig {
        crate::coordinator::ElasticConfig {
            max_instances: self.max_instances,
            prefill_up_tokens: self.prefill_up_tokens,
            decode_up_jobs: self.decode_up_jobs,
            down_idle_us: (self.down_idle_ms * 1e3) as Us,
            min_per_role: self.min_per_role,
        }
    }
}

// ---------------------------------------------------------------- prefix

/// Prompt-prefix reuse knob: stamps the workload with a popularity-skewed
/// prefix population (system prompts, few-shot templates, multi-turn
/// history) and arms the per-prefill-instance radix KV cache that lets
/// repeat prefixes skip their resident prefill chunks. The spec-level
/// mirror of `workload::PrefixPopulation` + `prefixcache::PrefixCacheConfig`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefixSpec {
    /// Distinct prefixes in the population.
    pub n_prefixes: u32,
    /// Shared-prefix length in tokens (clamped to each prompt).
    pub prefix_len: u32,
    /// Zipf popularity exponent (0 = uniform; higher = hotter head).
    pub zipf: f64,
    /// Per-prefill-instance cache capacity in KV pages.
    pub cache_pages: u32,
    /// Tokens per content-addressed hash block (reuse granule).
    pub block_tokens: u32,
}

impl Default for PrefixSpec {
    fn default() -> Self {
        PrefixSpec {
            n_prefixes: 32,
            prefix_len: 512,
            zipf: 1.0,
            cache_pages: 4096,
            block_tokens: 128,
        }
    }
}

/// Parse the `--prefix` CLI flag: comma-separated `key=value` pairs over
/// the same spellings as the spec's `prefix` object (`"off"` disables).
/// Missing keys take the defaults, exactly like a partial JSON object.
pub fn parse_prefix_flag(s: &str) -> Result<Option<PrefixSpec>, String> {
    if s == "off" {
        return Ok(None);
    }
    let mut p = PrefixSpec::default();
    for part in s.split(',').filter(|p| !p.is_empty()) {
        let (key, val) = part
            .split_once('=')
            .ok_or_else(|| format!("--prefix part '{part}' is not key=value"))?;
        let parsed = val
            .parse::<f64>()
            .map_err(|_| format!("--prefix {key}: '{val}' is not a number"))?;
        match key {
            "n_prefixes" => p.n_prefixes = parsed as u32,
            "prefix_len" => p.prefix_len = parsed as u32,
            "zipf" => p.zipf = parsed,
            "cache_pages" => p.cache_pages = parsed as u32,
            "block_tokens" => {
                if parsed < 1.0 {
                    return Err("--prefix block_tokens must be at least 1".to_string());
                }
                p.block_tokens = parsed as u32;
            }
            _ => {
                return Err(format!(
                    "unknown --prefix key '{key}' (known: {})",
                    PREFIX_KEYS.join(", ")
                ))
            }
        }
    }
    Ok(Some(p))
}

impl PrefixSpec {
    /// The workload-generator side: which prefixes requests are stamped with.
    pub fn population(self) -> crate::workload::PrefixPopulation {
        crate::workload::PrefixPopulation {
            n_prefixes: self.n_prefixes,
            prefix_len: self.prefix_len,
            zipf: self.zipf,
        }
    }

    /// The cluster side: the per-prefill-instance cache the stamps hit.
    pub fn cache_config(self) -> crate::prefixcache::PrefixCacheConfig {
        crate::prefixcache::PrefixCacheConfig {
            capacity_pages: self.cache_pages,
            block_tokens: self.block_tokens,
            ..Default::default()
        }
    }
}

// ------------------------------------------------------------- telemetry

/// The spec's `telemetry` object: arms the per-request span tracer,
/// per-phase latency breakdown, and virtual-time series sampler
/// (`telemetry::Telemetry`). `None` — the default — attaches no observer
/// at all, so the run is bit-identical to pre-telemetry builds (the
/// 3-driver parity test pins it). Purely observational: telemetry never
/// influences scheduling, so even armed runs keep the same trajectory.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TelemetrySpec {
    /// Series sampler period in virtual milliseconds.
    pub sample_ms: f64,
    /// Series ring-buffer capacity: on overflow the sampler keeps every
    /// other point and doubles its interval, so memory stays bounded on
    /// arbitrarily long runs (deterministic downsampling).
    pub max_samples: usize,
    /// Also record Perfetto/Chrome trace events (per-request lanes,
    /// instance slices, fault instants) for `--trace` export.
    pub trace: bool,
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        TelemetrySpec { sample_ms: 50.0, max_samples: 4096, trace: false }
    }
}

/// Parse the `--telemetry` CLI flag: comma-separated `key=value` pairs
/// over the same spellings as the spec's `telemetry` object (`"off"`
/// disables, `""` arms the defaults, a bare `trace` arms trace export).
pub fn parse_telemetry_flag(s: &str) -> Result<Option<TelemetrySpec>, String> {
    if s == "off" {
        return Ok(None);
    }
    let mut t = TelemetrySpec::default();
    for part in s.split(',').filter(|p| !p.is_empty()) {
        if part == "trace" {
            t.trace = true;
            continue;
        }
        let (key, val) = part
            .split_once('=')
            .ok_or_else(|| format!("--telemetry part '{part}' is not key=value"))?;
        match key {
            "sample_ms" => {
                let f = val
                    .parse::<f64>()
                    .map_err(|_| format!("--telemetry sample_ms: '{val}' is not a number"))?;
                if !f.is_finite() || f <= 0.0 {
                    return Err("--telemetry sample_ms must be positive".to_string());
                }
                t.sample_ms = f;
            }
            "max_samples" => {
                let f = val
                    .parse::<f64>()
                    .map_err(|_| format!("--telemetry max_samples: '{val}' is not a number"))?;
                if f < 2.0 {
                    return Err("--telemetry max_samples must be at least 2".to_string());
                }
                t.max_samples = f as usize;
            }
            "trace" => {
                t.trace = match val {
                    "true" | "on" | "1" => true,
                    "false" | "off" | "0" => false,
                    _ => {
                        return Err(format!(
                            "--telemetry trace: '{val}' is not a boolean"
                        ))
                    }
                }
            }
            _ => {
                return Err(format!(
                    "unknown --telemetry key '{key}' (known: {})",
                    TELEMETRY_KEYS.join(", ")
                ))
            }
        }
    }
    Ok(Some(t))
}

// ---------------------------------------------------------------- phases

/// One workload phase of a multi-phase trace (load-shift scenarios like
/// the §3.5 flip study). Phases draw from a single `WorkloadGen` stream in
/// order, so a phased scenario is exactly equivalent to the hand-stitched
/// `gen.trace(..); trace.extend(gen.trace(..))` pattern it replaces.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Phase {
    pub workload: WorkloadKind,
    pub requests: usize,
    pub rate: f64,
    /// Arrival-process start offset, milliseconds of virtual time.
    pub start_ms: f64,
}

// -------------------------------------------------------------- optimize

/// The spec's `optimize` object: the search grid and halving/pruning
/// knobs `sim optimize` feeds to `optimizer::optimize`. Inert under a
/// plain `sim` run — the scenario's own topology/policy fields describe
/// the base cell, and the grid axes describe the candidate overrides.
/// An empty axis means "keep the base scenario's value" for that knob.
#[derive(Clone, Debug, PartialEq)]
pub struct OptimizeGrid {
    /// Candidate `n_prefill` values (empty = base value only).
    pub prefill: Vec<usize>,
    /// Candidate `n_decode` values (empty = base value only).
    pub decode: Vec<usize>,
    /// Candidate chunk sizes (empty = base value only).
    pub chunk: Vec<u32>,
    /// Candidate prefill policies (empty = base value only).
    pub prefill_policy: Vec<PrefillPolicy>,
    /// Candidate KV links (empty = base value only).
    pub link: Vec<LinkSpec>,
    /// Candidate elastic caps as `max_instances` values; `0` = static
    /// pool (empty = base elastic config only).
    pub elastic: Vec<usize>,
    /// Candidate drivers (empty = base driver only).
    pub drivers: Vec<String>,
    /// First successive-halving rung's horizon as a fraction of the full
    /// request count (floored at 8 requests).
    pub start_fraction: f64,
    /// Fraction of active cells kept per halving rung (1.0 disables
    /// halving discards — every cell survives to full length).
    pub keep_fraction: f64,
    /// Required SLO attainment per rung: a cell whose non-attained
    /// outcomes already exceed `(1 - min_attainment) × horizon` aborts
    /// mid-run (the miss-budget prune). 0.0 = off.
    pub min_attainment: f64,
    /// Arm the dominance prune: during the final full-length stage, skip
    /// cells whose optimistic goodput-per-dollar upper bound cannot reach
    /// the best completed cell (see DESIGN.md §Optimizer).
    pub prune: bool,
    /// Extra relative slack on the dominance bound (`ub < (1 - slack) ×
    /// incumbent` prunes); larger = more conservative. 0.0 = exact bound.
    pub prune_slack: f64,
}

impl Default for OptimizeGrid {
    fn default() -> Self {
        OptimizeGrid {
            prefill: Vec::new(),
            decode: Vec::new(),
            chunk: Vec::new(),
            prefill_policy: Vec::new(),
            link: Vec::new(),
            elastic: Vec::new(),
            drivers: Vec::new(),
            start_fraction: 1.0 / 16.0,
            keep_fraction: 0.5,
            min_attainment: 0.0,
            prune: true,
            prune_slack: 0.0,
        }
    }
}

// -------------------------------------------------------------- scenario

/// A complete, declarative experiment specification. Equality is
/// field-wise (`PartialEq`), and `to_json`/`from_json` round-trip to the
/// identical value — the golden tests pin both properties.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Free-form label echoed into reports and file names.
    pub name: String,
    /// Driver registry key: `"tetri"` (disaggregated cluster) or `"vllm"`
    /// (coupled baseline). See `api::Registry`.
    pub driver: String,
    pub workload: WorkloadKind,
    pub requests: usize,
    /// Poisson arrivals per second; 0 = batch arrival at t=0.
    pub rate: f64,
    /// Driver policy seed (`ClusterConfig::seed` / `BaselineConfig::seed`).
    /// Keep seeds ≤ 2^53: the JSON spec format carries numbers as f64 and
    /// `from_json` rejects seeds that would not round-trip exactly.
    pub seed: u64,
    /// Workload-generator seed (defaults to `seed` when absent in JSON;
    /// same ≤ 2^53 bound).
    pub trace_seed: u64,
    pub n_prefill: usize,
    pub n_decode: usize,
    /// Coupled (vanilla-vLLM) instances serving *inside* the cluster —
    /// the hybrid-fleet study. 0 is the pure disaggregated setup; the
    /// `"hybrid"` driver key defaults this to 1 when unset.
    pub n_coupled: usize,
    pub link: LinkSpec,
    pub prefill_policy: PrefillPolicy,
    pub decode_policy: DecodePolicy,
    pub dispatch: DispatchPolicy,
    pub predictor: PredictorMode,
    pub predictor_accuracy: f64,
    pub chunk_size: u32,
    pub sched_batch: usize,
    /// TetriInfer's decode continuous-batching cap (tetri driver only —
    /// the coupled baseline's fixed batch is `prefill_batch`).
    pub max_batch: u32,
    /// Instance-flip idle threshold in ms; `None` disables flipping.
    pub flip_idle_ms: Option<f64>,
    /// KV transfer granularity (§3.3.4 ablation).
    pub transfer: crate::fabric::Granularity,
    /// SRTF preemptive chunk assembly (§3.3.1 future-work ablation).
    pub srtf_chunking: bool,
    /// The coupled baseline's fixed batch size for *both* phases
    /// (vllm driver only; paper §5.2.1 uses 16).
    pub prefill_batch: usize,
    /// Override the per-instance KV pool in bytes (memory-pressure
    /// scenarios); `None` = calibrated CostModel default.
    pub hbm_kv_bytes: Option<f64>,
    /// Keep per-request `RequestRecord`s in the run metrics. On (the
    /// default) for golden/figure runs — exact summaries; off for scale
    /// runs — constant memory, summaries from streaming histograms
    /// (`--no-records` on the CLI).
    pub records: bool,
    /// Elastic instance-pool policy; `None` keeps the pool static.
    pub elastic: Option<ElasticSpec>,
    /// Multi-phase trace; when non-empty it replaces
    /// `workload`/`requests`/`rate` for trace generation.
    pub phases: Vec<Phase>,
    /// Workload-class table (SLO multi-tenancy): arrival shares, priority
    /// tiers, TTFT/TPOT deadlines, admission limits. Empty (the default)
    /// = classless legacy run — every request is the implicit class 0,
    /// no deadlines, and the trace is bit-identical to pre-SLO builds.
    pub classes: Vec<ClassSpec>,
    /// Run the deterministic entry admission gate (token buckets +
    /// queue-depth sheds per class). Off by default.
    pub admission: bool,
    /// Deterministic fault injection: chaos event schedule + recovery
    /// knobs (retry budget, backoff, degraded-mode watermark). `None` —
    /// the default — runs fault-free and is bit-identical to pre-fault
    /// builds; `Some` with an empty event list is fault-free too (the
    /// parity golden pins both).
    pub faults: Option<FaultPlanSpec>,
    /// Prompt-prefix reuse: stamp the trace with a zipf prefix population
    /// and arm the per-prefill-instance radix KV cache. `None` — the
    /// default — draws nothing from the prefix RNG stream and runs
    /// bit-identical to pre-cache builds.
    pub prefix: Option<PrefixSpec>,
    /// Collect a per-event-kind wall-time profile during the run
    /// (`--profile-events` on the CLI). Observability only: the
    /// virtual-time trajectory, records, and fingerprints are identical
    /// either way.
    pub profile_events: bool,
    /// Topology search grid + halving/pruning knobs for `sim optimize`
    /// (see [`OptimizeGrid`]). `None` — the default — makes the key
    /// absent from JSON; a plain `sim` run ignores it either way.
    pub optimize: Option<OptimizeGrid>,
    /// Span tracer + series sampler (see [`TelemetrySpec`]). `None` — the
    /// default — attaches no telemetry observer; runs are bit-identical
    /// to pre-telemetry builds.
    pub telemetry: Option<TelemetrySpec>,
    /// Early-stop knobs copied into the driver config (see
    /// [`crate::sim::StopPolicy`]). Programmatic only — the optimizer
    /// arms it per rung; it is *not* part of the JSON spec format and is
    /// skipped by `to_json` (shipped specs always run to completion).
    pub stop: crate::sim::StopPolicy,
}

impl Default for Scenario {
    /// Paper defaults — identical to a bare `tetri sim` invocation and to
    /// `ClusterConfig::default()`.
    fn default() -> Self {
        Scenario {
            name: String::new(),
            driver: "tetri".to_string(),
            workload: WorkloadKind::Mixed,
            requests: 128,
            rate: 0.0,
            seed: 0,
            trace_seed: 0,
            n_prefill: 1,
            n_decode: 1,
            n_coupled: 0,
            link: LinkSpec::Roce,
            prefill_policy: PrefillPolicy::Sjf,
            decode_policy: DecodePolicy::ReserveDynamic,
            dispatch: DispatchPolicy::PowerOfTwo,
            predictor: PredictorMode::Parallel,
            predictor_accuracy: 0.749,
            chunk_size: 512,
            sched_batch: 16,
            max_batch: 128,
            flip_idle_ms: Some(60_000.0),
            transfer: crate::fabric::Granularity::RequestLevel,
            srtf_chunking: false,
            prefill_batch: 16,
            hbm_kv_bytes: None,
            records: true,
            elastic: None,
            phases: Vec::new(),
            classes: Vec::new(),
            admission: false,
            faults: None,
            prefix: None,
            profile_events: false,
            optimize: None,
            telemetry: None,
            stop: crate::sim::StopPolicy::off(),
        }
    }
}

/// Every key the JSON spec format accepts (unknown keys are rejected so
/// typos can't silently revert a knob to its default).
const KNOWN_KEYS: &[&str] = &[
    "name",
    "driver",
    "workload",
    "requests",
    "rate",
    "seed",
    "trace_seed",
    "n_prefill",
    "n_decode",
    "n_coupled",
    "link",
    "prefill_policy",
    "decode_policy",
    "dispatch",
    "predictor",
    "predictor_accuracy",
    "chunk_size",
    "sched_batch",
    "max_batch",
    "flip_idle_ms",
    "transfer",
    "srtf_chunking",
    "prefill_batch",
    "hbm_kv_bytes",
    "records",
    "elastic",
    "phases",
    "classes",
    "admission",
    "faults",
    "prefix",
    "profile_events",
    "optimize",
    "telemetry",
];

const PHASE_KEYS: &[&str] = &["workload", "requests", "rate", "start_ms"];

const ELASTIC_KEYS: &[&str] =
    &["max_instances", "prefill_up_tokens", "decode_up_jobs", "down_idle_ms", "min_per_role"];

const CLASS_KEYS: &[&str] =
    &["name", "weight", "tier", "ttft_ms", "tpot_ms", "rate_limit", "burst", "max_queue"];

const FAULT_KEYS: &[&str] = &["events", "retry_max", "backoff_ms", "watermark"];

const FAULT_EVENT_KEYS: &[&str] = &["kind", "at_ms", "instance", "down_ms", "factor"];

const PREFIX_KEYS: &[&str] =
    &["n_prefixes", "prefix_len", "zipf", "cache_pages", "block_tokens"];

const TELEMETRY_KEYS: &[&str] = &["sample_ms", "max_samples", "trace"];

const OPTIMIZE_KEYS: &[&str] = &[
    "prefill",
    "decode",
    "chunk",
    "prefill_policy",
    "link",
    "elastic",
    "drivers",
    "start_fraction",
    "keep_fraction",
    "min_attainment",
    "prune",
    "prune_slack",
];

/// Every key the JSON spec format accepts — single source of truth shared
/// with the CLI's `--list` output.
pub fn spec_keys() -> &'static [&'static str] {
    KNOWN_KEYS
}

/// Keys of one entry in the spec's `phases` array.
pub fn phase_keys() -> &'static [&'static str] {
    PHASE_KEYS
}

/// Keys of the spec's `elastic` object.
pub fn elastic_keys() -> &'static [&'static str] {
    ELASTIC_KEYS
}

/// Keys of one entry in the spec's `classes` array (same spellings as the
/// `--class` CLI flag).
pub fn class_keys() -> &'static [&'static str] {
    CLASS_KEYS
}

/// Keys of the spec's `faults` object.
pub fn fault_keys() -> &'static [&'static str] {
    FAULT_KEYS
}

/// Keys of one entry in the spec's `faults.events` array (same spellings
/// as the `--fault` CLI flag).
pub fn fault_event_keys() -> &'static [&'static str] {
    FAULT_EVENT_KEYS
}

/// Keys of the spec's `prefix` object (same spellings as the `--prefix`
/// CLI flag).
pub fn prefix_keys() -> &'static [&'static str] {
    PREFIX_KEYS
}

/// Keys of the spec's `optimize` object (grid axes + halving/pruning
/// knobs for `sim optimize`).
pub fn optimize_keys() -> &'static [&'static str] {
    OPTIMIZE_KEYS
}

/// Keys of the spec's `telemetry` object (same spellings as the
/// `--telemetry` CLI flag).
pub fn telemetry_keys() -> &'static [&'static str] {
    TELEMETRY_KEYS
}

/// Every recognized value spelling per enum-valued spec key, generated
/// by running the variants through the same `*_key` maps the parsers
/// invert — so the CLI's `--list` output cannot drift in *spelling*
/// from what the parsers accept (each vocab entry is round-trip-tested
/// through its parser below; a new variant extends the exhaustive key
/// match, whose arms are what these arrays feed from).
pub fn value_vocab() -> Vec<(&'static str, Vec<&'static str>)> {
    use crate::fabric::Granularity;
    vec![
        ("workload", WorkloadKind::ALL.iter().map(|w| w.name()).collect()),
        (
            "link",
            vec![LinkSpec::Nvlink.key(), LinkSpec::Roce.key(), LinkSpec::Socket.key()],
        ),
        (
            "prefill_policy",
            [PrefillPolicy::Fcfs, PrefillPolicy::Sjf, PrefillPolicy::Ljf, PrefillPolicy::Slo]
                .iter()
                .map(|p| prefill_policy_key(*p))
                .collect(),
        ),
        (
            "decode_policy",
            [DecodePolicy::Greedy, DecodePolicy::ReserveStatic, DecodePolicy::ReserveDynamic]
                .iter()
                .map(|p| decode_policy_key(*p))
                .collect(),
        ),
        (
            "dispatch",
            [
                DispatchPolicy::PowerOfTwo,
                DispatchPolicy::Random,
                DispatchPolicy::Imbalance,
                DispatchPolicy::LeastLoad,
            ]
            .iter()
            .map(|p| dispatch_key(*p))
            .collect(),
        ),
        (
            "predictor",
            [PredictorMode::Parallel, PredictorMode::Sequential, PredictorMode::Disabled]
                .iter()
                .map(|m| predictor_key(*m))
                .collect(),
        ),
        (
            "transfer",
            [Granularity::RequestLevel, Granularity::ChunkLevel, Granularity::LayerLevel]
                .iter()
                .map(|g| granularity_key(*g))
                .collect(),
        ),
        ("fault_kind", FaultKind::ALL.iter().map(|k| fault_kind_key(*k)).collect()),
    ]
}

fn want_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    j.as_str().ok_or_else(|| format!("spec key '{key}' must be a string"))
}

fn want_num(j: &Json, key: &str) -> Result<f64, String> {
    j.as_f64().ok_or_else(|| format!("spec key '{key}' must be a number"))
}

fn want_bool(j: &Json, key: &str) -> Result<bool, String> {
    match j {
        Json::Bool(b) => Ok(*b),
        _ => Err(format!("spec key '{key}' must be a boolean")),
    }
}

/// Seeds travel through JSON as f64, which represents integers exactly
/// only below 2^53 — and a too-large literal silently *rounds* during
/// parsing (2^53 + 1 parses as 2^53), so by the time we see the value the
/// damage is done. Rejecting everything ≥ 2^53 therefore also rejects
/// every literal that could have been corrupted; the spec/flag
/// bit-identity guarantee depends on seeds surviving the trip.
fn want_seed(j: &Json, key: &str) -> Result<u64, String> {
    let x = want_num(j, key)?;
    const LIMIT: f64 = 9_007_199_254_740_992.0; // 2^53
    if !(0.0..LIMIT).contains(&x) || x.fract() != 0.0 {
        return Err(format!(
            "spec key '{key}' must be an integer in [0, 2^53) (JSON numbers are f64; \
             larger seeds would not round-trip exactly)"
        ));
    }
    Ok(x as u64)
}

impl Scenario {
    pub fn builder() -> ScenarioBuilder {
        ScenarioBuilder { sc: Scenario::default() }
    }

    // ------------------------------------------------------------- trace

    /// Generate this scenario's request trace (deterministic in
    /// `trace_seed`; bit-identical to the legacy hand-rolled
    /// `WorkloadGen::new(seed).trace(..)` call sites).
    pub fn trace(&self) -> Vec<Request> {
        let mut gen = WorkloadGen::new(self.trace_seed);
        gen.set_classes(self.class_weights());
        gen.set_prefix(self.prefix.map(PrefixSpec::population));
        if self.phases.is_empty() {
            return gen.trace(self.workload, self.requests, self.rate, 0);
        }
        let mut out = Vec::new();
        for ph in &self.phases {
            out.extend(gen.trace(
                ph.workload,
                ph.requests,
                ph.rate,
                (ph.start_ms * 1e3) as Us,
            ));
        }
        out
    }

    /// Fingerprint of everything [`Scenario::trace`] depends on — and
    /// nothing else. Two scenarios with equal keys generate bit-identical
    /// traces, so the optimizer's trace cache can share one `Arc`'d trace
    /// across every grid cell (topology/policy/link axes never enter the
    /// generator). Floats are keyed by their exact bit pattern.
    pub fn trace_key(&self) -> String {
        use std::fmt::Write;
        let mut k = format!(
            "w={};n={};r={:x};s={}",
            self.workload.name(),
            self.requests,
            self.rate.to_bits(),
            self.trace_seed
        );
        for c in &self.classes {
            let _ = write!(k, ";cw={:x}", c.weight.to_bits());
        }
        if let Some(p) = &self.prefix {
            let _ = write!(
                k,
                ";px={}/{}/{:x}",
                p.n_prefixes,
                p.prefix_len,
                p.zipf.to_bits()
            );
        }
        for ph in &self.phases {
            let _ = write!(
                k,
                ";ph={}/{}/{:x}/{:x}",
                ph.workload.name(),
                ph.requests,
                ph.rate.to_bits(),
                ph.start_ms.to_bits()
            );
        }
        k
    }

    /// Pull-based arrival source for this scenario, bit-identical to
    /// [`Scenario::trace`] in delivered order: single-phase specs stream
    /// straight from the workload generator (O(1) memory — this is the
    /// million-request path); phased specs materialize and stable-sort,
    /// because phases share one sequential RNG stream and may overlap in
    /// time, so they cannot stream without buffering anyway.
    pub fn source(&self) -> Box<dyn crate::sim::ArrivalSource> {
        if self.phases.is_empty() {
            Box::new(
                crate::workload::GenSource::new(
                    self.trace_seed,
                    self.workload,
                    self.requests,
                    self.rate,
                    0,
                )
                .with_classes(self.class_weights())
                .with_prefix(self.prefix.map(PrefixSpec::population)),
            )
        } else {
            Box::new(crate::sim::TraceSource::new(self.trace()))
        }
    }

    /// Per-class arrival weights for the workload generator (empty for
    /// classless scenarios — no extra RNG stream is consumed).
    pub fn class_weights(&self) -> Vec<f64> {
        self.classes.iter().map(|c| c.weight).collect()
    }

    /// Resolve the workload-class table + admission knob to the runtime
    /// [`SloConfig`] both driver configs carry (ms → µs).
    pub fn slo_config(&self) -> SloConfig {
        SloConfig {
            classes: self.classes.iter().map(ClassSpec::to_def).collect(),
            admission: self.admission,
        }
    }

    /// Total requests across phases (or the flat `requests` count).
    pub fn total_requests(&self) -> usize {
        if self.phases.is_empty() {
            self.requests
        } else {
            self.phases.iter().map(|p| p.requests).sum()
        }
    }

    /// Clamp the scenario to at most `n` requests (per phase) — the smoke
    /// mode `scripts/check.sh` uses to keep spec files runnable in CI
    /// without paying full-size runs.
    pub fn clamp_requests(&mut self, n: usize) {
        self.requests = self.requests.min(n);
        for ph in &mut self.phases {
            ph.requests = ph.requests.min(n);
        }
    }

    // ----------------------------------------------------------- configs

    /// Resolve to the disaggregated cluster's config.
    pub fn cluster_config(&self) -> ClusterConfig {
        let mut cost = CostModel::default();
        if let Some(bytes) = self.hbm_kv_bytes {
            cost.hbm_kv_bytes = bytes;
        }
        ClusterConfig {
            n_prefill: self.n_prefill,
            n_decode: self.n_decode,
            n_coupled: self.n_coupled,
            coupled_batch: self.prefill_batch,
            chunk_size: self.chunk_size,
            prefill_policy: self.prefill_policy,
            sched_batch: self.sched_batch,
            srtf_chunking: self.srtf_chunking,
            dispatch: self.dispatch,
            decode_policy: self.decode_policy,
            max_batch: self.max_batch,
            link: self.link.to_link(),
            transfer_granularity: self.transfer,
            predictor_mode: self.predictor,
            predictor_accuracy: self.predictor_accuracy,
            flip: self.flip_idle_ms.map(|ms| FlipConfig {
                idle_us: (ms * 1e3) as Us,
                ..Default::default()
            }),
            elastic: self.elastic.map(ElasticSpec::to_config),
            retain_records: self.records,
            slo: self.slo_config(),
            fault: self.faults.as_ref().map(FaultPlanSpec::to_config),
            prefix_cache: self.prefix.map(PrefixSpec::cache_config),
            profile_events: self.profile_events,
            stop: self.stop,
            cost,
            seed: self.seed,
            ..Default::default()
        }
    }

    /// Resolve to the coupled baseline's config. The instance count
    /// follows the paper's §5.1 fairness convention: one coupled instance
    /// per disaggregated prefill+decode *pair*, i.e.
    /// `min(n_prefill, n_decode).max(1)`. `prefill_batch` is vanilla
    /// vLLM's *fixed batch size for both phases* (§5.2.1), so it caps the
    /// baseline's decode window too; `max_batch` is the TetriInfer decode
    /// cap and does not apply here (see the field docs).
    pub fn baseline_config(&self) -> BaselineConfig {
        let mut cost = CostModel::default();
        if let Some(bytes) = self.hbm_kv_bytes {
            cost.hbm_kv_bytes = bytes;
        }
        BaselineConfig {
            n_instances: self.n_prefill.min(self.n_decode).max(1),
            prefill_batch: self.prefill_batch,
            max_batch: self.prefill_batch as u32,
            retain_records: self.records,
            slo: self.slo_config(),
            fault: self.faults.as_ref().map(FaultPlanSpec::to_config),
            profile_events: self.profile_events,
            stop: self.stop,
            cost,
            seed: self.seed,
            ..Default::default()
        }
    }

    /// The coupled-baseline counterpart of this scenario (same trace and
    /// seeds, `vllm` driver) — what `tetri sim` runs for its comparison
    /// rows.
    pub fn baseline_counterpart(&self) -> Scenario {
        Scenario { driver: "vllm".to_string(), ..self.clone() }
    }

    // -------------------------------------------------------------- runs

    /// Resolve the driver from the builtin registry and run to completion
    /// with no observer attached.
    pub fn run(&self) -> Result<super::Report, String> {
        self.run_with(&mut super::NullObserver)
    }

    /// Resolve the driver and run with `obs` attached, streaming arrivals
    /// from [`Scenario::source`] (bit-identical to running the
    /// materialized trace — parity-tested in tests/golden.rs). Errors
    /// only on an unknown driver key.
    pub fn run_with(&self, obs: &mut dyn super::Observer) -> Result<super::Report, String> {
        let driver = super::Registry::builtin().resolve(self)?;
        let mut source = self.source();
        match &self.telemetry {
            // The zero-cost path: no telemetry observer exists at all, so
            // armed-off runs pay exactly the pre-telemetry hook cost
            // (default no-op Observer methods).
            None => Ok(driver.run_source(source.as_mut(), obs)),
            Some(spec) => {
                let mut tel = crate::telemetry::Telemetry::from_spec(spec, self);
                let mut report = {
                    let mut tee = super::Tee::new(&mut tel, obs);
                    driver.run_source(source.as_mut(), &mut tee)
                };
                report.telemetry = Some(tel.into_summary(&report.metrics));
                Ok(report)
            }
        }
    }

    // -------------------------------------------------------------- json

    /// Canonical JSON form: every key, in the spec's vocabulary.
    /// `Json::parse(s).and_then(Scenario::from_json)` returns the
    /// identical value (round-trip-tested).
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("name", Json::from(self.name.clone())),
            ("driver", Json::from(self.driver.clone())),
            ("workload", Json::from(self.workload.name())),
            ("requests", Json::from(self.requests)),
            ("rate", Json::from(self.rate)),
            ("seed", Json::from(self.seed)),
            ("trace_seed", Json::from(self.trace_seed)),
            ("n_prefill", Json::from(self.n_prefill)),
            ("n_decode", Json::from(self.n_decode)),
            ("n_coupled", Json::from(self.n_coupled)),
            ("link", Json::from(self.link.key())),
            ("prefill_policy", Json::from(prefill_policy_key(self.prefill_policy))),
            ("decode_policy", Json::from(decode_policy_key(self.decode_policy))),
            ("dispatch", Json::from(dispatch_key(self.dispatch))),
            ("predictor", Json::from(predictor_key(self.predictor))),
            ("predictor_accuracy", Json::from(self.predictor_accuracy)),
            ("chunk_size", Json::from(u64::from(self.chunk_size))),
            ("sched_batch", Json::from(self.sched_batch)),
            ("max_batch", Json::from(u64::from(self.max_batch))),
            (
                "flip_idle_ms",
                self.flip_idle_ms.map(Json::from).unwrap_or(Json::Null),
            ),
            ("transfer", Json::from(granularity_key(self.transfer))),
            ("srtf_chunking", Json::from(self.srtf_chunking)),
            ("prefill_batch", Json::from(self.prefill_batch)),
            (
                "hbm_kv_bytes",
                self.hbm_kv_bytes.map(Json::from).unwrap_or(Json::Null),
            ),
            ("records", Json::from(self.records)),
            ("admission", Json::from(self.admission)),
            ("profile_events", Json::from(self.profile_events)),
        ];
        if let Some(el) = self.elastic {
            pairs.push((
                "elastic",
                Json::obj([
                    ("max_instances", Json::from(el.max_instances)),
                    ("prefill_up_tokens", Json::from(el.prefill_up_tokens)),
                    ("decode_up_jobs", Json::from(el.decode_up_jobs)),
                    ("down_idle_ms", Json::from(el.down_idle_ms)),
                    ("min_per_role", Json::from(el.min_per_role)),
                ]),
            ));
        }
        if let Some(fp) = &self.faults {
            let events: Vec<Json> = fp
                .events
                .iter()
                .map(|ev| {
                    let mut pairs: Vec<(&str, Json)> = vec![
                        ("kind", Json::from(fault_kind_key(ev.kind))),
                        ("at_ms", Json::from(ev.at_ms)),
                    ];
                    if let Some(i) = ev.instance {
                        pairs.push(("instance", Json::from(i)));
                    }
                    if let Some(d) = ev.down_ms {
                        pairs.push(("down_ms", Json::from(d)));
                    }
                    if let Some(f) = ev.factor {
                        pairs.push(("factor", Json::from(f)));
                    }
                    Json::obj(pairs)
                })
                .collect();
            pairs.push((
                "faults",
                Json::obj([
                    ("events", Json::from(events)),
                    ("retry_max", Json::from(u64::from(fp.retry_max))),
                    ("backoff_ms", Json::from(fp.backoff_ms)),
                    ("watermark", Json::from(fp.watermark)),
                ]),
            ));
        }
        if let Some(p) = self.prefix {
            pairs.push((
                "prefix",
                Json::obj([
                    ("n_prefixes", Json::from(u64::from(p.n_prefixes))),
                    ("prefix_len", Json::from(u64::from(p.prefix_len))),
                    ("zipf", Json::from(p.zipf)),
                    ("cache_pages", Json::from(u64::from(p.cache_pages))),
                    ("block_tokens", Json::from(u64::from(p.block_tokens))),
                ]),
            ));
        }
        if !self.classes.is_empty() {
            let classes: Vec<Json> = self
                .classes
                .iter()
                .map(|c| {
                    let mut pairs: Vec<(&str, Json)> = vec![
                        ("name", Json::from(c.name.clone())),
                        ("weight", Json::from(c.weight)),
                        ("tier", Json::from(u64::from(c.tier))),
                    ];
                    if let Some(v) = c.ttft_ms {
                        pairs.push(("ttft_ms", Json::from(v)));
                    }
                    if let Some(v) = c.tpot_ms {
                        pairs.push(("tpot_ms", Json::from(v)));
                    }
                    if let Some(v) = c.rate_limit {
                        pairs.push(("rate_limit", Json::from(v)));
                    }
                    if let Some(v) = c.burst {
                        pairs.push(("burst", Json::from(v)));
                    }
                    if let Some(v) = c.max_queue {
                        pairs.push(("max_queue", Json::from(v)));
                    }
                    Json::obj(pairs)
                })
                .collect();
            pairs.push(("classes", Json::from(classes)));
        }
        if !self.phases.is_empty() {
            let phases: Vec<Json> = self
                .phases
                .iter()
                .map(|p| {
                    Json::obj([
                        ("workload", Json::from(p.workload.name())),
                        ("requests", Json::from(p.requests)),
                        ("rate", Json::from(p.rate)),
                        ("start_ms", Json::from(p.start_ms)),
                    ])
                })
                .collect();
            pairs.push(("phases", Json::from(phases)));
        }
        if let Some(g) = &self.optimize {
            let nums = |v: &[usize]| {
                Json::from(v.iter().map(|&n| Json::from(n)).collect::<Vec<_>>())
            };
            pairs.push((
                "optimize",
                Json::obj([
                    ("prefill", nums(&g.prefill)),
                    ("decode", nums(&g.decode)),
                    (
                        "chunk",
                        Json::from(
                            g.chunk.iter().map(|&c| Json::from(u64::from(c))).collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "prefill_policy",
                        Json::from(
                            g.prefill_policy
                                .iter()
                                .map(|&p| Json::from(prefill_policy_key(p)))
                                .collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "link",
                        Json::from(
                            g.link.iter().map(|l| Json::from(l.key())).collect::<Vec<_>>(),
                        ),
                    ),
                    ("elastic", nums(&g.elastic)),
                    (
                        "drivers",
                        Json::from(
                            g.drivers.iter().map(|d| Json::from(d.clone())).collect::<Vec<_>>(),
                        ),
                    ),
                    ("start_fraction", Json::from(g.start_fraction)),
                    ("keep_fraction", Json::from(g.keep_fraction)),
                    ("min_attainment", Json::from(g.min_attainment)),
                    ("prune", Json::from(g.prune)),
                    ("prune_slack", Json::from(g.prune_slack)),
                ]),
            ));
        }
        if let Some(t) = self.telemetry {
            pairs.push((
                "telemetry",
                Json::obj([
                    ("sample_ms", Json::from(t.sample_ms)),
                    ("max_samples", Json::from(t.max_samples)),
                    ("trace", Json::from(t.trace)),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    /// Parse a spec object. Missing keys take the paper defaults
    /// (`trace_seed` defaults to `seed`); unknown keys and bad value
    /// spellings are errors.
    pub fn from_json(j: &Json) -> Result<Scenario, String> {
        let obj = j.as_obj().ok_or("scenario spec must be a JSON object")?;
        for key in obj.keys() {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                return Err(format!(
                    "unknown spec key '{key}' (known: {})",
                    KNOWN_KEYS.join(", ")
                ));
            }
        }
        let mut sc = Scenario::default();
        let mut saw_trace_seed = false;
        for (key, v) in obj {
            match key.as_str() {
                "name" => sc.name = want_str(v, key)?.to_string(),
                "driver" => sc.driver = want_str(v, key)?.to_string(),
                "workload" => sc.workload = parse_workload(want_str(v, key)?)?,
                "requests" => sc.requests = want_num(v, key)? as usize,
                "rate" => sc.rate = want_num(v, key)?,
                "seed" => sc.seed = want_seed(v, key)?,
                "trace_seed" => {
                    sc.trace_seed = want_seed(v, key)?;
                    saw_trace_seed = true;
                }
                "n_prefill" => sc.n_prefill = want_num(v, key)? as usize,
                "n_decode" => sc.n_decode = want_num(v, key)? as usize,
                "n_coupled" => sc.n_coupled = want_num(v, key)? as usize,
                "link" => sc.link = parse_link(want_str(v, key)?)?,
                "prefill_policy" => sc.prefill_policy = parse_prefill_policy(want_str(v, key)?)?,
                "decode_policy" => sc.decode_policy = parse_decode_policy(want_str(v, key)?)?,
                "dispatch" => sc.dispatch = parse_dispatch(want_str(v, key)?)?,
                "predictor" => sc.predictor = parse_predictor(want_str(v, key)?)?,
                "predictor_accuracy" => sc.predictor_accuracy = want_num(v, key)?,
                "chunk_size" => sc.chunk_size = want_num(v, key)? as u32,
                "sched_batch" => sc.sched_batch = want_num(v, key)? as usize,
                "max_batch" => sc.max_batch = want_num(v, key)? as u32,
                "flip_idle_ms" => {
                    sc.flip_idle_ms = match v {
                        Json::Null => None,
                        _ => Some(want_num(v, key)?),
                    }
                }
                "transfer" => sc.transfer = parse_granularity(want_str(v, key)?)?,
                "srtf_chunking" => sc.srtf_chunking = want_bool(v, key)?,
                "prefill_batch" => sc.prefill_batch = want_num(v, key)? as usize,
                "hbm_kv_bytes" => {
                    sc.hbm_kv_bytes = match v {
                        Json::Null => None,
                        _ => Some(want_num(v, key)?),
                    }
                }
                "records" => sc.records = want_bool(v, key)?,
                "elastic" => {
                    sc.elastic = match v {
                        Json::Null => None,
                        _ => {
                            let eobj =
                                v.as_obj().ok_or("spec key 'elastic' must be an object or null")?;
                            for ek in eobj.keys() {
                                if !ELASTIC_KEYS.contains(&ek.as_str()) {
                                    return Err(format!(
                                        "unknown elastic key '{ek}' (known: {})",
                                        ELASTIC_KEYS.join(", ")
                                    ));
                                }
                            }
                            let mut el = ElasticSpec::default();
                            if let Some(x) = v.get("max_instances") {
                                el.max_instances = want_num(x, "max_instances")? as usize;
                            }
                            if let Some(x) = v.get("prefill_up_tokens") {
                                el.prefill_up_tokens = want_num(x, "prefill_up_tokens")? as u64;
                            }
                            if let Some(x) = v.get("decode_up_jobs") {
                                el.decode_up_jobs = want_num(x, "decode_up_jobs")? as u64;
                            }
                            if let Some(x) = v.get("down_idle_ms") {
                                el.down_idle_ms = want_num(x, "down_idle_ms")?;
                            }
                            if let Some(x) = v.get("min_per_role") {
                                el.min_per_role = want_num(x, "min_per_role")? as usize;
                            }
                            Some(el)
                        }
                    }
                }
                "admission" => sc.admission = want_bool(v, key)?,
                "profile_events" => sc.profile_events = want_bool(v, key)?,
                "faults" => {
                    sc.faults = match v {
                        Json::Null => None,
                        _ => {
                            let fobj =
                                v.as_obj().ok_or("spec key 'faults' must be an object or null")?;
                            for fk in fobj.keys() {
                                if !FAULT_KEYS.contains(&fk.as_str()) {
                                    return Err(format!(
                                        "unknown faults key '{fk}' (known: {})",
                                        FAULT_KEYS.join(", ")
                                    ));
                                }
                            }
                            let mut fp = FaultPlanSpec::default();
                            if let Some(x) = v.get("retry_max") {
                                fp.retry_max = want_num(x, "retry_max")? as u32;
                            }
                            if let Some(x) = v.get("backoff_ms") {
                                fp.backoff_ms = want_num(x, "backoff_ms")?;
                            }
                            if let Some(x) = v.get("watermark") {
                                fp.watermark = want_num(x, "watermark")?;
                            }
                            if let Some(evs) = v.get("events") {
                                let arr = evs
                                    .as_arr()
                                    .ok_or("faults key 'events' must be an array")?;
                                for ej in arr {
                                    let eobj = ej
                                        .as_obj()
                                        .ok_or("each fault event must be a JSON object")?;
                                    for ek in eobj.keys() {
                                        if !FAULT_EVENT_KEYS.contains(&ek.as_str()) {
                                            return Err(format!(
                                                "unknown fault event key '{ek}' (known: {})",
                                                FAULT_EVENT_KEYS.join(", ")
                                            ));
                                        }
                                    }
                                    let kind = parse_fault_kind(want_str(
                                        ej.get("kind").ok_or("fault event missing 'kind'")?,
                                        "kind",
                                    )?)?;
                                    let at_ms = want_num(
                                        ej.get("at_ms").ok_or("fault event missing 'at_ms'")?,
                                        "at_ms",
                                    )?;
                                    let instance = ej
                                        .get("instance")
                                        .map(|x| want_num(x, "instance").map(|n| n as usize))
                                        .transpose()?;
                                    let down_ms = ej
                                        .get("down_ms")
                                        .map(|x| want_num(x, "down_ms"))
                                        .transpose()?;
                                    let factor = ej
                                        .get("factor")
                                        .map(|x| want_num(x, "factor"))
                                        .transpose()?;
                                    fp.events.push(FaultSpec {
                                        kind,
                                        at_ms,
                                        instance,
                                        down_ms,
                                        factor,
                                    });
                                }
                            }
                            fp.validate()?;
                            Some(fp)
                        }
                    }
                }
                "prefix" => {
                    sc.prefix = match v {
                        Json::Null => None,
                        _ => {
                            let pobj =
                                v.as_obj().ok_or("spec key 'prefix' must be an object or null")?;
                            for pk in pobj.keys() {
                                if !PREFIX_KEYS.contains(&pk.as_str()) {
                                    return Err(format!(
                                        "unknown prefix key '{pk}' (known: {})",
                                        PREFIX_KEYS.join(", ")
                                    ));
                                }
                            }
                            let mut p = PrefixSpec::default();
                            if let Some(x) = v.get("n_prefixes") {
                                p.n_prefixes = want_num(x, "n_prefixes")? as u32;
                            }
                            if let Some(x) = v.get("prefix_len") {
                                p.prefix_len = want_num(x, "prefix_len")? as u32;
                            }
                            if let Some(x) = v.get("zipf") {
                                p.zipf = want_num(x, "zipf")?;
                            }
                            if let Some(x) = v.get("cache_pages") {
                                p.cache_pages = want_num(x, "cache_pages")? as u32;
                            }
                            if let Some(x) = v.get("block_tokens") {
                                let b = want_num(x, "block_tokens")?;
                                if b < 1.0 {
                                    return Err(
                                        "prefix key 'block_tokens' must be at least 1".to_string()
                                    );
                                }
                                p.block_tokens = b as u32;
                            }
                            Some(p)
                        }
                    }
                }
                "classes" => {
                    let arr = v.as_arr().ok_or("spec key 'classes' must be an array")?;
                    if arr.len() > MAX_CLASSES {
                        return Err(format!(
                            "spec declares {} classes; class ids are u8, max {MAX_CLASSES}",
                            arr.len()
                        ));
                    }
                    for cj in arr {
                        let cobj = cj.as_obj().ok_or("each class must be a JSON object")?;
                        for ck in cobj.keys() {
                            if !CLASS_KEYS.contains(&ck.as_str()) {
                                return Err(format!(
                                    "unknown class key '{ck}' (known: {})",
                                    CLASS_KEYS.join(", ")
                                ));
                            }
                        }
                        let mut cl = ClassSpec {
                            name: want_str(
                                cj.get("name").ok_or("class missing 'name'")?,
                                "name",
                            )?
                            .to_string(),
                            ..Default::default()
                        };
                        if let Some(x) = cj.get("weight") {
                            cl.weight = want_num(x, "weight")?;
                        }
                        if let Some(x) = cj.get("tier") {
                            let t = want_num(x, "tier")?;
                            if !(0.0..=255.0).contains(&t) || t.fract() != 0.0 {
                                return Err(format!(
                                    "class '{}': tier must be an integer in [0,255]",
                                    cl.name
                                ));
                            }
                            cl.tier = t as u8;
                        }
                        if let Some(x) = cj.get("ttft_ms") {
                            cl.ttft_ms = Some(want_num(x, "ttft_ms")?);
                        }
                        if let Some(x) = cj.get("tpot_ms") {
                            cl.tpot_ms = Some(want_num(x, "tpot_ms")?);
                        }
                        if let Some(x) = cj.get("rate_limit") {
                            cl.rate_limit = Some(want_num(x, "rate_limit")?);
                        }
                        if let Some(x) = cj.get("burst") {
                            cl.burst = Some(want_num(x, "burst")?);
                        }
                        if let Some(x) = cj.get("max_queue") {
                            cl.max_queue = Some(want_num(x, "max_queue")? as u64);
                        }
                        sc.classes.push(cl);
                    }
                }
                "phases" => {
                    let arr = v.as_arr().ok_or("spec key 'phases' must be an array")?;
                    for pj in arr {
                        let pobj = pj.as_obj().ok_or("each phase must be a JSON object")?;
                        for pk in pobj.keys() {
                            if !PHASE_KEYS.contains(&pk.as_str()) {
                                return Err(format!(
                                    "unknown phase key '{pk}' (known: {})",
                                    PHASE_KEYS.join(", ")
                                ));
                            }
                        }
                        let workload = parse_workload(want_str(
                            pj.get("workload").ok_or("phase missing 'workload'")?,
                            "workload",
                        )?)?;
                        let requests = want_num(
                            pj.get("requests").ok_or("phase missing 'requests'")?,
                            "requests",
                        )? as usize;
                        let rate = pj.get("rate").map(|x| want_num(x, "rate")).transpose()?.unwrap_or(0.0);
                        let start_ms = pj
                            .get("start_ms")
                            .map(|x| want_num(x, "start_ms"))
                            .transpose()?
                            .unwrap_or(0.0);
                        sc.phases.push(Phase { workload, requests, rate, start_ms });
                    }
                }
                "optimize" => {
                    sc.optimize = match v {
                        Json::Null => None,
                        _ => {
                            let oobj = v
                                .as_obj()
                                .ok_or("spec key 'optimize' must be an object or null")?;
                            for ok in oobj.keys() {
                                if !OPTIMIZE_KEYS.contains(&ok.as_str()) {
                                    return Err(format!(
                                        "unknown optimize key '{ok}' (known: {})",
                                        OPTIMIZE_KEYS.join(", ")
                                    ));
                                }
                            }
                            let nums = |x: &Json, name: &str| -> Result<Vec<usize>, String> {
                                let arr = x
                                    .as_arr()
                                    .ok_or(format!("optimize key '{name}' must be an array"))?;
                                arr.iter().map(|n| want_num(n, name).map(|f| f as usize)).collect()
                            };
                            let mut g = OptimizeGrid::default();
                            if let Some(x) = v.get("prefill") {
                                g.prefill = nums(x, "prefill")?;
                            }
                            if let Some(x) = v.get("decode") {
                                g.decode = nums(x, "decode")?;
                            }
                            if let Some(x) = v.get("chunk") {
                                g.chunk = nums(x, "chunk")?.iter().map(|&n| n as u32).collect();
                            }
                            if let Some(x) = v.get("prefill_policy") {
                                let arr = x
                                    .as_arr()
                                    .ok_or("optimize key 'prefill_policy' must be an array")?;
                                g.prefill_policy = arr
                                    .iter()
                                    .map(|p| parse_prefill_policy(want_str(p, "prefill_policy")?))
                                    .collect::<Result<Vec<_>, _>>()?;
                            }
                            if let Some(x) = v.get("link") {
                                let arr =
                                    x.as_arr().ok_or("optimize key 'link' must be an array")?;
                                g.link = arr
                                    .iter()
                                    .map(|l| parse_link(want_str(l, "link")?))
                                    .collect::<Result<Vec<_>, _>>()?;
                            }
                            if let Some(x) = v.get("elastic") {
                                g.elastic = nums(x, "elastic")?;
                            }
                            if let Some(x) = v.get("drivers") {
                                let arr =
                                    x.as_arr().ok_or("optimize key 'drivers' must be an array")?;
                                g.drivers = arr
                                    .iter()
                                    .map(|d| want_str(d, "drivers").map(str::to_string))
                                    .collect::<Result<Vec<_>, _>>()?;
                            }
                            if let Some(x) = v.get("start_fraction") {
                                let f = want_num(x, "start_fraction")?;
                                if !(f > 0.0 && f <= 1.0) {
                                    return Err(
                                        "optimize key 'start_fraction' must be in (0,1]".to_string()
                                    );
                                }
                                g.start_fraction = f;
                            }
                            if let Some(x) = v.get("keep_fraction") {
                                let f = want_num(x, "keep_fraction")?;
                                if !(f > 0.0 && f <= 1.0) {
                                    return Err(
                                        "optimize key 'keep_fraction' must be in (0,1]".to_string()
                                    );
                                }
                                g.keep_fraction = f;
                            }
                            if let Some(x) = v.get("min_attainment") {
                                let f = want_num(x, "min_attainment")?;
                                if !(0.0..=1.0).contains(&f) {
                                    return Err(
                                        "optimize key 'min_attainment' must be in [0,1]".to_string()
                                    );
                                }
                                g.min_attainment = f;
                            }
                            if let Some(x) = v.get("prune") {
                                g.prune = want_bool(x, "prune")?;
                            }
                            if let Some(x) = v.get("prune_slack") {
                                let f = want_num(x, "prune_slack")?;
                                if !(0.0..=1.0).contains(&f) {
                                    return Err(
                                        "optimize key 'prune_slack' must be in [0,1]".to_string()
                                    );
                                }
                                g.prune_slack = f;
                            }
                            Some(g)
                        }
                    }
                }
                "telemetry" => {
                    sc.telemetry = match v {
                        Json::Null => None,
                        _ => {
                            let tobj = v
                                .as_obj()
                                .ok_or("spec key 'telemetry' must be an object or null")?;
                            for tk in tobj.keys() {
                                if !TELEMETRY_KEYS.contains(&tk.as_str()) {
                                    return Err(format!(
                                        "unknown telemetry key '{tk}' (known: {})",
                                        TELEMETRY_KEYS.join(", ")
                                    ));
                                }
                            }
                            let mut t = TelemetrySpec::default();
                            if let Some(x) = v.get("sample_ms") {
                                let f = want_num(x, "sample_ms")?;
                                if !f.is_finite() || f <= 0.0 {
                                    return Err(
                                        "telemetry key 'sample_ms' must be positive".to_string()
                                    );
                                }
                                t.sample_ms = f;
                            }
                            if let Some(x) = v.get("max_samples") {
                                let f = want_num(x, "max_samples")?;
                                if f < 2.0 {
                                    return Err(
                                        "telemetry key 'max_samples' must be at least 2"
                                            .to_string(),
                                    );
                                }
                                t.max_samples = f as usize;
                            }
                            if let Some(x) = v.get("trace") {
                                t.trace = want_bool(x, "trace")?;
                            }
                            Some(t)
                        }
                    }
                }
                _ => unreachable!("key checked against KNOWN_KEYS above"),
            }
        }
        if !saw_trace_seed {
            sc.trace_seed = sc.seed;
        }
        Ok(sc)
    }

    /// Parse a spec from JSON text.
    pub fn from_str(s: &str) -> Result<Scenario, String> {
        let j = Json::parse(s).map_err(|e| e.to_string())?;
        Scenario::from_json(&j)
    }

    /// Load a spec file. The file name (minus `.json`) becomes the
    /// scenario name when the spec doesn't set one.
    pub fn load(path: &str) -> Result<Scenario, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read spec {path}: {e}"))?;
        let mut sc = Scenario::from_str(&text).map_err(|e| format!("{path}: {e}"))?;
        if sc.name.is_empty() {
            sc.name = std::path::Path::new(path)
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("scenario")
                .to_string();
        }
        Ok(sc)
    }

    /// One line with every resolved knob — printed at `tetri sim` startup
    /// so any run is reproducible from its log.
    pub fn summary_line(&self) -> String {
        let phases = if self.phases.is_empty() {
            format!("workload={} n={} rate={}/s", self.workload.name(), self.requests, self.rate)
        } else {
            let parts: Vec<String> = self
                .phases
                .iter()
                .map(|p| format!("{}x{}@{}/s+{}ms", p.workload.name(), p.requests, p.rate, p.start_ms))
                .collect();
            format!("phases=[{}]", parts.join(","))
        };
        format!(
            "scenario{}: driver={} {} prefill={} decode={} coupled={} link={} prefill_policy={} \
             decode_policy={} dispatch={} predictor={} acc={} chunk={} sched_batch={} \
             max_batch={} flip_idle_ms={} elastic={} transfer={} srtf={} prefill_batch={} \
             hbm_kv_bytes={} records={} classes={} admission={} faults={} prefix={} \
             telemetry={} seed={} trace_seed={}",
            if self.name.is_empty() { String::new() } else { format!(" '{}'", self.name) },
            self.driver,
            phases,
            self.n_prefill,
            self.n_decode,
            self.n_coupled,
            self.link.key(),
            prefill_policy_key(self.prefill_policy),
            decode_policy_key(self.decode_policy),
            dispatch_key(self.dispatch),
            predictor_key(self.predictor),
            self.predictor_accuracy,
            self.chunk_size,
            self.sched_batch,
            self.max_batch,
            self.flip_idle_ms.map(|ms| ms.to_string()).unwrap_or_else(|| "off".into()),
            self.elastic
                .map(|el| {
                    format!(
                        "max{},up{}t/{}j,down{}ms,min{}",
                        el.max_instances,
                        el.prefill_up_tokens,
                        el.decode_up_jobs,
                        el.down_idle_ms,
                        el.min_per_role
                    )
                })
                .unwrap_or_else(|| "off".into()),
            granularity_key(self.transfer),
            self.srtf_chunking,
            self.prefill_batch,
            self.hbm_kv_bytes.map(|b| b.to_string()).unwrap_or_else(|| "default".into()),
            self.records,
            if self.classes.is_empty() {
                "off".to_string()
            } else {
                let names: Vec<&str> = self.classes.iter().map(|c| c.name.as_str()).collect();
                format!("[{}]", names.join(","))
            },
            self.admission,
            self.faults
                .as_ref()
                .map(|fp| {
                    format!(
                        "{}ev,retry{},backoff{}ms,wm{}",
                        fp.events.len(),
                        fp.retry_max,
                        fp.backoff_ms,
                        fp.watermark
                    )
                })
                .unwrap_or_else(|| "off".into()),
            self.prefix
                .map(|p| {
                    format!(
                        "{}x{}t,zipf{},pages{},blk{}",
                        p.n_prefixes, p.prefix_len, p.zipf, p.cache_pages, p.block_tokens
                    )
                })
                .unwrap_or_else(|| "off".into()),
            self.telemetry
                .map(|t| {
                    let mut s = format!("{}ms,cap{}", t.sample_ms, t.max_samples);
                    if t.trace {
                        s.push_str(",trace");
                    }
                    s
                })
                .unwrap_or_else(|| "off".into()),
            self.seed,
            self.trace_seed,
        )
    }
}

// --------------------------------------------------------------- builder

/// Fluent construction of a [`Scenario`] starting from paper defaults.
/// `seed(s)` sets both the policy seed and the trace seed (the common
/// case); use `trace_seed` after it to split them.
#[derive(Clone, Debug)]
pub struct ScenarioBuilder {
    sc: Scenario,
}

impl ScenarioBuilder {
    pub fn name(mut self, v: &str) -> Self {
        self.sc.name = v.to_string();
        self
    }

    pub fn driver(mut self, v: &str) -> Self {
        self.sc.driver = v.to_string();
        self
    }

    pub fn workload(mut self, v: WorkloadKind) -> Self {
        self.sc.workload = v;
        self
    }

    pub fn requests(mut self, v: usize) -> Self {
        self.sc.requests = v;
        self
    }

    pub fn rate(mut self, v: f64) -> Self {
        self.sc.rate = v;
        self
    }

    /// Sets both the policy seed and the trace seed.
    pub fn seed(mut self, v: u64) -> Self {
        self.sc.seed = v;
        self.sc.trace_seed = v;
        self
    }

    pub fn trace_seed(mut self, v: u64) -> Self {
        self.sc.trace_seed = v;
        self
    }

    pub fn topology(mut self, n_prefill: usize, n_decode: usize) -> Self {
        self.sc.n_prefill = n_prefill;
        self.sc.n_decode = n_decode;
        self
    }

    /// Coupled (vanilla-vLLM) instances inside the cluster (hybrid mode).
    pub fn coupled(mut self, n: usize) -> Self {
        self.sc.n_coupled = n;
        self
    }

    pub fn elastic(mut self, v: Option<ElasticSpec>) -> Self {
        self.sc.elastic = v;
        self
    }

    pub fn link(mut self, v: LinkSpec) -> Self {
        self.sc.link = v;
        self
    }

    pub fn prefill_policy(mut self, v: PrefillPolicy) -> Self {
        self.sc.prefill_policy = v;
        self
    }

    pub fn decode_policy(mut self, v: DecodePolicy) -> Self {
        self.sc.decode_policy = v;
        self
    }

    pub fn dispatch(mut self, v: DispatchPolicy) -> Self {
        self.sc.dispatch = v;
        self
    }

    pub fn predictor(mut self, v: PredictorMode) -> Self {
        self.sc.predictor = v;
        self
    }

    pub fn predictor_accuracy(mut self, v: f64) -> Self {
        self.sc.predictor_accuracy = v;
        self
    }

    pub fn chunk_size(mut self, v: u32) -> Self {
        self.sc.chunk_size = v;
        self
    }

    pub fn sched_batch(mut self, v: usize) -> Self {
        self.sc.sched_batch = v;
        self
    }

    pub fn max_batch(mut self, v: u32) -> Self {
        self.sc.max_batch = v;
        self
    }

    pub fn flip_idle_ms(mut self, v: Option<f64>) -> Self {
        self.sc.flip_idle_ms = v;
        self
    }

    pub fn transfer(mut self, v: crate::fabric::Granularity) -> Self {
        self.sc.transfer = v;
        self
    }

    pub fn srtf_chunking(mut self, v: bool) -> Self {
        self.sc.srtf_chunking = v;
        self
    }

    pub fn prefill_batch(mut self, v: usize) -> Self {
        self.sc.prefill_batch = v;
        self
    }

    pub fn hbm_kv_bytes(mut self, v: Option<f64>) -> Self {
        self.sc.hbm_kv_bytes = v;
        self
    }

    /// Per-request record retention (off = constant-memory scale mode).
    pub fn records(mut self, v: bool) -> Self {
        self.sc.records = v;
        self
    }

    pub fn phase(mut self, workload: WorkloadKind, requests: usize, rate: f64, start_ms: f64) -> Self {
        self.sc.phases.push(Phase { workload, requests, rate, start_ms });
        self
    }

    /// Replace the whole workload-class table.
    pub fn classes(mut self, v: Vec<ClassSpec>) -> Self {
        self.sc.classes = v;
        self
    }

    /// Append one workload class (class id = declaration order).
    pub fn class(mut self, c: ClassSpec) -> Self {
        self.sc.classes.push(c);
        self
    }

    /// Toggle the deterministic entry admission gate.
    pub fn admission(mut self, v: bool) -> Self {
        self.sc.admission = v;
        self
    }

    /// Replace the whole fault plan (`None` = fault-free).
    pub fn faults(mut self, v: Option<FaultPlanSpec>) -> Self {
        self.sc.faults = v;
        self
    }

    /// Prompt-prefix reuse population + radix KV cache (`None` = off).
    pub fn prefix(mut self, v: Option<PrefixSpec>) -> Self {
        self.sc.prefix = v;
        self
    }

    /// Collect the per-event-kind wall-time profile (observability only).
    pub fn profile_events(mut self, v: bool) -> Self {
        self.sc.profile_events = v;
        self
    }

    /// Attach the optimizer search grid (`None` = plain scenario).
    pub fn optimize(mut self, v: Option<OptimizeGrid>) -> Self {
        self.sc.optimize = v;
        self
    }

    /// Arm the span tracer + series sampler (`None` = zero-cost off).
    pub fn telemetry(mut self, v: Option<TelemetrySpec>) -> Self {
        self.sc.telemetry = v;
        self
    }

    /// Arm the early-stop knobs (programmatic only — never serialized).
    pub fn stop(mut self, v: crate::sim::StopPolicy) -> Self {
        self.sc.stop = v;
        self
    }

    /// Append one fault event, creating a default-knobbed plan on first
    /// use (the builder mirror of a repeated `--fault` CLI flag).
    pub fn fault(mut self, ev: FaultSpec) -> Self {
        self.sc.faults.get_or_insert_with(FaultPlanSpec::default).events.push(ev);
        self
    }

    /// Finish the scenario. Panics when more than
    /// [`MAX_CLASSES`](crate::slo::MAX_CLASSES) classes were declared —
    /// class ids travel as `u8`, and a silent wraparound would merge the
    /// overflow classes into class 0 (the JSON path rejects this with an
    /// error; builder misuse is a programming bug, so it asserts).
    pub fn build(self) -> Scenario {
        assert!(
            self.sc.classes.len() <= MAX_CLASSES,
            "scenario declares {} classes; class ids are u8, max {MAX_CLASSES}",
            self.sc.classes.len()
        );
        self.sc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_round_trips_through_json() {
        let sc = Scenario::default();
        let s = sc.to_json().dump();
        assert_eq!(Scenario::from_str(&s).unwrap(), sc);
    }

    #[test]
    fn exotic_scenario_round_trips() {
        let sc = Scenario::builder()
            .name("fig-x")
            .driver("vllm")
            .workload(WorkloadKind::Hphd)
            .requests(7)
            .rate(3.25)
            .seed(99)
            .trace_seed(7)
            .topology(2, 4)
            .coupled(2)
            .elastic(Some(ElasticSpec { max_instances: 12, down_idle_ms: 750.0, ..Default::default() }))
            .link(LinkSpec::Socket)
            .prefill_policy(PrefillPolicy::Ljf)
            .decode_policy(DecodePolicy::Greedy)
            .dispatch(DispatchPolicy::Imbalance)
            .predictor(PredictorMode::Sequential)
            .predictor_accuracy(1.0)
            .chunk_size(256)
            .sched_batch(32)
            .max_batch(64)
            .flip_idle_ms(None)
            .transfer(crate::fabric::Granularity::ChunkLevel)
            .srtf_chunking(true)
            .prefill_batch(8)
            .hbm_kv_bytes(Some(2e9))
            .records(false)
            .phase(WorkloadKind::Hpld, 64, 16.0, 0.0)
            .phase(WorkloadKind::Lphd, 96, 16.0, 8_000.0)
            .build();
        let s = sc.to_json().dump();
        assert_eq!(Scenario::from_str(&s).unwrap(), sc);
    }

    #[test]
    fn optimize_grid_round_trips_and_is_validated() {
        let sc = Scenario::builder()
            .name("opt")
            .optimize(Some(OptimizeGrid {
                prefill: vec![1, 2, 4],
                decode: vec![2, 8],
                chunk: vec![256, 512],
                prefill_policy: vec![PrefillPolicy::Sjf, PrefillPolicy::Slo],
                link: vec![LinkSpec::Roce, LinkSpec::Nvlink],
                elastic: vec![0, 12],
                drivers: vec!["tetri".into(), "vllm".into()],
                start_fraction: 0.125,
                keep_fraction: 0.25,
                min_attainment: 0.9,
                prune: false,
                prune_slack: 0.1,
            }))
            .build();
        let s = sc.to_json().dump();
        assert_eq!(Scenario::from_str(&s).unwrap(), sc);
        // grid axes never enter the trace generator, so the cache key is
        // identical with and without the optimize block
        assert_eq!(sc.trace_key(), Scenario { optimize: None, ..sc.clone() }.trace_key());
        // knob ranges are validated at parse time
        for bad in [
            r#"{"optimize": {"start_fraction": 0.0}}"#,
            r#"{"optimize": {"keep_fraction": 1.5}}"#,
            r#"{"optimize": {"min_attainment": -0.1}}"#,
            r#"{"optimize": {"prune_slack": 2.0}}"#,
            r#"{"optimize": {"bogus": 1}}"#,
        ] {
            assert!(Scenario::from_str(bad).is_err(), "{bad} should be rejected");
        }
        // trace_key separates what the generator reads…
        let base = Scenario::default();
        assert_ne!(base.trace_key(), Scenario { trace_seed: 1, ..base.clone() }.trace_key());
        assert_ne!(base.trace_key(), Scenario { requests: 7, ..base.clone() }.trace_key());
        // …and ignores what it doesn't
        assert_eq!(base.trace_key(), Scenario { n_prefill: 9, chunk_size: 64, ..base.clone() }.trace_key());
    }

    #[test]
    fn classed_scenario_round_trips_and_resolves() {
        let sc = Scenario::builder()
            .name("slo")
            .prefill_policy(PrefillPolicy::Slo)
            .admission(true)
            .class(ClassSpec {
                name: "chat".into(),
                weight: 0.5,
                tier: 0,
                ttft_ms: Some(300.0),
                tpot_ms: Some(100.0),
                ..Default::default()
            })
            .class(ClassSpec {
                name: "batch".into(),
                weight: 0.5,
                tier: 2,
                rate_limit: Some(4.0),
                burst: Some(8.0),
                max_queue: Some(64),
                ..Default::default()
            })
            .build();
        let s = sc.to_json().dump();
        assert_eq!(Scenario::from_str(&s).unwrap(), sc);
        // the resolved SLO config carries µs deadlines + gate limits
        let slo = sc.slo_config();
        assert!(slo.admission && slo.is_active());
        assert_eq!(slo.classes.len(), 2);
        assert_eq!(slo.classes[0].ttft_deadline_us, Some(300_000));
        assert_eq!(slo.classes[1].rate_limit, Some(4.0));
        assert_eq!(slo.prefill_table(), vec![(0, 300_000), (2, crate::types::Us::MAX)]);
        // both driver configs receive the identical config
        assert_eq!(sc.cluster_config().slo, slo);
        assert_eq!(sc.baseline_config().slo, slo);
        assert_eq!(sc.class_weights(), vec![0.5, 0.5]);
        // the trace carries class stamps from the declared shares
        let trace = Scenario { requests: 200, ..sc.clone() }.trace();
        assert!(trace.iter().any(|r| r.class == 0) && trace.iter().any(|r| r.class == 1));
        // the startup line names the classes
        let line = sc.summary_line();
        assert!(line.contains("classes=[chat,batch]") && line.contains("admission=true"), "{line}");
    }

    #[test]
    fn value_vocab_round_trips_through_the_parsers() {
        let vocab = value_vocab();
        assert_eq!(vocab.len(), 8, "one vocab entry per enum-valued spec key");
        for (key, vals) in vocab {
            assert!(!vals.is_empty(), "{key}: empty vocabulary");
            for v in vals {
                let ok = match key {
                    "workload" => parse_workload(v).is_ok(),
                    "link" => parse_link(v).is_ok(),
                    "prefill_policy" => parse_prefill_policy(v).is_ok(),
                    "decode_policy" => parse_decode_policy(v).is_ok(),
                    "dispatch" => parse_dispatch(v).is_ok(),
                    "predictor" => parse_predictor(v).is_ok(),
                    "transfer" => parse_granularity(v).is_ok(),
                    "fault_kind" => parse_fault_kind(v).is_ok(),
                    other => panic!("vocab names unknown spec key '{other}'"),
                };
                assert!(ok, "{key}: advertised value '{v}' must parse");
            }
        }
    }

    #[test]
    #[should_panic(expected = "class ids are u8")]
    fn builder_rejects_more_classes_than_u8_can_address() {
        let mut b = Scenario::builder();
        for i in 0..=crate::slo::MAX_CLASSES {
            b = b.class(ClassSpec { name: format!("c{i}"), ..Default::default() });
        }
        b.build();
    }

    #[test]
    fn class_spec_parsing_rejects_bad_shapes() {
        assert!(Scenario::from_str(r#"{"classes": [{"weight": 1}]}"#).is_err(), "name required");
        assert!(Scenario::from_str(r#"{"classes": [{"name": "a", "teir": 1}]}"#).is_err());
        assert!(Scenario::from_str(r#"{"classes": [{"name": "a", "tier": 300}]}"#).is_err());
        assert!(Scenario::from_str(r#"{"classes": [{"name": "a", "tier": 1.5}]}"#).is_err());
        assert!(Scenario::from_str(r#"{"classes": {"name": "a"}}"#).is_err(), "must be an array");
        assert!(Scenario::from_str(r#"{"admission": 1}"#).is_err(), "admission is a bool");
        // a well-formed minimal class takes every default
        let sc = Scenario::from_str(r#"{"classes": [{"name": "a"}]}"#).unwrap();
        assert_eq!(sc.classes[0].weight, 1.0);
        assert_eq!(sc.classes[0].tier, 0);
        assert!(sc.classes[0].ttft_ms.is_none() && !sc.admission);
    }

    #[test]
    fn classless_default_is_slo_inert() {
        let sc = Scenario::default();
        assert!(sc.classes.is_empty() && !sc.admission);
        let slo = sc.slo_config();
        assert!(!slo.is_active(), "classless scenarios must not activate SLO machinery");
        assert!(sc.class_weights().is_empty());
        // streamed source parity holds for classed scenarios too
        use crate::sim::ArrivalSource as _;
        let classed = Scenario::builder()
            .requests(64)
            .rate(16.0)
            .seed(5)
            .class(ClassSpec { name: "a".into(), weight: 0.7, ..Default::default() })
            .class(ClassSpec { name: "b".into(), weight: 0.3, tier: 1, ..Default::default() })
            .build();
        let want = classed.trace();
        let mut src = classed.source();
        for w in &want {
            let g = src.next_request().unwrap();
            assert_eq!((g.id, g.arrival, g.class), (w.id, w.arrival, w.class));
        }
        assert!(src.next_request().is_none());
    }

    #[test]
    fn unknown_keys_and_values_are_rejected() {
        assert!(Scenario::from_str(r#"{"dispach": "po2"}"#).is_err());
        assert!(Scenario::from_str(r#"{"dispatch": "typo"}"#).is_err());
        assert!(Scenario::from_str(r#"{"workload": "XXXX"}"#).is_err());
        assert!(Scenario::from_str(r#"{"link": "infiniband"}"#).is_err());
        assert!(Scenario::from_str(r#"{"requests": "many"}"#).is_err());
        assert!(Scenario::from_str(r#"{"phases": [{"workload": "LPLD"}]}"#).is_err());
        assert!(Scenario::from_str(r#"{"phases": [{"workload": "LPLD", "requests": 4, "rat": 1}]}"#)
            .is_err());
        assert!(Scenario::from_str(r#"{"elastic": {"max_instanses": 4}}"#).is_err());
        assert!(Scenario::from_str(r#"{"elastic": 4}"#).is_err());
        assert!(Scenario::from_str(r#"{"n_coupled": "two"}"#).is_err());
    }

    #[test]
    fn elastic_spec_defaults_fill_missing_keys() {
        let sc = Scenario::from_str(r#"{"elastic": {"max_instances": 5}}"#).unwrap();
        let el = sc.elastic.unwrap();
        assert_eq!(el.max_instances, 5);
        assert_eq!(el.min_per_role, ElasticSpec::default().min_per_role);
        // null turns it back off
        let sc = Scenario::from_str(r#"{"elastic": null}"#).unwrap();
        assert!(sc.elastic.is_none());
        // the resolved cluster config carries it through in µs
        let sc = Scenario::from_str(r#"{"elastic": {"down_idle_ms": 250}}"#).unwrap();
        let cfg = sc.cluster_config();
        assert_eq!(cfg.elastic.unwrap().down_idle_us, 250_000);
    }

    #[test]
    fn records_knob_reaches_both_configs() {
        let sc = Scenario::from_str(r#"{"records": false}"#).unwrap();
        assert!(!sc.records);
        assert!(!sc.cluster_config().retain_records);
        assert!(!sc.baseline_config().retain_records);
        // default stays on: golden runs keep exact per-request records
        let sc = Scenario::default();
        assert!(sc.records && sc.cluster_config().retain_records);
        assert!(Scenario::from_str(r#"{"records": 1}"#).is_err(), "records must be a bool");
    }

    #[test]
    fn profile_events_knob_reaches_both_configs() {
        let sc = Scenario::from_str(r#"{"profile_events": true}"#).unwrap();
        assert!(sc.profile_events);
        assert!(sc.cluster_config().profile_events);
        assert!(sc.baseline_config().profile_events);
        // default stays off: no wall-clock timing in the hot loop
        let sc = Scenario::default();
        assert!(!sc.profile_events && !sc.cluster_config().profile_events);
        assert!(
            Scenario::from_str(r#"{"profile_events": "yes"}"#).is_err(),
            "profile_events must be a bool"
        );
    }

    #[test]
    fn single_phase_sources_stream_without_materializing() {
        use crate::sim::ArrivalSource as _;
        let sc = Scenario::builder().requests(32).rate(16.0).seed(9).build();
        let want = sc.trace();
        let mut src = sc.source();
        assert_eq!(src.total(), 32);
        for w in &want {
            let g = src.next_request().unwrap();
            assert_eq!((g.id, g.arrival, g.prompt_len, g.decode_len), (w.id, w.arrival, w.prompt_len, w.decode_len));
        }
        assert!(src.next_request().is_none());
        // phased specs deliver in time order with trace-order ties
        let sc = Scenario::builder()
            .seed(9)
            .phase(WorkloadKind::Hpld, 8, 16.0, 0.0)
            .phase(WorkloadKind::Lphd, 8, 16.0, 100.0)
            .build();
        let mut src = sc.source();
        let mut last = 0;
        let mut n = 0;
        while let Some(r) = src.next_request() {
            assert!(r.arrival >= last, "phased source must be time-sorted");
            last = r.arrival;
            n += 1;
        }
        assert_eq!(n, 16);
    }

    #[test]
    fn hybrid_knob_reaches_the_cluster_config() {
        let sc = Scenario::from_str(r#"{"n_coupled": 2, "prefill_batch": 8}"#).unwrap();
        let cfg = sc.cluster_config();
        assert_eq!(cfg.n_coupled, 2);
        assert_eq!(cfg.coupled_batch, 8, "coupled instances use the vLLM fixed batch");
    }

    #[test]
    fn seeds_beyond_f64_precision_are_rejected() {
        // largest exactly-representable-and-safe seed: 2^53 - 1
        assert!(Scenario::from_str(r#"{"seed": 9007199254740991}"#).is_ok());
        // 2^53 is rejected: 2^53 + 1 parses (rounded) to the same f64, so
        // accepting it would let corrupted literals through undetected
        assert!(Scenario::from_str(r#"{"seed": 9007199254740992}"#).is_err());
        assert!(Scenario::from_str(r#"{"seed": 9007199254740993}"#).is_err());
        assert!(Scenario::from_str(r#"{"trace_seed": 1e300}"#).is_err());
        assert!(Scenario::from_str(r#"{"seed": -1}"#).is_err());
        assert!(Scenario::from_str(r#"{"seed": 1.5}"#).is_err());
    }

    #[test]
    fn trace_seed_defaults_to_seed() {
        let sc = Scenario::from_str(r#"{"seed": 42}"#).unwrap();
        assert_eq!(sc.trace_seed, 42);
        let sc = Scenario::from_str(r#"{"seed": 42, "trace_seed": 7}"#).unwrap();
        assert_eq!(sc.trace_seed, 7);
    }

    #[test]
    fn phased_trace_matches_hand_stitched_generation() {
        let sc = Scenario::builder()
            .seed(42)
            .phase(WorkloadKind::Hpld, 16, 16.0, 0.0)
            .phase(WorkloadKind::Lphd, 24, 16.0, 8_000.0)
            .build();
        let got = sc.trace();
        let mut gen = WorkloadGen::new(42);
        let mut want = gen.trace(WorkloadKind::Hpld, 16, 16.0, 0);
        want.extend(gen.trace(WorkloadKind::Lphd, 24, 16.0, 8_000_000));
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want.iter()) {
            assert_eq!(
                (a.id, a.arrival, a.prompt_len, a.decode_len),
                (b.id, b.arrival, b.prompt_len, b.decode_len)
            );
        }
        assert_eq!(sc.total_requests(), 40);
    }

    #[test]
    fn configs_mirror_legacy_defaults() {
        let sc = Scenario::default();
        let c = sc.cluster_config();
        let d = ClusterConfig::default();
        assert_eq!(c.n_prefill, d.n_prefill);
        assert_eq!(c.chunk_size, d.chunk_size);
        assert_eq!(c.prefill_policy, d.prefill_policy);
        assert_eq!(c.decode_policy, d.decode_policy);
        assert_eq!(c.dispatch, d.dispatch);
        assert_eq!(c.predictor_mode, d.predictor_mode);
        assert_eq!(c.flip.unwrap().idle_us, d.flip.unwrap().idle_us);
        let b = sc.baseline_config();
        assert_eq!(b.n_instances, 1);
        assert_eq!(b.prefill_batch, 16);
        assert_eq!(b.max_batch, 16, "baseline fixed batch follows prefill_batch");
    }

    #[test]
    fn clamp_requests_applies_to_phases_too() {
        let mut sc = Scenario::builder()
            .requests(128)
            .phase(WorkloadKind::Lpld, 64, 0.0, 0.0)
            .phase(WorkloadKind::Lphd, 4, 0.0, 0.0)
            .build();
        sc.clamp_requests(8);
        assert_eq!(sc.requests, 8);
        assert_eq!(sc.phases[0].requests, 8);
        assert_eq!(sc.phases[1].requests, 4);
    }

    #[test]
    fn summary_line_mentions_every_knob_family() {
        let line = Scenario::default().summary_line();
        for needle in [
            "driver=",
            "workload=",
            "prefill=",
            "link=",
            "dispatch=",
            "seed=",
            "flip_idle_ms=",
            "faults=off",
            "telemetry=off",
        ] {
            assert!(line.contains(needle), "summary missing {needle}: {line}");
        }
    }

    #[test]
    fn faulted_scenario_round_trips_and_resolves() {
        let sc = Scenario::builder()
            .name("chaos")
            .fault(FaultSpec { instance: Some(2), down_ms: Some(300.0), ..FaultSpec::new(FaultKind::Restart, 150.0) })
            .fault(FaultSpec::new(FaultKind::LinkOut, 400.0))
            .fault(FaultSpec { factor: Some(3.0), ..FaultSpec::new(FaultKind::Straggler, 50.0) })
            .build();
        let s = sc.to_json().dump();
        assert_eq!(Scenario::from_str(&s).unwrap(), sc);
        // the resolved configs carry the µs events, sorted by fire time
        let fc = sc.cluster_config().fault.unwrap();
        assert_eq!(fc.events.len(), 3);
        assert_eq!(fc.events[0].at, 50_000, "events sort by fire time");
        assert_eq!(fc.events[1].at, 150_000);
        assert_eq!(fc.events[1].instance, Some(2));
        assert_eq!(fc.events[1].down, 300_000);
        assert_eq!(fc.retry_max, 4);
        assert_eq!(fc.backoff_us, 25_000);
        assert_eq!(sc.baseline_config().fault.unwrap(), fc, "both drivers see one plan");
        // the startup line surfaces the plan
        assert!(sc.summary_line().contains("faults=3ev,retry4"), "{}", sc.summary_line());
    }

    #[test]
    fn prefixed_scenario_round_trips_and_resolves() {
        let sc = Scenario::builder()
            .name("reuse")
            .requests(64)
            .seed(11)
            .transfer(crate::fabric::Granularity::LayerLevel)
            .prefix(Some(PrefixSpec { n_prefixes: 8, zipf: 1.2, ..Default::default() }))
            .build();
        let s = sc.to_json().dump();
        assert_eq!(Scenario::from_str(&s).unwrap(), sc);
        // the resolved cluster config arms the cache and the layer fabric
        let cfg = sc.cluster_config();
        let pc = cfg.prefix_cache.unwrap();
        assert_eq!(pc.capacity_pages, 4096);
        assert_eq!(pc.block_tokens, 128);
        assert_eq!(cfg.transfer_granularity, crate::fabric::Granularity::LayerLevel);
        // the trace carries prefix stamps clamped to each prompt
        let trace = sc.trace();
        assert!(trace.iter().all(|r| r.prefix.is_some()));
        assert!(trace.iter().all(|r| {
            let st = r.prefix.unwrap();
            st.id < 8 && st.len <= 512.min(r.prompt_len)
        }));
        // the streamed source delivers the identical stamps
        use crate::sim::ArrivalSource as _;
        let mut src = sc.source();
        for w in &trace {
            assert_eq!(src.next_request().unwrap().prefix, w.prefix);
        }
        // the startup line surfaces the knob
        assert!(sc.summary_line().contains("prefix=8x512t,zipf1.2"), "{}", sc.summary_line());
        assert!(Scenario::default().summary_line().contains("prefix=off"));
    }

    #[test]
    fn prefix_spec_parsing_rejects_bad_shapes() {
        assert!(Scenario::from_str(r#"{"prefix": {"n_prefixs": 4}}"#).is_err(), "typo'd key");
        assert!(Scenario::from_str(r#"{"prefix": {"zipf": "hot"}}"#).is_err());
        assert!(Scenario::from_str(r#"{"prefix": {"block_tokens": 0}}"#).is_err());
        assert!(Scenario::from_str(r#"{"prefix": 4}"#).is_err());
        // null and a partial object are both accepted; defaults fill
        assert!(Scenario::from_str(r#"{"prefix": null}"#).unwrap().prefix.is_none());
        let sc = Scenario::from_str(r#"{"prefix": {"n_prefixes": 4}}"#).unwrap();
        let p = sc.prefix.unwrap();
        assert_eq!(p.n_prefixes, 4);
        assert_eq!(p.prefix_len, PrefixSpec::default().prefix_len);
        assert_eq!(p.cache_pages, 4096);
        // absent knob stays off and the cluster config stays cache-free
        assert!(Scenario::default().prefix.is_none());
        assert!(Scenario::default().cluster_config().prefix_cache.is_none());
    }

    #[test]
    fn prefix_flag_parses_like_the_spec_object() {
        assert_eq!(parse_prefix_flag("off").unwrap(), None);
        let p = parse_prefix_flag("n_prefixes=8,zipf=1.5,block_tokens=64").unwrap().unwrap();
        assert_eq!(p.n_prefixes, 8);
        assert_eq!(p.zipf, 1.5);
        assert_eq!(p.block_tokens, 64);
        assert_eq!(p.prefix_len, PrefixSpec::default().prefix_len);
        assert_eq!(parse_prefix_flag("").unwrap(), Some(PrefixSpec::default()));
        assert!(parse_prefix_flag("n_prefix=8").is_err(), "typo'd key");
        assert!(parse_prefix_flag("zipf=hot").is_err());
        assert!(parse_prefix_flag("block_tokens=0").is_err());
        assert!(parse_prefix_flag("n_prefixes").is_err(), "missing '='");
    }

    #[test]
    fn fault_spec_parsing_rejects_bad_shapes() {
        assert!(Scenario::from_str(r#"{"faults": {"events": [{"at_ms": 5}]}}"#).is_err(), "kind required");
        assert!(Scenario::from_str(r#"{"faults": {"events": [{"kind": "crash"}]}}"#).is_err(), "at_ms required");
        assert!(Scenario::from_str(r#"{"faults": {"events": [{"kind": "meteor", "at_ms": 5}]}}"#).is_err());
        assert!(Scenario::from_str(r#"{"faults": {"events": [{"kind": "crash", "at_ms": 5, "dwn_ms": 9}]}}"#).is_err());
        assert!(Scenario::from_str(r#"{"faults": {"evnts": []}}"#).is_err());
        assert!(Scenario::from_str(r#"{"faults": {"watermark": 1.5}}"#).is_err(), "validated");
        assert!(Scenario::from_str(r#"{"faults": {"backoff_ms": 0}}"#).is_err(), "validated");
        assert!(Scenario::from_str(r#"{"faults": 7}"#).is_err());
        // null and a knobs-only object are both accepted
        assert!(Scenario::from_str(r#"{"faults": null}"#).unwrap().faults.is_none());
        let sc = Scenario::from_str(r#"{"faults": {"retry_max": 2}}"#).unwrap();
        let fp = sc.faults.unwrap();
        assert_eq!(fp.retry_max, 2);
        assert!(fp.events.is_empty());
        assert_eq!(fp.backoff_ms, 25.0, "defaults fill the rest");
    }

    #[test]
    fn telemetry_spec_round_trips_and_validates() {
        let sc = Scenario::builder()
            .name("traced")
            .requests(16)
            .telemetry(Some(TelemetrySpec { sample_ms: 10.0, max_samples: 256, trace: true }))
            .build();
        let s = sc.to_json().dump();
        assert_eq!(Scenario::from_str(&s).unwrap(), sc);
        // partial objects fill from defaults; null turns it back off
        let t = Scenario::from_str(r#"{"telemetry": {"sample_ms": 5}}"#)
            .unwrap()
            .telemetry
            .unwrap();
        assert_eq!(t.sample_ms, 5.0);
        assert_eq!(t.max_samples, TelemetrySpec::default().max_samples);
        assert!(!t.trace);
        assert!(Scenario::from_str(r#"{"telemetry": null}"#).unwrap().telemetry.is_none());
        // bad shapes are rejected at parse time
        for bad in [
            r#"{"telemetry": {"sample_mss": 5}}"#,
            r#"{"telemetry": {"sample_ms": 0}}"#,
            r#"{"telemetry": {"sample_ms": -1}}"#,
            r#"{"telemetry": {"max_samples": 1}}"#,
            r#"{"telemetry": {"trace": 1}}"#,
            r#"{"telemetry": 7}"#,
        ] {
            assert!(Scenario::from_str(bad).is_err(), "{bad} should be rejected");
        }
        // the startup line surfaces the knob
        assert!(sc.summary_line().contains("telemetry=10ms,cap256,trace"), "{}", sc.summary_line());
        // telemetry never enters the trace generator
        assert_eq!(
            sc.trace_key(),
            Scenario { telemetry: None, ..sc.clone() }.trace_key()
        );
    }

    #[test]
    fn telemetry_flag_parses_like_the_spec_object() {
        assert_eq!(parse_telemetry_flag("off").unwrap(), None);
        assert_eq!(parse_telemetry_flag("").unwrap(), Some(TelemetrySpec::default()));
        let t = parse_telemetry_flag("sample_ms=5,max_samples=64,trace").unwrap().unwrap();
        assert_eq!(t.sample_ms, 5.0);
        assert_eq!(t.max_samples, 64);
        assert!(t.trace);
        assert!(!parse_telemetry_flag("trace=false").unwrap().unwrap().trace);
        assert!(parse_telemetry_flag("sample_ms=0").is_err());
        assert!(parse_telemetry_flag("max_samples=1").is_err());
        assert!(parse_telemetry_flag("sampl_ms=5").is_err(), "typo'd key");
        assert!(parse_telemetry_flag("trace=maybe").is_err());
        assert!(parse_telemetry_flag("sample_ms").is_err(), "missing '='");
    }
}
