//! Streaming run observers: per-event hooks threaded through both DES
//! drivers (cluster and coupled baseline).
//!
//! Observers *watch* a run — they never influence it. Both drivers call
//! the hooks at the instant an action is issued into the event queue, so
//! a hook receives `(now, dur)` and knows the action completes at
//! `now + dur`; metrics are bit-identical whichever observer is attached
//! (golden-tested). All hooks default to no-ops, so an observer implements
//! only what it cares about.

use crate::prefill::DecodeLoad;
use crate::types::{ReqId, Request, RequestRecord, Role, Us};
use crate::util::Json;

/// Per-event hooks over a DES run. `now` is virtual µs.
pub trait Observer {
    /// A request was first admitted by the global scheduler (retries after
    /// mid-flip windows do not re-fire this hook).
    fn on_arrival(&mut self, _now: Us, _req: &Request) {}

    /// A prefill chunk was issued on `instance`; it completes at
    /// `now + dur`. `tokens` are real prompt tokens, `pad` the shape
    /// filler of a partial final chunk.
    fn on_chunk(&mut self, _now: Us, _instance: usize, _tokens: u32, _pad: u32, _dur: Us) {}

    /// A KV transfer of `tokens` prompt tokens toward decode `instance`
    /// was scheduled for original request `req`; it lands at `now + dur`.
    fn on_transfer(&mut self, _now: Us, _instance: usize, _req: ReqId, _tokens: u32, _dur: Us) {}

    /// A decode iteration was issued on `instance` over `batch` resident
    /// requests holding `kv_tokens` of KV; it completes at `now + dur`.
    /// The coupled baseline fires this for the decode side of its mixed
    /// iterations, and only when that side is non-empty (`batch > 0`) —
    /// a pure-prefill iteration fires `on_chunk` alone.
    fn on_decode_iter(&mut self, _now: Us, _instance: usize, _batch: u32, _kv_tokens: u64, _dur: Us) {
    }

    /// `instance` began flipping toward role `to` (§3.5); the new
    /// incarnation is live at `now + dur`.
    fn on_flip(&mut self, _now: Us, _instance: usize, _to: Role, _dur: Us) {}

    /// The elastic autoscaler changed the pool: `instance` was added to
    /// serve `role` (`added`), or finished draining and retired from
    /// `role` (`!added`). Static pools never fire this.
    fn on_scale(&mut self, _now: Us, _instance: usize, _role: Role, _added: bool) {}

    /// A request finished; `rec` carries the original id and timestamps.
    fn on_finish(&mut self, _now: Us, _rec: &RequestRecord) {}

    /// The admission gate shed `req` at the entry router (over-rate or
    /// over-depth for its workload class). Sheds are first-class request
    /// outcomes: counted per class in the run metrics, surfaced here, and
    /// never re-delivered. Classless runs (admission off) never fire this.
    fn on_shed(&mut self, _now: Us, _req: &Request) {}

    /// A request finished *outside* its class SLO: `ttft` / `tpot` flag
    /// which deadline(s) it blew. Fires at most once per request, right
    /// after `on_finish`. Runs without declared deadlines never fire this.
    fn on_violation(&mut self, _now: Us, _rec: &RequestRecord, _ttft: bool, _tpot: bool) {}

    /// The cluster monitor broadcast fresh decode loads (one sample per
    /// decode instance, paper period ~100 ms). The baseline never fires
    /// this (it has no monitor).
    fn on_monitor(&mut self, _now: Us, _loads: &[DecodeLoad]) {}

    /// A fault fired. `kind` names it (`"crash"`, `"link_out"`,
    /// `"link_degrade"`, `"straggler"`, `"request_failed"`); `instance` is
    /// the victim when the fault targets one. Fault-free runs never fire
    /// this.
    fn on_fault(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {}

    /// The system recovered from a fault: `"restart"` (a crashed instance
    /// came back), `"requeue"` (a lost request re-entered the prefill
    /// queue with backoff), `"resend"` (an in-flight KV transfer hit a
    /// link outage and was re-sent). Fault-free runs never fire this.
    fn on_recovery(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {}

    /// Sequential-mode length prediction for original request `req` was
    /// issued; the result lands at `now + dur` and only then can the
    /// request be scheduled. Parallel/disabled predictor modes never
    /// fire this (prediction co-runs with prefill, §3.3.2).
    fn on_predict(&mut self, _now: Us, _req: ReqId, _dur: Us) {}

    /// Original request `req` was included in its first prefill
    /// chunk (or in the prefill side of its first coupled iteration) on
    /// `instance` — the queue→prefill phase boundary.
    fn on_prefill_start(&mut self, _now: Us, _instance: usize, _req: ReqId) {}

    /// Original request `req`'s prompt completed on `instance` — its
    /// first token exists and its KV is ready to dispatch. Fires for
    /// every completed prefill, including single-token requests that
    /// finish right here.
    fn on_prefill_finish(&mut self, _now: Us, _instance: usize, _req: ReqId) {}

    /// Original request `req` joined the decode batch on `instance`
    /// (post-transfer on disaggregated fleets; at the prefilling
    /// iteration's end on coupled instances).
    fn on_decode_enter(&mut self, _now: Us, _instance: usize, _req: ReqId) {}

    /// Original request `req` could not be dispatched to any decode
    /// instance and was parked pending capacity (degraded cluster).
    /// May re-fire at every monitor-tick retry while parked.
    fn on_parked(&mut self, _now: Us, _req: ReqId) {}

    /// Original request `req` was lost to a fault and re-queued with
    /// backoff; it re-enters the entry router at `until`. Fires right
    /// before the matching `on_recovery(_, "requeue", _)`.
    fn on_backoff(&mut self, _now: Us, _req: ReqId, _until: Us) {}

    /// A request exhausted its retry budget and failed terminally.
    /// Fires right after the matching `on_fault(_, "request_failed", _)`
    /// with the full request attached.
    fn on_request_failed(&mut self, _now: Us, _req: &Request) {}

    /// The prefix cache was consulted for original request `req`;
    /// `hit_tokens` prompt tokens were served from cache (0 = miss).
    /// Cache-off runs never fire this.
    fn on_cache(&mut self, _now: Us, _req: ReqId, _hit_tokens: u32) {}
}

/// Forwards every hook to two observers, in order — how the scenario
/// runner composes the telemetry collector with the caller's observer
/// without either knowing about the other.
pub struct Tee<'a> {
    pub first: &'a mut dyn Observer,
    pub second: &'a mut dyn Observer,
}

impl<'a> Tee<'a> {
    pub fn new(first: &'a mut dyn Observer, second: &'a mut dyn Observer) -> Self {
        Tee { first, second }
    }
}

impl Observer for Tee<'_> {
    fn on_arrival(&mut self, now: Us, req: &Request) {
        self.first.on_arrival(now, req);
        self.second.on_arrival(now, req);
    }

    fn on_chunk(&mut self, now: Us, instance: usize, tokens: u32, pad: u32, dur: Us) {
        self.first.on_chunk(now, instance, tokens, pad, dur);
        self.second.on_chunk(now, instance, tokens, pad, dur);
    }

    fn on_transfer(&mut self, now: Us, instance: usize, req: ReqId, tokens: u32, dur: Us) {
        self.first.on_transfer(now, instance, req, tokens, dur);
        self.second.on_transfer(now, instance, req, tokens, dur);
    }

    fn on_decode_iter(&mut self, now: Us, instance: usize, batch: u32, kv_tokens: u64, dur: Us) {
        self.first.on_decode_iter(now, instance, batch, kv_tokens, dur);
        self.second.on_decode_iter(now, instance, batch, kv_tokens, dur);
    }

    fn on_flip(&mut self, now: Us, instance: usize, to: Role, dur: Us) {
        self.first.on_flip(now, instance, to, dur);
        self.second.on_flip(now, instance, to, dur);
    }

    fn on_scale(&mut self, now: Us, instance: usize, role: Role, added: bool) {
        self.first.on_scale(now, instance, role, added);
        self.second.on_scale(now, instance, role, added);
    }

    fn on_finish(&mut self, now: Us, rec: &RequestRecord) {
        self.first.on_finish(now, rec);
        self.second.on_finish(now, rec);
    }

    fn on_shed(&mut self, now: Us, req: &Request) {
        self.first.on_shed(now, req);
        self.second.on_shed(now, req);
    }

    fn on_violation(&mut self, now: Us, rec: &RequestRecord, ttft: bool, tpot: bool) {
        self.first.on_violation(now, rec, ttft, tpot);
        self.second.on_violation(now, rec, ttft, tpot);
    }

    fn on_monitor(&mut self, now: Us, loads: &[DecodeLoad]) {
        self.first.on_monitor(now, loads);
        self.second.on_monitor(now, loads);
    }

    fn on_fault(&mut self, now: Us, kind: &'static str, instance: Option<usize>) {
        self.first.on_fault(now, kind, instance);
        self.second.on_fault(now, kind, instance);
    }

    fn on_recovery(&mut self, now: Us, kind: &'static str, instance: Option<usize>) {
        self.first.on_recovery(now, kind, instance);
        self.second.on_recovery(now, kind, instance);
    }

    fn on_predict(&mut self, now: Us, req: ReqId, dur: Us) {
        self.first.on_predict(now, req, dur);
        self.second.on_predict(now, req, dur);
    }

    fn on_prefill_start(&mut self, now: Us, instance: usize, req: ReqId) {
        self.first.on_prefill_start(now, instance, req);
        self.second.on_prefill_start(now, instance, req);
    }

    fn on_prefill_finish(&mut self, now: Us, instance: usize, req: ReqId) {
        self.first.on_prefill_finish(now, instance, req);
        self.second.on_prefill_finish(now, instance, req);
    }

    fn on_decode_enter(&mut self, now: Us, instance: usize, req: ReqId) {
        self.first.on_decode_enter(now, instance, req);
        self.second.on_decode_enter(now, instance, req);
    }

    fn on_parked(&mut self, now: Us, req: ReqId) {
        self.first.on_parked(now, req);
        self.second.on_parked(now, req);
    }

    fn on_backoff(&mut self, now: Us, req: ReqId, until: Us) {
        self.first.on_backoff(now, req, until);
        self.second.on_backoff(now, req, until);
    }

    fn on_request_failed(&mut self, now: Us, req: &Request) {
        self.first.on_request_failed(now, req);
        self.second.on_request_failed(now, req);
    }

    fn on_cache(&mut self, now: Us, req: ReqId, hit_tokens: u32) {
        self.first.on_cache(now, req, hit_tokens);
        self.second.on_cache(now, req, hit_tokens);
    }
}

/// The do-nothing observer: what `run_cluster`/`run_baseline` attach.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullObserver;

impl Observer for NullObserver {}

/// What kind of activity a [`TimelineObserver`] span records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    PrefillChunk,
    DecodeIter,
    Transfer,
    Flip,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::PrefillChunk => "chunk",
            SpanKind::DecodeIter => "decode",
            SpanKind::Transfer => "transfer",
            SpanKind::Flip => "flip",
        }
    }
}

/// One busy interval `[at, at + dur)` on an instance. `size` is the
/// kind-specific magnitude: chunk tokens, decode batch, transfer tokens,
/// or 0 for flips.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub at: Us,
    pub dur: Us,
    pub instance: usize,
    pub kind: SpanKind,
    pub size: u64,
}

/// One monitor-tick queue-depth sample for a decode instance.
#[derive(Clone, Copy, Debug)]
pub struct QueueSample {
    pub at: Us,
    pub instance: usize,
    pub queue_len: u32,
    pub n_heavy: u32,
    pub n_light: u32,
}

impl QueueSample {
    /// The one projection from a monitor broadcast entry to a sample —
    /// shared by [`TimelineObserver`] and the telemetry series sampler
    /// so the two can never drift on field semantics.
    pub fn from_load(at: Us, l: &DecodeLoad) -> Self {
        QueueSample {
            at,
            instance: l.instance,
            queue_len: l.queue_len,
            n_heavy: l.n_heavy,
            n_light: l.n_light,
        }
    }
}

/// Records per-instance busy/queue traces — the raw series behind
/// Figure-4-style interference plots. Also subsumes the driver's old
/// ad-hoc chunk counters (`total_chunks`/`total_pad_tokens` lived on the
/// cluster struct before this existed).
#[derive(Clone, Debug, Default)]
pub struct TimelineObserver {
    pub spans: Vec<Span>,
    pub queue: Vec<QueueSample>,
    /// (finish time, original request id).
    pub finished: Vec<(Us, ReqId)>,
    /// (arrival time, original request id) — timestamped, so the trace
    /// exporter can reuse the timeline as a span source.
    pub arrival_events: Vec<(Us, ReqId)>,
    /// (shed time, original request id).
    pub shed_events: Vec<(Us, ReqId)>,
    /// (violation time, original request id, blew_ttft, blew_tpot).
    pub violation_events: Vec<(Us, ReqId, bool, bool)>,
    pub arrivals: u64,
    pub chunks: u64,
    pub pad_tokens: u64,
    pub transfers: u64,
    pub decode_iters: u64,
    pub flips: u64,
    /// Elastic pool growth events (instances added mid-run).
    pub scale_ups: u64,
    /// Elastic pool shrink events (instances drained and retired).
    pub scale_downs: u64,
    /// Requests the admission gate shed (SLO multi-tenancy runs).
    pub sheds: u64,
    /// Requests that finished outside their class SLO.
    pub violations: u64,
    /// Fault injections delivered (chaos runs only).
    pub faults: u64,
    /// Recovery actions taken: restarts, requeues, transfer re-sends.
    pub recoveries: u64,
}

impl TimelineObserver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Busy µs attributed to `instance` (compute spans only — transfers
    /// occupy the wire, not the instance).
    pub fn busy_us(&self, instance: usize) -> Us {
        self.spans
            .iter()
            .filter(|s| {
                s.instance == instance
                    && matches!(s.kind, SpanKind::PrefillChunk | SpanKind::DecodeIter)
            })
            .map(|s| s.dur)
            .sum()
    }

    /// Busy intervals `(start, end)` for one instance, in issue order.
    pub fn busy_series(&self, instance: usize) -> Vec<(Us, Us)> {
        self.spans
            .iter()
            .filter(|s| {
                s.instance == instance
                    && matches!(s.kind, SpanKind::PrefillChunk | SpanKind::DecodeIter)
            })
            .map(|s| (s.at, s.at + s.dur))
            .collect()
    }

    /// Queue-depth series `(t, queue_len)` for one decode instance.
    pub fn queue_series(&self, instance: usize) -> Vec<(Us, u32)> {
        self.queue
            .iter()
            .filter(|q| q.instance == instance)
            .map(|q| (q.at, q.queue_len))
            .collect()
    }

    /// Machine-readable dump (spans + queue samples) for external plotting.
    pub fn to_json(&self) -> Json {
        let spans: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                Json::obj([
                    ("at_us", Json::from(s.at)),
                    ("dur_us", Json::from(s.dur)),
                    ("instance", Json::from(s.instance)),
                    ("kind", Json::from(s.kind.name())),
                    ("size", Json::from(s.size)),
                ])
            })
            .collect();
        let queue: Vec<Json> = self
            .queue
            .iter()
            .map(|q| {
                Json::obj([
                    ("at_us", Json::from(q.at)),
                    ("instance", Json::from(q.instance)),
                    ("queue_len", Json::from(u64::from(q.queue_len))),
                    ("n_heavy", Json::from(u64::from(q.n_heavy))),
                    ("n_light", Json::from(u64::from(q.n_light))),
                ])
            })
            .collect();
        let stamped = |evs: &[(Us, ReqId)]| -> Json {
            Json::from(
                evs.iter()
                    .map(|&(at, id)| {
                        Json::obj([("at_us", Json::from(at)), ("req", Json::from(id))])
                    })
                    .collect::<Vec<Json>>(),
            )
        };
        Json::obj([
            ("arrivals", Json::from(self.arrivals)),
            ("arrival_events", stamped(&self.arrival_events)),
            ("shed_events", stamped(&self.shed_events)),
            ("chunks", Json::from(self.chunks)),
            ("pad_tokens", Json::from(self.pad_tokens)),
            ("transfers", Json::from(self.transfers)),
            ("decode_iters", Json::from(self.decode_iters)),
            ("flips", Json::from(self.flips)),
            ("scale_ups", Json::from(self.scale_ups)),
            ("scale_downs", Json::from(self.scale_downs)),
            ("sheds", Json::from(self.sheds)),
            ("violations", Json::from(self.violations)),
            ("faults", Json::from(self.faults)),
            ("recoveries", Json::from(self.recoveries)),
            ("spans", Json::from(spans)),
            ("queue", Json::from(queue)),
        ])
    }
}

impl Observer for TimelineObserver {
    fn on_arrival(&mut self, now: Us, req: &Request) {
        self.arrivals += 1;
        self.arrival_events.push((now, req.id));
    }

    fn on_chunk(&mut self, now: Us, instance: usize, tokens: u32, pad: u32, dur: Us) {
        self.chunks += 1;
        self.pad_tokens += pad as u64;
        self.spans.push(Span {
            at: now,
            dur,
            instance,
            kind: SpanKind::PrefillChunk,
            size: tokens as u64,
        });
    }

    fn on_transfer(&mut self, now: Us, instance: usize, _req: ReqId, tokens: u32, dur: Us) {
        self.transfers += 1;
        self.spans.push(Span {
            at: now,
            dur,
            instance,
            kind: SpanKind::Transfer,
            size: tokens as u64,
        });
    }

    fn on_decode_iter(&mut self, now: Us, instance: usize, batch: u32, _kv_tokens: u64, dur: Us) {
        self.decode_iters += 1;
        self.spans.push(Span {
            at: now,
            dur,
            instance,
            kind: SpanKind::DecodeIter,
            size: batch as u64,
        });
    }

    fn on_flip(&mut self, now: Us, instance: usize, _to: Role, dur: Us) {
        self.flips += 1;
        self.spans.push(Span { at: now, dur, instance, kind: SpanKind::Flip, size: 0 });
    }

    fn on_scale(&mut self, _now: Us, _instance: usize, _role: Role, added: bool) {
        if added {
            self.scale_ups += 1;
        } else {
            self.scale_downs += 1;
        }
    }

    fn on_finish(&mut self, now: Us, rec: &RequestRecord) {
        self.finished.push((now, rec.id));
    }

    fn on_shed(&mut self, now: Us, req: &Request) {
        self.sheds += 1;
        self.shed_events.push((now, req.id));
    }

    fn on_violation(&mut self, now: Us, rec: &RequestRecord, ttft: bool, tpot: bool) {
        self.violations += 1;
        self.violation_events.push((now, rec.id, ttft, tpot));
    }

    fn on_fault(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {
        self.faults += 1;
    }

    fn on_recovery(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {
        self.recoveries += 1;
    }

    fn on_monitor(&mut self, now: Us, loads: &[DecodeLoad]) {
        for l in loads {
            self.queue.push(QueueSample::from_load(now, l));
        }
    }
}

/// Prints coarse progress to stderr as requests resolve — for long
/// interactive runs (`tetri sim --progress`). Every terminal outcome
/// advances progress: finishes, admission sheds, and terminal failures
/// all count, so a heavy-shed overload run ticks instead of appearing
/// hung at the last finished count.
#[derive(Debug)]
pub struct ProgressObserver {
    total: usize,
    done: usize,
    shed: usize,
    failed: usize,
    every: usize,
}

impl ProgressObserver {
    /// Report every `every` resolutions (and at the end). `every` is
    /// clamped to at least 1.
    pub fn new(total: usize, every: usize) -> Self {
        ProgressObserver { total, done: 0, shed: 0, failed: 0, every: every.max(1) }
    }

    /// Requests that finished normally.
    pub fn done(&self) -> usize {
        self.done
    }

    /// Every resolved request: finished + shed + failed — what progress
    /// is measured against.
    pub fn resolved(&self) -> usize {
        self.done + self.shed + self.failed
    }

    fn step(&mut self, now: Us) {
        let n = self.resolved();
        if n % self.every == 0 || n == self.total {
            eprintln!(
                "[progress] {}/{} requests resolved (finished {} / shed {} / failed {}) at t={:.2}s (sim)",
                n,
                self.total,
                self.done,
                self.shed,
                self.failed,
                now as f64 / 1e6
            );
        }
    }
}

impl Observer for ProgressObserver {
    fn on_finish(&mut self, now: Us, _rec: &RequestRecord) {
        self.done += 1;
        self.step(now);
    }

    fn on_shed(&mut self, now: Us, _req: &Request) {
        self.shed += 1;
        self.step(now);
    }

    fn on_request_failed(&mut self, now: Us, _req: &Request) {
        self.failed += 1;
        self.step(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn rec(id: ReqId) -> RequestRecord {
        RequestRecord {
            id,
            task: TaskType::Chat,
            class: 0,
            prompt_len: 10,
            decode_len: 5,
            arrival: 0,
            first_token: 10,
            finished: 20,
            predicted: None,
            retries: 0,
            recovered: false,
        }
    }

    #[test]
    fn timeline_accumulates_spans_and_counters() {
        let mut t = TimelineObserver::new();
        t.on_chunk(0, 0, 512, 12, 100);
        t.on_chunk(100, 0, 256, 0, 50);
        t.on_decode_iter(200, 1, 8, 800, 30);
        t.on_transfer(150, 1, 7, 512, 40);
        t.on_flip(400, 0, Role::Decode, 6_000);
        t.on_finish(500, &rec(7));
        let shed_req = Request {
            id: 8,
            task: TaskType::Chat,
            class: 2,
            arrival: 510,
            prompt_len: 4,
            decode_len: 4,
            predicted: None,
            prefix: None,
        };
        t.on_shed(510, &shed_req);
        t.on_violation(520, &rec(9), true, false);
        t.on_fault(530, "crash", Some(0));
        t.on_recovery(540, "restart", Some(0));
        t.on_recovery(550, "requeue", None);
        assert_eq!((t.sheds, t.violations), (1, 1));
        assert_eq!((t.faults, t.recoveries), (1, 2));
        assert_eq!(t.chunks, 2);
        assert_eq!(t.pad_tokens, 12);
        assert_eq!(t.busy_us(0), 150, "flip spans are not busy compute");
        assert_eq!(t.busy_us(1), 30, "transfer spans occupy the wire, not the instance");
        assert_eq!(t.busy_series(0), vec![(0, 100), (100, 150)]);
        assert_eq!(t.finished, vec![(500, 7)]);
        // json dump parses back
        let s = t.to_json().dump();
        assert!(crate::util::Json::parse(&s).is_ok());
    }

    #[test]
    fn progress_counts_finishes() {
        let mut p = ProgressObserver::new(3, 100);
        p.on_finish(1, &rec(0));
        p.on_finish(2, &rec(1));
        assert_eq!(p.done(), 2);
    }

    fn request(id: ReqId) -> Request {
        Request {
            id,
            task: TaskType::Chat,
            class: 0,
            arrival: 0,
            prompt_len: 4,
            decode_len: 4,
            predicted: None,
            prefix: None,
        }
    }

    #[test]
    fn progress_counts_shed_and_failed_toward_resolution() {
        // a heavy-shed overload run must tick: sheds and terminal
        // failures resolve requests just as finishes do
        let mut p = ProgressObserver::new(4, 100);
        p.on_finish(1, &rec(0));
        p.on_shed(2, &request(1));
        p.on_shed(3, &request(2));
        p.on_request_failed(4, &request(3));
        assert_eq!(p.done(), 1, "done() stays finishes-only");
        assert_eq!(p.resolved(), 4, "finished + shed + failed all advance progress");
    }

    #[test]
    fn timeline_routes_timestamped_arrival_shed_violation_events() {
        let mut t = TimelineObserver::new();
        t.on_arrival(100, &request(7));
        t.on_arrival(250, &request(8));
        t.on_shed(250, &request(8));
        t.on_violation(900, &rec(7), true, false);
        assert_eq!(t.arrivals, 2);
        assert_eq!(t.arrival_events, vec![(100, 7), (250, 8)], "arrival keeps its timestamp");
        assert_eq!(t.shed_events, vec![(250, 8)]);
        assert_eq!(t.violation_events, vec![(900, 7, true, false)]);
        let s = t.to_json().dump();
        let j = crate::util::Json::parse(&s).unwrap();
        assert_eq!(j.get("arrival_events").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("shed_events").unwrap().as_arr().unwrap()[0].get("at_us").unwrap().as_usize(),
            Some(250)
        );
    }

    #[test]
    fn queue_sample_from_load_is_the_shared_projection() {
        let l = DecodeLoad {
            instance: 3,
            free_kv_tokens: 100,
            n_heavy: 2,
            n_light: 5,
            queue_len: 7,
        };
        let q = QueueSample::from_load(42, &l);
        assert_eq!((q.at, q.instance, q.queue_len, q.n_heavy, q.n_light), (42, 3, 7, 2, 5));
        let mut t = TimelineObserver::new();
        t.on_monitor(42, &[l]);
        assert_eq!(t.queue_series(3), vec![(42, 7)]);
    }

    #[derive(Default)]
    struct Counter {
        calls: u64,
    }

    impl Observer for Counter {
        fn on_arrival(&mut self, _: Us, _: &Request) {
            self.calls += 1;
        }
        fn on_predict(&mut self, _: Us, _: ReqId, _: Us) {
            self.calls += 1;
        }
        fn on_prefill_start(&mut self, _: Us, _: usize, _: ReqId) {
            self.calls += 1;
        }
        fn on_prefill_finish(&mut self, _: Us, _: usize, _: ReqId) {
            self.calls += 1;
        }
        fn on_decode_enter(&mut self, _: Us, _: usize, _: ReqId) {
            self.calls += 1;
        }
        fn on_parked(&mut self, _: Us, _: ReqId) {
            self.calls += 1;
        }
        fn on_backoff(&mut self, _: Us, _: ReqId, _: Us) {
            self.calls += 1;
        }
        fn on_request_failed(&mut self, _: Us, _: &Request) {
            self.calls += 1;
        }
        fn on_cache(&mut self, _: Us, _: ReqId, _: u32) {
            self.calls += 1;
        }
        fn on_finish(&mut self, _: Us, _: &RequestRecord) {
            self.calls += 1;
        }
    }

    #[test]
    fn tee_forwards_every_hook_to_both_observers() {
        let (mut a, mut b) = (Counter::default(), Counter::default());
        {
            let mut tee = Tee::new(&mut a, &mut b);
            tee.on_arrival(0, &request(1));
            tee.on_predict(1, 1, 5);
            tee.on_prefill_start(2, 0, 1);
            tee.on_prefill_finish(3, 0, 1);
            tee.on_cache(3, 1, 64);
            tee.on_decode_enter(4, 1, 1);
            tee.on_parked(5, 1);
            tee.on_backoff(6, 1, 10);
            tee.on_request_failed(7, &request(1));
            tee.on_finish(8, &rec(1));
        }
        assert_eq!(a.calls, 10);
        assert_eq!(b.calls, 10, "both sides see every hook, in order");
    }

    #[test]
    fn null_observer_is_free() {
        let mut n = NullObserver;
        n.on_chunk(0, 0, 1, 0, 1);
        n.on_finish(0, &rec(0));
    }
}
