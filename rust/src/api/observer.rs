//! Streaming run observers: per-event hooks threaded through both DES
//! drivers (cluster and coupled baseline).
//!
//! Observers *watch* a run — they never influence it. Both drivers call
//! the hooks at the instant an action is issued into the event queue, so
//! a hook receives `(now, dur)` and knows the action completes at
//! `now + dur`; metrics are bit-identical whichever observer is attached
//! (golden-tested). All hooks default to no-ops, so an observer implements
//! only what it cares about.

use crate::prefill::DecodeLoad;
use crate::types::{ReqId, Request, RequestRecord, Role, Us};
use crate::util::Json;

/// Per-event hooks over a DES run. `now` is virtual µs.
pub trait Observer {
    /// A request was first admitted by the global scheduler (retries after
    /// mid-flip windows do not re-fire this hook).
    fn on_arrival(&mut self, _now: Us, _req: &Request) {}

    /// A prefill chunk was issued on `instance`; it completes at
    /// `now + dur`. `tokens` are real prompt tokens, `pad` the shape
    /// filler of a partial final chunk.
    fn on_chunk(&mut self, _now: Us, _instance: usize, _tokens: u32, _pad: u32, _dur: Us) {}

    /// A KV transfer of `tokens` prompt tokens toward decode `instance`
    /// was scheduled for original request `req`; it lands at `now + dur`.
    fn on_transfer(&mut self, _now: Us, _instance: usize, _req: ReqId, _tokens: u32, _dur: Us) {}

    /// A decode iteration was issued on `instance` over `batch` resident
    /// requests holding `kv_tokens` of KV; it completes at `now + dur`.
    /// The coupled baseline fires this for the decode side of its mixed
    /// iterations, and only when that side is non-empty (`batch > 0`) —
    /// a pure-prefill iteration fires `on_chunk` alone.
    fn on_decode_iter(&mut self, _now: Us, _instance: usize, _batch: u32, _kv_tokens: u64, _dur: Us) {
    }

    /// `instance` began flipping toward role `to` (§3.5); the new
    /// incarnation is live at `now + dur`.
    fn on_flip(&mut self, _now: Us, _instance: usize, _to: Role, _dur: Us) {}

    /// The elastic autoscaler changed the pool: `instance` was added to
    /// serve `role` (`added`), or finished draining and retired from
    /// `role` (`!added`). Static pools never fire this.
    fn on_scale(&mut self, _now: Us, _instance: usize, _role: Role, _added: bool) {}

    /// A request finished; `rec` carries the original id and timestamps.
    fn on_finish(&mut self, _now: Us, _rec: &RequestRecord) {}

    /// The admission gate shed `req` at the entry router (over-rate or
    /// over-depth for its workload class). Sheds are first-class request
    /// outcomes: counted per class in the run metrics, surfaced here, and
    /// never re-delivered. Classless runs (admission off) never fire this.
    fn on_shed(&mut self, _now: Us, _req: &Request) {}

    /// A request finished *outside* its class SLO: `ttft` / `tpot` flag
    /// which deadline(s) it blew. Fires at most once per request, right
    /// after `on_finish`. Runs without declared deadlines never fire this.
    fn on_violation(&mut self, _now: Us, _rec: &RequestRecord, _ttft: bool, _tpot: bool) {}

    /// The cluster monitor broadcast fresh decode loads (one sample per
    /// decode instance, paper period ~100 ms). The baseline never fires
    /// this (it has no monitor).
    fn on_monitor(&mut self, _now: Us, _loads: &[DecodeLoad]) {}

    /// A fault fired. `kind` names it (`"crash"`, `"link_out"`,
    /// `"link_degrade"`, `"straggler"`, `"request_failed"`); `instance` is
    /// the victim when the fault targets one. Fault-free runs never fire
    /// this.
    fn on_fault(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {}

    /// The system recovered from a fault: `"restart"` (a crashed instance
    /// came back), `"requeue"` (a lost request re-entered the prefill
    /// queue with backoff), `"resend"` (an in-flight KV transfer hit a
    /// link outage and was re-sent). Fault-free runs never fire this.
    fn on_recovery(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {}
}

/// The do-nothing observer: what `run_cluster`/`run_baseline` attach.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullObserver;

impl Observer for NullObserver {}

/// What kind of activity a [`TimelineObserver`] span records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    PrefillChunk,
    DecodeIter,
    Transfer,
    Flip,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::PrefillChunk => "chunk",
            SpanKind::DecodeIter => "decode",
            SpanKind::Transfer => "transfer",
            SpanKind::Flip => "flip",
        }
    }
}

/// One busy interval `[at, at + dur)` on an instance. `size` is the
/// kind-specific magnitude: chunk tokens, decode batch, transfer tokens,
/// or 0 for flips.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub at: Us,
    pub dur: Us,
    pub instance: usize,
    pub kind: SpanKind,
    pub size: u64,
}

/// One monitor-tick queue-depth sample for a decode instance.
#[derive(Clone, Copy, Debug)]
pub struct QueueSample {
    pub at: Us,
    pub instance: usize,
    pub queue_len: u32,
    pub n_heavy: u32,
    pub n_light: u32,
}

/// Records per-instance busy/queue traces — the raw series behind
/// Figure-4-style interference plots. Also subsumes the driver's old
/// ad-hoc chunk counters (`total_chunks`/`total_pad_tokens` lived on the
/// cluster struct before this existed).
#[derive(Clone, Debug, Default)]
pub struct TimelineObserver {
    pub spans: Vec<Span>,
    pub queue: Vec<QueueSample>,
    /// (finish time, original request id).
    pub finished: Vec<(Us, ReqId)>,
    pub arrivals: u64,
    pub chunks: u64,
    pub pad_tokens: u64,
    pub transfers: u64,
    pub decode_iters: u64,
    pub flips: u64,
    /// Elastic pool growth events (instances added mid-run).
    pub scale_ups: u64,
    /// Elastic pool shrink events (instances drained and retired).
    pub scale_downs: u64,
    /// Requests the admission gate shed (SLO multi-tenancy runs).
    pub sheds: u64,
    /// Requests that finished outside their class SLO.
    pub violations: u64,
    /// Fault injections delivered (chaos runs only).
    pub faults: u64,
    /// Recovery actions taken: restarts, requeues, transfer re-sends.
    pub recoveries: u64,
}

impl TimelineObserver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Busy µs attributed to `instance` (compute spans only — transfers
    /// occupy the wire, not the instance).
    pub fn busy_us(&self, instance: usize) -> Us {
        self.spans
            .iter()
            .filter(|s| {
                s.instance == instance
                    && matches!(s.kind, SpanKind::PrefillChunk | SpanKind::DecodeIter)
            })
            .map(|s| s.dur)
            .sum()
    }

    /// Busy intervals `(start, end)` for one instance, in issue order.
    pub fn busy_series(&self, instance: usize) -> Vec<(Us, Us)> {
        self.spans
            .iter()
            .filter(|s| {
                s.instance == instance
                    && matches!(s.kind, SpanKind::PrefillChunk | SpanKind::DecodeIter)
            })
            .map(|s| (s.at, s.at + s.dur))
            .collect()
    }

    /// Queue-depth series `(t, queue_len)` for one decode instance.
    pub fn queue_series(&self, instance: usize) -> Vec<(Us, u32)> {
        self.queue
            .iter()
            .filter(|q| q.instance == instance)
            .map(|q| (q.at, q.queue_len))
            .collect()
    }

    /// Machine-readable dump (spans + queue samples) for external plotting.
    pub fn to_json(&self) -> Json {
        let spans: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                Json::obj([
                    ("at_us", Json::from(s.at)),
                    ("dur_us", Json::from(s.dur)),
                    ("instance", Json::from(s.instance)),
                    ("kind", Json::from(s.kind.name())),
                    ("size", Json::from(s.size)),
                ])
            })
            .collect();
        let queue: Vec<Json> = self
            .queue
            .iter()
            .map(|q| {
                Json::obj([
                    ("at_us", Json::from(q.at)),
                    ("instance", Json::from(q.instance)),
                    ("queue_len", Json::from(u64::from(q.queue_len))),
                    ("n_heavy", Json::from(u64::from(q.n_heavy))),
                    ("n_light", Json::from(u64::from(q.n_light))),
                ])
            })
            .collect();
        Json::obj([
            ("arrivals", Json::from(self.arrivals)),
            ("chunks", Json::from(self.chunks)),
            ("pad_tokens", Json::from(self.pad_tokens)),
            ("transfers", Json::from(self.transfers)),
            ("decode_iters", Json::from(self.decode_iters)),
            ("flips", Json::from(self.flips)),
            ("scale_ups", Json::from(self.scale_ups)),
            ("scale_downs", Json::from(self.scale_downs)),
            ("sheds", Json::from(self.sheds)),
            ("violations", Json::from(self.violations)),
            ("faults", Json::from(self.faults)),
            ("recoveries", Json::from(self.recoveries)),
            ("spans", Json::from(spans)),
            ("queue", Json::from(queue)),
        ])
    }
}

impl Observer for TimelineObserver {
    fn on_arrival(&mut self, _now: Us, _req: &Request) {
        self.arrivals += 1;
    }

    fn on_chunk(&mut self, now: Us, instance: usize, tokens: u32, pad: u32, dur: Us) {
        self.chunks += 1;
        self.pad_tokens += pad as u64;
        self.spans.push(Span {
            at: now,
            dur,
            instance,
            kind: SpanKind::PrefillChunk,
            size: tokens as u64,
        });
    }

    fn on_transfer(&mut self, now: Us, instance: usize, _req: ReqId, tokens: u32, dur: Us) {
        self.transfers += 1;
        self.spans.push(Span {
            at: now,
            dur,
            instance,
            kind: SpanKind::Transfer,
            size: tokens as u64,
        });
    }

    fn on_decode_iter(&mut self, now: Us, instance: usize, batch: u32, _kv_tokens: u64, dur: Us) {
        self.decode_iters += 1;
        self.spans.push(Span {
            at: now,
            dur,
            instance,
            kind: SpanKind::DecodeIter,
            size: batch as u64,
        });
    }

    fn on_flip(&mut self, now: Us, instance: usize, _to: Role, dur: Us) {
        self.flips += 1;
        self.spans.push(Span { at: now, dur, instance, kind: SpanKind::Flip, size: 0 });
    }

    fn on_scale(&mut self, _now: Us, _instance: usize, _role: Role, added: bool) {
        if added {
            self.scale_ups += 1;
        } else {
            self.scale_downs += 1;
        }
    }

    fn on_finish(&mut self, now: Us, rec: &RequestRecord) {
        self.finished.push((now, rec.id));
    }

    fn on_shed(&mut self, _now: Us, _req: &Request) {
        self.sheds += 1;
    }

    fn on_violation(&mut self, _now: Us, _rec: &RequestRecord, _ttft: bool, _tpot: bool) {
        self.violations += 1;
    }

    fn on_fault(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {
        self.faults += 1;
    }

    fn on_recovery(&mut self, _now: Us, _kind: &'static str, _instance: Option<usize>) {
        self.recoveries += 1;
    }

    fn on_monitor(&mut self, now: Us, loads: &[DecodeLoad]) {
        for l in loads {
            self.queue.push(QueueSample {
                at: now,
                instance: l.instance,
                queue_len: l.queue_len,
                n_heavy: l.n_heavy,
                n_light: l.n_light,
            });
        }
    }
}

/// Prints coarse progress to stderr as requests finish — for long
/// interactive runs (`tetri sim --progress`).
#[derive(Debug)]
pub struct ProgressObserver {
    total: usize,
    done: usize,
    every: usize,
}

impl ProgressObserver {
    /// Report every `every` completions (and at the end). `every` is
    /// clamped to at least 1.
    pub fn new(total: usize, every: usize) -> Self {
        ProgressObserver { total, done: 0, every: every.max(1) }
    }

    pub fn done(&self) -> usize {
        self.done
    }
}

impl Observer for ProgressObserver {
    fn on_finish(&mut self, now: Us, _rec: &RequestRecord) {
        self.done += 1;
        if self.done % self.every == 0 || self.done == self.total {
            eprintln!(
                "[progress] {}/{} requests done at t={:.2}s (sim)",
                self.done,
                self.total,
                now as f64 / 1e6
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn rec(id: ReqId) -> RequestRecord {
        RequestRecord {
            id,
            task: TaskType::Chat,
            class: 0,
            prompt_len: 10,
            decode_len: 5,
            arrival: 0,
            first_token: 10,
            finished: 20,
            predicted: None,
            retries: 0,
            recovered: false,
        }
    }

    #[test]
    fn timeline_accumulates_spans_and_counters() {
        let mut t = TimelineObserver::new();
        t.on_chunk(0, 0, 512, 12, 100);
        t.on_chunk(100, 0, 256, 0, 50);
        t.on_decode_iter(200, 1, 8, 800, 30);
        t.on_transfer(150, 1, 7, 512, 40);
        t.on_flip(400, 0, Role::Decode, 6_000);
        t.on_finish(500, &rec(7));
        let shed_req = Request {
            id: 8,
            task: TaskType::Chat,
            class: 2,
            arrival: 510,
            prompt_len: 4,
            decode_len: 4,
            predicted: None,
            prefix: None,
        };
        t.on_shed(510, &shed_req);
        t.on_violation(520, &rec(9), true, false);
        t.on_fault(530, "crash", Some(0));
        t.on_recovery(540, "restart", Some(0));
        t.on_recovery(550, "requeue", None);
        assert_eq!((t.sheds, t.violations), (1, 1));
        assert_eq!((t.faults, t.recoveries), (1, 2));
        assert_eq!(t.chunks, 2);
        assert_eq!(t.pad_tokens, 12);
        assert_eq!(t.busy_us(0), 150, "flip spans are not busy compute");
        assert_eq!(t.busy_us(1), 30, "transfer spans occupy the wire, not the instance");
        assert_eq!(t.busy_series(0), vec![(0, 100), (100, 150)]);
        assert_eq!(t.finished, vec![(500, 7)]);
        // json dump parses back
        let s = t.to_json().dump();
        assert!(crate::util::Json::parse(&s).is_ok());
    }

    #[test]
    fn progress_counts_finishes() {
        let mut p = ProgressObserver::new(3, 100);
        p.on_finish(1, &rec(0));
        p.on_finish(2, &rec(1));
        assert_eq!(p.done(), 2);
    }

    #[test]
    fn null_observer_is_free() {
        let mut n = NullObserver;
        n.on_chunk(0, 0, 1, 0, 1);
        n.on_finish(0, &rec(0));
    }
}
