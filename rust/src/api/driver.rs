//! Pluggable run drivers and the string-keyed driver registry.
//!
//! A `Driver` turns a request trace into a [`Report`], streaming events to
//! an [`Observer`](super::Observer) along the way. Two builtin drivers
//! exist — the disaggregated TetriInfer cluster (`"tetri"`) and the
//! coupled vanilla-vLLM baseline (`"vllm"`) — and future systems plug in
//! by adding a registry entry. The legacy free functions
//! `run_cluster`/`run_baseline` are thin wrappers over these drivers.

use std::time::Instant;

use crate::baseline::{BaselineCluster, BaselineConfig};
use crate::coordinator::{Cluster, ClusterConfig};
use crate::sim::{ArrivalSource, TraceSource};
use crate::types::Request;

use super::{Observer, Report, Scenario};

/// A simulated serving system that can run an arrival stream to
/// completion.
pub trait Driver {
    /// Registry key / display name of this driver.
    fn name(&self) -> &str;

    /// Run a pull-based arrival stream to completion, streaming events to
    /// `obs`. Deterministic given the driver's config and the source; the
    /// observer never influences the run. This is the O(active)-memory
    /// hot path — scale runs never materialize a trace.
    fn run_source(&self, source: &mut dyn ArrivalSource, obs: &mut dyn Observer) -> Report;

    /// Run a materialized trace (wraps it in a [`TraceSource`], whose
    /// stable sort reproduces the pre-scheduled heap's delivery order).
    fn run(&self, trace: &[Request], obs: &mut dyn Observer) -> Report {
        self.run_source(&mut TraceSource::from_slice(trace), obs)
    }
}

/// The disaggregated TetriInfer cluster (§3) — also, under the
/// `"hybrid"` registry key, the mixed fleet that runs coupled
/// vanilla-vLLM instances alongside disaggregated ones in a single
/// simulation (the paper's comparison inside one cluster).
pub struct ClusterDriver {
    pub cfg: ClusterConfig,
    /// Scenario echo for the report, when the driver came from a spec.
    pub scenario: Option<Scenario>,
    /// Registry key this driver was resolved under (`"tetri"`/`"hybrid"`).
    key: &'static str,
}

impl ClusterDriver {
    pub fn from_config(cfg: ClusterConfig) -> Self {
        ClusterDriver { cfg, scenario: None, key: "tetri" }
    }

    pub fn from_scenario(sc: &Scenario) -> Self {
        ClusterDriver { cfg: sc.cluster_config(), scenario: Some(sc.clone()), key: "tetri" }
    }

    /// The `"hybrid"` resolution: same engine and config, but at least
    /// one coupled instance serves inside the cluster (a hybrid spec that
    /// sets `n_coupled` keeps its value). The normalization lands on the
    /// echoed scenario too, so reports describe the run that actually
    /// happened.
    pub fn from_scenario_hybrid(sc: &Scenario) -> Self {
        let mut sc = sc.clone();
        if sc.n_coupled == 0 {
            sc.n_coupled = 1;
        }
        let cfg = sc.cluster_config();
        ClusterDriver { cfg, scenario: Some(sc), key: "hybrid" }
    }
}

impl Driver for ClusterDriver {
    fn name(&self) -> &str {
        self.key
    }

    fn run_source(&self, source: &mut dyn ArrivalSource, obs: &mut dyn Observer) -> Report {
        let t = Instant::now();
        let metrics = Cluster::new(self.cfg.clone()).run_streamed(source, obs);
        Report {
            driver: self.key.to_string(),
            scenario: self.scenario.clone(),
            metrics,
            wall_secs: t.elapsed().as_secs_f64(),
            telemetry: None,
        }
    }
}

/// The coupled vanilla-vLLM baseline (§5.2.1).
pub struct BaselineDriver {
    pub cfg: BaselineConfig,
    pub scenario: Option<Scenario>,
}

impl BaselineDriver {
    pub fn from_config(cfg: BaselineConfig) -> Self {
        BaselineDriver { cfg, scenario: None }
    }

    pub fn from_scenario(sc: &Scenario) -> Self {
        BaselineDriver { cfg: sc.baseline_config(), scenario: Some(sc.clone()) }
    }
}

impl Driver for BaselineDriver {
    fn name(&self) -> &str {
        "vllm"
    }

    fn run_source(&self, source: &mut dyn ArrivalSource, obs: &mut dyn Observer) -> Report {
        let t = Instant::now();
        let metrics = BaselineCluster::new(self.cfg.clone()).run_streamed(source, obs);
        Report {
            driver: "vllm".to_string(),
            scenario: self.scenario.clone(),
            metrics,
            wall_secs: t.elapsed().as_secs_f64(),
            telemetry: None,
        }
    }
}

type DriverFactory = fn(&Scenario) -> Box<dyn Driver>;

/// String-keyed driver registry: the single resolver behind CLI flags,
/// JSON specs, and sweep grids. Unknown keys are errors that list the
/// known drivers — never silent fallbacks.
pub struct Registry {
    entries: Vec<(&'static str, DriverFactory)>,
}

impl Registry {
    /// The builtin systems: `"tetri"`, `"vllm"`, and `"hybrid"` (coupled
    /// + disaggregated instances in one cluster).
    pub fn builtin() -> Self {
        Registry {
            entries: vec![
                ("tetri", |sc| Box::new(ClusterDriver::from_scenario(sc))),
                ("vllm", |sc| Box::new(BaselineDriver::from_scenario(sc))),
                ("hybrid", |sc| Box::new(ClusterDriver::from_scenario_hybrid(sc))),
            ],
        }
    }

    /// Register an additional driver under `key` (later entries shadow
    /// earlier ones with the same key).
    pub fn register(&mut self, key: &'static str, factory: DriverFactory) {
        self.entries.retain(|(k, _)| *k != key);
        self.entries.push((key, factory));
    }

    pub fn driver_names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }

    /// Build the driver a scenario names, or an error listing valid keys.
    pub fn resolve(&self, sc: &Scenario) -> Result<Box<dyn Driver>, String> {
        self.entries
            .iter()
            .find(|(k, _)| *k == sc.driver)
            .map(|(_, f)| f(sc))
            .ok_or_else(|| {
                format!(
                    "unknown driver '{}' (known: {})",
                    sc.driver,
                    self.driver_names().join(", ")
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::NullObserver;
    use crate::workload::WorkloadKind;

    fn tiny(driver: &str) -> Scenario {
        Scenario::builder()
            .driver(driver)
            .workload(WorkloadKind::Lpld)
            .requests(8)
            .seed(3)
            // hybrid normalizes n_coupled 0 → 1 into its scenario echo;
            // setting it explicitly keeps the echo-equality assertion exact
            .coupled(if driver == "hybrid" { 1 } else { 0 })
            .build()
    }

    #[test]
    fn registry_resolves_builtin_drivers() {
        let reg = Registry::builtin();
        assert_eq!(reg.driver_names(), vec!["tetri", "vllm", "hybrid"]);
        for name in ["tetri", "vllm", "hybrid"] {
            let sc = tiny(name);
            let drv = reg.resolve(&sc).unwrap();
            assert_eq!(drv.name(), name);
            let report = drv.run(&sc.trace(), &mut NullObserver);
            assert_eq!(report.metrics.records.len(), 8, "{name}");
            assert_eq!(report.scenario.as_ref().unwrap(), &sc);
            assert_eq!(report.driver, name);
        }
    }

    #[test]
    fn hybrid_defaults_to_one_coupled_instance() {
        let bare = Scenario { n_coupled: 0, ..tiny("hybrid") };
        let drv = ClusterDriver::from_scenario_hybrid(&bare);
        assert_eq!(drv.cfg.n_coupled, 1, "a bare hybrid spec gets one coupled instance");
        assert_eq!(
            drv.scenario.as_ref().unwrap().n_coupled,
            1,
            "the scenario echo must describe the run that actually happens"
        );
        let sc = Scenario { n_coupled: 3, ..tiny("hybrid") };
        let drv = ClusterDriver::from_scenario_hybrid(&sc);
        assert_eq!(drv.cfg.n_coupled, 3, "explicit n_coupled wins");
    }

    #[test]
    fn unknown_driver_is_an_error_listing_known() {
        let err = Registry::builtin().resolve(&tiny("sglang")).unwrap_err();
        assert!(err.contains("sglang") && err.contains("tetri") && err.contains("vllm"), "{err}");
    }

    #[test]
    fn register_shadows_existing_key() {
        let mut reg = Registry::builtin();
        reg.register("tetri", |sc| Box::new(BaselineDriver::from_scenario(sc)));
        let drv = reg.resolve(&tiny("tetri")).unwrap();
        assert_eq!(drv.name(), "vllm", "shadowed entry must win");
        assert_eq!(reg.driver_names().len(), 3);
    }

    #[test]
    fn driver_runs_match_legacy_free_functions() {
        let sc = tiny("tetri");
        let trace = sc.trace();
        let via_driver = ClusterDriver::from_scenario(&sc).run(&trace, &mut NullObserver);
        let via_fn = crate::coordinator::run_cluster(sc.cluster_config(), trace.clone());
        assert_eq!(via_driver.metrics.makespan_us, via_fn.makespan_us);
        assert_eq!(via_driver.metrics.events, via_fn.events);

        let sc = tiny("vllm");
        let trace = sc.trace();
        let via_driver = BaselineDriver::from_scenario(&sc).run(&trace, &mut NullObserver);
        let via_fn = crate::baseline::run_baseline(sc.baseline_config(), trace);
        assert_eq!(via_driver.metrics.makespan_us, via_fn.makespan_us);
        assert_eq!(via_driver.metrics.events, via_fn.events);
    }
}
