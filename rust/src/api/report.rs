//! The unified run report: metrics + scenario echo + comparison helpers,
//! with the single JSON serializer used by `main.rs`,
//! `examples/figures.rs`, the sweep harness, and both benches.

use crate::metrics::{goodput_per_dollar, perf_per_dollar, RunMetrics, RunSummaries};
use crate::util::{Json, Summary};

use super::Scenario;

/// One finished run. Carries the scenario that produced it (when known),
/// so a report alone is enough to reproduce the run.
#[derive(Clone, Debug)]
pub struct Report {
    /// Driver registry key that produced this run.
    pub driver: String,
    /// Scenario echo; `None` when the driver was built from a raw config
    /// (the legacy `run_cluster`/`run_baseline` path).
    pub scenario: Option<Scenario>,
    pub metrics: RunMetrics,
    /// Host wall time of the DES run (not virtual time).
    pub wall_secs: f64,
    /// Per-phase latency breakdown + virtual-time series, present only
    /// when the scenario armed telemetry (`Scenario::telemetry`). Attached
    /// by `Scenario::run_with` after the run, so drivers stay
    /// telemetry-agnostic.
    pub telemetry: Option<crate::telemetry::TelemetrySummary>,
}

fn summary_json(s: &Summary) -> Json {
    Json::obj([
        ("mean", Json::from(s.mean)),
        ("p50", Json::from(s.p50)),
        ("p90", Json::from(s.p90)),
        ("p99", Json::from(s.p99)),
        ("min", Json::from(s.min)),
        ("max", Json::from(s.max)),
    ])
}

/// The one serializer for run metrics (milliseconds for latencies,
/// seconds for resource/makespan). Every JSON artifact in the repo that
/// embeds run results goes through this. Summaries are computed once per
/// report and threaded into every consumer (`metrics_json_with`).
pub fn metrics_json(m: &RunMetrics) -> Json {
    metrics_json_with(m, &m.summaries())
}

fn metrics_json_with(m: &RunMetrics, s: &RunSummaries) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("requests", Json::from(m.n_finished())),
        ("ttft_ms", summary_json(&s.ttft)),
        ("jct_ms", summary_json(&s.jct)),
        ("resource_s", Json::from(s.resource_s)),
        ("makespan_s", Json::from(m.makespan_us as f64 / 1e6)),
        ("events", Json::from(m.events)),
        ("macro_steps", Json::from(m.macro_steps)),
        ("peak_arena", Json::from(m.peak_arena)),
        ("decode_tok_per_s", Json::from(m.decode_throughput())),
        ("utilization", Json::from(m.utilization())),
        ("swapped_tokens", Json::from(m.swapped_tokens)),
        ("flips", Json::from(u64::from(m.flips))),
        ("scale_ups", Json::from(u64::from(m.scale_ups))),
        ("scale_downs", Json::from(u64::from(m.scale_downs))),
        ("shed", Json::from(m.shed)),
        ("attained", Json::from(m.attained)),
        ("goodput_rps", Json::from(s.goodput_rps)),
        ("failed", Json::from(m.failed)),
        ("recovered", Json::from(m.recovered)),
        ("faults_injected", Json::from(m.faults_injected)),
        ("transfer_resends", Json::from(m.transfer_resends)),
        ("degraded_ms", Json::from(m.degraded_us as f64 / 1e3)),
    ];
    // early-stop marker, only for runs a StopPolicy cut short (normal
    // run-to-completion reports stay byte-identical)
    if m.aborted {
        pairs.push(("aborted", Json::from(true)));
    }
    // recovery-latency summary, only for runs that actually lost requests
    // to faults (fault-free reports stay as compact as before)
    if m.recovered > 0 {
        pairs.push(("recovery_ms", summary_json(&m.recovery_hist.summary_scaled(1e-3))));
    }
    // prefix-cache section, only for runs that consulted a cache or
    // overlapped transfers (cache-off reports stay byte-identical)
    if m.cache_hits + m.cache_misses > 0 {
        pairs.push(("cache_hit_rate", Json::from(m.cache_hit_rate())));
        pairs.push(("prefill_tokens_saved", Json::from(m.prefill_tokens_saved)));
        pairs.push(("cache_evictions", Json::from(m.cache_evictions)));
    }
    if m.overlap_us > 0 {
        pairs.push(("overlap_ms", Json::from(m.overlap_us as f64 / 1e3)));
    }
    // per-class SLO section, only for runs that declared a class table
    // (classless reports stay exactly as compact as before, plus the
    // three scalar fields above)
    if !m.classes.is_empty() {
        let classes: Vec<Json> = m
            .per_class
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let tier = m.classes.get(i).map(|d| u64::from(d.tier)).unwrap_or(0);
                Json::obj([
                    ("name", Json::from(m.class_name(i as u8))),
                    ("tier", Json::from(tier)),
                    ("finished", Json::from(c.finished)),
                    ("shed", Json::from(c.shed)),
                    ("ttft_attainment", Json::from(c.ttft_attainment())),
                    ("tpot_attainment", Json::from(c.tpot_attainment())),
                    ("slo_attainment", Json::from(c.attainment())),
                    ("ttft_ms", summary_json(&c.ttft_hist.summary_scaled(1e-3))),
                    ("jct_ms", summary_json(&c.jct_hist.summary_scaled(1e-3))),
                    ("tpot_ms", summary_json(&c.tpot_hist.summary_scaled(1e-3))),
                ])
            })
            .collect();
        pairs.push(("classes", Json::from(classes)));
    }
    Json::obj(pairs)
}

impl Report {
    /// Full machine-readable report: scenario echo + metrics + wall time.
    pub fn to_json(&self) -> Json {
        self.to_json_with(&self.metrics.summaries())
    }

    /// `to_json` with the summaries precomputed by the caller (one
    /// collect+sort per report, however many consumers).
    pub fn to_json_with(&self, s: &RunSummaries) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("driver", Json::from(self.driver.clone())),
            (
                "scenario",
                self.scenario.as_ref().map(|s| s.to_json()).unwrap_or(Json::Null),
            ),
            ("metrics", metrics_json_with(&self.metrics, s)),
            ("wall_secs", Json::from(self.wall_secs)),
        ];
        // telemetry block, only for armed runs (off-path reports stay
        // byte-identical to pre-telemetry builds)
        if let Some(t) = &self.telemetry {
            pairs.push(("telemetry", t.to_json()));
        }
        Json::obj(pairs)
    }

    /// One human-readable line of the headline metrics.
    pub fn summary_line(&self) -> String {
        self.summary_line_with(&self.metrics.summaries())
    }

    /// `summary_line` with the summaries precomputed by the caller.
    pub fn summary_line_with(&self, s: &RunSummaries) -> String {
        format!(
            "{:<10} TTFT mean {:>8.1} ms p99 {:>8.1} | JCT mean {:>9.1} ms p99 {:>9.1} | resource {:>6.1}s | flips {}",
            self.driver,
            s.ttft.mean,
            s.ttft.p99,
            s.jct.mean,
            s.jct.p99,
            s.resource_s,
            self.metrics.flips
        )
    }

    /// Formatted comparison row against a baseline report (delegates to
    /// the paper's headline deltas).
    pub fn vs_row(&self, name: &str, base: &Report) -> String {
        self.metrics.vs_row(name, &base.metrics)
    }

    /// perf/$ of this run relative to `base` (>1 = better).
    pub fn perf_per_dollar_vs(&self, base: &Report) -> f64 {
        self.metrics.perf_per_dollar_vs(&base.metrics)
    }

    /// Machine-readable side-by-side of this run and a baseline, with the
    /// paper's relative deltas precomputed. Each side's summaries are
    /// computed once and shared by the embedded reports and the deltas.
    pub fn comparison_json(&self, base: &Report) -> Json {
        self.comparison_json_with(&self.metrics.summaries(), base, &base.metrics.summaries())
    }

    /// `comparison_json` with both sides' summaries precomputed by the
    /// caller (the CLI threads the ones it already printed rows from).
    pub fn comparison_json_with(&self, own: &RunSummaries, base: &Report, other: &RunSummaries) -> Json {
        let rel = |own: f64, other: f64| -> Json {
            if other == 0.0 {
                Json::Null
            } else {
                Json::from(own / other - 1.0)
            }
        };
        Json::obj([
            ("report", self.to_json_with(own)),
            ("baseline", base.to_json_with(other)),
            (
                "deltas",
                Json::obj([
                    ("ttft_rel", rel(own.ttft.mean, other.ttft.mean)),
                    ("jct_rel", rel(own.jct.mean, other.jct.mean)),
                    ("resource_rel", rel(own.resource_s, other.resource_s)),
                    ("perf_per_dollar", Json::from(perf_per_dollar(own, other))),
                    ("goodput_per_dollar", Json::from(goodput_per_dollar(own, other))),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{RequestRecord, TaskType};

    fn mk(jct_ms: f64, resource_s: f64) -> Report {
        Report {
            driver: "tetri".to_string(),
            scenario: Some(Scenario::default()),
            metrics: RunMetrics {
                records: vec![RequestRecord {
                    id: 0,
                    task: TaskType::Chat,
                    class: 0,
                    prompt_len: 10,
                    decode_len: 100,
                    arrival: 0,
                    first_token: 1_000,
                    finished: (jct_ms * 1e3) as u64,
                    predicted: None,
                    retries: 0,
                    recovered: false,
                }],
                busy_us: vec![(resource_s * 1e6) as u64],
                alive_us: vec![(resource_s * 2e6) as u64],
                makespan_us: 1_000_000,
                ..Default::default()
            },
            wall_secs: 0.01,
            telemetry: None,
        }
    }

    #[test]
    fn report_json_round_trips_and_echoes_scenario() {
        let r = mk(100.0, 1.0);
        let j = Json::parse(&r.to_json().dump()).unwrap();
        assert_eq!(j.at(&["driver"]).unwrap().as_str(), Some("tetri"));
        assert_eq!(j.at(&["metrics", "requests"]).unwrap().as_usize(), Some(1));
        // scenario echo parses back to the original spec
        let sc = Scenario::from_json(j.get("scenario").unwrap()).unwrap();
        assert_eq!(sc, Scenario::default());
    }

    #[test]
    fn comparison_json_carries_deltas() {
        let fast = mk(100.0, 1.0);
        let slow = mk(200.0, 2.0);
        let j = fast.comparison_json(&slow);
        let p = j.at(&["deltas", "perf_per_dollar"]).unwrap().as_f64().unwrap();
        assert!((p - 4.0).abs() < 1e-9, "{p}");
        let jd = j.at(&["deltas", "jct_rel"]).unwrap().as_f64().unwrap();
        assert!((jd - (-0.5)).abs() < 1e-9, "{jd}");
    }

    #[test]
    fn summary_and_vs_rows_render() {
        let a = mk(100.0, 1.0);
        let b = mk(200.0, 2.0);
        assert!(a.summary_line().contains("TTFT"));
        assert!(a.vs_row("a vs b", &b).contains("perf/$"));
    }
}
