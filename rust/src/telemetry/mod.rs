//! Telemetry (observability subsystem): per-request span tracing,
//! virtual-time series sampling, latency attribution, and Perfetto /
//! Chrome `trace_event` export.
//!
//! The whole subsystem rides the [`Observer`] seam — it *watches* a run
//! and never influences it, so telemetry-on and telemetry-off runs are
//! bit-identical (parity-tested across all three drivers). When the
//! scenario's `telemetry` knob is absent nothing here is even
//! constructed: the drivers fire the same no-op default hooks they
//! always fired, which is the zero-cost-off argument (see DESIGN.md
//! §Telemetry).
//!
//! Three pillars:
//!
//!  * **Span traces** — every delivered request walks a phase machine
//!    (queue → predict → prefill → dispatch → transfer → decode, with
//!    retry/parked excursions on faults and dispatch stalls). Each
//!    transition closes the open phase; at `on_finish` the per-phase
//!    accruals fold into constant-memory [`LogHist`]s (run-level and
//!    per-class), so the report can print "p99 TTFT = 41% queue + 52%
//!    prefill + 7% transfer" without retaining per-request records.
//!    For every finished request the phases *partition* its
//!    arrival→finish interval exactly (slack 0): the accrued sum equals
//!    its JCT, so breakdown totals reconcile with the JCT histogram.
//!  * **Series sampler** — a periodic virtual-time collector
//!    (configurable `sample_ms`) piggybacking on hook timestamps:
//!    state is piecewise-constant between DES events, so sampling at
//!    the *top* of each hook (before the event mutates gauges) is
//!    exact. The ring is capped at `max_samples`; on overflow it keeps
//!    every other point and doubles the interval (deterministic
//!    downsampling, O(log) total work however long the run).
//!  * **Perfetto export** — phase spans (pid = instance lane, tid =
//!    original request id), instance busy slices (chunks, decode
//!    iterations, flips), fault/recovery instants, and counter tracks
//!    serialize to the Chrome `trace_event` JSON format with virtual-µs
//!    timestamps; the file loads directly in `ui.perfetto.dev`.

use std::collections::HashMap;

use crate::api::{Observer, Scenario, TelemetrySpec};
use crate::metrics::RunMetrics;
use crate::prefill::DecodeLoad;
use crate::types::{ReqId, Request, RequestRecord, Role, Us};
use crate::util::{Json, LogHist};

/// Phases of the per-request span machine, in pipeline order. Every
/// delivered request is in exactly one phase at any instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Entry-router / local-scheduler wait, from delivery to first
    /// chunk inclusion (or to coupled-iteration prefill inclusion).
    Queue,
    /// Sequential length prediction plus any re-queue wait behind it
    /// (the request cannot be scheduled until predicted, so the whole
    /// interval is causally attributed here). Parallel-mode prediction
    /// co-runs with prefill and never opens this phase.
    Predict,
    /// First chunk inclusion to last-segment completion (first token).
    Prefill,
    /// Prefill done, waiting for a decode target to be chosen.
    Dispatch,
    /// KV transfer issued until the request joins a decode batch.
    Transfer,
    /// Resident on a decode (or coupled) instance until the final token.
    Decode,
    /// Lost to a fault and re-queued with backoff (covers the backoff
    /// wait plus the re-queue wait until re-inclusion in a chunk).
    Retry,
    /// Parked in `pending_dispatch`: no decode instance could accept
    /// the request (degraded cluster or all targets down).
    Parked,
}

/// Number of phases — the span machine's histogram arity.
pub const N_PHASES: usize = 8;

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [
        Phase::Queue,
        Phase::Predict,
        Phase::Prefill,
        Phase::Dispatch,
        Phase::Transfer,
        Phase::Decode,
        Phase::Retry,
        Phase::Parked,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Queue => "queue",
            Phase::Predict => "predict",
            Phase::Prefill => "prefill",
            Phase::Dispatch => "dispatch",
            Phase::Transfer => "transfer",
            Phase::Decode => "decode",
            Phase::Retry => "retry",
            Phase::Parked => "parked",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// One Chrome `trace_event` entry: a complete span (`ph == 'X'`) or a
/// global instant (`ph == 'i'`). Counters and metadata are synthesized
/// at export time from the sample ring.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    pub ph: char,
    /// Virtual µs (the trace_event spec's native unit).
    pub ts: Us,
    pub dur: Us,
    /// 0 = the scheduler lane; `instance + 1` otherwise.
    pub pid: u64,
    /// Original request id for request lanes; 0 for instance slices.
    pub tid: u64,
    pub arg: Option<(&'static str, u64)>,
}

impl TraceEvent {
    fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("name", Json::from(self.name)),
            ("ph", Json::from(if self.ph == 'X' { "X" } else { "i" })),
            ("ts", Json::from(self.ts)),
            ("pid", Json::from(self.pid)),
            ("tid", Json::from(self.tid)),
        ];
        if self.ph == 'X' {
            pairs.push(("dur", Json::from(self.dur)));
        } else {
            pairs.push(("s", Json::from("g")));
        }
        if let Some((k, v)) = self.arg {
            pairs.push(("args", Json::obj([(k, Json::from(v))])));
        }
        Json::obj(pairs)
    }
}

/// One virtual-time sample of the run's gauges. Cumulative counters
/// (finished/shed/failed/cache) are as-of `t`; phase populations and
/// in-flight are instantaneous.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SeriesPoint {
    pub t: Us,
    pub in_flight: u64,
    /// Requests currently in each phase, indexed like [`Phase::ALL`].
    pub phases: [u64; N_PHASES],
    pub finished: u64,
    pub shed: u64,
    pub failed: u64,
    /// Queued requests across decode instances (last monitor broadcast).
    pub decode_queue: u64,
    /// Resident KV tokens across decode batches (last iteration issue).
    pub kv_tokens: u64,
    /// Live instances per role: [prefill, decode, coupled].
    pub roles: [u64; 3],
    pub cache_hits: u64,
    pub cache_lookups: u64,
}

/// Header of the `*.series.csv` emitted from a [`TelemetrySummary`].
pub const SERIES_CSV_HEADER: &str = "t_ms,in_flight,queue,predict,prefill,dispatch,transfer,\
decode,retry,parked,finished,shed,failed,decode_queue,kv_tokens,n_prefill,n_decode,n_coupled,\
cache_hits,cache_lookups";

/// Digest of one phase's latency histogram (milliseconds). `sum_ms` and
/// `mean_ms` are exact; quantiles carry LogHist's ≤ ~3.2% bucket error.
#[derive(Clone, Debug)]
pub struct PhaseStat {
    pub phase: &'static str,
    pub count: u64,
    pub sum_ms: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Per-class latency breakdown (SLO multi-tenancy runs).
#[derive(Clone, Debug)]
pub struct ClassBreakdown {
    pub class: u8,
    pub name: String,
    pub phases: Vec<PhaseStat>,
}

/// Everything telemetry distilled from one run: the per-phase latency
/// attribution, the sampled series, and (when armed) the Perfetto trace.
/// Attached to [`crate::api::Report`] as `Some` only when the scenario's
/// `telemetry` knob was set, so telemetry-off reports stay byte-identical.
#[derive(Clone, Debug)]
pub struct TelemetrySummary {
    /// Final sampling interval (µs) — doubled on each ring overflow.
    pub sample_interval_us: Us,
    /// Run-level per-phase stats; phases nobody visited are omitted.
    pub breakdown: Vec<PhaseStat>,
    pub classes: Vec<ClassBreakdown>,
    pub series: Vec<SeriesPoint>,
    /// Phase spans closed over the run (finished + in-flight requests).
    pub spans: u64,
    /// Σ per-request phase time over finished requests (µs). Equals the
    /// exact JCT-histogram sum — the reconciliation invariant.
    pub accounted_us: u128,
    /// Chrome trace-event JSON, present when the spec armed `trace`.
    pub trace: Option<Json>,
}

fn stat_json(s: &PhaseStat) -> Json {
    Json::obj([
        ("phase", Json::from(s.phase)),
        ("count", Json::from(s.count)),
        ("sum_ms", Json::from(s.sum_ms)),
        ("mean_ms", Json::from(s.mean_ms)),
        ("p50_ms", Json::from(s.p50_ms)),
        ("p99_ms", Json::from(s.p99_ms)),
    ])
}

impl TelemetrySummary {
    /// Run-level stats for one phase by name, if anyone visited it.
    pub fn phase(&self, name: &str) -> Option<&PhaseStat> {
        self.breakdown.iter().find(|p| p.phase == name)
    }

    /// p99 of one phase in ms (0.0 when the phase never occurred) —
    /// what the sweep CSV's breakdown columns print.
    pub fn phase_p99_ms(&self, name: &str) -> f64 {
        self.phase(name).map(|p| p.p99_ms).unwrap_or(0.0)
    }

    pub fn accounted_ms(&self) -> f64 {
        self.accounted_us as f64 / 1e3
    }

    /// Compact JSON block for the report (`"telemetry"` key). The full
    /// series and the trace ship as separate files, not here.
    pub fn to_json(&self) -> Json {
        let breakdown: Vec<Json> = self.breakdown.iter().map(stat_json).collect();
        let mut pairs: Vec<(&str, Json)> = vec![
            ("sample_ms", Json::from(self.sample_interval_us as f64 / 1e3)),
            ("samples", Json::from(self.series.len())),
            ("spans", Json::from(self.spans)),
            ("accounted_ms", Json::from(self.accounted_ms())),
            ("breakdown", Json::from(breakdown)),
        ];
        if !self.classes.is_empty() {
            let classes: Vec<Json> = self
                .classes
                .iter()
                .map(|c| {
                    Json::obj([
                        ("class", Json::from(u64::from(c.class))),
                        ("name", Json::from(c.name.clone())),
                        ("breakdown", Json::from(c.phases.iter().map(stat_json).collect::<Vec<_>>())),
                    ])
                })
                .collect();
            pairs.push(("classes", Json::from(classes)));
        }
        Json::obj(pairs)
    }

    /// The sampled series as CSV (see [`SERIES_CSV_HEADER`]).
    pub fn series_csv(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(64 * (self.series.len() + 1));
        out.push_str(SERIES_CSV_HEADER);
        out.push('\n');
        for s in &self.series {
            let _ = write!(out, "{:.3},{}", s.t as f64 / 1e3, s.in_flight);
            for p in s.phases {
                let _ = write!(out, ",{p}");
            }
            let _ = writeln!(
                out,
                ",{},{},{},{},{},{},{},{},{},{}",
                s.finished,
                s.shed,
                s.failed,
                s.decode_queue,
                s.kv_tokens,
                s.roles[0],
                s.roles[1],
                s.roles[2],
                s.cache_hits,
                s.cache_lookups
            );
        }
        out
    }

    /// Human-readable breakdown rows ("where did my latency go?"),
    /// one per visited phase, with each phase's share of the total
    /// accounted request time.
    pub fn breakdown_lines(&self) -> Vec<String> {
        let total = self.accounted_ms().max(f64::MIN_POSITIVE);
        self.breakdown
            .iter()
            .map(|p| {
                format!(
                    "{:<9} n={:<8} mean {:>9.2} ms  p50 {:>9.2}  p99 {:>9.2}  | {:>5.1}% of request time",
                    p.phase,
                    p.count,
                    p.mean_ms,
                    p.p50_ms,
                    p.p99_ms,
                    100.0 * p.sum_ms / total
                )
            })
            .collect()
    }
}

/// Open-request state inside the span machine.
#[derive(Clone, Copy, Debug)]
struct Track {
    class: u8,
    phase: Phase,
    /// When the open phase started (the next span's `ts`).
    last: Us,
    /// Trace lane of the open phase (0 = scheduler, instance + 1 else).
    span_pid: u64,
    /// Accrued µs per phase, folded into the histograms at finish.
    acc: [Us; N_PHASES],
}

/// The telemetry observer: span machine + gauges + sampler + trace
/// buffer. Construct with [`Telemetry::from_spec`], attach via the
/// observer seam (the scenario runner tees it with the caller's
/// observer), then call [`Telemetry::into_summary`].
#[derive(Debug)]
pub struct Telemetry {
    interval: Us,
    max_samples: usize,
    trace_on: bool,
    next_sample: Us,
    tracks: HashMap<ReqId, Track>,
    hists: [LogHist; N_PHASES],
    per_class: Vec<(u8, Box<[LogHist; N_PHASES]>)>,
    phase_count: [u64; N_PHASES],
    arrived: u64,
    finished: u64,
    shed: u64,
    failed: u64,
    decode_queue: u64,
    kv_by_inst: Vec<u64>,
    roles: [i64; 3],
    cache_hits: u64,
    cache_lookups: u64,
    samples: Vec<SeriesPoint>,
    events: Vec<TraceEvent>,
    max_pid: u64,
    spans: u64,
    accounted_us: u128,
}

fn role_idx(r: Role) -> usize {
    match r {
        Role::Prefill => 0,
        Role::Decode => 1,
        Role::Coupled => 2,
    }
}

impl Telemetry {
    /// Raw constructor. `roles` seeds the live-instance gauges
    /// ([prefill, decode, coupled]); `interval_us` is clamped ≥ 1 and
    /// `max_samples` ≥ 2 so the sampler always terminates.
    pub fn new(interval_us: Us, max_samples: usize, trace_on: bool, roles: [i64; 3]) -> Self {
        let interval = interval_us.max(1);
        Telemetry {
            interval,
            max_samples: max_samples.max(2),
            trace_on,
            next_sample: interval,
            tracks: HashMap::new(),
            hists: std::array::from_fn(|_| LogHist::default()),
            per_class: Vec::new(),
            phase_count: [0; N_PHASES],
            arrived: 0,
            finished: 0,
            shed: 0,
            failed: 0,
            decode_queue: 0,
            kv_by_inst: Vec::new(),
            roles,
            cache_hits: 0,
            cache_lookups: 0,
            samples: Vec::new(),
            events: Vec::new(),
            max_pid: 0,
            spans: 0,
            accounted_us: 0,
        }
    }

    /// Build from the scenario's `telemetry` knob, seeding role gauges
    /// from the topology the driver will actually instantiate.
    pub fn from_spec(spec: &TelemetrySpec, sc: &Scenario) -> Self {
        let roles = if sc.driver == "vllm" {
            [0, 0, sc.baseline_config().n_instances as i64]
        } else {
            [sc.n_prefill as i64, sc.n_decode as i64, sc.n_coupled as i64]
        };
        Telemetry::new((spec.sample_ms * 1e3).max(1.0) as Us, spec.max_samples, spec.trace, roles)
    }

    /// Sample every interval boundary in `(last tick, now]`. Called at
    /// the top of every hook, *before* the event mutates any gauge —
    /// DES state is piecewise-constant between events, so each sample
    /// sees the exact state that held at its boundary.
    fn tick(&mut self, now: Us) {
        while self.next_sample <= now {
            if self.samples.len() >= self.max_samples {
                // deterministic downsample: keep every other point,
                // double the cadence
                let mut keep = false;
                self.samples.retain(|_| {
                    keep = !keep;
                    keep
                });
                self.interval = self.interval.saturating_mul(2);
            }
            let t = self.next_sample;
            self.samples.push(SeriesPoint {
                t,
                in_flight: self.arrived - self.finished - self.shed - self.failed,
                phases: self.phase_count,
                finished: self.finished,
                shed: self.shed,
                failed: self.failed,
                decode_queue: self.decode_queue,
                kv_tokens: self.kv_by_inst.iter().sum(),
                roles: self.roles.map(|r| r.max(0) as u64),
                cache_hits: self.cache_hits,
                cache_lookups: self.cache_lookups,
            });
            self.next_sample += self.interval;
        }
    }

    fn push_event(&mut self, ev: TraceEvent) {
        self.max_pid = self.max_pid.max(ev.pid);
        self.events.push(ev);
    }

    /// Close the open phase of `id` at `now` and open `next` on lane
    /// `pid`. Unknown ids (never delivered, or already closed) no-op.
    fn transition(&mut self, id: ReqId, now: Us, next: Phase, pid: u64) {
        let Some(tr) = self.tracks.get_mut(&id) else { return };
        let dur = now.saturating_sub(tr.last);
        let (closed, ts, span_pid) = (tr.phase, tr.last, tr.span_pid);
        tr.acc[closed.idx()] += dur;
        tr.last = now;
        tr.phase = next;
        tr.span_pid = pid;
        self.phase_count[closed.idx()] = self.phase_count[closed.idx()].saturating_sub(1);
        self.phase_count[next.idx()] += 1;
        if dur > 0 {
            self.spans += 1;
            if self.trace_on {
                self.push_event(TraceEvent {
                    name: closed.name(),
                    ph: 'X',
                    ts,
                    dur,
                    pid: span_pid,
                    tid: id,
                    arg: None,
                });
            }
        }
    }

    /// Remove `id` without folding into the breakdown (shed / failed —
    /// the breakdown covers finished requests only, so phase sums stay
    /// reconcilable with the JCT histogram). The closing span still
    /// reaches the trace so sheds are visible in Perfetto.
    fn drop_track(&mut self, id: ReqId, now: Us) {
        let Some(tr) = self.tracks.remove(&id) else { return };
        let dur = now.saturating_sub(tr.last);
        self.phase_count[tr.phase.idx()] = self.phase_count[tr.phase.idx()].saturating_sub(1);
        if dur > 0 {
            self.spans += 1;
            if self.trace_on {
                self.push_event(TraceEvent {
                    name: tr.phase.name(),
                    ph: 'X',
                    ts: tr.last,
                    dur,
                    pid: tr.span_pid,
                    tid: id,
                    arg: None,
                });
            }
        }
    }

    fn class_hists(&mut self, class: u8) -> &mut [LogHist; N_PHASES] {
        let pos = match self.per_class.iter().position(|(c, _)| *c == class) {
            Some(p) => p,
            None => {
                self.per_class.push((class, Box::new(std::array::from_fn(|_| LogHist::default()))));
                self.per_class.len() - 1
            }
        };
        &mut self.per_class[pos].1
    }

    /// The Chrome trace-event JSON: metadata lanes, every recorded
    /// span/instant, and counter tracks synthesized from the samples.
    fn trace_json(&self) -> Json {
        let mut evs: Vec<Json> = Vec::with_capacity(self.events.len() + 3 * self.samples.len() + 2);
        let meta = |pid: u64, name: String| {
            Json::obj([
                ("name", Json::from("process_name")),
                ("ph", Json::from("M")),
                ("pid", Json::from(pid)),
                ("args", Json::obj([("name", Json::from(name))])),
            ])
        };
        evs.push(meta(0, "scheduler".to_string()));
        for pid in 1..=self.max_pid {
            evs.push(meta(pid, format!("instance {}", pid - 1)));
        }
        for e in &self.events {
            evs.push(e.to_json());
        }
        for s in &self.samples {
            for (name, v) in [
                ("in_flight", s.in_flight),
                ("decode_queue", s.decode_queue),
                ("kv_tokens", s.kv_tokens),
            ] {
                evs.push(Json::obj([
                    ("name", Json::from(name)),
                    ("ph", Json::from("C")),
                    ("ts", Json::from(s.t)),
                    ("pid", Json::from(0u64)),
                    ("args", Json::obj([("value", Json::from(v))])),
                ]));
            }
        }
        Json::obj([
            ("displayTimeUnit", Json::from("ms")),
            ("traceEvents", Json::from(evs)),
        ])
    }

    /// Distill the run. `m` resolves class names for the per-class
    /// breakdown; in-flight tracks (aborted runs) are discarded.
    pub fn into_summary(mut self, m: &RunMetrics) -> TelemetrySummary {
        let trace = if self.trace_on { Some(self.trace_json()) } else { None };
        let stats_of = |hists: &[LogHist; N_PHASES]| -> Vec<PhaseStat> {
            Phase::ALL
                .iter()
                .filter_map(|p| {
                    let h = &hists[p.idx()];
                    if h.count() == 0 {
                        return None;
                    }
                    let s = h.summary_scaled(1e-3);
                    Some(PhaseStat {
                        phase: p.name(),
                        count: h.count(),
                        sum_ms: s.sum,
                        mean_ms: s.mean,
                        p50_ms: s.p50,
                        p99_ms: s.p99,
                    })
                })
                .collect()
        };
        let breakdown = stats_of(&self.hists);
        self.per_class.sort_by_key(|(c, _)| *c);
        let classes = self
            .per_class
            .iter()
            .map(|(c, hists)| ClassBreakdown {
                class: *c,
                name: m.class_name(*c).to_string(),
                phases: stats_of(hists),
            })
            .collect();
        TelemetrySummary {
            sample_interval_us: self.interval,
            breakdown,
            classes,
            series: self.samples,
            spans: self.spans,
            accounted_us: self.accounted_us,
            trace,
        }
    }
}

impl Observer for Telemetry {
    fn on_arrival(&mut self, now: Us, req: &Request) {
        self.tick(now);
        self.arrived += 1;
        self.phase_count[Phase::Queue.idx()] += 1;
        self.tracks.insert(
            req.id,
            Track { class: req.class, phase: Phase::Queue, last: now, span_pid: 0, acc: [0; N_PHASES] },
        );
    }

    fn on_predict(&mut self, now: Us, req: ReqId, _dur: Us) {
        self.tick(now);
        self.transition(req, now, Phase::Predict, 0);
    }

    fn on_prefill_start(&mut self, now: Us, instance: usize, req: ReqId) {
        self.tick(now);
        self.transition(req, now, Phase::Prefill, instance as u64 + 1);
    }

    fn on_prefill_finish(&mut self, now: Us, _instance: usize, req: ReqId) {
        self.tick(now);
        self.transition(req, now, Phase::Dispatch, 0);
    }

    fn on_transfer(&mut self, now: Us, instance: usize, req: ReqId, _tokens: u32, _dur: Us) {
        self.tick(now);
        self.transition(req, now, Phase::Transfer, instance as u64 + 1);
    }

    fn on_decode_enter(&mut self, now: Us, instance: usize, req: ReqId) {
        self.tick(now);
        self.transition(req, now, Phase::Decode, instance as u64 + 1);
    }

    fn on_parked(&mut self, now: Us, req: ReqId) {
        self.tick(now);
        self.transition(req, now, Phase::Parked, 0);
    }

    fn on_backoff(&mut self, now: Us, req: ReqId, _until: Us) {
        self.tick(now);
        self.transition(req, now, Phase::Retry, 0);
    }

    fn on_finish(&mut self, now: Us, rec: &RequestRecord) {
        self.tick(now);
        let Some(mut tr) = self.tracks.remove(&rec.id) else { return };
        let dur = now.saturating_sub(tr.last);
        tr.acc[tr.phase.idx()] += dur;
        self.phase_count[tr.phase.idx()] = self.phase_count[tr.phase.idx()].saturating_sub(1);
        if dur > 0 {
            self.spans += 1;
            if self.trace_on {
                self.push_event(TraceEvent {
                    name: tr.phase.name(),
                    ph: 'X',
                    ts: tr.last,
                    dur,
                    pid: tr.span_pid,
                    tid: rec.id,
                    arg: None,
                });
            }
        }
        self.finished += 1;
        let total: Us = tr.acc.iter().sum();
        self.accounted_us += total as u128;
        for p in 0..N_PHASES {
            if tr.acc[p] > 0 {
                self.hists[p].record(tr.acc[p]);
            }
        }
        let hists = self.class_hists(tr.class);
        for p in 0..N_PHASES {
            if tr.acc[p] > 0 {
                hists[p].record(tr.acc[p]);
            }
        }
    }

    fn on_shed(&mut self, now: Us, req: &Request) {
        self.tick(now);
        self.shed += 1;
        self.drop_track(req.id, now);
    }

    fn on_request_failed(&mut self, now: Us, req: &Request) {
        self.tick(now);
        self.failed += 1;
        self.drop_track(req.id, now);
    }

    fn on_chunk(&mut self, now: Us, instance: usize, tokens: u32, _pad: u32, dur: Us) {
        self.tick(now);
        if self.trace_on {
            self.push_event(TraceEvent {
                name: "chunk",
                ph: 'X',
                ts: now,
                dur,
                pid: instance as u64 + 1,
                tid: 0,
                arg: Some(("tokens", tokens as u64)),
            });
        }
    }

    fn on_decode_iter(&mut self, now: Us, instance: usize, batch: u32, kv_tokens: u64, dur: Us) {
        self.tick(now);
        if self.kv_by_inst.len() <= instance {
            self.kv_by_inst.resize(instance + 1, 0);
        }
        self.kv_by_inst[instance] = kv_tokens;
        if self.trace_on {
            self.push_event(TraceEvent {
                name: "decode_iter",
                ph: 'X',
                ts: now,
                dur,
                pid: instance as u64 + 1,
                tid: 0,
                arg: Some(("batch", batch as u64)),
            });
        }
    }

    fn on_flip(&mut self, now: Us, instance: usize, to: Role, dur: Us) {
        self.tick(now);
        // flips swap prefill↔decode; count the new role live at issue
        // time (the dur-long warmup is visible as the flip slice)
        let from = match to {
            Role::Decode => Role::Prefill,
            Role::Prefill => Role::Decode,
            Role::Coupled => Role::Coupled,
        };
        self.roles[role_idx(from)] -= 1;
        self.roles[role_idx(to)] += 1;
        if self.trace_on {
            self.push_event(TraceEvent {
                name: "flip",
                ph: 'X',
                ts: now,
                dur,
                pid: instance as u64 + 1,
                tid: 0,
                arg: None,
            });
        }
    }

    fn on_scale(&mut self, now: Us, _instance: usize, role: Role, added: bool) {
        self.tick(now);
        self.roles[role_idx(role)] += if added { 1 } else { -1 };
    }

    fn on_monitor(&mut self, now: Us, loads: &[DecodeLoad]) {
        self.tick(now);
        self.decode_queue = loads.iter().map(|l| u64::from(l.queue_len)).sum();
    }

    fn on_fault(&mut self, now: Us, kind: &'static str, instance: Option<usize>) {
        self.tick(now);
        if self.trace_on {
            self.push_event(TraceEvent {
                name: kind,
                ph: 'i',
                ts: now,
                dur: 0,
                pid: instance.map(|i| i as u64 + 1).unwrap_or(0),
                tid: 0,
                arg: None,
            });
        }
    }

    fn on_recovery(&mut self, now: Us, kind: &'static str, instance: Option<usize>) {
        self.tick(now);
        if self.trace_on {
            self.push_event(TraceEvent {
                name: kind,
                ph: 'i',
                ts: now,
                dur: 0,
                pid: instance.map(|i| i as u64 + 1).unwrap_or(0),
                tid: 0,
                arg: None,
            });
        }
    }

    fn on_cache(&mut self, now: Us, _req: ReqId, hit_tokens: u32) {
        self.tick(now);
        self.cache_lookups += 1;
        if hit_tokens > 0 {
            self.cache_hits += 1;
        }
    }

    fn on_violation(&mut self, now: Us, _rec: &RequestRecord, _ttft: bool, _tpot: bool) {
        self.tick(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn req(id: ReqId, class: u8, arrival: Us) -> Request {
        Request {
            id,
            task: TaskType::Chat,
            class,
            arrival,
            prompt_len: 100,
            decode_len: 10,
            predicted: None,
            prefix: None,
        }
    }

    fn rec(id: ReqId, class: u8, arrival: Us, finished: Us) -> RequestRecord {
        RequestRecord {
            id,
            task: TaskType::Chat,
            class,
            prompt_len: 100,
            decode_len: 10,
            arrival,
            first_token: arrival + 1,
            finished,
            predicted: None,
            retries: 0,
            recovered: false,
        }
    }

    /// Drive one request through the full pipeline by hand.
    fn walk(t: &mut Telemetry, id: ReqId, at: Us) {
        t.on_arrival(at, &req(id, 0, at));
        t.on_prefill_start(at + 10, 0, id);
        t.on_prefill_finish(at + 30, 0, id);
        t.on_transfer(at + 32, 1, id, 100, 5);
        t.on_decode_enter(at + 37, 1, id);
        t.on_finish(at + 100, &rec(id, 0, at, at + 100));
    }

    #[test]
    fn phases_partition_the_request_interval_exactly() {
        let mut t = Telemetry::new(1_000_000, 4096, false, [1, 1, 0]);
        walk(&mut t, 7, 1_000);
        assert_eq!(t.accounted_us, 100, "Σ phases == JCT, slack 0");
        assert_eq!(t.finished, 1);
        let s = t.into_summary(&RunMetrics::default());
        let total: f64 = s.breakdown.iter().map(|p| p.sum_ms).sum();
        assert!((total - s.accounted_ms()).abs() < 1e-9);
        let names: Vec<&str> = s.breakdown.iter().map(|p| p.phase).collect();
        assert_eq!(names, vec!["queue", "prefill", "dispatch", "transfer", "decode"]);
        assert_eq!(s.phase("queue").unwrap().count, 1);
        assert!((s.phase("decode").unwrap().sum_ms - 0.063).abs() < 1e-9);
        assert_eq!(s.phase_p99_ms("retry"), 0.0, "unvisited phases read 0");
    }

    #[test]
    fn retry_and_shed_paths_keep_the_books_straight() {
        let mut t = Telemetry::new(1_000_000, 4096, false, [1, 1, 0]);
        // a request crashes out of prefill, backs off, then finishes
        t.on_arrival(0, &req(1, 2, 0));
        t.on_prefill_start(5, 0, 1);
        t.on_backoff(20, 1, 45);
        t.on_prefill_start(60, 0, 1);
        t.on_prefill_finish(80, 0, 1);
        t.on_transfer(80, 1, 1, 100, 4);
        t.on_decode_enter(84, 1, 1);
        t.on_finish(120, &rec(1, 2, 0, 120));
        // a shed request leaves no breakdown trace
        t.on_arrival(50, &req(2, 2, 50));
        t.on_shed(50, &req(2, 2, 50));
        // a failed request likewise
        t.on_arrival(55, &req(3, 2, 55));
        t.on_request_failed(90, &req(3, 2, 55));
        assert_eq!((t.finished, t.shed, t.failed), (1, 1, 1));
        assert_eq!(t.accounted_us, 120, "shed/failed never enter the breakdown");
        assert_eq!(t.phase_count, [0; N_PHASES], "no open phases left behind");
        let s = t.into_summary(&RunMetrics::default());
        assert!((s.phase("retry").unwrap().sum_ms - 0.040).abs() < 1e-9, "backoff + requeue wait");
        assert_eq!(s.classes.len(), 1);
        assert_eq!(s.classes[0].class, 2);
    }

    #[test]
    fn sampler_is_piecewise_exact_and_downsamples_deterministically() {
        let mut t = Telemetry::new(10, 4, false, [1, 1, 0]);
        t.on_arrival(0, &req(1, 0, 0));
        // next event at t=35: boundaries 10,20,30 must see 1 in flight
        t.on_prefill_start(35, 0, 1);
        assert_eq!(t.samples.len(), 3);
        assert!(t.samples.iter().all(|s| s.in_flight == 1));
        assert_eq!(t.samples[2].phases[Phase::Queue.idx()], 1);
        // crossing the cap keeps every other point and doubles cadence
        t.on_prefill_finish(200, 0, 1);
        assert!(t.samples.len() <= 4);
        assert_eq!(t.interval, 20);
        let ts: Vec<Us> = t.samples.iter().map(|s| s.t).collect();
        let mut sorted = ts.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(ts, sorted, "series stays strictly increasing after downsampling");
    }

    #[test]
    fn trace_export_is_valid_chrome_trace_event_json() {
        let mut t = Telemetry::new(50, 4096, true, [1, 1, 0]);
        walk(&mut t, 3, 0);
        t.on_chunk(5, 0, 100, 28, 7);
        t.on_decode_iter(40, 1, 4, 400, 6);
        t.on_flip(90, 0, Role::Decode, 600);
        t.on_fault(95, "crash", Some(1));
        t.on_recovery(99, "restart", Some(1));
        let s = t.into_summary(&RunMetrics::default());
        let trace = s.trace.expect("trace armed");
        let parsed = Json::parse(&trace.dump()).expect("round-trips");
        let evs = parsed.get("traceEvents").expect("top-level traceEvents").as_arr().unwrap();
        assert!(!evs.is_empty());
        let mut phases = 0;
        for e in evs {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            assert!(e.get("name").is_some() && e.get("pid").is_some());
            match ph {
                "X" => {
                    assert!(e.get("ts").is_some() && e.get("dur").is_some());
                    if e.get("tid").unwrap().as_usize() == Some(3) {
                        phases += 1;
                    }
                }
                "i" => assert_eq!(e.get("s").unwrap().as_str(), Some("g")),
                "C" => assert!(e.at(&["args", "value"]).is_some()),
                "M" => assert!(e.at(&["args", "name"]).is_some()),
                other => panic!("unexpected ph {other}"),
            }
        }
        assert_eq!(phases, 5, "request 3's five phase spans all exported");
        // telemetry-off construction records no events at all
        let mut off = Telemetry::new(50, 4096, false, [1, 1, 0]);
        walk(&mut off, 9, 0);
        assert!(off.events.is_empty());
        assert!(off.into_summary(&RunMetrics::default()).trace.is_none());
    }

    #[test]
    fn series_csv_has_one_row_per_sample_and_pinned_header() {
        let mut t = Telemetry::new(25, 4096, false, [2, 1, 0]);
        walk(&mut t, 1, 0);
        t.on_monitor(110, &[]);
        let s = t.into_summary(&RunMetrics::default());
        let csv = s.series_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some(SERIES_CSV_HEADER));
        assert_eq!(lines.count(), s.series.len());
        assert_eq!(SERIES_CSV_HEADER.split(',').count(), 20);
        assert!(s.series.iter().all(|p| p.roles == [2, 1, 0]));
    }
}
