//! Fault injection and recovery vocabulary: deterministic chaos schedules
//! for the DES drivers.
//!
//! A scenario may declare a list of fault events — instance crashes
//! (permanent or with a restart after a configurable downtime), KV-link
//! outage/degradation windows, and slow-node straggler multipliers — plus
//! the recovery knobs those faults demand (retry budget, backoff base,
//! degraded-admission watermark). The spec level ([`FaultSpec`] /
//! [`FaultPlanSpec`], ms units) mirrors the JSON/builder/CLI surface the
//! way `ElasticSpec` and `ClassSpec` do; [`FaultPlanSpec::to_config`]
//! resolves to the runtime [`FaultConfig`] (µs) carried by
//! `ClusterConfig`/`BaselineConfig`.
//!
//! Determinism: the runtime [`FaultPlan`] owns its own seeded RNG stream
//! ([`FAULT_STREAM`], the same pattern as the class-stamping stream in the
//! workload generator), consumed *only* when an event needs a random
//! target (`instance` absent). Scheduled events draw nothing. A run with
//! `fault: None` builds no plan, schedules no events, and draws from no
//! extra stream — its trajectory is bit-identical to pre-fault builds
//! (golden-tested); a run with an empty event list likewise.

use crate::types::Us;
use crate::util::rng::Pcg;

/// RNG stream id for fault-target draws — distinct from the workload
/// length stream, the class-stamping stream, and the cluster dispatch
/// stream, so injecting faults never perturbs arrivals or routing draws.
pub const FAULT_STREAM: u64 = 0x7e57_fa17_c0de_0bad;

/// What kind of fault an event injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Instance dies permanently (its slot never serves again).
    Crash,
    /// Instance dies, then restarts with a fresh (empty) role state after
    /// `down_ms` of downtime. The restarted incarnation is a new epoch.
    Restart,
    /// KV-transfer link is fully out for `down_ms`: new sends wait for
    /// the window to close; in-flight transfers landing inside the window
    /// time out and re-send.
    LinkOut,
    /// KV-transfer link runs at `factor`× its nominal transfer time for
    /// `down_ms`.
    LinkDegrade,
    /// Instance compute runs at `factor`× its nominal iteration time for
    /// `down_ms` (a slow node, not a dead one).
    Straggler,
}

impl FaultKind {
    pub const ALL: [FaultKind; 5] = [
        FaultKind::Crash,
        FaultKind::Restart,
        FaultKind::LinkOut,
        FaultKind::LinkDegrade,
        FaultKind::Straggler,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Restart => "restart",
            FaultKind::LinkOut => "link_out",
            FaultKind::LinkDegrade => "link_degrade",
            FaultKind::Straggler => "straggler",
        }
    }
}

/// Every `kind` string a driver can pass to `Observer::on_fault` — the
/// fault-instant vocabulary of a Perfetto trace (superset of
/// [`FaultKind`] spellings: the engine adds derived conditions like
/// `degraded` and `request_failed`). Trace tooling and the telemetry
/// schema test key on this list.
pub const OBSERVED_FAULT_KINDS: [&str; 6] =
    ["crash", "link_out", "link_degrade", "straggler", "degraded", "request_failed"];

/// Likewise for `Observer::on_recovery` — every recovery-instant name.
pub const OBSERVED_RECOVERY_KINDS: [&str; 4] =
    ["requeue", "restart", "resend", "capacity_restored"];

/// Parse a fault-kind spelling (JSON `kind` value / `--fault kind=`).
pub fn parse_fault_kind(s: &str) -> Result<FaultKind, String> {
    FaultKind::ALL
        .into_iter()
        .find(|k| k.name() == s)
        .ok_or_else(|| format!("unknown fault kind '{s}' (known: crash, restart, link_out, link_degrade, straggler)"))
}

/// Inverse of [`parse_fault_kind`] (spec echo / `--list` vocabulary).
pub fn fault_kind_key(k: FaultKind) -> &'static str {
    k.name()
}

/// One injected fault event as declared in a scenario spec (ms units —
/// the spec-level mirror of the runtime [`FaultEvent`]).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Virtual time the event fires.
    pub at_ms: f64,
    /// Target instance id; `None` = pick uniformly among instances
    /// currently serving a role, from the plan's own RNG stream.
    /// Ignored by the link kinds (the link is cluster-wide).
    pub instance: Option<usize>,
    /// Window length: restart downtime / link window / straggler window.
    /// Defaults per kind (see [`FaultSpec::down_ms_or_default`]).
    pub down_ms: Option<f64>,
    /// Slowdown multiplier for `link_degrade`/`straggler` (must be ≥ 1).
    pub factor: Option<f64>,
}

impl FaultSpec {
    pub fn new(kind: FaultKind, at_ms: f64) -> Self {
        FaultSpec { kind, at_ms, instance: None, down_ms: None, factor: None }
    }

    /// Per-kind window default when `down_ms` is absent.
    pub fn down_ms_or_default(&self) -> f64 {
        self.down_ms.unwrap_or(match self.kind {
            FaultKind::Crash => 0.0, // permanent: no window
            FaultKind::Restart => 200.0,
            FaultKind::LinkOut => 100.0,
            FaultKind::LinkDegrade => 200.0,
            FaultKind::Straggler => 500.0,
        })
    }

    /// Per-kind factor default when `factor` is absent.
    pub fn factor_or_default(&self) -> f64 {
        self.factor.unwrap_or(match self.kind {
            FaultKind::LinkDegrade => 4.0,
            FaultKind::Straggler => 2.0,
            _ => 1.0,
        })
    }

    /// Reject malformed events with a friendly message (shared by the
    /// JSON loader and the `--fault` flag parser).
    pub fn validate(&self) -> Result<(), String> {
        if !self.at_ms.is_finite() || self.at_ms < 0.0 {
            return Err(format!("fault at_ms must be a non-negative number, got {}", self.at_ms));
        }
        if let Some(d) = self.down_ms {
            if !d.is_finite() || d <= 0.0 {
                return Err(format!("fault down_ms must be > 0, got {d}"));
            }
        }
        if let Some(f) = self.factor {
            if !f.is_finite() || f < 1.0 {
                return Err(format!("fault factor must be ≥ 1, got {f}"));
            }
            if matches!(self.kind, FaultKind::Crash | FaultKind::Restart | FaultKind::LinkOut) {
                return Err(format!("fault kind '{}' takes no factor", self.kind.name()));
            }
        }
        Ok(())
    }
}

/// The scenario-level `faults` object: the event list plus the recovery
/// knobs (all optional in the JSON — defaults below).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlanSpec {
    pub events: Vec<FaultSpec>,
    /// Bounded retry budget: a request re-queued more than this many
    /// times is permanently failed (counted, never silently dropped).
    pub retry_max: u32,
    /// Exponential-backoff base: retry k waits `backoff_ms · 2^k`.
    pub backoff_ms: f64,
    /// Degraded-mode watermark: when surviving serving capacity falls
    /// below this fraction of the initial capacity, the coordinator sheds
    /// non-tier-0 arrivals at admission until capacity recovers.
    pub watermark: f64,
}

impl Default for FaultPlanSpec {
    fn default() -> Self {
        FaultPlanSpec { events: Vec::new(), retry_max: 4, backoff_ms: 25.0, watermark: 0.5 }
    }
}

impl FaultPlanSpec {
    pub fn validate(&self) -> Result<(), String> {
        for ev in &self.events {
            ev.validate()?;
        }
        if !self.backoff_ms.is_finite() || self.backoff_ms <= 0.0 {
            return Err(format!("faults backoff_ms must be > 0, got {}", self.backoff_ms));
        }
        if !self.watermark.is_finite() || !(0.0..=1.0).contains(&self.watermark) {
            return Err(format!("faults watermark must be in [0,1], got {}", self.watermark));
        }
        Ok(())
    }

    /// Resolve to the runtime form (ms → µs, defaults applied, events
    /// sorted by fire time so delivery order is spec-order-independent).
    pub fn to_config(&self) -> FaultConfig {
        let mut events: Vec<FaultEvent> = self
            .events
            .iter()
            .map(|s| FaultEvent {
                at: (s.at_ms * 1e3) as Us,
                kind: s.kind,
                instance: s.instance,
                down: (s.down_ms_or_default() * 1e3) as Us,
                factor: s.factor_or_default(),
            })
            .collect();
        events.sort_by_key(|e| e.at);
        FaultConfig {
            events,
            retry_max: self.retry_max,
            backoff_us: (self.backoff_ms * 1e3) as Us,
            watermark: self.watermark,
        }
    }
}

/// Runtime form of one fault event (µs, defaults resolved).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    pub at: Us,
    pub kind: FaultKind,
    pub instance: Option<usize>,
    pub down: Us,
    pub factor: f64,
}

/// Runtime fault configuration carried by driver configs (the resolved
/// mirror of [`FaultPlanSpec`], like `SloConfig` vs `ClassSpec`).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Injected events, sorted by `at`.
    pub events: Vec<FaultEvent>,
    pub retry_max: u32,
    pub backoff_us: Us,
    pub watermark: f64,
}

/// A fired event, resolved against the live fleet (random targets drawn
/// from the plan's stream). The driver executes the action.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Injection {
    /// Kill `instance` now; if `restart_at` is set, bring it back (fresh
    /// state, bumped epoch) at that time.
    Crash { instance: usize, restart_at: Option<Us> },
    /// Link window: transfers run at `factor`× (or stall entirely when
    /// `outage`) until `until`.
    Link { factor: f64, outage: bool, until: Us },
    /// Instance `instance` computes at `factor`× until `until`.
    Straggle { instance: usize, factor: f64, until: Us },
    /// No live target existed at fire time (e.g. the named instance had
    /// already crashed) — the event is dropped, counted by the driver.
    Skipped,
}

/// Live per-run fault state: the schedule, the target RNG stream, and the
/// currently open link/straggler windows. Owned by a driver only when its
/// config carries a `FaultConfig` — absent, no fault code runs at all.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    cfg: FaultConfig,
    rng: Pcg,
    link_factor: f64,
    link_outage: bool,
    link_until: Us,
    /// Per-instance (factor, until) straggler windows; grows on demand.
    straggle: Vec<(f64, Us)>,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig, seed: u64) -> Self {
        FaultPlan {
            cfg,
            rng: Pcg::with_stream(seed, FAULT_STREAM),
            link_factor: 1.0,
            link_outage: false,
            link_until: 0,
            straggle: Vec::new(),
        }
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.cfg.events
    }

    pub fn retry_max(&self) -> u32 {
        self.cfg.retry_max
    }

    pub fn watermark(&self) -> f64 {
        self.cfg.watermark
    }

    /// Backoff before retry number `retries` (1-based): exponential in
    /// the retry count, shift-capped so huge budgets cannot overflow.
    pub fn backoff_us(&self, retries: u32) -> Us {
        self.cfg.backoff_us.saturating_mul(1u64 << retries.saturating_sub(1).min(16))
    }

    /// Fire event `k` at `now`. `live` is the set of instance ids
    /// currently serving a role (crash/straggler candidates). The RNG is
    /// drawn only for events with no explicit target.
    pub fn fire(&mut self, k: usize, now: Us, live: &[usize]) -> Injection {
        let ev = self.cfg.events[k].clone();
        match ev.kind {
            FaultKind::Crash | FaultKind::Restart => {
                let target = match ev.instance {
                    Some(i) if live.contains(&i) => Some(i),
                    Some(_) => None, // named target already dead/flipping
                    None if !live.is_empty() => Some(live[self.rng.index(live.len())]),
                    None => None,
                };
                match target {
                    Some(i) => Injection::Crash {
                        instance: i,
                        restart_at: match ev.kind {
                            FaultKind::Restart => Some(now + ev.down),
                            _ => None,
                        },
                    },
                    None => Injection::Skipped,
                }
            }
            FaultKind::LinkOut | FaultKind::LinkDegrade => {
                let outage = ev.kind == FaultKind::LinkOut;
                let until = now + ev.down;
                self.link_factor = if outage { 1.0 } else { ev.factor };
                self.link_outage = outage;
                self.link_until = until;
                Injection::Link { factor: ev.factor, outage, until }
            }
            FaultKind::Straggler => {
                let target = match ev.instance {
                    Some(i) if live.contains(&i) => Some(i),
                    Some(_) => None,
                    None if !live.is_empty() => Some(live[self.rng.index(live.len())]),
                    None => None,
                };
                match target {
                    Some(i) => {
                        let until = now + ev.down;
                        if self.straggle.len() <= i {
                            self.straggle.resize(i + 1, (1.0, 0));
                        }
                        self.straggle[i] = (ev.factor, until);
                        Injection::Straggle { instance: i, factor: ev.factor, until }
                    }
                    None => Injection::Skipped,
                }
            }
        }
    }

    /// Compute-slowdown multiplier for instance `i` at `now` (1.0 when no
    /// straggler window is open — the scheduling fast path).
    pub fn slowdown(&self, i: usize, now: Us) -> f64 {
        match self.straggle.get(i) {
            Some(&(f, until)) if now < until => f,
            _ => 1.0,
        }
    }

    /// If a link *outage* window is open at `now`, when it closes.
    pub fn link_outage_until(&self, now: Us) -> Option<Us> {
        if self.link_outage && now < self.link_until {
            Some(self.link_until)
        } else {
            None
        }
    }

    /// Exposed time of a KV transfer started at `now` whose fault-free
    /// exposed time is `nominal`: an open outage window delays the send
    /// to the window's close; an open degradation window stretches it.
    pub fn link_transfer_us(&self, now: Us, nominal: Us) -> Us {
        if now >= self.link_until {
            return nominal;
        }
        if self.link_outage {
            (self.link_until - now) + nominal
        } else {
            scale_dur(nominal, self.link_factor)
        }
    }
}

/// Scale a duration by a slowdown factor. The `f == 1.0` fast path keeps
/// fault-free and windows-closed trajectories bit-exact (no float round
/// trip on unaffected iterations).
pub fn scale_dur(dur: Us, f: f64) -> Us {
    if f == 1.0 {
        dur
    } else {
        ((dur as f64) * f).round() as Us
    }
}

// ------------------------------------------------------------- CLI flag

/// Parse one `--fault` CLI flag value into a [`FaultSpec`]. Format is
/// comma-separated `key=value` pairs using the same key spellings as the
/// JSON spec:
///
/// ```text
/// kind=restart,at_ms=500,instance=3,down_ms=200
/// kind=link_out,at_ms=800,down_ms=100
/// kind=straggler,at_ms=0,factor=3
/// ```
///
/// `kind` and `at_ms` are required; everything else takes the per-kind
/// defaults. Unknown keys, unknown kinds, and malformed numbers are
/// errors, never silent defaults.
pub fn parse_fault_flag(s: &str) -> Result<FaultSpec, String> {
    let mut kind: Option<FaultKind> = None;
    let mut at_ms: Option<f64> = None;
    let mut instance: Option<usize> = None;
    let mut down_ms: Option<f64> = None;
    let mut factor: Option<f64> = None;
    for pair in s.split(',') {
        let pair = pair.trim();
        if pair.is_empty() {
            continue;
        }
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| format!("--fault: expected key=value, got '{pair}'"))?;
        let num = |key: &str| -> Result<f64, String> {
            v.parse::<f64>().map_err(|_| format!("--fault: {key} needs a number, got '{v}'"))
        };
        match k {
            "kind" => kind = Some(parse_fault_kind(v).map_err(|e| format!("--fault: {e}"))?),
            "at_ms" => at_ms = Some(num("at_ms")?),
            "instance" => {
                instance = Some(v.parse::<usize>().map_err(|_| {
                    format!("--fault: instance needs a non-negative integer, got '{v}'")
                })?)
            }
            "down_ms" => down_ms = Some(num("down_ms")?),
            "factor" => factor = Some(num("factor")?),
            _ => {
                return Err(format!(
                    "--fault: unknown key '{k}' (known: kind, at_ms, instance, down_ms, factor)"
                ))
            }
        }
    }
    let kind = kind.ok_or_else(|| "--fault: 'kind=' is required".to_string())?;
    let at_ms = at_ms.ok_or_else(|| "--fault: 'at_ms=' is required".to_string())?;
    let spec = FaultSpec { kind, at_ms, instance, down_ms, factor };
    spec.validate().map_err(|e| format!("--fault: {e}"))?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for k in FaultKind::ALL {
            assert_eq!(parse_fault_kind(fault_kind_key(k)).unwrap(), k);
        }
        assert!(parse_fault_kind("meteor").is_err());
    }

    #[test]
    fn spec_resolves_ms_to_us_sorted_with_defaults() {
        let spec = FaultPlanSpec {
            events: vec![
                FaultSpec::new(FaultKind::LinkOut, 800.0),
                FaultSpec { instance: Some(3), ..FaultSpec::new(FaultKind::Restart, 500.0) },
            ],
            ..Default::default()
        };
        let cfg = spec.to_config();
        assert_eq!(cfg.events.len(), 2);
        assert_eq!(cfg.events[0].at, 500_000, "events sorted by fire time");
        assert_eq!(cfg.events[0].down, 200_000, "restart downtime default 200 ms");
        assert_eq!(cfg.events[0].instance, Some(3));
        assert_eq!(cfg.events[1].down, 100_000, "link outage default 100 ms");
        assert_eq!(cfg.retry_max, 4);
        assert_eq!(cfg.backoff_us, 25_000);
    }

    #[test]
    fn validation_rejects_malformed_events() {
        assert!(FaultSpec { at_ms: -1.0, ..FaultSpec::new(FaultKind::Crash, 0.0) }.validate().is_err());
        assert!(FaultSpec { down_ms: Some(0.0), ..FaultSpec::new(FaultKind::Restart, 0.0) }
            .validate()
            .is_err());
        assert!(FaultSpec { factor: Some(0.5), ..FaultSpec::new(FaultKind::Straggler, 0.0) }
            .validate()
            .is_err());
        assert!(
            FaultSpec { factor: Some(2.0), ..FaultSpec::new(FaultKind::Crash, 0.0) }.validate().is_err(),
            "crash takes no factor"
        );
        assert!(FaultSpec { factor: Some(2.0), ..FaultSpec::new(FaultKind::Straggler, 0.0) }
            .validate()
            .is_ok());
    }

    #[test]
    fn fire_resolves_targets_deterministically() {
        let spec = FaultPlanSpec {
            events: vec![
                FaultSpec::new(FaultKind::Restart, 1.0),
                FaultSpec { instance: Some(9), ..FaultSpec::new(FaultKind::Crash, 2.0) },
            ],
            ..Default::default()
        };
        let mut a = FaultPlan::new(spec.to_config(), 42);
        let mut b = FaultPlan::new(spec.to_config(), 42);
        let live = [0usize, 1, 2, 3];
        assert_eq!(a.fire(0, 1_000, &live), b.fire(0, 1_000, &live), "same seed, same pick");
        match a.fire(0, 1_000, &live) {
            Injection::Crash { instance, restart_at } => {
                assert!(live.contains(&instance));
                assert_eq!(restart_at, Some(201_000));
            }
            other => panic!("expected crash, got {other:?}"),
        }
        assert_eq!(a.fire(1, 2_000, &live), Injection::Skipped, "named target not live");
    }

    #[test]
    fn link_windows_delay_and_stretch_transfers() {
        let spec = FaultPlanSpec {
            events: vec![FaultSpec::new(FaultKind::LinkOut, 0.0)],
            ..Default::default()
        };
        let mut plan = FaultPlan::new(spec.to_config(), 7);
        assert_eq!(plan.link_transfer_us(0, 1_000), 1_000, "no window yet");
        let inj = plan.fire(0, 10_000, &[]);
        assert_eq!(inj, Injection::Link { factor: 1.0, outage: true, until: 110_000 });
        assert_eq!(plan.link_outage_until(50_000), Some(110_000));
        assert_eq!(plan.link_transfer_us(50_000, 1_000), 61_000, "wait out the outage, then send");
        assert_eq!(plan.link_outage_until(110_000), None);
        assert_eq!(plan.link_transfer_us(110_000, 1_000), 1_000, "window closed");
        // degradation stretches rather than stalls
        let spec = FaultPlanSpec {
            events: vec![FaultSpec {
                factor: Some(3.0),
                ..FaultSpec::new(FaultKind::LinkDegrade, 0.0)
            }],
            ..Default::default()
        };
        let mut plan = FaultPlan::new(spec.to_config(), 7);
        plan.fire(0, 0, &[]);
        assert_eq!(plan.link_transfer_us(0, 1_000), 3_000);
        assert!(plan.link_outage_until(0).is_none(), "degradation is not an outage");
    }

    #[test]
    fn straggler_windows_scope_to_instance_and_time() {
        let spec = FaultPlanSpec {
            events: vec![FaultSpec {
                instance: Some(1),
                factor: Some(2.0),
                down_ms: Some(10.0),
                ..FaultSpec::new(FaultKind::Straggler, 0.0)
            }],
            ..Default::default()
        };
        let mut plan = FaultPlan::new(spec.to_config(), 1);
        plan.fire(0, 0, &[0, 1]);
        assert_eq!(plan.slowdown(1, 5_000), 2.0);
        assert_eq!(plan.slowdown(0, 5_000), 1.0, "other instances unaffected");
        assert_eq!(plan.slowdown(1, 10_000), 1.0, "window closed");
        assert_eq!(plan.slowdown(7, 0), 1.0, "beyond the table: no slowdown");
        assert_eq!(scale_dur(1_000, 2.0), 2_000);
        assert_eq!(scale_dur(1_234, 1.0), 1_234, "factor 1 takes the exact fast path");
    }

    #[test]
    fn backoff_is_exponential_and_bounded() {
        let plan = FaultPlan::new(FaultPlanSpec::default().to_config(), 0);
        assert_eq!(plan.backoff_us(1), 25_000);
        assert_eq!(plan.backoff_us(2), 50_000);
        assert_eq!(plan.backoff_us(3), 100_000);
        assert!(plan.backoff_us(u32::MAX) > 0, "shift-capped, no overflow");
    }

    #[test]
    fn fault_flag_parses_and_rejects() {
        let f = parse_fault_flag("kind=restart,at_ms=500,instance=3,down_ms=200").unwrap();
        assert_eq!((f.kind, f.at_ms, f.instance, f.down_ms), (FaultKind::Restart, 500.0, Some(3), Some(200.0)));
        let f = parse_fault_flag("kind=link_out,at_ms=800").unwrap();
        assert_eq!(f.kind, FaultKind::LinkOut);
        assert!(parse_fault_flag("at_ms=1").is_err(), "kind required");
        assert!(parse_fault_flag("kind=crash").is_err(), "at_ms required");
        assert!(parse_fault_flag("kind=meteor,at_ms=1").is_err(), "unknown kind");
        assert!(parse_fault_flag("kind=crash,at_ms=1,color=red").is_err(), "unknown key");
        assert!(parse_fault_flag("kind=crash,at_ms=abc").is_err(), "bad number");
        assert!(parse_fault_flag("kind=straggler,at_ms=0,factor=0.2").is_err(), "factor < 1");
    }
}
