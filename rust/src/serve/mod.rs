//! Real-mode serving: the disaggregated pipeline running the actual AOT'd
//! model through PJRT — Python never on this path.
//!
//! Logical prefill and decode instances share the single CPU PJRT device
//! (our stand-in for two accelerators), but the *system* is identical to
//! sim mode: the same local schedulers, chunker, dispatcher-style KV
//! transfer, paged pool, and admission policies operate on real tensors.
//! KV "transfer" is a real copy from the prefill instance's contiguous
//! cache into the decode pool's pages, optionally throttled to emulate a
//! NVLink/RoCE link (the paper's own mock mechanism, §4).

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::decode::{DecodeJob, DecodePolicy};
use crate::fabric::Link;
use crate::kvcache::PagedKvCache;
use crate::metrics::RunMetrics;
use crate::prefill::{Chunker, PrefillPolicy, PrefillScheduler, Segment};
use crate::runtime::Engine;
use crate::types::{BucketPrediction, ReqId, Request, RequestRecord, Us};
use crate::workload::WorkloadGen;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub prefill_policy: PrefillPolicy,
    pub sched_batch: usize,
    pub decode_policy: DecodePolicy,
    /// Emulate this link's bandwidth on KV transfers (None = raw memcpy).
    pub emulate_link: Option<Link>,
    /// Use the real AOT'd length predictor (vs no prediction).
    pub use_predictor: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            prefill_policy: PrefillPolicy::Sjf,
            sched_batch: 16,
            decode_policy: DecodePolicy::ReserveDynamic,
            emulate_link: None,
            use_predictor: true,
        }
    }
}

#[derive(Debug, Default)]
pub struct ServeReport {
    pub metrics: RunMetrics,
    pub generated_tokens: u64,
    pub prefill_chunks: u64,
    pub decode_iters: u64,
    pub transfer_bytes: u64,
    pub wall_secs: f64,
    /// Sample of generated token ids (first request) for smoke checks.
    pub sample_output: Vec<i32>,
}

struct PrefillJob {
    /// Contiguous per-request KV caches (the artifact's [L,S,H,Dh] layout).
    k: Vec<f32>,
    v: Vec<f32>,
    tokens: Vec<i32>,
    /// Next-token logits after the prompt (set when the last chunk runs).
    first_logits: Option<Vec<f32>>,
}

struct DecodeSlotState {
    last_token: i32,
    out_tokens: Vec<i32>,
}

/// The real-mode server: single-threaded cooperative loop over logical
/// prefill/decode instances (deterministic; the CPU device is shared).
pub struct Server<'e> {
    engine: &'e Engine,
    cfg: ServeConfig,
}

impl<'e> Server<'e> {
    pub fn new(engine: &'e Engine, cfg: ServeConfig) -> Self {
        Server { engine, cfg }
    }

    /// Serve a trace of requests to completion. Requests' prompt/decode
    /// lengths are clamped to the artifact's context limits.
    pub fn serve(&self, trace: Vec<Request>, gen: &mut WorkloadGen) -> Result<ServeReport> {
        let m = self.engine.manifest.model.clone();
        let d = self.engine.manifest.decode.clone();
        let chunk = m.chunk;
        let t0 = Instant::now();
        let now_us = |t0: &Instant| -> Us { t0.elapsed().as_micros() as Us };

        // ---- clamp + synthesize prompts
        let mut requests: Vec<Request> = trace;
        for r in &mut requests {
            r.prompt_len = r.prompt_len.clamp(2, (m.max_seq / 2) as u32);
            r.decode_len = r.decode_len.clamp(1, (m.max_seq / 2 - 2) as u32);
        }
        let prompts: HashMap<ReqId, Vec<i32>> = requests
            .iter()
            .map(|r| (r.id, gen.prompt_tokens(r, m.vocab as u32)))
            .collect();

        // ---- logical prefill instance
        let mut sched = PrefillScheduler::new(self.cfg.prefill_policy, self.cfg.sched_batch);
        let mut chunker = Chunker::new(chunk as u32);
        let mut pjobs: HashMap<ReqId, PrefillJob> = HashMap::new();
        let mut book: HashMap<ReqId, Request> = HashMap::new();

        // ---- logical decode instance. Real mode drives its own admission
        // (transferred jobs already own their pages), so it keeps plain
        // queues instead of a DecodeScheduler; the pool-full backpressure
        // below plays the admission-policy role.
        let _policy = self.cfg.decode_policy;
        let max_batch = d.batch as u32;
        let mut d_waiting: VecDeque<DecodeJob> = VecDeque::new();
        let mut d_running: Vec<DecodeJob> = Vec::new();
        let mut kv = PagedKvCache::new(d.n_pages as u32, d.page_size as u32);
        let pool_n = self.engine.decode_pool_numel();
        let mut k_pool = vec![0f32; pool_n];
        let mut v_pool = vec![0f32; pool_n];
        let mut slots: HashMap<ReqId, DecodeSlotState> = HashMap::new();

        let mut report = ServeReport::default();
        let mut first_token: HashMap<ReqId, Us> = HashMap::new();
        let mut pending_transfer: VecDeque<ReqId> = VecDeque::new();

        // ---- admit everything (batch arrival; the e2e example measures
        // serving latency, not queueing theory)
        for r in &requests {
            let mut req = r.clone();
            if self.cfg.use_predictor {
                let p = &self.engine.manifest.predictor;
                let toks = &prompts[&r.id];
                let n = toks.len().min(p.max_prompt);
                let mut padded = vec![0i32; p.max_prompt];
                padded[..n].copy_from_slice(&toks[..n]);
                let logits = self.engine.predict_len(&padded, n as i32)?;
                let bucket = Engine::argmax(&logits) as u8;
                req.predicted =
                    Some(BucketPrediction::from_bucket(bucket, p.granularity as u32, p.n_buckets as u8));
            }
            sched.push(req.meta());
            book.insert(r.id, req);
        }

        let total = requests.len();
        let mut finished = 0usize;

        while finished < total {
            // ---------------- prefill: one chunk per loop turn
            while chunker.n_open() < 4 {
                let Some(r) = sched.pop() else { break };
                pjobs.insert(
                    r.id,
                    PrefillJob {
                        k: vec![0f32; self.engine.prefill_kv_numel()],
                        v: vec![0f32; self.engine.prefill_kv_numel()],
                        tokens: prompts[&r.id].clone(),
                        first_logits: None,
                    },
                );
                chunker.admit(r);
            }
            if let Some(ch) = chunker.next_chunk() {
                report.prefill_chunks += 1;
                for seg in &ch.segments {
                    self.run_segment(seg, chunk, &mut pjobs)?;
                    if seg.last {
                        first_token.insert(seg.req, now_us(&t0));
                        pending_transfer.push_back(seg.req);
                    }
                }
            }

            // ---------------- KV transfer: prefill cache → decode pool
            while let Some(id) = pending_transfer.pop_front() {
                let req = book[&id];
                let pj = pjobs.get(&id).unwrap();
                let first_tok = Engine::argmax(pj.first_logits.as_ref().unwrap()) as i32;
                if req.decode_len <= 1 {
                    // prefill's token completes the request
                    self.finish(&mut report.metrics, &book[&id], &first_token, now_us(&t0));
                    slots.insert(id, DecodeSlotState { last_token: first_tok, out_tokens: vec![first_tok] });
                    report.generated_tokens += 1;
                    pjobs.remove(&id);
                    finished += 1;
                    continue;
                }
                // allocate pages and copy rows (the *real* transfer)
                if !kv.can_fit(id, req.prompt_len + 1) {
                    pending_transfer.push_front(id);
                    break; // decode pool full: let decode drain first
                }
                kv.alloc(id, req.prompt_len).map_err(|e| anyhow!("{e:?}"))?;
                let bytes = self.copy_kv_to_pool(
                    &pjobs[&id],
                    kv.table(id).unwrap().pages.clone(),
                    req.prompt_len as usize,
                    d.page_size,
                    &m,
                    d.n_pages,
                    &mut k_pool,
                    &mut v_pool,
                );
                report.transfer_bytes += bytes;
                if let Some(link) = &self.cfg.emulate_link {
                    // paper §4: wait out the emulated wire time
                    let wire = link.transfer_us(bytes as f64);
                    std::thread::sleep(std::time::Duration::from_micros(wire));
                }
                // hand to the decode side: pages are already resident, so
                // the job enters the waiting line holding them.
                let mut job = DecodeJob::new(req.meta(), req.decode_len);
                job.generated = 1;
                slots.insert(id, DecodeSlotState { last_token: first_tok, out_tokens: vec![first_tok] });
                report.generated_tokens += 1;
                d_waiting.push_back(job);
                pjobs.remove(&id);
            }

            // ---------------- decode: one iteration per loop turn
            // admission: waiting jobs already hold pages (transferred), so
            // admission is just moving them into the running batch.
            while (d_running.len() as u32) < max_batch {
                let Some(job) = d_waiting.front() else { break };
                if !kv.contains(job.meta.id) {
                    break; // not transferred yet
                }
                let mut job = d_waiting.pop_front().unwrap();
                job.running = true;
                d_running.push(job);
            }
            if !d_running.is_empty() {
                report.decode_iters += 1;
                let completed = self.decode_iteration(
                    &mut d_running,
                    &mut kv,
                    &mut slots,
                    &mut k_pool,
                    &mut v_pool,
                    &mut report,
                )?;
                for id in completed {
                    self.finish(&mut report.metrics, &book[&id], &first_token, now_us(&t0));
                    finished += 1;
                }
            }

            if chunker.n_open() == 0
                && sched.is_empty()
                && d_running.is_empty()
                && d_waiting.is_empty()
                && pending_transfer.is_empty()
                && finished < total
            {
                return Err(anyhow!("serve loop stalled with {} unfinished", total - finished));
            }
        }

        report.wall_secs = t0.elapsed().as_secs_f64();
        report.metrics.makespan_us = now_us(&t0);
        report.metrics.busy_us = vec![report.metrics.makespan_us];
        report.metrics.alive_us = vec![report.metrics.makespan_us];
        if let Some(r0) = requests.first() {
            if let Some(s) = slots.get(&r0.id) {
                report.sample_output = s.out_tokens.clone();
            }
        }
        Ok(report)
    }

    fn run_segment(
        &self,
        seg: &Segment,
        chunk: usize,
        pjobs: &mut HashMap<ReqId, PrefillJob>,
    ) -> Result<()> {
        let pj = pjobs.get_mut(&seg.req).unwrap();
        let mut toks = vec![0i32; chunk];
        let lo = seg.start as usize;
        let hi = (seg.start + seg.len) as usize;
        toks[..(hi - lo)].copy_from_slice(&pj.tokens[lo..hi]);
        let logits = self.engine.prefill_segment(
            &toks,
            seg.start as i32,
            seg.len as i32,
            &mut pj.k,
            &mut pj.v,
        )?;
        if seg.last {
            pj.first_logits = Some(logits);
        }
        Ok(())
    }

    /// Copy a request's contiguous KV rows into its allocated pool pages.
    /// Returns bytes moved (both K and V).
    #[allow(clippy::too_many_arguments)]
    fn copy_kv_to_pool(
        &self,
        pj: &PrefillJob,
        pages: Vec<u32>,
        prompt_len: usize,
        page_size: usize,
        m: &crate::runtime::manifest::ModelShapes,
        n_pages: usize,
        k_pool: &mut [f32],
        v_pool: &mut [f32],
    ) -> u64 {
        let row = m.n_heads * m.d_head;
        let pool_rows = n_pages * page_size;
        let mut bytes = 0u64;
        for l in 0..m.n_layers {
            for t in 0..prompt_len {
                let page = pages[t / page_size] as usize;
                let dst_row = l * pool_rows + page * page_size + t % page_size;
                let src_row = l * m.max_seq + t;
                k_pool[dst_row * row..(dst_row + 1) * row]
                    .copy_from_slice(&pj.k[src_row * row..(src_row + 1) * row]);
                v_pool[dst_row * row..(dst_row + 1) * row]
                    .copy_from_slice(&pj.v[src_row * row..(src_row + 1) * row]);
                bytes += 2 * (row * 4) as u64;
            }
        }
        bytes
    }

    fn decode_iteration(
        &self,
        running: &mut Vec<DecodeJob>,
        kv: &mut PagedKvCache,
        slots: &mut HashMap<ReqId, DecodeSlotState>,
        k_pool: &mut Vec<f32>,
        v_pool: &mut Vec<f32>,
        report: &mut ServeReport,
    ) -> Result<Vec<ReqId>> {
        let m = &self.engine.manifest.model;
        let d = &self.engine.manifest.decode;
        let b = d.batch;
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut seq_lens = vec![1i32; b];
        let mut bt = vec![0i32; b * d.max_pages_per_req];
        let mut ids: Vec<Option<ReqId>> = vec![None; b];

        for (slot, job) in running.iter().take(b).enumerate() {
            let id = job.meta.id;
            let st = &slots[&id];
            let pos = job.meta.prompt_len as usize + job.generated as usize - 1;
            tokens[slot] = st.last_token;
            positions[slot] = pos as i32;
            seq_lens[slot] = pos as i32 + 1;
            let table = kv.table(id).expect("running job must hold pages");
            for (pi, page) in table.pages.iter().enumerate().take(d.max_pages_per_req) {
                bt[slot * d.max_pages_per_req + pi] = *page as i32;
            }
            ids[slot] = Some(id);
        }

        // grow pages for the tokens being written this iteration
        for job in running.iter().take(b) {
            kv.append_token(job.meta.id).map_err(|e| anyhow!("decode pool exhausted: {e:?}"))?;
        }
        // refresh block tables after growth
        for (slot, id) in ids.iter().enumerate() {
            let Some(id) = id else { continue };
            let table = kv.table(*id).unwrap();
            for (pi, page) in table.pages.iter().enumerate().take(d.max_pages_per_req) {
                bt[slot * d.max_pages_per_req + pi] = *page as i32;
            }
        }

        let logits =
            self.engine.decode_step(&tokens, &positions, k_pool, v_pool, &bt, &seq_lens)?;
        let vocab = m.vocab;
        let mut completed = Vec::new();
        for (slot, id) in ids.iter().enumerate() {
            let Some(id) = id else { continue };
            let next = Engine::argmax(&logits[slot * vocab..(slot + 1) * vocab]) as i32;
            let st = slots.get_mut(id).unwrap();
            st.last_token = next;
            st.out_tokens.push(next);
            report.generated_tokens += 1;
            let job = running.iter_mut().find(|j| j.meta.id == *id).unwrap();
            job.generated += 1;
            if job.done() {
                completed.push(*id);
            }
        }
        if !completed.is_empty() {
            // single stable pass: completed jobs leave, survivors keep order
            running.retain(|j| !j.done());
            for id in &completed {
                kv.release(*id);
            }
        }
        Ok(completed)
    }

    fn finish(
        &self,
        metrics: &mut RunMetrics,
        req: &Request,
        first_token: &HashMap<ReqId, Us>,
        now: Us,
    ) {
        metrics.records.push(RequestRecord {
            id: req.id,
            task: req.task,
            class: req.class,
            prompt_len: req.prompt_len,
            decode_len: req.decode_len,
            arrival: 0,
            first_token: *first_token.get(&req.id).unwrap_or(&now),
            finished: now,
            predicted: req.predicted,
        });
    }
}
