//! Paged KV-cache manager (vLLM-style [21]): fixed-size pages, per-request
//! block tables, swap accounting. Both TetriInfer decode instances and the
//! coupled baseline manage KV through this allocator; the real-mode engine
//! additionally mirrors the block tables into the decode artifact's inputs.
//!
//! Invariants (property-tested in rust/tests/proptest_kv.rs):
//!   * page 0 is never allocated (the decode artifact's trash page);
//!   * no page is owned by two live requests, nor by a request and a
//!     shared prefix group at once;
//!   * free + live + shared + 1 == total pages (shared prefix pages are
//!     counted once however many requests reference them);
//!   * a request's capacity always covers its written tokens;
//!   * every shared prefix group holds at least one reference.

use std::collections::HashMap;

use crate::types::ReqId;

#[derive(Clone, Debug)]
pub struct PagedKvCache {
    page_size: u32,
    /// Free list of page ids (page 0 reserved, never enters the list).
    free: Vec<u32>,
    /// Live allocations: request → block table (page ids, in order).
    tables: HashMap<ReqId, BlockTable>,
    /// Shared prefix-KV groups: content hash → refcounted page run. A
    /// group's pages are owned by the group alone — requests reference
    /// them through `retain_shared`/`release_shared` and never list them
    /// in their own block tables, so N sharers cost one copy of the pages
    /// (the radix-cache counterpart of vLLM's prefix caching).
    shared: HashMap<u64, SharedGroup>,
    total_pages: u32,
    /// Cumulative tokens swapped out (for swap-cost accounting).
    pub swapped_out_tokens: u64,
}

/// One refcounted run of prefix pages, keyed by content hash.
#[derive(Clone, Debug)]
struct SharedGroup {
    pages: Vec<u32>,
    refs: u32,
    tokens: u32,
}

#[derive(Clone, Debug, Default)]
pub struct BlockTable {
    pub pages: Vec<u32>,
    /// Tokens actually written (≤ pages.len() * page_size).
    pub len: u32,
}

#[derive(Debug, PartialEq, Eq)]
pub enum AllocError {
    OutOfPages { needed: u32, free: u32 },
    UnknownRequest,
}

impl PagedKvCache {
    pub fn new(total_pages: u32, page_size: u32) -> Self {
        assert!(total_pages >= 2, "need at least trash page + one real page");
        assert!(page_size > 0);
        PagedKvCache {
            page_size,
            free: (1..total_pages).rev().collect(), // page 0 reserved
            tables: HashMap::new(),
            shared: HashMap::new(),
            total_pages,
            swapped_out_tokens: 0,
        }
    }

    pub fn page_size(&self) -> u32 {
        self.page_size
    }

    pub fn free_pages(&self) -> u32 {
        self.free.len() as u32
    }

    pub fn free_tokens(&self) -> u64 {
        self.free.len() as u64 * self.page_size as u64
    }

    pub fn total_pages(&self) -> u32 {
        self.total_pages
    }

    /// Tokens currently resident across all live requests.
    pub fn live_tokens(&self) -> u64 {
        self.tables.values().map(|t| t.len as u64).sum()
    }

    pub fn n_live(&self) -> usize {
        self.tables.len()
    }

    pub fn contains(&self, id: ReqId) -> bool {
        self.tables.contains_key(&id)
    }

    pub fn table(&self, id: ReqId) -> Option<&BlockTable> {
        self.tables.get(&id)
    }

    pub fn pages_for_tokens(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.page_size)
    }

    /// Can `tokens` more tokens be appended for `id` (or allocated fresh)
    /// without running out of pages?
    pub fn can_fit(&self, id: ReqId, tokens: u32) -> bool {
        let (cap, len) = match self.tables.get(&id) {
            Some(t) => (t.pages.len() as u32 * self.page_size, t.len),
            None => (0, 0),
        };
        let needed_tokens = (len + tokens).saturating_sub(cap);
        self.pages_for_tokens(needed_tokens) <= self.free.len() as u32
    }

    /// Allocate a fresh table for `id` sized for `tokens` (e.g. the
    /// transferred prompt KV). Fails without side effects if pages are
    /// short.
    pub fn alloc(&mut self, id: ReqId, tokens: u32) -> Result<(), AllocError> {
        assert!(!self.tables.contains_key(&id), "double alloc for {id}");
        let need = self.pages_for_tokens(tokens).max(1);
        if need > self.free.len() as u32 {
            return Err(AllocError::OutOfPages { needed: need, free: self.free.len() as u32 });
        }
        let pages = self.free.split_off(self.free.len() - need as usize);
        self.tables.insert(id, BlockTable { pages, len: tokens });
        Ok(())
    }

    /// Append one generated token's KV for `id`, growing the table by a
    /// page when the current capacity is exhausted.
    pub fn append_token(&mut self, id: ReqId) -> Result<(), AllocError> {
        let t = self.tables.get_mut(&id).ok_or(AllocError::UnknownRequest)?;
        let cap = t.pages.len() as u32 * self.page_size;
        if t.len == cap {
            let Some(p) = self.free.pop() else {
                return Err(AllocError::OutOfPages { needed: 1, free: 0 });
            };
            t.pages.push(p);
        }
        t.len += 1;
        Ok(())
    }

    /// Release `id`'s pages back to the free list.
    pub fn release(&mut self, id: ReqId) -> u32 {
        match self.tables.remove(&id) {
            Some(t) => {
                let n = t.pages.len() as u32;
                self.free.extend(t.pages);
                n
            }
            None => 0,
        }
    }

    /// Swap a victim out (vLLM-style preemption): frees its pages and
    /// returns the token count that must later be re-fetched. The caller
    /// keeps the request's metadata to swap it back in via `alloc`.
    pub fn swap_out(&mut self, id: ReqId) -> Option<u32> {
        let t = self.tables.remove(&id)?;
        self.free.extend(t.pages);
        self.swapped_out_tokens += t.len as u64;
        Some(t.len)
    }

    // ------------------------------------------------- shared prefix pages

    /// Pages currently held by shared prefix groups (each counted once,
    /// however many requests reference it).
    pub fn shared_pages(&self) -> u32 {
        self.shared.values().map(|g| g.pages.len() as u32).sum()
    }

    /// Live references on the shared group `key`, 0 when absent.
    pub fn shared_refs(&self, key: u64) -> u32 {
        self.shared.get(&key).map_or(0, |g| g.refs)
    }

    /// Allocate a shared prefix group for `tokens` of KV under content
    /// hash `key`, with one reference. Fails without side effects when
    /// pages are short; the caller must not hold `key` already (reuse an
    /// existing group through `retain_shared` instead).
    pub fn alloc_shared(&mut self, key: u64, tokens: u32) -> Result<(), AllocError> {
        assert!(!self.shared.contains_key(&key), "double shared alloc for {key:#x}");
        let need = self.pages_for_tokens(tokens).max(1);
        if need > self.free.len() as u32 {
            return Err(AllocError::OutOfPages { needed: need, free: self.free.len() as u32 });
        }
        let pages = self.free.split_off(self.free.len() - need as usize);
        self.shared.insert(key, SharedGroup { pages, refs: 1, tokens });
        Ok(())
    }

    /// Add one reference to the shared group `key`. Returns false (and
    /// does nothing) when no such group exists — the caller then pays for
    /// a fresh `alloc_shared`.
    pub fn retain_shared(&mut self, key: u64) -> bool {
        match self.shared.get_mut(&key) {
            Some(g) => {
                g.refs += 1;
                true
            }
            None => false,
        }
    }

    /// Drop one reference from the shared group `key`; the last reference
    /// frees the pages. Returns the number of pages returned to the free
    /// list (0 while other sharers remain or when `key` is unknown).
    pub fn release_shared(&mut self, key: u64) -> u32 {
        let Some(g) = self.shared.get_mut(&key) else { return 0 };
        g.refs -= 1;
        if g.refs > 0 {
            return 0;
        }
        let g = self.shared.remove(&key).expect("present: just accessed");
        let n = g.pages.len() as u32;
        self.free.extend(g.pages);
        n
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for p in &self.free {
            if *p == 0 || *p >= self.total_pages {
                return Err(format!("free list holds invalid page {p}"));
            }
            if !seen.insert(*p) {
                return Err(format!("page {p} duplicated in free list"));
            }
        }
        for (id, t) in &self.tables {
            for p in &t.pages {
                if *p == 0 || *p >= self.total_pages {
                    return Err(format!("req {id} holds invalid page {p}"));
                }
                if !seen.insert(*p) {
                    return Err(format!("page {p} double-owned (req {id})"));
                }
            }
            let cap = t.pages.len() as u32 * self.page_size;
            if t.len > cap {
                return Err(format!("req {id} len {} exceeds capacity {cap}", t.len));
            }
            if t.len > 0 && (cap - t.len) >= self.page_size {
                return Err(format!("req {id} holds a fully-unused page"));
            }
        }
        for (key, g) in &self.shared {
            if g.refs == 0 {
                return Err(format!("shared group {key:#x} lingers with zero refs"));
            }
            for p in &g.pages {
                if *p == 0 || *p >= self.total_pages {
                    return Err(format!("shared group {key:#x} holds invalid page {p}"));
                }
                if !seen.insert(*p) {
                    return Err(format!("page {p} double-owned (shared group {key:#x})"));
                }
            }
            let cap = g.pages.len() as u32 * self.page_size;
            if g.tokens > cap {
                return Err(format!(
                    "shared group {key:#x} tokens {} exceed capacity {cap}",
                    g.tokens
                ));
            }
        }
        if seen.len() as u32 != self.total_pages - 1 {
            return Err(format!(
                "page leak: tracked {} of {} pages",
                seen.len(),
                self.total_pages - 1
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut kv = PagedKvCache::new(10, 16);
        assert_eq!(kv.free_pages(), 9);
        kv.alloc(1, 40).unwrap(); // 3 pages
        assert_eq!(kv.free_pages(), 6);
        assert_eq!(kv.table(1).unwrap().len, 40);
        kv.check_invariants().unwrap();
        assert_eq!(kv.release(1), 3);
        assert_eq!(kv.free_pages(), 9);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn page_zero_never_allocated() {
        let mut kv = PagedKvCache::new(4, 8);
        kv.alloc(1, 8).unwrap();
        kv.alloc(2, 8).unwrap();
        kv.alloc(3, 8).unwrap();
        for id in [1, 2, 3] {
            assert!(kv.table(id).unwrap().pages.iter().all(|&p| p != 0));
        }
        assert_eq!(kv.free_pages(), 0);
        assert!(kv.alloc(4, 1).is_err());
    }

    #[test]
    fn append_grows_pages_lazily() {
        let mut kv = PagedKvCache::new(5, 4);
        kv.alloc(1, 3).unwrap(); // 1 page, len 3
        kv.append_token(1).unwrap(); // fills page: len 4
        assert_eq!(kv.table(1).unwrap().pages.len(), 1);
        kv.append_token(1).unwrap(); // allocates 2nd page
        assert_eq!(kv.table(1).unwrap().pages.len(), 2);
        assert_eq!(kv.table(1).unwrap().len, 5);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn failed_alloc_has_no_side_effects() {
        let mut kv = PagedKvCache::new(4, 8);
        kv.alloc(1, 16).unwrap(); // 2 pages
        let before = kv.free_pages();
        assert_eq!(
            kv.alloc(2, 100),
            Err(AllocError::OutOfPages { needed: 13, free: 1 })
        );
        assert_eq!(kv.free_pages(), before);
        assert!(!kv.contains(2));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn swap_out_frees_and_accounts() {
        let mut kv = PagedKvCache::new(8, 4);
        kv.alloc(1, 10).unwrap(); // 3 pages
        assert_eq!(kv.swap_out(1), Some(10));
        assert_eq!(kv.swapped_out_tokens, 10);
        assert_eq!(kv.free_pages(), 7);
        assert!(!kv.contains(1));
    }

    #[test]
    fn shared_groups_refcount_and_free_once() {
        let mut kv = PagedKvCache::new(10, 4);
        kv.alloc_shared(0xabc, 10).unwrap(); // 3 pages, one copy
        assert_eq!(kv.shared_pages(), 3);
        assert_eq!(kv.free_pages(), 6);
        assert!(kv.retain_shared(0xabc));
        assert_eq!(kv.shared_refs(0xabc), 2, "second sharer costs no pages");
        assert_eq!(kv.shared_pages(), 3);
        kv.alloc(1, 4).unwrap(); // private table alongside
        kv.check_invariants().unwrap();
        assert_eq!(kv.release_shared(0xabc), 0, "one sharer remains");
        assert_eq!(kv.shared_pages(), 3);
        assert_eq!(kv.release_shared(0xabc), 3, "last ref frees the run");
        assert_eq!(kv.shared_pages(), 0);
        assert_eq!(kv.free_pages(), 8);
        kv.check_invariants().unwrap();
        assert!(!kv.retain_shared(0xabc), "gone after the last release");
        assert_eq!(kv.release_shared(0xabc), 0, "unknown key is inert");
    }

    #[test]
    fn failed_shared_alloc_has_no_side_effects() {
        let mut kv = PagedKvCache::new(4, 8);
        kv.alloc(1, 16).unwrap(); // 2 of 3 usable pages
        assert_eq!(
            kv.alloc_shared(7, 100),
            Err(AllocError::OutOfPages { needed: 13, free: 1 })
        );
        assert_eq!(kv.shared_pages(), 0);
        assert_eq!(kv.shared_refs(7), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn can_fit_accounts_for_partial_pages() {
        let mut kv = PagedKvCache::new(3, 4);
        kv.alloc(1, 3).unwrap(); // 1 page, 1 slot spare
        assert!(kv.can_fit(1, 1)); // fits in the spare slot
        assert!(kv.can_fit(1, 5)); // needs 1 more page, 1 free
        assert!(!kv.can_fit(1, 9)); // needs 2 more pages, only 1 free
        assert!(kv.can_fit(2, 4)); // fresh request, 1 page
        assert!(!kv.can_fit(2, 5));
    }
}
