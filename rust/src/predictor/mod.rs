//! Length predictor interface (§3.3.2): speculate a request's decode-length
//! *bucket* so the dispatcher (§3.3.4) and decode schedulers (§3.4) can be
//! working-set-aware.
//!
//! Two implementations:
//!  * `OraclePredictor` — sim mode. Knows the ground-truth decode length
//!    and corrupts it to a target accuracy (the paper's acc-200 = 74.9%,
//!    or 100% for Figure 18's ideal line). Mis-predictions land on nearby
//!    buckets (log-normal noise), matching how a real classifier errs.
//!  * `PjrtPredictor` (rust/src/runtime/) — real mode. Runs the AOT'd
//!    OPT-125M-style classifier artifact.

use crate::types::BucketPrediction;
use crate::util::Pcg;

pub trait Predictor {
    /// Predict the decode-length bucket for a request. `prompt_tokens` is
    /// the (possibly truncated) prompt; `true_decode_len` is available in
    /// sim mode only (the oracle corrupts it; a real model never sees it).
    fn predict(&mut self, prompt_tokens: &[i32], true_decode_len: u32) -> BucketPrediction;

    fn granularity(&self) -> u32;
    fn n_buckets(&self) -> u8;
}

/// Sim-mode predictor with controllable accuracy.
#[derive(Clone, Debug)]
pub struct OraclePredictor {
    pub granularity: u32,
    pub n_buckets: u8,
    /// Probability the predicted bucket equals the true bucket.
    pub accuracy: f64,
    rng: Pcg,
}

impl OraclePredictor {
    pub fn new(granularity: u32, n_buckets: u8, accuracy: f64, seed: u64) -> Self {
        OraclePredictor {
            granularity,
            n_buckets,
            accuracy,
            rng: Pcg::with_stream(seed, 0x5bd1e995),
        }
    }

    /// The paper's measured operating point (74.9% at granularity 200).
    pub fn paper_acc200(seed: u64) -> Self {
        Self::new(200, 8, 0.749, seed)
    }

    /// Figure 18's ideal-accuracy ablation.
    pub fn ideal(seed: u64) -> Self {
        Self::new(200, 8, 1.0, seed)
    }

    fn true_bucket(&self, decode_len: u32) -> u8 {
        ((decode_len / self.granularity).min(self.n_buckets as u32 - 1)) as u8
    }
}

impl Predictor for OraclePredictor {
    fn predict(&mut self, _prompt: &[i32], true_decode_len: u32) -> BucketPrediction {
        let truth = self.true_bucket(true_decode_len);
        let bucket = if self.rng.f64() < self.accuracy {
            truth
        } else {
            // Classifier errors cluster near the truth: multiplicative
            // log-noise on the length, resampled until the bucket differs.
            let mut b = truth;
            for _ in 0..16 {
                let noisy = true_decode_len.max(1) as f64 * (0.5 * self.rng.normal()).exp();
                b = self.true_bucket(noisy.round() as u32);
                if b != truth {
                    break;
                }
            }
            if b == truth {
                // force an off-by-one miss
                b = if truth + 1 < self.n_buckets { truth + 1 } else { truth.saturating_sub(1) };
            }
            b
        };
        BucketPrediction::from_bucket(bucket, self.granularity, self.n_buckets)
    }

    fn granularity(&self) -> u32 {
        self.granularity
    }

    fn n_buckets(&self) -> u8 {
        self.n_buckets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_predictor_is_exact() {
        let mut p = OraclePredictor::ideal(1);
        for len in [1u32, 50, 199, 200, 399, 1400, 5000] {
            let pred = p.predict(&[], len);
            let want = (len / 200).min(7) as u8;
            assert_eq!(pred.bucket, want, "len={len}");
            assert!(pred.lo <= len || pred.bucket == 7);
        }
    }

    #[test]
    fn accuracy_is_calibrated() {
        let mut p = OraclePredictor::paper_acc200(7);
        let mut rng = Pcg::new(3);
        let n = 20_000;
        let mut hits = 0;
        for _ in 0..n {
            let len = rng.lognormal(128.0, 0.9).round().clamp(1.0, 1599.0) as u32;
            let truth = (len / 200).min(7) as u8;
            if p.predict(&[], len).bucket == truth {
                hits += 1;
            }
        }
        let acc = hits as f64 / n as f64;
        assert!((acc - 0.749).abs() < 0.02, "{acc}");
    }

    #[test]
    fn misses_cluster_near_truth() {
        let mut p = OraclePredictor::new(200, 8, 0.0, 11); // always miss
        let mut total_dist = 0u32;
        let n = 2000;
        for i in 0..n {
            let len = 300 + (i % 7) * 100; // buckets 1..5
            let pred = p.predict(&[], len as u32);
            let truth = (len / 200).min(7) as u8;
            assert_ne!(pred.bucket, truth);
            total_dist += (pred.bucket as i32 - truth as i32).unsigned_abs();
        }
        assert!((total_dist as f64 / n as f64) < 2.5, "errors should be near-miss");
    }

    #[test]
    fn bucket_range_bounds_resource_estimate() {
        let mut p = OraclePredictor::ideal(5);
        let pred = p.predict(&[], 450);
        assert_eq!(pred.bucket, 2);
        assert_eq!((pred.lo, pred.hi), (400, 600));
    }
}
