//! Content-addressed prefix cache over token ids (the SGLang RadixAttention
//! / vLLM automatic-prefix-caching idea, applied to TetriInfer's prefill
//! instances): shared system prompts and multi-turn histories hash into
//! chunk-aligned *blocks* organized as a radix/trie index, so a request
//! whose prompt prefix is already resident skips those prefill chunks and
//! only the uncached suffix enters the chunk scheduler.
//!
//! Sim-mode content addressing: the workload generator stamps requests
//! with a [`PrefixStamp`](crate::types::PrefixStamp) naming which member
//! of the shared-prefix population their prompt starts with; the block
//! hash chain is derived deterministically from that stamp
//! ([`block_hashes`]), standing in for hashing real token ids. Everything
//! downstream — trie walk, refcount pinning, LRU eviction, epoch
//! invalidation — is the real algorithm.
//!
//! Invariants (property-tested in rust/tests/proptest_prefix.rs):
//!   * `used_pages <= capacity_pages` at every instant;
//!   * a pinned block (refcount > 0) is never evicted;
//!   * a resident block's whole ancestor chain is resident (trie shape);
//!   * lookups agree with a naive longest-common-prefix oracle when
//!     capacity never forces eviction;
//!   * a crash invalidation (epoch bump) empties the index and makes
//!     stale pins inert.

use std::collections::BTreeMap;

/// Per-prefill-instance cache sizing. `block_tokens` is the hash-block
/// granularity (chunk-aligned: only whole blocks are shared, so a prefix
/// shorter than one block never hits); `page_size` prices blocks in the
/// same page currency the paged KV allocator uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefixCacheConfig {
    /// Capacity of one prefill instance's cache, in pages.
    pub capacity_pages: u32,
    /// Tokens per page (matches `PagedKvCache` sizing).
    pub page_size: u32,
    /// Tokens per content-addressed block.
    pub block_tokens: u32,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig { capacity_pages: 4096, page_size: 16, block_tokens: 128 }
    }
}

/// Hit/miss/evict/pinned counters, cumulative across epochs (a crash
/// invalidation empties the index but keeps the ledger — the run report
/// wants totals, not per-incarnation shards).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Lookups that matched at least one whole block.
    pub hits: u64,
    /// Lookups that matched nothing.
    pub misses: u64,
    /// Prefill tokens actually skipped (post-clamp, added by the caller
    /// via [`PrefixCache::note_saved`] — the raw matched depth can exceed
    /// what the scheduler may legally skip).
    pub saved_tokens: u64,
    pub inserted_blocks: u64,
    pub evicted_blocks: u64,
    /// Blocks destroyed by crash invalidation (epoch bumps).
    pub invalidated_blocks: u64,
}

/// Handle returned by [`PrefixCache::lookup_pin`]: the deepest matched
/// node plus the epoch it was pinned under. Dropping it without
/// [`PrefixCache::release`] leaks the pin; releasing after a crash
/// invalidation is a harmless no-op (the epoch check makes it inert).
#[derive(Clone, Copy, Debug)]
pub struct Pin {
    node: usize,
    depth: u32,
    epoch: u32,
}

impl Pin {
    /// Whole blocks matched when this pin was taken.
    pub fn depth(&self) -> u32 {
        self.depth
    }
}

/// One trie node = one resident block. Children are keyed by the child
/// block's content hash in a `BTreeMap` so iteration order (and thus any
/// tie-break that ever walks it) is deterministic.
#[derive(Clone, Debug)]
struct Node {
    children: BTreeMap<u64, usize>,
    parent: usize,
    /// This node's key inside `parent.children` (needed to unlink).
    key: u64,
    /// Refcount: requests currently reusing this block (routing pinned it
    /// until their prefill completes). Pinned blocks never evict.
    pins: u32,
    /// LRU clock stamp (monotone tick, not virtual time — determinism).
    last_used: u64,
    live: bool,
}

/// The per-prefill-instance radix cache. Node 0 is the root (zero-length
/// prefix): always live, never evicted, holds no pages.
#[derive(Clone, Debug)]
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    pages_per_block: u32,
    used_pages: u32,
    /// Bumped by [`PrefixCache::invalidate`] (crash): pins taken under an
    /// older epoch release as no-ops, lookups only ever see fresh blocks.
    epoch: u32,
    tick: u64,
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    pub stats: CacheStats,
}

/// Deterministic 64-bit mix (splitmix64 finalizer).
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// The chunk-aligned content-hash chain for a stamped prefix: one hash
/// per *whole* block (`prefix_len / block_tokens`), each chained on its
/// predecessor so block k of prefix A never collides with block k of
/// prefix B — the radix property over synthetic content.
pub fn block_hashes(prefix_id: u64, prefix_len: u32, block_tokens: u32) -> Vec<u64> {
    let n = if block_tokens == 0 { 0 } else { prefix_len / block_tokens };
    let mut out = Vec::with_capacity(n as usize);
    let mut h = mix(prefix_id ^ 0x5157_a11a_b10c_c0de);
    for i in 0..n as u64 {
        h = mix(h ^ i);
        out.push(h);
    }
    out
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> Self {
        assert!(cfg.page_size > 0 && cfg.block_tokens > 0);
        let root = Node {
            children: BTreeMap::new(),
            parent: 0,
            key: 0,
            pins: 0,
            last_used: 0,
            live: true,
        };
        PrefixCache {
            pages_per_block: cfg.block_tokens.div_ceil(cfg.page_size),
            cfg,
            used_pages: 0,
            epoch: 0,
            tick: 0,
            nodes: vec![root],
            free_nodes: Vec::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn config(&self) -> &PrefixCacheConfig {
        &self.cfg
    }

    pub fn used_pages(&self) -> u32 {
        self.used_pages
    }

    pub fn capacity_pages(&self) -> u32 {
        self.cfg.capacity_pages
    }

    /// Resident blocks (root excluded).
    pub fn n_blocks(&self) -> usize {
        self.nodes.iter().filter(|n| n.live).count() - 1
    }

    /// Pages held by pinned blocks — the "pinned bytes" gauge in page
    /// currency (multiply by page_size × kv_bytes_per_tok for bytes).
    pub fn pinned_pages(&self) -> u32 {
        let pinned =
            self.nodes.iter().skip(1).filter(|n| n.live && n.pins > 0).count() as u32;
        pinned * self.pages_per_block
    }

    /// Tokens covered by `depth` matched blocks.
    pub fn tokens_for_depth(&self, depth: u32) -> u32 {
        depth * self.cfg.block_tokens
    }

    /// Read-only longest-match walk: how many whole blocks of `hashes`
    /// are resident. No LRU touch, no pin, no stats — what cache-aware
    /// routing probes every instance with before committing to one.
    pub fn peek(&self, hashes: &[u64]) -> u32 {
        let mut at = 0usize;
        let mut depth = 0u32;
        for h in hashes {
            match self.nodes[at].children.get(h) {
                Some(&c) => {
                    at = c;
                    depth += 1;
                }
                None => break,
            }
        }
        depth
    }

    /// Longest-match walk that *commits*: bumps LRU stamps along the
    /// matched path, pins every node on it (refcounts), and counts the
    /// hit/miss. The caller holds the [`Pin`] until the request's prefill
    /// completes, then [`PrefixCache::release`]s it.
    pub fn lookup_pin(&mut self, hashes: &[u64]) -> Pin {
        self.tick += 1;
        let tick = self.tick;
        let mut at = 0usize;
        let mut depth = 0u32;
        for h in hashes {
            match self.nodes[at].children.get(h) {
                Some(&c) => {
                    at = c;
                    depth += 1;
                    self.nodes[at].pins += 1;
                    self.nodes[at].last_used = tick;
                }
                None => break,
            }
        }
        if depth > 0 {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
        }
        Pin { node: at, depth, epoch: self.epoch }
    }

    /// Count prefill tokens actually skipped thanks to a hit (the caller
    /// clamps the matched depth against the request's real prompt).
    pub fn note_saved(&mut self, tokens: u64) {
        self.stats.saved_tokens += tokens;
    }

    /// Drop a pin taken by [`PrefixCache::lookup_pin`]: decrement the
    /// refcount of every node on the pinned path. Inert if the cache was
    /// invalidated since the pin was taken (the epoch moved on).
    pub fn release(&mut self, pin: Pin) {
        if pin.epoch != self.epoch || pin.depth == 0 {
            return;
        }
        let mut at = pin.node;
        for _ in 0..pin.depth {
            debug_assert!(self.nodes[at].pins > 0, "release of an unpinned block");
            self.nodes[at].pins -= 1;
            at = self.nodes[at].parent;
        }
    }

    /// Insert the block chain for a just-prefilled prefix, extending the
    /// deepest existing match. Evicts unpinned LRU leaves to make room;
    /// stops early (deeper blocks stay uncached) when every resident page
    /// is pinned. Returns the number of blocks newly inserted.
    pub fn insert(&mut self, hashes: &[u64]) -> u32 {
        self.tick += 1;
        let tick = self.tick;
        let mut at = 0usize;
        let mut inserted = 0u32;
        for h in hashes {
            if let Some(&c) = self.nodes[at].children.get(h) {
                at = c;
                self.nodes[at].last_used = tick;
                continue;
            }
            if !self.make_room() {
                break;
            }
            let node = Node {
                children: BTreeMap::new(),
                parent: at,
                key: *h,
                pins: 0,
                last_used: tick,
                live: true,
            };
            let idx = match self.free_nodes.pop() {
                Some(i) => {
                    self.nodes[i] = node;
                    i
                }
                None => {
                    self.nodes.push(node);
                    self.nodes.len() - 1
                }
            };
            self.nodes[at].children.insert(*h, idx);
            self.used_pages += self.pages_per_block;
            self.stats.inserted_blocks += 1;
            inserted += 1;
            at = idx;
        }
        inserted
    }

    /// Free one block's worth of pages if the next insert would overflow.
    /// Victims are unpinned *leaves* (evicting an interior block would
    /// orphan its subtree and break the radix walk), least-recently-used
    /// first, node index as the deterministic tie-break. Returns false
    /// when capacity cannot be made (everything resident is pinned or on
    /// a pinned path).
    fn make_room(&mut self) -> bool {
        while self.used_pages + self.pages_per_block > self.cfg.capacity_pages {
            let mut victim: Option<(u64, usize)> = None;
            for (i, n) in self.nodes.iter().enumerate().skip(1) {
                if n.live && n.pins == 0 && n.children.is_empty() {
                    let cand = (n.last_used, i);
                    if victim.map_or(true, |v| cand < v) {
                        victim = Some(cand);
                    }
                }
            }
            let Some((_, v)) = victim else { return false };
            self.evict(v);
        }
        true
    }

    fn evict(&mut self, idx: usize) {
        let (parent, key) = (self.nodes[idx].parent, self.nodes[idx].key);
        self.nodes[parent].children.remove(&key);
        self.nodes[idx].live = false;
        self.free_nodes.push(idx);
        self.used_pages -= self.pages_per_block;
        self.stats.evicted_blocks += 1;
    }

    /// Crash invalidation: the instance's KV (and with it every cached
    /// block) died with the old incarnation. Empties the index, bumps the
    /// epoch so in-flight pins go inert, keeps the cumulative stats.
    pub fn invalidate(&mut self) {
        self.stats.invalidated_blocks += self.n_blocks() as u64;
        self.nodes.truncate(1);
        self.nodes[0].children.clear();
        self.nodes[0].pins = 0;
        self.free_nodes.clear();
        self.used_pages = 0;
        self.epoch += 1;
    }

    /// Internal consistency check (tests / debug assertions).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.used_pages > self.cfg.capacity_pages {
            return Err(format!(
                "capacity exceeded: {} of {} pages",
                self.used_pages, self.cfg.capacity_pages
            ));
        }
        let live = self.n_blocks() as u32;
        if live * self.pages_per_block != self.used_pages {
            return Err(format!(
                "page accounting drift: {live} blocks vs {} used pages",
                self.used_pages
            ));
        }
        for (i, n) in self.nodes.iter().enumerate().skip(1) {
            if !n.live {
                continue;
            }
            if !self.nodes[n.parent].live {
                return Err(format!("block {i} has a dead parent {}", n.parent));
            }
            if self.nodes[n.parent].children.get(&n.key) != Some(&i) {
                return Err(format!("block {i} unlinked from parent {}", n.parent));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(capacity_pages: u32) -> PrefixCache {
        PrefixCache::new(PrefixCacheConfig {
            capacity_pages,
            page_size: 16,
            block_tokens: 128,
        })
    }

    #[test]
    fn block_hashes_are_chained_and_prefix_free() {
        let a = block_hashes(1, 512, 128);
        let b = block_hashes(2, 512, 128);
        assert_eq!(a.len(), 4);
        // same prefix id shares every block; different ids share none
        assert_eq!(a, block_hashes(1, 512, 128));
        assert!(a.iter().zip(&b).all(|(x, y)| x != y));
        // partial blocks never hash
        assert_eq!(block_hashes(1, 127, 128).len(), 0);
        assert_eq!(block_hashes(1, 129, 128).len(), 1);
        // a shorter stamp of the same id is a strict hash-chain prefix
        assert_eq!(block_hashes(1, 256, 128), a[..2]);
    }

    #[test]
    fn insert_then_lookup_matches_whole_blocks() {
        let mut c = cache(1024);
        let h = block_hashes(7, 512, 128);
        assert_eq!(c.insert(&h), 4);
        assert_eq!(c.peek(&h), 4);
        assert_eq!(c.peek(&h[..2]), 2);
        assert_eq!(c.peek(&block_hashes(8, 512, 128)), 0);
        assert_eq!(c.used_pages(), 4 * (128 / 16));
        assert_eq!(c.tokens_for_depth(4), 512);
        c.check_invariants().unwrap();
        // re-insert is idempotent
        assert_eq!(c.insert(&h), 0);
        assert_eq!(c.n_blocks(), 4);
    }

    #[test]
    fn lookup_pin_counts_hits_and_release_unpins() {
        let mut c = cache(1024);
        c.insert(&block_hashes(1, 256, 128));
        let pin = c.lookup_pin(&block_hashes(1, 512, 128));
        assert_eq!(pin.depth(), 2);
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.pinned_pages(), 2 * (128 / 16));
        let miss = c.lookup_pin(&block_hashes(9, 512, 128));
        assert_eq!(miss.depth(), 0);
        assert_eq!(c.stats.misses, 1);
        c.release(pin);
        c.release(miss);
        assert_eq!(c.pinned_pages(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn lru_eviction_spares_pinned_blocks() {
        // room for exactly 2 blocks (128 tokens = 8 pages each)
        let mut c = cache(16);
        let a = block_hashes(1, 128, 128);
        let b = block_hashes(2, 128, 128);
        let d = block_hashes(3, 128, 128);
        c.insert(&a);
        c.insert(&b);
        let pin_a = c.lookup_pin(&a); // pins a AND makes it most recent
        c.insert(&d); // must evict b (unpinned LRU), never a
        assert_eq!(c.peek(&a), 1, "pinned block survives");
        assert_eq!(c.peek(&b), 0, "unpinned LRU block evicted");
        assert_eq!(c.peek(&d), 1);
        assert_eq!(c.stats.evicted_blocks, 1);
        assert!(c.used_pages() <= c.capacity_pages());
        c.check_invariants().unwrap();
        c.release(pin_a);
    }

    #[test]
    fn insert_stops_when_everything_is_pinned() {
        let mut c = cache(8); // one block only
        let a = block_hashes(1, 128, 128);
        c.insert(&a);
        let pin = c.lookup_pin(&a);
        let inserted = c.insert(&block_hashes(2, 256, 128));
        assert_eq!(inserted, 0, "no unpinned victim: insert must back off");
        assert_eq!(c.peek(&a), 1);
        c.check_invariants().unwrap();
        c.release(pin);
    }

    #[test]
    fn eviction_is_leaf_first_preserving_trie_shape() {
        let mut c = cache(24); // three blocks
        c.insert(&block_hashes(1, 384, 128)); // chain of 3
        // inserting a fresh chain evicts the deepest (leaf) block first
        c.insert(&block_hashes(2, 128, 128));
        assert_eq!(c.peek(&block_hashes(1, 384, 128)), 2, "leaf went, spine stays");
        c.check_invariants().unwrap();
    }

    #[test]
    fn invalidate_empties_the_index_and_makes_pins_inert() {
        let mut c = cache(1024);
        let h = block_hashes(4, 512, 128);
        c.insert(&h);
        let pin = c.lookup_pin(&h);
        assert_eq!(pin.depth(), 4);
        c.invalidate();
        assert_eq!(c.n_blocks(), 0);
        assert_eq!(c.used_pages(), 0);
        assert_eq!(c.peek(&h), 0);
        assert_eq!(c.stats.invalidated_blocks, 4);
        c.release(pin); // stale epoch: must not underflow or touch anything
        c.check_invariants().unwrap();
        // the next epoch works normally
        c.insert(&h);
        assert_eq!(c.peek(&h), 4);
    }

    #[test]
    fn stats_survive_invalidation() {
        let mut c = cache(1024);
        let h = block_hashes(5, 256, 128);
        c.insert(&h);
        let p = c.lookup_pin(&h);
        c.release(p);
        c.note_saved(256);
        c.invalidate();
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.saved_tokens, 256);
        assert_eq!(c.stats.inserted_blocks, 2);
    }
}
