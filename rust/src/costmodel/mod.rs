//! Calibrated analytical cost model of the paper's testbed: OPT-13B (TP=2)
//! on NVIDIA V100-32GB pairs (§5).
//!
//! The model reproduces the *observables* every scheduling decision in the
//! paper consumes — iteration latency as a function of batched prefill
//! tokens, decode batch size and KV working set, HBM capacity, swap
//! penalties — so the interference phenomena of §2.2 (Figures 3/4/5) are
//! *emergent*, not hard-coded:
//!
//! * Prefill (compute-bound, Fig 2 left): throughput ramps until the
//!   accelerator saturates at `sat_tokens` (512 for OPT-13B on V100),
//!   then goes flat — latency becomes linear in tokens. A fixed `base`
//!   per-iteration overhead makes small batches underutilize hardware.
//! * Decode (memory-bound, Fig 2 right): every iteration streams the
//!   weights plus the batch's KV working set from HBM; throughput grows
//!   with batch size but plateaus at the memory-bandwidth roofline.
//!
//! Calibration targets (§2.2): 1 LP vs 7 co-running LPs → ~2x, vs 63 LPs →
//! ~8x, vs HPs → >10x (Fig 3); one HP in a continuous batch → ~5x decode
//! slowdown (Fig 4); half-heavy decode batch at bs=128 → ~16% throughput
//! drop (Fig 5). See rust/tests/interference.rs.

use crate::types::{Us, US_PER_SEC};

/// Hardware + model constants for one serving instance (2xV100, OPT-13B).
/// Plain constants — `Copy`, so hot paths pass it by value for free.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Fixed per-iteration overhead (kernel launches, scheduling): µs.
    pub base_us: f64,
    /// Prefill per-token cost once the accelerator is saturated: µs/token.
    pub prefill_us_per_tok: f64,
    /// Token count at which prefill saturates compute (ChunkSize): tokens.
    pub sat_tokens: u32,
    /// Decode: weight-streaming floor per iteration: µs.
    pub decode_base_us: f64,
    /// Decode: per-sequence overhead (attention launch, sampling): µs.
    pub decode_us_per_seq: f64,
    /// Decode: KV-cache streaming cost: µs per cached token per iteration.
    pub decode_us_per_kv_tok: f64,
    /// KV bytes per token (all layers, fp16): bytes.
    pub kv_bytes_per_tok: f64,
    /// HBM available for KV after weights/activations: bytes.
    pub hbm_kv_bytes: f64,
    /// Swap (PCIe) cost per token moved: µs.
    pub swap_us_per_tok: f64,
    /// Dollar cost per instance-second (relative units; perf/$ only uses
    /// ratios so the absolute value cancels).
    pub dollar_per_sec: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            base_us: 28_000.0,            // ~28 ms launch+overhead floor
            prefill_us_per_tok: 260.0,    // 512-tok chunk ≈ 133 ms compute
            sat_tokens: 512,              // paper's measured ChunkSize
            decode_base_us: 14_000.0,     // 26 GB fp16 weights / ~1.8 TB/s
            decode_us_per_seq: 50.0,
            // Effective KV-streaming cost per cached token per iteration.
            // The naive bound (820 KB/tok / 1.8 TB/s = 0.45 µs) overstates
            // what batched flash-decode attention pays; 0.17 µs calibrates
            // the Figure 5 measurement (half-heavy bs=128 batch: latency
            // +23%, throughput −16%).
            decode_us_per_kv_tok: 0.17,
            kv_bytes_per_tok: 820_000.0,  // 2*2*40 layers*5120 dim fp16
            hbm_kv_bytes: 32e9,           // 2x32 GB minus weights+activations
            // Preemption cost per token brought back. vLLM's default
            // preemption mode *recomputes* the victim's KV (a full prefill
            // pass, 260 µs/tok) rather than paging over PCIe (51 µs/tok);
            // thrashing is therefore charged at recompute cost.
            swap_us_per_tok: 260.0,
            dollar_per_sec: 1.0,
        }
    }
}

impl CostModel {
    /// Latency of one prefill iteration processing `tokens` prompt tokens
    /// (Figure 2 left: flat throughput past saturation).
    ///
    /// Below saturation the iteration still pays most of the fixed base —
    /// that is exactly why batching more light prefills than the saturation
    /// point "for free" is impossible and chunked prefill wins.
    pub fn prefill_iter_us(&self, tokens: u32) -> Us {
        (self.base_us + self.prefill_us_per_tok * tokens as f64) as Us
    }

    /// Prefill throughput in tokens/s at a given iteration size.
    pub fn prefill_throughput(&self, tokens: u32) -> f64 {
        tokens as f64 * US_PER_SEC as f64 / self.prefill_iter_us(tokens) as f64
    }

    /// Latency of one decode iteration over `batch` sequences whose KV
    /// caches total `kv_tokens` (Figure 2 right: bandwidth plateau).
    pub fn decode_iter_us(&self, batch: u32, kv_tokens: u64) -> Us {
        if batch == 0 {
            return 0;
        }
        (self.decode_base_us
            + self.decode_us_per_seq * batch as f64
            + self.decode_us_per_kv_tok * kv_tokens as f64) as Us
    }

    /// Decode throughput in generated tokens/s.
    pub fn decode_throughput(&self, batch: u32, kv_tokens: u64) -> f64 {
        batch as f64 * US_PER_SEC as f64 / self.decode_iter_us(batch, kv_tokens).max(1) as f64
    }

    /// Latency of one *mixed* continuous-batching iteration (the vanilla
    /// vLLM deployment): prefill tokens and decode sequences ride the same
    /// iteration, so each part inflates the other — this is the §2.2.2
    /// interference. Selective batching runs the prefill and decode
    /// kernel phases back to back, so both phases' costs add (the decode
    /// phase re-streams weights: its attention/FFN passes cannot reuse
    /// the prefill pass's tiles).
    pub fn mixed_iter_us(&self, prefill_tokens: u32, batch: u32, kv_tokens: u64) -> Us {
        if prefill_tokens == 0 {
            return self.decode_iter_us(batch, kv_tokens);
        }
        let mut us = self.base_us + self.prefill_us_per_tok * prefill_tokens as f64;
        if batch > 0 {
            us += self.decode_base_us
                + self.decode_us_per_seq * batch as f64
                + self.decode_us_per_kv_tok * kv_tokens as f64;
        }
        us as Us
    }

    /// How many KV tokens fit in this instance's HBM.
    pub fn kv_capacity_tokens(&self) -> u64 {
        (self.hbm_kv_bytes / self.kv_bytes_per_tok) as u64
    }

    /// Cost of swapping `tokens` of KV cache out (or in) over PCIe.
    pub fn swap_us(&self, tokens: u64) -> Us {
        (self.swap_us_per_tok * tokens as f64) as Us
    }

    /// Time to stream a prompt's KV cache over a link of `gbps` (Gbit/s)
    /// with `lat_us` fixed latency — the prefill→decode transfer (§3.3.4).
    pub fn kv_transfer_us(&self, tokens: u32, gbps: f64, lat_us: f64) -> Us {
        let bytes = self.kv_bytes_per_tok * tokens as f64;
        (lat_us + bytes * 8.0 / (gbps * 1e3)) as Us // gbps*1e3 = bits/µs
    }

    /// The predictor model (OPT-125M) is ~10x faster than the target
    /// (§3.3.2); its prefill rides the same accelerator in parallel mode.
    pub fn predictor_iter_us(&self, tokens: u32) -> Us {
        (self.base_us / 10.0 + self.prefill_us_per_tok / 10.0 * tokens as f64) as Us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LP: u32 = 18; // light prefill (ShareGPT short-prompt median)
    const HP: u32 = 512; // heavy prefill (saturation length)

    #[test]
    fn fig2_prefill_throughput_saturates() {
        let m = CostModel::default();
        let t256 = m.prefill_throughput(256);
        let t512 = m.prefill_throughput(512);
        let t2048 = m.prefill_throughput(2048);
        assert!(t512 > t256, "throughput still ramping below sat");
        // flat (within 25%) past saturation
        assert!((t2048 / t512 - 1.0).abs() < 0.25, "{t512} vs {t2048}");
    }

    #[test]
    fn fig2_decode_throughput_plateaus() {
        let m = CostModel::default();
        // average context 512 tokens/sequence (where the plateau shows)
        let t8 = m.decode_throughput(8, 8 * 512);
        let t64 = m.decode_throughput(64, 64 * 512);
        let t256 = m.decode_throughput(256, 256 * 512);
        assert!(t64 > 4.0 * t8, "decode batching must pay off early");
        assert!(t256 < 2.0 * t64, "bandwidth plateau past ~64");
    }

    #[test]
    fn fig3_prefill_prefill_interference() {
        let m = CostModel::default();
        let solo = m.prefill_iter_us(LP) as f64;
        let with7 = m.prefill_iter_us(8 * LP) as f64;
        let with63 = m.prefill_iter_us(64 * LP) as f64;
        let with_hp = m.prefill_iter_us(LP + 7 * HP) as f64;
        assert!(with7 / solo > 1.6 && with7 / solo < 2.6, "{}", with7 / solo);
        assert!(with63 / solo > 6.0 && with63 / solo < 11.0, "{}", with63 / solo);
        assert!(with_hp / solo > 10.0, "{}", with_hp / solo);
        // heavy prefill slows ~3x with 63 light co-runners
        let hp_solo = m.prefill_iter_us(HP) as f64;
        let hp_with = m.prefill_iter_us(HP + 63 * LP) as f64;
        assert!(hp_with / hp_solo > 2.0 && hp_with / hp_solo < 4.0, "{}", hp_with / hp_solo);
    }

    #[test]
    fn fig4_prefill_decode_interference() {
        let m = CostModel::default();
        // decode-only step, 8 sequences with ~100-token contexts
        let dec = m.mixed_iter_us(0, 8, 800) as f64;
        // one heavy prefill rides the same iteration → ≥5x (paper: ~5x)
        let dec_hp = m.mixed_iter_us(HP, 8, 800) as f64;
        assert!(dec_hp / dec > 5.0, "{}", dec_hp / dec);
        // light prefill co-running with many light decodes slows ~2.5x
        let lp_solo = m.mixed_iter_us(LP, 0, 0) as f64;
        let lp_with = m.mixed_iter_us(LP, 64, 64 * 100) as f64;
        assert!(lp_with / lp_solo > 1.5 && lp_with / lp_solo < 3.5, "{}", lp_with / lp_solo);
    }

    #[test]
    fn fig5_decode_decode_interference() {
        let m = CostModel::default();
        // bs=128: all light (ctx ~60) vs half light / half heavy (ctx ~512)
        let all_light = m.decode_iter_us(128, 128 * 60);
        let half_heavy = m.decode_iter_us(128, 64 * 60 + 64 * 512);
        let lat_ratio = half_heavy as f64 / all_light as f64;
        let thpt_drop = 1.0 - all_light as f64 / half_heavy as f64;
        assert!(lat_ratio > 1.15 && lat_ratio < 1.5, "{lat_ratio}");
        assert!(thpt_drop > 0.10 && thpt_drop < 0.35, "{thpt_drop}");
    }

    #[test]
    fn kv_capacity_matches_hardware() {
        let m = CostModel::default();
        let cap = m.kv_capacity_tokens();
        assert!(cap > 30_000 && cap < 50_000, "{cap}");
    }

    #[test]
    fn transfer_times_nvlink_vs_roce() {
        let m = CostModel::default();
        // 512-token prompt: NVLink 300 GBps = 2400 Gbps, RoCE 200 Gbps
        let nv = m.kv_transfer_us(512, 2400.0, 30.0);
        let roce = m.kv_transfer_us(512, 200.0, 100.0);
        assert!(nv < 2_500, "{nv}");
        assert!(roce > 10_000 && roce < 30_000, "{roce}");
    }

    #[test]
    fn predictor_is_10x_faster() {
        let m = CostModel::default();
        let big = m.prefill_iter_us(512) as f64;
        let small = m.predictor_iter_us(512) as f64;
        assert!((big / small - 10.0).abs() < 1.0, "{}", big / small);
    }
}
