//! TetriInfer — reproduction of "Inference without Interference:
//! Disaggregate LLM Inference for Mixed Downstream Workloads" (Hu et al.,
//! 2024) as a three-layer rust + JAX + Pallas serving stack.
//!
//! Layer map (see DESIGN.md):
//!  * L3 (this crate): disaggregated prefill/decode coordinator — global
//!    scheduler, cluster monitor, chunked prefill, length-prediction-aware
//!    two-level scheduling, KV-transfer fabric, instance flipping, plus the
//!    vanilla-vLLM coupled baseline and a calibrated V100/OPT-13B cost
//!    model for cluster-scale simulation.
//!  * L2/L1 (python/, build-time only): OPT-style JAX model whose chunked
//!    prefill and paged decode attention are Pallas kernels, AOT-lowered to
//!    HLO text and executed here via the PJRT CPU client (`runtime`).

pub mod api;
pub mod baseline;
pub mod coordinator;
pub mod costmodel;
pub mod decode;
pub mod fabric;
pub mod fault;
pub mod instance;
pub mod kvcache;
pub mod metrics;
pub mod optimizer;
pub mod predictor;
pub mod prefill;
pub mod prefixcache;
/// Real-mode PJRT runtime. Gated behind the `pjrt` cargo feature: it
/// needs the vendored `xla` bindings + `anyhow`, which the default
/// (dependency-free) sim build does not ship.
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(feature = "pjrt")]
pub mod serve;
pub mod sim;
pub mod slo;
pub mod sweep;
pub mod telemetry;
pub mod types;
pub mod util;
pub mod workload;

pub use api::{
    Driver, ElasticSpec, NullObserver, Observer, ProgressObserver, Registry, Report, Scenario,
    Tee, TelemetrySpec, TimelineObserver,
};
pub use baseline::{run_baseline, BaselineConfig};
pub use fault::{FaultConfig, FaultKind, FaultPlan, FaultPlanSpec, FaultSpec};
pub use slo::{AdmissionGate, ClassDef, ClassSpec, SloConfig, TokenBucket};
pub use coordinator::{run_cluster, Cluster, ClusterConfig};
pub use telemetry::{Telemetry, TelemetrySummary};
pub use instance::{InstancePool, InstanceRole, InstanceState};
