//! Serving metrics (§5): TTFT, JCT, resource-usage time, perf-per-dollar.
//!
//! Resource usage follows the paper's definition: "the aggregated wall time
//! that the prefill and decode instances use to run a particular workload"
//! (busy time, per instance, summed). perf/$ is throughput-per-resource
//! normalized against a baseline run:
//!     perf/$  ∝  (1 / mean JCT) / (resource_time · $rate)
//! so `perf_per_dollar_vs(base)` reports the paper's "x-fold" improvements.
//!
//! Memory contract (million-request runs): every per-request quantity is
//! *streamed* at finish time into exact counters (`finished`,
//! `generated_tokens`) and log-bucketed histograms (`ttft_hist`,
//! `jct_hist`), so the summaries work with `records` retention switched
//! off. Retention stays on for golden/figure runs, where summaries are
//! computed exactly from the records as before.

use crate::types::{RequestRecord, Us, US_PER_SEC};
use crate::util::{summarize, LogHist, Summary};

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Per-request records. Retention is opt-in per run (`retain_records`;
    /// `Scenario`'s `records` knob): on for golden/figure runs (exact
    /// summaries), off for scale runs (constant memory — summaries come
    /// from the histograms below).
    pub records: Vec<RequestRecord>,
    /// Whether [`RunMetrics::note_finish`] pushes into `records`. Drivers
    /// set this from their config before the run starts.
    pub retain_records: bool,
    /// Requests finished — exact, counted whether or not records are kept.
    pub finished: u64,
    /// Σ decode_len over finished requests (throughput numerator).
    pub generated_tokens: u64,
    /// Streaming TTFT distribution in µs: exact count/sum/min/max,
    /// ≤ ~3.2% relative quantile error (see `util::LogHist`).
    pub ttft_hist: LogHist,
    /// Streaming JCT distribution in µs (same shape as `ttft_hist`).
    pub jct_hist: LogHist,
    /// Busy µs per instance (index = instance id).
    pub busy_us: Vec<Us>,
    /// µs each instance existed in the run (for utilization).
    pub alive_us: Vec<Us>,
    /// Total virtual duration of the run.
    pub makespan_us: Us,
    /// DES events processed by the driver (sim-throughput denominator for
    /// the perf-trajectory benches — see EXPERIMENTS.md §Perf).
    pub events: u64,
    /// Decode/coupled iterations absorbed into a macro-stepped event
    /// instead of paying their own queue round-trip (diagnostic for the
    /// collapsed event class; not part of the virtual-time trajectory).
    pub macro_steps: u64,
    /// High-water arena size = peak in-flight requests. The O(active)
    /// memory proof for scale runs: with records off, total run memory is
    /// proportional to this, not to the trace.
    pub peak_arena: usize,
    /// Swap traffic observed (tokens), for Figure 18 diagnostics.
    pub swapped_tokens: u64,
    /// Number of instance flips that occurred (§3.5).
    pub flips: u32,
    /// Instances the elastic autoscaler added mid-run.
    pub scale_ups: u32,
    /// Instances the elastic autoscaler drained and retired mid-run.
    pub scale_downs: u32,
    /// Per-instance (heavy, light) decode assignments by *true* decode
    /// length — Figure 19's balance diagnostic. Indexed by instance id;
    /// non-decode instances stay (0, 0).
    pub decode_assign: Vec<(u32, u32)>,
}

/// TTFT/JCT/resource for one run, computed once and threaded through
/// comparison rows (each summary is a full collect + sort over records —
/// `vs_row` and perf/$ used to recompute them several times per row).
#[derive(Clone, Debug)]
pub struct RunSummaries {
    pub ttft: Summary,
    pub jct: Summary,
    pub resource_s: f64,
}

/// perf/$ from precomputed summaries: ratio of (1/meanJCT)/resource.
pub fn perf_per_dollar(own: &RunSummaries, base: &RunSummaries) -> f64 {
    let a = 1.0 / (own.jct.mean * own.resource_s);
    let b = 1.0 / (base.jct.mean * base.resource_s);
    a / b
}

impl RunMetrics {
    /// Stream one completed request into the metrics: exact counters +
    /// histograms always; the full record only when retention is on.
    pub fn note_finish(&mut self, rec: RequestRecord) {
        self.finished += 1;
        self.generated_tokens += rec.decode_len as u64;
        self.ttft_hist.record(rec.ttft());
        self.jct_hist.record(rec.jct());
        if self.retain_records {
            self.records.push(rec);
        }
    }

    /// Requests finished: the streamed counter, or the record count for
    /// hand-assembled metrics that never went through `note_finish`.
    pub fn n_finished(&self) -> usize {
        (self.finished as usize).max(self.records.len())
    }

    pub fn ttft_summary(&self) -> Summary {
        if self.records.is_empty() {
            self.ttft_hist.summary_scaled(1e-3)
        } else {
            summarize(&self.records.iter().map(|r| r.ttft() as f64 / 1e3).collect::<Vec<_>>())
        }
    }

    pub fn jct_summary(&self) -> Summary {
        if self.records.is_empty() {
            self.jct_hist.summary_scaled(1e-3)
        } else {
            summarize(&self.records.iter().map(|r| r.jct() as f64 / 1e3).collect::<Vec<_>>())
        }
    }

    /// Every comparison input computed once (see [`RunSummaries`]).
    pub fn summaries(&self) -> RunSummaries {
        RunSummaries {
            ttft: self.ttft_summary(),
            jct: self.jct_summary(),
            resource_s: self.resource_seconds(),
        }
    }

    /// Aggregate busy time across instances, in seconds (the paper's
    /// "resource usage time").
    pub fn resource_seconds(&self) -> f64 {
        self.busy_us.iter().sum::<Us>() as f64 / US_PER_SEC as f64
    }

    /// Generated tokens per second of makespan.
    pub fn decode_throughput(&self) -> f64 {
        let toks: u64 = if self.records.is_empty() {
            self.generated_tokens
        } else {
            self.records.iter().map(|r| r.decode_len as u64).sum()
        };
        toks as f64 / (self.makespan_us.max(1) as f64 / US_PER_SEC as f64)
    }

    /// Performance-per-dollar of this run relative to `base` (>1 = better):
    /// ratio of (1/meanJCT)/resource.
    pub fn perf_per_dollar_vs(&self, base: &RunMetrics) -> f64 {
        perf_per_dollar(&self.summaries(), &base.summaries())
    }

    /// Mean utilization across instances that existed.
    pub fn utilization(&self) -> f64 {
        let busy: f64 = self.busy_us.iter().sum::<Us>() as f64;
        let alive: f64 = self.alive_us.iter().sum::<Us>() as f64;
        if alive == 0.0 {
            0.0
        } else {
            busy / alive
        }
    }

    /// Formatted single-line comparison against a baseline (used by the
    /// figure harness to print the paper's headline rows). Each side's
    /// summaries are computed exactly once for the whole row; callers
    /// that already hold them use [`vs_row_from`] directly.
    pub fn vs_row(&self, name: &str, base: &RunMetrics) -> String {
        vs_row_from(name, &self.summaries(), &base.summaries())
    }
}

/// The comparison row from precomputed summaries (see [`RunSummaries`]).
pub fn vs_row_from(name: &str, own: &RunSummaries, base: &RunSummaries) -> String {
    let dt = 1.0 - own.ttft.mean / base.ttft.mean;
    let dj = 1.0 - own.jct.mean / base.jct.mean;
    let dr = 1.0 - own.resource_s / base.resource_s;
    format!(
        "{name}: TTFT {:+.0}%  JCT {:+.0}%  resource {:+.0}%  perf/$ {:.2}x",
        -dt * 100.0,
        -dj * 100.0,
        -dr * 100.0,
        perf_per_dollar(own, base)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn rec(arrival: Us, first: Us, fin: Us, dlen: u32) -> RequestRecord {
        RequestRecord {
            id: 0,
            task: TaskType::Chat,
            prompt_len: 10,
            decode_len: dlen,
            arrival,
            first_token: first,
            finished: fin,
            predicted: None,
        }
    }

    fn run(jct_ms: f64, resource_s: f64) -> RunMetrics {
        RunMetrics {
            records: vec![rec(0, 1_000, (jct_ms * 1e3) as Us, 100)],
            busy_us: vec![(resource_s * 1e6) as Us],
            alive_us: vec![(resource_s * 2e6) as Us],
            makespan_us: 1_000_000,
            ..Default::default()
        }
    }

    #[test]
    fn ttft_and_jct_in_ms() {
        let m = run(250.0, 1.0);
        assert!((m.ttft_summary().mean - 1.0).abs() < 1e-9);
        assert!((m.jct_summary().mean - 250.0).abs() < 1e-9);
    }

    #[test]
    fn perf_per_dollar_rewards_speed_and_thrift() {
        let fast_cheap = run(100.0, 1.0);
        let slow_pricey = run(200.0, 2.0);
        let ratio = fast_cheap.perf_per_dollar_vs(&slow_pricey);
        assert!((ratio - 4.0).abs() < 1e-9, "{ratio}");
    }

    #[test]
    fn utilization_is_busy_over_alive() {
        let m = run(100.0, 1.0);
        assert!((m.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn throughput_counts_generated_tokens() {
        let m = run(100.0, 1.0); // 100 tokens over 1 s makespan
        assert!((m.decode_throughput() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn records_off_metrics_stream_through_histograms() {
        let mut on = RunMetrics { retain_records: true, ..Default::default() };
        let mut off = RunMetrics { retain_records: false, ..Default::default() };
        let mut t = 0u64;
        for i in 0..2_000u64 {
            t += 350 + (i * 7919) % 9_000; // deterministic skewed arrivals
            let r = rec(t, t + 40_000 + (i % 50) * 1_000, t + 300_000 + (i % 211) * 4_000, 32);
            on.note_finish(r.clone());
            off.note_finish(r);
        }
        assert_eq!(on.records.len(), 2_000);
        assert!(off.records.is_empty(), "retention off keeps no records");
        assert_eq!(off.n_finished(), 2_000);
        assert_eq!(off.generated_tokens, 2_000 * 32);
        // means are exact either way; quantiles within the bucket bound
        let (eo, ao) = (on.jct_summary(), off.jct_summary());
        assert!((eo.mean - ao.mean).abs() < 1e-6, "{} vs {}", eo.mean, ao.mean);
        assert_eq!(eo.min, ao.min);
        assert_eq!(eo.max, ao.max);
        assert!((ao.p99 / eo.p99 - 1.0).abs() < 0.04, "{} vs {}", ao.p99, eo.p99);
        let (et, at) = (on.ttft_summary(), off.ttft_summary());
        assert!((et.mean - at.mean).abs() < 1e-6);
        // comparison rows work without records
        off.busy_us = vec![1_000_000];
        let base = {
            let mut b = off.clone();
            b.busy_us = vec![2_000_000];
            b
        };
        assert!(off.vs_row("off vs base", &base).contains("perf/$"));
        assert!((off.perf_per_dollar_vs(&base) - 2.0).abs() < 1e-9);
    }
}
