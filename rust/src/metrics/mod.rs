//! Serving metrics (§5): TTFT, JCT, resource-usage time, perf-per-dollar.
//!
//! Resource usage follows the paper's definition: "the aggregated wall time
//! that the prefill and decode instances use to run a particular workload"
//! (busy time, per instance, summed). perf/$ is throughput-per-resource
//! normalized against a baseline run:
//!     perf/$  ∝  (1 / mean JCT) / (resource_time · $rate)
//! so `perf_per_dollar_vs(base)` reports the paper's "x-fold" improvements.

use crate::types::{RequestRecord, Us, US_PER_SEC};
use crate::util::{summarize, Summary};

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub records: Vec<RequestRecord>,
    /// Busy µs per instance (index = instance id).
    pub busy_us: Vec<Us>,
    /// µs each instance existed in the run (for utilization).
    pub alive_us: Vec<Us>,
    /// Total virtual duration of the run.
    pub makespan_us: Us,
    /// DES events processed by the driver (sim-throughput denominator for
    /// the perf-trajectory benches — see EXPERIMENTS.md §Perf).
    pub events: u64,
    /// Swap traffic observed (tokens), for Figure 18 diagnostics.
    pub swapped_tokens: u64,
    /// Number of instance flips that occurred (§3.5).
    pub flips: u32,
    /// Instances the elastic autoscaler added mid-run.
    pub scale_ups: u32,
    /// Instances the elastic autoscaler drained and retired mid-run.
    pub scale_downs: u32,
    /// Per-instance (heavy, light) decode assignments by *true* decode
    /// length — Figure 19's balance diagnostic. Indexed by instance id;
    /// non-decode instances stay (0, 0).
    pub decode_assign: Vec<(u32, u32)>,
}

impl RunMetrics {
    pub fn ttft_summary(&self) -> Summary {
        summarize(&self.records.iter().map(|r| r.ttft() as f64 / 1e3).collect::<Vec<_>>())
    }

    pub fn jct_summary(&self) -> Summary {
        summarize(&self.records.iter().map(|r| r.jct() as f64 / 1e3).collect::<Vec<_>>())
    }

    /// Aggregate busy time across instances, in seconds (the paper's
    /// "resource usage time").
    pub fn resource_seconds(&self) -> f64 {
        self.busy_us.iter().sum::<Us>() as f64 / US_PER_SEC as f64
    }

    /// Generated tokens per second of makespan.
    pub fn decode_throughput(&self) -> f64 {
        let toks: u64 = self.records.iter().map(|r| r.decode_len as u64).sum();
        toks as f64 / (self.makespan_us.max(1) as f64 / US_PER_SEC as f64)
    }

    /// Performance-per-dollar of this run relative to `base` (>1 = better):
    /// ratio of (1/meanJCT)/resource.
    pub fn perf_per_dollar_vs(&self, base: &RunMetrics) -> f64 {
        let own = 1.0 / (self.jct_summary().mean * self.resource_seconds());
        let other = 1.0 / (base.jct_summary().mean * base.resource_seconds());
        own / other
    }

    /// Mean utilization across instances that existed.
    pub fn utilization(&self) -> f64 {
        let busy: f64 = self.busy_us.iter().sum::<Us>() as f64;
        let alive: f64 = self.alive_us.iter().sum::<Us>() as f64;
        if alive == 0.0 {
            0.0
        } else {
            busy / alive
        }
    }

    /// Formatted single-line comparison against a baseline (used by the
    /// figure harness to print the paper's headline rows).
    pub fn vs_row(&self, name: &str, base: &RunMetrics) -> String {
        let dt = 1.0 - self.ttft_summary().mean / base.ttft_summary().mean;
        let dj = 1.0 - self.jct_summary().mean / base.jct_summary().mean;
        let dr = 1.0 - self.resource_seconds() / base.resource_seconds();
        format!(
            "{name}: TTFT {:+.0}%  JCT {:+.0}%  resource {:+.0}%  perf/$ {:.2}x",
            -dt * 100.0,
            -dj * 100.0,
            -dr * 100.0,
            self.perf_per_dollar_vs(base)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn rec(arrival: Us, first: Us, fin: Us, dlen: u32) -> RequestRecord {
        RequestRecord {
            id: 0,
            task: TaskType::Chat,
            prompt_len: 10,
            decode_len: dlen,
            arrival,
            first_token: first,
            finished: fin,
            predicted: None,
        }
    }

    fn run(jct_ms: f64, resource_s: f64) -> RunMetrics {
        RunMetrics {
            records: vec![rec(0, 1_000, (jct_ms * 1e3) as Us, 100)],
            busy_us: vec![(resource_s * 1e6) as Us],
            alive_us: vec![(resource_s * 2e6) as Us],
            makespan_us: 1_000_000,
            ..Default::default()
        }
    }

    #[test]
    fn ttft_and_jct_in_ms() {
        let m = run(250.0, 1.0);
        assert!((m.ttft_summary().mean - 1.0).abs() < 1e-9);
        assert!((m.jct_summary().mean - 250.0).abs() < 1e-9);
    }

    #[test]
    fn perf_per_dollar_rewards_speed_and_thrift() {
        let fast_cheap = run(100.0, 1.0);
        let slow_pricey = run(200.0, 2.0);
        let ratio = fast_cheap.perf_per_dollar_vs(&slow_pricey);
        assert!((ratio - 4.0).abs() < 1e-9, "{ratio}");
    }

    #[test]
    fn utilization_is_busy_over_alive() {
        let m = run(100.0, 1.0);
        assert!((m.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn throughput_counts_generated_tokens() {
        let m = run(100.0, 1.0); // 100 tokens over 1 s makespan
        assert!((m.decode_throughput() - 100.0).abs() < 1e-9);
    }
}
