//! Serving metrics (§5): TTFT, JCT, resource-usage time, perf-per-dollar.
//!
//! Resource usage follows the paper's definition: "the aggregated wall time
//! that the prefill and decode instances use to run a particular workload"
//! (busy time, per instance, summed). perf/$ is throughput-per-resource
//! normalized against a baseline run:
//!     perf/$  ∝  (1 / mean JCT) / (resource_time · $rate)
//! so `perf_per_dollar_vs(base)` reports the paper's "x-fold" improvements.
//!
//! Memory contract (million-request runs): every per-request quantity is
//! *streamed* at finish time into exact counters (`finished`,
//! `generated_tokens`) and log-bucketed histograms (`ttft_hist`,
//! `jct_hist`), so the summaries work with `records` retention switched
//! off. Retention stays on for golden/figure runs, where summaries are
//! computed exactly from the records as before.

use crate::slo::ClassDef;
use crate::types::{RequestRecord, Us, US_PER_SEC};
use crate::util::{summarize, LogHist, Summary};

/// Per-workload-class streamed counters + histograms — constant memory
/// per class however many requests stream through (the SLO counterpart
/// of the run-wide `ttft_hist`/`jct_hist`). Indexed by class id in
/// [`RunMetrics::per_class`].
#[derive(Clone, Debug, Default)]
pub struct ClassMetrics {
    /// Requests of this class that completed.
    pub finished: u64,
    /// Requests of this class the admission gate shed (counted, never
    /// silently dropped).
    pub shed: u64,
    /// Requests of this class permanently failed by faults (retry budget
    /// exhausted or capacity never returned) — the third conservation
    /// outcome: `finished + shed + failed == arrivals`.
    pub failed: u64,
    /// Requests of this class that *finished* after losing in-flight
    /// state to at least one fault (lost-then-recovered).
    pub recovered: u64,
    /// Streaming recovery-latency distribution (µs from first fault loss
    /// to finish) over recovered requests of this class.
    pub recovery_hist: LogHist,
    /// Finishes meeting the class TTFT deadline (all of them when the
    /// class declares none — vacuous attainment).
    pub ttft_attained: u64,
    /// Finishes with ≥ 2 decode tokens (the TPOT denominator; TPOT is
    /// undefined for single-token requests, which attain vacuously).
    pub tpot_eligible: u64,
    /// TPOT-eligible finishes meeting the class TPOT deadline.
    pub tpot_attained: u64,
    /// Finishes meeting *every* declared deadline (the goodput numerator).
    pub attained: u64,
    /// Streaming TTFT distribution (µs).
    pub ttft_hist: LogHist,
    /// Streaming JCT distribution (µs).
    pub jct_hist: LogHist,
    /// Streaming per-request mean TPOT distribution (µs/token, decode
    /// tokens past the first).
    pub tpot_hist: LogHist,
}

impl ClassMetrics {
    /// TTFT attainment fraction (1.0 when nothing finished).
    pub fn ttft_attainment(&self) -> f64 {
        if self.finished == 0 {
            1.0
        } else {
            self.ttft_attained as f64 / self.finished as f64
        }
    }

    /// TPOT attainment fraction over eligible finishes (1.0 when none).
    pub fn tpot_attainment(&self) -> f64 {
        if self.tpot_eligible == 0 {
            1.0
        } else {
            self.tpot_attained as f64 / self.tpot_eligible as f64
        }
    }

    /// Full-SLO attainment fraction (the per-class goodput ratio).
    pub fn attainment(&self) -> f64 {
        if self.finished == 0 {
            1.0
        } else {
            self.attained as f64 / self.finished as f64
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Per-request records. Retention is opt-in per run (`retain_records`;
    /// `Scenario`'s `records` knob): on for golden/figure runs (exact
    /// summaries), off for scale runs (constant memory — summaries come
    /// from the histograms below).
    pub records: Vec<RequestRecord>,
    /// Whether [`RunMetrics::note_finish`] pushes into `records`. Drivers
    /// set this from their config before the run starts.
    pub retain_records: bool,
    /// Requests finished — exact, counted whether or not records are kept.
    pub finished: u64,
    /// Σ decode_len over finished requests (throughput numerator).
    pub generated_tokens: u64,
    /// Streaming TTFT distribution in µs: exact count/sum/min/max,
    /// ≤ ~3.2% relative quantile error (see `util::LogHist`).
    pub ttft_hist: LogHist,
    /// Streaming JCT distribution in µs (same shape as `ttft_hist`).
    pub jct_hist: LogHist,
    /// Busy µs per instance (index = instance id).
    pub busy_us: Vec<Us>,
    /// µs each instance existed in the run (for utilization).
    pub alive_us: Vec<Us>,
    /// Total virtual duration of the run.
    pub makespan_us: Us,
    /// DES events processed by the driver (sim-throughput denominator for
    /// the perf-trajectory benches — see EXPERIMENTS.md §Perf).
    pub events: u64,
    /// Decode/coupled iterations absorbed into a macro-stepped event
    /// instead of paying their own queue round-trip (diagnostic for the
    /// collapsed event class; not part of the virtual-time trajectory).
    pub macro_steps: u64,
    /// High-water arena size = peak in-flight requests. The O(active)
    /// memory proof for scale runs: with records off, total run memory is
    /// proportional to this, not to the trace.
    pub peak_arena: usize,
    /// Swap traffic observed (tokens), for Figure 18 diagnostics.
    pub swapped_tokens: u64,
    /// Number of instance flips that occurred (§3.5).
    pub flips: u32,
    /// Instances the elastic autoscaler added mid-run.
    pub scale_ups: u32,
    /// Instances the elastic autoscaler drained and retired mid-run.
    pub scale_downs: u32,
    /// Per-instance (heavy, light) decode assignments by *true* decode
    /// length — Figure 19's balance diagnostic. Indexed by instance id;
    /// non-decode instances stay (0, 0).
    pub decode_assign: Vec<(u32, u32)>,
    /// The resolved workload-class table this run served under (empty =
    /// classless legacy run: implicit single class, no deadlines).
    /// Drivers stamp it from their config before the run starts; finish-
    /// time attainment reads deadlines from here.
    pub classes: Vec<ClassDef>,
    /// Per-class streamed counters + histograms, indexed by class id.
    /// Pre-sized to the declared table by [`RunMetrics::set_classes`]
    /// (zero-traffic tenants still report) and grown on demand past it;
    /// classless runs keep everything in slot 0.
    pub per_class: Vec<ClassMetrics>,
    /// Total requests the admission gate shed (Σ per-class sheds).
    pub shed: u64,
    /// Total finishes meeting every declared deadline — the goodput
    /// numerator. With no deadlines declared this equals `finished`, so
    /// goodput degenerates to plain throughput.
    pub attained: u64,
    /// Requests permanently failed by faults (Σ per-class). Completes the
    /// conservation law under fault injection:
    /// `finished + shed + failed == arrivals`.
    pub failed: u64,
    /// Requests that finished after surviving at least one fault loss
    /// (Σ per-class lost-then-recovered).
    pub recovered: u64,
    /// Streaming run-wide recovery-latency distribution (µs from first
    /// fault loss to finish) over recovered requests.
    pub recovery_hist: LogHist,
    /// Fault-plan events actually injected (skipped events excluded).
    pub faults_injected: u64,
    /// KV transfers that timed out against a link outage and re-sent.
    pub transfer_resends: u64,
    /// Virtual µs the coordinator spent in degraded mode (surviving
    /// capacity below the fault plan's watermark).
    pub degraded_us: Us,
    /// Prefix-cache lookups that matched at least one whole block
    /// (0 in cache-off runs — the legacy report shape is preserved).
    pub cache_hits: u64,
    /// Prefix-cache lookups that matched nothing.
    pub cache_misses: u64,
    /// Prefill tokens skipped because their prefix KV was cache-resident.
    pub prefill_tokens_saved: u64,
    /// Prefix-cache blocks evicted under capacity pressure.
    pub cache_evictions: u64,
    /// Wire µs hidden behind prefill compute by overlapped transfer
    /// granularities (chunk- or layer-level), vs shipping everything
    /// after the last chunk.
    pub overlap_us: Us,
    /// The run was cut short by an armed [`crate::sim::StopPolicy`] knob
    /// (successive-halving horizon or the optimizer's miss-budget abort).
    /// Aborted runs carry exact metrics for everything simulated up to
    /// the cut, but the conservation law `finished + shed + failed ==
    /// arrivals` does not hold — in-flight requests are never counted.
    pub aborted: bool,
    /// Heap allocations the `alloc-count` counting allocator observed in
    /// the steady-state window (second half of the run, cold sections
    /// excluded). Always 0 without the feature. Host-side diagnostic —
    /// never part of fingerprints or reports.
    pub steady_allocs: u64,
    /// Per-event-kind time/count table from the engine loop
    /// (`--profile-events`), moved out of the core at finalize. Host
    /// wall-clock diagnostic — never part of fingerprints or reports.
    pub event_profile: Option<Box<EventProfile>>,
}

/// Per-event-kind wall-time profile of the engine loop
/// (`--profile-events`): one `(count, total_nanos)` row per [`Event`]
/// variant, indexed by `Event::kind_index()`. Measures *host* time around
/// each `EngineHost::handle` call — purely diagnostic, it never touches
/// the virtual-time trajectory.
///
/// [`Event`]: crate::sim::Event
#[derive(Clone, Debug, Default)]
pub struct EventProfile {
    /// `(events handled, total handler nanos)` per event kind.
    pub rows: [(u64, u64); Self::KINDS],
}

impl EventProfile {
    /// Event-kind count — must equal the `Event` enum's variant count
    /// (`sim::tests::event_kind_indices_are_dense_and_stable` pins the
    /// mapping both ways).
    pub const KINDS: usize = 11;

    /// Display names, indexed like `rows` (= `Event::kind_index()`).
    pub const NAMES: [&'static str; Self::KINDS] = [
        "Arrival",
        "PrefillIterDone",
        "PredictDone",
        "TransferDone",
        "DecodeIterDone",
        "MonitorTick",
        "FlipDone",
        "CoupledIterDone",
        "Fault",
        "Restart",
        "Retry",
    ];

    /// Formatted table: one row per kind that handled any events, busiest
    /// (by total handler time) first, then a totals line.
    pub fn render(&self) -> Vec<String> {
        let mut idx: Vec<usize> = (0..Self::KINDS).filter(|&i| self.rows[i].0 > 0).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(self.rows[i].1));
        let total_n: u64 = self.rows.iter().map(|r| r.0).sum();
        let total_ns: u64 = self.rows.iter().map(|r| r.1).sum();
        let mut out = Vec::with_capacity(idx.len() + 1);
        for i in idx {
            let (n, ns) = self.rows[i];
            out.push(format!(
                "  {:<16} {:>10} events  {:>10.1} ms total  {:>8.0} ns/event  {:>5.1}%",
                Self::NAMES[i],
                n,
                ns as f64 / 1e6,
                ns as f64 / n.max(1) as f64,
                100.0 * ns as f64 / total_ns.max(1) as f64,
            ));
        }
        out.push(format!(
            "  {:<16} {:>10} events  {:>10.1} ms total",
            "total",
            total_n,
            total_ns as f64 / 1e6
        ));
        out
    }
}

/// TTFT/JCT/resource for one run, computed once and threaded through
/// comparison rows (each summary is a full collect + sort over records —
/// `vs_row` and perf/$ used to recompute them several times per row).
#[derive(Clone, Debug)]
pub struct RunSummaries {
    pub ttft: Summary,
    pub jct: Summary,
    pub resource_s: f64,
    /// SLO-attained finishes per second of makespan (the DistServe
    /// goodput lens; equals plain request throughput when no deadlines
    /// are declared).
    pub goodput_rps: f64,
}

/// perf/$ from precomputed summaries: ratio of (1/meanJCT)/resource.
pub fn perf_per_dollar(own: &RunSummaries, base: &RunSummaries) -> f64 {
    let a = 1.0 / (own.jct.mean * own.resource_s);
    let b = 1.0 / (base.jct.mean * base.resource_s);
    a / b
}

/// goodput/$ from precomputed summaries: ratio of goodput-per-resource —
/// requests completed *within their SLO* per resource-second, normalized
/// against a baseline run (>1 = better). NaN when the baseline achieved
/// zero goodput (the ratio is meaningless there).
pub fn goodput_per_dollar(own: &RunSummaries, base: &RunSummaries) -> f64 {
    let a = own.goodput_rps / own.resource_s;
    let b = base.goodput_rps / base.resource_s;
    if b == 0.0 {
        f64::NAN
    } else {
        a / b
    }
}

impl RunMetrics {
    /// The per-class slot for `class`, grown on demand (O(classes)
    /// memory, not O(requests) — the constant-memory contract holds).
    fn class_entry(per_class: &mut Vec<ClassMetrics>, class: u8) -> &mut ClassMetrics {
        let i = class as usize;
        if per_class.len() <= i {
            per_class.resize_with(i + 1, ClassMetrics::default);
        }
        &mut per_class[i]
    }

    /// Stamp the resolved workload-class table (drivers call this before
    /// the run starts) and pre-size the per-class ledger to cover every
    /// *declared* class — a tenant that happens to receive zero arrivals
    /// still gets its finished=0/shed=0 row in reports instead of
    /// silently vanishing.
    pub fn set_classes(&mut self, classes: Vec<ClassDef>) {
        if self.per_class.len() < classes.len() {
            self.per_class.resize_with(classes.len(), ClassMetrics::default);
        }
        self.classes = classes;
    }

    /// Fraction of prefix-cache lookups that hit (0.0 when the cache was
    /// off or never consulted).
    pub fn cache_hit_rate(&self) -> f64 {
        let lookups = self.cache_hits + self.cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / lookups as f64
        }
    }

    /// Display name of a class (table name, or `class<N>` past the table).
    pub fn class_name(&self, class: u8) -> String {
        self.classes
            .get(class as usize)
            .map(|c| c.name.clone())
            .unwrap_or_else(|| format!("class{class}"))
    }

    /// Stream one completed request into the metrics: exact counters +
    /// histograms always (run-wide and per-class); the full record only
    /// when retention is on. Returns `(ttft_violated, tpot_violated)`
    /// against the request's class deadlines, so the engine can fire
    /// `Observer::on_violation` without recomputing.
    pub fn note_finish(&mut self, rec: &RequestRecord) -> (bool, bool) {
        self.finished += 1;
        self.generated_tokens += rec.decode_len as u64;
        let ttft = rec.ttft();
        let jct = rec.jct();
        self.ttft_hist.record(ttft);
        self.jct_hist.record(jct);
        // Per-request mean TPOT: decode time over tokens past the first
        // (undefined for single-token requests, which attain vacuously).
        let tpot = if rec.decode_len > 1 {
            Some(rec.finished.saturating_sub(rec.first_token) / (rec.decode_len as u64 - 1))
        } else {
            None
        };
        let (ttft_dl, tpot_dl) = self
            .classes
            .get(rec.class as usize)
            .map(|c| (c.ttft_deadline_us, c.tpot_deadline_us))
            .unwrap_or((None, None));
        let ttft_violated = ttft_dl.is_some_and(|dl| ttft > dl);
        let tpot_violated = matches!((tpot_dl, tpot), (Some(dl), Some(t)) if t > dl);
        let c = Self::class_entry(&mut self.per_class, rec.class);
        c.finished += 1;
        c.ttft_hist.record(ttft);
        c.jct_hist.record(jct);
        if let Some(t) = tpot {
            c.tpot_hist.record(t);
            c.tpot_eligible += 1;
            if !tpot_violated {
                c.tpot_attained += 1;
            }
        }
        if !ttft_violated {
            c.ttft_attained += 1;
        }
        if !ttft_violated && !tpot_violated {
            c.attained += 1;
            self.attained += 1;
        }
        if self.retain_records {
            self.records.push(rec.clone());
        }
        (ttft_violated, tpot_violated)
    }

    /// Stream one admission-gate shed: counted run-wide and per class —
    /// shed requests are first-class outcomes, never silent drops.
    pub fn note_shed(&mut self, class: u8) {
        self.shed += 1;
        Self::class_entry(&mut self.per_class, class).shed += 1;
    }

    /// Stream one permanent fault failure: counted run-wide and per class
    /// (the `shed` twin for the fault path — failed requests are
    /// first-class outcomes too).
    pub fn note_fail(&mut self, class: u8) {
        self.failed += 1;
        Self::class_entry(&mut self.per_class, class).failed += 1;
    }

    /// Stream one recovered completion: `dur` is the µs from the
    /// request's first fault loss to its finish. Called by the engine
    /// just before `note_finish` stamps the record.
    pub fn note_recovery(&mut self, class: u8, dur: Us) {
        self.recovered += 1;
        self.recovery_hist.record(dur);
        let c = Self::class_entry(&mut self.per_class, class);
        c.recovered += 1;
        c.recovery_hist.record(dur);
    }

    /// SLO-attained finishes per second of makespan (goodput).
    pub fn goodput_rps(&self) -> f64 {
        self.attained as f64 / (self.makespan_us.max(1) as f64 / US_PER_SEC as f64)
    }

    /// Human-readable per-class SLO rows (one per class that saw any
    /// traffic) — what the CLI and examples print under the summary line.
    pub fn class_rows(&self) -> Vec<String> {
        let mut rows = Vec::new();
        for (i, c) in self.per_class.iter().enumerate() {
            // every *declared* class reports (even with zero traffic);
            // undeclared slots only appear once traffic touched them
            if i >= self.classes.len() && c.finished == 0 && c.shed == 0 && c.failed == 0 {
                continue;
            }
            let tier =
                self.classes.get(i).map(|d| d.tier.to_string()).unwrap_or_else(|| "-".into());
            let ttft = c.ttft_hist.summary_scaled(1e-3);
            let tpot = c.tpot_hist.summary_scaled(1e-3);
            let mut row = format!(
                "  class {:<12} tier {:<2} finished {:>6}  shed {:>5}  TTFT attain {:>5.1}% \
                 (mean {:>7.1} ms)  TPOT attain {:>5.1}% (mean {:>6.1} ms)  SLO attain {:>5.1}%",
                self.class_name(i as u8),
                tier,
                c.finished,
                c.shed,
                c.ttft_attainment() * 100.0,
                ttft.mean,
                c.tpot_attainment() * 100.0,
                tpot.mean,
                c.attainment() * 100.0,
            );
            // fault columns only when the run saw faults — fault-free
            // output stays byte-identical to pre-fault builds
            if self.failed > 0 || self.recovered > 0 {
                row.push_str(&format!(
                    "  failed {:>5}  recovered {:>5} (mean {:>7.1} ms)",
                    c.failed,
                    c.recovered,
                    c.recovery_hist.summary_scaled(1e-3).mean,
                ));
            }
            rows.push(row);
        }
        rows
    }

    /// Requests finished: the streamed counter, or the record count for
    /// hand-assembled metrics that never went through `note_finish`.
    pub fn n_finished(&self) -> usize {
        (self.finished as usize).max(self.records.len())
    }

    pub fn ttft_summary(&self) -> Summary {
        if self.records.is_empty() {
            self.ttft_hist.summary_scaled(1e-3)
        } else {
            summarize(&self.records.iter().map(|r| r.ttft() as f64 / 1e3).collect::<Vec<_>>())
        }
    }

    pub fn jct_summary(&self) -> Summary {
        if self.records.is_empty() {
            self.jct_hist.summary_scaled(1e-3)
        } else {
            summarize(&self.records.iter().map(|r| r.jct() as f64 / 1e3).collect::<Vec<_>>())
        }
    }

    /// Exact Σ JCT over finished requests, in µs. `LogHist` accumulates
    /// the true sum at record time (only quantiles are bucketed), so
    /// this holds with or without record retention. Telemetry's
    /// reconciliation invariant: an armed run's `accounted_us` equals
    /// this exactly — every finished request's phases partition its
    /// arrival→finish interval (tests/telemetry.rs pins it, slack 0).
    pub fn jct_sum_us(&self) -> u128 {
        self.jct_hist.sum()
    }

    /// Every comparison input computed once (see [`RunSummaries`]).
    pub fn summaries(&self) -> RunSummaries {
        RunSummaries {
            ttft: self.ttft_summary(),
            jct: self.jct_summary(),
            resource_s: self.resource_seconds(),
            goodput_rps: self.goodput_rps(),
        }
    }

    /// Aggregate busy time across instances, in seconds (the paper's
    /// "resource usage time").
    pub fn resource_seconds(&self) -> f64 {
        self.busy_us.iter().sum::<Us>() as f64 / US_PER_SEC as f64
    }

    /// Generated tokens per second of makespan.
    pub fn decode_throughput(&self) -> f64 {
        let toks: u64 = if self.records.is_empty() {
            self.generated_tokens
        } else {
            self.records.iter().map(|r| r.decode_len as u64).sum()
        };
        toks as f64 / (self.makespan_us.max(1) as f64 / US_PER_SEC as f64)
    }

    /// Performance-per-dollar of this run relative to `base` (>1 = better):
    /// ratio of (1/meanJCT)/resource.
    pub fn perf_per_dollar_vs(&self, base: &RunMetrics) -> f64 {
        perf_per_dollar(&self.summaries(), &base.summaries())
    }

    /// Goodput-per-dollar of this run relative to `base` (>1 = better):
    /// SLO-attained requests per resource-second, as a ratio.
    pub fn goodput_per_dollar_vs(&self, base: &RunMetrics) -> f64 {
        goodput_per_dollar(&self.summaries(), &base.summaries())
    }

    /// Mean utilization across instances that existed.
    pub fn utilization(&self) -> f64 {
        let busy: f64 = self.busy_us.iter().sum::<Us>() as f64;
        let alive: f64 = self.alive_us.iter().sum::<Us>() as f64;
        if alive == 0.0 {
            0.0
        } else {
            busy / alive
        }
    }

    /// Formatted single-line comparison against a baseline (used by the
    /// figure harness to print the paper's headline rows). Each side's
    /// summaries are computed exactly once for the whole row; callers
    /// that already hold them use [`vs_row_from`] directly.
    pub fn vs_row(&self, name: &str, base: &RunMetrics) -> String {
        vs_row_from(name, &self.summaries(), &base.summaries())
    }
}

/// The comparison row from precomputed summaries (see [`RunSummaries`]).
pub fn vs_row_from(name: &str, own: &RunSummaries, base: &RunSummaries) -> String {
    let dt = 1.0 - own.ttft.mean / base.ttft.mean;
    let dj = 1.0 - own.jct.mean / base.jct.mean;
    let dr = 1.0 - own.resource_s / base.resource_s;
    format!(
        "{name}: TTFT {:+.0}%  JCT {:+.0}%  resource {:+.0}%  perf/$ {:.2}x  goodput/$ {:.2}x",
        -dt * 100.0,
        -dj * 100.0,
        -dr * 100.0,
        perf_per_dollar(own, base),
        goodput_per_dollar(own, base)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskType;

    fn rec(arrival: Us, first: Us, fin: Us, dlen: u32) -> RequestRecord {
        RequestRecord {
            id: 0,
            task: TaskType::Chat,
            class: 0,
            prompt_len: 10,
            decode_len: dlen,
            arrival,
            first_token: first,
            finished: fin,
            predicted: None,
            retries: 0,
            recovered: false,
        }
    }

    fn run(jct_ms: f64, resource_s: f64) -> RunMetrics {
        RunMetrics {
            records: vec![rec(0, 1_000, (jct_ms * 1e3) as Us, 100)],
            busy_us: vec![(resource_s * 1e6) as Us],
            alive_us: vec![(resource_s * 2e6) as Us],
            makespan_us: 1_000_000,
            ..Default::default()
        }
    }

    #[test]
    fn ttft_and_jct_in_ms() {
        let m = run(250.0, 1.0);
        assert!((m.ttft_summary().mean - 1.0).abs() < 1e-9);
        assert!((m.jct_summary().mean - 250.0).abs() < 1e-9);
    }

    #[test]
    fn perf_per_dollar_rewards_speed_and_thrift() {
        let fast_cheap = run(100.0, 1.0);
        let slow_pricey = run(200.0, 2.0);
        let ratio = fast_cheap.perf_per_dollar_vs(&slow_pricey);
        assert!((ratio - 4.0).abs() < 1e-9, "{ratio}");
    }

    #[test]
    fn utilization_is_busy_over_alive() {
        let m = run(100.0, 1.0);
        assert!((m.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn throughput_counts_generated_tokens() {
        let m = run(100.0, 1.0); // 100 tokens over 1 s makespan
        assert!((m.decode_throughput() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn per_class_attainment_and_goodput() {
        use crate::slo::ClassSpec;
        let mut m = RunMetrics {
            classes: vec![
                ClassSpec {
                    name: "chat".into(),
                    ttft_ms: Some(100.0),
                    tpot_ms: Some(10.0),
                    ..Default::default()
                }
                .to_def(),
                ClassSpec { name: "batch".into(), tier: 2, ..Default::default() }.to_def(),
            ],
            ..Default::default()
        };
        // chat, on time: TTFT 50 ms ≤ 100 ms, TPOT (450ms/99) ≈ 4.5 ms ≤ 10
        let mut a = rec(0, 50_000, 500_000, 100);
        let v = m.note_finish(&a);
        assert_eq!(v, (false, false));
        // chat, TTFT blown
        a = rec(0, 150_000, 500_000, 100);
        assert_eq!(m.note_finish(&a), (true, false));
        // chat, TPOT blown: 2 tokens, 50 ms between first and last > 10 ms
        a = rec(0, 10_000, 60_000, 2);
        assert_eq!(m.note_finish(&a), (false, true));
        // chat single-token: TPOT undefined → vacuous attainment
        a = rec(0, 10_000, 10_000, 1);
        assert_eq!(m.note_finish(&a), (false, false));
        // batch class: no deadlines, anything attains
        let mut b = rec(0, 9_000_000, 99_000_000, 50);
        b.class = 1;
        assert_eq!(m.note_finish(&b), (false, false));
        m.note_shed(1);
        m.note_shed(1);

        let chat = &m.per_class[0];
        assert_eq!((chat.finished, chat.ttft_attained, chat.attained), (4, 3, 2));
        assert_eq!((chat.tpot_eligible, chat.tpot_attained), (3, 2));
        assert!((chat.ttft_attainment() - 0.75).abs() < 1e-12);
        assert!((chat.attainment() - 0.5).abs() < 1e-12);
        let batch = &m.per_class[1];
        assert_eq!((batch.finished, batch.shed, batch.attained), (1, 2, 1));
        assert_eq!((m.shed, m.attained, m.finished), (2, 3, 5));
        // goodput: 3 attained over a 1 s makespan; classless ⇒ throughput
        m.makespan_us = 1_000_000;
        assert!((m.goodput_rps() - 3.0).abs() < 1e-9);
        let rows = m.class_rows();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].contains("chat") && rows[0].contains("attain"), "{}", rows[0]);
        assert!(rows[1].contains("batch") && rows[1].contains("shed"), "{}", rows[1]);
        assert_eq!(m.class_name(7), "class7");
    }

    #[test]
    fn fault_outcomes_count_per_class_and_render_rows() {
        let mut m = RunMetrics::default();
        m.note_fail(0);
        m.note_fail(2);
        m.note_recovery(2, 150_000);
        let mut r = rec(0, 1_000, 2_000, 4);
        r.class = 2;
        r.recovered = true;
        r.retries = 1;
        m.note_finish(&r);
        assert_eq!(m.failed, 2);
        assert_eq!(m.recovered, 1);
        assert_eq!(m.per_class[0].failed, 1);
        assert_eq!(m.per_class[2].failed, 1);
        assert_eq!(m.per_class[2].recovered, 1);
        assert_eq!(m.per_class[2].recovery_hist.count(), 1);
        assert_eq!(m.recovery_hist.count(), 1);
        let rows = m.class_rows();
        assert!(rows.iter().any(|r| r.contains("failed")), "fault columns render: {rows:?}");
        // conservation bookkeeping: 1 finished + 0 shed + 2 failed = 3 outcomes
        assert_eq!(m.finished + m.shed + m.failed, 3);
        // fault-free runs keep the legacy row shape
        let clean = RunMetrics::default();
        assert!(!clean.class_rows().iter().any(|r| r.contains("failed")));
    }

    #[test]
    fn set_classes_presizes_so_zero_traffic_tenants_report() {
        use crate::slo::ClassSpec;
        let mut m = RunMetrics::default();
        m.set_classes(vec![
            ClassSpec { name: "chat".into(), ..Default::default() }.to_def(),
            ClassSpec { name: "idle".into(), tier: 2, ..Default::default() }.to_def(),
        ]);
        assert_eq!(m.per_class.len(), 2, "declared classes get ledger slots up front");
        m.note_finish(&rec(0, 1_000, 2_000, 4));
        let rows = m.class_rows();
        assert_eq!(rows.len(), 2, "the zero-traffic tenant still reports");
        assert!(rows[1].contains("idle"), "{}", rows[1]);
        assert_eq!(m.per_class[1].finished, 0);
    }

    #[test]
    fn goodput_per_dollar_tracks_attained_per_resource() {
        // same resource, twice the attained rate → 2x goodput/$
        let mut a = run(100.0, 1.0);
        a.attained = 4;
        let mut b = run(100.0, 1.0);
        b.attained = 2;
        assert!((a.goodput_per_dollar_vs(&b) - 2.0).abs() < 1e-9);
        // vs_row renders both dollar lenses
        assert!(a.vs_row("a vs b", &b).contains("goodput/$"));
        // zero-goodput baseline: ratio is meaningless → NaN
        let mut z = run(100.0, 1.0);
        z.attained = 0;
        assert!(a.goodput_per_dollar_vs(&z).is_nan());
    }

    #[test]
    fn event_profile_renders_busiest_first_with_totals() {
        let mut p = EventProfile::default();
        p.rows[0] = (10, 5_000_000); // Arrival: 10 events, 5 ms
        p.rows[4] = (100, 20_000_000); // DecodeIterDone: 100 events, 20 ms
        let rows = p.render();
        assert_eq!(rows.len(), 3, "two active kinds + totals: {rows:?}");
        assert!(rows[0].contains("DecodeIterDone"), "busiest first: {}", rows[0]);
        assert!(rows[1].contains("Arrival"), "{}", rows[1]);
        assert!(rows[2].contains("total") && rows[2].contains("110"), "{}", rows[2]);
    }

    #[test]
    fn records_off_metrics_stream_through_histograms() {
        let mut on = RunMetrics { retain_records: true, ..Default::default() };
        let mut off = RunMetrics { retain_records: false, ..Default::default() };
        let mut t = 0u64;
        for i in 0..2_000u64 {
            t += 350 + (i * 7919) % 9_000; // deterministic skewed arrivals
            let r = rec(t, t + 40_000 + (i % 50) * 1_000, t + 300_000 + (i % 211) * 4_000, 32);
            on.note_finish(&r);
            off.note_finish(&r);
        }
        assert_eq!(on.records.len(), 2_000);
        assert!(off.records.is_empty(), "retention off keeps no records");
        assert_eq!(off.n_finished(), 2_000);
        assert_eq!(off.generated_tokens, 2_000 * 32);
        // means are exact either way; quantiles within the bucket bound
        let (eo, ao) = (on.jct_summary(), off.jct_summary());
        assert!((eo.mean - ao.mean).abs() < 1e-6, "{} vs {}", eo.mean, ao.mean);
        assert_eq!(eo.min, ao.min);
        assert_eq!(eo.max, ao.max);
        assert!((ao.p99 / eo.p99 - 1.0).abs() < 0.04, "{} vs {}", ao.p99, eo.p99);
        let (et, at) = (on.ttft_summary(), off.ttft_summary());
        assert!((et.mean - at.mean).abs() < 1e-6);
        // comparison rows work without records
        off.busy_us = vec![1_000_000];
        let base = {
            let mut b = off.clone();
            b.busy_us = vec![2_000_000];
            b
        };
        assert!(off.vs_row("off vs base", &base).contains("perf/$"));
        assert!((off.perf_per_dollar_vs(&base) - 2.0).abs() < 1e-9);
    }
}
