//! Cluster configuration: topology, policies, hardware emulation knobs.

use crate::costmodel::CostModel;
use crate::decode::DecodePolicy;
use crate::fabric::Link;
use crate::prefill::{DispatchPolicy, PrefillPolicy};
use crate::slo::SloConfig;
use crate::types::Us;

/// How the length predictor shares the prefill accelerator (§3.3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorMode {
    /// Run predict model and main LLM concurrently: no added queueing
    /// latency, but concurrent chunks slow ~10% under stress (Figure 17).
    Parallel,
    /// Predict first, then prefill: main LLM unaffected, but every request
    /// pays the predictor's latency up front.
    Sequential,
    /// No prediction at all (ablation): schedulers fall back to
    /// one-granule assumptions.
    Disabled,
}

/// Instance-flip policy (§3.5): flip an instance that has been idle for
/// `idle_us` toward the role with queued work.
#[derive(Clone, Copy, Debug)]
pub struct FlipConfig {
    pub idle_us: Us,
    /// Actual role-switch cost once drained (paper: 5–7 ms).
    pub flip_min_us: Us,
    pub flip_max_us: Us,
    /// Never flip below this many instances of either role.
    pub min_per_role: usize,
}

impl Default for FlipConfig {
    fn default() -> Self {
        FlipConfig { idle_us: 60_000_000, flip_min_us: 5_000, flip_max_us: 7_000, min_per_role: 1 }
    }
}

/// Elastic instance-pool policy: grow the pool when a role's backlog per
/// active instance exceeds its threshold, drain + retire instances that
/// sit idle (Arrow-style adaptive repurposing, arXiv:2505.11916, applied
/// to pool *size* where flipping covers pool *shape*). Each monitor tick
/// makes at most one new scaling *decision* (one scale-up or one new
/// drain); drains already in progress complete (retire) whenever their
/// last work item leaves, so a tick can additionally finish several.
#[derive(Clone, Copy, Debug)]
pub struct ElasticConfig {
    /// Hard cap on non-retired instances (live + draining + flipping).
    pub max_instances: usize,
    /// Scale prefill up when queued+in-flight prompt tokens per active
    /// prefill instance exceed this.
    pub prefill_up_tokens: u64,
    /// Scale decode up when total decode jobs per active decode instance
    /// exceed this.
    pub decode_up_jobs: u64,
    /// Drain + retire an instance idle at least this long.
    pub down_idle_us: Us,
    /// Never retire below this many active instances of either role.
    pub min_per_role: usize,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            max_instances: 8,
            prefill_up_tokens: 4096,
            decode_up_jobs: 32,
            down_idle_us: 2_000_000,
            min_per_role: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub n_prefill: usize,
    pub n_decode: usize,
    /// Coupled (vanilla-vLLM) instances serving *inside* this cluster —
    /// the hybrid-fleet study. 0 (the default) is the pure disaggregated
    /// paper setup; ≥ 1 runs both architectures in one simulation over
    /// one arena, with arrivals routed to whichever entry point is least
    /// loaded.
    pub n_coupled: usize,
    /// Fixed batch size coupled instances use for both phases (vanilla
    /// vLLM semantics, §5.2.1; mirrors `BaselineConfig::prefill_batch`).
    pub coupled_batch: usize,
    /// ChunkSize in tokens (512 for OPT-13B on V100, §3.3.3).
    pub chunk_size: u32,
    pub prefill_policy: PrefillPolicy,
    /// PrefillSchedBatch (§3.3.1).
    pub sched_batch: usize,
    /// Shortest-remaining-time-first chunk assembly — the preemptive
    /// scheduling §3.3.1 notes chunked prefill enables but leaves to
    /// future work. Implemented here as an ablation (off by default).
    pub srtf_chunking: bool,
    pub dispatch: DispatchPolicy,
    pub decode_policy: DecodePolicy,
    /// Continuous-batching cap per decode instance.
    pub max_batch: u32,
    /// Prefill→decode KV link (TS-RoCE / TS-NVLink / Indirect).
    pub link: Link,
    /// KV transfer granularity (§3.3.4): the paper implements
    /// request-level; chunk-level overlaps shipping with later chunks'
    /// compute (its noted future work — kept as an ablation).
    pub transfer_granularity: crate::fabric::Granularity,
    pub predictor_mode: PredictorMode,
    /// Bucket-prediction accuracy (sim oracle): paper acc-200 = 0.749.
    pub predictor_accuracy: f64,
    pub granularity: u32,
    pub n_buckets: u8,
    /// Cluster-monitor broadcast period (paper: ~100 ms).
    pub monitor_interval_us: Us,
    pub flip: Option<FlipConfig>,
    /// Elastic pool growth/shrink policy; `None` keeps the pool static.
    pub elastic: Option<ElasticConfig>,
    /// Keep per-request `RequestRecord`s in the run metrics (exact
    /// summaries, O(requests) memory). Scale runs turn this off and read
    /// the constant-memory streaming histograms instead.
    pub retain_records: bool,
    /// Collapse decode/coupled iteration chains into one macro-stepped
    /// event when no external event can land inside the window. Pure perf
    /// knob: the virtual-time trajectory and every record are identical
    /// either way (parity-tested in tests/golden.rs); off = one event per
    /// iteration, the reference stepping.
    pub macro_step: bool,
    /// SLO multi-tenancy: workload-class table + admission gate (see
    /// `slo::SloConfig`). The default — no classes, admission off — is
    /// the classless legacy behavior, bit-identical to pre-SLO builds.
    pub slo: SloConfig,
    /// Deterministic fault injection: chaos schedule + recovery policy
    /// (see `fault::FaultConfig`). `None` — the default — runs fault-free
    /// and is bit-identical to pre-fault builds.
    pub fault: Option<crate::fault::FaultConfig>,
    /// Per-prefill-instance prefix cache (radix KV reuse). `None` — the
    /// default — skips cache bookkeeping entirely and is bit-identical to
    /// pre-cache builds.
    pub prefix_cache: Option<crate::prefixcache::PrefixCacheConfig>,
    /// Collect a per-event-kind wall-time profile during the run (the
    /// `--profile-events` CLI flag). Observability only: the virtual-time
    /// trajectory, records, and fingerprints are identical either way —
    /// the profile lives outside the fingerprinted metrics.
    pub profile_events: bool,
    /// Early-stop knobs (successive-halving rungs, miss-budget aborts).
    /// Off by default — the normal run-to-completion semantics (see
    /// [`crate::sim::StopPolicy`]).
    pub stop: crate::sim::StopPolicy,
    pub cost: CostModel,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_prefill: 1,
            n_decode: 1,
            n_coupled: 0,
            coupled_batch: 16,
            chunk_size: 512,
            prefill_policy: PrefillPolicy::Sjf,
            sched_batch: 16,
            srtf_chunking: false,
            dispatch: DispatchPolicy::PowerOfTwo,
            decode_policy: DecodePolicy::ReserveDynamic,
            max_batch: 128,
            link: Link::roce200(),
            transfer_granularity: crate::fabric::Granularity::RequestLevel,
            predictor_mode: PredictorMode::Parallel,
            predictor_accuracy: 0.749,
            granularity: 200,
            n_buckets: 8,
            monitor_interval_us: 100_000,
            flip: Some(FlipConfig::default()),
            elastic: None,
            retain_records: true,
            macro_step: true,
            slo: SloConfig::default(),
            fault: None,
            prefix_cache: None,
            profile_events: false,
            stop: crate::sim::StopPolicy::off(),
            cost: CostModel::default(),
            seed: 0,
        }
    }
}

impl ClusterConfig {
    /// The §5.1 evaluation setup: TS-RoCE emulated hardware.
    pub fn ts_roce(n_prefill: usize, n_decode: usize) -> Self {
        ClusterConfig { n_prefill, n_decode, link: Link::roce200(), ..Default::default() }
    }

    /// The §5.1 evaluation setup: TS-NVLink emulated hardware.
    pub fn ts_nvlink(n_prefill: usize, n_decode: usize) -> Self {
        ClusterConfig { n_prefill, n_decode, link: Link::nvlink(), ..Default::default() }
    }
}
