//! Cluster configuration: topology, policies, hardware emulation knobs.

use crate::costmodel::CostModel;
use crate::decode::DecodePolicy;
use crate::fabric::Link;
use crate::prefill::{DispatchPolicy, PrefillPolicy};
use crate::types::Us;

/// How the length predictor shares the prefill accelerator (§3.3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorMode {
    /// Run predict model and main LLM concurrently: no added queueing
    /// latency, but concurrent chunks slow ~10% under stress (Figure 17).
    Parallel,
    /// Predict first, then prefill: main LLM unaffected, but every request
    /// pays the predictor's latency up front.
    Sequential,
    /// No prediction at all (ablation): schedulers fall back to
    /// one-granule assumptions.
    Disabled,
}

/// Instance-flip policy (§3.5): flip an instance that has been idle for
/// `idle_us` toward the role with queued work.
#[derive(Clone, Copy, Debug)]
pub struct FlipConfig {
    pub idle_us: Us,
    /// Actual role-switch cost once drained (paper: 5–7 ms).
    pub flip_min_us: Us,
    pub flip_max_us: Us,
    /// Never flip below this many instances of either role.
    pub min_per_role: usize,
}

impl Default for FlipConfig {
    fn default() -> Self {
        FlipConfig { idle_us: 60_000_000, flip_min_us: 5_000, flip_max_us: 7_000, min_per_role: 1 }
    }
}

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub n_prefill: usize,
    pub n_decode: usize,
    /// ChunkSize in tokens (512 for OPT-13B on V100, §3.3.3).
    pub chunk_size: u32,
    pub prefill_policy: PrefillPolicy,
    /// PrefillSchedBatch (§3.3.1).
    pub sched_batch: usize,
    /// Shortest-remaining-time-first chunk assembly — the preemptive
    /// scheduling §3.3.1 notes chunked prefill enables but leaves to
    /// future work. Implemented here as an ablation (off by default).
    pub srtf_chunking: bool,
    pub dispatch: DispatchPolicy,
    pub decode_policy: DecodePolicy,
    /// Continuous-batching cap per decode instance.
    pub max_batch: u32,
    /// Prefill→decode KV link (TS-RoCE / TS-NVLink / Indirect).
    pub link: Link,
    /// KV transfer granularity (§3.3.4): the paper implements
    /// request-level; chunk-level overlaps shipping with later chunks'
    /// compute (its noted future work — kept as an ablation).
    pub transfer_granularity: crate::fabric::Granularity,
    pub predictor_mode: PredictorMode,
    /// Bucket-prediction accuracy (sim oracle): paper acc-200 = 0.749.
    pub predictor_accuracy: f64,
    pub granularity: u32,
    pub n_buckets: u8,
    /// Cluster-monitor broadcast period (paper: ~100 ms).
    pub monitor_interval_us: Us,
    pub flip: Option<FlipConfig>,
    pub cost: CostModel,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_prefill: 1,
            n_decode: 1,
            chunk_size: 512,
            prefill_policy: PrefillPolicy::Sjf,
            sched_batch: 16,
            srtf_chunking: false,
            dispatch: DispatchPolicy::PowerOfTwo,
            decode_policy: DecodePolicy::ReserveDynamic,
            max_batch: 128,
            link: Link::roce200(),
            transfer_granularity: crate::fabric::Granularity::RequestLevel,
            predictor_mode: PredictorMode::Parallel,
            predictor_accuracy: 0.749,
            granularity: 200,
            n_buckets: 8,
            monitor_interval_us: 100_000,
            flip: Some(FlipConfig::default()),
            cost: CostModel::default(),
            seed: 0,
        }
    }
}

impl ClusterConfig {
    /// The §5.1 evaluation setup: TS-RoCE emulated hardware.
    pub fn ts_roce(n_prefill: usize, n_decode: usize) -> Self {
        ClusterConfig { n_prefill, n_decode, link: Link::roce200(), ..Default::default() }
    }

    /// The §5.1 evaluation setup: TS-NVLink emulated hardware.
    pub fn ts_nvlink(n_prefill: usize, n_decode: usize) -> Self {
        ClusterConfig { n_prefill, n_decode, link: Link::nvlink(), ..Default::default() }
    }
}
